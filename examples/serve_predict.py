"""Batched-inference microbenchmark: the shape-bucketed PredictEngine
serving mixed request sizes with zero recompiles after warmup.

Trains a small binary model, wraps it in `repro.serve.PredictEngine`, then
replays a mixed-batch-size request trace (1-row point lookups up to
4k-row bulk scoring). The engine pads every request onto a power-of-two
row-bucket ladder, so the whole trace reuses the warmup-compiled programs
— the script asserts trace_count does not move — and reports p50/p99
request latency and end-to-end rows/s.

    PYTHONPATH=src python examples/serve_predict.py
"""
import numpy as np

from repro.core import Booster, DeviceDMatrix
from repro.serve import PredictEngine

# --- train a model to serve ---------------------------------------------
rng = np.random.default_rng(0)
n, f = 20_000, 12
x = rng.normal(size=(n, f)).astype(np.float32)
y = ((x[:, 0] * x[:, 1] + x[:, 2] > 0.1)).astype(np.float32)
x[rng.random(x.shape) < 0.05] = np.nan

bst = Booster(n_rounds=40, max_depth=5, objective="binary:logistic")
bst.fit(DeviceDMatrix(x, label=y))

# --- engine: compile the bucket ladder once, up front --------------------
engine = PredictEngine(bst, buckets=(16, 64, 256, 1024, 4096))
engine.warmup()
traces_after_warmup = engine.trace_count
print(f"warmup compiled {traces_after_warmup} bucket programs")

# --- replay a mixed-size request trace -----------------------------------
sizes = [1, 3, 16, 50, 100, 333, 777, 1024, 2000, 4096] * 5
off = 0
for sz in sizes:
    p = engine.predict(x[off:off + sz])
    assert p.shape == (sz,)
    off = (off + sz) % (n - 4096)

recompiles = engine.trace_count - traces_after_warmup
assert recompiles == 0, f"bucketing failed: {recompiles} recompiles"
print(f"served {len(sizes)} requests across {len(set(sizes))} batch sizes, "
      "0 recompiles")

# --- latency / throughput ------------------------------------------------
s = engine.stats()
print(f"p50 {s['p50_ms']:.2f} ms  p99 {s['p99_ms']:.2f} ms  "
      f"{s['rows_per_s']:,.0f} rows/s over {s['rows']:,} rows")

# parity with the plain predict path, on a NaN-bearing slice
direct = np.asarray(bst.predict(x[:777]))
assert np.array_equal(engine.predict(x[:777]), direct)
print("engine output matches Booster.predict exactly")
