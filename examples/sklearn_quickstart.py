"""sklearn estimator facade: fit/predict/score, multi-metric early
stopping, custom losses through the registries, and GridSearchCV driving
the booster like any other sklearn estimator.

    PYTHONPATH=src python examples/sklearn_quickstart.py
"""
import numpy as np

from repro.sklearn import HAVE_SKLEARN, XGBClassifier, XGBRegressor

rng = np.random.default_rng(0)
n, f = 8_000, 12
x = rng.normal(size=(n, f)).astype(np.float32)
y_reg = (x @ rng.normal(size=f) + 0.4 * x[:, 0] * x[:, 1]).astype(np.float32)
y_cls = np.where(y_reg > 0, "pos", "neg")
xt, xv = x[:6_000], x[6_000:]

# --- classifier: string labels, multi-metric in-scan eval, early stop ----
clf = XGBClassifier(n_estimators=60, max_depth=5,
                    eval_metric=["logloss", "auc"], early_stopping_rounds=8)
clf.fit(xt, y_cls[:6_000], eval_set=[(xv, y_cls[6_000:])])
print("classes:", clf.classes_, "| best_iteration:", clf.best_iteration_)
print("holdout accuracy:", clf.score(xv, y_cls[6_000:]))
print("proba row:", clf.predict_proba(xv[:1])[0])

# --- regressor: a beyond-paper objective through the same facade ---------
q90 = XGBRegressor(n_estimators=40, max_depth=4, objective="reg:quantile",
                   quantile_alpha=0.9)
q90.fit(xt, y_reg[:6_000])
cover = float(np.mean(y_reg[6_000:] <= q90.predict(xv)))
print(f"q90 holdout coverage: {cover:.3f} (target 0.9)")

# --- sklearn meta-estimators work out of the box -------------------------
if HAVE_SKLEARN:
    from sklearn.model_selection import GridSearchCV

    gs = GridSearchCV(XGBClassifier(n_estimators=15),
                      {"max_depth": [3, 5]}, cv=2)
    gs.fit(x[:3_000], y_cls[:3_000])
    print("GridSearchCV best:", gs.best_params_,
          f"(cv accuracy {gs.best_score_:.3f})")
else:
    print("scikit-learn not installed; skipped GridSearchCV demo")
