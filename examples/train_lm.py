"""Train a small LM with the framework's model substrate + AdamW + token
pipeline (deliverable b's second scenario; the assigned architectures are
selectable with --arch).

    PYTHONPATH=src python examples/train_lm.py --arch glm4-9b --steps 100
"""
import argparse

from repro.configs import ARCHS, get_arch
from repro.launch.train import train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCHS, default="yi-6b")
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--full", action="store_true",
                help="full config (default: reduced variant for CPU)")
args = ap.parse_args()

cfg = get_arch(args.arch)
if not args.full:
    cfg = cfg.reduced(n_layers=2, d_model=256)
print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
      f"({cfg.arch_type})")
params, hist = train_loop(cfg, args.steps, args.batch, args.seq, lr=3e-3)
print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
