"""Quickstart: train the paper's GBDT on a binary task, evaluate, save.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import BoosterConfig, train, predict_proba
from repro.checkpoint import save_ensemble, load_ensemble

# --- data: 20k rows, 20 features, nonlinear signal + 5% missing ---------
rng = np.random.default_rng(0)
n, f = 20_000, 20
x = rng.normal(size=(n, f)).astype(np.float32)
y = ((x[:, 0] * x[:, 1] + np.sin(2 * x[:, 2]) + x[:, 3] > 0.2)).astype(np.float32)
x[rng.random(x.shape) < 0.05] = np.nan
xt, yt, xv, yv = x[:16_000], y[:16_000], x[16_000:], y[16_000:]

# --- train (Figure 1 pipeline: quantise -> compress -> boost) -----------
cfg = BoosterConfig(
    n_rounds=60, max_depth=6, learning_rate=0.3, max_bins=256,
    objective="binary:logistic",
)
state = train(xt, yt, cfg, eval_set=(xv, yv), verbose_every=20,
              callback=lambda r, rec: print(rec))

print(f"compressed matrix: {state.matrix.bits}-bit, "
      f"{state.matrix.compression_ratio():.1f}x smaller than fp32")

# --- evaluate ------------------------------------------------------------
p = np.asarray(predict_proba(state.ensemble, xv, cfg.max_depth, cfg.objective))
print("valid accuracy:", float(np.mean((p > 0.5) == yv)))

# --- save / load ----------------------------------------------------------
save_ensemble("/tmp/quickstart_ens.msgpack", state.ensemble)
ens = load_ensemble("/tmp/quickstart_ens.msgpack")
p2 = np.asarray(predict_proba(ens, xv, cfg.max_depth, cfg.objective))
assert np.allclose(p, p2)
print("checkpoint roundtrip OK")
