"""Quickstart: the two-noun API — DeviceDMatrix (quantise + compress once)
and Booster (fit / predict / save / load, self-describing).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Booster, DeviceDMatrix

# --- data: 20k rows, 20 features, nonlinear signal + 5% missing ---------
rng = np.random.default_rng(0)
n, f = 20_000, 20
x = rng.normal(size=(n, f)).astype(np.float32)
y = ((x[:, 0] * x[:, 1] + np.sin(2 * x[:, 2]) + x[:, 3] > 0.2)).astype(np.float32)
x[rng.random(x.shape) < 0.05] = np.nan
xt, yt, xv, yv = x[:16_000], y[:16_000], x[16_000:], y[16_000:]

# --- quantise + compress ONCE (Figure 1's left boxes) --------------------
dtrain = DeviceDMatrix(xt, label=yt)           # own quantile cuts
dvalid = DeviceDMatrix(xv, label=yv, ref=dtrain)  # shares dtrain's cuts
print(dtrain, "->", f"{dtrain.compression_ratio():.1f}x smaller than fp32")

# --- fit: per-round eval metrics computed INSIDE the training scan -------
bst = Booster(n_rounds=60, max_depth=6, learning_rate=0.3,
              objective="binary:logistic")
bst.fit(dtrain, evals=[(dvalid, "valid")], verbose_every=20,
        callback=lambda r, rec: print(rec))

# --- predict: numpy in, no max_depth / objective arguments ---------------
p = np.asarray(bst.predict(xv))
print("valid accuracy:", float(np.mean((p > 0.5) == yv)))

# --- the DeviceDMatrix is reusable: continue training, no re-quantise ----
bst.update(dtrain, 20)
print("continued to", bst.n_rounds_trained, "rounds:", bst.eval(dvalid, "valid"))

# --- save / load: the checkpoint is self-describing ----------------------
bst.save("/tmp/quickstart_booster.msgpack")
p2 = np.asarray(Booster.load("/tmp/quickstart_booster.msgpack").predict(xv))
assert np.array_equal(np.asarray(bst.predict(xv)), p2)
print("checkpoint roundtrip OK (bit-identical predictions)")
