"""Batched decode serving demo: prefill a prompt batch, then decode tokens
step by step with the KV cache (the decode_32k shape's serve_step, at CPU
scale).

    PYTHONPATH=src python examples/serve_decode.py --arch glm4-9b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.models import NO_SHARDING, build_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCHS, default="glm4-9b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
key = jax.random.PRNGKey(1)
prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

cap = args.prompt_len + args.gen
cache = model.init_cache(args.batch, cap, dtype=jnp.float32)
decode = jax.jit(lambda p, b, c, i: model.decode_fn(p, b, c, i, NO_SHARDING))

# prefill by stepping the prompt (simple; a production server would batch it)
tok = prompt[:, :1]
t0 = time.perf_counter()
for t in range(args.prompt_len):
    logits, cache = decode(params, {"tokens": prompt[:, t:t+1]}, cache, t)
# greedy generation
out = []
tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
for t in range(args.prompt_len, cap):
    out.append(tok)
    logits, cache = decode(params, {"tokens": tok}, cache, t)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
dt = time.perf_counter() - t0
gen = jnp.concatenate(out, axis=1)
print(f"{args.arch} (reduced): generated {gen.shape} tokens in {dt:.2f}s "
      f"({args.batch * args.gen / dt:.1f} tok/s incl. prefill steps)")
print("first sequence:", gen[0][:16].tolist())
