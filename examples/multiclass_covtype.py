"""Multiclass (covtype-shaped, 7 classes) — the paper's Table 2 scenario
where the GPU competitors struggled (cat-gpu N/A). Softmax gradients are
evaluated on-device (beyond-paper: the 2018 paper computed multiclass
gradients on CPU). The saved Booster is self-describing: loading it back
needs no max_depth / objective / n_classes.

    PYTHONPATH=src python examples/multiclass_covtype.py
"""
import numpy as np
from repro.core import Booster, DeviceDMatrix
from repro.data import make_dataset

x, y, spec = make_dataset("covtype", n_rows=20_000)
n_tr = 16_000
dtrain = DeviceDMatrix(x[:n_tr], label=y[:n_tr], max_bins=128)
dvalid = DeviceDMatrix(x[n_tr:], label=y[n_tr:], ref=dtrain)

bst = Booster(n_rounds=20, max_depth=6, max_bins=128,
              objective="multi:softmax", n_classes=spec.n_classes)
bst.fit(dtrain, evals=[(dvalid, "valid")], verbose_every=5,
        callback=lambda r, rec: print(rec, flush=True))

pred = np.asarray(bst.predict(x[n_tr:]))  # class ids, no extra args
print("valid accuracy:", float(np.mean(pred == y[n_tr:])))
print(f"{bst.ensemble.n_trees} trees "
      f"({bst.n_rounds_trained} rounds x {spec.n_classes} classes)")

bst.save("/tmp/covtype_booster.msgpack")
reloaded = Booster.load("/tmp/covtype_booster.msgpack")
assert np.array_equal(pred, np.asarray(reloaded.predict(x[n_tr:])))
print("self-describing checkpoint roundtrip OK")
