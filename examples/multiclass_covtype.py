"""Multiclass (covtype-shaped, 7 classes) — the paper's Table 2 scenario
where the GPU competitors struggled (cat-gpu N/A). Softmax gradients are
evaluated on-device (beyond-paper: the 2018 paper computed multiclass
gradients on CPU).

    PYTHONPATH=src python examples/multiclass_covtype.py
"""
import numpy as np
from repro.core import BoosterConfig, train, predict_proba
from repro.data import make_dataset

x, y, spec = make_dataset("covtype", n_rows=20_000)
n_tr = 16_000
cfg = BoosterConfig(n_rounds=20, max_depth=6, max_bins=128,
                    objective="multi:softmax", n_classes=spec.n_classes)
st = train(x[:n_tr], y[:n_tr], cfg, verbose_every=5,
           callback=lambda r, rec: print(rec, flush=True))
pred = np.asarray(predict_proba(st.ensemble, x[n_tr:], cfg.max_depth,
                                "multi:softmax"))
print("valid accuracy:", float(np.mean(pred == y[n_tr:])))
print(f"{st.ensemble.n_trees} trees ({cfg.n_rounds} rounds x {spec.n_classes} classes)")
