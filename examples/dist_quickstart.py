"""Distributed quickstart: multi-device training with pluggable collectives
(repro.dist, DESIGN.md §15). Runs on 8 virtual CPU devices so it works —
and means the same thing — on a laptop or an accelerator pod:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/dist_quickstart.py

Every collective at f32 grows bit-identical trees to the single-device
fit; compression (f16 / q16) narrows the histogram allreduce wire to 2
bytes/element with an on-device error check that falls back to exact f32
when the tolerance is exceeded.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import Booster, DeviceDMatrix  # noqa: E402
from repro.dist import sharded_sketch_cuts  # noqa: E402
from repro.jaxcompat import make_mesh  # noqa: E402

rng = np.random.default_rng(0)
n, f = 8_192, 10
x = rng.normal(size=(n, f)).astype(np.float32)
y = (x[:, 0] * 2 + np.sin(x[:, 1]) + 0.1 * rng.normal(size=n)).astype(
    np.float32
)

# --- device-sharded sketch: each shard sorts + sketches its rows, then a
# --- log-depth tree merge produces one mergeable-summary cut set ---------
mesh = make_mesh((8,), ("data",))
cuts = sharded_sketch_cuts(x, max_bins=64, capacity=4096, mesh=mesh)
dtrain = DeviceDMatrix(x, label=y, max_bins=64, cuts=np.asarray(cuts))

# --- single-device reference fit ----------------------------------------
ref = Booster(n_rounds=5, max_depth=4, max_bins=64).fit(dtrain)

# --- every collective strategy reproduces it bit-identically at f32 ------
for name in ("psum", "ring", "hier"):
    bst = Booster(n_rounds=5, max_depth=4, max_bins=64).fit(
        dtrain, mesh=mesh, collective=name
    )
    assert bool(jnp.all(bst.ensemble.feature == ref.ensemble.feature)), name
    assert bool(
        jnp.all(bst.ensemble.split_bin == ref.ensemble.split_bin)
    ), name
    leaf_diff = float(
        jnp.max(jnp.abs(bst.ensemble.leaf_value - ref.ensemble.leaf_value))
    )
    assert leaf_diff < 1e-4, (name, leaf_diff)
    cs = bst.comm_stats  # per-round communication accounting
    print(
        f"{name:5s} f32: identical trees, "
        f"{cs['bytes_per_round']:>9d} B/round, "
        f"{cs['collective_calls_per_round']} calls/round"
    )

# --- compressed allreduce: 2-byte wire, error-checked fallback to f32 ----
for comp in ("f16", "q16"):
    bst = Booster(n_rounds=5, max_depth=4, max_bins=64).fit(
        dtrain, mesh=mesh, collective="ring", compression=comp
    )
    cs = bst.comm_stats
    rmse = float(np.sqrt(np.mean((np.asarray(bst.predict(x)) - y) ** 2)))
    rmse0 = float(np.sqrt(np.mean((np.asarray(ref.predict(x)) - y) ** 2)))
    assert abs(rmse - rmse0) <= 0.05 * rmse0 + 1e-3, (comp, rmse, rmse0)
    print(
        f"ring  {comp}: rmse {rmse:.4f} (f32 {rmse0:.4f}), "
        f"{cs['bytes_per_round']:>9d} B/round "
        f"({cs['bytes_per_round_f32'] / cs['bytes_per_round']:.2f}x less), "
        f"{cs['fallback_events']} fallbacks"
    )

print("dist quickstart OK")
