"""End-to-end driver (the paper's large-scale scenario, reduced for CPU):
airline-shaped data (13 features, binary), 200 boosting rounds, multi-
device row sharding with AllReduce histogram combination (Algorithm 1) as a
strategy behind the same Booster.fit signature.

Run single-device:
    PYTHONPATH=src python examples/airline_e2e.py
Across 8 virtual devices (Algorithm 1 multi-GPU path):
    PYTHONPATH=src python examples/airline_e2e.py --devices 8

(paper scale: 115M rows on 8 V100s in under 3 minutes; here 200k rows on
a 1-core CPU container — the algorithm and collectives are the same.)
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=1)
ap.add_argument("--rows", type=int, default=200_000)
ap.add_argument("--rounds", type=int, default=200)
args = ap.parse_args()

if args.devices > 1 and "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time
import numpy as np
from repro.core import Booster, DeviceDMatrix
from repro.data import make_dataset

x, y, spec = make_dataset("airline", n_rows=args.rows)
n_tr = int(0.9 * args.rows)
n_tr = (n_tr // args.devices) * args.devices  # shard-divisible (no-op at 1)

mesh = None
if args.devices > 1:
    from repro.jaxcompat import make_mesh
    mesh = make_mesh((args.devices,), ("data",))

t0 = time.perf_counter()
dtrain = DeviceDMatrix(x[:n_tr], label=y[:n_tr])
t_build = time.perf_counter() - t0

bst = Booster(n_rounds=args.rounds, max_depth=6, max_bins=256,
              objective=spec.objective)
t0 = time.perf_counter()
bst.fit(dtrain, verbose_every=50, mesh=mesh,
        callback=lambda r, rec: print(rec, flush=True))
t_fit = time.perf_counter() - t0

p = np.asarray(bst.predict(x[n_tr:]))
acc = float(np.mean((p > 0.5) == y[n_tr:]))
print(f"rows={args.rows} rounds={args.rounds} devices={args.devices} "
      f"dmatrix={t_build:.1f}s fit={t_fit:.1f}s valid_accuracy={acc:.4f}")
