"""End-to-end driver (the paper's large-scale scenario, reduced for CPU):
airline-shaped data (13 features, binary), 200 boosting rounds, multi-
device row sharding with AllReduce histogram combination (Algorithm 1).

Run single-device:
    PYTHONPATH=src python examples/airline_e2e.py
Across 8 virtual devices (Algorithm 1 multi-GPU path):
    PYTHONPATH=src python examples/airline_e2e.py --devices 8

(paper scale: 115M rows on 8 V100s in under 3 minutes; here 200k rows on
a 1-core CPU container — the algorithm and collectives are the same.)
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=1)
ap.add_argument("--rows", type=int, default=200_000)
ap.add_argument("--rounds", type=int, default=200)
args = ap.parse_args()

if args.devices > 1 and "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import BoosterConfig, train, predict_proba
from repro.core.distributed import train_distributed
from repro.data import make_dataset

x, y, spec = make_dataset("airline", n_rows=args.rows)
n_tr = int(0.9 * args.rows)
cfg = BoosterConfig(n_rounds=args.rounds, max_depth=6, max_bins=256,
                    objective=spec.objective)
t0 = time.perf_counter()
if args.devices > 1:
    mesh = jax.make_mesh((args.devices,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    keep = (n_tr // args.devices) * args.devices
    ens, margins, _ = train_distributed(x[:keep], y[:keep], cfg, mesh,
                                        verbose_every=50)
else:
    st = train(x[:n_tr], y[:n_tr], cfg, verbose_every=50,
               callback=lambda r, rec: print(rec, flush=True))
    ens = st.ensemble
dt = time.perf_counter() - t0

p = np.asarray(predict_proba(ens, x[n_tr:], cfg.max_depth, cfg.objective))
acc = float(np.mean((p > 0.5) == y[n_tr:]))
print(f"rows={args.rows} rounds={args.rounds} devices={args.devices} "
      f"time={dt:.1f}s valid_accuracy={acc:.4f}")
