"""Optimizers for the LM substrate (built here; no external optax dep)."""
from repro.optimizer.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optimizer.sgd import sgd_init, sgd_update
from repro.optimizer.util import clip_by_global_norm, global_norm

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "sgd_init",
    "sgd_update",
    "clip_by_global_norm",
    "global_norm",
]
