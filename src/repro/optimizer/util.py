"""Shared optimizer utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: g * scale, grads), norm


def cosine_schedule(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
