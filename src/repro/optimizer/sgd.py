"""SGD with momentum (baseline optimizer for ablations)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    step: jax.Array
    momentum: object


def sgd_init(params) -> SGDState:
    return SGDState(
        step=jnp.zeros((), jnp.int32),
        momentum=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
    )


def sgd_update(params, grads, state: SGDState, lr: float, beta: float = 0.9):
    new_m = jax.tree.map(
        lambda m, g: beta * m + g.astype(jnp.float32), state.momentum, grads
    )
    new_p = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, new_m)
    return new_p, SGDState(step=state.step + 1, momentum=new_m)
