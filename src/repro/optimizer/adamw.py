"""AdamW with decoupled weight decay. Optimizer state mirrors the param
pytree, so any sharding applied to params propagates to m/v (critical for
the multi-pod dry-run: optimizer state must shard with the weights)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: object  # pytree like params
    v: object


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig, lr=None):
    from repro.optimizer.util import clip_by_global_norm

    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat, vhat = m / b1c, v / b2c
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
