"""GBDT training driver — the paper's own end-to-end pipeline (Figure 1).

Single-device by default; --devices N uses N virtual host devices and the
shard_map/psum distributed builder (Algorithm 1's multi-GPU path; set
XLA_FLAGS by re-exec so the flag precedes jax init).

Examples:
  PYTHONPATH=src python -m repro.launch.train_gbdt --dataset higgs \
      --rows 20000 --rounds 50
  PYTHONPATH=src python -m repro.launch.train_gbdt --dataset airline \
      --rows 100000 --rounds 100 --devices 8
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="higgs")
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--max-depth", type=int, default=6)
    ap.add_argument("--max-bins", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--growth", default="depthwise", choices=["depthwise", "lossguide"])
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route histograms through the Pallas kernel")
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    if args.devices > 1 and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )
        os.execv(sys.executable, [sys.executable, "-m", "repro.launch.train_gbdt", *sys.argv[1:]])

    import jax
    import numpy as np
    from repro.core import BoosterConfig, train
    from repro.core.booster import predict_margins
    from repro.core import objectives as O
    from repro.core.distributed import train_distributed
    from repro.data import make_dataset

    x, y, spec = make_dataset(args.dataset, n_rows=args.rows)
    n_tr = int(0.8 * len(x))
    xt, yt, xv, yv = x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]
    cfg = BoosterConfig(
        n_rounds=args.rounds,
        max_depth=args.max_depth,
        max_bins=args.max_bins,
        learning_rate=args.lr,
        objective=spec.objective,
        n_classes=spec.n_classes,
        growth=args.growth,
        use_kernel_histograms=args.use_kernel,
    )
    t0 = time.perf_counter()
    if args.devices > 1:
        n_keep = (len(xt) // args.devices) * args.devices
        from repro import jaxcompat
        mesh = jaxcompat.make_mesh((args.devices,), ("data",))
        ens, margins, hist = train_distributed(xt[:n_keep], yt[:n_keep], cfg, mesh,
                                               verbose_every=max(args.rounds // 5, 1))
    else:
        st = train(xt, yt, cfg, verbose_every=max(args.rounds // 5, 1),
                   callback=lambda r, rec: print(rec, flush=True))
        ens, hist = st.ensemble, st.history
    elapsed = time.perf_counter() - t0

    obj = O.OBJECTIVES[spec.objective]
    import jax.numpy as jnp
    mv = predict_margins(ens, jnp.asarray(xv), cfg.max_depth)
    metric = float(obj.metric(mv, jnp.asarray(yv)))
    print(f"dataset={args.dataset} rows={args.rows} rounds={args.rounds} "
          f"devices={args.devices} time={elapsed:.1f}s "
          f"valid_{obj.metric_name}={metric:.4f}")
    if args.checkpoint:
        from repro.checkpoint import save_ensemble
        save_ensemble(args.checkpoint, ens)
        print("saved ensemble to", args.checkpoint)


if __name__ == "__main__":
    main()
