"""GBDT training driver — the paper's own end-to-end pipeline (Figure 1)
behind the two-noun API: DeviceDMatrix (quantise once) + Booster.fit.

Single-device by default; --devices N uses N virtual host devices and the
shard_map/psum distributed strategy behind the same Booster.fit signature
(Algorithm 1's multi-GPU path; set XLA_FLAGS by re-exec so the flag precedes
jax init). Both paths produce the same Booster object.

Examples:
  PYTHONPATH=src python -m repro.launch.train_gbdt --dataset higgs \
      --rows 20000 --rounds 50
  PYTHONPATH=src python -m repro.launch.train_gbdt --dataset airline \
      --rows 100000 --rounds 100 --devices 8
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="higgs")
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--max-depth", type=int, default=6)
    ap.add_argument("--max-bins", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--growth", default="depthwise", choices=["depthwise", "lossguide"])
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route histograms through the Pallas kernel")
    ap.add_argument("--early-stopping", type=int, default=0,
                    help="stop when the valid metric stalls for N rounds")
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    if args.devices > 1 and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )
        os.execv(sys.executable, [sys.executable, "-m", "repro.launch.train_gbdt", *sys.argv[1:]])

    from repro.core import Booster, BoosterConfig, DeviceDMatrix
    from repro.data import make_dataset

    x, y, spec = make_dataset(args.dataset, n_rows=args.rows)
    n_tr = int(0.8 * len(x))
    n_tr = (n_tr // args.devices) * args.devices  # shard-divisible (no-op at 1)
    cfg = BoosterConfig(
        n_rounds=args.rounds,
        max_depth=args.max_depth,
        max_bins=args.max_bins,
        learning_rate=args.lr,
        objective=spec.objective,
        n_classes=spec.n_classes,
        growth=args.growth,
        use_kernel_histograms=args.use_kernel,
    )

    t0 = time.perf_counter()
    dtrain = DeviceDMatrix(x[:n_tr], label=y[:n_tr], max_bins=args.max_bins)
    dval = DeviceDMatrix(x[n_tr:], label=y[n_tr:], ref=dtrain)
    t_build = time.perf_counter() - t0

    mesh = None
    if args.devices > 1:
        from repro import jaxcompat
        mesh = jaxcompat.make_mesh((args.devices,), ("data",))

    t0 = time.perf_counter()
    bst = Booster(cfg).fit(
        dtrain,
        evals=[(dval, "valid")],
        early_stopping_rounds=args.early_stopping or None,
        verbose_every=max(args.rounds // 5, 1),
        callback=lambda r, rec: print(rec, flush=True),
        mesh=mesh,
    )
    t_fit = time.perf_counter() - t0

    metric_name, metric = next(iter(bst.eval(dval, "valid").items()))
    print(f"dataset={args.dataset} rows={args.rows} "
          f"rounds={bst.n_rounds_trained} devices={args.devices} "
          f"dmatrix={t_build:.1f}s fit={t_fit:.1f}s "
          f"{metric_name}={metric:.4f}")
    if args.checkpoint:
        bst.save(args.checkpoint)
        print("saved booster to", args.checkpoint)


if __name__ == "__main__":
    main()
