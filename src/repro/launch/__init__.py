"""Launch layer: production mesh, dry-run driver, trainers."""
