"""LM training driver (deliverable b: end-to-end runnable on CPU with a
reduced config, and mesh-ready for the production topology).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import ARCHS, get_arch
from repro.data import TokenStream
from repro.models import NO_SHARDING, build_model
from repro.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.optimizer.util import cosine_schedule


def make_train_step(model, rules, acfg: AdamWConfig, total_steps: int):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, rules)
        )(params)
        lr = cosine_schedule(opt_state.step, acfg.lr, warmup=20, total=total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, acfg, lr=lr)
        return params, opt_state, loss

    return jax.jit(step, donate_argnums=(0, 1))


def train_loop(cfg, steps: int, batch: int, seq: int, lr: float = 3e-4,
               seed: int = 0, log_every: int = 10, checkpoint_path: str = ""):
    model = build_model(cfg)
    rules = NO_SHARDING  # single-host driver; dryrun.py exercises the mesh
    params = model.init_params(jax.random.PRNGKey(seed))
    acfg = AdamWConfig(lr=lr)
    opt_state = adamw_init(params)
    stream = TokenStream(cfg.vocab_size, batch, seq, seed=seed)
    step_fn = make_train_step(model, rules, acfg, steps)

    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        toks, tgts = stream.next_batch()
        b = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
        if cfg.arch_type == "vlm":
            b["prefix_embeds"] = jnp.zeros((batch, cfg.n_prefix_tokens, cfg.d_model))
        if cfg.arch_type in ("audio", "encdec"):
            b["src_embeds"] = jnp.asarray(
                np.random.default_rng(seed + i).normal(
                    size=(batch, min(seq, 64), cfg.d_model)
                ).astype(np.float32) * 0.02
            )
        params, opt_state, loss = step_fn(params, opt_state, b)
        if i % log_every == 0 or i == steps - 1:
            rec = {"step": i, "loss": float(loss),
                   "elapsed_s": round(time.perf_counter() - t0, 2)}
            history.append(rec)
            print(rec, flush=True)
    if checkpoint_path:
        save_pytree(checkpoint_path, {"params": params, "step": steps})
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    _, history = train_loop(cfg, args.steps, args.batch, args.seq,
                            lr=args.lr, checkpoint_path=args.checkpoint)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} ({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
