import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) pair, jit the step function with the
production in/out shardings, .lower() it with ShapeDtypeStruct stand-ins
(no allocation), .compile() it for the 16x16 single-pod mesh or the
2x16x16 multi-pod mesh, and record:

  * compiled.memory_analysis()       (fits-on-chip evidence)
  * compiled.cost_analysis()         (XLA's own counters, body-once caveat)
  * hlo_analysis.analyze()           (loop-corrected per-device dot FLOPs,
                                      dot bytes, collective bytes by type)
  * derived roofline terms           (197 TF bf16, 819 GB/s HBM, 50 GB/s link)

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

NOTE the XLA_FLAGS line above MUST precede any jax import (device count is
locked at first init); this module is the only place it is set.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.launch import specs as SP
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.optimizer import AdamWConfig, adamw_init, adamw_update

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link


def count_params(struct) -> int:
    import math

    return sum(math.prod(x.shape) for x in jax.tree.leaves(struct))


def active_params(cfg: ArchConfig, total: int) -> int:
    if not cfg.n_experts:
        return total
    moe_part = cfg.n_layers * 3 * cfg.n_experts * cfg.d_model * cfg.d_ff
    return total - moe_part + moe_part * cfg.top_k // cfg.n_experts


def model_flops(cfg: ArchConfig, shape: ShapeConfig, n_active: int) -> float:
    """6*N*D (train) / 2*N*D (inference) global useful FLOPs."""
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * tokens


def build_step(model, cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, arg_structs, in_shardings)."""
    rules = SP.rules_for(mesh, shape)
    batch_structs = SP.input_specs(cfg, shape)
    batch_specs = SP.batch_partition_specs(cfg, shape, rules)
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    params_struct = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    pspecs = model.param_specs()

    if shape.kind == "train":
        acfg = AdamWConfig()
        opt_struct = jax.eval_shape(adamw_init, params_struct)
        ospecs = type(opt_struct)(step=P(), m=pspecs, v=pspecs)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch, rules)
            )(params)
            new_params, new_opt = adamw_update(params, grads, opt_state, acfg)
            return new_params, new_opt, loss

        return (
            train_step,
            (params_struct, opt_struct, batch_structs),
            (ns(pspecs), ns(ospecs), ns(batch_specs)),
        )

    if shape.kind == "prefill":

        def prefill_step(params, batch):
            return model.forward_logits(params, batch, rules)

        return prefill_step, (params_struct, batch_structs), (ns(pspecs), ns(batch_specs))

    # decode
    cap = SP.cache_capacity(cfg, shape)
    cache_struct = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cap, jnp.bfloat16)
    )
    cspecs = model.cache_specs(rules)
    idx_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, batch, cache, index):
        return model.decode_fn(params, batch, cache, index, rules)

    return (
        decode_step,
        (params_struct, batch_structs, cache_struct, idx_struct),
        (ns(pspecs), ns(batch_specs), ns(cspecs), NamedSharding(mesh, P())),
    )


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    base_cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = SP.supports_shape(base_cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=reason)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    cfg = SP.cfg_for_shape(base_cfg, shape)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    t0 = time.perf_counter()
    try:
        fn, structs, shardings = build_step(model, cfg, shape, mesh)
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=shardings).lower(*structs)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze(compiled.as_text())

        n_total = count_params(jax.eval_shape(model.init_params, jax.random.PRNGKey(0)))
        n_active = active_params(cfg, n_total)
        mf = model_flops(cfg, shape, n_active)
        flops_dev = hlo["dot_flops_per_device"]
        bytes_dev = hlo["dot_bytes_per_device"]
        coll_dev = hlo["collective_bytes_total"]

        compute_s = flops_dev / PEAK_FLOPS
        memory_s = bytes_dev / HBM_BW
        collective_s = coll_dev / LINK_BW
        dominant = max(
            [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
            key=lambda kv: kv[1],
        )[0]

        rec.update(
            status="ok",
            chips=int(chips),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            params_total=int(n_total),
            params_active=int(n_active),
            memory_analysis={
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "peak_hbm_bytes_est": int(
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes
                ),
            },
            cost_analysis={
                "flops_body_once": float(ca.get("flops", 0.0)),
                "bytes_accessed_body_once": float(ca.get("bytes accessed", 0.0)),
            },
            hlo=hlo,
            roofline={
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "dominant": dominant,
                "model_flops_global": mf,
                "model_flops_per_device": mf / chips,
                "useful_flops_ratio": (mf / chips) / flops_dev if flops_dev else 0.0,
            },
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    pairs = (
        [(a, s) for a in ARCHS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in pairs:
        for mp in meshes:
            rec = run_one(arch, shape, mp, args.out)
            r = rec.get("roofline", {})
            print(
                f"{rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:10s} "
                f"{rec['status']:8s} "
                + (
                    f"compile={rec['compile_s']:7.1f}s dom={r['dominant']:10s} "
                    f"c/m/coll={r['compute_s']:.2e}/{r['memory_s']:.2e}/{r['collective_s']:.2e} "
                    f"useful={r['useful_flops_ratio']:.2f}"
                    if rec["status"] == "ok"
                    else rec.get("reason", rec.get("error", ""))[:120]
                ),
                flush=True,
            )


if __name__ == "__main__":
    main()
