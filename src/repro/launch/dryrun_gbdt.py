import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""GBDT dry-run on the production mesh — the paper-representative §Perf pair.

Lowers ONE boosting round (gradients -> depth-6 tree build -> margin update,
Algorithm 1) for an airline-shaped matrix as ShapeDtypeStructs on the
(data=16, model=16) mesh, in two distribution modes:

  baseline   — paper-faithful: rows sharded over BOTH axes (256-way row
               partitioning, the paper's per-GPU instance partitioning);
               full gradient histograms AllReduced over all 256 shards.
  feature    — beyond-paper: rows over `data`, features over `model`;
               histograms stay feature-local (psum only over `data`),
               winners chosen via an all-gather of per-node best-split
               records, row routing broadcast by a tiny psum.

Reports per-device collective bytes from the partitioned HLO for both, plus
the roofline terms. Usage:
  python -m repro.launch.dryrun_gbdt [--rows 1048576] [--features 13]
"""
import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import objectives as O
from repro.core import tree as T
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS


def build_round(mode: str, mesh, n_rows: int, n_features: int,
                max_bins: int, max_depth: int):
    obj = O.OBJECTIVES["binary:logistic"]

    if mode == "baseline":
        data_axes = ("data", "model")  # paper: rows across ALL devices
        in_specs = (P(data_axes, None), P(data_axes), P(data_axes), P(None, None))
        kwargs = dict(axis_name="data", extra_axes=("model",))
    else:
        data_axes = ("data",)
        in_specs = (P("data", "model"), P("data"), P("data"), P("model", None))
        kwargs = dict(axis_name="data", feature_axis="model")

    def round_body(bins, margins, y, cuts):
        gh = obj.grad(margins[:, None], y)[:, 0, :]
        tree = T.grow_tree(
            bins, gh, cuts, max_depth, max_bins, growth="depthwise", **kwargs
        )
        return tree

    fn = jax.shard_map(
        round_body, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_vma=False,
    )
    structs = (
        jax.ShapeDtypeStruct((n_rows, n_features), jnp.int32),
        jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        jax.ShapeDtypeStruct((n_rows,), jnp.float32),
        jax.ShapeDtypeStruct((n_features, max_bins - 2), jnp.float32),
    )
    return fn, structs


def run(mode: str, n_rows: int, n_features: int, max_bins: int = 256,
        max_depth: int = 6):
    mesh = make_production_mesh()
    fn, structs = build_round(mode, mesh, n_rows, n_features, max_bins, max_depth)
    with jax.set_mesh(mesh):
        compiled = jax.jit(fn).lower(*structs).compile()
    h = analyze(compiled.as_text())
    return {
        "mode": mode,
        "rows": n_rows,
        "features": n_features,
        "compute_s": h["dot_flops_per_device"] / PEAK_FLOPS,
        "memory_s": h["dot_bytes_per_device"] / HBM_BW,
        "collective_s": h["collective_bytes_total"] / LINK_BW,
        "collective_bytes_per_device": h["collective_bytes_per_device"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1 << 20)
    ap.add_argument("--features", type=int, default=13)
    ap.add_argument("--max-bins", type=int, default=256)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    recs = []
    for mode in ("baseline", "feature"):
        # feature mode shards columns over model=16: pad feature count (a
        # constant padded column can never win a split — zero gain).
        nf = args.features if mode == "baseline" else -(-args.features // 16) * 16
        r = run(mode, args.rows, nf, args.max_bins)
        recs.append(r)
        print(f"{mode:9s} coll_bytes/dev={sum(r['collective_bytes_per_device'].values()):.3e} "
              f"({ {k: f'{v:.2e}' for k, v in r['collective_bytes_per_device'].items()} }) "
              f"coll_s={r['collective_s']:.2e}", flush=True)
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, "gbdt_round.json"), "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
