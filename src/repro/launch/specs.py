"""input_specs(): ShapeDtypeStruct stand-ins + PartitionSpecs for every
(architecture x input-shape) combination — no device allocation anywhere.

Modality frontends are stubs per the brief: VLM batches carry precomputed
patch embeddings (576 tokens, CLIP ViT-L/14 grid), audio batches carry
precomputed frame embeddings; both are consumed by the backbone directly.

Sliding windows are a per-shape decision (DESIGN.md §6): the config's
`sliding_window` is the *available variant* and is engaged ONLY for
long_500k; all other shapes run full attention.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.models.transformer import ShardingRules

AUDIO_SRC_FRAMES = 4096  # stub frontend: fixed source frame budget


def cfg_for_shape(cfg: ArchConfig, shape: ShapeConfig) -> ArchConfig:
    """Engage the sliding-window variant only for long_500k."""
    if shape.name != "long_500k":
        return dataclasses.replace(cfg, sliding_window=0)
    return cfg


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k":
        if cfg.arch_type in ("encdec", "audio"):
            return False, (
                "enc-dec translation decoder: target length architecturally "
                "bounded far below 500k (DESIGN.md §6)"
            )
        if cfg.arch_type in ("dense", "moe", "vlm") and not cfg.sliding_window:
            return False, "pure full-attention arch without a sub-quadratic variant"
    return True, ""


def rules_for(mesh: jax.sharding.Mesh, shape: ShapeConfig) -> ShardingRules:
    bt = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if shape.kind == "decode":
        if shape.global_batch == 1:  # long_500k: shard the cache sequence
            seq = tuple(mesh.axis_names)  # all axes
            return ShardingRules(batch=None, model="model", seq=seq)
        return ShardingRules(batch=bt, model="model", seq="model")
    return ShardingRules(batch=bt, model="model", seq=None)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Batch ShapeDtypeStructs for the step function of this shape."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        specs = {}
        if cfg.arch_type == "vlm":
            text = s - cfg.n_prefix_tokens
            specs["prefix_embeds"] = sds((b, cfg.n_prefix_tokens, d), f32)
            specs["tokens"] = sds((b, text), i32)
            if shape.kind == "train":
                specs["targets"] = sds((b, text), i32)
        elif cfg.arch_type in ("audio", "encdec"):
            specs["src_embeds"] = sds((b, min(s, AUDIO_SRC_FRAMES), d), f32)
            specs["tokens"] = sds((b, s), i32)
            if shape.kind == "train":
                specs["targets"] = sds((b, s), i32)
        else:
            specs["tokens"] = sds((b, s), i32)
            if shape.kind == "train":
                specs["targets"] = sds((b, s), i32)
        return specs

    # decode: ONE new token against a cache of seq_len (or window) entries.
    specs = {"tokens": sds((b, 1), i32)}
    if cfg.arch_type in ("audio", "encdec"):
        # encoder output is precomputed at serve time (not re-encoded per step)
        specs["enc_out"] = sds((b, min(s, AUDIO_SRC_FRAMES), d), f32)
    return specs


def batch_partition_specs(cfg: ArchConfig, shape: ShapeConfig,
                          rules: ShardingRules) -> dict:
    bt = rules.batch
    specs = {}
    for k in input_specs(cfg, shape):
        if k in ("tokens", "targets"):
            specs[k] = P(bt, None)
        else:  # embeddings (B, S, D)
            specs[k] = P(bt, None, None)
    return specs


def cache_capacity(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Decode cache capacity: full seq_len, or the sliding window when the
    SWA variant is engaged (long_500k)."""
    cfg = cfg_for_shape(cfg, shape)
    if cfg.sliding_window and shape.name == "long_500k":
        return cfg.sliding_window
    return shape.seq_len


def shape_by_name(name: str) -> ShapeConfig:
    return SHAPES[name]
