"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
tests and benches must keep seeing 1 device).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
DCN-connected dimension (data parallelism across pods).
"""
from __future__ import annotations

import jax

from repro import jaxcompat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jaxcompat.make_mesh(shape, axes)


def batch_axes(mesh: jax.sharding.Mesh):
    """Mesh axes used for batch data parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def seq_axes_long(mesh: jax.sharding.Mesh):
    """Axes used to shard the KV cache sequence dim for long_500k (batch=1)."""
    return (
        ("pod", "data", "model") if "pod" in mesh.axis_names else ("data", "model")
    )
