"""Post-SPMD HLO text analysis for the roofline (EXPERIMENTS.md §Roofline).

Why not compiled.cost_analysis() alone: XLA's HloCostAnalysis visits while
bodies ONCE, so a scanned 62-layer model reports ~1 layer of FLOPs.
This module parses compiled.as_text() (the optimized, partitioned HLO):

  * builds a name -> shape table from op definitions,
  * counts matmul FLOPs from `dot` / `convolution` ops,
  * sums collective bytes from all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute result shapes,
  * attributes ops to their computation and multiplies every while body's
    counts by the loop trip count recovered from the loop condition's
    comparison constant (nested whiles multiply through).

All counts are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shape(text: str):
    """First shape in `text` -> (dtype, dims) or None. Handles tuples by
    returning the list of all component shapes."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


def _nbytes(shapes) -> int:
    tot = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


@dataclass
class ComputationStats:
    name: str
    dot_flops: float = 0.0
    dot_bytes: float = 0.0  # lhs + rhs + out of every dot (HBM-traffic proxy)
    collective_bytes: dict = field(default_factory=dict)
    whiles: list = field(default_factory=list)  # (body, cond) computation names
    called: list = field(default_factory=list)  # fusions etc. (not multiplied)
    max_constant: int = 0  # used when this computation is a loop condition


def parse_hlo(text: str) -> dict[str, ComputationStats]:
    comps: dict[str, ComputationStats] = {}
    cur: ComputationStats | None = None
    shapes: dict[str, list] = {}

    for raw in text.splitlines():
        line = raw.rstrip()
        # computation header: `%name (params...) -> ... {` or `ENTRY %name ...{`
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if header and not line.lstrip().startswith(("ROOT", "//")) and "=" not in line.split("(")[0]:
            cur = ComputationStats(name=header.group(1))
            if line.startswith("ENTRY"):
                cur.name = "ENTRY"
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        res_shapes = _parse_shape(rhs.split("(")[0] if "(" in rhs else rhs)
        if res_shapes:
            shapes[name] = res_shapes

        # constants (for loop trip counts)
        cm = re.search(r"constant\((\d+)\)", rhs)
        if cm:
            cur.max_constant = max(cur.max_constant, int(cm.group(1)))

        # while ops
        wm = re.search(r"\bwhile\(", rhs)
        if wm:
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            cm2 = re.search(r"condition=%?([\w.\-]+)", rhs)
            if bm and cm2:
                cur.whiles.append((bm.group(1), cm2.group(1)))

        # dot ops: flops = 2 * prod(result dims) * contracted size. Operands
        # may be bare (`dot(%a, %b)`, newer XLA) or typed
        # (`dot(f32[4,64]{1,0} %a, ...)`, older XLA text) — handle both.
        dm = re.search(r"\bdot\(([^)]*)\)", rhs)
        if dm and res_shapes:
            args = dm.group(1)
            opnames = re.findall(r"%([\w.\-]+)", args)
            if not opnames:
                opnames = [a.strip() for a in args.split(",") if a.strip()]
            lhs_name = opnames[0] if opnames else ""
            lcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            k = 1
            lhs_shapes = shapes.get(lhs_name)
            if lcd is not None:
                if lhs_shapes:
                    dims = lhs_shapes[0][1]
                else:
                    # typed operand carries its own shape inline
                    inline = _parse_shape(args)
                    dims = inline[0][1] if inline else []
                for di in (int(x) for x in lcd.group(1).split(",") if x):
                    if di < len(dims):
                        k *= dims[di]
            res_elems = 1
            for d in res_shapes[0][1]:
                res_elems *= d
            cur.dot_flops += 2.0 * res_elems * k
            operand_bytes = sum(
                _nbytes(shapes.get(nm, [])) for nm in opnames[:2]
            )
            cur.dot_bytes += _nbytes(res_shapes) + operand_bytes

        # collectives: bytes = result size
        for cname in _COLLECTIVES:
            if re.search(rf"\b{cname}(?:-start|-done)?\(", rhs):
                if cname + "-done(" in rhs:
                    continue  # avoid double count of async pairs
                b = _nbytes(res_shapes)
                cur.collective_bytes[cname] = cur.collective_bytes.get(cname, 0) + b
                break
    return comps


def _trip_count(cond_name: str, comps: dict[str, ComputationStats]) -> int:
    cond = comps.get(cond_name)
    return max(cond.max_constant, 1) if cond else 1


def aggregate(comps: dict[str, ComputationStats]):
    """Fold while bodies into their callers with trip-count multipliers."""

    def total(name: str, mult: float, seen: frozenset):
        if name not in comps or name in seen:
            return 0.0, 0.0, {}
        c = comps[name]
        flops = c.dot_flops * mult
        dbytes = c.dot_bytes * mult
        coll = {k: v * mult for k, v in c.collective_bytes.items()}
        for body, cond in c.whiles:
            n = _trip_count(cond, comps)
            f2, b2, c2 = total(body, mult * n, seen | {name})
            flops += f2
            dbytes += b2
            for k, v in c2.items():
                coll[k] = coll.get(k, 0) + v
        return flops, dbytes, coll

    return total("ENTRY", 1.0, frozenset())


def analyze(compiled_text: str) -> dict:
    comps = parse_hlo(compiled_text)
    flops, dot_bytes, coll = aggregate(comps)
    return {
        "dot_flops_per_device": flops,
        "dot_bytes_per_device": dot_bytes,
        "collective_bytes_per_device": coll,
        "collective_bytes_total": sum(coll.values()),
        "n_computations": len(comps),
    }
