"""Deterministic synthetic token stream for the LM substrate.

Zipf-distributed tokens with a planted bigram structure so perplexity has
headroom to improve during training (pure uniform tokens would pin loss at
log(vocab)). Batches are generated on host in numpy and device_put with the
caller's sharding — the same pattern a real input pipeline (grain etc.)
would follow.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.rng = np.random.default_rng(seed)
        # Zipf-ish unigram distribution over a capped alphabet for speed.
        self.alphabet = min(vocab_size, 4096)
        ranks = np.arange(1, self.alphabet + 1)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        # Planted bigram: each token deterministically biases its successor.
        self.succ = self.rng.integers(0, self.alphabet, size=self.alphabet)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, targets), both (batch, seq) int32; targets are
        tokens shifted left (next-token prediction)."""
        draws = self.rng.choice(
            self.alphabet, size=(self.batch, self.seq + 1), p=self.probs
        )
        # 50% of positions follow the planted bigram of their (final)
        # predecessor — chained sequentially so the bigram statistics hold.
        follow = self.rng.random((self.batch, self.seq)) < 0.5
        toks = draws.copy()
        for t in range(self.seq):
            toks[:, t + 1] = np.where(
                follow[:, t], self.succ[toks[:, t]], draws[:, t + 1]
            )
        return (
            toks[:, :-1].astype(np.int32),
            toks[:, 1:].astype(np.int32),
        )
