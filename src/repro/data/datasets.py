"""Synthetic stand-ins for the paper's six datasets (Table 1).

The container has no network access, so each public dataset is mirrored by
a generator with the SAME rows/columns/task (and sparsity character for
bosch); benchmark tables run a `scale` fraction of the full row count by
default on CPU, with --full selecting the paper's exact shapes. Learnable
structure (linear + interactions + noise) is injected so accuracy numbers
are meaningful to compare across our baselines, even though absolute values
cannot match the real data.

| name            | rows | cols | task                      |
|-----------------|------|------|---------------------------|
| year_prediction | 515K | 90   | regression                |
| synthetic       | 10M  | 100  | regression                |
| higgs           | 11M  | 28   | binary classification     |
| covtype         | 581K | 54   | multiclass (7)            |
| bosch           | 1M   | 968  | binary, 81% missing       |
| airline         | 115M | 13   | binary classification     |
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_rows: int
    n_features: int
    task: str  # reg | binary | multiclass
    n_classes: int = 1
    missing_frac: float = 0.0
    objective: str = "reg:squarederror"
    metric: str = "rmse"


DATASETS: dict[str, DatasetSpec] = {
    "year_prediction": DatasetSpec(
        "year_prediction", 515_345, 90, "reg", objective="reg:squarederror"
    ),
    "synthetic": DatasetSpec(
        "synthetic", 10_000_000, 100, "reg", objective="reg:squarederror"
    ),
    "higgs": DatasetSpec(
        "higgs", 11_000_000, 28, "binary",
        objective="binary:logistic", metric="accuracy",
    ),
    "covtype": DatasetSpec(
        "covtype", 581_012, 54, "multiclass", n_classes=7,
        objective="multi:softmax", metric="accuracy",
    ),
    "bosch": DatasetSpec(
        "bosch", 1_183_747, 968, "binary", missing_frac=0.81,
        objective="binary:logistic", metric="accuracy",
    ),
    "airline": DatasetSpec(
        "airline", 115_000_000, 13, "binary",
        objective="binary:logistic", metric="accuracy",
    ),
}


def dataset_spec(name: str) -> DatasetSpec:
    return DATASETS[name]


def make_dataset(
    name: str,
    n_rows: int | None = None,
    seed: int = 0,
    dtype=np.float32,
) -> tuple[np.ndarray, np.ndarray, DatasetSpec]:
    """Generate (x, y, spec). n_rows defaults to the full paper size —
    pass a reduced count for CPU benchmarking."""
    spec = DATASETS[name]
    n = n_rows or spec.n_rows
    f = spec.n_features
    # zlib.crc32, not hash(): Python string hashing is randomised per
    # process, which would make every process generate different "datasets"
    # (and cross-process comparisons — e.g. single- vs multi-device CLI
    # runs — silently incomparable).
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2**31)

    x = rng.standard_normal((n, f), dtype=np.float32)
    # Learnable structure: sparse linear signal + pairwise interactions.
    # Informative features are the FIRST k columns so that benchmark column
    # caps (e.g. bosch's 968 -> 128 on CPU) keep the signal intact.
    k = max(3, min(f // 5, 24))
    w = np.zeros(f, np.float32)
    w[:k] = rng.standard_normal(k).astype(np.float32)
    signal = x @ w
    for _ in range(3):
        i, j = rng.integers(0, k, size=2)
        signal += 0.5 * x[:, i] * x[:, j]
    noise = 0.3 * rng.standard_normal(n).astype(np.float32)

    if spec.task == "reg":
        y = (signal + noise).astype(dtype)
    elif spec.task == "binary":
        y = (signal + noise > 0).astype(dtype)
    else:
        qs = np.quantile(signal, np.linspace(0, 1, spec.n_classes + 1)[1:-1])
        y = np.digitize(signal + noise, qs).astype(dtype)

    if spec.missing_frac > 0:
        mask = rng.random(x.shape) < spec.missing_frac
        x[mask] = np.nan

    return x.astype(dtype), y, spec
