"""Data pipeline: synthetic generators for the paper's six benchmark
datasets (Table 1 shapes) and a deterministic LM token stream."""
from repro.data.datasets import DATASETS, DatasetSpec, dataset_spec, make_dataset
from repro.data.tokens import TokenStream

__all__ = ["DATASETS", "DatasetSpec", "dataset_spec", "make_dataset", "TokenStream"]
