"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060]

Pure Mamba2 stack; decode state is O(1) per layer so long_500k is the
native use case (no KV cache at all).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_head_dim=64,
    source="arXiv:2405.21060",
)
