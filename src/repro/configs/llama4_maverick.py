"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, MoE 128 experts top-1, vocab=202048 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

All layers are MoE with switch (top-1) routing over 128 experts; experts
are sharded over the `model` mesh axis (8 experts/chip at model=16).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    sliding_window=8192,  # engaged only for long_500k
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
