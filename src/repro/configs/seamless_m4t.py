"""seamless-m4t-medium [audio]: 12L d_model=1024 16H d_ff=4096
vocab=256206 — encoder-decoder, multimodal. [arXiv:2308.11596]

12 encoder + 12 decoder layers (the model card's text/speech stacks are
12L each; n_layers here counts the decoder, n_enc_layers the encoder).
The audio frontend (mel + conv feature extractor) is a STUB per the brief:
input_specs() provides precomputed frame embeddings. long_500k is SKIPPED
for this arch (DESIGN.md §6): the translation decoder's target length is
architecturally bounded far below 500k tokens.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    source="arXiv:2308.11596",
)
