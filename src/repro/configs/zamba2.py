"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

81 Mamba2 layers with ONE weight-shared attention block applied every 6
mamba layers (13 applications + 3 trailing mamba layers). The shared block
keeps a separate KV cache per application. long_500k runs natively (SSM
state is O(1)); the shared attention uses its sliding window there.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    sliding_window=8192,  # engaged for long_500k shared-attn blocks
    source="arXiv:2411.15242",
)
