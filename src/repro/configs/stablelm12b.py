"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    arch_type="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    sliding_window=8192,  # engaged only for long_500k
    source="hf:stabilityai/stablelm-2-1_6b",
)
