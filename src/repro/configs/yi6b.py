"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-architecture GQA. [arXiv:2403.04652]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    sliding_window=8192,  # engaged only for long_500k
    source="arXiv:2403.04652",
)
