"""Assigned architecture configs (exact specs from the public pool) plus the
GBDT configs for the paper's own benchmark datasets.

get_arch(name) -> ArchConfig;  ARCHS lists all ten assigned ids.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "phi-3-vision-4.2b",
    "zamba2-7b",
    "mamba2-2.7b",
    "minicpm3-4b",
    "glm4-9b",
    "yi-6b",
    "seamless-m4t-medium",
    "llama4-maverick-400b-a17b",
    "stablelm-12b",
    "llama4-scout-17b-a16e",
]

_MODULES = {
    "phi-3-vision-4.2b": "phi3_vision",
    "zamba2-7b": "zamba2",
    "mamba2-2.7b": "mamba2",
    "minicpm3-4b": "minicpm3",
    "glm4-9b": "glm4",
    "yi-6b": "yi6b",
    "seamless-m4t-medium": "seamless_m4t",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "stablelm-12b": "stablelm12b",
    "llama4-scout-17b-a16e": "llama4_scout",
}


def get_arch(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
