"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
MoE 16 experts top-1, vocab=202048 — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    sliding_window=8192,  # engaged only for long_500k
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
