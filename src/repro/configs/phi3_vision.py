"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — phi3-mini LM backbone + CLIP vision frontend.
[hf:microsoft/Phi-3-vision-128k-instruct]

The vision frontend (CLIP ViT-L/14 + projector) is a STUB per the brief:
input_specs() provides 576 precomputed patch embeddings per image, consumed
through a learned projection by the decoder-only LM backbone implemented
here. For long_500k the backbone runs the sliding-window variant (the real
phi3 family uses blocksparse/LongRoPE for 128k; SWA is our documented
sub-quadratic carve-out, DESIGN.md §6).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    n_prefix_tokens=576,  # 24x24 CLIP patches per image
    sliding_window=8192,  # engaged only for the long_500k shape
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
