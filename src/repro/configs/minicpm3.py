"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention). [hf:openbmb/MiniCPM3-4B]

MLA compresses the KV state to a rank-256 latent + one shared RoPE key:
the decode cache stores kv_lora_rank + rope_head_dim = 288 floats/token
instead of 2*40*64 = 5120 — an 17.8x KV-cache compression, the same
memory-per-token play as the paper's bit-packed quantised matrix (§2.2).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    arch_type="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    kv_lora_rank=256,
    q_lora_rank=768,
    rope_head_dim=32,
    nope_head_dim=64,
    sliding_window=8192,  # engaged only for long_500k
    source="hf:openbmb/MiniCPM3-4B",
)
