"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — RoPE, aggressive GQA (2 KV heads). [hf:THUDM/glm-4-9b]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    arch_type="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    sliding_window=8192,  # engaged only for long_500k
    source="hf:THUDM/glm-4-9b",
)
