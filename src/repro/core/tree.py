"""Decision tree construction (paper §2.3, Algorithm 1), jit-compatible form.

Algorithm 1 grows via a dynamic expand queue; data-dependent tree shapes
cannot be traced, so we grow *level-synchronously* into a fixed arena of
2^(max_depth+1) - 1 node slots (DESIGN.md §7.3). All nodes of a level are
histogrammed in ONE fused build (the level-local node id joins the scatter
index), which also batches the AllReduce — one collective per level instead
of one per expand-queue entry (a beyond-paper win recorded in EXPERIMENTS.md).

Growth strategies (the paper: "reconfigurable to prioritise expanding nodes
with a higher reduction in the objective function or nodes closer to the
root"):
  * "depthwise"  — expand every node whose best gain > 0 (closer-to-root
    priority is implied by level order);
  * "lossguide"  — a max_leaves budget; within each level only the top-k
    gains split, k = remaining leaf budget (gain-priority emulation).

`axis_name`: when set, histograms are partial (this shard's rows) and are
combined with jax.lax.psum — the paper's NCCL AllReduceHistograms.
`extra_axes`: further mesh axes to reduce over (e.g. ("pod",)).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import compress as C
from repro.core import histogram as H
from repro.core import partition as P
from repro.core import sampling as SMP
from repro.core import split as S


class Tree(NamedTuple):
    """Array-form tree arena (all arrays length 2^(max_depth+1) - 1)."""

    feature: jax.Array  # int32
    split_bin: jax.Array  # int32 (bin-space threshold: bin <= split_bin -> left)
    threshold: jax.Array  # float32 (raw-space threshold: x <= threshold -> left)
    default_left: jax.Array  # bool
    leaf_value: jax.Array  # float32
    is_leaf: jax.Array  # bool
    gain: jax.Array  # float32 (split gain; for importances)

    @property
    def n_arena(self) -> int:
        return self.feature.shape[0]


def arena_size(max_depth: int) -> int:
    return 2 ** (max_depth + 1) - 1


def level_offset(level: int) -> int:
    return 2**level - 1


def grow_tree(
    bins: jax.Array | C.PackedBins | C.ChunkedPackedBins,  # dense rows OR packed
    gh: jax.Array,  # (n, 2) float32
    cuts: jax.Array,  # (f, n_cuts) float32
    max_depth: int,
    max_bins: int,
    params: S.SplitParams = S.SplitParams(),
    growth: str = "depthwise",
    max_leaves: int = 0,  # only used by lossguide
    axis_name: str | None = None,
    extra_axes: Sequence[str] = (),
    feature_axis: str | None = None,
    hist_builder=None,  # optional kernel-backed builder (kernels.ops)
    hist_block_rows: int = 65536,  # packed fallback's dense-tile bound
    hist_subtraction: bool = True,  # smaller-child build + sibling = parent - child
    ctx: SMP.TreeContext | None = None,  # stochastic/constrained growth
    collective=None,  # repro.dist.Collective reduction strategy
) -> Tree:
    """When `bins` is a compress.PackedBins, the tree grows *packed-native*
    (DESIGN.md §2): histograms are built straight from the uint32 words
    (Pallas kernel or the row-block-scan XLA fallback) and row routing
    extracts the split-feature column on the fly — the dense (n, f) bins
    matrix is never materialised. A custom `hist_builder` receives whatever
    representation grow_tree was given.

    When `feature_axis` is set (beyond-paper mode, DESIGN.md §3): `bins`
    and `cuts` hold only this shard's feature slice; histograms stay
    feature-local (1/p of the paper's AllReduce bytes move over the wire),
    splits are evaluated feature-locally and the winner is chosen via an
    all-gather of tiny per-node best-split records; row routing for a split
    owned by another shard arrives via a psum'd route vector.

    `ctx` (DESIGN.md §12) threads per-tree stochastic state: when
    `ctx.row_ids` is set, `gh` is the gathered (m, 2) buffer and the whole
    construction — histograms (via the compacted `*_rows` builders, the
    subtraction trick composed on top), routing, node sums — runs in
    buffer space, so a subsampled round does proportionally less scatter
    work while the dense matrix still never materialises. Feature masks
    (per tree/level/node) and monotone bounds are applied in
    split.evaluate_splits; bounds propagate down the arena. `ctx=None`
    compiles to the exact pre-stochastic program."""
    if collective is not None:
        # A dist.Collective owns the reduction topology (and optional
        # payload compression); its mesh axes drive the same sharded-growth
        # gating as plain axis_name (no subtraction trick, masked-mode
        # subsampling only).
        axis_name, extra_axes = collective.axes[0], collective.axes[1:]
    packed_mode = isinstance(bins, C.PackedBins)
    chunked_mode = isinstance(bins, C.ChunkedPackedBins)
    # Streamed out-of-core bins (core/stream.py) are duck-typed: they are
    # not a traceable pytree (they own a Python chunk pager), so grow_tree
    # must be running EAGERLY to use them — the stream runner guarantees
    # that. Dispatch by attribute to avoid a tree -> stream import cycle.
    streamed_mode = bool(getattr(bins, "is_streamed", False))
    if streamed_mode and (axis_name is not None or collective is not None
                          or feature_axis is not None
                          or hist_builder is not None):
        raise NotImplementedError(
            "streamed out-of-core growth is single-shard with the default "
            "builders; use resident paging for sharded or kernel fits"
        )
    if packed_mode or chunked_mode or streamed_mode:
        if feature_axis is not None:
            raise NotImplementedError(
                "feature-sharded growth requires dense bins (unpack per shard)"
            )
        n, f = bins.n_rows, bins.n_features
    else:
        n, f = bins.shape
    na = arena_size(max_depth)
    missing_bin = max_bins - 1

    stoch = ctx.params if ctx is not None else None
    row_ids = ctx.row_ids if ctx is not None else None
    sampled = row_ids is not None
    if sampled:
        if hist_builder is not None:
            raise NotImplementedError(
                "custom/kernel hist builders are not row-subset aware; use "
                "masked-mode subsampling (ctx.row_ids=None) with them"
            )
        if feature_axis is not None or axis_name is not None:
            raise NotImplementedError(
                "sharded growth uses masked-mode subsampling "
                "(ctx.row_ids=None); compact buffers are single-shard only"
            )
        if not (packed_mode or chunked_mode or streamed_mode):
            # Dense path: gather the sampled view once, then grow as usual.
            bins = bins[row_ids]
            row_ids, sampled = None, False
        n = gh.shape[0]  # buffer size m — positions/compaction live here
    mono_on = stoch is not None and stoch.monotone_on
    if mono_on:
        if len(stoch.monotone) != f:
            raise ValueError(
                f"monotone constraints cover {len(stoch.monotone)} features "
                f"but the matrix has {f}"
            )
        mono_arr = jnp.asarray(stoch.monotone, jnp.int32)
        lower = jnp.full(na, -jnp.inf, jnp.float32)
        upper = jnp.full(na, jnp.inf, jnp.float32)

    if hist_builder is not None:
        if chunked_mode:
            raise NotImplementedError(
                "custom/kernel hist builders are not chunk-aware; use the "
                "default builders for external-memory training"
            )
        build = hist_builder
    elif sampled and streamed_mode:
        def build(sb, gh_, pos_, n_nodes_, max_bins_):
            return sb.build_histograms_rows(gh_, pos_, row_ids, n_nodes_,
                                            max_bins_)
    elif streamed_mode:
        def build(sb, gh_, pos_, n_nodes_, max_bins_):
            return sb.build_histograms(gh_, pos_, n_nodes_, max_bins_)
    elif sampled and chunked_mode:
        def build(cpb, gh_, pos_, n_nodes_, max_bins_):
            return H.build_histograms_chunked_rows(
                cpb.packed, gh_, pos_, row_ids, n_nodes_, max_bins_,
                cpb.bits, cpb.chunk_rows, block_rows=hist_block_rows,
            )
    elif sampled:
        def build(pb, gh_, pos_, n_nodes_, max_bins_):
            return H.build_histograms_packed_rows(
                pb.packed, gh_, pos_, row_ids, n_nodes_, max_bins_,
                pb.bits, block_rows=hist_block_rows,
            )
    elif chunked_mode:
        def build(cpb, gh_, pos_, n_nodes_, max_bins_):
            return H.build_histograms_chunked(
                cpb.packed, gh_, pos_, n_nodes_, max_bins_,
                cpb.bits, cpb.chunk_rows, cpb.n_rows,
            )
    elif packed_mode:
        def build(pb, gh_, pos_, n_nodes_, max_bins_):
            return H.build_histograms_packed(
                pb.packed, gh_, pos_, n_nodes_, max_bins_,
                pb.bits, pb.n_rows, block_rows=hist_block_rows,
            )
    else:
        build = H.build_histograms

    feature = jnp.zeros(na, jnp.int32)
    split_bin = jnp.zeros(na, jnp.int32)
    default_left = jnp.zeros(na, bool)
    leaf_value = jnp.zeros(na, jnp.float32)
    is_leaf = jnp.zeros(na, bool)
    gain_arr = jnp.full(na, -jnp.inf, jnp.float32)
    node_sum = jnp.zeros((na, 2), jnp.float32)

    positions = jnp.zeros(n, jnp.int32)  # all rows start at the root
    root_sum = jnp.sum(gh, axis=0)
    if collective is not None:
        root_sum = collective.allreduce(root_sum)
    elif axis_name is not None:
        root_sum = jax.lax.psum(root_sum, (axis_name, *extra_axes))
    node_sum = node_sum.at[0].set(root_sum)
    active = jnp.zeros(na, bool).at[0].set(True)
    # lossguide leaf budget: a tree starts as 1 leaf; each split adds 1.
    budget = jnp.asarray(max(max_leaves - 1, 0) if growth == "lossguide" else na)

    # Histogram-subtraction trick (DESIGN.md §7.5): below the root, build
    # histograms only for each parent's smaller child (by instance count)
    # over a compacted n//2 row buffer, and derive the sibling as
    # parent_hist - child_hist. Needs single-shard rows and the default
    # builders (a kernel builder keeps full per-level builds).
    use_subtraction = (
        hist_subtraction
        and hist_builder is None
        and axis_name is None
        and feature_axis is None
    )
    hist_prev = None

    for level in range(max_depth):
        off = level_offset(level)
        n_nodes = 2**level

        # --- BuildPartialHistograms (per-shard rows) ---------------------
        local = jnp.where(
            (positions >= off) & (positions < off + n_nodes),
            positions - off,
            n_nodes,
        ).astype(jnp.int32)
        if use_subtraction and level > 0:
            hist = _histograms_by_subtraction(
                bins, gh, local, hist_prev, n_nodes, max_bins,
                hist_block_rows, row_ids=row_ids,
            )
        else:
            hist = build(bins, gh, local, n_nodes, max_bins)
            # --- AllReduceHistograms (paper: NCCL; here: psum, or a
            # dist.Collective strategy with optional compressed payload) ---
            if collective is not None:
                hist = collective.allreduce_hist(hist)
            elif axis_name is not None:
                hist = jax.lax.psum(hist, (axis_name, *extra_axes))
        hist_prev = hist

        # --- EvaluateSplit (prefix-sum scan over bins) -------------------
        parent = jax.lax.dynamic_slice_in_dim(node_sum, off, n_nodes)
        feature_mask = (
            SMP.level_feature_mask(ctx, level, n_nodes, f)
            if ctx is not None else None
        )
        if mono_on:
            lvl_lo = jax.lax.dynamic_slice_in_dim(lower, off, n_nodes)
            lvl_hi = jax.lax.dynamic_slice_in_dim(upper, off, n_nodes)
            bounds = jnp.stack([lvl_lo, lvl_hi], axis=-1)
            sp = S.evaluate_splits(
                hist, parent, params, feature_mask=feature_mask,
                monotone=mono_arr, node_bounds=bounds,
            )
        else:
            sp = S.evaluate_splits(hist, parent, params,
                                   feature_mask=feature_mask)
        if feature_axis is not None:
            sp = _combine_feature_shards(sp, f, feature_axis)

        lvl_active = jax.lax.dynamic_slice_in_dim(active, off, n_nodes)
        will_split = lvl_active & (sp.gain > 0.0) & jnp.isfinite(sp.gain)

        if growth == "lossguide":
            # Keep only the top-`budget` gains among would-be splits.
            g = jnp.where(will_split, sp.gain, -jnp.inf)
            order = jnp.argsort(-g)  # descending
            rank = jnp.zeros(n_nodes, jnp.int32).at[order].set(
                jnp.arange(n_nodes, dtype=jnp.int32)
            )
            will_split = will_split & (rank < budget)
            budget = budget - jnp.sum(will_split)

        idx = off + jnp.arange(n_nodes)
        feature = feature.at[idx].set(jnp.where(will_split, sp.feature, 0))
        split_bin = split_bin.at[idx].set(jnp.where(will_split, sp.split_bin, 0))
        default_left = default_left.at[idx].set(will_split & sp.default_left)
        gain_arr = gain_arr.at[idx].set(jnp.where(will_split, sp.gain, -jnp.inf))
        is_leaf = is_leaf.at[idx].set(lvl_active & ~will_split)
        lvl_leaf = S.leaf_value(parent, params.reg_lambda)
        if mono_on:  # leaf weights respect the inherited bounds
            lvl_leaf = jnp.clip(lvl_leaf, lvl_lo, lvl_hi)
        leaf_value = leaf_value.at[idx].set(
            jnp.where(lvl_active & ~will_split, lvl_leaf, 0.0)
        )

        # Children bookkeeping (sums come from the split evaluation — no
        # extra pass over the data, mirroring the paper's histogram reuse).
        lidx, ridx = 2 * idx + 1, 2 * idx + 2
        node_sum = node_sum.at[lidx].set(jnp.where(will_split[:, None], sp.left_sum, 0.0))
        node_sum = node_sum.at[ridx].set(jnp.where(will_split[:, None], sp.right_sum, 0.0))
        active = active.at[lidx].set(will_split).at[ridx].set(will_split)

        if mono_on:
            # Monotone bound propagation (XGBoost's scheme): the midpoint of
            # the clipped child weights becomes the dividing bound on the
            # constrained side; the other side inherits the parent's bound.
            wl = jnp.clip(S.leaf_value(sp.left_sum, params.reg_lambda),
                          lvl_lo, lvl_hi)
            wr = jnp.clip(S.leaf_value(sp.right_sum, params.reg_lambda),
                          lvl_lo, lvl_hi)
            mid = 0.5 * (wl + wr)
            csign = mono_arr[sp.feature]
            l_lo = jnp.where(csign < 0, mid, lvl_lo)
            l_hi = jnp.where(csign > 0, mid, lvl_hi)
            r_lo = jnp.where(csign > 0, mid, lvl_lo)
            r_hi = jnp.where(csign < 0, mid, lvl_hi)
            keep = ~will_split
            lower = lower.at[lidx].set(jnp.where(keep, -jnp.inf, l_lo))
            lower = lower.at[ridx].set(jnp.where(keep, -jnp.inf, r_lo))
            upper = upper.at[lidx].set(jnp.where(keep, jnp.inf, l_hi))
            upper = upper.at[ridx].set(jnp.where(keep, jnp.inf, r_hi))

        # --- RepartitionInstances ----------------------------------------
        split_mask = jnp.zeros(na, bool).at[idx].set(will_split)
        full_feature = jnp.zeros(na, jnp.int32).at[idx].set(feature[idx])
        full_bin = jnp.zeros(na, jnp.int32).at[idx].set(split_bin[idx])
        full_dl = jnp.zeros(na, bool).at[idx].set(default_left[idx])
        if sampled and streamed_mode:
            positions = bins.update_positions_rows(
                positions, split_mask, full_feature, full_bin, full_dl,
                missing_bin, row_ids,
            )
        elif streamed_mode:
            positions = bins.update_positions(
                positions, split_mask, full_feature, full_bin, full_dl,
                missing_bin,
            )
        elif sampled and chunked_mode:
            positions = P.update_positions_chunked_rows(
                bins.packed, positions, split_mask, full_feature, full_bin,
                full_dl, missing_bin, bins.bits, bins.chunk_rows, row_ids,
            )
        elif sampled:
            positions = P.update_positions_packed_rows(
                bins.packed, positions, split_mask, full_feature, full_bin,
                full_dl, missing_bin, bins.bits, row_ids,
            )
        elif chunked_mode:
            positions = P.update_positions_chunked(
                bins.packed, positions, split_mask, full_feature, full_bin,
                full_dl, missing_bin, bins.bits, bins.chunk_rows, bins.n_rows,
            )
        elif packed_mode:
            positions = P.update_positions_packed(
                bins.packed, positions, split_mask, full_feature, full_bin,
                full_dl, missing_bin, bins.bits,
            )
        elif feature_axis is None:
            positions = P.update_positions(
                bins, positions, split_mask, full_feature, full_bin, full_dl,
                missing_bin,
            )
        else:
            positions = _update_positions_feature_sharded(
                bins, positions, split_mask, full_feature, full_bin, full_dl,
                missing_bin, f, feature_axis,
            )

    # Final level: every still-active node is a leaf.
    off = level_offset(max_depth)
    n_nodes = 2**max_depth
    idx = off + jnp.arange(n_nodes)
    lvl_active = jax.lax.dynamic_slice_in_dim(active, off, n_nodes)
    parent = jax.lax.dynamic_slice_in_dim(node_sum, off, n_nodes)
    is_leaf = is_leaf.at[idx].set(lvl_active)
    final_leaf = S.leaf_value(parent, params.reg_lambda)
    if mono_on:
        final_leaf = jnp.clip(
            final_leaf,
            jax.lax.dynamic_slice_in_dim(lower, off, n_nodes),
            jax.lax.dynamic_slice_in_dim(upper, off, n_nodes),
        )
    leaf_value = leaf_value.at[idx].set(
        jnp.where(lvl_active, final_leaf, 0.0)
    )

    # Raw-space thresholds for prediction on unquantised inputs.
    if feature_axis is None:
        threshold = cuts[feature, jnp.clip(split_bin, 0, cuts.shape[1] - 1)]
    else:
        my = jax.lax.axis_index(feature_axis)
        f_loc = jnp.clip(feature - my * f, 0, f - 1)
        owned = (feature // f) == my
        thr_local = cuts[f_loc, jnp.clip(split_bin, 0, cuts.shape[1] - 1)]
        threshold = jax.lax.psum(jnp.where(owned, thr_local, 0.0), feature_axis)
    threshold = jnp.where(is_leaf, jnp.inf, threshold)

    return Tree(
        feature=feature,
        split_bin=split_bin,
        threshold=threshold,
        default_left=default_left,
        leaf_value=leaf_value,
        is_leaf=is_leaf,
        gain=gain_arr,
    )


def _histograms_by_subtraction(
    bins: jax.Array | C.PackedBins,
    gh: jax.Array,
    local: jax.Array,  # (n,) level-local child index, n_nodes = inactive
    hist_prev: jax.Array,  # (n_nodes/2, f, max_bins, 2) parents' full hist
    n_nodes: int,
    max_bins: int,
    hist_block_rows: int,
    row_ids: jax.Array | None = None,  # sampled mode: slot -> global row id
) -> jax.Array:
    """Level histogram via the subtraction trick (DESIGN.md §7.5).

    Per parent, only the smaller child (by instance count) is histogrammed;
    its sibling is parent - child. Since sum_p min(left_p, right_p) <=
    floor(n/2), a static n//2 compaction buffer always suffices — the
    scatter work of every level below the root is halved, which is the
    dominant cost of a boosting round on scatter-bound backends.

    With `row_ids` (subsampled growth, DESIGN.md §12) everything above runs
    in buffer space — `gh`/`local` are (m,)-shaped, the compaction buffer is
    m//2 — and only the word gathers translate slots to global rows.
    """
    packed_mode = isinstance(bins, C.PackedBins)
    chunked_mode = isinstance(bins, C.ChunkedPackedBins)
    n = gh.shape[0]
    n_par = n_nodes // 2
    m = n // 2

    # Instance counts per child -> smaller-child bit per parent (ties: left).
    cnt = jnp.zeros(n_nodes + 1, jnp.int32).at[local].add(1)
    small_bit = (cnt[1:n_nodes:2] < cnt[0:n_nodes:2]).astype(jnp.int32)

    is_active = local < n_nodes
    par = jnp.minimum(local >> 1, n_par - 1)
    sel = is_active & ((local & 1) == small_bit[par])

    # Compact selected row ids into the n//2 buffer (sentinel n = padding).
    order = jnp.cumsum(sel) - 1
    buf = jnp.full(m, n, jnp.int32).at[
        jnp.where(sel, order, m)
    ].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    parent_ext = jnp.concatenate(
        [jnp.where(sel, par, n_par).astype(jnp.int32),
         jnp.full((1,), n_par, jnp.int32)]
    )
    pos_c = parent_ext[jnp.minimum(buf, n)]
    gh_c = gh[jnp.minimum(buf, n - 1)]
    # Buffer slots -> rows for the word gathers (padding slots carry a real
    # row id but their pos is the dump slot, so they contribute nothing).
    rid_c = buf if row_ids is None else row_ids[jnp.minimum(buf, n - 1)]

    if getattr(bins, "is_streamed", False):
        # buf is ascending (selected rows in row order, sentinels at the
        # tail), so rid_c is ascending too — the streamed builder's
        # per-chunk segmentation requirement. Sentinel slots route to the
        # dump position and contribute nothing wherever they land.
        hist_small = bins.build_histograms_rows(gh_c, pos_c, rid_c, n_par,
                                                max_bins)
    elif chunked_mode:
        hist_small = H.build_histograms_chunked_rows(
            bins.packed, gh_c, pos_c, rid_c, n_par, max_bins, bins.bits,
            bins.chunk_rows, block_rows=hist_block_rows,
        )
    elif packed_mode:
        hist_small = H.build_histograms_packed_rows(
            bins.packed, gh_c, pos_c, rid_c, n_par, max_bins, bins.bits,
            block_rows=hist_block_rows,
        )
    else:
        bins_c = bins[jnp.minimum(buf, n - 1)]
        hist_small = H.build_histograms(bins_c, gh_c, pos_c, n_par, max_bins)

    other = hist_prev - hist_small
    built_left = (small_bit == 0)[:, None, None, None]
    left = jnp.where(built_left, hist_small, other)
    right = jnp.where(built_left, other, hist_small)
    f = hist_prev.shape[1]
    return jnp.stack([left, right], axis=1).reshape(n_nodes, f, max_bins, 2)


def _combine_feature_shards(sp: S.Splits, f_local: int, feature_axis: str) -> S.Splits:
    """Pick the global best split from feature-shard-local bests.

    All-gathers only the per-node best-split records (a few bytes per node)
    instead of full histograms — this is the collective-term optimisation
    measured in EXPERIMENTS.md §Perf. Tie-break matches the single-shard
    global argmax (lowest global feature id wins).
    """
    my = jax.lax.axis_index(feature_axis)
    sp = sp._replace(feature=sp.feature + my * f_local)
    g = jax.lax.all_gather(sp, feature_axis)  # every leaf gains axis 0 (p,)
    win = jnp.argmax(g.gain, axis=0)  # (n_nodes,) first max = lowest shard

    def take(arr):
        w = win.reshape(win.shape + (1,) * (arr.ndim - 1 - win.ndim))
        return jnp.take_along_axis(arr, w[None], axis=0)[0]

    return S.Splits(*(take(x) for x in g))


def _update_positions_feature_sharded(
    bins: jax.Array,
    positions: jax.Array,
    split_mask: jax.Array,
    feature: jax.Array,  # (n_arena,) GLOBAL feature ids
    split_bin: jax.Array,
    default_left: jax.Array,
    missing_bin: int,
    f_local: int,
    feature_axis: str,
) -> jax.Array:
    """RepartitionInstances when the winning feature's bins may live on
    another feature shard: the owner computes the route (1=left, 2=right)
    and a psum broadcasts it to all shards (n_rows int32 per level)."""
    my = jax.lax.axis_index(feature_axis)
    pos = jnp.maximum(positions, 0)
    active = positions >= 0
    splits_here = split_mask[pos] & active

    f_glob = feature[pos]
    owned = (f_glob // f_local) == my
    f_loc = jnp.clip(f_glob - my * f_local, 0, f_local - 1)
    b = jnp.take_along_axis(bins, f_loc[:, None], axis=1)[:, 0]
    go_left = jnp.where(b == missing_bin, default_left[pos], b <= split_bin[pos])
    route = jnp.where(splits_here & owned, jnp.where(go_left, 1, 2), 0)
    # int8 on the wire: exactly one shard contributes a nonzero (<=2) value,
    # so the psum fits in int8 — 4x fewer routing bytes per level (§Perf
    # GBDT iteration 2; routing dominates collectives for narrow matrices).
    route = jax.lax.psum(route.astype(jnp.int8), feature_axis).astype(jnp.int32)
    child = 2 * pos + route
    return jnp.where(splits_here, child, -1).astype(jnp.int32)
