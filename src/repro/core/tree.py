"""Decision tree construction (paper §2.3, Algorithm 1), jit-compatible form.

Algorithm 1 grows via a dynamic expand queue; data-dependent tree shapes
cannot be traced, so we grow *level-synchronously* into a fixed arena of
2^(max_depth+1) - 1 node slots (DESIGN.md §7.3). All nodes of a level are
histogrammed in ONE fused build (the level-local node id joins the scatter
index), which also batches the AllReduce — one collective per level instead
of one per expand-queue entry (a beyond-paper win recorded in EXPERIMENTS.md).

Growth strategies (the paper: "reconfigurable to prioritise expanding nodes
with a higher reduction in the objective function or nodes closer to the
root"):
  * "depthwise"  — expand every node whose best gain > 0 (closer-to-root
    priority is implied by level order);
  * "lossguide"  — a max_leaves budget; within each level only the top-k
    gains split, k = remaining leaf budget (gain-priority emulation).

`axis_name`: when set, histograms are partial (this shard's rows) and are
combined with jax.lax.psum — the paper's NCCL AllReduceHistograms.
`extra_axes`: further mesh axes to reduce over (e.g. ("pod",)).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import histogram as H
from repro.core import partition as P
from repro.core import split as S


class Tree(NamedTuple):
    """Array-form tree arena (all arrays length 2^(max_depth+1) - 1)."""

    feature: jax.Array  # int32
    split_bin: jax.Array  # int32 (bin-space threshold: bin <= split_bin -> left)
    threshold: jax.Array  # float32 (raw-space threshold: x <= threshold -> left)
    default_left: jax.Array  # bool
    leaf_value: jax.Array  # float32
    is_leaf: jax.Array  # bool
    gain: jax.Array  # float32 (split gain; for importances)

    @property
    def n_arena(self) -> int:
        return self.feature.shape[0]


def arena_size(max_depth: int) -> int:
    return 2 ** (max_depth + 1) - 1


def level_offset(level: int) -> int:
    return 2**level - 1


def grow_tree(
    bins: jax.Array,  # (n, f) int32 quantised rows (this shard's rows)
    gh: jax.Array,  # (n, 2) float32
    cuts: jax.Array,  # (f, n_cuts) float32
    max_depth: int,
    max_bins: int,
    params: S.SplitParams = S.SplitParams(),
    growth: str = "depthwise",
    max_leaves: int = 0,  # only used by lossguide
    axis_name: str | None = None,
    extra_axes: Sequence[str] = (),
    feature_axis: str | None = None,
    hist_builder=None,  # optional kernel-backed builder (kernels.ops)
) -> Tree:
    """When `feature_axis` is set (beyond-paper mode, DESIGN.md §3): `bins`
    and `cuts` hold only this shard's feature slice; histograms stay
    feature-local (1/p of the paper's AllReduce bytes move over the wire),
    splits are evaluated feature-locally and the winner is chosen via an
    all-gather of tiny per-node best-split records; row routing for a split
    owned by another shard arrives via a psum'd route vector."""
    n, f = bins.shape
    na = arena_size(max_depth)
    missing_bin = max_bins - 1
    build = hist_builder or H.build_histograms

    feature = jnp.zeros(na, jnp.int32)
    split_bin = jnp.zeros(na, jnp.int32)
    default_left = jnp.zeros(na, bool)
    leaf_value = jnp.zeros(na, jnp.float32)
    is_leaf = jnp.zeros(na, bool)
    gain_arr = jnp.full(na, -jnp.inf, jnp.float32)
    node_sum = jnp.zeros((na, 2), jnp.float32)

    positions = jnp.zeros(n, jnp.int32)  # all rows start at the root
    root_sum = jnp.sum(gh, axis=0)
    if axis_name is not None:
        root_sum = jax.lax.psum(root_sum, (axis_name, *extra_axes))
    node_sum = node_sum.at[0].set(root_sum)
    active = jnp.zeros(na, bool).at[0].set(True)
    # lossguide leaf budget: a tree starts as 1 leaf; each split adds 1.
    budget = jnp.asarray(max(max_leaves - 1, 0) if growth == "lossguide" else na)

    for level in range(max_depth):
        off = level_offset(level)
        n_nodes = 2**level

        # --- BuildPartialHistograms (per-shard rows) ---------------------
        local = jnp.where(
            (positions >= off) & (positions < off + n_nodes),
            positions - off,
            n_nodes,
        ).astype(jnp.int32)
        hist = build(bins, gh, local, n_nodes, max_bins)
        # --- AllReduceHistograms (paper: NCCL; here: psum) ---------------
        if axis_name is not None:
            hist = jax.lax.psum(hist, (axis_name, *extra_axes))

        # --- EvaluateSplit (prefix-sum scan over bins) -------------------
        parent = jax.lax.dynamic_slice_in_dim(node_sum, off, n_nodes)
        sp = S.evaluate_splits(hist, parent, params)
        if feature_axis is not None:
            sp = _combine_feature_shards(sp, f, feature_axis)

        lvl_active = jax.lax.dynamic_slice_in_dim(active, off, n_nodes)
        will_split = lvl_active & (sp.gain > 0.0) & jnp.isfinite(sp.gain)

        if growth == "lossguide":
            # Keep only the top-`budget` gains among would-be splits.
            g = jnp.where(will_split, sp.gain, -jnp.inf)
            order = jnp.argsort(-g)  # descending
            rank = jnp.zeros(n_nodes, jnp.int32).at[order].set(
                jnp.arange(n_nodes, dtype=jnp.int32)
            )
            will_split = will_split & (rank < budget)
            budget = budget - jnp.sum(will_split)

        idx = off + jnp.arange(n_nodes)
        feature = feature.at[idx].set(jnp.where(will_split, sp.feature, 0))
        split_bin = split_bin.at[idx].set(jnp.where(will_split, sp.split_bin, 0))
        default_left = default_left.at[idx].set(will_split & sp.default_left)
        gain_arr = gain_arr.at[idx].set(jnp.where(will_split, sp.gain, -jnp.inf))
        is_leaf = is_leaf.at[idx].set(lvl_active & ~will_split)
        leaf_value = leaf_value.at[idx].set(
            jnp.where(lvl_active & ~will_split, S.leaf_value(parent, params.reg_lambda), 0.0)
        )

        # Children bookkeeping (sums come from the split evaluation — no
        # extra pass over the data, mirroring the paper's histogram reuse).
        lidx, ridx = 2 * idx + 1, 2 * idx + 2
        node_sum = node_sum.at[lidx].set(jnp.where(will_split[:, None], sp.left_sum, 0.0))
        node_sum = node_sum.at[ridx].set(jnp.where(will_split[:, None], sp.right_sum, 0.0))
        active = active.at[lidx].set(will_split).at[ridx].set(will_split)

        # --- RepartitionInstances ----------------------------------------
        split_mask = jnp.zeros(na, bool).at[idx].set(will_split)
        full_feature = jnp.zeros(na, jnp.int32).at[idx].set(feature[idx])
        full_bin = jnp.zeros(na, jnp.int32).at[idx].set(split_bin[idx])
        full_dl = jnp.zeros(na, bool).at[idx].set(default_left[idx])
        if feature_axis is None:
            positions = P.update_positions(
                bins, positions, split_mask, full_feature, full_bin, full_dl,
                missing_bin,
            )
        else:
            positions = _update_positions_feature_sharded(
                bins, positions, split_mask, full_feature, full_bin, full_dl,
                missing_bin, f, feature_axis,
            )

    # Final level: every still-active node is a leaf.
    off = level_offset(max_depth)
    n_nodes = 2**max_depth
    idx = off + jnp.arange(n_nodes)
    lvl_active = jax.lax.dynamic_slice_in_dim(active, off, n_nodes)
    parent = jax.lax.dynamic_slice_in_dim(node_sum, off, n_nodes)
    is_leaf = is_leaf.at[idx].set(lvl_active)
    leaf_value = leaf_value.at[idx].set(
        jnp.where(lvl_active, S.leaf_value(parent, params.reg_lambda), 0.0)
    )

    # Raw-space thresholds for prediction on unquantised inputs.
    if feature_axis is None:
        threshold = cuts[feature, jnp.clip(split_bin, 0, cuts.shape[1] - 1)]
    else:
        my = jax.lax.axis_index(feature_axis)
        f_loc = jnp.clip(feature - my * f, 0, f - 1)
        owned = (feature // f) == my
        thr_local = cuts[f_loc, jnp.clip(split_bin, 0, cuts.shape[1] - 1)]
        threshold = jax.lax.psum(jnp.where(owned, thr_local, 0.0), feature_axis)
    threshold = jnp.where(is_leaf, jnp.inf, threshold)

    return Tree(
        feature=feature,
        split_bin=split_bin,
        threshold=threshold,
        default_left=default_left,
        leaf_value=leaf_value,
        is_leaf=is_leaf,
        gain=gain_arr,
    )


def _combine_feature_shards(sp: S.Splits, f_local: int, feature_axis: str) -> S.Splits:
    """Pick the global best split from feature-shard-local bests.

    All-gathers only the per-node best-split records (a few bytes per node)
    instead of full histograms — this is the collective-term optimisation
    measured in EXPERIMENTS.md §Perf. Tie-break matches the single-shard
    global argmax (lowest global feature id wins).
    """
    my = jax.lax.axis_index(feature_axis)
    sp = sp._replace(feature=sp.feature + my * f_local)
    g = jax.lax.all_gather(sp, feature_axis)  # every leaf gains axis 0 (p,)
    win = jnp.argmax(g.gain, axis=0)  # (n_nodes,) first max = lowest shard

    def take(arr):
        w = win.reshape(win.shape + (1,) * (arr.ndim - 1 - win.ndim))
        return jnp.take_along_axis(arr, w[None], axis=0)[0]

    return S.Splits(*(take(x) for x in g))


def _update_positions_feature_sharded(
    bins: jax.Array,
    positions: jax.Array,
    split_mask: jax.Array,
    feature: jax.Array,  # (n_arena,) GLOBAL feature ids
    split_bin: jax.Array,
    default_left: jax.Array,
    missing_bin: int,
    f_local: int,
    feature_axis: str,
) -> jax.Array:
    """RepartitionInstances when the winning feature's bins may live on
    another feature shard: the owner computes the route (1=left, 2=right)
    and a psum broadcasts it to all shards (n_rows int32 per level)."""
    my = jax.lax.axis_index(feature_axis)
    pos = jnp.maximum(positions, 0)
    active = positions >= 0
    splits_here = split_mask[pos] & active

    f_glob = feature[pos]
    owned = (f_glob // f_local) == my
    f_loc = jnp.clip(f_glob - my * f_local, 0, f_local - 1)
    b = jnp.take_along_axis(bins, f_loc[:, None], axis=1)[:, 0]
    go_left = jnp.where(b == missing_bin, default_left[pos], b <= split_bin[pos])
    route = jnp.where(splits_here & owned, jnp.where(go_left, 1, 2), 0)
    # int8 on the wire: exactly one shard contributes a nonzero (<=2) value,
    # so the psum fits in int8 — 4x fewer routing bytes per level (§Perf
    # GBDT iteration 2; routing dominates collectives for narrow matrices).
    route = jax.lax.psum(route.astype(jnp.int8), feature_axis).astype(jnp.int32)
    child = 2 * pos + route
    return jnp.where(splits_here, child, -1).astype(jnp.int32)
