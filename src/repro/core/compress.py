"""Bit-packed quantised matrix (paper §2.2).

Matrix values are compressed to ceil(log2(max_value+1)) bits and packed into
uint32 words, unpacked at runtime with bitwise operations — exactly the
paper's scheme. The paper notes runtime unpacking is "more flexible than
precompiling many versions of the program"; in JAX the analogue is that the
bit width is a *static* argument so XLA specialises the shift/mask constants
per width without any code duplication on our side.

Layout is column-major per feature: symbols of feature f occupy packed[f, :],
with `spw = 32 // bits` symbols per word and no symbol straddling a word.
This is chosen for the Pallas histogram kernel: a (F_BLK, W_BLK) word tile
unpacks to a (F_BLK, W_BLK * spw) bin tile with pure lane-wise shifts.

Typical saving vs the fp32 input: 8-bit bins -> 4x (the paper's ">= 4x").
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def bits_needed(max_value: int) -> int:
    """ceil(log2(max_value + 1)), minimum 1."""
    return max(1, int(max_value).bit_length())


def symbols_per_word(bits: int) -> int:
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    return 32 // bits


@functools.partial(jax.jit, static_argnames=("bits",))
def pack(bins: jax.Array, bits: int) -> jax.Array:
    """Pack (n_rows, n_features) int bins -> (n_features, n_words) uint32.

    Rows are padded to a multiple of symbols_per_word(bits) with zeros.
    """
    n, f = bins.shape
    spw = symbols_per_word(bits)
    n_pad = (-n) % spw
    b = jnp.pad(bins.astype(jnp.uint32), ((0, n_pad), (0, 0)))
    b = b.T.reshape(f, -1, spw)  # (F, W, spw)
    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    return jnp.bitwise_or.reduce((b & mask) << shifts, axis=-1)


@functools.partial(jax.jit, static_argnames=("bits", "n_rows"))
def unpack(packed: jax.Array, bits: int, n_rows: int) -> jax.Array:
    """Inverse of pack: (n_features, n_words) uint32 -> (n_rows, n_features)."""
    spw = symbols_per_word(bits)
    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    b = (packed[:, :, None] >> shifts) & mask  # (F, W, spw)
    return b.reshape(packed.shape[0], -1)[:, :n_rows].T.astype(jnp.int32)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["packed"],
    meta_fields=["bits", "n_rows"],
)
@dataclass(frozen=True)
class PackedBins:
    """Traced view of the bit-packed matrix — the first-class training
    representation (DESIGN.md §2).

    Unlike CompressedMatrix (a host-side container that also carries cuts),
    PackedBins is a registered pytree so it can flow through jit / scan /
    shard_map. `bits` and `n_rows` are static metadata, so XLA specialises
    the shift/mask constants per width. grow_tree, update_positions and
    binned prediction all dispatch on this type and consume the packed
    words directly — the full dense (n_rows, n_features) bins array is
    never materialised after initial quantisation.
    """

    packed: jax.Array  # (n_features, n_words) uint32
    bits: int
    n_rows: int

    @property
    def n_features(self) -> int:
        return self.packed.shape[0]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["packed"],
    meta_fields=["bits", "chunk_rows", "n_rows"],
)
@dataclass(frozen=True)
class ChunkedPackedBins:
    """Chunk-stacked bit-packed matrix — the external-memory training
    representation (DESIGN.md §11).

    Each chunk of `chunk_rows` rows is packed independently (so chunks can
    be produced, paged and decoded without their neighbours) and the chunks
    are stacked on a leading axis. Like PackedBins this is a registered
    pytree, so the whole stack flows through jit / lax.scan / shard_map;
    the training loop scans the chunk axis, keeping dense per-row
    transients bounded by one chunk regardless of n_rows. Global row ids
    map to (chunk, offset) as (r // chunk_rows, r % chunk_rows); the last
    chunk may be logically short (n_rows bounds the real rows) and is
    padded with zero words.
    """

    packed: jax.Array  # (n_chunks, n_features, words_per_chunk) uint32
    bits: int
    chunk_rows: int
    n_rows: int

    @property
    def n_chunks(self) -> int:
        return self.packed.shape[0]

    @property
    def n_features(self) -> int:
        return self.packed.shape[1]

    @property
    def padded_rows(self) -> int:
        return self.packed.shape[0] * self.chunk_rows


def gather_rows_chunked(
    packed: jax.Array, bits: int, chunk_rows: int, row_ids: jax.Array
) -> jax.Array:
    """All features' bins for an arbitrary set of global row ids, straight
    from the chunk stack: (m,) int32 row ids -> (m, n_features) int32.

    The chunked analogue of `packed[:, r // spw]` + shift/mask on the flat
    layout — one word gather per (row, feature). row_ids are clipped into
    the padded range, so callers may use out-of-range sentinels for padding
    rows (their bins are garbage; route them to a dump slot).
    """
    n_chunks, f, _ = packed.shape
    spw = symbols_per_word(bits)
    r = jnp.clip(row_ids, 0, n_chunks * chunk_rows - 1)
    c = r // chunk_rows
    off = r % chunk_rows
    fidx = jnp.arange(f, dtype=jnp.int32)[None, :]
    words = packed[c[:, None], fidx, (off // spw)[:, None]]  # (m, f)
    shift = ((off % spw).astype(jnp.uint32) * jnp.uint32(bits))[:, None]
    return ((words >> shift) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def gather_feature_bins(packed: jax.Array, bits: int, feat: jax.Array) -> jax.Array:
    """Extract bins[i, feat[i]] for every row i straight from packed words.

    One uint32 word gather per row plus a shift/mask — the dense matrix
    never needs to exist. feat is (n,) int32 (a per-row feature id, e.g.
    the split feature of the node each row currently sits in).
    """
    n = feat.shape[0]
    row = jnp.arange(n, dtype=jnp.int32)
    return gather_feature_bins_rows(packed, bits, feat, row)


def gather_feature_bins_rows(
    packed: jax.Array, bits: int, feat: jax.Array, row_ids: jax.Array
) -> jax.Array:
    """gather_feature_bins for an ARBITRARY row set: bins[row_ids[i],
    feat[i]] per buffer slot i (the subsampled-row routing path,
    DESIGN.md §12). Same cost shape: one word gather + shift/mask per slot.
    """
    spw = symbols_per_word(bits)
    word = packed[feat, row_ids // spw]
    shift = (row_ids % spw).astype(jnp.uint32) * jnp.uint32(bits)
    return ((word >> shift) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def gather_feature_bins_chunked(
    packed: jax.Array, bits: int, chunk_rows: int,
    feat: jax.Array, row_ids: jax.Array,
) -> jax.Array:
    """gather_feature_bins_rows over the chunk-stacked layout: each global
    row id resolves to (chunk, offset) = (r // chunk_rows, r % chunk_rows)
    and one word of its owning chunk is gathered."""
    n_chunks, _, _ = packed.shape
    spw = symbols_per_word(bits)
    r = jnp.clip(row_ids, 0, n_chunks * chunk_rows - 1)
    c = r // chunk_rows
    off = r % chunk_rows
    word = packed[c, feat, off // spw]
    shift = (off % spw).astype(jnp.uint32) * jnp.uint32(bits)
    return ((word >> shift) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


@dataclass(frozen=True)
class CompressedMatrix:
    """The quantised + bit-packed training matrix ("ELLPACK page" analogue)."""

    packed: jax.Array  # (n_features, n_words) uint32
    cuts: jax.Array  # (n_features, n_cuts) float32
    bits: int
    n_rows: int
    max_bins: int

    @property
    def n_features(self) -> int:
        return self.packed.shape[0]

    def unpack(self) -> jax.Array:
        return unpack(self.packed, self.bits, self.n_rows)

    def nbytes_compressed(self) -> int:
        return int(np.prod(self.packed.shape)) * 4

    def nbytes_dense_fp32(self) -> int:
        return self.n_rows * self.n_features * 4

    def compression_ratio(self) -> float:
        return self.nbytes_dense_fp32() / self.nbytes_compressed()

    def as_packed_bins(self) -> PackedBins:
        return PackedBins(packed=self.packed, bits=self.bits, n_rows=self.n_rows)


def compress(
    bins: jax.Array,
    cuts: jax.Array,
    max_bins: int,
    max_value: int | None = None,
) -> CompressedMatrix:
    """Quantised matrix -> compressed form, choosing the minimal bit width.

    The paper compresses to log2(max_value) bits where max_value is the
    largest bin id actually present; we honour that (a dataset whose features
    all quantise to <= 16 distinct bins packs at 4-5 bits, not 8).

    `max_value`: pass it when known (e.g. a caller that just quantised with
    NaNs present knows the missing bin max_bins - 1 is occupied) to skip the
    device->host sync that `int(jnp.max(bins))` otherwise forces.
    """
    if max_value is None:
        max_value = int(jnp.max(bins))
    bits = bits_needed(max_value)
    return CompressedMatrix(
        packed=pack(bins, bits),
        cuts=cuts,
        bits=bits,
        n_rows=bins.shape[0],
        max_bins=max_bins,
    )
