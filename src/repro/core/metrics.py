"""Evaluation metric registry — metrics as first-class, pluggable objects.

XGBoost's enduring extension point: `eval_metric=[...]` accepts any mix of
built-in names and user callables, each metric carries its own `maximize`
direction (early stopping reads it from the METRIC, never from the
objective — see DESIGN.md §10), and several metrics can be evaluated per
round *inside* the compiled training scan (extra entries in the ys-stack,
no host round trips).

Every metric is an on-device JAX function `(margins, y, **extra) -> scalar`
over raw margins, so it traces straight into `lax.scan`:

  * margins: (n_rows, n_outputs) raw scores (pre-transform)
  * y:       (n_rows,) labels
  * extra:   dataset/config keywords (`group_ids` for ranking metrics,
             `quantile_alpha` for pinball loss); metrics ignore what they
             don't use.

Registry surface:

  * `METRICS` — name -> Metric for the built-ins
  * `register_metric(name, fn, maximize=...)` — user plugins
  * `get_metric(spec)` — resolves str | Metric | callable | (name, fn)
    | (name, fn, maximize); parameterised families like `ndcg@k` are
    constructed on demand and cached, so repeated lookups return the
    identical object (compile-cache friendly).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Metric(NamedTuple):
    name: str
    fn: Callable  # (margins, y, **extra) -> scalar
    maximize: bool = False  # early-stopping / best_iteration direction


METRICS: dict[str, Metric] = {}


def adapt_extra(fn: Callable) -> Callable:
    """Wrap `fn(margins, y, ...)` so surplus `extra` keywords (group_ids,
    quantile_alpha, ...) are filtered down to what the callable's signature
    accepts — inspected once, so plugins can take only the keywords they
    care about. Callables with `**kwargs` pass through untouched."""
    import inspect

    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):  # builtins / C callables
        return fn
    if any(p.kind == p.VAR_KEYWORD for p in params):
        return fn
    named = {p.name for p in params
             if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}

    def wrapped(*args, **extra):
        return fn(*args, **{k: v for k, v in extra.items() if k in named})

    return wrapped


def register_metric(name: str, fn: Callable, *, maximize: bool = False,
                    overwrite: bool = False) -> Metric:
    """Register a custom eval metric under `name`.

    `fn(margins, y, **extra) -> scalar` must be traceable JAX (it runs
    inside the compiled training scan). `maximize` tells early stopping
    which direction is better. Returns the registered Metric.
    """
    if name in METRICS and not overwrite:
        raise ValueError(
            f"metric {name!r} is already registered "
            "(pass overwrite=True to replace it)"
        )
    m = Metric(name=name, fn=adapt_extra(fn), maximize=maximize)
    METRICS[name] = m
    return m


# User-constructed Metric instances, adapted once (extra-kwarg filtering)
# and memoised by value so repeat fits resolve to the identical object.
_ADAPTED: dict = {}


def get_metric(spec) -> Metric:
    """Resolve a metric spec to a Metric.

    Accepts a registry name (including parameterised `ndcg@k`), a Metric,
    a bare callable (wrapped, minimizing, named after the function), or a
    (name, fn) / (name, fn, maximize) tuple.
    """
    if isinstance(spec, Metric):
        cached = _ADAPTED.get(spec)
        if cached is None:
            fn = adapt_extra(spec.fn)
            cached = spec if fn is spec.fn else spec._replace(fn=fn)
            _ADAPTED[spec] = cached
        return cached
    if isinstance(spec, str):
        m = METRICS.get(spec)
        if m is not None:
            return m
        if "@" in spec:
            base, _, arg = spec.partition("@")
            factory = _PARAMETRIC.get(base)
            if factory is not None:
                m = factory(int(arg))
                METRICS[spec] = m  # cache: same name -> identical object
                return m
        raise ValueError(
            f"unknown eval metric {spec!r}; built-ins: "
            f"{sorted(METRICS)} (+ parameterised {sorted(_PARAMETRIC)}@k); "
            "custom metrics: register_metric(name, fn) or pass a callable"
        )
    if isinstance(spec, (tuple, list)):
        if len(spec) == 2:
            name, fn = spec
            maximize = False
        elif len(spec) == 3:
            name, fn, maximize = spec
        else:
            raise ValueError(
                "metric tuple must be (name, fn) or (name, fn, maximize), "
                f"got length {len(spec)}"
            )
        return _wrap_callable(fn, name=name, maximize=maximize)
    if callable(spec):
        return _wrap_callable(spec)
    raise TypeError(f"cannot interpret {type(spec)} as an eval metric")


def resolve_metrics(spec) -> tuple[Metric, ...]:
    """Resolve `fit(eval_metric=...)`-style input to a Metric tuple:
    None -> (), a single spec (name / Metric / callable / bare
    (name, fn[, maximize]) tuple) -> 1-tuple, a sequence of specs ->
    one Metric each."""
    if spec is None:
        return ()
    if isinstance(spec, (str, Metric)) or callable(spec):
        return (get_metric(spec),)
    if isinstance(spec, (tuple, list)) and len(spec) in (2, 3) \
            and isinstance(spec[0], str) and callable(spec[1]):
        return (get_metric(tuple(spec)),)  # one bare (name, fn[, maximize])
    return tuple(get_metric(s) for s in spec)


# Wrapped callables cached by (fn, name, maximize) identity so a repeated
# fit with the same custom metric resolves to the identical Metric object
# and hits the compiled-train-fn cache (DESIGN.md §10).
_WRAPPED: dict = {}


def _wrap_callable(fn: Callable, name: str | None = None,
                   maximize: bool = False) -> Metric:
    name = name or getattr(fn, "__name__", "custom_metric")
    key = (fn, name, maximize)
    m = _WRAPPED.get(key)
    if m is None:
        def wrapped(margins, y, **extra):
            return fn(margins, y)

        m = _WRAPPED[key] = Metric(name=name, fn=wrapped, maximize=maximize)
    return m


# --- regression ------------------------------------------------------------

def _rmse(margins, y, **_):
    return jnp.sqrt(jnp.mean((margins[:, 0] - y) ** 2))


def _mae(margins, y, **_):
    return jnp.mean(jnp.abs(margins[:, 0] - y))


def _quantile_loss(margins, y, quantile_alpha=0.5, **_):
    """Mean pinball loss at `quantile_alpha` (reg:quantile's default)."""
    err = y - margins[:, 0]
    return jnp.mean(jnp.maximum(quantile_alpha * err,
                                (quantile_alpha - 1.0) * err))


def _mphe(margins, y, **_):
    """Mean pseudo-Huber error (slope 1), reg:pseudohubererror's default."""
    r = margins[:, 0] - y
    return jnp.mean(jnp.sqrt(1.0 + r * r) - 1.0)


def _poisson_nloglik(margins, y, **_):
    """Negative Poisson log-likelihood with log link (pred = exp(margin))."""
    return jnp.mean(jnp.exp(margins[:, 0]) - y * margins[:, 0]
                    + jax.scipy.special.gammaln(y + 1.0))


# --- binary classification -------------------------------------------------

def _logloss(margins, y, **_):
    # softplus(m) - y*m == -[y log p + (1-y) log(1-p)], numerically stable.
    return jnp.mean(jax.nn.softplus(margins[:, 0]) - y * margins[:, 0])


def _accuracy(margins, y, **_):
    """Classification accuracy; binary on sign(margin), multiclass on
    argmax (the margin width is static, so the branch traces cleanly)."""
    if margins.shape[1] == 1:
        return jnp.mean((margins[:, 0] > 0.0) == (y > 0.5))
    return jnp.mean(jnp.argmax(margins, axis=1) == y.astype(jnp.int32))


def _error(margins, y, **_):
    return 1.0 - _accuracy(margins, y)


def _auc(margins, y, **_):
    """ROC AUC via the rank-sum (Mann-Whitney U) identity, with average
    ranks for ties — O(n log n) sort/searchsorted, fully on-device, so it
    can ride inside the training scan."""
    s = margins[:, 0]
    pos = y > 0.5
    sorted_s = jnp.sort(s)
    lo = jnp.searchsorted(sorted_s, s, side="left").astype(jnp.float32)
    hi = jnp.searchsorted(sorted_s, s, side="right").astype(jnp.float32)
    rank = 0.5 * (lo + hi + 1.0)  # average 1-based rank under ties
    n_pos = jnp.sum(pos.astype(jnp.float32))
    n_neg = s.shape[0] - n_pos
    rank_sum = jnp.sum(jnp.where(pos, rank, 0.0))
    u = rank_sum - n_pos * (n_pos + 1.0) / 2.0
    return u / jnp.maximum(n_pos * n_neg, 1.0)


# --- multiclass ------------------------------------------------------------

def _merror(margins, y, **_):
    return jnp.mean(jnp.argmax(margins, axis=1) != y.astype(jnp.int32))


def _mlogloss(margins, y, **_):
    lse = jax.nn.logsumexp(margins, axis=1)
    tgt = jnp.take_along_axis(
        margins, y.astype(jnp.int32)[:, None], axis=1
    )[:, 0]
    return jnp.mean(lse - tgt)


# --- ranking ---------------------------------------------------------------

def _pairwise_acc(margins, y, **_):
    """Global pairwise ordering accuracy — the coarse proxy predating the
    real ndcg@k metric; kept for continuity of recorded histories."""
    s = margins[:, 0]
    better = y[:, None] > y[None, :]
    correct = (s[:, None] > s[None, :]) & better
    denom = jnp.maximum(jnp.sum(better), 1)
    return jnp.sum(correct) / denom


def _make_ndcg(k: int) -> Metric:
    """NDCG@k averaged over query groups, entirely on-device.

    Per-group ranks come from masked pair comparisons (same O(group^2)
    regime as the pairwise objective's gradient — fine for benchmark group
    sizes), gains are XGBoost's 2^rel - 1, and the group mean is a
    segment-sum: each row carries its group's DCG/IDCG and a 1/group_size
    weight, so no host-side group bookkeeping exists. Groups with zero
    ideal DCG score 1 (XGBoost's convention). Missing `group_ids` treats
    the whole set as one query.
    """
    if k <= 0:
        raise ValueError(f"ndcg@k needs k >= 1, got {k}")

    def ndcg(margins, y, group_ids=None, **_):
        s = margins[:, 0]
        n = s.shape[0]
        if group_ids is None:
            group_ids = jnp.zeros(n, jnp.int32)
        same = group_ids[:, None] == group_ids[None, :]
        idx = jnp.arange(n)
        earlier = idx[None, :] < idx[:, None]  # deterministic tie-break

        def within_group_rank(keys):
            ahead = (keys[None, :] > keys[:, None]) | (
                (keys[None, :] == keys[:, None]) & earlier
            )
            return jnp.sum(same & ahead, axis=1)  # 0-based rank in group

        def discount(rank):
            return jnp.where(
                rank < k, 1.0 / jnp.log2(rank.astype(jnp.float32) + 2.0), 0.0
            )

        gain = jnp.exp2(y) - 1.0
        dcg_i = gain * discount(within_group_rank(s))
        idcg_i = gain * discount(within_group_rank(y))
        # Segment sums: row i receives its own group's totals.
        dcg_g = jnp.sum(jnp.where(same, dcg_i[None, :], 0.0), axis=1)
        idcg_g = jnp.sum(jnp.where(same, idcg_i[None, :], 0.0), axis=1)
        gsize = jnp.sum(same, axis=1).astype(jnp.float32)
        per_group = jnp.where(
            idcg_g > 0.0, dcg_g / jnp.where(idcg_g > 0.0, idcg_g, 1.0), 1.0
        )
        n_groups = jnp.sum(1.0 / gsize)
        return jnp.sum(per_group / gsize) / n_groups

    return Metric(name=f"ndcg@{k}", fn=ndcg, maximize=True)


_PARAMETRIC: dict[str, Callable[[int], Metric]] = {"ndcg": _make_ndcg}


for _name, _fn, _maximize in (
    ("rmse", _rmse, False),
    ("mae", _mae, False),
    ("quantile", _quantile_loss, False),
    ("mphe", _mphe, False),
    ("poisson-nloglik", _poisson_nloglik, False),
    ("logloss", _logloss, False),
    ("error", _error, False),
    ("accuracy", _accuracy, True),
    ("auc", _auc, True),
    ("merror", _merror, False),
    ("mlogloss", _mlogloss, False),
    ("pairwise_acc", _pairwise_acc, True),
):
    register_metric(_name, _fn, maximize=_maximize)
