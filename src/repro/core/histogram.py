"""Gradient histogram construction (paper §2.3, BuildPartialHistograms).

Each device sums (g, h) pairs of its row shard into per-(node, feature, bin)
histograms. This module is the XLA-native path (scatter-add); the TPU-MXU
Pallas kernel lives in repro.kernels.histogram and is numerically checked
against build_histograms() below.

positions[i] is the *level-local* node index of row i (0..n_nodes-1), or
`n_nodes` for rows that are inactive (already in a finalised leaf) — they
fall into a dump slot that is sliced off.

Two scatter layouts coexist (DESIGN.md §16):

  * row-major (`_scatter_rows`): one flat scatter over a (rows, f) tile,
    used by the dense builder and the compacted-row subset builders.
  * feature-major (`_scatter_feature` under a lax.scan over features):
    used by the packed and chunked full-matrix builders. Each feature's
    (g, h) pairs land in a private ((n_nodes+1)*max_bins, 2) slab that
    stays L1/L2-resident, which is what makes the packed build beat the
    dense one on CPU (the XLA scatter is serial; a cache-resident
    destination halves its per-update cost). Both layouts add each
    (node, f, bin) slot's contributions in global row order, so they are
    bit-identical to each other — tested, and load-bearing for the
    external-memory identity guarantee (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compress import gather_rows_chunked, symbols_per_word


def _scatter_rows(
    flat: jax.Array,  # ((n_nodes + 1) * f * max_bins, 2) float32 accumulator
    b: jax.Array,  # (rows, f) int32 bin ids
    pos: jax.Array,  # (rows,) int32 node ids (dump slot included)
    gh: jax.Array,  # (rows, 2) float32
    max_bins: int,
) -> jax.Array:
    """Scatter one row block's (g, h) pairs into the flat histogram.

    The single definition of the flat scatter index
    ((pos * F) + f) * B + bin, shared by every builder below: the per-bin
    f32 add order this encodes (rows outer, features inner, in block/chunk
    order) is load-bearing for the external-memory bit-identity guarantee
    (DESIGN.md §11) — change it in one place or not at all.
    """
    rows, f = b.shape
    fidx = jnp.arange(f, dtype=jnp.int32)[None, :]
    idx = (pos[:, None] * f + fidx) * max_bins + b
    gh_rep = jnp.broadcast_to(gh[:, None, :], (rows, f, 2)).reshape(-1, 2)
    return flat.at[idx.reshape(-1)].add(gh_rep, mode="drop")


def _unpack_words(words: jax.Array, bits: int) -> jax.Array:
    """One feature's packed words (w,) uint32 -> (w * spw,) int32 bins.

    Byte-aligned widths (8/16 bits) use a bitcast instead of shift/mask:
    pack() stores symbol j at shift j*bits, i.e. little-endian within the
    word, which is exactly the sub-word lane order bitcast_convert_type
    exposes. The bitcast halves unpack cost on CPU, which is what tips the
    packed builder below dense at the root (n_nodes=1) where the scatter
    itself has no locality advantage. Parity with the shift/mask path is
    exact (integer bins) and pinned by tests/test_compress.py round trips.
    """
    spw = symbols_per_word(bits)
    if bits in (8, 16):
        dt = jnp.uint8 if bits == 8 else jnp.uint16
        return jax.lax.bitcast_convert_type(words, dt).reshape(-1).astype(jnp.int32)
    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, :]
    mask = jnp.uint32((1 << bits) - 1)
    return ((words[:, None] >> shifts) & mask).reshape(-1).astype(jnp.int32)


def _scatter_feature(
    slab: jax.Array,  # ((n_nodes + 1) * max_bins, 2) f32 — one feature's slab
    b: jax.Array,  # (rows,) int32 bin ids of this feature
    base: jax.Array,  # (rows,) int32 = pos * max_bins (dump slot included)
    gh: jax.Array,  # (rows, 2) float32
) -> jax.Array:
    """Scatter one feature's (g, h) pairs into its private histogram slab.

    The feature-major dual of `_scatter_rows`: index pos * B + bin into a
    ((n_nodes+1)*B, 2) slab. Per (node, bin) slot the adds happen in row
    order, the same per-slot order `_scatter_rows` produces, so builders
    using either layout agree bitwise (tests/test_histogram_split.py).
    """
    return slab.at[base + b].add(gh, mode="drop")


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins"))
def build_histograms(
    bins: jax.Array,  # (n, f) int32 bin ids
    gh: jax.Array,  # (n, 2) float32 gradient/hessian pairs
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Returns hist (n_nodes, n_features, max_bins, 2) float32."""
    n, f = bins.shape
    pos = jnp.minimum(positions, n_nodes).astype(jnp.int32)
    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat = _scatter_rows(flat, bins, pos, gh, max_bins)
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "n_rows", "block_rows"),
)
def build_histograms_packed(
    packed: jax.Array,  # (f, n_words) uint32 bit-packed bins
    gh: jax.Array,  # (n, 2) float32
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    n_rows: int,
    block_rows: int = 65536,
) -> jax.Array:
    """build_histograms from the bit-packed matrix, without ever
    materialising the full dense (n_rows, n_features) bins array.

    XLA-native fallback for the Pallas kernel (kernels/histogram.py), in
    feature-major order: a lax.scan over FEATURES unpacks one (n_rows,)
    column at a time and scatter-adds it into that feature's private
    ((n_nodes+1)*max_bins, 2) slab — the histogram-privatisation discipline
    of the paper's shared-memory kernel (§2.3), expressed at XLA level. The
    slab stays L1/L2-resident for the whole column, which makes this build
    faster than the dense one at every depth (BENCH `kernels` section); HBM
    reads of the dominant input stream stay at the compressed size
    (DESIGN.md §2), and dense transients are O(n_rows) per feature — the
    (n, f) matrix never exists. Per (node, f, bin) slot the f32 adds happen
    in global row order, so the result is bit-identical to
    build_histograms on the unpacked matrix.

    block_rows is kept for API stability (the dense-tile bound of the old
    row-blocked formulation); the feature-major build's transients are
    bounded by one column regardless of its value.
    """
    del block_rows  # transients are one (n_rows,) column, no tiling needed
    f, w = packed.shape
    spw = symbols_per_word(bits)
    rows_up = w * spw

    # Padding rows (word-alignment) go to the dump slot n_nodes, exactly
    # like inactive rows.
    pos_p = jnp.pad(
        jnp.minimum(positions, n_nodes).astype(jnp.int32),
        (0, rows_up - n_rows),
        constant_values=n_nodes,
    )
    gh_p = jnp.pad(gh, ((0, rows_up - n_rows), (0, 0)))
    base = pos_p * max_bins
    slots = (n_nodes + 1) * max_bins

    def per_feature(carry, words):
        b = _unpack_words(words, bits)  # (rows_up,) — one column
        slab = jnp.zeros((slots, 2), jnp.float32)
        return carry, _scatter_feature(slab, b, base, gh_p)

    _, slabs = jax.lax.scan(per_feature, None, packed)  # (f, slots, 2)
    return slabs.reshape(f, n_nodes + 1, max_bins, 2).transpose(1, 0, 2, 3)[:n_nodes]


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "chunk_rows", "n_rows"),
)
def build_histograms_chunked(
    packed: jax.Array,  # (n_chunks, f, words_per_chunk) uint32
    gh: jax.Array,  # (n, 2) float32
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    chunk_rows: int,
    n_rows: int,
) -> jax.Array:
    """build_histograms over the chunk-stacked packed matrix (external-
    memory path, DESIGN.md §11): a lax.scan over CHUNKS threads the
    feature-major slab stack through the chunk axis, so dense transients
    are bounded by one chunk's column and — because chunk c scatters into
    the slabs chunk c-1 left behind, feature by feature in the same order
    as build_histograms_packed's single pass — the result is bit-identical
    to the in-memory build on the same rows (per-bin f32 adds happen in
    the same global row order; chunk padding rows land in the dump slot).
    The inner per-feature scan consumes each feature's running slab as a
    scanned input and emits the updated slab, so features stay independent
    while the chunk axis stays sequential.
    """
    n_chunks, f, w_c = packed.shape
    spw = symbols_per_word(bits)
    rows_up = w_c * spw  # unpacked rows per chunk (>= chunk_rows)
    n_padded = n_chunks * chunk_rows

    gh_c = jnp.pad(gh, ((0, n_padded - n_rows), (0, 0)))
    gh_c = gh_c.reshape(n_chunks, chunk_rows, 2)
    pos_c = jnp.pad(
        jnp.minimum(positions, n_nodes).astype(jnp.int32),
        (0, n_padded - n_rows),
        constant_values=n_nodes,
    ).reshape(n_chunks, chunk_rows)
    if rows_up > chunk_rows:  # word-alignment padding rows -> dump slot
        gh_c = jnp.pad(gh_c, ((0, 0), (0, rows_up - chunk_rows), (0, 0)))
        pos_c = jnp.pad(
            pos_c, ((0, 0), (0, rows_up - chunk_rows)), constant_values=n_nodes
        )

    slots = (n_nodes + 1) * max_bins

    def chunk_body(hist, chunk):
        words_c, g, p = chunk
        return _chunk_slab_update(hist, words_c, g, p, bits, max_bins), None

    hist0 = jnp.zeros((f, slots, 2), jnp.float32)
    hist, _ = jax.lax.scan(chunk_body, hist0, (packed, gh_c, pos_c))
    return hist.reshape(f, n_nodes + 1, max_bins, 2).transpose(1, 0, 2, 3)[:n_nodes]


def _chunk_slab_update(
    hist: jax.Array,  # (f, (n_nodes + 1) * max_bins, 2) running slab stack
    words_c: jax.Array,  # (f, w_c) uint32 — one chunk's packed columns
    gh: jax.Array,  # (rows_up, 2) float32, word-alignment rows zero-padded
    pos: jax.Array,  # (rows_up,) int32, dump slot for inactive/padding rows
    bits: int,
    max_bins: int,
) -> jax.Array:
    """Scatter ONE chunk's rows into the feature-major slab stack.

    The single definition of the per-chunk scatter body, shared by the
    compiled resident scan (build_histograms_chunked, which lax.scans it
    over the device-resident stack) and the eager streamed path
    (histogram_chunk_update, which applies it once per paged-in chunk).
    Same ops, same per-(node, f, bin) f32 add order — which is the whole
    bit-identity argument for streamed == resident == in-memory fits.
    """
    base = pos * max_bins

    def per_feature(_, xs):
        words, slab = xs
        b = _unpack_words(words, bits)  # (rows_up,) — one chunk column
        return None, _scatter_feature(slab, b, base, gh)

    _, hist = jax.lax.scan(per_feature, None, (words_c, hist))
    return hist


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins", "bits"))
def histogram_chunk_update(
    hist: jax.Array,  # (f, (n_nodes + 1) * max_bins, 2) running slab stack
    words_c: jax.Array,  # (f, w_c) uint32 — one paged-in chunk
    gh_c: jax.Array,  # (rows, 2) float32 — this chunk's gradient slice
    pos_c: jax.Array,  # (rows,) int32 — this chunk's position slice
    n_nodes: int,
    max_bins: int,
    bits: int,
) -> jax.Array:
    """One streamed chunk's scatter into the running slab stack — the eager
    per-chunk twin of build_histograms_chunked's scan body (the streamed
    out-of-core path pages chunks through a prefetching ring and cannot put
    the whole stack inside one jit). rows may be short on the final chunk;
    word-alignment padding rows go to the dump slot exactly as in the
    resident scan. Callers finalise the threaded slab stack with
    finalize_slab_histogram once every chunk has been applied.
    """
    spw = symbols_per_word(bits)
    rows_up = words_c.shape[1] * spw
    rows = pos_c.shape[0]
    pos = jnp.minimum(pos_c, n_nodes).astype(jnp.int32)
    if rows_up > rows:
        pos = jnp.pad(pos, (0, rows_up - rows), constant_values=n_nodes)
        gh_c = jnp.pad(gh_c, ((0, rows_up - rows), (0, 0)))
    return _chunk_slab_update(hist, words_c, gh_c, pos, bits, max_bins)


def finalize_slab_histogram(
    hist: jax.Array, n_nodes: int, max_bins: int
) -> jax.Array:
    """(f, slots, 2) slab stack -> (n_nodes, f, max_bins, 2) histogram,
    dropping the dump slot — the tail of every chunked builder above."""
    f = hist.shape[0]
    return hist.reshape(f, n_nodes + 1, max_bins, 2).transpose(1, 0, 2, 3)[:n_nodes]


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins", "bits"))
def histogram_rows_chunk_update(
    flat: jax.Array,  # ((n_nodes + 1) * f * max_bins, 2) running accumulator
    words_c: jax.Array,  # (f, w_c) uint32 — one paged-in chunk
    gh_b: jax.Array,  # (m, 2) float32 — this segment's compacted gradients
    pos_b: jax.Array,  # (m,) int32 — this segment's node ids
    rid_local: jax.Array,  # (m,) int32 CHUNK-LOCAL row ids
    n_nodes: int,
    max_bins: int,
    bits: int,
) -> jax.Array:
    """One chunk-segment's compacted-row scatter into the running flat
    histogram — the streamed twin of build_histograms_chunked_rows' body,
    for the subtraction trick and GOSS-compacted builds out-of-core. The
    caller splits the (ascending) compacted row list into per-chunk
    segments, so applying segments in chunk order reproduces the resident
    builder's global-row-order adds per (node, f, bin) slot bitwise;
    padding entries carry pos_b = n_nodes (dump slot).
    """
    spw = symbols_per_word(bits)
    mask = jnp.uint32((1 << bits) - 1)
    r = jnp.minimum(rid_local, words_c.shape[1] * spw - 1)
    words = words_c[:, r // spw]  # (f, m) word gather
    shift = ((r % spw).astype(jnp.uint32) * jnp.uint32(bits))[None, :]
    b = ((words >> shift) & mask).T.astype(jnp.int32)  # (m, f)
    p = jnp.minimum(pos_b, n_nodes).astype(jnp.int32)
    return _scatter_rows(flat, b, p, gh_b, max_bins)


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "chunk_rows", "block_rows"),
)
def build_histograms_chunked_rows(
    packed: jax.Array,  # (n_chunks, f, words_per_chunk) uint32
    gh_sel: jax.Array,  # (m, 2) float32, pre-gathered for the selected rows
    pos_sel: jax.Array,  # (m,) int32 node ids, n_nodes = dump/padding slot
    row_ids: jax.Array,  # (m,) int32 GLOBAL row ids (out of range = padding)
    n_nodes: int,
    max_bins: int,
    bits: int,
    chunk_rows: int,
    block_rows: int = 65536,
) -> jax.Array:
    """build_histograms_packed_rows over the chunk stack: the compacted-row
    histogram of the subtraction trick, with each row's words gathered from
    its owning chunk. Blocking and scatter order match the flat-layout
    version exactly, so sibling subtraction stays bit-identical between the
    in-memory and external-memory paths.
    """
    _, f, _ = packed.shape
    m = row_ids.shape[0]
    bs = max(1, min(block_rows, m))
    pad = (-m) % bs
    n_chunks_scan = (m + pad) // bs

    rid = jnp.pad(row_ids, (0, pad))  # gather_rows_chunked clips internally
    pos_p = jnp.pad(
        jnp.minimum(pos_sel, n_nodes).astype(jnp.int32),
        (0, pad),
        constant_values=n_nodes,
    )
    gh_p = jnp.pad(gh_sel, ((0, pad), (0, 0)))
    rid_c = rid.reshape(n_chunks_scan, bs)
    pos_c = pos_p.reshape(n_chunks_scan, bs)
    gh_c = gh_p.reshape(n_chunks_scan, bs, 2)

    def body(flat, chunk):
        r, p, g = chunk
        b = gather_rows_chunked(packed, bits, chunk_rows, r)  # (bs, f)
        return _scatter_rows(flat, b, p, g, max_bins), None

    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat, _ = jax.lax.scan(body, flat, (rid_c, pos_c, gh_c))
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "max_bins", "bits", "block_rows")
)
def build_histograms_packed_rows(
    packed: jax.Array,  # (f, n_words) uint32 bit-packed bins
    gh_sel: jax.Array,  # (m, 2) float32, pre-gathered for the selected rows
    pos_sel: jax.Array,  # (m,) int32 node ids, n_nodes = dump/padding slot
    row_ids: jax.Array,  # (m,) int32 original row ids (>= n_rows = padding)
    n_nodes: int,
    max_bins: int,
    bits: int,
    block_rows: int = 65536,
) -> jax.Array:
    """Histogram over a *compacted row subset* straight from packed words.

    The workhorse of the histogram-subtraction trick (DESIGN.md §7.5): the
    caller compacts the rows of each level's smaller children into row_ids
    and gets their histogram at subset cost; sibling histograms come from
    parent - subset. Rows are fetched with one word gather + shift/mask per
    (row, feature) — the dense matrix never exists, and the dense tile is
    bounded by block_rows.
    """
    f, w = packed.shape
    spw = symbols_per_word(bits)
    m = row_ids.shape[0]
    bs = max(1, min(block_rows, m))
    pad = (-m) % bs
    n_chunks = (m + pad) // bs

    rid = jnp.minimum(jnp.pad(row_ids, (0, pad)), w * spw - 1)
    pos_p = jnp.pad(
        jnp.minimum(pos_sel, n_nodes).astype(jnp.int32),
        (0, pad),
        constant_values=n_nodes,
    )
    gh_p = jnp.pad(gh_sel, ((0, pad), (0, 0)))
    rid_c = rid.reshape(n_chunks, bs)
    pos_c = pos_p.reshape(n_chunks, bs)
    gh_c = gh_p.reshape(n_chunks, bs, 2)

    mask = jnp.uint32((1 << bits) - 1)

    def body(flat, chunk):
        r, p, g = chunk
        words = packed[:, r // spw]  # (f, bs) word gather
        shift = ((r % spw).astype(jnp.uint32) * jnp.uint32(bits))[None, :]
        b = ((words >> shift) & mask).T.astype(jnp.int32)  # (bs, f)
        return _scatter_rows(flat, b, p, g, max_bins), None

    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat, _ = jax.lax.scan(body, flat, (rid_c, pos_c, gh_c))
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


def node_sums(hist: jax.Array) -> jax.Array:
    """Total (G, H) per node from a histogram: sum over one feature's bins.

    Every feature's bins partition the same rows, so feature 0 suffices.
    Returns (n_nodes, 2).
    """
    return jnp.sum(hist[:, 0, :, :], axis=1)
