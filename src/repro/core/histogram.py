"""Gradient histogram construction (paper §2.3, BuildPartialHistograms).

Each device sums (g, h) pairs of its row shard into per-(node, feature, bin)
histograms. This module is the XLA-native path (scatter-add); the TPU-MXU
Pallas kernel lives in repro.kernels.histogram and is numerically checked
against build_histograms() below.

positions[i] is the *level-local* node index of row i (0..n_nodes-1), or
`n_nodes` for rows that are inactive (already in a finalised leaf) — they
fall into a dump slot that is sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins"))
def build_histograms(
    bins: jax.Array,  # (n, f) int32 bin ids
    gh: jax.Array,  # (n, 2) float32 gradient/hessian pairs
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Returns hist (n_nodes, n_features, max_bins, 2) float32."""
    n, f = bins.shape
    pos = jnp.minimum(positions, n_nodes).astype(jnp.int32)
    # Flat scatter index per (row, feature): ((pos * F) + f) * B + bin.
    idx = (pos[:, None] * f + jnp.arange(f, dtype=jnp.int32)[None, :]) * max_bins
    idx = idx + bins
    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    gh_rep = jnp.broadcast_to(gh[:, None, :], (n, f, 2)).reshape(-1, 2)
    flat = flat.at[idx.reshape(-1)].add(gh_rep, mode="drop")
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


def node_sums(hist: jax.Array) -> jax.Array:
    """Total (G, H) per node from a histogram: sum over one feature's bins.

    Every feature's bins partition the same rows, so feature 0 suffices.
    Returns (n_nodes, 2).
    """
    return jnp.sum(hist[:, 0, :, :], axis=1)
