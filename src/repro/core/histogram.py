"""Gradient histogram construction (paper §2.3, BuildPartialHistograms).

Each device sums (g, h) pairs of its row shard into per-(node, feature, bin)
histograms. This module is the XLA-native path (scatter-add); the TPU-MXU
Pallas kernel lives in repro.kernels.histogram and is numerically checked
against build_histograms() below.

positions[i] is the *level-local* node index of row i (0..n_nodes-1), or
`n_nodes` for rows that are inactive (already in a finalised leaf) — they
fall into a dump slot that is sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compress import gather_rows_chunked, symbols_per_word


def _scatter_rows(
    flat: jax.Array,  # ((n_nodes + 1) * f * max_bins, 2) float32 accumulator
    b: jax.Array,  # (rows, f) int32 bin ids
    pos: jax.Array,  # (rows,) int32 node ids (dump slot included)
    gh: jax.Array,  # (rows, 2) float32
    max_bins: int,
) -> jax.Array:
    """Scatter one row block's (g, h) pairs into the flat histogram.

    The single definition of the flat scatter index
    ((pos * F) + f) * B + bin, shared by every builder below: the per-bin
    f32 add order this encodes (rows outer, features inner, in block/chunk
    order) is load-bearing for the external-memory bit-identity guarantee
    (DESIGN.md §11) — change it in one place or not at all.
    """
    rows, f = b.shape
    fidx = jnp.arange(f, dtype=jnp.int32)[None, :]
    idx = (pos[:, None] * f + fidx) * max_bins + b
    gh_rep = jnp.broadcast_to(gh[:, None, :], (rows, f, 2)).reshape(-1, 2)
    return flat.at[idx.reshape(-1)].add(gh_rep, mode="drop")


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins"))
def build_histograms(
    bins: jax.Array,  # (n, f) int32 bin ids
    gh: jax.Array,  # (n, 2) float32 gradient/hessian pairs
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Returns hist (n_nodes, n_features, max_bins, 2) float32."""
    n, f = bins.shape
    pos = jnp.minimum(positions, n_nodes).astype(jnp.int32)
    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat = _scatter_rows(flat, bins, pos, gh, max_bins)
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "n_rows", "block_rows"),
)
def build_histograms_packed(
    packed: jax.Array,  # (f, n_words) uint32 bit-packed bins
    gh: jax.Array,  # (n, 2) float32
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    n_rows: int,
    block_rows: int = 65536,
) -> jax.Array:
    """build_histograms from the bit-packed matrix, without ever
    materialising the full dense (n_rows, n_features) bins array.

    XLA-native fallback for the Pallas kernel (kernels/histogram.py): a
    lax.scan over row blocks unpacks one (block_rows, f) tile at a time in
    registers/cache and scatter-adds it into the carried flat histogram.
    HBM reads of the dominant input stream stay at the compressed size
    (DESIGN.md §2), and the dense intermediate is bounded by block_rows
    regardless of n_rows.
    """
    f, w = packed.shape
    spw = symbols_per_word(bits)
    bw = max(1, min(block_rows // spw, w))  # words per row block
    w_pad = (-w) % bw
    n_chunks = (w + w_pad) // bw
    rows_pc = bw * spw
    n_padded = n_chunks * rows_pc

    packed_c = jnp.pad(packed, ((0, 0), (0, w_pad)))
    packed_c = packed_c.reshape(f, n_chunks, bw).transpose(1, 0, 2)
    gh_c = jnp.pad(gh, ((0, n_padded - n_rows), (0, 0))).reshape(n_chunks, rows_pc, 2)
    # Padding rows (both word-alignment and block padding) go to the dump
    # slot n_nodes, exactly like inactive rows.
    pos_c = jnp.pad(
        jnp.minimum(positions, n_nodes).astype(jnp.int32),
        (0, n_padded - n_rows),
        constant_values=n_nodes,
    ).reshape(n_chunks, rows_pc)

    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)

    def body(flat, chunk):
        words, g, p = chunk
        b = ((words[:, :, None] >> shifts) & mask).reshape(f, rows_pc)
        b = b.T.astype(jnp.int32)  # (rows_pc, f) — the only dense tile
        return _scatter_rows(flat, b, p, g, max_bins), None

    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat, _ = jax.lax.scan(body, flat, (packed_c, gh_c, pos_c))
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "chunk_rows", "n_rows"),
)
def build_histograms_chunked(
    packed: jax.Array,  # (n_chunks, f, words_per_chunk) uint32
    gh: jax.Array,  # (n, 2) float32
    positions: jax.Array,  # (n,) int32 level-local node ids, n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    chunk_rows: int,
    n_rows: int,
) -> jax.Array:
    """build_histograms over the chunk-stacked packed matrix (external-
    memory path, DESIGN.md §11): a lax.scan over CHUNKS accumulates each
    chunk's scatter-add into the carried flat histogram, so the dense tile
    is bounded by one chunk and — because the carry threads the partial
    histogram through chunks in row order, exactly like the row-block scan
    of build_histograms_packed — the result is bit-identical to the
    in-memory build on the same rows (per-bin f32 adds happen in the same
    row order; chunk padding rows land in the dump slot).
    """
    n_chunks, f, w_c = packed.shape
    spw = symbols_per_word(bits)
    rows_up = w_c * spw  # unpacked rows per chunk (>= chunk_rows)
    n_padded = n_chunks * chunk_rows

    gh_c = jnp.pad(gh, ((0, n_padded - n_rows), (0, 0)))
    gh_c = gh_c.reshape(n_chunks, chunk_rows, 2)
    pos_c = jnp.pad(
        jnp.minimum(positions, n_nodes).astype(jnp.int32),
        (0, n_padded - n_rows),
        constant_values=n_nodes,
    ).reshape(n_chunks, chunk_rows)
    if rows_up > chunk_rows:  # word-alignment padding rows -> dump slot
        gh_c = jnp.pad(gh_c, ((0, 0), (0, rows_up - chunk_rows), (0, 0)))
        pos_c = jnp.pad(
            pos_c, ((0, 0), (0, rows_up - chunk_rows)), constant_values=n_nodes
        )

    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)

    def body(flat, chunk):
        words, g, p = chunk
        b = ((words[:, :, None] >> shifts) & mask).reshape(f, rows_up)
        b = b.T.astype(jnp.int32)  # (rows_up, f) — the only dense tile
        return _scatter_rows(flat, b, p, g, max_bins), None

    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat, _ = jax.lax.scan(body, flat, (packed, gh_c, pos_c))
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "chunk_rows", "block_rows"),
)
def build_histograms_chunked_rows(
    packed: jax.Array,  # (n_chunks, f, words_per_chunk) uint32
    gh_sel: jax.Array,  # (m, 2) float32, pre-gathered for the selected rows
    pos_sel: jax.Array,  # (m,) int32 node ids, n_nodes = dump/padding slot
    row_ids: jax.Array,  # (m,) int32 GLOBAL row ids (out of range = padding)
    n_nodes: int,
    max_bins: int,
    bits: int,
    chunk_rows: int,
    block_rows: int = 65536,
) -> jax.Array:
    """build_histograms_packed_rows over the chunk stack: the compacted-row
    histogram of the subtraction trick, with each row's words gathered from
    its owning chunk. Blocking and scatter order match the flat-layout
    version exactly, so sibling subtraction stays bit-identical between the
    in-memory and external-memory paths.
    """
    _, f, _ = packed.shape
    m = row_ids.shape[0]
    bs = max(1, min(block_rows, m))
    pad = (-m) % bs
    n_chunks_scan = (m + pad) // bs

    rid = jnp.pad(row_ids, (0, pad))  # gather_rows_chunked clips internally
    pos_p = jnp.pad(
        jnp.minimum(pos_sel, n_nodes).astype(jnp.int32),
        (0, pad),
        constant_values=n_nodes,
    )
    gh_p = jnp.pad(gh_sel, ((0, pad), (0, 0)))
    rid_c = rid.reshape(n_chunks_scan, bs)
    pos_c = pos_p.reshape(n_chunks_scan, bs)
    gh_c = gh_p.reshape(n_chunks_scan, bs, 2)

    def body(flat, chunk):
        r, p, g = chunk
        b = gather_rows_chunked(packed, bits, chunk_rows, r)  # (bs, f)
        return _scatter_rows(flat, b, p, g, max_bins), None

    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat, _ = jax.lax.scan(body, flat, (rid_c, pos_c, gh_c))
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "max_bins", "bits", "block_rows")
)
def build_histograms_packed_rows(
    packed: jax.Array,  # (f, n_words) uint32 bit-packed bins
    gh_sel: jax.Array,  # (m, 2) float32, pre-gathered for the selected rows
    pos_sel: jax.Array,  # (m,) int32 node ids, n_nodes = dump/padding slot
    row_ids: jax.Array,  # (m,) int32 original row ids (>= n_rows = padding)
    n_nodes: int,
    max_bins: int,
    bits: int,
    block_rows: int = 65536,
) -> jax.Array:
    """Histogram over a *compacted row subset* straight from packed words.

    The workhorse of the histogram-subtraction trick (DESIGN.md §7.5): the
    caller compacts the rows of each level's smaller children into row_ids
    and gets their histogram at subset cost; sibling histograms come from
    parent - subset. Rows are fetched with one word gather + shift/mask per
    (row, feature) — the dense matrix never exists, and the dense tile is
    bounded by block_rows.
    """
    f, w = packed.shape
    spw = symbols_per_word(bits)
    m = row_ids.shape[0]
    bs = max(1, min(block_rows, m))
    pad = (-m) % bs
    n_chunks = (m + pad) // bs

    rid = jnp.minimum(jnp.pad(row_ids, (0, pad)), w * spw - 1)
    pos_p = jnp.pad(
        jnp.minimum(pos_sel, n_nodes).astype(jnp.int32),
        (0, pad),
        constant_values=n_nodes,
    )
    gh_p = jnp.pad(gh_sel, ((0, pad), (0, 0)))
    rid_c = rid.reshape(n_chunks, bs)
    pos_c = pos_p.reshape(n_chunks, bs)
    gh_c = gh_p.reshape(n_chunks, bs, 2)

    mask = jnp.uint32((1 << bits) - 1)

    def body(flat, chunk):
        r, p, g = chunk
        words = packed[:, r // spw]  # (f, bs) word gather
        shift = ((r % spw).astype(jnp.uint32) * jnp.uint32(bits))[None, :]
        b = ((words >> shift) & mask).T.astype(jnp.int32)  # (bs, f)
        return _scatter_rows(flat, b, p, g, max_bins), None

    flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
    flat, _ = jax.lax.scan(body, flat, (rid_c, pos_c, gh_c))
    return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]


def node_sums(hist: jax.Array) -> jax.Array:
    """Total (G, H) per node from a histogram: sum over one feature's bins.

    Every feature's bins partition the same rows, so feature 0 suffices.
    Returns (n_nodes, 2).
    """
    return jnp.sum(hist[:, 0, :, :], axis=1)
