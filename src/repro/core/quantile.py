"""Feature quantile generation (paper §2.1).

The paper maps quantile sketch construction to the GPU because it is a
considerable preprocessing cost. Here the same computation is expressed in
JAX (sort-based exact quantiles, vmapped over features) so XLA runs it on
the accelerator. Missing values (NaN) are excluded from the sketch and are
assigned a reserved *missing bin* (the last bin), which is what makes the
sparsity-aware default-direction logic in split.py possible (DESIGN.md §7.4).

Two cut generators live here (DESIGN.md §11):

  * `compute_cuts`   — exact sort-based quantiles; needs the whole matrix
    resident at once. The in-memory (`DeviceDMatrix`) path.
  * `StreamingQuantileSketch` — a mergeable weighted quantile summary
    (GK/XGBoost-WQSummary style) with `push(batch)` / `merge` / `get_cuts`
    and memory bounded by `capacity` entries per feature, used by the
    external-memory path to stream cut generation over host-resident
    chunks. When `capacity` exceeds the number of distinct values seen the
    summary is exact and `get_cuts` reproduces `compute_cuts`' interpolation
    formula; under pruning the rank error of any cut is O(1/capacity) per
    merge (tests/test_quantile_sketch.py pins the bound empirically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# Reserved: the last bin id of every feature is the "missing" bin.
# With max_bins=256 we get 255 value bins + 1 missing bin, so every bin id
# fits in 8 bits and the histogram axis is 256 = 2x128 (MXU lane aligned).
DEFAULT_MAX_BINS = 256


def missing_bin_id(max_bins: int = DEFAULT_MAX_BINS) -> int:
    return max_bins - 1


def n_value_bins(max_bins: int = DEFAULT_MAX_BINS) -> int:
    return max_bins - 1


@functools.partial(jax.jit, static_argnames=("max_bins",))
def select_cuts_from_sorted(
    srt: jax.Array,  # (n_rows, n_features) column-sorted f32, +inf tail
    n_valid: jax.Array,  # (n_features,) finite count per column
    max_bins: int = DEFAULT_MAX_BINS,
) -> jax.Array:
    """Selection stage of compute_cuts: weighted-rank pick + interpolation
    + dedup over pre-sorted columns. Split out so the sort stage can
    dispatch independently (host sort on CPU, device sort / Pallas
    selection kernel elsewhere — kernels/quantile_cuts.py reproduces this
    arithmetic operation for operation and is parity-tested against it).
    """
    nvb = n_value_bins(max_bins)
    n = srt.shape[0]

    def per_feature(col: jax.Array, nv: jax.Array) -> jax.Array:
        # Quantile positions: interior boundaries between nvb equal-mass bins.
        qs = (jnp.arange(1, nvb, dtype=jnp.float32) / nvb) * jnp.maximum(
            nv - 1, 1
        ).astype(jnp.float32)
        lo = jnp.clip(jnp.floor(qs).astype(jnp.int32), 0, n - 1)
        hi = jnp.clip(lo + 1, 0, n - 1)
        frac = qs - lo.astype(jnp.float32)
        lov, hiv = col[lo], col[hi]
        # Linear interpolation, guarding the all-missing / +inf tail case.
        hiv = jnp.where(jnp.isfinite(hiv), hiv, lov)
        cand = lov + frac * (hiv - lov)
        cand = jnp.where(jnp.isfinite(cand), cand, jnp.inf)
        # Deduplicate: a cut equal to its predecessor is useless; push to +inf
        # so searchsorted collapses duplicate-mass bins (low-cardinality cols).
        prev = jnp.concatenate([jnp.array([-jnp.inf], cand.dtype), cand[:-1]])
        cand = jnp.where(cand > prev, cand, jnp.inf)
        return jnp.sort(cand)  # keep +inf padding at the tail

    return jax.vmap(per_feature, in_axes=(1, 0))(srt, n_valid)


def compute_cuts(x: jax.Array, max_bins: int = DEFAULT_MAX_BINS) -> jax.Array:
    """Per-feature quantile cut points.

    Args:
      x: (n_rows, n_features) float array, NaN = missing.
      max_bins: total bins per feature incl. the reserved missing bin.

    Returns:
      cuts: (n_features, n_value_bins - 1) float32, ascending; value bin b
        holds x <= cuts[b] (and x > cuts[b-1]). Unused tail cuts are +inf so
        quantize() naturally maps everything into the used prefix.

    Dispatches through kernels.ops.compute_cuts_op: the sort stage runs on
    the host (np.sort) when the backend is CPU — an order of magnitude
    faster than XLA's CPU sort at 1M rows, see BENCH `kernels` section —
    and on device otherwise, where the selection stage additionally uses
    the Pallas kernel when the matrix fits VMEM. Every path produces
    bit-identical cuts to `compute_cuts_reference` (tested): the sorted
    multiset is the same array no matter who sorts it, and the selection
    arithmetic is shared.
    """
    from repro.kernels import ops as KO  # lazy: ops imports core modules

    return KO.compute_cuts_op(x, max_bins)


@functools.partial(jax.jit, static_argnames=("max_bins",))
def compute_cuts_reference(
    x: jax.Array, max_bins: int = DEFAULT_MAX_BINS
) -> jax.Array:
    """The original single-pass compute_cuts (vmapped per-feature device
    sort + selection). Kept as the oracle for the dispatching fast path and
    the Pallas selection kernel; also exercises the pure-jnp route on
    backends without host callbacks.
    """
    x = x.astype(jnp.float32)
    finite = jnp.isfinite(x)
    # Push NaNs to the end of the sort; count of valid entries.
    filled = jnp.where(finite, x, jnp.inf)
    srt = jnp.sort(filled, axis=0)
    n_valid = jnp.sum(finite, axis=0)
    return select_cuts_from_sorted(srt, n_valid, max_bins)


def quantize(x: jax.Array, cuts: jax.Array) -> jax.Array:
    """Map raw features to bin ids. NaN -> missing bin (= n_cuts + 1).

    bin = #cuts strictly below x, i.e. x <= cuts[b] lands in bin b. The last
    value bin is everything above the final finite cut; missing bin id is
    cuts.shape[1] + 1 == n_value_bins - ... == max_bins - 1 by construction.

    Dispatches through kernels.ops.quantize_op: on CPU (and outside a jit
    trace) the binary search runs as host-side np.searchsorted — the same
    exact float comparisons, bit-identical bins, no XLA compile/dispatch
    overhead on the DMatrix build path. Everywhere else the jitted
    `quantize_reference` below runs.
    """
    from repro.kernels import ops as KO  # lazy: ops imports core modules

    return KO.quantize_op(x, cuts)


@jax.jit
def quantize_reference(x: jax.Array, cuts: jax.Array) -> jax.Array:
    """The original all-device quantize (vmapped searchsorted). Oracle for
    the dispatching fast path; also the route taken under jit traces."""
    n_cuts = cuts.shape[1]

    def per_feature(col: jax.Array, c: jax.Array) -> jax.Array:
        b = jnp.searchsorted(c, col, side="left").astype(jnp.int32)
        return jnp.where(jnp.isnan(col), jnp.int32(n_cuts + 1), b)

    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(
        x.astype(jnp.float32), cuts
    )


# --- streaming sketch (external-memory cut generation, DESIGN.md §11) -------

# A per-feature summary is the tuple (vals, rmin, rmax, w):
#   vals  float32, strictly ascending distinct values
#   rmin  float64, lower bound on the total weight strictly below vals[i]
#   rmax  float64, upper bound on the total weight <= vals[i]
#   w     float64, weight known to sit exactly at vals[i]
# For a summary built from raw data rmin/rmax are the exact exclusive /
# inclusive cumulative weights; merging keeps them exact, pruning widens
# the [rmin, rmax] band by at most total/capacity per prune (GK invariant).
_EMPTY_SUMMARY = (
    np.empty(0, np.float32),
    np.empty(0, np.float64),
    np.empty(0, np.float64),
    np.empty(0, np.float64),
)


def _exact_summary(values: np.ndarray, weights: np.ndarray):
    """Exact summary of a raw (already finite) value batch."""
    order = np.argsort(values, kind="stable")
    v = values[order]
    w = weights[order]
    if v.size == 0:
        return _EMPTY_SUMMARY
    newgrp = np.empty(v.size, bool)
    newgrp[0] = True
    np.not_equal(v[1:], v[:-1], out=newgrp[1:])
    starts = np.flatnonzero(newgrp)
    vu = v[starts]
    wu = np.add.reduceat(w, starts)
    rmax = np.cumsum(wu)
    return vu, rmax - wu, rmax, wu


def _exact_summary_presorted(values: np.ndarray):
    """Exact unit-weight summary of an already-sorted finite value array.

    The device-sharded sketch build (repro.dist.sketch) sorts columns on
    device under shard_map; this skips the host-side re-sort that
    `_exact_summary` would do.
    """
    v = np.asarray(values, np.float32)
    if v.size == 0:
        return _EMPTY_SUMMARY
    newgrp = np.empty(v.size, bool)
    newgrp[0] = True
    np.not_equal(v[1:], v[:-1], out=newgrp[1:])
    starts = np.flatnonzero(newgrp)
    vu = v[starts]
    counts = np.diff(np.append(starts, v.size)).astype(np.float64)
    rmax = np.cumsum(counts)
    return vu, rmax - counts, rmax, counts


def _summary_contrib(summary, vu: np.ndarray):
    """This summary's (rmin, rmax, w) contribution at each union value."""
    vals, rmin, rmax, w = summary
    m = vals.size
    if m == 0:
        z = np.zeros(vu.size, np.float64)
        return z, z.copy(), z.copy()
    total = rmax[-1]
    i = np.searchsorted(vals, vu, side="left")
    ic = np.minimum(i, m - 1)
    present = vals[ic] == vu
    # Floor entry (last strictly below): everything <= it is surely below.
    fl = np.maximum(i - 1, 0)
    rmin_next = np.where(i > 0, rmin[fl] + w[fl], 0.0)
    # Ceil entry (first strictly above): its rmax minus its own weight
    # bounds the mass <= vu from above.
    j = np.searchsorted(vals, vu, side="right")
    jc = np.minimum(j, m - 1)
    rmax_prev = np.where(j < m, rmax[jc] - w[jc], total)
    return (
        np.where(present, rmin[ic], rmin_next),
        np.where(present, rmax[ic], rmax_prev),
        np.where(present, w[ic], 0.0),
    )


def _combine_summaries(a, b):
    """XGBoost WQSummary::Combine — exact summaries merge exactly."""
    if a[0].size == 0:
        return b
    if b[0].size == 0:
        return a
    vu = np.unique(np.concatenate([a[0], b[0]]))
    ra_min, ra_max, wa = _summary_contrib(a, vu)
    rb_min, rb_max, wb = _summary_contrib(b, vu)
    return vu.astype(np.float32), ra_min + rb_min, ra_max + rb_max, wa + wb


def _prune_summary(summary, capacity: int):
    """WQSummary::SetPrune — keep the endpoints plus the entries nearest to
    capacity-2 evenly spaced rank targets."""
    vals, rmin, rmax, w = summary
    m = vals.size
    if m <= capacity:
        return summary
    total = rmax[-1]
    mids = (rmin + rmax) * 0.5
    targets = total * np.arange(1, capacity - 1, dtype=np.float64) / (capacity - 1)
    pos = np.searchsorted(mids, targets)
    lo = np.clip(pos - 1, 0, m - 1)
    hi = np.clip(pos, 0, m - 1)
    pick = np.where(np.abs(mids[hi] - targets) < np.abs(mids[lo] - targets), hi, lo)
    keep = np.unique(np.concatenate([[0], pick, [m - 1]]))
    return tuple(arr[keep] for arr in summary)


def _value_at_rank(summary, ranks: np.ndarray) -> np.ndarray:
    """Summary value covering each (0-based) rank: the first entry whose
    inclusive upper rank bound exceeds the query. Exact order statistics
    for exact summaries; off by at most the summary's rank error otherwise."""
    vals, _, rmax, _ = summary
    idx = np.minimum(np.searchsorted(rmax, ranks, side="right"), vals.size - 1)
    return vals[idx]


class StreamingQuantileSketch:
    """Mergeable weighted quantile sketch over feature columns.

    Streams over host-resident chunks with `push(batch)` (NaN = missing,
    excluded), combines sketches built elsewhere with `merge(other)` —
    merge of exact summaries is exact, so merge order cannot change the
    result until pruning kicks in — and emits `compute_cuts`-shaped cut
    points with `get_cuts()`. Memory is bounded by O(capacity) entries per
    feature regardless of how many rows are pushed.
    """

    def __init__(self, n_features: int, max_bins: int = DEFAULT_MAX_BINS,
                 capacity: int = 1024):
        if n_features <= 0:
            raise ValueError(f"n_features must be positive, got {n_features}")
        if capacity < 8:
            raise ValueError(f"capacity must be >= 8, got {capacity}")
        self.n_features = n_features
        self.max_bins = max_bins
        self.capacity = capacity
        self.n_pushed = 0
        self._summaries = [_EMPTY_SUMMARY] * n_features

    def push(self, batch, weights=None) -> "StreamingQuantileSketch":
        """Fold one (chunk_rows, n_features) batch into the sketch."""
        x = np.asarray(batch, np.float32)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(
                f"batch must be (rows, {self.n_features}), got {x.shape}"
            )
        if weights is None:
            w = np.ones(x.shape[0], np.float64)
        else:
            w = np.asarray(weights, np.float64)
            if w.shape != (x.shape[0],):
                raise ValueError(
                    f"weights must be ({x.shape[0]},), got {w.shape}"
                )
        for j in range(self.n_features):
            col = x[:, j]
            finite = np.isfinite(col)
            if not finite.any():
                continue
            batch_summary = _exact_summary(col[finite], w[finite])
            self._summaries[j] = _prune_summary(
                _combine_summaries(self._summaries[j], batch_summary),
                self.capacity,
            )
        self.n_pushed += x.shape[0]
        return self

    def push_sorted(self, cols_sorted, n_valid) -> "StreamingQuantileSketch":
        """Fold pre-sorted unit-weight columns into the sketch.

        Args:
          cols_sorted: (rows, n_features) with every column ascending and
            non-finite entries (missing markers / +inf padding) sorted to
            the tail — exactly what a device-side `jnp.sort` of a
            NaN->+inf-filled shard produces.
          n_valid: (n_features,) count of finite entries per column.

        Equivalent to `push` on the unsorted data (same summaries), minus
        the host-side argsort.
        """
        x = np.asarray(cols_sorted, np.float32)
        if x.ndim != 2 or x.shape[1] != self.n_features:
            raise ValueError(
                f"cols_sorted must be (rows, {self.n_features}), got {x.shape}"
            )
        nv = np.asarray(n_valid, np.int64).reshape(-1)
        if nv.shape != (self.n_features,):
            raise ValueError(
                f"n_valid must be ({self.n_features},), got {nv.shape}"
            )
        for j in range(self.n_features):
            if nv[j] == 0:
                continue
            batch_summary = _exact_summary_presorted(x[: nv[j], j])
            self._summaries[j] = _prune_summary(
                _combine_summaries(self._summaries[j], batch_summary),
                self.capacity,
            )
        self.n_pushed += x.shape[0]
        return self

    def merge(self, other: "StreamingQuantileSketch") -> "StreamingQuantileSketch":
        """Fold another sketch into this one (distributed cut generation)."""
        if not isinstance(other, StreamingQuantileSketch):
            raise TypeError(f"cannot merge {type(other)}")
        if (other.n_features, other.max_bins) != (self.n_features, self.max_bins):
            raise ValueError(
                "sketches disagree on shape: "
                f"({self.n_features}, max_bins={self.max_bins}) vs "
                f"({other.n_features}, max_bins={other.max_bins})"
            )
        for j in range(self.n_features):
            self._summaries[j] = _prune_summary(
                _combine_summaries(self._summaries[j], other._summaries[j]),
                self.capacity,
            )
        self.n_pushed += other.n_pushed
        return self

    def n_valid(self, feature: int) -> float:
        """Total (weighted) finite mass seen for one feature."""
        s = self._summaries[feature]
        return float(s[2][-1]) if s[0].size else 0.0

    def get_cuts(self) -> jax.Array:
        """Cut points in `compute_cuts`' exact output format: (n_features,
        n_value_bins - 1) float32 ascending, +inf padding past the used
        prefix, duplicates collapsed. For exact (unpruned) summaries this
        reproduces compute_cuts' rank interpolation arithmetic in float32.
        """
        nvb = n_value_bins(self.max_bins)
        out = np.full((self.n_features, nvb - 1), np.inf, np.float32)
        for j in range(self.n_features):
            summary = self._summaries[j]
            if summary[0].size == 0:
                continue  # all-missing feature: every cut stays +inf
            total = summary[2][-1]
            # Mirror compute_cuts bit-for-bit (same f32 ops, same guards).
            qs = (
                np.arange(1, nvb, dtype=np.float32) / np.float32(nvb)
            ) * np.float32(max(total - 1.0, 1.0))
            lo = np.floor(qs).astype(np.int64)
            frac = qs - lo.astype(np.float32)
            hi = lo + 1
            lov = _value_at_rank(summary, lo.astype(np.float64))
            hiv = np.where(
                hi < total,
                _value_at_rank(summary, np.minimum(hi, total - 1)),
                lov,
            )
            cand = (lov + frac * (hiv - lov)).astype(np.float32)
            cand = np.where(np.isfinite(cand), cand, np.float32(np.inf))
            prev = np.concatenate([[np.float32(-np.inf)], cand[:-1]])
            cand = np.where(cand > prev, cand, np.float32(np.inf))
            out[j] = np.sort(cand)
        return jnp.asarray(out)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        sizes = [s[0].size for s in self._summaries]
        return (
            f"StreamingQuantileSketch({self.n_features} features, "
            f"{self.n_pushed} rows pushed, capacity={self.capacity}, "
            f"max summary={max(sizes) if sizes else 0})"
        )
