"""Feature quantile generation (paper §2.1).

The paper maps quantile sketch construction to the GPU because it is a
considerable preprocessing cost. Here the same computation is expressed in
JAX (sort-based exact quantiles, vmapped over features) so XLA runs it on
the accelerator. Missing values (NaN) are excluded from the sketch and are
assigned a reserved *missing bin* (the last bin), which is what makes the
sparsity-aware default-direction logic in split.py possible (DESIGN.md §7.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Reserved: the last bin id of every feature is the "missing" bin.
# With max_bins=256 we get 255 value bins + 1 missing bin, so every bin id
# fits in 8 bits and the histogram axis is 256 = 2x128 (MXU lane aligned).
DEFAULT_MAX_BINS = 256


def missing_bin_id(max_bins: int = DEFAULT_MAX_BINS) -> int:
    return max_bins - 1


def n_value_bins(max_bins: int = DEFAULT_MAX_BINS) -> int:
    return max_bins - 1


@functools.partial(jax.jit, static_argnames=("max_bins",))
def compute_cuts(x: jax.Array, max_bins: int = DEFAULT_MAX_BINS) -> jax.Array:
    """Per-feature quantile cut points.

    Args:
      x: (n_rows, n_features) float array, NaN = missing.
      max_bins: total bins per feature incl. the reserved missing bin.

    Returns:
      cuts: (n_features, n_value_bins - 1) float32, ascending; value bin b
        holds x <= cuts[b] (and x > cuts[b-1]). Unused tail cuts are +inf so
        quantize() naturally maps everything into the used prefix.
    """
    nvb = n_value_bins(max_bins)
    n = x.shape[0]

    def per_feature(col: jax.Array) -> jax.Array:
        finite = jnp.isfinite(col)
        # Push NaNs to the end of the sort; count of valid entries.
        filled = jnp.where(finite, col, jnp.inf)
        srt = jnp.sort(filled)
        n_valid = jnp.sum(finite)
        # Quantile positions: interior boundaries between nvb equal-mass bins.
        qs = (jnp.arange(1, nvb, dtype=jnp.float32) / nvb) * jnp.maximum(
            n_valid - 1, 1
        ).astype(jnp.float32)
        lo = jnp.clip(jnp.floor(qs).astype(jnp.int32), 0, n - 1)
        hi = jnp.clip(lo + 1, 0, n - 1)
        frac = qs - lo.astype(jnp.float32)
        lov, hiv = srt[lo], srt[hi]
        # Linear interpolation, guarding the all-missing / +inf tail case.
        hiv = jnp.where(jnp.isfinite(hiv), hiv, lov)
        cand = lov + frac * (hiv - lov)
        cand = jnp.where(jnp.isfinite(cand), cand, jnp.inf)
        # Deduplicate: a cut equal to its predecessor is useless; push to +inf
        # so searchsorted collapses duplicate-mass bins (low-cardinality cols).
        prev = jnp.concatenate([jnp.array([-jnp.inf], cand.dtype), cand[:-1]])
        cand = jnp.where(cand > prev, cand, jnp.inf)
        return jnp.sort(cand)  # keep +inf padding at the tail

    return jax.vmap(per_feature, in_axes=1)(x.astype(jnp.float32))


@jax.jit
def quantize(x: jax.Array, cuts: jax.Array) -> jax.Array:
    """Map raw features to bin ids. NaN -> missing bin (= n_cuts + 1).

    bin = #cuts strictly below x, i.e. x <= cuts[b] lands in bin b. The last
    value bin is everything above the final finite cut; missing bin id is
    cuts.shape[1] + 1 == n_value_bins - ... == max_bins - 1 by construction.
    """
    n_cuts = cuts.shape[1]

    def per_feature(col: jax.Array, c: jax.Array) -> jax.Array:
        b = jnp.searchsorted(c, col, side="left").astype(jnp.int32)
        return jnp.where(jnp.isnan(col), jnp.int32(n_cuts + 1), b)

    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(
        x.astype(jnp.float32), cuts
    )
