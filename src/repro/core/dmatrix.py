"""DeviceDMatrix — the quantised, compressed training matrix as a first-class
user-facing object (paper Figure 1, left boxes; XGBoost's `DMatrix` noun).

Construction runs the paper's preprocessing pipeline ONCE on device:
quantile generation (`compute_cuts`) -> quantisation (`quantize`) ->
bit-packed compression (`compress`). The resulting object is the durable
on-device artifact: it can be reused across any number of `Booster.fit` /
`Booster.update` calls without re-quantising, and it is the only training-set
representation the booster ever sees (the raw float matrix can be freed by
the caller immediately after construction).

Evaluation sets must share the training matrix's cut points so that
bin-space tree traversal agrees exactly with raw-threshold traversal
(threshold == cuts[feature, split_bin] and `quantize` uses
searchsorted-left, so `x <= threshold  <=>  bin <= split_bin`). Build them
with `ref=`, mirroring XGBoost's `QuantileDMatrix(..., ref=dtrain)`:

    dtrain = DeviceDMatrix(x_train, label=y_train)
    dvalid = DeviceDMatrix(x_valid, label=y_valid, ref=dtrain)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import quantile as Q


def cuts_equal(a: jax.Array | None, b: jax.Array | None) -> bool:
    """Identity-or-value equality of two cut-point arrays — the single
    definition used by both DeviceDMatrix and Booster validation."""
    if a is b:
        return True
    if a is None or b is None:
        return False
    return a.shape == b.shape and bool(jnp.all(a == b))


class DeviceDMatrix:
    """Device-resident quantised + compressed data matrix.

    Args:
      x: (n_rows, n_features) float array (numpy or jax), NaN = missing.
      label: optional (n_rows,) targets; required for `Booster.fit`.
      group_ids: optional (n_rows,) int query-group ids (rank:pairwise).
      max_bins: total bins per feature incl. the reserved missing bin.
      ref: another DeviceDMatrix whose cut points (and max_bins) to reuse —
        required for evaluation sets so bin-space traversal is exact.
    """

    def __init__(
        self,
        x,
        label=None,
        *,
        group_ids=None,
        max_bins: int = Q.DEFAULT_MAX_BINS,
        ref: "DeviceDMatrix | None" = None,
    ):
        x = jnp.asarray(x, jnp.float32)
        if x.ndim != 2:
            raise ValueError(f"x must be 2-D (n_rows, n_features), got {x.shape}")
        if ref is not None:
            cuts = ref.cuts
            max_bins = ref.max_bins
            if x.shape[1] != ref.n_features:
                raise ValueError(
                    f"ref has {ref.n_features} features, x has {x.shape[1]}"
                )
        else:
            cuts = Q.compute_cuts(x, max_bins)
        bins = Q.quantize(x, cuts)
        self.matrix: C.CompressedMatrix = C.compress(bins, cuts, max_bins)
        self.label = None if label is None else jnp.asarray(label, jnp.float32)
        self.group_ids = (
            None if group_ids is None else jnp.asarray(group_ids, jnp.int32)
        )
        # Per-shard re-packings built by the distributed strategy, keyed by
        # shard count — paid once per (matrix, mesh size), not per fit.
        self._shard_pack_cache: dict = {}
        if self.label is not None and self.label.shape[0] != self.n_rows:
            raise ValueError(
                f"label has {self.label.shape[0]} rows, x has {self.n_rows}"
            )

    # --- surface -----------------------------------------------------------
    @property
    def cuts(self) -> jax.Array:
        return self.matrix.cuts

    @property
    def max_bins(self) -> int:
        return self.matrix.max_bins

    @property
    def bits(self) -> int:
        return self.matrix.bits

    @property
    def n_rows(self) -> int:
        return self.matrix.n_rows

    @property
    def n_features(self) -> int:
        return self.matrix.n_features

    @property
    def nbytes(self) -> int:
        """Device bytes held: packed words + cut points + labels/groups."""
        total = self.matrix.nbytes_compressed() + int(np.prod(self.cuts.shape)) * 4
        if self.label is not None:
            total += self.label.shape[0] * 4
        if self.group_ids is not None:
            total += self.group_ids.shape[0] * 4
        return total

    def packed_bins(self) -> C.PackedBins:
        """The traced (jit-flowable) view consumed by the training scan."""
        return self.matrix.as_packed_bins()

    def compression_ratio(self) -> float:
        return self.matrix.compression_ratio()

    def same_cuts(self, other: "DeviceDMatrix") -> bool:
        return cuts_equal(self.cuts, other.cuts)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"DeviceDMatrix({self.n_rows}x{self.n_features}, "
            f"{self.bits}-bit, max_bins={self.max_bins}, "
            f"{self.nbytes / 1e6:.2f} MB"
            f"{', labelled' if self.label is not None else ''})"
        )
