"""DeviceDMatrix — the quantised, compressed training matrix as a first-class
user-facing object (paper Figure 1, left boxes; XGBoost's `DMatrix` noun).

Construction runs the paper's preprocessing pipeline ONCE on device:
quantile generation (`compute_cuts`) -> quantisation (`quantize`) ->
bit-packed compression (`compress`). The resulting object is the durable
on-device artifact: it can be reused across any number of `Booster.fit` /
`Booster.update` calls without re-quantising, and it is the only training-set
representation the booster ever sees (the raw float matrix can be freed by
the caller immediately after construction).

Evaluation sets must share the training matrix's cut points so that
bin-space tree traversal agrees exactly with raw-threshold traversal
(threshold == cuts[feature, split_bin] and `quantize` uses
searchsorted-left, so `x <= threshold  <=>  bin <= split_bin`). Build them
with `ref=`, mirroring XGBoost's `QuantileDMatrix(..., ref=dtrain)`:

    dtrain = DeviceDMatrix(x_train, label=y_train)
    dvalid = DeviceDMatrix(x_valid, label=y_valid, ref=dtrain)

Two batch-iterator constructors remove the all-resident-at-once ceiling
(DESIGN.md §11):

  * `DeviceDMatrix.from_batches(batches)` assembles the SAME in-memory
    matrix from an iterator of chunks (bit-identical to constructing from
    the concatenated array) — convenience for sources that are naturally
    chunked but still fit on device.
  * `ExternalDMatrix(batches, chunk_rows=...)` never builds the flat
    matrix at all: cut points stream through a quantile sketch, every
    chunk is quantised + bit-packed independently, and the chunks live
    host-side until training pages the compressed stack in. Training over
    it scans chunk-by-chunk, bounding dense device transients by one chunk.
"""
from __future__ import annotations

import queue
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import quantile as Q
from repro.core import resilience as RES
from repro.testing import faults as FA


def _split_batch_item(item, index: int):
    """One iterator item -> (x, label | None, group_ids | None)."""
    if isinstance(item, (tuple, list)):
        if not 1 <= len(item) <= 3:
            raise ValueError(
                f"batch {index}: expected x, (x, y) or (x, y, group_ids), "
                f"got a {len(item)}-tuple"
            )
        return tuple(item) + (None,) * (3 - len(item))
    return item, None, None


def _collect_batches(batches):
    """Validate and materialise a batch iterator as host float32 chunks.

    Every chunk must be a 2-D numeric array with the same n_features and
    the same dtype as the first chunk, and labels/group_ids must be present
    either for every chunk or for none, with lengths matching their chunk —
    anything else raises a ValueError naming the offending batch (instead
    of an opaque XLA shape error deep inside quantise/compress).

    Returns (x_chunks, label or None, group_ids or None, n_features).
    """
    xs, ys, gs = [], [], []
    n_features = None
    dtype0 = None
    for i, item in enumerate(batches):
        x, y, g = _split_batch_item(item, i)
        x = np.asarray(x)
        if x.dtype == object or not (
            np.issubdtype(x.dtype, np.number) or x.dtype == np.bool_
        ):
            raise ValueError(
                f"batch {i} has non-numeric dtype {x.dtype!r}; batches must "
                "be numeric 2-D arrays"
            )
        if x.ndim != 2:
            raise ValueError(
                f"batch {i} must be 2-D (rows, n_features), got shape {x.shape}"
            )
        if x.shape[0] == 0:
            raise ValueError(f"batch {i} is empty (0 rows)")
        if x.shape[1] == 0:
            raise ValueError(f"batch {i} has 0 features")
        if n_features is None:
            n_features, dtype0 = x.shape[1], x.dtype
        else:
            if x.shape[1] != n_features:
                raise ValueError(
                    f"batch {i} has {x.shape[1]} features but batch 0 had "
                    f"{n_features}; all batches must agree"
                )
            if x.dtype != dtype0:
                raise ValueError(
                    f"batch {i} has dtype {x.dtype!r} but batch 0 had "
                    f"{dtype0!r}; all batches must agree"
                )
        if (y is None) != (not ys) and i > 0:
            raise ValueError(
                f"batch {i} {'has no label but earlier batches did' if y is None else 'has a label but earlier batches did not'}"
                "; labels must be given for every batch or for none"
            )
        if y is not None:
            y = np.asarray(y, np.float32).reshape(-1)
            if y.shape[0] != x.shape[0]:
                raise ValueError(
                    f"batch {i}: label has {y.shape[0]} rows, x has {x.shape[0]}"
                )
            if not np.isfinite(y).all():
                raise ValueError(
                    f"batch {i}: label contains non-finite values (NaN/inf); "
                    "clean or drop those rows before training"
                )
            ys.append(y)
        if (g is None) != (not gs) and i > 0:
            raise ValueError(
                f"batch {i}: group_ids must be given for every batch or none"
            )
        if g is not None:
            g = np.asarray(g, np.int32).reshape(-1)
            if g.shape[0] != x.shape[0]:
                raise ValueError(
                    f"batch {i}: group_ids has {g.shape[0]} rows, "
                    f"x has {x.shape[0]}"
                )
            gs.append(g)
        xf = np.ascontiguousarray(x, np.float32)
        if np.isinf(xf).any():
            raise ValueError(
                f"batch {i} contains infinite feature values; replace ±inf "
                "with NaN (legal missing marker) or a large finite value "
                "before quantisation"
            )
        xs.append(xf)
    if not xs:
        raise ValueError("batch iterator produced no batches")
    label = np.concatenate(ys) if ys else None
    groups = np.concatenate(gs) if gs else None
    return xs, label, groups, n_features


def _push_chunk_sorted(sk: "Q.StreamingQuantileSketch", chunk: np.ndarray) -> None:
    """Fold one host chunk into a sketch via the sorted fast path.

    One column-wise np.sort + push_sorted replaces the per-feature host
    argsort loop that push() runs — same summaries, same cuts (push_sorted
    is exactly push for unit weights), a large constant factor cheaper on
    wide chunks. NaNs (the only non-finite values _collect_batches admits)
    are filled with +inf so they sort to the tail, matching push_sorted's
    input contract; n_valid counts the finite prefix per column.
    """
    filled = np.where(np.isnan(chunk), np.inf, chunk)
    cols = np.sort(filled, axis=0)
    n_valid = np.isfinite(cols).sum(axis=0)
    sk.push_sorted(cols, n_valid)


def cuts_equal(a: jax.Array | None, b: jax.Array | None) -> bool:
    """Identity-or-value equality of two cut-point arrays — the single
    definition used by both DeviceDMatrix and Booster validation."""
    if a is b:
        return True
    if a is None or b is None:
        return False
    return a.shape == b.shape and bool(jnp.all(a == b))


class DeviceDMatrix:
    """Device-resident quantised + compressed data matrix.

    Args:
      x: (n_rows, n_features) float array (numpy or jax), NaN = missing.
      label: optional (n_rows,) targets; required for `Booster.fit`.
      group_ids: optional (n_rows,) int query-group ids (rank:pairwise).
      max_bins: total bins per feature incl. the reserved missing bin.
      ref: another DeviceDMatrix whose cut points (and max_bins) to reuse —
        required for evaluation sets so bin-space traversal is exact.
      cuts: optional precomputed (n_features, n_value_bins - 1) cut array —
        e.g. from `repro.dist.sharded_sketch_cuts` (device-sharded sketch
        build, paper §quantiles). Mutually exclusive with `ref`.
    """

    def __init__(
        self,
        x,
        label=None,
        *,
        group_ids=None,
        max_bins: int = Q.DEFAULT_MAX_BINS,
        ref: "DeviceDMatrix | None" = None,
        cuts=None,
    ):
        x = jnp.asarray(x, jnp.float32)
        if x.ndim != 2:
            raise ValueError(f"x must be 2-D (n_rows, n_features), got {x.shape}")
        if x.shape[0] == 0:
            raise ValueError(
                "x has 0 rows; cannot build a DeviceDMatrix from an empty "
                "matrix"
            )
        if x.shape[1] == 0:
            raise ValueError(
                "x has 0 features; every row needs at least one feature "
                "column"
            )
        if bool(jnp.any(jnp.isinf(x))):
            raise ValueError(
                "x contains infinite feature values; replace ±inf with NaN "
                "(the legal missing marker) or a large finite value before "
                "quantisation"
            )
        if ref is not None:
            if cuts is not None:
                raise ValueError(
                    "pass either ref= or cuts=, not both (ref already "
                    "carries its cut points)"
                )
            cuts = ref.cuts
            max_bins = ref.max_bins
            if x.shape[1] != ref.n_features:
                raise ValueError(
                    f"ref has {ref.n_features} features, x has {x.shape[1]}"
                )
        elif cuts is not None:
            cuts = jnp.asarray(cuts, jnp.float32)
            nvb = Q.n_value_bins(max_bins)
            if cuts.shape != (x.shape[1], nvb - 1):
                raise ValueError(
                    f"cuts must have shape ({x.shape[1]}, {nvb - 1}) for "
                    f"max_bins={max_bins}, got {cuts.shape}"
                )
        else:
            cuts = Q.compute_cuts(x, max_bins)
        bins = Q.quantize(x, cuts)
        self.matrix: C.CompressedMatrix = C.compress(bins, cuts, max_bins)
        self.label = None if label is None else jnp.asarray(label, jnp.float32)
        self.group_ids = (
            None if group_ids is None else jnp.asarray(group_ids, jnp.int32)
        )
        # Per-shard re-packings built by the distributed strategy, keyed by
        # shard count — paid once per (matrix, mesh size), not per fit.
        self._shard_pack_cache: dict = {}
        if self.label is not None and self.label.shape[0] != self.n_rows:
            raise ValueError(
                f"label has {self.label.shape[0]} rows, x has {self.n_rows}"
            )
        if self.label is not None and \
                not bool(jnp.all(jnp.isfinite(self.label))):
            raise ValueError(
                "label contains non-finite values (NaN/inf); clean or drop "
                "those rows before training"
            )

    @classmethod
    def from_batches(
        cls,
        batches,
        *,
        max_bins: int = Q.DEFAULT_MAX_BINS,
        ref: "DeviceDMatrix | None" = None,
    ) -> "DeviceDMatrix":
        """Build the in-memory matrix from an iterator of chunks.

        `batches` yields `x`, `(x, y)` or `(x, y, group_ids)` chunks; they
        are validated (consistent n_features/dtype, matching label lengths
        — a clear ValueError instead of an opaque XLA error) and assembled
        into exactly the matrix `DeviceDMatrix(concat(chunks), ...)` would
        produce, bit for bit. For data that must never be resident all at
        once, use `ExternalDMatrix` instead.
        """
        xs, label, groups, _ = _collect_batches(batches)
        x = xs[0] if len(xs) == 1 else np.concatenate(xs)
        return cls(x, label=label, group_ids=groups, max_bins=max_bins,
                   ref=ref)

    # --- surface -----------------------------------------------------------
    @property
    def cuts(self) -> jax.Array:
        return self.matrix.cuts

    @property
    def max_bins(self) -> int:
        return self.matrix.max_bins

    @property
    def bits(self) -> int:
        return self.matrix.bits

    @property
    def n_rows(self) -> int:
        return self.matrix.n_rows

    @property
    def n_features(self) -> int:
        return self.matrix.n_features

    @property
    def nbytes(self) -> int:
        """Device bytes held: packed words + cut points + labels/groups."""
        total = self.matrix.nbytes_compressed() + int(np.prod(self.cuts.shape)) * 4
        if self.label is not None:
            total += self.label.shape[0] * 4
        if self.group_ids is not None:
            total += self.group_ids.shape[0] * 4
        return total

    def packed_bins(self) -> C.PackedBins:
        """The traced (jit-flowable) view consumed by the training scan."""
        return self.matrix.as_packed_bins()

    def compression_ratio(self) -> float:
        return self.matrix.compression_ratio()

    def same_cuts(self, other: "DeviceDMatrix") -> bool:
        return cuts_equal(self.cuts, other.cuts)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"DeviceDMatrix({self.n_rows}x{self.n_features}, "
            f"{self.bits}-bit, max_bins={self.max_bins}, "
            f"{self.nbytes / 1e6:.2f} MB"
            f"{', labelled' if self.label is not None else ''})"
        )


class ChunkPager:
    """Bounded background prefetcher over a sequence of chunk indices.

    A daemon thread walks `indices`, calls `load_fn(i)` for each (the
    host->device staging step — crc verify + `jnp.asarray` transfer), and
    parks the results in a queue of at most `depth` staged chunks. The
    consumer iterates `(index, chunk)` pairs: while it computes on chunk k,
    the worker is already transferring chunk k+1 (double-buffered at
    depth=2), hiding host->device latency behind compute. XLA dispatch and
    the crc32 both release the GIL, so the overlap is genuine even on CPU.

    `depth <= 0` (or a single chunk) degrades to a plain synchronous loop
    — same yields, same order, no thread — which is the bit-identity
    anchor: the consumer's arithmetic never depends on the staging mode.

    Exceptions raised by `load_fn` (after its own retry policy is
    exhausted) are forwarded through the queue and re-raised in the
    consumer; the worker stops producing past a failure so a broken source
    cannot keep filling the ring. `close()` (called automatically when
    iteration ends, breaks, or raises) stops the worker and drains the
    queue so blocked puts can observe the stop flag.
    """

    def __init__(self, load_fn, indices, depth: int):
        self._load = load_fn
        self._indices = list(indices)
        self._queue: queue.Queue | None = None
        self._stop: threading.Event | None = None
        self._thread: threading.Thread | None = None
        if depth > 0 and len(self._indices) > 1:
            self._queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._worker, name="chunk-pager", daemon=True
            )
            self._thread.start()

    def _worker(self) -> None:
        for i in self._indices:
            if self._stop.is_set():
                return
            try:
                item = (i, self._load(i), None)
            except BaseException as exc:  # forwarded, not swallowed
                item = (i, None, exc)
            while not self._stop.is_set():
                try:
                    self._queue.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if item[2] is not None:
                return

    def __iter__(self):
        try:
            if self._thread is None:
                for i in self._indices:
                    yield i, self._load(i)
                return
            for _ in self._indices:
                i, chunk, exc = self._queue.get()
                if exc is not None:
                    raise exc
                yield i, chunk
        finally:
            self.close()

    def close(self) -> None:
        """Stop the worker and release staged chunks (idempotent)."""
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join()
            self._thread = None
            self._queue = None

    def __enter__(self) -> "ChunkPager":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def _normalize_verify(verify) -> str:
    """verify_chunks knob -> one of 'once' | 'always' | 'never'."""
    if verify is True:
        return "once"
    if verify is False:
        return "never"
    if verify in ("once", "always", "never"):
        return verify
    raise ValueError(
        "verify_chunks must be True ('once'), False ('never'), 'once', "
        f"'always' or 'never', got {verify!r}"
    )


class ExternalDMatrix:
    """External-memory training matrix: host-resident bit-packed chunks.

    The flat (n_rows, n_features) matrix never exists on device — not as
    floats, not as dense bins. Cut points come from a streaming quantile
    sketch (one pass over the chunks, bounded memory), each chunk is then
    quantised and bit-packed independently, and the packed chunks are kept
    host-side as one (n_chunks, n_features, words_per_chunk) uint32 stack.
    `packed_bins()` pages the compressed stack onto the device (cached;
    `unload()` drops it again) as a `ChunkedPackedBins` pytree that the
    booster's compiled scan consumes chunk by chunk, so dense device
    transients stay bounded by one chunk regardless of n_rows
    (DESIGN.md §11).

    Labels, group ids and per-round gradients stay fully device-resident
    (they are O(n), the matrix is O(n * f) — the same split XGBoost's
    external-memory mode makes).

    Args:
      batches: iterator of `x`, `(x, y)` or `(x, y, group_ids)` chunks
        (validated like `DeviceDMatrix.from_batches`; incoming chunk sizes
        are arbitrary — rows are re-chunked to `chunk_rows`).
      chunk_rows: rows per stored chunk — the unit of device paging and the
        bound on dense transients during construction and training.
      max_bins: total bins per feature incl. the reserved missing bin.
      ref: reuse another matrix's cut points (evaluation sets; overrides
        `cuts`).
      cuts: "sketch" (default — stream a StreamingQuantileSketch over the
        chunks), "exact" (gather the full float matrix once and run
        `compute_cuts`; bit-identical to the in-memory matrix, for
        artificially chunked data and parity testing), or a precomputed
        (n_features, n_value_bins - 1) cut array.
      sketch_capacity: per-feature summary size for cuts="sketch".
      sketch_shards: with cuts="sketch", build one sketch per shard of the
        chunk list and combine them by repro.dist's log-depth tree merge
        (the paper's distributed sketch build) instead of one sequential
        fold — fewer prune rounds on any leaf-to-root path, and the
        host-side analogue of the device-sharded build
        (`repro.dist.sharded_sketch_cuts`). 1 (default) keeps the
        sequential stream.
      verify_chunks: crc32 verification policy for page-in (crcs are
        recorded at build so bit-flips between build and load surface as a
        ChunkIntegrityError instead of silently training on garbage,
        DESIGN.md §13). True or "once" (default): each chunk is verified
        the first time it is paged in and re-verified after any load
        retry, then trusted — steady-state epochs pay zero checksum cost.
        "always": re-verify on every page-in (paranoid mode for flaky
        storage). False or "never": skip verification entirely.
      load_retries / load_backoff: transient page-in failures (I/O errors,
        integrity failures in the transfer path) are retried this many
        times with exponential backoff before the error propagates.
      paging: "resident" pages the whole compressed stack to device once
        and trains on the compiled chunked scan; "stream" keeps the stack
        host-side and streams chunks through a bounded prefetching pager
        every round (device footprint ~prefetch_chunks+1 chunks instead of
        the full stack — for stacks that do not fit device memory);
        "auto" (default) picks "stream" only when the device reports a
        memory limit and the stack would occupy more than half of it,
        otherwise "resident" (DESIGN.md §17).
      prefetch_chunks: staged-chunk ring depth for streamed paging — the
        worker thread keeps up to this many chunks in flight ahead of
        compute (2 = double buffering). 0 disables the background thread
        (synchronous loads, bit-identical results).
    """

    def __init__(
        self,
        batches,
        *,
        chunk_rows: int = 131072,
        max_bins: int = Q.DEFAULT_MAX_BINS,
        ref=None,
        cuts="sketch",
        sketch_capacity: int = 1024,
        sketch_shards: int = 1,
        verify_chunks: bool | str = True,
        load_retries: int = 2,
        load_backoff: float = 0.05,
        paging: str = "auto",
        prefetch_chunks: int = 2,
    ):
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
        if paging not in ("auto", "resident", "stream"):
            raise ValueError(
                f"paging must be 'auto', 'resident' or 'stream', got {paging!r}"
            )
        if prefetch_chunks < 0:
            raise ValueError(
                f"prefetch_chunks must be >= 0, got {prefetch_chunks}"
            )
        xs, label, groups, n_features = _collect_batches(batches)
        n_rows = sum(c.shape[0] for c in xs)
        xs = _rechunk(xs, chunk_rows)

        if ref is not None:
            if n_features != ref.n_features:
                raise ValueError(
                    f"ref has {ref.n_features} features, batches have "
                    f"{n_features}"
                )
            cut_arr = ref.cuts
            max_bins = ref.max_bins
        elif isinstance(cuts, str):
            if cuts == "exact":
                cut_arr = Q.compute_cuts(
                    jnp.asarray(np.concatenate(xs)), max_bins
                )
            elif cuts == "sketch":
                if sketch_shards < 1:
                    raise ValueError(
                        f"sketch_shards must be >= 1, got {sketch_shards}"
                    )
                shards = min(sketch_shards, len(xs))
                if shards > 1:
                    # Distributed-style build: one sketch per chunk shard,
                    # combined by log-depth tree merge (repro.dist.sketch).
                    from repro.dist.sketch import tree_merge

                    sketches = []
                    for s in range(shards):
                        sk = Q.StreamingQuantileSketch(
                            n_features, max_bins, capacity=sketch_capacity
                        )
                        for chunk in xs[s::shards]:
                            _push_chunk_sorted(sk, chunk)
                        sketches.append(sk)
                    cut_arr = tree_merge(sketches).get_cuts()
                else:
                    sketch = Q.StreamingQuantileSketch(
                        n_features, max_bins, capacity=sketch_capacity
                    )
                    for chunk in xs:
                        _push_chunk_sorted(sketch, chunk)
                    cut_arr = sketch.get_cuts()
            else:
                raise ValueError(
                    f"cuts must be 'sketch', 'exact' or an array, got {cuts!r}"
                )
        else:
            cut_arr = jnp.asarray(cuts, jnp.float32)
            nvb = Q.n_value_bins(max_bins)
            if cut_arr.shape != (n_features, nvb - 1):
                raise ValueError(
                    f"cuts must have shape ({n_features}, {nvb - 1}), "
                    f"got {cut_arr.shape}"
                )

        # Quantise + pack chunk by chunk: the dense transients (float chunk,
        # int32 bin chunk) are bounded by chunk_rows. Bit width is fixed
        # from max_bins so every chunk packs identically without a second
        # global pass over the data.
        bits = C.bits_needed(max_bins - 1)
        spw = C.symbols_per_word(bits)
        words_per_chunk = -(-chunk_rows // spw)
        host_chunks = np.zeros(
            (len(xs), n_features, words_per_chunk), np.uint32
        )
        for i, chunk in enumerate(xs):
            bins = Q.quantize(jnp.asarray(chunk), cut_arr)
            packed = np.asarray(C.pack(bins, bits))
            host_chunks[i, :, : packed.shape[1]] = packed

        self._host_packed = host_chunks
        self._device_stack: jax.Array | None = None
        self.cuts = cut_arr
        self.max_bins = max_bins
        self.bits = bits
        self.chunk_rows = chunk_rows
        self.n_rows = n_rows
        self.label = None if label is None else jnp.asarray(label, jnp.float32)
        self.group_ids = (
            None if groups is None else jnp.asarray(groups, jnp.int32)
        )
        self.verify_chunks = _normalize_verify(verify_chunks)
        self.load_retries = load_retries
        self.load_backoff = load_backoff
        self.paging = paging
        self.prefetch_chunks = prefetch_chunks
        self._chunk_crcs = RES.crc32_chunks(host_chunks)
        self._verified = np.zeros(host_chunks.shape[0], np.bool_)
        self.stream_stats = None  # last streamed fit's counters (stream.py)

    @classmethod
    def from_dmatrix(cls, dmat: "DeviceDMatrix", *, chunk_rows: int,
                     **kw) -> "ExternalDMatrix":
        """Convert an in-memory DeviceDMatrix to external memory — the
        `fit(on_oom="external")` degradation path. Bins are recovered from
        the packed words and re-chunked; no raw float matrix is needed, the
        cut points are shared, and training on the result is bit-identical
        to the in-memory matrix (DESIGN.md §11)."""
        bins = np.asarray(dmat.matrix.unpack())
        return cls._from_host_bins(bins, dmat.cuts, dmat.max_bins,
                                   dmat.label, dmat.group_ids, chunk_rows,
                                   **kw)

    @classmethod
    def _from_host_bins(cls, bins, cuts, max_bins, label, group_ids,
                        chunk_rows, *, verify_chunks: bool | str = True,
                        load_retries: int = 2, load_backoff: float = 0.05,
                        paging: str = "auto", prefetch_chunks: int = 2):
        """Build from already-quantised host bins (from_dmatrix / rechunk):
        the float->bins pipeline is skipped, everything downstream of
        quantisation is identical to __init__."""
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
        self = cls.__new__(cls)
        n_rows, n_features = bins.shape
        bits = C.bits_needed(max_bins - 1)
        spw = C.symbols_per_word(bits)
        words_per_chunk = -(-chunk_rows // spw)
        n_chunks = -(-n_rows // chunk_rows)
        host_chunks = np.zeros(
            (n_chunks, n_features, words_per_chunk), np.uint32
        )
        for i, s in enumerate(range(0, n_rows, chunk_rows)):
            packed = np.asarray(
                C.pack(jnp.asarray(bins[s : s + chunk_rows]), bits)
            )
            host_chunks[i, :, : packed.shape[1]] = packed
        self._host_packed = host_chunks
        self._device_stack = None
        self.cuts = cuts
        self.max_bins = max_bins
        self.bits = bits
        self.chunk_rows = chunk_rows
        self.n_rows = n_rows
        self.label = None if label is None else jnp.asarray(label, jnp.float32)
        self.group_ids = (
            None if group_ids is None else jnp.asarray(group_ids, jnp.int32)
        )
        self.verify_chunks = _normalize_verify(verify_chunks)
        self.load_retries = load_retries
        self.load_backoff = load_backoff
        self.paging = paging
        self.prefetch_chunks = prefetch_chunks
        self._chunk_crcs = RES.crc32_chunks(host_chunks)
        self._verified = np.zeros(n_chunks, np.bool_)
        self.stream_stats = None  # last streamed fit's counters (stream.py)
        return self

    def rechunk(self, chunk_rows: int) -> "ExternalDMatrix":
        """A new ExternalDMatrix over the same data with a different chunk
        size (the OOM path halves chunk_rows until the fit fits). Chunks
        are decoded host-side and re-packed; cuts, labels and groups are
        shared, so training stays bit-identical."""
        return type(self)._from_host_bins(
            self._decode_host_bins(), self.cuts, self.max_bins, self.label,
            self.group_ids, chunk_rows, verify_chunks=self.verify_chunks,
            load_retries=self.load_retries, load_backoff=self.load_backoff,
            paging=self.paging, prefetch_chunks=self.prefetch_chunks,
        )

    def _decode_host_bins(self) -> np.ndarray:
        """The dense bins matrix, host-side (transient: only rechunk and
        parity tests materialise it)."""
        out = np.empty((self.n_rows, self.n_features), np.int32)
        for i in range(self.n_chunks):
            s = i * self.chunk_rows
            rows = min(self.chunk_rows, self.n_rows - s)
            out[s : s + rows] = np.asarray(
                C.unpack(jnp.asarray(self._host_packed[i]), self.bits, rows)
            )
        return out

    @classmethod
    def from_arrays(
        cls, x, label=None, *, group_ids=None, chunk_rows: int = 131072, **kw
    ) -> "ExternalDMatrix":
        """Artificially chunk an in-memory array (tests, benchmarks, and
        the parity check against `DeviceDMatrix`)."""
        x = np.asarray(x, np.float32)

        def batches():
            for s in range(0, x.shape[0], chunk_rows):
                xb = x[s : s + chunk_rows]
                yb = None if label is None else np.asarray(label)[s : s + chunk_rows]
                gb = None if group_ids is None else np.asarray(group_ids)[s : s + chunk_rows]
                if gb is not None:
                    yield xb, yb, gb
                elif yb is not None:
                    yield xb, yb
                else:
                    yield xb
        return cls(batches(), chunk_rows=chunk_rows, **kw)

    # --- surface -----------------------------------------------------------
    @property
    def n_chunks(self) -> int:
        return self._host_packed.shape[0]

    @property
    def n_features(self) -> int:
        return self._host_packed.shape[1]

    @property
    def nbytes_host(self) -> int:
        """Host bytes held by the packed chunk stack."""
        return self._host_packed.nbytes

    @property
    def nbytes_device(self) -> int:
        """Device bytes currently held (0 when paged out)."""
        if self._device_stack is None:
            return 0
        return int(np.prod(self._device_stack.shape)) * 4

    def resolved_paging(self) -> str:
        """The effective paging mode: "resident" or "stream".

        "auto" resolves to "stream" only when the backing device reports a
        memory limit and the compressed stack would occupy more than half
        of it (leaving headroom for gradients, histograms and transients);
        anywhere the limit is unknown — notably CPU backends — it resolves
        to "resident", the proven compiled-scan path.
        """
        if self.paging != "auto":
            return self.paging
        try:
            stats = jax.devices()[0].memory_stats()
            limit = (stats or {}).get("bytes_limit")
        except Exception:
            limit = None
        if limit and self.nbytes_host > 0.5 * limit:
            return "stream"
        return "resident"

    def packed_bins(self) -> C.ChunkedPackedBins:
        """Page the compressed chunk stack onto the device (cached) as the
        traced representation the training scan consumes. Page-in verifies
        per-chunk crc32s and retries transient failures (DESIGN.md §13)."""
        if self._device_stack is None:
            self._device_stack = self._page_in()
        return C.ChunkedPackedBins(
            packed=self._device_stack,
            bits=self.bits,
            chunk_rows=self.chunk_rows,
            n_rows=self.n_rows,
        )

    def _page_in(self) -> jax.Array:
        """Host -> device transfer with integrity verification and
        retry/backoff. The chunk_load / chunk_corrupt fault sites
        (repro.testing.faults) live here. Verification follows the
        verify_chunks policy: "once" verifies only stacks with unverified
        chunks (first page-in, or after a retry cleared the flags),
        "always" re-verifies every page-in, "never" skips."""

        def attempt():
            FA.check("chunk_load")
            stack = FA.corrupt_array("chunk_corrupt", self._host_packed)
            if self.verify_chunks == "always" or (
                self.verify_chunks == "once" and not self._verified.all()
            ):
                RES.verify_chunk_crcs(
                    stack, self._chunk_crcs,
                    context=f"ExternalDMatrix({self.n_rows}x"
                            f"{self.n_features})",
                )
                self._verified[:] = True
            return jnp.asarray(stack)

        def note(n, exc):
            self._verified[:] = False
            warnings.warn(
                f"chunk page-in failed ({exc}); "
                f"retry {n + 1}/{self.load_retries}"
            )

        return RES.with_retries(
            attempt, retries=self.load_retries, backoff=self.load_backoff,
            retry_on=(OSError, RES.ChunkIntegrityError), on_retry=note,
        )

    def _load_chunk(self, i: int) -> jax.Array:
        """Page ONE chunk host -> device: the per-chunk analogue of
        `_page_in`, with the same fault sites, verify policy and
        retry/backoff. A retry clears the chunk's verified flag so the
        re-attempt re-checks the crc even under the "once" policy."""

        def attempt():
            FA.check("chunk_load")
            chunk = FA.corrupt_array("chunk_corrupt", self._host_packed[i])
            if self.verify_chunks == "always" or (
                self.verify_chunks == "once" and not self._verified[i]
            ):
                RES.verify_chunk_crcs(
                    chunk[None], self._chunk_crcs[i : i + 1],
                    context=f"ExternalDMatrix chunk {i}",
                )
                self._verified[i] = True
            return jnp.asarray(chunk)

        def note(n, exc):
            self._verified[i] = False
            warnings.warn(
                f"chunk {i} page-in failed ({exc}); "
                f"retry {n + 1}/{self.load_retries}"
            )

        return RES.with_retries(
            attempt, retries=self.load_retries, backoff=self.load_backoff,
            retry_on=(OSError, RES.ChunkIntegrityError), on_retry=note,
        )

    def chunk_pager(self, indices=None, prefetch: int | None = None
                    ) -> ChunkPager:
        """A `ChunkPager` over `indices` (default: every chunk in order).

        When the stack is already device-resident the pager serves cached
        slices synchronously (they were verified when paged in); otherwise
        a background worker stages up to `prefetch` chunks (default
        `self.prefetch_chunks`) ahead of the consumer via `_load_chunk`,
        so transfer of chunk k+1 overlaps compute on chunk k. Iterate
        `(index, chunk)` pairs; iteration cleans up the worker on exit."""
        if indices is None:
            indices = range(self.n_chunks)
        if self._device_stack is not None:
            stack = self._device_stack
            return ChunkPager(lambda i: stack[i], indices, 0)
        if prefetch is None:
            prefetch = self.prefetch_chunks
        return ChunkPager(self._load_chunk, indices, prefetch)

    def iter_device_chunks(self):
        """Yield each packed chunk as a device array, ONE at a time — the
        streaming predict path (DESIGN.md §14). Unlike `packed_bins()` the
        full device stack is never materialised: device transients stay
        bounded by the pager ring (prefetch_chunks staged + 1 in use), and
        `nbytes_device` stays 0. Chunk crc32s are verified per the
        verify_chunks policy with the same retry/backoff as training (when
        the stack is already device-resident the cached copy is served
        instead — it was verified when paged in)."""
        for _, chunk in self.chunk_pager():
            yield chunk

    def unload(self) -> None:
        """Drop the device copy of the chunk stack (page out). The host
        stack is retained; the next `packed_bins()` pages back in."""
        self._device_stack = None

    def same_cuts(self, other) -> bool:
        return cuts_equal(self.cuts, getattr(other, "cuts", None))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"ExternalDMatrix({self.n_rows}x{self.n_features}, "
            f"{self.n_chunks} chunks of {self.chunk_rows} rows, "
            f"{self.bits}-bit, {self.nbytes_host / 1e6:.2f} MB host"
            f"{', labelled' if self.label is not None else ''})"
        )


def _rechunk(xs: list, chunk_rows: int) -> list:
    """Re-slice a list of arbitrary-sized row chunks into uniform
    chunk_rows pieces (the last may be short) without building the full
    matrix: peak extra memory is one output chunk."""
    out, buf, buffered = [], [], 0
    for chunk in xs:
        buf.append(chunk)
        buffered += chunk.shape[0]
        while buffered >= chunk_rows:
            take, need = [], chunk_rows
            while need > 0:
                head = buf[0]
                if head.shape[0] <= need:
                    take.append(head)
                    need -= head.shape[0]
                    buf.pop(0)
                else:
                    take.append(head[:need])
                    buf[0] = head[need:]
                    need = 0
            out.append(take[0] if len(take) == 1 else np.concatenate(take))
            buffered -= chunk_rows
    if buffered:
        out.append(buf[0] if len(buf) == 1 else np.concatenate(buf))
    return out
