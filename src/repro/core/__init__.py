"""Core: the paper's contribution — GPU(-style) gradient boosting in JAX.

Public API (XGBoost's two nouns): `DeviceDMatrix` (quantise + compress once,
reuse forever) and `Booster` (fit / update / eval / predict / save / load).

Pipeline (paper Figure 1): quantile generation -> data compression ->
gradient evaluation -> histogram tree construction (AllReduce across
devices) -> prediction, all on-device.
"""
# NOTE: function re-exports must not shadow submodule names (`compress`,
# `predict`, `metrics`, `objectives` stay module-only; use predict_proba /
# compress_matrix aliases).
from repro.core.booster import Booster, BoosterConfig, TrainState
from repro.core.booster import predict_margins, train
from repro.core.booster import predict as predict_proba
from repro.core.compress import (
    ChunkedPackedBins,
    CompressedMatrix,
    PackedBins,
    pack,
    unpack,
)
from repro.core.compress import compress as compress_matrix
from repro.core.dmatrix import DeviceDMatrix, ExternalDMatrix
from repro.core.metrics import Metric, get_metric, register_metric
from repro.core.quantile import StreamingQuantileSketch
from repro.core.objectives import (
    Objective,
    get_objective,
    register_objective,
)
from repro.core.quantile import compute_cuts, quantize
from repro.core.resilience import (
    CheckpointError,
    ChunkIntegrityError,
    DivergenceError,
    NumericError,
    TrainingFault,
)
from repro.core.sampling import StochasticParams, TreeContext
from repro.core.split import SplitParams
from repro.core.tree import Tree, grow_tree
from repro.core.predict import (
    Ensemble,
    concat_ensembles,
    predict_binned,
    predict_binned_packed,
    predict_raw,
    truncate_rounds,
)

__all__ = [
    "Booster",
    "BoosterConfig",
    "CheckpointError",
    "ChunkIntegrityError",
    "ChunkedPackedBins",
    "DivergenceError",
    "NumericError",
    "TrainingFault",
    "DeviceDMatrix",
    "ExternalDMatrix",
    "StreamingQuantileSketch",
    "Metric",
    "Objective",
    "get_metric",
    "get_objective",
    "register_metric",
    "register_objective",
    "TrainState",
    "train",
    "predict_proba",
    "predict_margins",
    "CompressedMatrix",
    "PackedBins",
    "compress_matrix",
    "pack",
    "unpack",
    "compute_cuts",
    "quantize",
    "SplitParams",
    "StochasticParams",
    "Tree",
    "TreeContext",
    "grow_tree",
    "Ensemble",
    "concat_ensembles",
    "truncate_rounds",
    "predict_binned",
    "predict_binned_packed",
    "predict_raw",
]
