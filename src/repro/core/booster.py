"""Gradient boosting driver — the Figure 1 pipeline, end-to-end on device.

Train loop per boosting round (all phases on-accelerator, as in the paper):
  predict (incremental margins) -> gradient evaluation -> quantised-histogram
  tree construction -> margin update.

Feature quantisation + compression happen once up front (Figure 1's left
boxes). The booster never touches the raw float matrix again after
quantisation; training-set prediction runs on bin-space thresholds
(predict_binned), validation on raw thresholds (predict_raw).

Multiclass trains n_classes trees per round on softmax gradients (round-robin
class layout, XGBoost's scheme). Margins are maintained incrementally — each
new tree's leaf outputs are added — rather than re-predicting the whole
ensemble per round, matching the real implementation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import objectives as O
from repro.core import quantile as Q
from repro.core import split as S
from repro.core import tree as T
from repro.core import predict as PR


@dataclass(frozen=True)
class BoosterConfig:
    n_rounds: int = 100
    learning_rate: float = 0.3
    max_depth: int = 6
    max_bins: int = Q.DEFAULT_MAX_BINS
    reg_lambda: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1.0
    objective: str = "reg:squarederror"
    n_classes: int = 1
    growth: str = "depthwise"  # or "lossguide"
    max_leaves: int = 0  # lossguide budget (0 = 2^max_depth)
    use_kernel_histograms: bool = False  # route through the Pallas kernel path
    compress_matrix: bool = True  # paper §2.2 (False = raw int32 bins)

    @property
    def split_params(self) -> S.SplitParams:
        return S.SplitParams(self.reg_lambda, self.gamma, self.min_child_weight)


@dataclass
class TrainState:
    ensemble: PR.Ensemble
    margins: jax.Array  # (n, n_outputs) training margins
    matrix: C.CompressedMatrix
    history: list[dict] = field(default_factory=list)


def _make_round_step(cfg: BoosterConfig, obj: O.Objective, cuts: jax.Array,
                     n_rows: int, bits: int, hist_builder=None):
    """One boosting round as a single jit: gradients -> K trees -> margins."""
    k = obj.n_outputs(cfg.n_classes)
    mb = cfg.max_bins - 1  # missing bin id

    def round_step(packed_or_bins, margins, y, extra):
        if cfg.compress_matrix:
            bins = C.unpack(packed_or_bins, bits, n_rows)
        else:
            bins = packed_or_bins
        gh_all = obj.grad(margins, y, **extra)  # (n, k, 2)
        trees = []
        for c in range(k):
            tr = T.grow_tree(
                bins,
                gh_all[:, c, :],
                cuts,
                cfg.max_depth,
                cfg.max_bins,
                cfg.split_params,
                growth=cfg.growth,
                max_leaves=cfg.max_leaves or 2**cfg.max_depth,
                hist_builder=hist_builder,
            )
            trees.append(tr)
        # Incremental margin update from this round's trees only.
        new_margins = margins
        for c, tr in enumerate(trees):
            ens1 = PR.Ensemble(
                feature=tr.feature[None],
                split_bin=tr.split_bin[None],
                threshold=tr.threshold[None],
                default_left=tr.default_left[None],
                leaf_value=tr.leaf_value[None],
                is_leaf=tr.is_leaf[None],
                n_classes=1,
                base_score=0.0,
            )
            delta = PR.predict_binned(ens1, bins, mb, cfg.max_depth)[:, 0]
            new_margins = new_margins.at[:, c].add(cfg.learning_rate * delta)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        return stacked, new_margins

    return jax.jit(round_step)


def train(
    x: np.ndarray | jax.Array,
    y: np.ndarray | jax.Array,
    cfg: BoosterConfig,
    eval_set: tuple[Any, Any] | None = None,
    group_ids: np.ndarray | None = None,
    verbose_every: int = 0,
    callback: Callable[[int, dict], None] | None = None,
) -> TrainState:
    obj = O.OBJECTIVES[cfg.objective]
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    k = obj.n_outputs(cfg.n_classes)

    # --- Figure 1: generate feature quantiles + data compression ---------
    cuts = Q.compute_cuts(x, cfg.max_bins)
    bins = Q.quantize(x, cuts)
    matrix = C.compress(bins, cuts, cfg.max_bins)
    del x  # the raw matrix is not needed for training anymore

    base = obj.init_base_score(y)
    margins = jnp.full((n, k), base, jnp.float32)
    extra = {"group_ids": jnp.asarray(group_ids)} if group_ids is not None else {}

    hist_builder = None
    if cfg.use_kernel_histograms:
        from repro.kernels import ops as KO

        hist_builder = KO.build_histograms_kernel

    data = matrix.packed if cfg.compress_matrix else bins
    round_step = _make_round_step(cfg, obj, cuts, n, matrix.bits, hist_builder)

    trees_per_class: list = []
    history: list[dict] = []
    t0 = time.perf_counter()
    for r in range(cfg.n_rounds):
        stacked, margins = round_step(data, margins, y, extra)
        trees_per_class.append(stacked)
        if verbose_every and (r % verbose_every == 0 or r == cfg.n_rounds - 1):
            m = float(obj.metric(margins, y))
            rec = {"round": r, f"train_{obj.metric_name}": m,
                   "elapsed_s": time.perf_counter() - t0}
            history.append(rec)
            if callback:
                callback(r, rec)

    # Stack rounds: each `stacked` is a Tree pytree with leading axis k.
    all_trees = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees_per_class)
    ens = PR.Ensemble(
        feature=all_trees.feature,
        split_bin=all_trees.split_bin,
        threshold=all_trees.threshold,
        default_left=all_trees.default_left,
        leaf_value=all_trees.leaf_value,
        is_leaf=all_trees.is_leaf,
        n_classes=k,
        base_score=base,
    )
    ens = _scale_leaves(ens, cfg.learning_rate)
    state = TrainState(ensemble=ens, margins=margins, matrix=matrix, history=history)

    if eval_set is not None:
        xv, yv = eval_set
        mv = predict_margins(state.ensemble, jnp.asarray(xv, jnp.float32), cfg.max_depth)
        state.history.append(
            {"round": cfg.n_rounds - 1,
             f"valid_{obj.metric_name}": float(obj.metric(mv, jnp.asarray(yv, jnp.float32)))}
        )
    return state


def _scale_leaves(ens: PR.Ensemble, eta: float) -> PR.Ensemble:
    """Bake the learning rate into stored leaf values (margins during
    training already used eta; the stored ensemble must match)."""
    return ens._replace(leaf_value=ens.leaf_value * eta)


def predict_margins(ens: PR.Ensemble, x: jax.Array, max_depth: int) -> jax.Array:
    return PR.predict_raw(ens, x, max_depth)


def predict(ens: PR.Ensemble, x: jax.Array, max_depth: int, objective: str) -> jax.Array:
    obj = O.OBJECTIVES[objective]
    return obj.transform(predict_margins(ens, jnp.asarray(x, jnp.float32), max_depth))
