"""Gradient boosting — the Figure 1 pipeline behind a two-noun public API.

The API is organised around XGBoost's two nouns (Chen & Guestrin 2016):

  * `DeviceDMatrix` (dmatrix.py) — quantise + compress ONCE, reuse forever.
  * `Booster` — the single entry point for `fit(dtrain, evals=[...])`,
    `update(dtrain, n_rounds)` (warm-start continued training),
    `predict(x | DeviceDMatrix)`, `eval(dmat)`, `save`/`load`.

The model is self-describing: a `Booster` checkpoint carries its config,
cut points, base score and best_iteration, so `Booster.load(path).predict(x)`
needs no caller-supplied `max_depth` / `objective` / `n_classes`.

Training is ONE compiled program: a jax.lax.scan over boosting rounds whose
ys-stack is the preallocated (n_rounds * k, arena) ensemble arena. Per round
(all phases on-accelerator, as in the paper): predict (incremental margins)
-> gradient evaluation -> quantised-histogram tree construction -> margin
update. Evaluation sets ride INSIDE the scan: each eval set is a
`DeviceDMatrix` quantised with the training cuts, its margins are maintained
incrementally next to the training margins, and EVERY requested eval metric
(`fit(eval_metric=[...], custom_metric=...)`) comes out as a scan ys-stack
entry — no per-round host round trips. With `early_stopping_rounds=e` the
scan runs in compiled chunks of e rounds with one host-side check per chunk
(overtraining bounded by < 2e rounds), stopping on the LAST metric of the
LAST eval set in that metric's declared direction, and the stored ensemble
is truncated to `best_iteration + 1` rounds.

Objectives and metrics are pluggable registries (DESIGN.md §10):
`fit(obj=...)` traces custom `(margins, y) -> (g, h)` callables straight
into the scan, and the compiled-fn cache is keyed by the resolved
Objective/Metric objects, so repeat fits with the same plugins reuse the
compiled program.

Feature quantisation + compression happen once, at DeviceDMatrix
construction (Figure 1's left boxes). With compress_matrix=True the
bit-packed words are the *only* training-set representation (paper §2.2,
DESIGN.md §2): histograms are built from the packed words, row
repartitioning and training-set prediction extract the needed feature column
from the words on the fly. The dense (n, f) int32 bins array is never
materialised again after quantisation.

Multiclass trains n_classes trees per round on softmax gradients (round-robin
class layout, XGBoost's scheme). The multi-device path (distributed.py) is a
strategy behind the same `Booster.fit(dtrain, mesh=...)` signature and
returns the identical object.

The old `train()` / `predict()` functions survive as thin deprecated shims
over this API.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import metrics as M
from repro.core import objectives as O
from repro.core import quantile as Q
from repro.core import resilience as RES
from repro.core import sampling as SMP
from repro.core import split as S
from repro.core import tree as T
from repro.core import predict as PR
from repro.core.dmatrix import DeviceDMatrix, ExternalDMatrix, cuts_equal
from repro.testing import faults as FA


@dataclass(frozen=True)
class BoosterConfig:
    n_rounds: int = 100
    learning_rate: float = 0.3
    max_depth: int = 6
    max_bins: int = Q.DEFAULT_MAX_BINS
    reg_lambda: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1.0
    objective: str = "reg:squarederror"
    n_classes: int = 1
    quantile_alpha: float = 0.5  # reg:quantile pinball target
    growth: str = "depthwise"  # or "lossguide"
    max_leaves: int = 0  # lossguide budget (0 = 2^max_depth)
    use_kernel_histograms: bool = False  # route through the Pallas kernel path
    compress_matrix: bool = True  # paper §2.2 (False = raw int32 bins)
    hist_block_rows: int = 65536  # packed-histogram fallback dense-tile bound
    # Stochastic regularisers + constraints (DESIGN.md §12). All-default
    # values select the exact deterministic pre-stochastic program.
    subsample: float = 1.0  # per-tree row fraction (static round(n*s) buffer)
    colsample_bytree: float = 1.0  # per-tree feature fraction
    colsample_bylevel: float = 1.0  # per-level fraction OF the tree's set
    colsample_bynode: float = 1.0  # per-node fraction OF the level's set
    monotone_constraints: tuple | None = None  # per-feature {-1, 0, +1}
    # GOSS (DESIGN.md §17): sampling_method="goss" keeps the top_rate
    # fraction of rows by |gradient| and uniformly samples other_rate of
    # the rest per tree, reweighting the sampled remainder by
    # (1 - top_rate) / other_rate. Mutually exclusive with subsample < 1.
    sampling_method: str = "uniform"  # or "goss"
    top_rate: float = 0.2  # GOSS: kept fraction of largest-|g| rows
    other_rate: float = 0.1  # GOSS: uniformly sampled fraction of the rest
    seed: int = 0  # PRNG seed; keys fold as (seed, round, class, site)
    # Numeric sentinel (DESIGN.md §13): "off" keeps the exact pre-sentinel
    # compiled program; otherwise a per-round finite flag on grads/hessians/
    # leaf weights rides the ys-stack and the host applies the policy at
    # chunk granularity — "raise" (NumericError), "warn_skip" (zero the
    # offending trees so later margins stay clean), "clamp" (nan_to_num +
    # clip gradients before tree growth).
    numeric_check: str = "off"

    def __post_init__(self):
        RES.validate_numeric_policy(self.numeric_check)
        mc = self.monotone_constraints
        if mc is not None:
            mc = tuple(int(c) for c in mc)  # hashable (lists/arrays coerce)
            object.__setattr__(self, "monotone_constraints", mc)
            if any(c not in (-1, 0, 1) for c in mc):
                raise ValueError(
                    f"monotone_constraints must be -1/0/+1, got {mc}"
                )
        for knob in ("subsample", "colsample_bytree", "colsample_bylevel",
                     "colsample_bynode"):
            v = getattr(self, knob)
            if not 0.0 < v <= 1.0:
                raise ValueError(f"{knob} must be in (0, 1], got {v}")
        if self.sampling_method not in ("uniform", "goss"):
            raise ValueError(
                f"sampling_method must be 'uniform' or 'goss', "
                f"got {self.sampling_method!r}"
            )
        if self.sampling_method == "goss":
            for knob in ("top_rate", "other_rate"):
                v = getattr(self, knob)
                if not 0.0 < v < 1.0:
                    raise ValueError(
                        f"{knob} must be in (0, 1) with sampling_method="
                        f"'goss', got {v}"
                    )
            if self.top_rate + self.other_rate > 1.0:
                raise ValueError(
                    f"top_rate + other_rate must be <= 1.0, got "
                    f"{self.top_rate} + {self.other_rate}"
                )
            if self.subsample < 1.0:
                raise ValueError(
                    "sampling_method='goss' replaces uniform row "
                    "subsampling — leave subsample at 1.0"
                )

    @property
    def split_params(self) -> S.SplitParams:
        return S.SplitParams(self.reg_lambda, self.gamma, self.min_child_weight)


def _tree_margin_delta(cfg: BoosterConfig, tr: T.Tree, data) -> jax.Array:
    """One tree's margin contribution (learning rate already applied) over
    all rows, straight from the quantised representation (packed, chunked
    or dense) — no Ensemble construction."""
    mb = cfg.max_bins - 1
    if getattr(data, "is_streamed", False):
        # Streaming executor (core/stream.py): per-chunk traversal over the
        # host-resident stack, same jitted kernel as the chunked scan body.
        delta = data.traverse_tree(tr, mb, cfg.max_depth)
    elif isinstance(data, C.ChunkedPackedBins):
        delta = PR.traverse_tree_chunked(
            tr.feature, tr.split_bin, tr.default_left, tr.leaf_value, tr.is_leaf,
            data.packed, data.bits, data.chunk_rows, data.n_rows, mb,
            cfg.max_depth,
        )
    elif isinstance(data, C.PackedBins):
        delta = PR.traverse_tree_packed(
            tr.feature, tr.split_bin, tr.default_left, tr.leaf_value, tr.is_leaf,
            data.packed, data.bits, data.n_rows, mb, cfg.max_depth,
        )
    else:
        delta = PR.traverse_tree_binned(
            tr.feature, tr.split_bin, tr.default_left, tr.leaf_value, tr.is_leaf,
            data, mb, cfg.max_depth,
        )
    return cfg.learning_rate * delta


def _apply_stacked_trees(cfg: BoosterConfig, stacked: T.Tree, data,
                         margins: jax.Array) -> jax.Array:
    """Add one round's k stacked trees (unscaled leaves, leading axis k) to
    margins — the training-set margin update of the round step, eval-set
    margins inside the scan, and the distributed per-round loop all route
    through here.

    The update is ONE full-array add of an optimization_barrier'd update
    stack (each margin column receives exactly one tree's contribution, so
    this is elementwise-identical to per-class updates). The barrier is
    load-bearing for external memory: without it XLA may contract
    `margins + lr * delta` into an FMA — or rematerialise tree arithmetic
    inside the fused update — differently depending on the data
    representation's producer graph, silently breaking the bit-identity
    between the in-memory and chunked paths (DESIGN.md §11)."""
    k = stacked.feature.shape[0]
    if getattr(data, "is_streamed", False):
        # Streamed executor: the traversals run eagerly per chunk, but the
        # scale-and-add must compile as ONE jitted program. XLA's CPU
        # emitter contracts `margins + lr * delta` into a single-rounding
        # FMA inside compiled programs — optimization_barrier does not
        # block the instruction-level contraction — while eager op-by-op
        # dispatch rounds the multiply and the add separately. Compiling
        # the same mul/barrier/add subgraph standalone reproduces the
        # scan body's rounding exactly (the bit-identity tests pin this).
        mb = cfg.max_bins - 1
        deltas = jnp.stack(
            [
                data.traverse_tree(jax.tree.map(lambda a: a[c], stacked),
                                   mb, cfg.max_depth)
                for c in range(k)
            ],
            axis=1,
        )
        return _streamed_margin_update(margins, deltas, cfg.learning_rate)
    updates = jnp.stack(
        [
            _tree_margin_delta(cfg, jax.tree.map(lambda a: a[c], stacked), data)
            for c in range(k)
        ],
        axis=1,
    )
    return margins + jax.lax.optimization_barrier(updates)


@functools.partial(jax.jit, static_argnames=("lr",))
def _streamed_margin_update(margins: jax.Array, deltas: jax.Array,
                            lr: float) -> jax.Array:
    """The margin update's arithmetic tail (scale, barrier, add) compiled
    standalone — the streamed twin of the in-scan update (see the streamed
    branch of _apply_stacked_trees for why this must be jitted)."""
    return margins + jax.lax.optimization_barrier(jnp.float32(lr) * deltas)


def _round_step_fn(cfg: BoosterConfig, obj: O.Objective, hist_builder=None):
    """One boosting round: gradients -> K trees -> margins. Pure (not jit'd
    on its own) so it can be the body of the training scan. `cuts` is an
    argument, not a closure, so compiled train functions can be cached by
    static config alone and reused across DeviceDMatrices.

    With stochastic knobs active (DESIGN.md §12) the per-round PRNG key
    `rkey` (folded from (seed, round) by the scan body) is folded per class
    tree and drives row/column sampling INSIDE the compiled program; the
    per-tree row buffer is compacted statically so a subsampled round does
    proportionally less scatter work. Kernel hist builders aren't
    row-subset aware, so they fall back to masked-mode subsampling.

    With cfg.numeric_check != "off" the step returns a third element: a
    scalar bool `ok` (all grads/hessians/leaf values/margins finite this
    round) that rides the scan's ys-stack for host-side policy handling.
    The default config keeps the exact two-tuple return and traced program.
    The nan_grad fault site (repro.testing.faults) is read at trace time —
    callers that cache compiled programs key on faults.trace_key."""
    k = obj.n_outputs(cfg.n_classes)
    stoch = SMP.stochastic_params(cfg)
    compact_rows = hist_builder is None
    sentinel = cfg.numeric_check != "off"
    fault = FA.active("nan_grad")

    def round_step(data, margins, y, extra, cuts, rkey=None, round_idx=None):
        if stoch is not None and rkey is None:
            raise ValueError(
                "this config has stochastic knobs (subsample/colsample/"
                "monotone or non-default seed use) — the round step needs "
                "a per-round PRNG key (rkey)"
            )
        gh_all = obj.grad(margins, y, **extra)  # (n, k, 2)
        if fault is not None and round_idx is not None:
            bad_round = int(fault.payload.get("round", 0))
            bad_val = float(fault.payload.get("value", np.nan))
            gh_all = jnp.where(jnp.equal(round_idx, bad_round),
                               jnp.full_like(gh_all, bad_val), gh_all)
        gh_raw = gh_all
        if cfg.numeric_check == "clamp":
            gh_all = RES.clamp_gradients(gh_all)
        n_features = getattr(data, "n_features", None)
        if n_features is None:  # dense (n, f) bins array
            n_features = data.shape[1]
        trees = []
        for c in range(k):
            gh_c = gh_all[:, c, :]
            ctx = None
            if stoch is not None:
                ctx, gh_c = SMP.make_tree_context(
                    stoch, jax.random.fold_in(rkey, c), gh_c, n_features,
                    compact=compact_rows,
                )
            tr = T.grow_tree(
                data,
                gh_c,
                cuts,
                cfg.max_depth,
                cfg.max_bins,
                cfg.split_params,
                growth=cfg.growth,
                max_leaves=cfg.max_leaves or 2**cfg.max_depth,
                hist_builder=hist_builder,
                hist_block_rows=cfg.hist_block_rows,
                ctx=ctx,
            )
            # Materialise the tree arrays before they fan out to the margin
            # update: without the barrier XLA may rematerialise leaf-value
            # arithmetic inside the fused traversal, with representation-
            # dependent FMA contraction (DESIGN.md §11).
            trees.append(jax.lax.optimization_barrier(tr))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        # Trees only depend on round-start gradients, so the k margin
        # columns update in one barriered add (see _apply_stacked_trees).
        new_margins = _apply_stacked_trees(cfg, stacked, data, margins)
        if not sentinel:
            return stacked, new_margins
        ok = RES.finite_flags(gh_raw, stacked.leaf_value, new_margins)
        if cfg.numeric_check == "warn_skip":
            # Neutralise the offending trees: zero leaves (the tree adds
            # nothing to any margin), -inf gains (importances ignore it),
            # and carry the round-start margins forward unpolluted.
            stacked = stacked._replace(
                leaf_value=jnp.where(ok, stacked.leaf_value,
                                     jnp.zeros_like(stacked.leaf_value)),
                gain=jnp.where(ok, stacked.gain,
                               jnp.full_like(stacked.gain, -jnp.inf)),
            )
            new_margins = jnp.where(ok, new_margins, margins)
        return stacked, new_margins, ok

    return round_step


def _make_round_step(cfg: BoosterConfig, obj: O.Objective, cuts: jax.Array,
                     hist_builder=None):
    """Round step with `cuts` bound (the jaxpr-discipline tests and phase
    benchmarks inspect this closed form). Stochastic configs must pass the
    per-round key: `round_step(data, margins, y, extra, rkey=...)`."""
    step = _round_step_fn(cfg, obj, hist_builder)

    def round_step(data, margins, y, extra, rkey=None, round_idx=None):
        return step(data, margins, y, extra, cuts, rkey, round_idx)

    return round_step


# Compiled train functions, keyed by static config + objective + metric
# tuple (cuts/data are traced arguments). Objective and Metric are hashable
# NamedTuples and registry lookups return singletons — a refit with the same
# config, same (possibly custom) objective and same eval metrics reuses the
# compiled program as long as shapes match, so the quantise-once API isn't
# eaten by per-fit recompilation (DESIGN.md §10).
_TRAIN_FN_CACHE: dict = {}


def _make_train_fn(cfg: BoosterConfig, obj: O.Objective, cuts: jax.Array,
                   hist_builder, metrics: tuple, track_metric: bool,
                   n_rounds: int | None = None):
    """The whole training run as one jit: scan over rounds.

    Returns a function
      (data, margins0, y, extra, eval_data, eval_margins0, eval_y,
       eval_extra) ->
      (final_margins, stacked_trees (n_rounds, k, arena...),
       train_metrics tuple-per-metric of (n_rounds,), final_eval_margins,
       eval_metrics tuple-per-set of tuple-per-metric of (n_rounds,))

    With stochastic knobs in cfg the returned function instead takes
      (base_key, start_round, data, margins0, y, extra, ...)
    where start_round is the ABSOLUTE index of the first round — the scan
    folds (base_key, round) per step, so ES chunks and update()
    continuation replay one long fit's key stream (see _run_rounds'
    run_chunk, the only internal caller).

    Eval sets ride inside the scan: eval_data is a tuple of PackedBins
    (quantised with the TRAINING cuts), their margins are carried next to
    the training margins, and EVERY requested metric of every eval set
    lands in its own ys-stack entry — multi-metric per-round history with
    zero host round trips.

    Every variant returns a 6-tuple whose last element is the numeric
    sentinel's per-round flags: a (length,) bool array when
    cfg.numeric_check != "off", else the empty pytree () (no ys entry, so
    the default compiled program is unchanged). An armed nan_grad fault
    (repro.testing.faults) is baked in at trace time and keyed into the
    cache, and forces the start_round-taking signature so the injection
    round is absolute.
    """
    length = cfg.n_rounds if n_rounds is None else n_rounds
    fault_key = FA.trace_key("nan_grad")
    key = (cfg, obj, hist_builder, metrics, track_metric, length, fault_key)
    jitted = _TRAIN_FN_CACHE.get(key)
    stoch = SMP.stochastic_params(cfg)
    sentinel = cfg.numeric_check != "off"
    if jitted is None:
        round_step = _round_step_fn(cfg, obj, hist_builder)

        def _make_body(data, y, extra, eval_data, eval_y, eval_extra, cuts,
                       rkey_of, ridx_of):
            def body(carry, x):
                margins, ev = carry
                out = round_step(data, margins, y, extra, cuts, rkey_of(x),
                                 ridx_of(x))
                if sentinel:
                    stacked, new_margins, ok = out
                else:
                    (stacked, new_margins), ok = out, ()
                new_ev, ev_metrics = [], []
                for pb, em, ey, ex in zip(eval_data, ev, eval_y, eval_extra):
                    em = _apply_stacked_trees(cfg, stacked, pb, em)
                    new_ev.append(em)
                    ev_metrics.append(tuple(
                        m.fn(em, ey, **ex).astype(jnp.float32)
                        for m in metrics
                    ))
                tr_metrics = tuple(
                    m.fn(new_margins, y, **extra).astype(jnp.float32)
                    for m in metrics
                ) if track_metric else ()
                return (new_margins, tuple(new_ev)), (stacked, tr_metrics,
                                                      tuple(ev_metrics), ok)
            return body

        def _scan(body, margins0, eval_margins0, xs):
            (margins, ev), (all_trees, tr_metrics, ev_metrics, flags) = \
                jax.lax.scan(body, (margins0, tuple(eval_margins0)), xs,
                             length=length if xs is None else None)
            return margins, all_trees, tr_metrics, ev, ev_metrics, flags

        if stoch is not None:
            # Stochastic variant: the base PRNG key and the ABSOLUTE first
            # round index ride in as traced args; the scan folds
            # (key, round) per step so ES chunking and update() continuation
            # replay the identical key stream as one long fit.
            @jax.jit
            def train_fn(cuts, base_key, start_round, data, margins0, y,
                         extra, eval_data=(), eval_margins0=(), eval_y=(),
                         eval_extra=()):
                body = _make_body(
                    data, y, extra, eval_data, eval_y, eval_extra, cuts,
                    lambda r: jax.random.fold_in(base_key, r), lambda r: r,
                )
                xs = start_round + jnp.arange(length, dtype=jnp.int32)
                return _scan(body, margins0, eval_margins0, xs)
        elif fault_key is not None:
            # Deterministic config with an armed nan_grad fault: the scan
            # still needs absolute round indices so the fault fires at its
            # configured round regardless of chunk boundaries.
            @jax.jit
            def train_fn(cuts, start_round, data, margins0, y, extra,
                         eval_data=(), eval_margins0=(), eval_y=(),
                         eval_extra=()):
                body = _make_body(data, y, extra, eval_data, eval_y,
                                  eval_extra, cuts, lambda _: None,
                                  lambda r: r)
                xs = start_round + jnp.arange(length, dtype=jnp.int32)
                return _scan(body, margins0, eval_margins0, xs)
        else:
            @jax.jit
            def train_fn(cuts, data, margins0, y, extra, eval_data=(),
                         eval_margins0=(), eval_y=(), eval_extra=()):
                body = _make_body(data, y, extra, eval_data, eval_y,
                                  eval_extra, cuts, lambda _: None,
                                  lambda _: None)
                return _scan(body, margins0, eval_margins0, None)

        jitted = _TRAIN_FN_CACHE[key] = train_fn
    return functools.partial(jitted, cuts)


def _scale_leaves(ens: PR.Ensemble, eta: float) -> PR.Ensemble:
    """Bake the learning rate into stored leaf values (margins during
    training already used eta; the stored ensemble must match)."""
    return ens._replace(leaf_value=ens.leaf_value * eta)


def _stack_to_ensemble(all_trees: T.Tree, k: int,
                       base_score: float) -> PR.Ensemble:
    """Reshape a scan ys-stack of trees (rounds, k, arena...) into an
    Ensemble in XGBoost's round-robin (rounds * k, arena) layout."""
    arena = all_trees.feature.shape[-1]
    return PR.Ensemble(
        feature=all_trees.feature.reshape(-1, arena),
        split_bin=all_trees.split_bin.reshape(-1, arena),
        threshold=all_trees.threshold.reshape(-1, arena),
        default_left=all_trees.default_left.reshape(-1, arena),
        leaf_value=all_trees.leaf_value.reshape(-1, arena),
        is_leaf=all_trees.is_leaf.reshape(-1, arena),
        gain=all_trees.gain.reshape(-1, arena),
        n_classes=k,
        base_score=base_score,
    )


class Booster:
    """Self-describing gradient-boosted model (XGBoost's `Booster` noun).

    Construct with a `BoosterConfig` (or keyword overrides), then:

        bst = Booster(n_rounds=100, objective="binary:logistic")
        bst.fit(dtrain, evals=[(dvalid, "valid")], early_stopping_rounds=10)
        p = bst.predict(x_new)          # numpy / jax array / DeviceDMatrix
        bst.save(path); Booster.load(path).predict(x_new)  # no extra args

    Both the objective and the eval metrics are pluggable (DESIGN.md §10):
    `fit(obj=...)` accepts a registry name, an `objectives.register_objective`
    result, or a bare `(margins, y) -> (g, h)` callable traced straight into
    the compiled scan; `fit(eval_metric=[...], custom_metric=...)` evaluates
    any number of metrics per round inside the scan, and early stopping is
    keyed to the LAST metric of the LAST eval set with the direction taken
    from that metric's `maximize` flag (XGBoost's convention).

    After fit: `ensemble` (stacked tree arenas), `history` (per-round eval
    records keyed `{set}_{metric}`), `best_iteration`/`best_score` (when
    early stopping ran), `n_rounds_trained`. `update(dtrain, n)` continues
    training by re-entering the scan with the existing margins.
    """

    def __init__(self, cfg: BoosterConfig | None = None, **params):
        if cfg is None:
            cfg = BoosterConfig(**params)
        elif params:
            cfg = dataclasses.replace(cfg, **params)
        self.cfg = cfg
        self.ensemble: PR.Ensemble | None = None
        self.cuts: jax.Array | None = None
        self.base_score: float = 0.0
        self.history: list[dict] = []
        self.best_iteration: int | None = None
        self.best_score: float | None = None
        self.n_rounds_trained: int = 0
        self._obj: O.Objective | None = None  # fit(obj=...) override
        self._metrics: tuple[M.Metric, ...] | None = None
        self._margins: jax.Array | None = None  # training margins cache
        self._train_dmat: DeviceDMatrix | None = None  # cache key for _margins
        # Resilience record (DESIGN.md §13): rounds whose trees were zeroed
        # under numeric_check="warn_skip", and a log of degradations the
        # runtime absorbed (OOM fallback, failed checkpoint writes, clamps).
        self.skipped_rounds: list[int] = []
        self.resilience_events: list[dict] = []
        # Per-fit communication profile of the latest mesh= fit (DESIGN.md
        # §15): wire bytes/round, collective calls, compression fallbacks.
        self.comm_stats: dict | None = None

    # --- small surface -----------------------------------------------------
    @property
    def obj(self) -> O.Objective:
        if self._obj is not None and self._obj.name == self.cfg.objective:
            return self._obj
        return O.get_objective(self.cfg.objective)

    @property
    def margins(self) -> jax.Array | None:
        """Training margins of the last fit/update (TrainState compat)."""
        return self._margins

    @property
    def matrix(self) -> C.CompressedMatrix | None:
        """Compressed matrix of the last training set (TrainState compat).
        None after external-memory fits (no single flat matrix exists)."""
        return getattr(self._train_dmat, "matrix", None)

    def num_boosted_rounds(self) -> int:
        return self.n_rounds_trained

    def _require_fitted(self):
        if self.ensemble is None:
            raise RuntimeError("Booster is not fitted yet — call fit() first")

    # --- training ----------------------------------------------------------
    def _resolve_metrics(self, eval_metric, custom_metric
                         ) -> tuple[M.Metric, ...]:
        """eval_metric: one spec or a sequence of specs (registry names,
        Metric objects, callables, (name, fn[, maximize]) tuples);
        custom_metric: a single extra spec appended LAST, so with early
        stopping it drives the stop (XGBoost's custom_metric semantics).
        Defaults to the objective's metric."""
        metrics = M.resolve_metrics(eval_metric)
        if custom_metric is not None:
            metrics = metrics + (M.get_metric(custom_metric),)
        if not metrics:
            metrics = (M.get_metric(self.obj.default_metric),)
        return metrics

    def _dataset_extra(self, dmat: DeviceDMatrix) -> dict:
        """Keywords forwarded to grad/metric fns for one dataset: config
        scalars (traced, so e.g. quantile_alpha changes don't recompile)
        plus the dataset's query groups when present."""
        extra = dict(O.config_kwargs(self.cfg))
        if dmat.group_ids is not None:
            extra["group_ids"] = dmat.group_ids
        return extra

    def fit(
        self,
        dtrain: DeviceDMatrix,
        evals: Sequence = (),
        *,
        obj=None,
        eval_metric=None,
        custom_metric=None,
        early_stopping_rounds: int | None = None,
        verbose_every: int = 0,
        callback: Callable[[int, dict], None] | None = None,
        mesh=None,
        data_axes: Sequence[str] = ("data",),
        collective="psum",
        compression: str | None = None,
        comm_tolerance: float = 0.05,
        checkpoint_every: int | None = None,
        checkpoint_path: str | None = None,
        on_oom: str = "raise",
    ) -> "Booster":
        """Train cfg.n_rounds rounds from scratch on a DeviceDMatrix or an
        ExternalDMatrix (external-memory path: the chunk-stacked compressed
        representation trains through the same compiled scan, bit-identical
        to the in-memory path on the same data — DESIGN.md §11).

        evals: sequence of (DeviceDMatrix, name) pairs (or bare matrices;
          ExternalDMatrix eval sets work too)
          built with `ref=dtrain`; metrics are computed per round inside the
          compiled scan. With `early_stopping_rounds`, the LAST metric of
          the LAST eval set drives stopping (direction = that metric's
          `maximize`) and the ensemble is truncated to best_iteration+1.
        obj: override cfg.objective — a registry name, an Objective (e.g.
          from objectives.register_objective), or a bare callable
          `(margins, y) -> (g, h)` traced into the compiled scan.
        eval_metric: metric spec or list of specs (names like "rmse"/"auc"/
          "ndcg@10", Metric objects, callables) evaluated per round on every
          eval set; defaults to the objective's default metric.
        custom_metric: one extra metric spec (callable or (name, fn[,
          maximize]) tuple), appended after eval_metric.
        mesh: optional jax Mesh — rows are sharded over `data_axes` and
          histograms combined per level (paper Algorithm 1); same Booster out.
        collective: histogram-reduction strategy with mesh= — a registry name
          ("psum" | "ring" | "hier"), a repro.dist.Collective subclass, or an
          instance (DESIGN.md §15). f32 mode trains identically to
          single-device fits for every strategy.
        compression: None | "f16" | "q16" — compressed per-level histogram
          bin sums with an on-device max-error check that falls back to
          exact f32 when `comm_tolerance` (relative) is exceeded. Per-fit
          wire accounting lands on `self.comm_stats`.
        checkpoint_every: write an atomic resumable snapshot every this many
          rounds to `checkpoint_path` (DESIGN.md §13). `Booster.resume(path,
          dtrain)` continues a killed fit to a bit-identical booster.
        checkpoint_path: snapshot file; with checkpoint_every unset, only a
          final complete checkpoint is written there.
        on_oom: "raise" (default) or "external" — on device RESOURCE_EXHAUSTED
          the fit is retried through an ExternalDMatrix with halved
          chunk_rows (repeatedly, until it fits or chunks hit one row).
        """
        if on_oom not in ("raise", "external"):
            raise ValueError(
                f"on_oom must be 'raise' or 'external', got {on_oom!r}"
            )

        def reset():
            self.ensemble = None
            self.history = []
            self.best_iteration = None
            self.best_score = None
            self.n_rounds_trained = 0
            self._margins = None
            self._train_dmat = None
            self.skipped_rounds = []

        reset()
        self.resilience_events = []
        if obj is not None:
            resolved = O.as_objective(obj)
            self._obj = resolved
            self.cfg = dataclasses.replace(self.cfg, objective=resolved.name)
        if dtrain.label is None:
            raise ValueError("dtrain must be constructed with label= to fit")
        self._metrics = self._resolve_metrics(eval_metric, custom_metric)
        self.cuts = dtrain.cuts
        self.base_score = float(self.obj.init_base_score(
            dtrain.label, **O.config_kwargs(self.cfg)
        ))
        dmat = dtrain
        while True:
            try:
                self._run_rounds(dmat, self.cfg.n_rounds, evals,
                                 early_stopping_rounds, verbose_every,
                                 callback, mesh, data_axes,
                                 checkpoint_every=checkpoint_every,
                                 checkpoint_path=checkpoint_path,
                                 collective=collective,
                                 compression=compression,
                                 comm_tolerance=comm_tolerance)
                return self
            except Exception as exc:
                if on_oom != "external" or not RES.is_oom(exc):
                    raise
                dmat = self._oom_fallback_matrix(dmat, exc)
                reset()  # drop any partial history before the re-fit

    def _oom_fallback_matrix(self, dmat, exc):
        """Next, smaller-footprint training matrix after a device OOM: an
        in-memory matrix degrades to external memory at half its rows per
        chunk; an external matrix halves chunk_rows again. Re-raises the
        OOM when chunks can no longer shrink."""
        if isinstance(dmat, ExternalDMatrix):
            new_rows = dmat.chunk_rows // 2
            if new_rows < 1:
                raise exc
            nd = dmat.rechunk(new_rows)
        else:
            nd = ExternalDMatrix.from_dmatrix(
                dmat, chunk_rows=max(dmat.n_rows // 2, 1)
            )
        warnings.warn(
            f"device OOM during fit ({str(exc).splitlines()[0][:120]}); "
            f"retrying via external-memory training with "
            f"chunk_rows={nd.chunk_rows} (on_oom='external')"
        )
        self.resilience_events.append({
            "event": "oom_fallback",
            "chunk_rows": int(nd.chunk_rows),
            "error": str(exc)[:200],
        })
        return nd

    def update(
        self,
        dtrain: DeviceDMatrix,
        n_rounds: int,
        evals: Sequence = (),
        *,
        eval_metric=None,
        custom_metric=None,
        early_stopping_rounds: int | None = None,
        verbose_every: int = 0,
        callback: Callable[[int, dict], None] | None = None,
        mesh=None,
        data_axes: Sequence[str] = ("data",),
        collective="psum",
        compression: str | None = None,
        comm_tolerance: float = 0.05,
        checkpoint_every: int | None = None,
        checkpoint_path: str | None = None,
    ) -> "Booster":
        """Continue training for n_rounds more rounds (warm start).

        Re-enters the scan with the existing margins: if `dtrain` is the same
        DeviceDMatrix the booster last trained on, the cached margins are
        reused and the continuation is bit-identical to a single longer fit;
        otherwise margins are rebuilt by on-device binned prediction. The
        objective is fixed at fit time; metrics may be changed per update.
        """
        self._require_fitted()
        if dtrain.label is None:
            raise ValueError("dtrain must be constructed with label= to update")
        if not self._cuts_match(dtrain.cuts):
            raise ValueError(
                "dtrain was quantised with different cuts than this booster; "
                "build it with ref= the original training matrix"
            )
        if eval_metric is not None or custom_metric is not None \
                or self._metrics is None:
            self._metrics = self._resolve_metrics(eval_metric, custom_metric)
        self._run_rounds(dtrain, n_rounds, evals, early_stopping_rounds,
                         verbose_every, callback, mesh, data_axes,
                         checkpoint_every=checkpoint_every,
                         checkpoint_path=checkpoint_path,
                         collective=collective, compression=compression,
                         comm_tolerance=comm_tolerance)
        return self

    @classmethod
    def resume(
        cls,
        path: str,
        dtrain,
        evals: Sequence = (),
        *,
        callback: Callable[[int, dict], None] | None = None,
        verbose_every: int | None = None,
        checkpoint_every: int | None = None,
        checkpoint_path: str | None = None,
        mesh=None,
        data_axes: Sequence[str] = ("data",),
        collective="psum",
        compression: str | None = None,
        comm_tolerance: float = 0.05,
    ) -> "Booster":
        """Continue a killed fit from an in-run checkpoint (DESIGN.md §13).

        `dtrain` (and `evals`, same sets in the same order) must be rebuilt
        exactly as for the original fit — the checkpoint carries the model,
        margins, ES state and the absolute-round PRNG anchor, but not the
        data. The resumed booster is bit-identical (trees, margins,
        predictions) to one from an uninterrupted fit: margins re-enter the
        scan exactly as carried, the stochastic key stream folds absolute
        round indices, and ES stop checks fire at the same fit-relative
        boundaries.

        Checkpointing continues with the original cadence to the same file
        by default (override with checkpoint_every/checkpoint_path); the
        file is rewritten as a completed checkpoint when the fit finishes.
        """
        from repro.checkpoint import io as CIO

        bst, rs = CIO.load_booster_with_resume(path)
        if rs is None:
            raise CIO.CheckpointError(
                f"{path} checkpoints a COMPLETED fit (no resume section); "
                "use Booster.load() to load it, or update() to train further"
            )
        try:
            bst._metrics = tuple(
                M.get_metric(n) for n in rs["metric_names"]
            ) or None
        except Exception as exc:
            raise ValueError(
                f"cannot resolve checkpointed eval metrics "
                f"{list(rs['metric_names'])}: {exc}. Re-register custom "
                "metrics (metrics.register_metric) before resuming."
            ) from exc
        if dtrain.label is None:
            raise ValueError("dtrain must be constructed with label= to resume")
        if not bst._cuts_match(dtrain.cuts):
            raise ValueError(
                "dtrain was quantised with different cuts than the "
                "checkpointed fit; rebuild it from the same data with the "
                "same max_bins (or with ref= the original matrix)"
            )
        evals_n = bst._normalise_evals(evals, dtrain)
        names = [n for _, n in evals_n]
        want = [str(n) for n in rs["eval_names"]]
        if names != want:
            raise ValueError(
                f"resume requires the original fit's eval sets in order: "
                f"expected {want}, got {names}"
            )
        remaining = int(rs["target"]) - int(rs["rounds_done"])
        if remaining <= 0:
            return bst
        ve = int(rs.get("verbose_every", 0)) if verbose_every is None \
            else verbose_every
        ck = (int(rs.get("checkpoint_every", 0)) or None) \
            if checkpoint_every is None else checkpoint_every
        cpath = checkpoint_path if checkpoint_path is not None else path
        es = int(rs.get("early_stopping_rounds", 0)) or None
        bst._run_rounds(dtrain, remaining, evals_n, es, ve, callback, mesh,
                        tuple(data_axes), checkpoint_every=ck,
                        checkpoint_path=cpath, resume_state=rs,
                        collective=collective, compression=compression,
                        comm_tolerance=comm_tolerance)
        return bst

    def _cuts_match(self, cuts: jax.Array) -> bool:
        return cuts_equal(self.cuts, cuts)

    def _initial_margins(self, dmat) -> jax.Array:
        """Margins to (re-)enter training with: base score if unfitted, else
        on-device binned prediction of the current ensemble."""
        k = self.obj.n_outputs(self.cfg.n_classes)
        if self.ensemble is None:
            return jnp.full((dmat.n_rows, k), self.base_score, jnp.float32)
        if isinstance(dmat, ExternalDMatrix):
            if dmat.resolved_paging() == "stream":
                # Never page the whole stack in just to rebuild margins:
                # stream chunks through the fused traversal instead
                # (bit-identical to the per-tree chunked scan).
                return self._predict_margins_external(self.ensemble, dmat)
            cpb = dmat.packed_bins()
            return PR.predict_binned_chunked(
                self.ensemble, cpb.packed, cpb.bits, cpb.chunk_rows,
                cpb.n_rows, self.cfg.max_bins - 1, self.cfg.max_depth,
            )
        return PR.predict_binned_packed(
            self.ensemble, dmat.matrix.packed, dmat.bits, dmat.n_rows,
            self.cfg.max_bins - 1, self.cfg.max_depth,
        )

    def _normalise_evals(self, evals, dtrain):
        out = []
        for i, e in enumerate(evals):
            d, name = e if isinstance(e, (tuple, list)) else (e, f"eval{i}")
            if not isinstance(d, (DeviceDMatrix, ExternalDMatrix)):
                raise TypeError(
                    "evals entries must be DeviceDMatrix / ExternalDMatrix "
                    f"(or (matrix, name)), got {type(d)}; build with ref=dtrain"
                )
            if d.label is None:
                raise ValueError(f"eval set '{name}' has no label")
            if not dtrain.same_cuts(d):
                raise ValueError(
                    f"eval set '{name}' was quantised with different cuts; "
                    "build it with DeviceDMatrix(x, label=y, ref=dtrain)"
                )
            out.append((d, name))
        return out

    def _run_rounds(self, dtrain, n_rounds, evals, early_stopping_rounds,
                    verbose_every, callback, mesh, data_axes,
                    checkpoint_every=None, checkpoint_path=None,
                    resume_state=None, collective="psum", compression=None,
                    comm_tolerance=0.05):
        if n_rounds <= 0:
            raise ValueError(f"n_rounds must be positive, got {n_rounds}")
        cfg, obj = self.cfg, self.obj
        if early_stopping_rounds and not evals:
            raise ValueError(
                "early_stopping_rounds requires at least one eval set "
                "(pass evals=[(DeviceDMatrix(..., ref=dtrain), name)])"
            )
        if checkpoint_every is not None:
            if checkpoint_every <= 0:
                raise ValueError(
                    f"checkpoint_every must be positive, got {checkpoint_every}"
                )
            if checkpoint_path is None:
                raise ValueError(
                    "checkpoint_every requires checkpoint_path= (the file "
                    "snapshots are written to)"
                )
        if dtrain.max_bins != cfg.max_bins:
            raise ValueError(
                f"{type(dtrain).__name__} was quantised with "
                f"max_bins={dtrain.max_bins} but this booster expects "
                f"max_bins={cfg.max_bins}; build the matrix with the same "
                "max_bins (bin-space thresholds and the reserved missing bin "
                "must agree)"
            )
        if cfg.monotone_constraints is not None \
                and len(cfg.monotone_constraints) != dtrain.n_features:
            raise ValueError(
                f"monotone_constraints has {len(cfg.monotone_constraints)} "
                f"entries but dtrain has {dtrain.n_features} features"
            )
        evals = self._normalise_evals(evals, dtrain)
        record_every = verbose_every or (1 if (callback or evals) else 0)
        track_metric = record_every > 0
        if self._metrics is None:  # direct _run_rounds callers / legacy paths
            self._metrics = self._resolve_metrics(None, None)
        metrics = self._metrics if track_metric else ()

        y = dtrain.label
        eval_pbs = tuple(d.packed_bins() for d, _ in evals)
        eval_ys = tuple(d.label for d, _ in evals)
        eval_extras = tuple(self._dataset_extra(d) for d, _ in evals)
        if resume_state is not None:
            # Checkpointed margins re-enter the scan exactly as carried —
            # rebuilding them by prediction is NOT bit-identical, so both
            # training and eval margins come from the snapshot verbatim.
            margins = jnp.asarray(resume_state["margins"], jnp.float32)
            eval_margins = tuple(
                jnp.asarray(m, jnp.float32)
                for m in resume_state["eval_margins"]
            )
            done = int(resume_state["rounds_done"])
            rounds_before = int(resume_state["rounds_before"])
            es_history = [float(v) for v in resume_state["es_history"]]
        else:
            if self._train_dmat is dtrain and self._margins is not None:
                margins = self._margins  # exact continuation, same matrix
            else:
                margins = self._initial_margins(dtrain)
            eval_margins = tuple(self._initial_margins(d) for d, _ in evals)
            done = 0
            rounds_before = self.n_rounds_trained  # absolute offset (keys)
            es_history = []
        target = done + n_rounds
        extra = self._dataset_extra(dtrain)
        stoch = SMP.stochastic_params(cfg)
        base_key = jax.random.PRNGKey(cfg.seed) if stoch is not None else None

        if mesh is not None:
            if dtrain.group_ids is not None:
                raise NotImplementedError(
                    "group_ids (rank:pairwise) is single-device only"
                )
            from repro import dist as D

            run_chunk = D.make_chunk_runner(
                cfg, obj, dtrain, mesh, data_axes, eval_pbs, eval_ys,
                eval_extras, metrics, track_metric,
                collective=collective, compression=compression,
                comm_tolerance=comm_tolerance,
            )
        else:
            external = isinstance(dtrain, ExternalDMatrix)
            if cfg.use_kernel_histograms and external:
                raise NotImplementedError(
                    "use_kernel_histograms is not supported with "
                    "ExternalDMatrix (the Pallas kernels are not "
                    "chunk-aware); train with the default builders"
                )
            if external and dtrain.resolved_paging() == "stream":
                # Streaming out-of-core executor (DESIGN.md §17): rounds run
                # eagerly, per-chunk kernels pull from the async prefetch
                # ring; the stack is never device-resident all at once.
                from repro.core import stream as STRM

                run_chunk = STRM.make_stream_runner(
                    cfg, obj, self.cuts, dtrain, y, extra, eval_pbs,
                    eval_ys, eval_extras, metrics, track_metric, base_key,
                )
            else:
                if external:
                    # Resident external-memory path: the chunk-stacked
                    # packed words are the only representation; a dense
                    # matrix never exists.
                    data = dtrain.packed_bins()
                else:
                    data = (
                        dtrain.packed_bins() if cfg.compress_matrix
                        else dtrain.matrix.unpack()
                    )
                hist_builder = None
                if cfg.use_kernel_histograms:
                    from repro.kernels import ops as KO

                    hist_builder = (
                        KO.build_histograms_kernel_packed
                        if cfg.compress_matrix
                        else KO.build_histograms_kernel
                    )
                fns: dict = {}

                def run_chunk(length, start_round, margins, eval_margins):
                    fkey = FA.trace_key("nan_grad")
                    fn = fns.get((length, fkey))
                    if fn is None:
                        fn = fns[(length, fkey)] = _make_train_fn(
                            cfg, obj, self.cuts, hist_builder, metrics,
                            track_metric, n_rounds=length,
                        )
                    if stoch is not None:
                        return fn(base_key,
                                  jnp.asarray(start_round, jnp.int32),
                                  data, margins, y, extra, eval_pbs,
                                  eval_margins, eval_ys, eval_extras)
                    if fkey is not None:
                        return fn(jnp.asarray(start_round, jnp.int32), data,
                                  margins, y, extra, eval_pbs, eval_margins,
                                  eval_ys, eval_extras)
                    return fn(data, margins, y, extra, eval_pbs,
                              eval_margins, eval_ys, eval_extras)

        # Per-fit communication accounting (DESIGN.md §15): analytic wire
        # bytes / collective calls for the chosen strategy, plus the
        # measured compressed-allreduce fallback count (filled post-loop).
        self.comm_stats = (
            run_chunk.comm_stats.as_dict() if mesh is not None else None
        )

        FA.check("oom")
        # The scan runs in compiled chunks delimited by the next early-
        # stopping boundary (multiples of e, one host read per chunk —
        # never per round), the next checkpoint boundary (multiples of
        # checkpoint_every), and the end of the run. Boundaries are FIT-
        # relative, so a resumed fit re-enters the identical chunk schedule
        # and ES decisions replay exactly.
        es_on = bool(early_stopping_rounds) and bool(evals)
        e = int(early_stopping_rounds) if es_on else None
        ck = int(checkpoint_every) if checkpoint_every else None
        eval_names = [name for _, name in evals]
        k = obj.n_outputs(cfg.n_classes)
        run_ens: PR.Ensemble | None = None  # this call's trees, scaled
        best_round: int | None = None
        stopped = False
        last_chunk = None  # (start, tr_host, ev_host) for the final record
        while done < target and not stopped:
            nxt = target
            if es_on:
                nxt = min(nxt, (done // e + 1) * e)
            if ck:
                nxt = min(nxt, (done // ck + 1) * ck)
            length = nxt - done
            margins, all_trees, tr_metrics, eval_margins, ev_metrics, flags \
                = run_chunk(length, rounds_before + done, margins,
                            eval_margins)
            self._handle_numeric_flags(flags, rounds_before + done)
            # The scan's ys-stack IS the ensemble arena: (rounds, k, arena)
            # fields reshaped to XGBoost's round-robin (rounds * k, arena)
            # layout — no per-round host round trips.
            chunk_ens = _scale_leaves(
                _stack_to_ensemble(all_trees, k, self.base_score),
                cfg.learning_rate,
            )
            run_ens = chunk_ens if run_ens is None \
                else PR.concat_ensembles(run_ens, chunk_ens)
            tr_host = [np.asarray(v) for v in tr_metrics]
            ev_host = [[np.asarray(v) for v in vals] for vals in ev_metrics]
            if record_every > 0:
                self._record_history(done, length, tr_host, ev_host, metrics,
                                     eval_names, rounds_before, record_every,
                                     callback)
            last_chunk = (done, tr_host, ev_host)
            self._check_divergence(ev_host, eval_names, metrics,
                                   rounds_before + done)
            if es_on:
                # The LAST metric of the LAST eval set drives stopping, in
                # the direction that METRIC declares (XGBoost convention;
                # the objective itself carries no direction). The stop
                # check fires only at fit-relative multiples of e (and at
                # the end), so extra checkpoint boundaries never change the
                # stopping decision.
                es_history.extend(ev_host[-1][-1].tolist())
                if nxt % e == 0 or nxt == target:
                    arr = np.asarray(es_history)
                    best_round = int(np.argmax(arr) if metrics[-1].maximize
                                     else np.argmin(arr))
                    if (len(arr) - 1 - best_round) >= e:
                        stopped = True
            done = nxt
            if ck and not stopped and done < target and done % ck == 0:
                self._write_checkpoint(
                    checkpoint_path, run_ens=run_ens, done=done,
                    target=target, rounds_before=rounds_before,
                    margins=margins, eval_margins=eval_margins,
                    es_history=es_history, early_stopping_rounds=e,
                    checkpoint_every=ck, verbose_every=verbose_every,
                    eval_names=eval_names,
                )
        jax.block_until_ready(margins)
        if self.comm_stats is not None:
            self.comm_stats["fallback_events"] = int(
                run_chunk.fallback_events
            )

        # Deferred final history record: the cadence above records round r
        # when r % record_every == 0, but the last trained round is recorded
        # unconditionally and is only known once the loop exits.
        if record_every > 0 and last_chunk is not None:
            start, tr_host, ev_host = last_chunk
            final_r = done - 1
            if final_r % record_every != 0:
                self._emit_record(final_r, final_r - start, tr_host, ev_host,
                                  metrics, eval_names, rounds_before,
                                  callback)

        keep = best_round + 1 if stopped else done
        full = run_ens if self.ensemble is None \
            else PR.concat_ensembles(self.ensemble, run_ens)
        if stopped and keep < done:
            # Early stopped: truncate the FULL ensemble to best_iteration+1
            # total rounds (best_round may precede a resume point, so the
            # cut can fall inside the pre-resume trees).
            full = PR.truncate_rounds(full, rounds_before + keep)
        self.ensemble = full
        self.n_rounds_trained = rounds_before + keep
        if es_on and best_round is not None:
            self.best_iteration = rounds_before + best_round
            self.best_score = float(es_history[best_round])
        if keep == done:
            self._margins = margins
            self._train_dmat = dtrain
        else:  # ensemble truncated; cached margins would be stale
            self._margins = None
            self._train_dmat = None
        if checkpoint_path is not None:
            self._write_final_checkpoint(checkpoint_path)

    # --- resilience plumbing (DESIGN.md §13) --------------------------------
    def _record_history(self, start, length, tr_host, ev_host, metrics,
                        eval_names, rounds_before, record_every, callback):
        for i in range(length):
            r = start + i
            if r % record_every:
                continue
            self._emit_record(r, i, tr_host, ev_host, metrics, eval_names,
                              rounds_before, callback)

    def _emit_record(self, r, i, tr_host, ev_host, metrics, eval_names,
                     rounds_before, callback):
        rec: dict[str, Any] = {"round": rounds_before + r}
        for j, m in enumerate(metrics):
            rec[f"train_{m.name}"] = float(tr_host[j][i])
        for name, vals in zip(eval_names, ev_host):
            for j, m in enumerate(metrics):
                rec[f"{name}_{m.name}"] = float(vals[j][i])
        self.history.append(rec)
        if callback:
            callback(rounds_before + r, rec)

    def _handle_numeric_flags(self, flags, start_round):
        """Host-side numeric-sentinel policy, applied once per chunk from
        the per-round finite flags that rode the ys-stack."""
        policy = self.cfg.numeric_check
        if policy == "off" or isinstance(flags, tuple):
            return
        bad = np.flatnonzero(~np.asarray(flags))
        if bad.size == 0:
            return
        rounds = [int(start_round + b) for b in bad]
        if policy == "raise":
            raise RES.NumericError(
                f"non-finite gradients/hessians/leaf values at boosting "
                f"round(s) {rounds} (numeric_check='raise'). Check labels "
                "and objective stability, or train with numeric_check="
                "'warn_skip' or 'clamp'."
            )
        if policy == "warn_skip":
            warnings.warn(
                f"round(s) {rounds} produced non-finite values; their trees "
                "were zeroed and margins carried forward unchanged "
                "(numeric_check='warn_skip')"
            )
            self.skipped_rounds.extend(rounds)
            self.resilience_events.append(
                {"event": "rounds_skipped", "rounds": rounds}
            )
        else:  # clamp
            warnings.warn(
                f"non-finite gradients at round(s) {rounds} were replaced/"
                "clipped before tree growth (numeric_check='clamp')"
            )
            self.resilience_events.append(
                {"event": "gradients_clamped", "rounds": rounds}
            )

    def _check_divergence(self, ev_host, eval_names, metrics, start_round):
        """Divergence detection on eval metrics (active with any non-"off"
        numeric_check): a non-finite metric means later rounds can only
        compound the damage."""
        if self.cfg.numeric_check == "off" or not eval_names:
            return
        for name, vals in zip(eval_names, ev_host):
            for m, arr in zip(metrics, vals):
                bad = np.flatnonzero(~np.isfinite(arr))
                if bad.size == 0:
                    continue
                at = int(start_round + bad[0])
                msg = (f"eval metric {name}_{m.name} became non-finite at "
                       f"round {at} — the fit is diverging")
                if self.cfg.numeric_check == "raise":
                    raise RES.DivergenceError(msg)
                warnings.warn(msg)
                self.resilience_events.append(
                    {"event": "divergence", "metric": f"{name}_{m.name}",
                     "round": at}
                )
                return

    def _write_checkpoint(self, path, *, run_ens, done, target, rounds_before,
                          margins, eval_margins, es_history,
                          early_stopping_rounds, checkpoint_every,
                          verbose_every, eval_names):
        """Atomic in-run snapshot at a chunk boundary: the partial ensemble
        plus everything `resume` needs to replay the rest of the fit
        bit-identically (carried margins, ES history, the absolute-round
        PRNG anchor, and the recording cadence)."""
        from repro.checkpoint import io as CIO

        ens = run_ens if self.ensemble is None \
            else PR.concat_ensembles(self.ensemble, run_ens)
        resume = {
            "rounds_done": int(done),
            "target": int(target),
            "rounds_before": int(rounds_before),
            "margins": margins,
            "eval_margins": tuple(eval_margins),
            "es_history": [float(v) for v in es_history],
            "early_stopping_rounds": int(early_stopping_rounds or 0),
            "checkpoint_every": int(checkpoint_every or 0),
            "verbose_every": int(verbose_every or 0),
            "eval_names": [str(n) for n in eval_names],
            "metric_names": [m.name for m in (self._metrics or ())],
        }
        self._save_snapshot(
            path,
            lambda: CIO.save_booster(
                path, self, ensemble=ens,
                n_rounds_trained=rounds_before + done,
                history=self.history, resume=resume,
            ),
            at_round=rounds_before + done,
        )

    def _write_final_checkpoint(self, path):
        from repro.checkpoint import io as CIO

        self._save_snapshot(path, lambda: CIO.save_booster(path, self),
                            at_round=self.n_rounds_trained)

    def _save_snapshot(self, path, write, at_round):
        """Checkpoint writes retry on transient I/O errors and degrade to a
        warning on persistent failure — losing a snapshot must not kill the
        training run it exists to protect."""
        try:
            RES.with_retries(write, retries=2, backoff=0.05,
                             retry_on=(OSError,))
        except OSError as exc:
            warnings.warn(
                f"checkpoint write to {path} failed after retries ({exc}); "
                "training continues without this snapshot"
            )
            self.resilience_events.append({
                "event": "checkpoint_write_failed", "path": str(path),
                "round": int(at_round), "error": str(exc),
            })

    # --- inference ---------------------------------------------------------
    def predict_margins(
        self, data, iteration_range: tuple[int, int] = (0, 0)
    ) -> jax.Array:
        """Raw margins (n_rows, n_outputs). `data` may be a numpy array, a
        jax array (one float32 conversion, done here and nowhere else) or a
        DeviceDMatrix (bin-space traversal on the packed words — exact, since
        thresholds are cut values and quantisation is searchsorted-left).

        Batch inference runs the fused ensemble traversal (all trees x all
        rows per level; serve/traversal.py) — bit-identical to the per-tree
        scan the training loop uses, in max_depth launches instead of
        n_trees scan steps.

        iteration_range=(a, b) restricts to boosting rounds [a, b), XGBoost
        semantics (b=0 means "through the last round"); the default is the
        whole model.
        """
        from repro.serve import traversal as ST

        self._require_fitted()
        ens = self.ensemble
        if iteration_range != (0, 0):
            ens = PR.slice_rounds(ens, *iteration_range)
        if isinstance(data, (DeviceDMatrix, ExternalDMatrix)):
            if not self._cuts_match(data.cuts):
                raise ValueError(
                    f"{type(data).__name__} was quantised with different cuts "
                    "than this booster; build it with ref= the training matrix"
                )
            if isinstance(data, ExternalDMatrix):
                return self._predict_margins_external(ens, data)
            return ST.predict_margins_fused_packed(
                ens, data.matrix.packed, data.bits, data.n_rows,
                self.cfg.max_bins - 1, self.cfg.max_depth,
            )
        x = jnp.asarray(data, jnp.float32)
        return ST.predict_margins_fused(ens, x, self.cfg.max_depth)

    def _predict_margins_external(self, ens, data: ExternalDMatrix):
        """Margins over an ExternalDMatrix by streaming packed chunks
        through the fused traversal one at a time: the full chunk stack is
        never paged in for inference — device transients stay bounded by
        one chunk's words plus one chunk's margins (DESIGN.md §14). When
        training already left the stack device-resident the cached chunks
        are served from it instead of the host."""
        from repro.serve import traversal as ST

        missing_bin = self.cfg.max_bins - 1
        parts = []
        for words in data.iter_device_chunks():
            parts.append(ST.predict_margins_fused_packed(
                ens, words, data.bits, data.chunk_rows, missing_bin,
                self.cfg.max_depth,
            ))
        return jnp.concatenate(parts, axis=0)[: data.n_rows]

    def predict(
        self, data, output_margin: bool = False,
        iteration_range: tuple[int, int] = (0, 0),
    ) -> jax.Array:
        """Transformed predictions (probabilities / values / class ids) —
        the model knows its own objective, depth and class count.
        output_margin / iteration_range follow XGBoost's predict knobs."""
        m = self.predict_margins(data, iteration_range=iteration_range)
        return m if output_margin else self.obj.transform(m)

    def eval(self, dmat: DeviceDMatrix, name: str = "eval",
             metrics=None) -> dict:
        """One-shot metrics on a labelled DeviceDMatrix.

        metrics: optional spec or list of specs (as in fit's eval_metric);
        defaults to the objective's default metric. Returns
        {f"{name}_{metric}": value} for each metric.
        """
        self._require_fitted()
        if dmat.label is None:
            raise ValueError("eval requires a labelled DeviceDMatrix")
        resolved = M.resolve_metrics(metrics) or (
            M.get_metric(self.obj.default_metric),
        )
        margins = self.predict_margins(dmat)
        extra = self._dataset_extra(dmat)
        return {
            f"{name}_{m.name}": float(m.fn(margins, dmat.label, **extra))
            for m in resolved
        }

    def feature_importances(self, importance_type: str = "gain") -> np.ndarray:
        """Per-feature importance over the fitted ensemble, from the split
        gains stored in the tree arenas (a split node is any arena slot
        with finite gain; leaves and inactive slots carry -inf).

        importance_type:
          * "gain"       — mean objective reduction per split on the feature
                           (XGBoost's default importance_type);
          * "total_gain" — summed objective reduction;
          * "weight"     — number of splits on the feature.

        Returns a float64 (n_features,) vector (unnormalised — the sklearn
        estimators' `feature_importances_` normalises to sum 1). Boosters
        loaded from checkpoints that predate stored gains report zeros.
        """
        self._require_fitted()
        gain = np.asarray(self.ensemble.gain, np.float64)
        feat = np.asarray(self.ensemble.feature)
        split = np.isfinite(gain)
        n_features = self.cuts.shape[0]
        counts = np.bincount(
            feat[split], minlength=n_features
        ).astype(np.float64)
        if importance_type == "weight":
            return counts
        if importance_type in ("gain", "total_gain"):
            total = np.zeros(n_features, np.float64)
            np.add.at(total, feat[split], gain[split])
            if importance_type == "total_gain":
                return total
            return np.divide(total, counts, out=np.zeros_like(total),
                             where=counts > 0)
        raise ValueError(
            f"importance_type must be 'gain', 'total_gain' or 'weight', "
            f"got {importance_type!r}"
        )

    # --- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        """Self-describing checkpoint (config + cuts + base score + trees)
        via the msgpack layer, with a versioned metadata header."""
        self._require_fitted()
        from repro.checkpoint import io as CIO

        CIO.save_booster(path, self)

    @classmethod
    def load(cls, path: str) -> "Booster":
        from repro.checkpoint import io as CIO

        return CIO.load_booster(path)


# Deprecated alias: the old TrainState (ensemble/margins/matrix/history
# attribute surface) is now the Booster itself.
TrainState = Booster


def train(
    x: np.ndarray | jax.Array,
    y: np.ndarray | jax.Array,
    cfg: BoosterConfig,
    eval_set: tuple[Any, Any] | None = None,
    group_ids: np.ndarray | None = None,
    verbose_every: int = 0,
    callback: Callable[[int, dict], None] | None = None,
) -> Booster:
    """Deprecated one-shot shim over DeviceDMatrix + Booster.fit.

    Re-quantises x on every call — build a DeviceDMatrix once and call
    `Booster.fit` to amortise that. `eval_set` is routed through the in-scan
    eval path, so history records are honest per-round entries.
    """
    dtrain = DeviceDMatrix(x, label=y, group_ids=group_ids,
                           max_bins=cfg.max_bins)
    evals = []
    if eval_set is not None:
        xv, yv = eval_set
        evals.append((DeviceDMatrix(xv, label=yv, ref=dtrain), "valid"))
    return Booster(cfg).fit(dtrain, evals=evals, verbose_every=verbose_every,
                            callback=callback)


def predict_margins(ens: PR.Ensemble, x, max_depth: int) -> jax.Array:
    """Deprecated shim: raw-threshold margins. The single float32 conversion
    lives here (predict() does not convert again)."""
    return PR.predict_raw(ens, jnp.asarray(x, jnp.float32), max_depth)


def predict(ens: PR.Ensemble, x, max_depth: int, objective: str) -> jax.Array:
    """Deprecated shim: prefer Booster.predict (no per-call max_depth /
    objective — the model describes itself)."""
    obj = O.get_objective(objective)
    return obj.transform(predict_margins(ens, x, max_depth))
