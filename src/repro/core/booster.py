"""Gradient boosting driver — the Figure 1 pipeline, end-to-end on device.

The entire training run is ONE compiled program: a jax.lax.scan over
boosting rounds whose ys-stack is the preallocated (n_rounds * k, arena)
ensemble arena. Per round (all phases on-accelerator, as in the paper):
  predict (incremental margins) -> gradient evaluation -> quantised-histogram
  tree construction -> margin update.
There is no per-round Python dispatch and no end-of-training concatenate —
scan writes each round's trees into its output buffer in place.

Feature quantisation + compression happen once up front (Figure 1's left
boxes). With compress_matrix=True the bit-packed CompressedMatrix is the
*only* training-set representation from then on (paper §2.2, DESIGN.md §2):
histograms are built from the packed words (Pallas kernel or the row-block
XLA fallback), row repartitioning and training-set prediction extract the
needed feature column from the words on the fly. The dense (n, f) int32
bins array is never materialised again after quantisation. Validation runs
on raw thresholds (predict_raw).

Multiclass trains n_classes trees per round on softmax gradients (round-robin
class layout, XGBoost's scheme). Margins are maintained incrementally — each
new tree's leaf outputs are added — rather than re-predicting the whole
ensemble per round, matching the real implementation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import objectives as O
from repro.core import quantile as Q
from repro.core import split as S
from repro.core import tree as T
from repro.core import predict as PR


@dataclass(frozen=True)
class BoosterConfig:
    n_rounds: int = 100
    learning_rate: float = 0.3
    max_depth: int = 6
    max_bins: int = Q.DEFAULT_MAX_BINS
    reg_lambda: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1.0
    objective: str = "reg:squarederror"
    n_classes: int = 1
    growth: str = "depthwise"  # or "lossguide"
    max_leaves: int = 0  # lossguide budget (0 = 2^max_depth)
    use_kernel_histograms: bool = False  # route through the Pallas kernel path
    compress_matrix: bool = True  # paper §2.2 (False = raw int32 bins)
    hist_block_rows: int = 65536  # packed-histogram fallback dense-tile bound

    @property
    def split_params(self) -> S.SplitParams:
        return S.SplitParams(self.reg_lambda, self.gamma, self.min_child_weight)


@dataclass
class TrainState:
    ensemble: PR.Ensemble
    margins: jax.Array  # (n, n_outputs) training margins
    matrix: C.CompressedMatrix
    history: list[dict] = field(default_factory=list)


def _tree_margin_delta(cfg: BoosterConfig, tr: T.Tree, data) -> jax.Array:
    """One tree's leaf outputs over all training rows, straight from the
    training representation (packed or dense) — no Ensemble construction."""
    mb = cfg.max_bins - 1
    if isinstance(data, C.PackedBins):
        return PR.traverse_tree_packed(
            tr.feature, tr.split_bin, tr.default_left, tr.leaf_value, tr.is_leaf,
            data.packed, data.bits, data.n_rows, mb, cfg.max_depth,
        )
    return PR.traverse_tree_binned(
        tr.feature, tr.split_bin, tr.default_left, tr.leaf_value, tr.is_leaf,
        data, mb, cfg.max_depth,
    )


def _make_round_step(cfg: BoosterConfig, obj: O.Objective, cuts: jax.Array,
                     hist_builder=None):
    """One boosting round: gradients -> K trees -> margins. Pure (not jit'd
    on its own) so it can be the body of the training scan."""
    k = obj.n_outputs(cfg.n_classes)

    def round_step(data, margins, y, extra):
        gh_all = obj.grad(margins, y, **extra)  # (n, k, 2)
        trees = []
        new_margins = margins
        for c in range(k):
            tr = T.grow_tree(
                data,
                gh_all[:, c, :],
                cuts,
                cfg.max_depth,
                cfg.max_bins,
                cfg.split_params,
                growth=cfg.growth,
                max_leaves=cfg.max_leaves or 2**cfg.max_depth,
                hist_builder=hist_builder,
                hist_block_rows=cfg.hist_block_rows,
            )
            trees.append(tr)
            # Incremental margin update from this tree only.
            delta = _tree_margin_delta(cfg, tr, data)
            new_margins = new_margins.at[:, c].add(cfg.learning_rate * delta)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        return stacked, new_margins

    return round_step


def _make_train_fn(cfg: BoosterConfig, obj: O.Objective, cuts: jax.Array,
                   hist_builder, track_metric: bool):
    """The whole training run as one jit: scan over rounds. Returns
    (final_margins, stacked_trees (n_rounds, k, arena...), metrics (n_rounds,))."""
    round_step = _make_round_step(cfg, obj, cuts, hist_builder)

    @jax.jit
    def train_fn(data, margins0, y, extra):
        def body(margins, _):
            stacked, new_margins = round_step(data, margins, y, extra)
            metric = (
                obj.metric(new_margins, y).astype(jnp.float32)
                if track_metric
                else jnp.float32(0.0)
            )
            return new_margins, (stacked, metric)

        margins, (all_trees, metrics) = jax.lax.scan(
            body, margins0, None, length=cfg.n_rounds
        )
        return margins, all_trees, metrics

    return train_fn


def train(
    x: np.ndarray | jax.Array,
    y: np.ndarray | jax.Array,
    cfg: BoosterConfig,
    eval_set: tuple[Any, Any] | None = None,
    group_ids: np.ndarray | None = None,
    verbose_every: int = 0,
    callback: Callable[[int, dict], None] | None = None,
) -> TrainState:
    obj = O.OBJECTIVES[cfg.objective]
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    k = obj.n_outputs(cfg.n_classes)

    # --- Figure 1: generate feature quantiles + data compression ---------
    cuts = Q.compute_cuts(x, cfg.max_bins)
    bins = Q.quantize(x, cuts)
    matrix = C.compress(bins, cuts, cfg.max_bins)
    del x  # the raw matrix is not needed for training anymore

    base = obj.init_base_score(y)
    margins = jnp.full((n, k), base, jnp.float32)
    extra = {"group_ids": jnp.asarray(group_ids)} if group_ids is not None else {}

    if cfg.compress_matrix:
        data = matrix.as_packed_bins()
        del bins  # packed words are the training representation from here on
    else:
        data = bins

    hist_builder = None
    if cfg.use_kernel_histograms:
        from repro.kernels import ops as KO

        hist_builder = (
            KO.build_histograms_kernel_packed
            if cfg.compress_matrix
            else KO.build_histograms_kernel
        )

    # Record cadence: verbose_every if set, else every round when only a
    # callback wants records. The whole run is one compiled program, so
    # records are emitted post-hoc and share the fit's wall clock.
    record_every = verbose_every or (1 if callback else 0)
    track_metric = record_every > 0
    train_fn = _make_train_fn(cfg, obj, cuts, hist_builder, track_metric)

    t0 = time.perf_counter()
    margins, all_trees, metrics = train_fn(data, margins, y, extra)
    jax.block_until_ready(margins)
    elapsed = time.perf_counter() - t0

    history: list[dict] = []
    if track_metric:
        metrics_host = np.asarray(metrics)
        for r in range(cfg.n_rounds):
            if r % record_every == 0 or r == cfg.n_rounds - 1:
                rec = {
                    "round": r,
                    f"train_{obj.metric_name}": float(metrics_host[r]),
                    "elapsed_s": elapsed,  # whole-fit wall clock (one program)
                }
                history.append(rec)
                if callback:
                    callback(r, rec)

    # The scan's ys-stack IS the ensemble arena: (n_rounds, k, arena) fields
    # reshaped to XGBoost's round-robin (n_rounds * k, arena) layout — no
    # concatenate, no per-round host round trips.
    arena = all_trees.feature.shape[-1]
    ens = PR.Ensemble(
        feature=all_trees.feature.reshape(-1, arena),
        split_bin=all_trees.split_bin.reshape(-1, arena),
        threshold=all_trees.threshold.reshape(-1, arena),
        default_left=all_trees.default_left.reshape(-1, arena),
        leaf_value=all_trees.leaf_value.reshape(-1, arena),
        is_leaf=all_trees.is_leaf.reshape(-1, arena),
        n_classes=k,
        base_score=base,
    )
    ens = _scale_leaves(ens, cfg.learning_rate)
    state = TrainState(ensemble=ens, margins=margins, matrix=matrix, history=history)

    if eval_set is not None:
        xv, yv = eval_set
        mv = predict_margins(state.ensemble, jnp.asarray(xv, jnp.float32), cfg.max_depth)
        state.history.append(
            {"round": cfg.n_rounds - 1,
             f"valid_{obj.metric_name}": float(obj.metric(mv, jnp.asarray(yv, jnp.float32)))}
        )
    return state


def _scale_leaves(ens: PR.Ensemble, eta: float) -> PR.Ensemble:
    """Bake the learning rate into stored leaf values (margins during
    training already used eta; the stored ensemble must match)."""
    return ens._replace(leaf_value=ens.leaf_value * eta)


def predict_margins(ens: PR.Ensemble, x: jax.Array, max_depth: int) -> jax.Array:
    return PR.predict_raw(ens, x, max_depth)


def predict(ens: PR.Ensemble, x: jax.Array, max_depth: int, objective: str) -> jax.Array:
    obj = O.OBJECTIVES[objective]
    return obj.transform(predict_margins(ens, jnp.asarray(x, jnp.float32), max_depth))
