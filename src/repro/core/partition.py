"""RepartitionInstances (paper §2.3 / Algorithm 1).

After a level of splits is decided, every active row is routed to a child
node based on its bin id for the split feature; the paper does this per-GPU
on each device's row shard, and so do we (the function is elementwise over
rows, so under shard_map it is embarrassingly parallel with no collectives).

Arena indexing: complete binary tree, children of node k are 2k+1 / 2k+2.
positions[i] = arena node id of row i, or -1 once the row rests in a leaf.

One routing rule, four data layouts: `_route` holds the missing-bin /
default-direction / child-index semantics ONCE; the public functions differ
only in how the split-feature bin is fetched (dense gather, packed word
shift/mask, sampled-row-buffer variants, chunk-stack scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compress as C


def _route(
    positions: jax.Array,  # (n,) int32 arena node ids, -1 = inactive
    split_mask: jax.Array,  # (n_arena,) bool — nodes that split this level
    feature: jax.Array,  # (n_arena,) int32
    split_bin: jax.Array,  # (n_arena,) int32
    default_left: jax.Array,  # (n_arena,) bool
    missing_bin: int,
    gather_bins,  # (per-row feature ids) -> per-row bin ids
) -> jax.Array:
    """Shared routing body: fetch each row's split-feature bin via
    `gather_bins`, then left/right by threshold with the learned default
    direction for missing values."""
    pos = jnp.maximum(positions, 0)
    active = positions >= 0
    splits_here = split_mask[pos] & active

    f = feature[pos]
    b = gather_bins(f)
    is_missing = b == missing_bin
    go_left = jnp.where(is_missing, default_left[pos], b <= split_bin[pos])

    child = jnp.where(go_left, 2 * pos + 1, 2 * pos + 2)
    return jnp.where(splits_here, child, -1).astype(jnp.int32)


@jax.jit
def update_positions(
    bins: jax.Array,  # (n, f) int32
    positions: jax.Array,
    split_mask: jax.Array,
    feature: jax.Array,
    split_bin: jax.Array,
    default_left: jax.Array,
    missing_bin: int,
) -> jax.Array:
    return _route(
        positions, split_mask, feature, split_bin, default_left, missing_bin,
        lambda f: jnp.take_along_axis(bins, f[:, None], axis=1)[:, 0],
    )


@functools.partial(jax.jit, static_argnames=("missing_bin", "bits"))
def update_positions_packed(
    packed: jax.Array,  # (f, n_words) uint32 bit-packed bins
    positions: jax.Array,
    split_mask: jax.Array,
    feature: jax.Array,
    split_bin: jax.Array,
    default_left: jax.Array,
    missing_bin: int,
    bits: int,
) -> jax.Array:
    """update_positions on the bit-packed matrix: the split-feature bin of
    each row is extracted on the fly (one word gather + shift/mask per row),
    so routing touches n_rows/spw-word columns instead of a dense (n, f)
    matrix — the dense bins never exist."""
    return _route(
        positions, split_mask, feature, split_bin, default_left, missing_bin,
        lambda f: C.gather_feature_bins(packed, bits, f),
    )


@functools.partial(jax.jit, static_argnames=("missing_bin", "bits"))
def update_positions_packed_rows(
    packed: jax.Array,  # (f, n_words) uint32 bit-packed bins
    positions: jax.Array,  # (m,) int32 arena node ids of the BUFFER slots
    split_mask: jax.Array,
    feature: jax.Array,
    split_bin: jax.Array,
    default_left: jax.Array,
    missing_bin: int,
    bits: int,
    row_ids: jax.Array,  # (m,) int32 global row id of each buffer slot
) -> jax.Array:
    """update_positions_packed over a sampled-row buffer (DESIGN.md §12):
    positions live in buffer space, and each slot's split-feature bin is
    gathered via its global row id — routing cost scales with the buffer,
    not n_rows."""
    return _route(
        positions, split_mask, feature, split_bin, default_left, missing_bin,
        lambda f: C.gather_feature_bins_rows(packed, bits, f, row_ids),
    )


@functools.partial(
    jax.jit, static_argnames=("missing_bin", "bits", "chunk_rows")
)
def update_positions_chunked_rows(
    packed: jax.Array,  # (n_chunks, f, words_per_chunk) uint32
    positions: jax.Array,  # (m,) int32 arena node ids of the BUFFER slots
    split_mask: jax.Array,
    feature: jax.Array,
    split_bin: jax.Array,
    default_left: jax.Array,
    missing_bin: int,
    bits: int,
    chunk_rows: int,
    row_ids: jax.Array,  # (m,) int32 global row id of each buffer slot
) -> jax.Array:
    """update_positions_packed_rows over the chunk-stacked matrix: the
    buffer's rows gather their split-feature word from the owning chunk
    directly (no scan over chunks — the buffer is already compact)."""
    return _route(
        positions, split_mask, feature, split_bin, default_left, missing_bin,
        lambda f: C.gather_feature_bins_chunked(
            packed, bits, chunk_rows, f, row_ids
        ),
    )


@functools.partial(
    jax.jit, static_argnames=("missing_bin", "bits", "chunk_rows", "n_rows")
)
def update_positions_chunked(
    packed: jax.Array,  # (n_chunks, f, words_per_chunk) uint32
    positions: jax.Array,  # (n,) int32 arena node ids, -1 = inactive
    split_mask: jax.Array,  # (n_arena,) bool — nodes that split this level
    feature: jax.Array,  # (n_arena,) int32
    split_bin: jax.Array,  # (n_arena,) int32
    default_left: jax.Array,  # (n_arena,) bool
    missing_bin: int,
    bits: int,
    chunk_rows: int,
    n_rows: int,
) -> jax.Array:
    """update_positions_packed over the chunk-stacked matrix (external-
    memory path): a lax.scan over chunks routes each chunk's rows with that
    chunk's words. Routing is elementwise per row, so the result is
    bit-identical to the flat-layout version on the same rows."""
    n_chunks = packed.shape[0]
    pos_c = jnp.pad(
        positions, (0, n_chunks * chunk_rows - n_rows), constant_values=-1
    ).reshape(n_chunks, chunk_rows)

    def body(carry, chunk):
        words, p = chunk
        return carry, update_positions_packed(
            words, p, split_mask, feature, split_bin, default_left,
            missing_bin, bits,
        )

    _, new_pos = jax.lax.scan(body, None, (packed, pos_c))
    return new_pos.reshape(-1)[:n_rows]
