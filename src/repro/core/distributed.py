"""Multi-device GBDT training (paper §2.3, Algorithm 1) via shard_map.

Rows are partitioned across the `data` (and `pod`) mesh axes — the paper's
"each GPU processes a subset of training instances". Each shard builds
partial histograms; jax.lax.psum combines them (the NCCL AllReduceHistograms
call); split evaluation and tree state are replicated, positions stay
shard-local. The per-round function is a single shard_map body, so XLA sees
one SPMD program with exactly one all-reduce per tree level.

Beyond-paper option (`feature_shards` > 1): histograms are additionally
sharded over features on the `model` axis, turning the full-histogram
all-reduce into a reduce-scatter-shaped psum of 1/p of the bytes, with each
shard evaluating only its features and an argmax-allgather of the (tiny)
per-node best-split records. See EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.core import compress as C
from repro.core import objectives as O
from repro.core import quantile as Q
from repro.core import split as S
from repro.core import tree as T
from repro.core import predict as PR


def make_distributed_round(
    cfg,
    obj: O.Objective,
    cuts: jax.Array,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    n_rows_per_shard: int | None = None,
    bits: int | None = None,
):
    """Returns a jit'd per-round function over row-sharded data.

    Inputs to the returned fn: bins_or_packed row-sharded over data_axes,
    margins/y row-sharded, replicated tree output.
    """
    k = obj.n_outputs(cfg.n_classes)
    mb = cfg.max_bins - 1
    axis0, extra = data_axes[0], tuple(data_axes[1:])

    def round_body(data, margins, y):
        if cfg.compress_matrix:
            # Packed-native: each shard's words ARE its training matrix —
            # no per-round unpack, no dense (n, f) bins (DESIGN.md §2).
            rep = C.PackedBins(packed=data, bits=bits, n_rows=n_rows_per_shard)
        else:
            rep = data
        gh_all = obj.grad(margins, y)
        trees = []
        new_margins = margins
        for c in range(k):
            tr = T.grow_tree(
                rep,
                gh_all[:, c, :],
                cuts,
                cfg.max_depth,
                cfg.max_bins,
                cfg.split_params,
                growth=cfg.growth,
                max_leaves=cfg.max_leaves or 2**cfg.max_depth,
                axis_name=axis0,
                extra_axes=extra,
            )
            trees.append(tr)
            if cfg.compress_matrix:
                delta = PR.traverse_tree_packed(
                    tr.feature, tr.split_bin, tr.default_left, tr.leaf_value,
                    tr.is_leaf, rep.packed, rep.bits, rep.n_rows, mb,
                    cfg.max_depth,
                )
            else:
                delta = PR.traverse_tree_binned(
                    tr.feature, tr.split_bin, tr.default_left, tr.leaf_value,
                    tr.is_leaf, rep, mb, cfg.max_depth,
                )
            new_margins = new_margins.at[:, c].add(cfg.learning_rate * delta)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        return stacked, new_margins

    axes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    row_spec = P(axes)
    if cfg.compress_matrix:
        # packed matrix is (F, W): rows live in the words axis.
        data_spec = P(None, axes)
    else:
        data_spec = P(axes, None)

    shard_fn = jaxcompat.shard_map(
        round_body,
        mesh=mesh,
        in_specs=(data_spec, row_spec, row_spec),
        out_specs=(P(), row_spec),
    )
    return jax.jit(shard_fn)


def train_distributed(
    x,
    y,
    cfg,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    verbose_every: int = 0,
):
    """End-to-end distributed boosting. x, y are global arrays; rows must be
    divisible by the product of data-axis sizes (pad upstream)."""
    obj = O.OBJECTIVES[cfg.objective]
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    n = x.shape[0]
    k = obj.n_outputs(cfg.n_classes)
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    assert n % n_shards == 0, (n, n_shards)

    cuts = Q.compute_cuts(x, cfg.max_bins)
    bins = Q.quantize(x, cuts)

    if cfg.compress_matrix:
        # Pack per-shard so each shard's words decode independently.
        per = n // n_shards
        packed_shards = [
            C.pack(bins[i * per : (i + 1) * per], C.bits_needed(cfg.max_bins - 1))
            for i in range(n_shards)
        ]
        data = jnp.concatenate(packed_shards, axis=1)  # (F, n_shards*W)
        bits = C.bits_needed(cfg.max_bins - 1)
        n_per = per
    else:
        data = bins
        bits, n_per = None, None

    axes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    row_sharding = jax.NamedSharding(mesh, P(axes))
    data_sharding = jax.NamedSharding(
        mesh, P(None, axes) if cfg.compress_matrix else P(axes, None)
    )
    base = obj.init_base_score(y)
    margins = jax.device_put(jnp.full((n, k), base, jnp.float32), row_sharding)
    y = jax.device_put(y, row_sharding)
    data = jax.device_put(data, data_sharding)

    round_fn = make_distributed_round(
        cfg, obj, cuts, mesh, data_axes, n_rows_per_shard=n_per, bits=bits
    )

    trees, history = [], []
    for r in range(cfg.n_rounds):
        stacked, margins = round_fn(data, margins, y)
        trees.append(stacked)
        if verbose_every and r % verbose_every == 0:
            history.append(
                {"round": r, f"train_{obj.metric_name}": float(obj.metric(margins, y))}
            )

    all_trees = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)
    ens = PR.Ensemble(
        feature=all_trees.feature,
        split_bin=all_trees.split_bin,
        threshold=all_trees.threshold,
        default_left=all_trees.default_left,
        leaf_value=all_trees.leaf_value * cfg.learning_rate,
        is_leaf=all_trees.is_leaf,
        n_classes=k,
        base_score=base,
    )
    return ens, margins, history
