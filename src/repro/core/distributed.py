"""Multi-device GBDT training (paper §2.3, Algorithm 1) via shard_map.

Rows are partitioned across the `data` (and `pod`) mesh axes — the paper's
"each GPU processes a subset of training instances". Each shard builds
partial histograms; jax.lax.psum combines them (the NCCL AllReduceHistograms
call); split evaluation and tree state are replicated, positions stay
shard-local. The per-round function is a single shard_map body, so XLA sees
one SPMD program with exactly one all-reduce per tree level.

Beyond-paper option (`feature_shards` > 1): histograms are additionally
sharded over features on the `model` axis, turning the full-histogram
all-reduce into a reduce-scatter-shaped psum of 1/p of the bytes, with each
shard evaluating only its features and an argmax-allgather of the (tiny)
per-node best-split records. See EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.core import compress as C
from repro.core import objectives as O
from repro.core import resilience as RES
from repro.core import sampling as SMP
from repro.core import tree as T


# Compiled per-round shard_map programs and eval-margin updaters, keyed by
# static config (cuts/data are traced arguments) — mirrors
# booster._TRAIN_FN_CACHE so refits with mesh= skip recompilation too.
_ROUND_FN_CACHE: dict = {}
_APPLY_EVAL_CACHE: dict = {}


def make_distributed_round(
    cfg,
    obj: O.Objective,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    n_rows_per_shard: int | None = None,
    bits: int | None = None,
    chunk_rows: int | None = None,
):
    """Returns a jit'd per-round function over row-sharded data.

    Inputs to the returned fn: bins_or_packed row-sharded over data_axes,
    margins/y row-sharded, cuts replicated; replicated tree output. Cached
    by static config so repeated fits reuse the compiled program.

    `chunk_rows` set means external-memory data: each shard holds a stack
    of independently packed chunks (its row shard), and the per-level
    histogram is a chunk-scan on-shard followed by the usual psum — the
    chunk loop composes with Algorithm 1's AllReduce unchanged.
    """
    # Objective is a hashable NamedTuple; registry lookups return singletons,
    # so registered (incl. custom-registered) objectives key stably.
    key = (cfg, obj, mesh, tuple(data_axes), n_rows_per_shard, bits,
           chunk_rows)
    cached = _ROUND_FN_CACHE.get(key)
    if cached is not None:
        return cached
    k = obj.n_outputs(cfg.n_classes)
    axis0, extra = data_axes[0], tuple(data_axes[1:])
    cfg_kw = O.config_kwargs(cfg)  # static under shard_map (cfg keys cache)
    chunked = chunk_rows is not None
    stoch = SMP.stochastic_params(cfg)
    sentinel = cfg.numeric_check != "off"
    # Static shard geometry for the shared-key sampling (DESIGN.md §12):
    # every shard draws the SAME global row selection / feature masks from
    # the replicated per-round key, then slices its own rows — identical to
    # the single-device sample, no extra collective, psum unchanged.
    axis_sizes = tuple(mesh.shape[a] for a in data_axes)
    n_shards = 1
    for s in axis_sizes:
        n_shards *= s

    def _shard_offset(n_local):
        lin = jnp.int32(0)
        for a, s in zip(data_axes, axis_sizes):
            lin = lin * s + jax.lax.axis_index(a)
        return lin * n_local

    def round_body(data, margins, y, cuts, rkey=None):
        from repro.core import booster as B  # lazy: avoid import cycle

        if chunked:
            # External-memory: this shard's chunk stack is its matrix.
            rep = C.ChunkedPackedBins(
                packed=data, bits=bits, chunk_rows=chunk_rows,
                n_rows=n_rows_per_shard,
            )
        elif cfg.compress_matrix:
            # Packed-native: each shard's words ARE its training matrix —
            # no per-round unpack, no dense (n, f) bins (DESIGN.md §2).
            rep = C.PackedBins(packed=data, bits=bits, n_rows=n_rows_per_shard)
        else:
            rep = data
        n_features = (
            rep.n_features if cfg.compress_matrix or chunked
            else rep.shape[1]
        )
        gh_all = obj.grad(margins, y, **cfg_kw)
        gh_raw = gh_all
        if cfg.numeric_check == "clamp":
            gh_all = RES.clamp_gradients(gh_all)
        trees = []
        for c in range(k):
            gh_c = gh_all[:, c, :]
            ctx = None
            if stoch is not None:
                n_local = margins.shape[0]
                ctx, gh_c = SMP.make_tree_context(
                    stoch, jax.random.fold_in(rkey, c), gh_c, n_features,
                    compact=False,
                    n_total=n_local * n_shards,
                    row_offset=_shard_offset(n_local),
                )
            tr = T.grow_tree(
                rep,
                gh_c,
                cuts,
                cfg.max_depth,
                cfg.max_bins,
                cfg.split_params,
                growth=cfg.growth,
                max_leaves=cfg.max_leaves or 2**cfg.max_depth,
                axis_name=axis0,
                extra_axes=extra,
                ctx=ctx,
            )
            # Materialise tree arrays before the margin update (same
            # barrier as booster._round_step_fn — see DESIGN.md §11).
            trees.append(jax.lax.optimization_barrier(tr))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        # One barriered add for all k columns, shared with the
        # single-device scan so both compile the update identically.
        new_margins = B._apply_stacked_trees(cfg, stacked, rep, margins)
        if not sentinel:
            return stacked, new_margins
        # Gradients/margins are shard-local; a shard seeing non-finite
        # values must poison the round globally (trees are replicated), so
        # the bad count is psum-all-reduced before the policy applies.
        ok_local = RES.finite_flags(gh_raw, stacked.leaf_value, new_margins)
        bad = jax.lax.psum(
            jnp.where(ok_local, 0, 1).astype(jnp.int32), tuple(data_axes)
        )
        ok = bad == 0
        if cfg.numeric_check == "warn_skip":
            # Same neutralisation as booster._round_step_fn: zero leaves,
            # -inf gains, round-start margins carried forward.
            stacked = stacked._replace(
                leaf_value=jnp.where(ok, stacked.leaf_value,
                                     jnp.zeros_like(stacked.leaf_value)),
                gain=jnp.where(ok, stacked.gain,
                               jnp.full_like(stacked.gain, -jnp.inf)),
            )
            new_margins = jnp.where(ok, new_margins, margins)
        return stacked, new_margins, ok

    axes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    row_spec = P(axes)
    if chunked:
        # chunk stack is (C, F, W): rows live in whole chunks on axis 0.
        data_spec = P(axes, None, None)
    elif cfg.compress_matrix:
        # packed matrix is (F, W): rows live in the words axis.
        data_spec = P(None, axes)
    else:
        data_spec = P(axes, None)

    in_specs = (data_spec, row_spec, row_spec, P())
    if stoch is not None:
        in_specs = in_specs + (P(),)  # per-round key, replicated
    out_specs = (P(), row_spec)
    if sentinel:
        out_specs = out_specs + (P(),)  # psum'd ok flag, replicated
    shard_fn = jaxcompat.shard_map(
        round_body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    fn = _ROUND_FN_CACHE[key] = jax.jit(shard_fn)
    return fn


def make_chunk_runner(
    cfg,
    obj: O.Objective,
    dmat,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str],
    eval_pbs: tuple = (),
    eval_ys: tuple = (),
    eval_extras: tuple = (),
    metrics: tuple = (),
    track_metric: bool = False,
):
    """The multi-device strategy behind Booster.fit(dtrain, mesh=...).

    Shards the DeviceDMatrix's rows over the data axes (re-packing the words
    per shard so each shard decodes independently), then exposes the same
    chunk interface as the single-device scan:

        run(length, start_round, margins, eval_margins) ->
            (margins, stacked_trees (length, k, arena...),
             train_metrics tuple-per-metric of (length,), eval_margins,
             eval_metrics tuple-per-set of tuple-per-metric of (length,),
             sentinel flags ((length,) bool, or () when numeric_check="off"))

    The per-round loop dispatches one shard_map'd program per round (one
    psum per tree level, Algorithm 1); eval-set margins are maintained
    incrementally on replicated eval data, and every requested metric is
    evaluated per round with values staying on device until the Booster
    reads them at chunk granularity — the same multi-metric stack as the
    single-device scan.
    """
    from repro.core.dmatrix import ExternalDMatrix

    n = dmat.n_rows
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    if n % n_shards != 0:
        raise ValueError(
            f"n_rows={n} must be divisible by the {n_shards} data shards "
            "(truncate or pad upstream)"
        )
    cuts = dmat.cuts
    if isinstance(dmat, ExternalDMatrix):
        # External-memory + multi-device: whole chunks are the sharding
        # unit (each chunk already decodes independently, so no per-shard
        # re-packing is needed). Shard boundaries must align with chunk
        # boundaries so each shard's rows are exactly its chunks' rows.
        if n % dmat.chunk_rows != 0:
            raise ValueError(
                f"external-memory training with mesh= requires n_rows={n} "
                f"to be a multiple of chunk_rows={dmat.chunk_rows} (the "
                "last chunk must be full so shards get whole chunks)"
            )
        if dmat.n_chunks % n_shards != 0:
            raise ValueError(
                f"n_chunks={dmat.n_chunks} must be divisible by the "
                f"{n_shards} data shards; pick chunk_rows so chunks "
                "distribute evenly"
            )
        bits, n_per = dmat.bits, n // n_shards
        data = dmat.packed_bins().packed
        chunk_rows = dmat.chunk_rows
    elif cfg.compress_matrix:
        # Re-pack per shard so each shard's words decode independently.
        # Cached on the DeviceDMatrix: the dense-bins transient (the matrix
        # DESIGN.md §2 bans from steady state) exists once per shard count,
        # not once per fit.
        bits = dmat.bits
        n_per = n // n_shards
        chunk_rows = None
        data = dmat._shard_pack_cache.get(n_shards)
        if data is None:
            bins = dmat.matrix.unpack()
            packed_shards = [
                C.pack(bins[i * n_per : (i + 1) * n_per], bits)
                for i in range(n_shards)
            ]
            data = jnp.concatenate(packed_shards, axis=1)  # (F, n_shards*W)
            dmat._shard_pack_cache[n_shards] = data
    else:
        data = dmat.matrix.unpack()
        bits, n_per, chunk_rows = None, None, None

    axes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    row_sharding = jax.NamedSharding(mesh, P(axes))
    if chunk_rows is not None:
        data_spec = P(axes, None, None)  # whole chunks per shard
    elif cfg.compress_matrix:
        data_spec = P(None, axes)
    else:
        data_spec = P(axes, None)
    data_sharding = jax.NamedSharding(mesh, data_spec)
    y = jax.device_put(dmat.label, row_sharding)
    data = jax.device_put(data, data_sharding)
    round_fn = make_distributed_round(
        cfg, obj, mesh, data_axes, n_rows_per_shard=n_per, bits=bits,
        chunk_rows=chunk_rows,
    )

    from repro.core import booster as B  # lazy: avoid import cycle

    apply_eval = _APPLY_EVAL_CACHE.get(cfg)
    if apply_eval is None:
        apply_eval = _APPLY_EVAL_CACHE[cfg] = jax.jit(
            lambda stacked, pb, m, _cfg=cfg:
                B._apply_stacked_trees(_cfg, stacked, pb, m)
        )

    train_kw = O.config_kwargs(cfg)  # group_ids is single-device only
    stoch = SMP.stochastic_params(cfg)
    base_key = jax.random.PRNGKey(cfg.seed) if stoch is not None else None

    sentinel = cfg.numeric_check != "off"

    def run(length, start_round, margins, eval_margins):
        margins = jax.device_put(margins, row_sharding)
        trees, tr_rows, ev_rows, ok_rows = [], [], [], []
        for r in range(length):
            if stoch is None:
                out = round_fn(data, margins, y, cuts)
            else:
                # Same fold path as the single-device scan body, from the
                # ABSOLUTE round index — single- and multi-device fits draw
                # identical samples/masks (DESIGN.md §12).
                rkey = jax.random.fold_in(
                    base_key, jnp.asarray(start_round + r, jnp.int32)
                )
                out = round_fn(data, margins, y, cuts, rkey)
            if sentinel:
                stacked, margins, ok = out
                ok_rows.append(ok)
            else:
                stacked, margins = out
            trees.append(stacked)
            eval_margins = tuple(
                apply_eval(stacked, pb, em)
                for pb, em in zip(eval_pbs, eval_margins)
            )
            if track_metric:
                tr_rows.append(tuple(
                    m.fn(margins, y, **train_kw).astype(jnp.float32)
                    for m in metrics
                ))
            ev_rows.append(tuple(
                tuple(m.fn(em, ey, **ex).astype(jnp.float32) for m in metrics)
                for em, ey, ex in zip(eval_margins, eval_ys, eval_extras)
            ))
        all_trees = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        tr_metrics = tuple(
            jnp.stack([row[j] for row in tr_rows])
            for j in range(len(metrics))
        ) if track_metric else ()
        ev_metrics = tuple(
            tuple(jnp.stack([row[i][j] for row in ev_rows])
                  for j in range(len(metrics)))
            for i in range(len(eval_pbs))
        )
        flags = jnp.stack(ok_rows) if sentinel else ()
        return margins, all_trees, tr_metrics, eval_margins, ev_metrics, flags

    return run


def train_distributed(
    x,
    y,
    cfg,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    verbose_every: int = 0,
):
    """Deprecated shim: quantises x and runs Booster.fit(dtrain, mesh=mesh).

    Returns the same Booster object as single-device training (the old
    (ensemble, margins, history) tuple is reachable as attributes)."""
    from repro.core.booster import Booster
    from repro.core.dmatrix import DeviceDMatrix

    dtrain = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
    return Booster(cfg).fit(dtrain, verbose_every=verbose_every, mesh=mesh,
                            data_axes=data_axes)
