"""Back-compat shim: the multi-device training round moved to `repro.dist`.

The shard_map round runner grew into a subsystem — pluggable collectives
(psum / ring / hierarchical), compressed histogram allreduce, device-sharded
sketch construction, per-round communication accounting — and lives in
`repro/dist/` (DESIGN.md §15). This module re-exports the old names so
existing imports keep working; new code should import `repro.dist`.
"""
from repro.dist.runner import (  # noqa: F401
    _APPLY_EVAL_CACHE,
    _ROUND_FN_CACHE,
    RoundInputs,
    make_chunk_runner,
    make_distributed_round,
    train_distributed,
)

__all__ = [
    "RoundInputs",
    "make_chunk_runner",
    "make_distributed_round",
    "train_distributed",
]
