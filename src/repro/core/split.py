"""Split gain evaluation via parallel prefix sum (paper §2.3, EvaluateSplit).

The paper computes split gain "by performing a scan over the gradient
histogram ... achieved on the GPU with a parallel prefix sum operation".
Here the scan is a cumulative sum over the bin axis (XLA lowers cumsum to a
log-depth parallel scan); the fused Pallas version is kernels/split_scan.py.

Sparsity awareness (XGBoost's default-direction learning, kept per DESIGN.md
§7.4): the last bin of every feature is the *missing* bin. For each candidate
threshold we evaluate both routings of the missing mass — missing-left and
missing-right — and keep the better, recording the learned default direction.

Gain formula (XGBoost objective, regularised):
  gain = 1/2 [ GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam) ] - gamma

Stochastic/constrained extensions (DESIGN.md §12), all statically gated so
the default path compiles to the identical program:

  * feature_mask — (f,) or (n_nodes, f) bool; masked-out features score
    -inf and can never win (colsample_bytree/bylevel/bynode).
  * monotone + node_bounds — per-feature direction constraints with
    per-node inherited value bounds [lower, upper]. Child weights are
    clipped to the bounds, candidate gain is computed AT the clipped
    weights (XGBoost's CalcGainGivenWeight), and splits whose clipped
    child weights violate the feature's direction are rejected.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SplitParams(NamedTuple):
    reg_lambda: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1.0


class Splits(NamedTuple):
    """Best split per node (level-local arrays of length n_nodes)."""

    gain: jax.Array  # (n,) float32, -inf if no valid split
    feature: jax.Array  # (n,) int32
    split_bin: jax.Array  # (n,) int32: bin <= split_bin goes left
    default_left: jax.Array  # (n,) bool: where missing values go
    left_sum: jax.Array  # (n, 2) float32 (G, H) of the left child
    right_sum: jax.Array  # (n, 2) float32


def _leaf_gain(g: jax.Array, h: jax.Array, lam: float) -> jax.Array:
    return (g * g) / (h + lam)


def _gain_at_weight(g: jax.Array, h: jax.Array, w: jax.Array, lam: float) -> jax.Array:
    """Objective reduction of a leaf evaluated AT weight w (XGBoost's
    CalcGainGivenWeight): -(2 G w + (H + lam) w^2). Equals G^2/(H+lam) at
    the unconstrained optimum w = -G/(H+lam)."""
    return -(2.0 * g * w + (h + lam) * w * w)


@functools.partial(jax.jit, static_argnames=("params",))
def evaluate_splits(
    hist: jax.Array,  # (n_nodes, n_features, max_bins, 2)
    parent_sum: jax.Array,  # (n_nodes, 2) total (G, H) per node
    params: SplitParams = SplitParams(),
    feature_mask: jax.Array | None = None,  # (f,) or (n_nodes, f) bool
    monotone: jax.Array | None = None,  # (f,) int32 in {-1, 0, 1}
    node_bounds: jax.Array | None = None,  # (n_nodes, 2) [lower, upper]
) -> Splits:
    n_nodes, n_features, max_bins, _ = hist.shape
    lam, gamma, mcw = params.reg_lambda, params.gamma, params.min_child_weight

    g, h = hist[..., 0], hist[..., 1]  # (n, f, b)
    g_tot = parent_sum[:, None, 0:1]  # (n, 1, 1)
    h_tot = parent_sum[:, None, 1:2]
    g_miss = g[..., -1:]  # missing bin mass (n, f, 1)
    h_miss = h[..., -1:]

    # Prefix sums over value bins (excluding the missing bin), computed ONCE
    # and shared between candidate scoring and the winning-split gather.
    # Candidate threshold at value-bin b means: bin <= b goes left. The last
    # value bin is excluded as a threshold (nothing would go right).
    gl_full = jnp.cumsum(g[..., :-1], axis=-1)  # (n, f, b-1)
    hl_full = jnp.cumsum(h[..., :-1], axis=-1)
    gl = gl_full[..., :-1]  # (n, f, b-2)
    hl = hl_full[..., :-1]

    if monotone is None:
        parent = _leaf_gain(g_tot, h_tot, lam)

        def direction_gain(gl_, hl_):
            gr_, hr_ = g_tot - gl_, h_tot - hl_
            gain = 0.5 * (
                _leaf_gain(gl_, hl_, lam) + _leaf_gain(gr_, hr_, lam) - parent
            ) - gamma
            ok = (hl_ >= mcw) & (hr_ >= mcw)
            return jnp.where(ok, gain, -jnp.inf)
    else:
        # Constrained evaluation: weights clipped to the node's inherited
        # bounds, gain computed at the clipped weights, direction-violating
        # candidates rejected. node_bounds is required alongside monotone.
        lo = node_bounds[:, 0][:, None, None]  # (n, 1, 1)
        hi = node_bounds[:, 1][:, None, None]
        c = monotone[None, :, None].astype(jnp.int32)  # (1, f, 1)
        w_parent = jnp.clip(-g_tot / (h_tot + lam), lo, hi)
        parent = _gain_at_weight(g_tot, h_tot, w_parent, lam)

        def direction_gain(gl_, hl_):
            gr_, hr_ = g_tot - gl_, h_tot - hl_
            wl = jnp.clip(-gl_ / (hl_ + lam), lo, hi)
            wr = jnp.clip(-gr_ / (hr_ + lam), lo, hi)
            gain = 0.5 * (
                _gain_at_weight(gl_, hl_, wl, lam)
                + _gain_at_weight(gr_, hr_, wr, lam)
                - parent
            ) - gamma
            ok = (hl_ >= mcw) & (hr_ >= mcw)
            ok &= (c == 0) | ((c > 0) & (wl <= wr)) | ((c < 0) & (wl >= wr))
            return jnp.where(ok, gain, -jnp.inf)

    # missing-right: missing mass stays out of the left prefix.
    gain_r = direction_gain(gl, hl)
    # missing-left: missing mass joins the left child.
    gain_l = direction_gain(gl + g_miss, hl + h_miss)

    default_left = gain_l > gain_r
    gain = jnp.maximum(gain_l, gain_r)  # (n, f, b-2)

    if feature_mask is not None:
        fm = feature_mask if feature_mask.ndim == 2 else feature_mask[None, :]
        gain = jnp.where(fm[:, :, None], gain, -jnp.inf)

    flat = gain.reshape(n_nodes, -1)
    best = jnp.argmax(flat, axis=1)
    best_gain = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]
    n_thresh = max_bins - 2
    best_f = (best // n_thresh).astype(jnp.int32)
    best_b = (best % n_thresh).astype(jnp.int32)
    best_dl = jnp.take_along_axis(
        default_left.reshape(n_nodes, -1), best[:, None], axis=1
    )[:, 0]

    # Child sums at the winning (feature, bin, direction), gathered from the
    # prefix sums computed above (no recomputation).
    nf = jnp.arange(n_nodes)
    gl_w = gl_full[nf, best_f, best_b]
    hl_w = hl_full[nf, best_f, best_b]
    gl_w = gl_w + jnp.where(best_dl, g_miss[nf, best_f, 0], 0.0)
    hl_w = hl_w + jnp.where(best_dl, h_miss[nf, best_f, 0], 0.0)
    left_sum = jnp.stack([gl_w, hl_w], axis=-1)
    right_sum = parent_sum - left_sum

    return Splits(
        gain=best_gain,
        feature=best_f,
        split_bin=best_b,
        default_left=best_dl,
        left_sum=left_sum,
        right_sum=right_sum,
    )


def leaf_value(sum_gh: jax.Array, reg_lambda: float) -> jax.Array:
    """Optimal leaf weight -G/(H+lambda). sum_gh (..., 2) -> (...)."""
    return -sum_gh[..., 0] / (sum_gh[..., 1] + reg_lambda)
