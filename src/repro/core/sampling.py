"""Stochastic training context: per-tree sampling + constraints (DESIGN.md §12).

XGBoost's stochastic regularisers (Chen & Guestrin 2016 §2.3) and monotone
constraints, threaded through the construction stack as ONE object:

  * `StochasticParams` — the static policy (subsample / colsample fractions,
    monotone constraint vector). Hashable, so it rides inside BoosterConfig
    and the compiled-fn cache keys.
  * `TreeContext` — the per-tree traced state: a PRNG key folded
    deterministically from `(seed, round, class)`, the statically-shaped
    sampled-row buffer (or None in masked mode), and the per-tree feature
    mask. A registered pytree, so it flows through jit / lax.scan /
    shard_map next to the data.

Determinism contract: every random draw derives from
`fold_in(fold_in(PRNGKey(seed), round), class)` plus a fixed integer tag per
draw site, and each draw is a function of GLOBAL sizes only (n_rows total,
n_features). Distributed shards therefore compute bit-identical masks and
row selections by replaying the same replicated computation — no collective
is needed to agree on the sample, and the per-level histogram psum is
unchanged (each shard just slices its rows out of the shared selection).

Row subsampling has two executions with identical semantics:

  * compact mode (single-device default): the selected `m = round(n *
    subsample)` row ids are compacted, ascending, into a static buffer;
    histograms are built only over that buffer via the `*_rows` builders,
    so a subsampled round does proportionally less scatter work.
  * masked mode (distributed / kernel builders): unselected rows keep
    their (g, h) zeroed instead. Adding 0.0 terms in the same row order
    leaves f32 bin sums bitwise unchanged, so the two modes agree exactly
    per shard.

GOSS (`sampling_method="goss"`, Ke et al. 2017) rides the same two
executions: keep the top-`a*n` rows by |g|, uniformly sample `b*n` of the
rest and amplify their (g, h) by (1 - a) / b. Unlike uniform subsampling
the selection depends on the DATA (the gradient vector), so distributed
shards all_gather gh first and replay one replicated global selection —
see `make_tree_context(axis_name=...)` and DESIGN.md §17.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Fixed fold_in tags keep the draw sites' key streams disjoint.
TAG_ROWS = 0x517C0DE1
TAG_COLS_TREE = 0x517C0DE2
TAG_COLS_LEVEL = 0x517C0DE3
TAG_COLS_NODE = 0x517C0DE4
TAG_GOSS = 0x517C0DE5


class StochasticParams(NamedTuple):
    """Static sampling/constraint policy (hashable; lives in cache keys).

    `monotone` is a per-feature tuple of {-1, 0, +1} or None; fractions are
    in (0, 1]. A value of None for the whole object (see
    `stochastic_params`) means "fully deterministic defaults" and selects
    the untouched pre-refactor code path bit for bit.
    """

    subsample: float = 1.0
    colsample_bytree: float = 1.0
    colsample_bylevel: float = 1.0
    colsample_bynode: float = 1.0
    monotone: tuple | None = None
    sampling_method: str = "uniform"
    top_rate: float = 0.2
    other_rate: float = 0.1

    @property
    def row_sampling(self) -> bool:
        return self.subsample < 1.0

    @property
    def goss(self) -> bool:
        return self.sampling_method == "goss"

    @property
    def monotone_on(self) -> bool:
        return self.monotone is not None and any(self.monotone)


def stochastic_params(cfg) -> StochasticParams | None:
    """BoosterConfig -> StochasticParams, or None when every knob is at its
    default (the None signals callers to keep the exact legacy program)."""
    mono = cfg.monotone_constraints
    if mono is not None and not any(mono):
        mono = None
    if (
        cfg.subsample >= 1.0
        and cfg.colsample_bytree >= 1.0
        and cfg.colsample_bylevel >= 1.0
        and cfg.colsample_bynode >= 1.0
        and mono is None
        and cfg.sampling_method == "uniform"
    ):
        return None
    return StochasticParams(
        subsample=cfg.subsample,
        colsample_bytree=cfg.colsample_bytree,
        colsample_bylevel=cfg.colsample_bylevel,
        colsample_bynode=cfg.colsample_bynode,
        monotone=mono,
        sampling_method=cfg.sampling_method,
        top_rate=cfg.top_rate,
        other_rate=cfg.other_rate,
    )


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["key", "row_ids", "feature_mask"],
    meta_fields=["params"],
)
@dataclass(frozen=True)
class TreeContext:
    """Per-tree stochastic state, threaded through grow_tree.

    key: per-tree PRNG key (fold path: seed -> round -> class).
    row_ids: (m,) int32 ascending global row ids of the subsample, or None
      (masked mode / no row sampling). When set, the gh passed alongside is
      already gathered to the buffer, and positions/histograms/routing all
      live in buffer space.
    feature_mask: (f,) bool per-tree column sample, or None. Level/node
      masks are drawn inside grow_tree from `key` (they need the level id).
    params: the static StochasticParams policy.
    """

    key: jax.Array
    row_ids: jax.Array | None
    feature_mask: jax.Array | None
    params: StochasticParams


def sample_size(n: int, frac: float) -> int:
    """Static sample size: round(n * frac), at least 1 (XGBoost keeps a
    non-empty sample for any frac > 0)."""
    return max(1, int(round(n * frac)))


def _rank_along_last(u: jax.Array) -> jax.Array:
    """Rank of each element within its last axis (0 = smallest). Double
    argsort: deterministic under ties (lower index wins)."""
    return jnp.argsort(jnp.argsort(u, axis=-1), axis=-1)


def row_selection_mask(key: jax.Array, n: int, m: int) -> jax.Array:
    """(n,) bool mask with EXACTLY m True entries, a deterministic function
    of (key, n, m) only — identical on every shard and device count."""
    u = jax.random.uniform(jax.random.fold_in(key, TAG_ROWS), (n,))
    order = jnp.argsort(u)
    return jnp.zeros(n, bool).at[order[:m]].set(True)


def compact_row_ids(sel: jax.Array, m: int) -> jax.Array:
    """Compact a selection mask with m True entries into an ascending (m,)
    int32 row-id buffer (static shape)."""
    n = sel.shape[0]
    order = jnp.cumsum(sel) - 1
    return (
        jnp.zeros(m, jnp.int32)
        .at[jnp.where(sel, order, m)]
        .set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    )


def goss_sizes(n_total: int, params: StochasticParams) -> tuple[int, int]:
    """(m_top, m_other): static GOSS buffer sizes over the GLOBAL row count.
    m_other is clipped so top + rest never exceeds n_total (tiny-n corner
    where round(n * a) + round(n * b) > n)."""
    m_top = sample_size(n_total, params.top_rate)
    m_other = min(sample_size(n_total, params.other_rate), n_total - m_top)
    return m_top, max(m_other, 0)


def goss_selection(
    key: jax.Array, g_abs: jax.Array, m_top: int, m_other: int
) -> tuple[jax.Array, jax.Array]:
    """GOSS row selection (Ke et al. 2017, via the XGBoost lineage): keep
    the m_top rows with largest |g|, then uniformly sample m_other of the
    remainder. Returns (selected, rest) bool masks over the full row range
    — `rest` marks the uniformly-sampled small-gradient rows that need the
    (1 - a) / b reweighting.

    Deterministic contract matches `row_selection_mask`: a pure function of
    (key, |g|, sizes) over GLOBAL rows, with |g| ties broken by row index
    (double-argsort rank), so every shard and device count replays the
    identical selection.
    """
    top = _rank_along_last(-g_abs) < m_top
    u = jax.random.uniform(jax.random.fold_in(key, TAG_GOSS), g_abs.shape)
    u = jnp.where(top, jnp.inf, u)  # top rows never drawn again as "rest"
    rest = _rank_along_last(u) < m_other
    return top | rest, rest


def feature_sample_mask(
    key: jax.Array, k: int, f: int, base_mask: jax.Array | None = None,
    n_nodes: int | None = None,
) -> jax.Array:
    """Sample k features without replacement from the base_mask's allowed
    set: keep the k smallest uniforms (disallowed features score +inf).
    Returns (f,) bool, or (n_nodes, f) when n_nodes is given (independent
    draw per node)."""
    shape = (f,) if n_nodes is None else (n_nodes, f)
    u = jax.random.uniform(key, shape)
    if base_mask is not None:
        u = jnp.where(base_mask, u, jnp.inf)
    return _rank_along_last(u) < k


def tree_feature_mask(
    key: jax.Array, f: int, params: StochasticParams
) -> jax.Array | None:
    """The per-tree column sample (colsample_bytree), or None when off."""
    if params.colsample_bytree >= 1.0:
        return None
    k = sample_size(f, params.colsample_bytree)
    return feature_sample_mask(jax.random.fold_in(key, TAG_COLS_TREE), k, f)


def level_feature_counts(f: int, params: StochasticParams) -> tuple[int, int]:
    """(k_level, k_node): static per-level / per-node feature sample sizes,
    applied hierarchically (bylevel samples from bytree's set, bynode from
    bylevel's — XGBoost's nesting)."""
    k_tree = (
        sample_size(f, params.colsample_bytree)
        if params.colsample_bytree < 1.0 else f
    )
    k_level = (
        sample_size(k_tree, params.colsample_bylevel)
        if params.colsample_bylevel < 1.0 else k_tree
    )
    k_node = (
        sample_size(k_level, params.colsample_bynode)
        if params.colsample_bynode < 1.0 else k_level
    )
    return k_level, k_node


def level_feature_mask(
    ctx: TreeContext, level: int, n_nodes: int, f: int
) -> jax.Array | None:
    """Combined (tree ∩ level ∩ node) feature mask for one level: (f,) or
    (n_nodes, f) bool, or None when no column sampling is active. Pure
    function of (ctx.key, level) — identical on every shard."""
    p = ctx.params
    mask = ctx.feature_mask  # (f,) or None
    if p.colsample_bylevel >= 1.0 and p.colsample_bynode >= 1.0:
        return mask
    k_level, k_node = level_feature_counts(f, p)
    if p.colsample_bylevel < 1.0:
        lkey = jax.random.fold_in(
            jax.random.fold_in(ctx.key, TAG_COLS_LEVEL), level
        )
        mask = feature_sample_mask(lkey, k_level, f, base_mask=mask)
    if p.colsample_bynode < 1.0:
        nkey = jax.random.fold_in(
            jax.random.fold_in(ctx.key, TAG_COLS_NODE), level
        )
        mask = feature_sample_mask(
            nkey, k_node, f, base_mask=mask, n_nodes=n_nodes
        )
    return mask


def make_tree_context(
    params: StochasticParams,
    tree_key: jax.Array,
    gh: jax.Array,
    n_features: int,
    *,
    compact: bool = True,
    n_total: int | None = None,
    row_offset=0,
    axis_name=None,
) -> tuple[TreeContext, jax.Array]:
    """Build the per-tree context and the gh view grow_tree consumes.

    compact=True (single-device): returns gh gathered to the static (m, 2)
    sampled-row buffer recorded in ctx.row_ids.
    compact=False (distributed shards / kernel builders): returns gh with
    unselected rows zeroed (row_ids=None). `n_total` is the GLOBAL row
    count and `row_offset` this shard's first global row — the selection
    is drawn over n_total and sliced, so every shard sees the same global
    sample regardless of device count.

    GOSS (params.goss) is data-dependent: the selection needs the GLOBAL
    |g| vector, not just global sizes. Under shard_map callers pass
    `axis_name` (the data axes) so gh is all_gather'd — the gather order
    matches the runner's row linearisation, every shard then computes the
    identical replicated selection and slices its rows at `row_offset`.
    Selected small-gradient rows get BOTH g and h scaled by (1 - a) / b;
    the per-row products are the same f32 values in compact and masked
    mode, so the two executions stay bitwise-equal per histogram bin.
    """
    n_local = gh.shape[0]
    n_total = n_local if n_total is None else n_total
    row_ids = None
    if params.goss:
        m_top, m_other = goss_sizes(n_total, params)
        if not compact and axis_name is not None and n_total != n_local:
            gh_all = jax.lax.all_gather(gh, axis_name, tiled=True)
        else:
            gh_all = gh  # single shard: local rows ARE the global rows
        sel, rest = goss_selection(
            tree_key, jnp.abs(gh_all[:, 0]), m_top, m_other
        )
        amp = (1.0 - params.top_rate) / params.other_rate
        w = jnp.where(rest, jnp.float32(amp), jnp.float32(1.0))
        if compact:
            if n_total != n_local:
                raise ValueError(
                    "compact GOSS needs the full row range on one shard "
                    f"(n_total={n_total}, local={n_local})"
                )
            row_ids = compact_row_ids(sel, m_top + m_other)
            gh = gh[row_ids] * w[row_ids][:, None]
        else:
            off = (jnp.asarray(row_offset, jnp.int32),)
            sel_local = jax.lax.dynamic_slice(sel, off, (n_local,))
            w_local = jax.lax.dynamic_slice(w, off, (n_local,))
            gh = jnp.where(sel_local[:, None], gh * w_local[:, None], 0.0)
    elif params.row_sampling:
        m = sample_size(n_total, params.subsample)
        sel = row_selection_mask(tree_key, n_total, m)
        if compact:
            if n_total != n_local:
                raise ValueError(
                    "compact row sampling needs the full row range on one "
                    f"shard (n_total={n_total}, local={n_local})"
                )
            row_ids = compact_row_ids(sel, m)
            gh = gh[row_ids]
        else:
            sel_local = jax.lax.dynamic_slice(
                sel, (jnp.asarray(row_offset, jnp.int32),), (n_local,)
            )
            gh = jnp.where(sel_local[:, None], gh, 0.0)
    return (
        TreeContext(
            key=tree_key,
            row_ids=row_ids,
            feature_mask=tree_feature_mask(tree_key, n_features, params),
            params=params,
        ),
        gh,
    )
