"""Resilience primitives shared across the training runtime (DESIGN.md §13).

This module owns the *vocabulary* of failure — the exception taxonomy, the
numeric-sentinel policies, OOM classification, retry/backoff, and chunk
checksums — so that booster.py, dmatrix.py, distributed.py and
checkpoint/io.py all speak the same language about what failed and what the
caller may do about it. Nothing here touches jax except the small traced
helpers (`clamp_gradients`, `finite_flags`) that run inside the compiled
round step.
"""
from __future__ import annotations

import time
import zlib
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from ..checkpoint.io import CheckpointError  # noqa: F401  (re-export)


class TrainingFault(RuntimeError):
    """Base class for failures the resilience layer detects and names."""


class NumericError(TrainingFault):
    """Non-finite gradients/hessians/leaf weights surfaced by the in-scan
    sentinel under the ``numeric_check="raise"`` policy."""


class DivergenceError(TrainingFault):
    """Eval metric became non-finite — the fit is diverging and later
    rounds can only make it worse."""


class ChunkIntegrityError(TrainingFault):
    """An external-memory chunk failed its crc32 on page-in: the bytes the
    device would train on are not the bytes recorded at build time."""


NUMERIC_POLICIES = ("off", "raise", "warn_skip", "clamp")

# Gradient/hessian magnitudes beyond this are treated as runaway under the
# "clamp" policy; generous enough that no healthy objective ever hits it.
CLAMP_LIMIT = 1e10


def validate_numeric_policy(policy: str) -> None:
    if policy not in NUMERIC_POLICIES:
        raise ValueError(
            f"numeric_check must be one of {NUMERIC_POLICIES}, got {policy!r}"
        )


# --------------------------------------------------------------------------
# Traced helpers (used inside the compiled round step)
# --------------------------------------------------------------------------

def clamp_gradients(gh: jnp.ndarray) -> jnp.ndarray:
    """Replace NaN with 0 and clip +-inf / runaway magnitudes, keeping the
    round usable under the "clamp" policy."""
    gh = jnp.nan_to_num(gh, nan=0.0, posinf=CLAMP_LIMIT, neginf=-CLAMP_LIMIT)
    return jnp.clip(gh, -CLAMP_LIMIT, CLAMP_LIMIT)


def finite_flags(*arrays: jnp.ndarray) -> jnp.ndarray:
    """Scalar bool: True iff every element of every array is finite. Cheap —
    one fused reduce per array, no host sync (the flag rides the ys-stack
    and is inspected host-side once per ES chunk)."""
    ok = jnp.asarray(True)
    for a in arrays:
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(a)))
    return ok


# --------------------------------------------------------------------------
# OOM classification + retry/backoff
# --------------------------------------------------------------------------

def is_oom(exc: BaseException) -> bool:
    """True for XLA's RESOURCE_EXHAUSTED family (and the simulated stand-in
    from repro.testing.faults, which embeds the same marker)."""
    msg = str(exc)
    return "RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg


def with_retries(
    fn: Callable[[], "object"],
    *,
    retries: int = 0,
    backoff: float = 0.0,
    retry_on: tuple = (IOError, OSError),
    describe: str = "operation",
    on_retry: Callable[[int, BaseException], None] | None = None,
):
    """Run `fn`, retrying up to `retries` times on `retry_on` exceptions with
    exponential backoff (backoff * 2**attempt seconds). The final failure is
    re-raised unchanged so callers keep the original type."""
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as exc:
            if attempt >= retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            if backoff > 0:
                time.sleep(backoff * (2.0 ** attempt))
            attempt += 1


# --------------------------------------------------------------------------
# Chunk integrity
# --------------------------------------------------------------------------

def crc32_chunks(stack: np.ndarray) -> tuple:
    """crc32 of each leading-axis slot of a host array (the per-chunk packed
    words of an ExternalDMatrix). Returned as a tuple so it hashes and
    serialises trivially."""
    arr = np.ascontiguousarray(stack)
    return tuple(zlib.crc32(arr[i].tobytes()) & 0xFFFFFFFF
                 for i in range(arr.shape[0]))


def verify_chunk_crcs(stack: np.ndarray, expected: Sequence[int],
                      context: str = "ExternalDMatrix") -> None:
    """Raise ChunkIntegrityError naming every chunk whose crc32 no longer
    matches the build-time record."""
    got = crc32_chunks(stack)
    bad = [i for i, (g, e) in enumerate(zip(got, expected)) if g != e]
    if bad:
        raise ChunkIntegrityError(
            f"{context}: chunk checksum mismatch on page-in for chunk(s) "
            f"{bad} — data corrupted between build and load "
            f"(expected crc32 {[expected[i] for i in bad]}, "
            f"got {[got[i] for i in bad]})"
        )
