"""Ensemble prediction (paper §2.4).

The paper assigns one GPU thread per instance and iterates trees
sequentially, noting tree traversal is branch-heavy. The TPU adaptation
(DESIGN.md §3) replaces divergent per-thread branching with a *level-wise
vectorised gather*: all rows advance one tree level per fori_loop step, so
the computation stays dense (a gather + select per level) and the ensemble
is folded with lax.scan over stacked tree arrays.

Two input modes, as in XGBoost:
  * binned   — training-set prediction on the quantised matrix (bin-space
    thresholds). Used inside the boosting loop (Figure 1's Predict box).
  * raw      — float inputs vs raw-space thresholds, NaN = missing.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import compress as C
from repro.core.tree import Tree


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["feature", "split_bin", "threshold", "default_left",
                 "leaf_value", "is_leaf", "gain"],
    meta_fields=["n_classes", "base_score"],
)
@dataclasses.dataclass(frozen=True)
class Ensemble:
    """Stacked tree arenas: every field has leading axis n_trees.

    For multiclass, trees are laid out round-robin: tree t predicts
    class t % n_classes (XGBoost's convention). n_classes/base_score are
    static pytree metadata so jit specialises on them.

    `gain` carries each split node's objective reduction (-inf on leaves
    and inactive arena slots) — the source for Booster.feature_importances.
    """

    feature: jax.Array  # (t, a) int32
    split_bin: jax.Array  # (t, a) int32
    threshold: jax.Array  # (t, a) float32
    default_left: jax.Array  # (t, a) bool
    leaf_value: jax.Array  # (t, a) float32
    is_leaf: jax.Array  # (t, a) bool
    gain: jax.Array  # (t, a) float32, -inf = not a split
    n_classes: int = 1
    base_score: float = 0.0

    def _replace(self, **kw) -> "Ensemble":
        return dataclasses.replace(self, **kw)

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def max_depth(self) -> int:
        return int(self.feature.shape[1] + 1).bit_length() - 2


def stack_trees(trees: list[Tree], n_classes: int = 1, base_score: float = 0.0) -> Ensemble:
    st = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    return Ensemble(
        feature=st.feature,
        split_bin=st.split_bin,
        threshold=st.threshold,
        default_left=st.default_left,
        leaf_value=st.leaf_value,
        is_leaf=st.is_leaf,
        gain=st.gain,
        n_classes=n_classes,
        base_score=base_score,
    )


_ENSEMBLE_ARRAY_FIELDS = (
    "feature", "split_bin", "threshold", "default_left", "leaf_value",
    "is_leaf", "gain",
)


def concat_ensembles(a: Ensemble, b: Ensemble) -> Ensemble:
    """Append b's trees after a's (continued training). Static metadata must
    agree — the two halves describe one model."""
    if a.n_classes != b.n_classes or a.base_score != b.base_score:
        raise ValueError("cannot concatenate ensembles with different metadata")
    if a.feature.shape[1] != b.feature.shape[1]:
        raise ValueError("cannot concatenate ensembles with different arenas")
    return Ensemble(
        **{f: jnp.concatenate([getattr(a, f), getattr(b, f)], axis=0)
           for f in _ENSEMBLE_ARRAY_FIELDS},
        n_classes=a.n_classes,
        base_score=a.base_score,
    )


def truncate_rounds(ens: Ensemble, n_rounds: int) -> Ensemble:
    """Keep only the first n_rounds boosting rounds (n_rounds * n_classes
    trees, round-robin layout) — used by early stopping."""
    keep = n_rounds * ens.n_classes
    return ens._replace(
        **{f: getattr(ens, f)[:keep] for f in _ENSEMBLE_ARRAY_FIELDS}
    )


def slice_rounds(ens: Ensemble, start: int, end: int) -> Ensemble:
    """Keep boosting rounds [start, end) — XGBoost `iteration_range`
    semantics (end=0 means "through the last round"). base_score is part of
    the model, not of any round, so it survives the slice unchanged."""
    n_rounds = ens.n_trees // ens.n_classes
    if end == 0:
        end = n_rounds
    if not (0 <= start < end <= n_rounds):
        raise ValueError(
            f"iteration_range ({start}, {end}) out of range for a model "
            f"with {n_rounds} rounds"
        )
    lo, hi = start * ens.n_classes, end * ens.n_classes
    return ens._replace(
        **{f: getattr(ens, f)[lo:hi] for f in _ENSEMBLE_ARRAY_FIELDS}
    )


def _traverse(tree_arrays, x_row_lookup, max_depth: int) -> jax.Array:
    """Level-wise traversal for one stacked tree over all rows at once.

    x_row_lookup(feature_ids) -> (go_left_bool, is_missing_bool) per row.
    """
    feature, default_left, leaf_value, is_leaf = tree_arrays

    def body(_, node):
        f = feature[node]
        go_left, is_missing = x_row_lookup(f, node)
        go_left = jnp.where(is_missing, default_left[node], go_left)
        child = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
        return jnp.where(is_leaf[node], node, child)

    n_rows = x_row_lookup.n_rows
    node = jnp.zeros(n_rows, jnp.int32)
    node = jax.lax.fori_loop(0, max_depth, body, node)
    return leaf_value[node]


def traverse_tree_binned(
    feature, split_bin, default_left, leaf_value, is_leaf,
    bins: jax.Array, missing_bin: int, max_depth: int,
) -> jax.Array:
    """Leaf outputs (n_rows,) of ONE tree arena over dense quantised rows.

    Used directly by the boosting round step for incremental margin updates
    (no single-tree Ensemble needs to be constructed)."""
    nr = bins.shape[0]

    class Lookup:
        n_rows = nr

        def __call__(self, f, node):
            b = jnp.take_along_axis(bins, f[:, None], axis=1)[:, 0]
            return b <= split_bin[node], b == missing_bin

    return _traverse((feature, default_left, leaf_value, is_leaf), Lookup(), max_depth)


def traverse_tree_packed(
    feature, split_bin, default_left, leaf_value, is_leaf,
    packed: jax.Array, bits: int, n_rows: int, missing_bin: int, max_depth: int,
) -> jax.Array:
    """traverse_tree_binned on the bit-packed matrix: each level gathers one
    uint32 word per row and extracts the node's split-feature bin with a
    shift/mask — the dense (n, f) bins matrix never exists."""
    nr = n_rows

    class Lookup:
        n_rows = nr

        def __call__(self, f, node):
            b = C.gather_feature_bins(packed, bits, f)
            return b <= split_bin[node], b == missing_bin

    return _traverse((feature, default_left, leaf_value, is_leaf), Lookup(), max_depth)


def traverse_tree_chunked(
    feature, split_bin, default_left, leaf_value, is_leaf,
    packed: jax.Array, bits: int, chunk_rows: int, n_rows: int,
    missing_bin: int, max_depth: int,
) -> jax.Array:
    """traverse_tree_packed over the chunk-stacked matrix (external-memory
    path): a lax.scan over chunks traverses each chunk's rows against that
    chunk's words. Traversal is elementwise per row (gather + select), so
    leaf outputs are bit-identical to the flat-layout version."""

    def one_chunk(carry, words):
        return carry, traverse_tree_packed(
            feature, split_bin, default_left, leaf_value, is_leaf,
            words, bits, chunk_rows, missing_bin, max_depth,
        )

    _, leaves = jax.lax.scan(one_chunk, None, packed)  # (n_chunks, chunk_rows)
    return leaves.reshape(-1)[:n_rows]


@functools.partial(
    jax.jit,
    static_argnames=("bits", "chunk_rows", "n_rows", "missing_bin", "max_depth"),
)
def predict_binned_chunked(
    ens: Ensemble, packed: jax.Array, bits: int, chunk_rows: int,
    n_rows: int, missing_bin: int, max_depth: int,
) -> jax.Array:
    """predict_binned straight from the chunk-stacked packed matrix."""

    def one_tree(carry, t):
        feature, split_bin, default_left, leaf_value, is_leaf = t
        return carry, traverse_tree_chunked(
            feature, split_bin, default_left, leaf_value, is_leaf,
            packed, bits, chunk_rows, n_rows, missing_bin, max_depth,
        )

    _, leaves = jax.lax.scan(
        one_tree,
        None,
        (ens.feature, ens.split_bin, ens.default_left, ens.leaf_value, ens.is_leaf),
    )
    return _fold_classes(leaves, ens, n_rows)


@functools.partial(jax.jit, static_argnames=("missing_bin", "max_depth"))
def predict_binned(
    ens: Ensemble, bins: jax.Array, missing_bin: int, max_depth: int
) -> jax.Array:
    """Margins (n_rows, n_classes) from the quantised matrix."""
    n_rows = bins.shape[0]

    def one_tree(carry, t):
        feature, split_bin, default_left, leaf_value, is_leaf = t
        return carry, traverse_tree_binned(
            feature, split_bin, default_left, leaf_value, is_leaf,
            bins, missing_bin, max_depth,
        )

    _, leaves = jax.lax.scan(
        one_tree,
        None,
        (ens.feature, ens.split_bin, ens.default_left, ens.leaf_value, ens.is_leaf),
    )  # (n_trees, n_rows)
    return _fold_classes(leaves, ens, n_rows)


@functools.partial(
    jax.jit, static_argnames=("bits", "n_rows", "missing_bin", "max_depth")
)
def predict_binned_packed(
    ens: Ensemble, packed: jax.Array, bits: int, n_rows: int,
    missing_bin: int, max_depth: int,
) -> jax.Array:
    """predict_binned straight from the bit-packed matrix (DESIGN.md §2)."""

    def one_tree(carry, t):
        feature, split_bin, default_left, leaf_value, is_leaf = t
        return carry, traverse_tree_packed(
            feature, split_bin, default_left, leaf_value, is_leaf,
            packed, bits, n_rows, missing_bin, max_depth,
        )

    _, leaves = jax.lax.scan(
        one_tree,
        None,
        (ens.feature, ens.split_bin, ens.default_left, ens.leaf_value, ens.is_leaf),
    )
    return _fold_classes(leaves, ens, n_rows)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def predict_raw(ens: Ensemble, x: jax.Array, max_depth: int) -> jax.Array:
    """Margins (n_rows, n_classes) from raw float inputs (NaN = missing)."""
    n_rows = x.shape[0]

    def one_tree(carry, t):
        feature, threshold, default_left, leaf_value, is_leaf = t

        class Lookup:
            n_rows = x.shape[0]

            def __call__(self, f, node):
                v = jnp.take_along_axis(x, f[:, None], axis=1)[:, 0]
                return v <= threshold[node], jnp.isnan(v)

        return carry, _traverse(
            (feature, default_left, leaf_value, is_leaf), Lookup(), max_depth
        )

    _, leaves = jax.lax.scan(
        one_tree,
        None,
        (ens.feature, ens.threshold, ens.default_left, ens.leaf_value, ens.is_leaf),
    )
    return _fold_classes(leaves, ens, n_rows)


def _fold_classes(leaves: jax.Array, ens: Ensemble, n_rows: int) -> jax.Array:
    """(n_trees, n_rows) leaf outputs -> (n_rows, n_classes) margins."""
    k = ens.n_classes
    n_trees = leaves.shape[0]
    n_rounds = n_trees // k
    per_class = leaves.reshape(n_rounds, k, n_rows).sum(axis=0)  # (k, n_rows)
    return per_class.T + ens.base_score
