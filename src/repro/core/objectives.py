"""On-device gradient evaluation (paper §2.5).

First/second-order gradients per instance, elementwise over the row shard —
the paper's eqs. (1)-(2) for logistic loss plus squared error. The paper
notes multiclass and ranking were CPU-evaluated, with GPU versions "a work
in progress"; here ALL objectives are on-device JAX (a beyond-paper
completion, noted in EXPERIMENTS.md):

  * reg:squarederror   g = yhat - y            h = 1
  * binary:logistic    g = sigmoid(m) - y      h = p(1-p)          (eqs 1-2)
  * multi:softmax      g_k = p_k - [y=k]       h_k = p_k(1-p_k)
  * rank:pairwise      LambdaRank-style pairwise logistic within query groups

Each objective also provides its eval metric (RMSE / accuracy / error) so the
booster can report the paper's Table 2 columns.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Objective(NamedTuple):
    name: str
    n_outputs: Callable[[int], int]  # n_classes -> margin dims
    init_base_score: Callable[[jax.Array], float]
    grad: Callable  # (margins, y, **kw) -> gh (n, outputs, 2)
    transform: Callable  # margins -> predictions
    metric_name: str
    metric: Callable  # (margins, y) -> scalar
    maximize: bool = True  # metric direction (early stopping / best_iteration)


def _sq_grad(margins, y, **_):
    g = margins[:, 0] - y
    h = jnp.ones_like(g)
    return jnp.stack([g, h], axis=-1)[:, None, :]


def _sq_metric(margins, y):
    return jnp.sqrt(jnp.mean((margins[:, 0] - y) ** 2))


squared_error = Objective(
    name="reg:squarederror",
    n_outputs=lambda k: 1,
    init_base_score=lambda y: float(jnp.mean(y)),
    grad=_sq_grad,
    transform=lambda m: m[:, 0],
    metric_name="rmse",
    metric=_sq_metric,
    maximize=False,
)


def _logistic_grad(margins, y, **_):
    p = jax.nn.sigmoid(margins[:, 0])
    g = p - y  # eq. (1)
    h = p * (1.0 - p)  # eq. (2)
    return jnp.stack([g, h], axis=-1)[:, None, :]


def _logistic_metric(margins, y):
    return jnp.mean((margins[:, 0] > 0.0) == (y > 0.5))


logistic = Objective(
    name="binary:logistic",
    n_outputs=lambda k: 1,
    init_base_score=lambda y: 0.0,
    grad=_logistic_grad,
    transform=lambda m: jax.nn.sigmoid(m[:, 0]),
    metric_name="accuracy",
    metric=_logistic_metric,
)


def _softmax_grad(margins, y, **kw):
    k = margins.shape[1]
    p = jax.nn.softmax(margins, axis=1)
    onehot = jax.nn.one_hot(y.astype(jnp.int32), k)
    g = p - onehot
    h = p * (1.0 - p)
    return jnp.stack([g, h], axis=-1)  # (n, k, 2)


def _softmax_metric(margins, y):
    return jnp.mean(jnp.argmax(margins, axis=1) == y.astype(jnp.int32))


softmax = Objective(
    name="multi:softmax",
    n_outputs=lambda k: k,
    init_base_score=lambda y: 0.0,
    grad=_softmax_grad,
    transform=lambda m: jnp.argmax(m, axis=1),
    metric_name="accuracy",
    metric=_softmax_metric,
)


def _pairwise_grad(margins, y, group_ids=None, **_):
    """LambdaRank pairwise logistic gradients within query groups.

    For every in-group pair (i, j) with y_i > y_j the pairwise logistic loss
    log(1 + exp(-(s_i - s_j))) contributes rho = sigmoid(s_j - s_i) to g_i
    (negative) and g_j (positive), with hessian rho(1-rho). O(n^2) in the
    group — evaluated with a masked dense pair matrix (fine for benchmark
    group sizes; the paper's CPU version is the same complexity).
    """
    s = margins[:, 0]
    if group_ids is None:
        group_ids = jnp.zeros_like(s, dtype=jnp.int32)
    same = group_ids[:, None] == group_ids[None, :]
    better = (y[:, None] > y[None, :]) & same
    rho = jax.nn.sigmoid(s[None, :] - s[:, None])  # sigmoid(s_j - s_i)
    w = rho * (1.0 - rho)
    g = -jnp.sum(jnp.where(better, rho, 0.0), axis=1) + jnp.sum(
        jnp.where(better.T, rho.T, 0.0), axis=1
    )
    h = jnp.sum(jnp.where(better | better.T, w, 0.0), axis=1)
    return jnp.stack([g, jnp.maximum(h, 1e-6)], axis=-1)[:, None, :]


def _pairwise_metric(margins, y):
    # Pairwise ordering accuracy (global, proxy for NDCG on synthetic data).
    s = margins[:, 0]
    better = y[:, None] > y[None, :]
    correct = (s[:, None] > s[None, :]) & better
    denom = jnp.maximum(jnp.sum(better), 1)
    return jnp.sum(correct) / denom


pairwise_rank = Objective(
    name="rank:pairwise",
    n_outputs=lambda k: 1,
    init_base_score=lambda y: 0.0,
    grad=_pairwise_grad,
    transform=lambda m: m[:, 0],
    metric_name="pairwise_acc",
    metric=_pairwise_metric,
)

OBJECTIVES = {
    o.name: o for o in (squared_error, logistic, softmax, pairwise_rank)
}
