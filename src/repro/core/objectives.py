"""On-device gradient evaluation (paper §2.5) behind an open registry.

First/second-order gradients per instance, elementwise over the row shard —
the paper's eqs. (1)-(2) for logistic loss plus squared error. The paper
notes multiclass and ranking were CPU-evaluated, with GPU versions "a work
in progress"; here ALL objectives are on-device JAX, and the set goes
beyond the paper's four (EXPERIMENTS.md §Repro status):

  * reg:squarederror      g = yhat - y            h = 1
  * binary:logistic       g = sigmoid(m) - y      h = p(1-p)        (eqs 1-2)
  * multi:softmax         g_k = p_k - [y=k]       h_k = p_k(1-p_k)
  * rank:pairwise         LambdaRank-style pairwise logistic in query groups
  * reg:quantile          pinball loss at `quantile_alpha` (unit hessian)
  * reg:pseudohubererror  smooth L1, slope 1
  * count:poisson         log-link Poisson regression

An `Objective` carries ONLY loss structure (gradients, margin layout, base
score, prediction transform) plus the NAME of its default eval metric —
metrics themselves live in their own registry (`core/metrics.py`) and carry
their own `maximize` direction, so a new objective cannot silently early-stop
in the wrong direction (DESIGN.md §10).

Registry surface:

  * `OBJECTIVES` — name -> Objective for the built-ins
  * `register_objective(name, grad, ...)` — user plugins; registered
    objectives checkpoint by name (`checkpoint/io.py`)
  * `get_objective(name)` / `as_objective(spec)` — resolution, including
    bare `(margins, y) -> (g, h)` callables for `Booster.fit(obj=...)`,
    wrapped once and cached so repeat fits hit the compiled-fn cache
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.metrics import adapt_extra


class Objective(NamedTuple):
    name: str
    n_outputs: Callable[[int], int]  # n_classes -> margin dims
    init_base_score: Callable  # (y, **extra) -> float
    grad: Callable  # (margins, y, **extra) -> gh (n, outputs, 2)
    transform: Callable  # margins -> predictions
    default_metric: str  # metrics.py registry name (direction lives there)


OBJECTIVES: dict[str, Objective] = {}


def register_objective(
    name: str,
    grad: Callable,
    *,
    n_outputs: Callable[[int], int] | int = 1,
    init_base_score: Callable | float = 0.0,
    transform: Callable | None = None,
    default_metric: str = "rmse",
    overwrite: bool = False,
) -> Objective:
    """Register a custom training objective under `name`.

    `grad(margins, y, **extra) -> (n, n_outputs, 2)` stacked (g, h), or a
    simpler `(margins, y) -> (g, h)` pair of (n,) / (n, k) arrays — both
    trace into the compiled training scan. Registered objectives round-trip
    through `Booster.save`/`load` by name. Returns the Objective.
    """
    if name in OBJECTIVES and not overwrite:
        raise ValueError(
            f"objective {name!r} is already registered "
            "(pass overwrite=True to replace it)"
        )
    if isinstance(n_outputs, int):
        k_fixed = n_outputs
        n_outputs = lambda k, _k=k_fixed: _k  # noqa: E731
    if callable(init_base_score):
        init_base_score = adapt_extra(init_base_score)
    else:
        base_val = float(init_base_score)
        init_base_score = lambda y, **_: base_val  # noqa: E731
    obj = Objective(
        name=name,
        n_outputs=n_outputs,
        init_base_score=init_base_score,
        grad=_adapt_grad(grad),
        transform=transform if transform is not None else (lambda m: m[:, 0]),
        default_metric=default_metric,
    )
    OBJECTIVES[name] = obj
    return obj


def get_objective(name: str) -> Objective:
    obj = OBJECTIVES.get(name)
    if obj is None:
        raise ValueError(
            f"unknown objective {name!r}; built-ins: {sorted(OBJECTIVES)}. "
            "Custom losses: register_objective(name, grad) or pass a "
            "callable via Booster.fit(obj=...)"
        )
    return obj


# Bare callables wrapped once and cached by function identity: the SAME
# callable across fits resolves to the identical Objective, so the compiled
# train-fn cache (booster._TRAIN_FN_CACHE) is keyed stably and a refit with
# the same custom loss does not recompile (DESIGN.md §10).
_WRAPPED_OBJECTIVES: dict = {}


def as_objective(spec, n_classes: int = 1) -> Objective:
    """Resolve Booster.fit's `obj=` argument: a registry name, an Objective
    (e.g. the return of register_objective), or a bare callable
    `(margins, y) -> (g, h)` traced straight into the scan."""
    if isinstance(spec, Objective):
        return spec
    if isinstance(spec, str):
        return get_objective(spec)
    if callable(spec):
        obj = _WRAPPED_OBJECTIVES.get(spec)
        if obj is None:
            obj = Objective(
                name=f"custom:{getattr(spec, '__name__', 'objective')}",
                n_outputs=lambda k: k,
                init_base_score=lambda y, **_: 0.0,
                grad=_adapt_grad(spec),
                transform=lambda m: m[:, 0] if m.shape[1] == 1 else m,
                default_metric="rmse",
            )
            _WRAPPED_OBJECTIVES[spec] = obj
        return obj
    raise TypeError(f"cannot interpret {type(spec)} as an objective")


def _adapt_grad(fn: Callable) -> Callable:
    """Normalise a gradient callable to `(margins, y, **extra) -> (n, k, 2)`.

    User callables may return a `(g, h)` pair of (n,) or (n, k) arrays
    (XGBoost's custom-objective convention) and may take only the keywords
    they care about — the signature is inspected once and `extra` filtered
    to what the callable accepts. The stacked layout passes through
    untouched.
    """
    filtered = adapt_extra(fn)

    def grad(margins, y, **extra):
        out = filtered(margins, y, **extra)
        if isinstance(out, tuple):
            g, h = out
            g = jnp.asarray(g)
            h = jnp.asarray(h)
            if g.ndim == 1:
                g = g[:, None]
            if h.ndim == 1:
                h = h[:, None]
            return jnp.stack([g, h], axis=-1)
        return out

    return grad


def config_kwargs(cfg) -> dict:
    """Config-derived keywords forwarded to grad / base-score / metric
    functions (alongside dataset keywords like `group_ids`)."""
    return {"quantile_alpha": cfg.quantile_alpha}


# --- built-ins: regression -------------------------------------------------

def _sq_grad(margins, y, **_):
    g = margins[:, 0] - y
    h = jnp.ones_like(g)
    return jnp.stack([g, h], axis=-1)[:, None, :]


squared_error = register_objective(
    "reg:squarederror",
    _sq_grad,
    init_base_score=lambda y, **_: float(jnp.mean(y)),
    default_metric="rmse",
)


def _quantile_grad(margins, y, quantile_alpha=0.5, **_):
    """Pinball loss d/dm: -alpha where the target sits above the prediction,
    (1 - alpha) below. The true hessian is zero a.e.; unit hessian makes
    leaves plain quantile-gradient means (XGBoost's reg:quantileerror)."""
    err = margins[:, 0] - y
    g = jnp.where(err >= 0.0, 1.0 - quantile_alpha, -quantile_alpha)
    h = jnp.ones_like(g)
    return jnp.stack([g, h], axis=-1)[:, None, :]


quantile = register_objective(
    "reg:quantile",
    _quantile_grad,
    init_base_score=lambda y, quantile_alpha=0.5, **_: float(
        jnp.quantile(y, quantile_alpha)
    ),
    default_metric="quantile",
)


def _pseudohuber_grad(margins, y, **_):
    """Pseudo-Huber with unit slope: sqrt(1 + r^2) - 1 — quadratic near 0,
    linear in the tails (outlier-robust squared error)."""
    r = margins[:, 0] - y
    scale = jnp.sqrt(1.0 + r * r)
    g = r / scale
    h = 1.0 / (scale * scale * scale)
    return jnp.stack([g, h], axis=-1)[:, None, :]


pseudohuber = register_objective(
    "reg:pseudohubererror",
    _pseudohuber_grad,
    init_base_score=lambda y, **_: float(jnp.mean(y)),
    default_metric="mphe",
)


def _poisson_grad(margins, y, **_):
    """Poisson regression with log link: nll = exp(m) - y*m, so g = exp(m)-y
    and h = exp(m). The hessian is inflated by exp(0.7) (XGBoost's
    max_delta_step trick) to bound the leaf step when counts are sparse.

    Margins are clamped to ±30 before the exponential: exp(88) already
    overflows float32 to inf, and a single runaway leaf would otherwise
    poison every later round's gradients (DESIGN.md §13). exp(30) ≈ 1e13 is
    far beyond any count this objective can fit, so the clamp is inactive
    on healthy fits."""
    mu = jnp.exp(jnp.clip(margins[:, 0], -30.0, 30.0))
    g = mu - y
    h = mu * jnp.exp(0.7)
    return jnp.stack([g, h], axis=-1)[:, None, :]


poisson = register_objective(
    "count:poisson",
    _poisson_grad,
    init_base_score=lambda y, **_: float(
        jnp.log(jnp.maximum(jnp.mean(y), 1e-8))
    ),
    transform=lambda m: jnp.exp(m[:, 0]),
    default_metric="poisson-nloglik",
)


# --- built-ins: classification ---------------------------------------------

def _logistic_grad(margins, y, **_):
    p = jax.nn.sigmoid(margins[:, 0])
    g = p - y  # eq. (1)
    h = p * (1.0 - p)  # eq. (2)
    return jnp.stack([g, h], axis=-1)[:, None, :]


logistic = register_objective(
    "binary:logistic",
    _logistic_grad,
    transform=lambda m: jax.nn.sigmoid(m[:, 0]),
    default_metric="accuracy",
)


def _softmax_grad(margins, y, **_):
    k = margins.shape[1]
    p = jax.nn.softmax(margins, axis=1)
    onehot = jax.nn.one_hot(y.astype(jnp.int32), k)
    g = p - onehot
    h = p * (1.0 - p)
    return jnp.stack([g, h], axis=-1)  # (n, k, 2)


softmax = register_objective(
    "multi:softmax",
    _softmax_grad,
    n_outputs=lambda k: k,
    transform=lambda m: jnp.argmax(m, axis=1),
    default_metric="accuracy",
)


# --- built-ins: ranking ----------------------------------------------------

def _pairwise_grad(margins, y, group_ids=None, **_):
    """LambdaRank pairwise logistic gradients within query groups.

    For every in-group pair (i, j) with y_i > y_j the pairwise logistic loss
    log(1 + exp(-(s_i - s_j))) contributes rho = sigmoid(s_j - s_i) to g_i
    (negative) and g_j (positive), with hessian rho(1-rho). O(n^2) in the
    group — evaluated with a masked dense pair matrix (fine for benchmark
    group sizes; the paper's CPU version is the same complexity).

    The hessian is floored at 1e-6: rows in no comparable pair (singleton
    groups, all-equal relevance) have exactly zero pairwise hessian, and
    rho(1-rho) underflows once a pair is confidently ordered — the floor
    keeps leaf values g/(h + lambda) finite without visibly perturbing
    informative rows (their h sums over many pairs, >> 1e-6).
    """
    s = margins[:, 0]
    if group_ids is None:
        group_ids = jnp.zeros_like(s, dtype=jnp.int32)
    same = group_ids[:, None] == group_ids[None, :]
    better = (y[:, None] > y[None, :]) & same
    rho = jax.nn.sigmoid(s[None, :] - s[:, None])  # sigmoid(s_j - s_i)
    w = rho * (1.0 - rho)
    g = -jnp.sum(jnp.where(better, rho, 0.0), axis=1) + jnp.sum(
        jnp.where(better.T, rho.T, 0.0), axis=1
    )
    h = jnp.sum(jnp.where(better | better.T, w, 0.0), axis=1)
    return jnp.stack([g, jnp.maximum(h, 1e-6)], axis=-1)[:, None, :]


pairwise_rank = register_objective(
    "rank:pairwise",
    _pairwise_grad,
    default_metric="ndcg@10",
)
