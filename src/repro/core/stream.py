"""Streamed out-of-core training executor (DESIGN.md §17).

The resident external-memory path (`ExternalDMatrix.packed_bins()`) pages
the WHOLE compressed chunk stack to device before the fit and scans it
inside one compiled program. That is the fastest shape when the stack fits,
but it is exactly what an out-of-core path must not require. This module is
the other execution of the same arithmetic: the stack stays host-side, a
bounded prefetching pager (`dmatrix.ChunkPager`) stages chunks host->device
on a background thread, and every per-chunk unit of work — histogram slab
update, row routing, tree traversal — runs as an eagerly-dispatched jitted
kernel that is the SAME scan body the resident path compiles
(`histogram._chunk_slab_update` and friends). Chunk k+1 transfers while
chunk k computes; device residency is bounded by the pager ring
(prefetch_chunks staged + 1 in use) plus O(n) row state, never the stack.

Bit-identity discipline (the repo's external-memory contract, DESIGN.md
§11): per-(node, feature, bin) f32 adds happen in global row order in both
executions, routing/traversal are elementwise, and the per-chunk kernels
are the extracted bodies of the resident scans — so streamed fits equal
resident fits equal in-memory fits bitwise on shared cuts, with the
prefetch ring on or off (overlap changes WHEN a chunk arrives, never what
is computed from it).

GOSS composes with streaming through the compacted-row builders: the
selection needs only the gradient vector (device-resident, O(n)) — never
the matrix — and the compacted row ids arrive ascending, so they split
into per-chunk segments host-side (`np.searchsorted`) and chunks with no
selected rows are never requested from the pager at all. `rows_touched` /
`chunks_paged` counters feed the BENCH `external_memory.goss` subsection.

`StreamedChunkedBins` is duck-typed (class attr `is_streamed`) rather than
a compress.py pytree: it is deliberately NOT traceable — it owns a Python
pager and host-side counters — and tree.py/booster.py dispatch on the
attribute to call its methods eagerly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import histogram as H
from repro.core import partition as P
from repro.core import predict as PR
from repro.core import sampling as SMP
from repro.testing import faults as FA


def _bucket(m: int) -> int:
    """Next power of two >= m, floor 64 — segment buffers are padded to
    bucket sizes so per-level jit kernels see O(log n) distinct shapes
    instead of one per (level, chunk) segment length."""
    return 1 << max(6, (max(m, 1) - 1).bit_length())


def _pad1(arr: jax.Array, size: int, value) -> jax.Array:
    pad = size - arr.shape[0]
    return arr if pad == 0 else jnp.pad(arr, (0, pad), constant_values=value)


def _pad2(arr: jax.Array, size: int) -> jax.Array:
    pad = size - arr.shape[0]
    return arr if pad == 0 else jnp.pad(arr, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("missing_bin", "bits"))
def _update_positions_rows_chunk(
    words, positions, split_mask, feature, split_bin, default_left,
    missing_bin, bits, rid_local,
):
    """Route one chunk-segment of the compacted row buffer with that
    chunk's words — the streamed twin of partition.update_positions_chunked_
    rows, which gathers from the resident stack. Same `_route` body, same
    elementwise rule, so per-slot results are identical."""
    return P._route(
        positions, split_mask, feature, split_bin, default_left, missing_bin,
        lambda f: C.gather_feature_bins_rows(words, bits, f, rid_local),
    )


@functools.partial(
    jax.jit,
    static_argnames=("bits", "n_rows", "missing_bin", "max_depth"),
)
def _traverse_chunk(
    feature, split_bin, default_left, leaf_value, is_leaf,
    words, bits, n_rows, missing_bin, max_depth,
):
    """One chunk's leaf outputs for one tree — the body predict.traverse_
    tree_chunked scans over the resident stack, applied per paged-in
    chunk."""
    return PR.traverse_tree_packed(
        feature, split_bin, default_left, leaf_value, is_leaf,
        words, bits, n_rows, missing_bin, max_depth,
    )


class StreamedChunkedBins:
    """Duck-typed training representation over a host-resident chunk stack.

    Presents the same work units grow_tree needs (histograms, routing,
    traversal) as METHODS that internally stream chunks through the
    source ExternalDMatrix's prefetching pager. tree.py and booster.py
    dispatch on the `is_streamed` class attribute (duck typing avoids an
    import cycle tree -> stream -> predict -> tree).

    Counters (host ints, reset per fit by the stream runner's caller or
    read cumulatively by benchmarks):
      rows_touched — rows scattered into histograms (the GOSS win metric:
        full fits touch ~n + (depth-1) * n/2 rows per tree, GOSS fits
        ~(a+b) * that).
      chunks_paged — chunks served by the pager (GOSS skips chunks with
        no selected rows in the compacted builders).
    """

    is_streamed = True

    def __init__(self, source):
        self.source = source  # ExternalDMatrix
        self.bits = source.bits
        self.chunk_rows = source.chunk_rows
        self.n_rows = source.n_rows
        self.rows_touched = 0
        self.chunks_paged = 0

    @property
    def n_chunks(self) -> int:
        return self.source.n_chunks

    @property
    def n_features(self) -> int:
        return self.source.n_features

    def iter_chunks(self, indices=None):
        """(index, device_chunk) pairs via the source's prefetching pager
        (double-buffered by default; synchronous at prefetch_chunks=0)."""
        for i, chunk in self.source.chunk_pager(indices):
            self.chunks_paged += 1
            yield i, chunk

    def _segments(self, rid: np.ndarray):
        """Split an ASCENDING global row-id buffer into per-chunk segments:
        segment i covers slots [starts[i], ends[i]) whose rows live in
        chunk i. Out-of-range sentinel ids (subtraction-buffer padding)
        fall past the last boundary and are dropped — they only ever
        scatter into the dump slot, which every builder slices off."""
        bounds = np.arange(1, self.n_chunks + 1) * self.chunk_rows
        ends = np.searchsorted(rid, bounds)
        starts = np.concatenate(([0], ends[:-1]))
        return starts, ends

    # --- histogram builds --------------------------------------------------
    def build_histograms(self, gh, pos, n_nodes, max_bins):
        """Full-matrix level histogram: thread the feature-major slab stack
        through every chunk (resident build_histograms_chunked's scan,
        unrolled over the pager)."""
        slots = (n_nodes + 1) * max_bins
        hist = jnp.zeros((self.n_features, slots, 2), jnp.float32)
        for i, words in self.iter_chunks():
            s = i * self.chunk_rows
            e = min(s + self.chunk_rows, self.n_rows)
            hist = H.histogram_chunk_update(
                hist, words, gh[s:e], pos[s:e], n_nodes, max_bins, self.bits
            )
            self.rows_touched += e - s
        return H.finalize_slab_histogram(hist, n_nodes, max_bins)

    def build_histograms_rows(self, gh_sel, pos_sel, row_ids, n_nodes,
                              max_bins):
        """Compacted-row level histogram (subtraction trick / GOSS): the
        ascending buffer splits into per-chunk segments; chunks with no
        selected rows are never paged. Scatter order per (node, f, bin)
        slot is the buffer's global slot order, matching the resident
        build_histograms_chunked_rows bitwise."""
        rid = np.asarray(row_ids)
        starts, ends = self._segments(rid)
        f = self.n_features
        flat = jnp.zeros(((n_nodes + 1) * f * max_bins, 2), jnp.float32)
        todo = [i for i in range(self.n_chunks) if ends[i] > starts[i]]
        for i, words in self.iter_chunks(todo):
            s, e = int(starts[i]), int(ends[i])
            size = _bucket(e - s)
            rl = jnp.asarray(rid[s:e] - i * self.chunk_rows, jnp.int32)
            flat = H.histogram_rows_chunk_update(
                flat, words,
                _pad2(gh_sel[s:e], size),
                _pad1(pos_sel[s:e], size, n_nodes),
                _pad1(rl, size, 0),
                n_nodes, max_bins, self.bits,
            )
            self.rows_touched += e - s
        return flat.reshape(n_nodes + 1, f, max_bins, 2)[:n_nodes]

    # --- row routing -------------------------------------------------------
    def update_positions(self, positions, split_mask, feature, split_bin,
                         default_left, missing_bin):
        """Full-row routing, one chunk slice at a time (elementwise — the
        concatenation equals the resident update_positions_chunked)."""
        parts = []
        for i, words in self.iter_chunks():
            s = i * self.chunk_rows
            e = min(s + self.chunk_rows, self.n_rows)
            parts.append(P.update_positions_packed(
                words, positions[s:e], split_mask, feature, split_bin,
                default_left, missing_bin, self.bits,
            ))
        return jnp.concatenate(parts)

    def update_positions_rows(self, positions, split_mask, feature,
                              split_bin, default_left, missing_bin, row_ids):
        """Buffer-space routing for compacted rows: segments partition the
        buffer (row_ids are real ascending rows here, no sentinels), so the
        trimmed per-segment results concatenate back to the full buffer in
        slot order."""
        rid = np.asarray(row_ids)
        starts, ends = self._segments(rid)
        parts = []
        todo = [i for i in range(self.n_chunks) if ends[i] > starts[i]]
        for i, words in self.iter_chunks(todo):
            s, e = int(starts[i]), int(ends[i])
            size = _bucket(e - s)
            rl = jnp.asarray(rid[s:e] - i * self.chunk_rows, jnp.int32)
            res = _update_positions_rows_chunk(
                words,
                _pad1(positions[s:e], size, -1),
                split_mask, feature, split_bin, default_left,
                missing_bin, self.bits,
                _pad1(rl, size, 0),
            )
            parts.append(res[: e - s])
        return jnp.concatenate(parts)

    # --- prediction --------------------------------------------------------
    def traverse_tree(self, tr, missing_bin, max_depth):
        """One tree's leaf outputs over all rows (the per-round margin
        update). Streams the stack once per tree — multiclass rounds stream
        it k times; correctness-first, the pager hides the transfers."""
        parts = []
        for _, words in self.iter_chunks():
            parts.append(_traverse_chunk(
                tr.feature, tr.split_bin, tr.default_left, tr.leaf_value,
                tr.is_leaf, words, self.bits, self.chunk_rows, missing_bin,
                max_depth,
            ))
        return jnp.concatenate(parts)[: self.n_rows]


@functools.partial(jax.jit, static_argnames=("cfg",))
def _apply_eval(cfg, stacked, pb, em):
    """Eval-set margin update for one round's stacked trees, jitted so the
    streamed executor applies the same compiled arithmetic (same barrier
    discipline) as the resident scan body."""
    from repro.core import booster as B

    return B._apply_stacked_trees(cfg, stacked, pb, em)


def make_stream_runner(cfg, obj, cuts, dtrain, y, extra, eval_pbs, eval_ys,
                       eval_extras, metrics, track_metric, base_key):
    """A `run_chunk(length, start_round, margins, eval_margins)` closure
    with the same contract as booster._make_train_fn's compiled scan, but
    executing rounds EAGERLY over a StreamedChunkedBins: the per-round body
    is the very same `_round_step_fn` the resident path scans — gradients,
    GOSS/subsample context, tree growth, margin update — with the data
    methods streaming chunks through the prefetch ring. The per-round PRNG
    key folds the ABSOLUTE round index, so resume/update()/early-stopping
    chunks replay one long fit's key stream exactly as the compiled scan
    does."""
    from repro.core import booster as B

    sbins = StreamedChunkedBins(dtrain)
    dtrain.stream_stats = sbins  # surface the counters (benchmarks/tests)
    stoch = SMP.stochastic_params(cfg)
    sentinel = cfg.numeric_check != "off"
    steps: dict = {}

    def run_chunk(length, start_round, margins, eval_margins):
        fkey = FA.trace_key("nan_grad")
        step = steps.get(fkey)
        if step is None:
            step = steps[fkey] = B._round_step_fn(cfg, obj, None)
        ev = tuple(eval_margins)
        trees, tr_ms, ev_ms, flags = [], [], [], []
        for r in range(length):
            ridx = jnp.asarray(start_round + r, jnp.int32)
            rkey = (
                jax.random.fold_in(base_key, start_round + r)
                if stoch is not None else None
            )
            out = step(sbins, margins, y, extra, cuts, rkey, ridx)
            if sentinel:
                stacked, margins, ok = out
                flags.append(ok)
            else:
                stacked, margins = out
            new_ev, round_ev = [], []
            for pb, em, ey, ex in zip(eval_pbs, ev, eval_ys, eval_extras):
                em = _apply_eval(cfg, stacked, pb, em)
                new_ev.append(em)
                round_ev.append(tuple(
                    m.fn(em, ey, **ex).astype(jnp.float32) for m in metrics
                ))
            ev = tuple(new_ev)
            trees.append(stacked)
            ev_ms.append(round_ev)
            if track_metric:
                tr_ms.append(tuple(
                    m.fn(margins, y, **extra).astype(jnp.float32)
                    for m in metrics
                ))
        all_trees = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        tr_stack = tuple(
            jnp.stack([row[j] for row in tr_ms])
            for j in range(len(metrics))
        ) if track_metric else ()
        ev_stack = tuple(
            tuple(
                jnp.stack([ev_ms[r][si][j] for r in range(length)])
                for j in range(len(metrics))
            )
            for si in range(len(eval_pbs))
        )
        flag_stack = jnp.stack(flags) if sentinel else ()
        return margins, all_trees, tr_stack, ev, ev_stack, flag_stack

    run_chunk.bins = sbins  # counters surface for benchmarks/tests
    return run_chunk
