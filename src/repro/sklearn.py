"""scikit-learn estimator facade over the two-noun core API.

`XGBRegressor` / `XGBClassifier` / `XGBRanker` wrap `DeviceDMatrix` +
`Booster` behind sklearn's estimator contract (`get_params` / `set_params`
/ `fit(X, y, eval_set=...)` / `predict` / `predict_proba` / `score`), thin
enough that `GridSearchCV`, `cross_val_score` and `Pipeline` work out of
the box — the integration surface XGBoost's own sklearn wrapper made
ubiquitous (pipeline frameworks like ZenML build against exactly this).

scikit-learn itself is an OPTIONAL dependency: when importable, the
estimators subclass `sklearn.base.BaseEstimator` and the standard mixins
(so tags, cloning and scorers behave natively); without it, a minimal
local base supplies `get_params`/`set_params`/`score` with the same
semantics, and everything except sklearn's own meta-estimators still works.

    from repro.sklearn import XGBClassifier

    clf = XGBClassifier(n_estimators=50, max_depth=4)
    clf.fit(xt, yt, eval_set=[(xv, yv)])
    p = clf.predict_proba(xv)

    from sklearn.model_selection import GridSearchCV
    GridSearchCV(XGBClassifier(n_estimators=20),
                 {"max_depth": [3, 5]}, cv=3).fit(x, y)

All estimators share one constructor surface (sklearn introspects the
inherited `__init__`); `objective=None` picks the task default, and the
pluggable registries flow through: `objective` accepts any registered
objective name, `eval_metric` any metric spec list (DESIGN.md §10).
"""
from __future__ import annotations

import numpy as np

try:  # sklearn is optional: estimators degrade to a local base without it
    from sklearn.base import (  # type: ignore
        BaseEstimator,
        ClassifierMixin,
        RegressorMixin,
    )

    HAVE_SKLEARN = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_SKLEARN = False

    class BaseEstimator:  # minimal stand-in with sklearn's param contract
        @classmethod
        def _get_param_names(cls):
            import inspect

            sig = inspect.signature(cls.__init__)
            return sorted(
                p.name for p in sig.parameters.values()
                if p.name != "self" and p.kind == p.POSITIONAL_OR_KEYWORD
            )

        def get_params(self, deep: bool = True) -> dict:
            return {k: getattr(self, k) for k in self._get_param_names()}

        def set_params(self, **params):
            valid = set(self._get_param_names())
            for k, v in params.items():
                if k not in valid:
                    raise ValueError(
                        f"invalid parameter {k!r} for {type(self).__name__}"
                    )
                setattr(self, k, v)
            return self

    class RegressorMixin:
        def score(self, X, y, sample_weight=None):
            pred = np.asarray(self.predict(X), np.float64)
            y = np.asarray(y, np.float64)
            ss_res = float(np.sum((y - pred) ** 2))
            ss_tot = float(np.sum((y - np.mean(y)) ** 2))
            return 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0

    class ClassifierMixin:
        def score(self, X, y, sample_weight=None):
            return float(np.mean(np.asarray(self.predict(X)) == np.asarray(y)))


from repro.core import Booster, BoosterConfig, DeviceDMatrix, ExternalDMatrix


class _BoosterEstimator(BaseEstimator):
    """Shared constructor + fit plumbing. sklearn introspects this
    `__init__` (inherited by all three estimators), so every argument must
    be stored verbatim on self — task-specific behaviour lives in class
    attributes and `_fit_objective`, not in constructor logic."""

    _default_objective = "reg:squarederror"

    def __init__(
        self,
        n_estimators: int = 100,
        learning_rate: float = 0.3,
        max_depth: int = 6,
        max_bins: int = 256,
        reg_lambda: float = 1.0,
        gamma: float = 0.0,
        min_child_weight: float = 1.0,
        growth: str = "depthwise",
        max_leaves: int = 0,
        objective: str | None = None,
        eval_metric=None,
        early_stopping_rounds: int | None = None,
        quantile_alpha: float = 0.5,
        verbose: int = 0,
        chunk_rows: int | None = None,
        subsample: float = 1.0,
        sampling_method: str = "uniform",
        top_rate: float = 0.2,
        other_rate: float = 0.1,
        colsample_bytree: float = 1.0,
        colsample_bylevel: float = 1.0,
        colsample_bynode: float = 1.0,
        monotone_constraints=None,
        random_state: int = 0,
        numeric_check: str = "off",
        on_oom: str = "raise",
        checkpoint_every: int | None = None,
        checkpoint_path: str | None = None,
        serve: bool = False,
        mesh=None,
        collective: str = "psum",
        compression: str | None = None,
    ):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.max_bins = max_bins
        self.reg_lambda = reg_lambda
        self.gamma = gamma
        self.min_child_weight = min_child_weight
        self.growth = growth
        self.max_leaves = max_leaves
        self.objective = objective
        self.eval_metric = eval_metric
        self.early_stopping_rounds = early_stopping_rounds
        self.quantile_alpha = quantile_alpha
        self.verbose = verbose
        # chunk_rows=None trains in-memory; an int routes the training set
        # through ExternalDMatrix (chunked, external-memory path) so fits
        # bound dense device transients by one chunk (DESIGN.md §11).
        self.chunk_rows = chunk_rows
        # Stochastic regularisers + constraints (DESIGN.md §12); defaults
        # keep training fully deterministic regardless of random_state.
        # sampling_method="goss" enables gradient-based one-side sampling
        # (top_rate/other_rate, XGBoost/LightGBM semantics — DESIGN.md §17).
        self.subsample = subsample
        self.sampling_method = sampling_method
        self.top_rate = top_rate
        self.other_rate = other_rate
        self.colsample_bytree = colsample_bytree
        self.colsample_bylevel = colsample_bylevel
        self.colsample_bynode = colsample_bynode
        self.monotone_constraints = monotone_constraints
        self.random_state = random_state
        # Fault-tolerance knobs (DESIGN.md §13): numeric_check arms the
        # in-scan sentinel; on_oom="external" degrades to external-memory
        # training on device OOM; checkpoint_every/checkpoint_path snapshot
        # the fit for Booster.resume after a crash.
        self.numeric_check = numeric_check
        self.on_oom = on_oom
        self.checkpoint_every = checkpoint_every
        self.checkpoint_path = checkpoint_path
        # serve=True routes predict through repro.serve.PredictEngine:
        # shape-bucketed compiled caches keep mixed batch sizes from
        # recompiling (DESIGN.md §14). Predictions are identical either way.
        self.serve = serve
        # Scale-out knobs (DESIGN.md §15): mesh shards rows across devices;
        # collective picks the histogram allreduce strategy ("psum" | "ring"
        # | "hier"); compression (None | "f16" | "q16") shrinks the wire
        # payload with an f32 fallback. Per-fit wire accounting is exposed
        # as `est.comm_stats_` after fitting with a mesh.
        self.mesh = mesh
        self.collective = collective
        self.compression = compression

    # --- fit plumbing ------------------------------------------------------
    def _fit_objective(self, y: np.ndarray) -> tuple[str, int, np.ndarray]:
        """(objective name, n_classes, encoded labels) for this task."""
        obj = self.objective or self._default_objective
        return obj, 1, np.asarray(y, np.float32)

    def _config(self, objective: str, n_classes: int) -> BoosterConfig:
        return BoosterConfig(
            n_rounds=self.n_estimators,
            learning_rate=self.learning_rate,
            max_depth=self.max_depth,
            max_bins=self.max_bins,
            reg_lambda=self.reg_lambda,
            gamma=self.gamma,
            min_child_weight=self.min_child_weight,
            growth=self.growth,
            max_leaves=self.max_leaves,
            objective=objective,
            n_classes=n_classes,
            quantile_alpha=self.quantile_alpha,
            subsample=self.subsample,
            sampling_method=self.sampling_method,
            top_rate=self.top_rate,
            other_rate=self.other_rate,
            colsample_bytree=self.colsample_bytree,
            colsample_bylevel=self.colsample_bylevel,
            colsample_bynode=self.colsample_bynode,
            monotone_constraints=(
                None if self.monotone_constraints is None
                else tuple(int(c) for c in self.monotone_constraints)
            ),
            seed=self.random_state,
            numeric_check=self.numeric_check,
        )

    def _fit(self, X, y, eval_set=None, group_ids=None, eval_group_ids=None):
        X = np.asarray(X, np.float32)
        objective, n_classes, y_enc = self._fit_objective(y)
        if self.chunk_rows is not None:
            dtrain = ExternalDMatrix.from_arrays(
                X, y_enc, group_ids=group_ids, chunk_rows=self.chunk_rows,
                max_bins=self.max_bins)
        else:
            dtrain = DeviceDMatrix(X, label=y_enc, group_ids=group_ids,
                                   max_bins=self.max_bins)
        evals = []
        for i, (xv, yv) in enumerate(eval_set or ()):
            gv = None if eval_group_ids is None else eval_group_ids[i]
            evals.append((
                DeviceDMatrix(np.asarray(xv, np.float32),
                              label=self._encode_labels(yv),
                              group_ids=gv, ref=dtrain),
                f"validation_{i}",
            ))
        self.booster_ = Booster(self._config(objective, n_classes)).fit(
            dtrain,
            evals=evals,
            eval_metric=self.eval_metric,
            early_stopping_rounds=self.early_stopping_rounds,
            verbose_every=self.verbose,
            on_oom=self.on_oom,
            checkpoint_every=self.checkpoint_every,
            checkpoint_path=self.checkpoint_path,
            mesh=self.mesh,
            collective=self.collective,
            compression=self.compression,
        )
        self.n_features_in_ = X.shape[1]
        self.evals_result_ = list(self.booster_.history)
        self._engines_ = {}  # serve=True engine cache; stale after refit
        return self

    def _encode_labels(self, y) -> np.ndarray:
        return np.asarray(y, np.float32)

    def _check_fitted(self):
        if not hasattr(self, "booster_"):
            raise RuntimeError(
                f"{type(self).__name__} is not fitted yet — call fit() first"
            )

    # --- serving (serve=True) ----------------------------------------------
    def _serve_engine(self, output_margin: bool):
        """Lazily-built PredictEngine per output mode (margins for the
        classifier's decision path, transformed values otherwise)."""
        key = "margin" if output_margin else "value"
        engines = getattr(self, "_engines_", None)
        if engines is None:
            engines = self._engines_ = {}
        if key not in engines:
            from repro.serve import PredictEngine

            engines[key] = PredictEngine(
                self.booster_, output_margin=output_margin
            )
        return engines[key]

    def _predict_values(self, X) -> np.ndarray:
        """Transformed predictions, through the serving engine when
        serve=True (bucketed, recompile-free) else the booster directly."""
        self._check_fitted()
        if self.serve:
            return self._serve_engine(output_margin=False).predict(X)
        return np.asarray(self.booster_.predict(np.asarray(X, np.float32)))

    def _predict_margins(self, X) -> np.ndarray:
        self._check_fitted()
        if self.serve:
            return self._serve_engine(output_margin=True).predict(X)
        return np.asarray(
            self.booster_.predict_margins(np.asarray(X, np.float32))
        )

    # --- common fitted surface ---------------------------------------------
    @property
    def best_iteration_(self) -> int | None:
        self._check_fitted()
        return self.booster_.best_iteration

    @property
    def best_score_(self) -> float | None:
        self._check_fitted()
        return self.booster_.best_score

    def get_booster(self) -> Booster:
        self._check_fitted()
        return self.booster_

    @property
    def comm_stats_(self) -> dict | None:
        """Communication accounting of the latest fit (DESIGN.md §15):
        wire bytes/round, collective calls/round, compression fallback
        events. None for single-device fits (mesh=None)."""
        self._check_fitted()
        return self.booster_.comm_stats

    @property
    def feature_importances_(self) -> np.ndarray:
        """Gain-based importances normalised to sum 1 (XGBoost's sklearn
        default importance_type="gain"); zeros when the model never split."""
        self._check_fitted()
        imp = self.booster_.feature_importances("gain")
        total = imp.sum()
        return imp / total if total > 0 else imp


class XGBRegressor(RegressorMixin, _BoosterEstimator):
    """sklearn-style regressor over the compiled boosting scan.

    `objective=None` means squared error; any registered regression
    objective name works (`reg:quantile` + `quantile_alpha=0.9`,
    `reg:pseudohubererror`, `count:poisson`, a `register_objective` name).
    """

    _default_objective = "reg:squarederror"

    def fit(self, X, y, *, eval_set=None):
        return self._fit(X, y, eval_set=eval_set)

    def predict(self, X) -> np.ndarray:
        return self._predict_values(X)


class XGBClassifier(ClassifierMixin, _BoosterEstimator):
    """sklearn-style classifier: binary logistic for two classes,
    softmax above; `classes_` round-trips arbitrary label values."""

    _default_objective = None  # chosen from the label cardinality

    def _fit_objective(self, y):
        y = np.asarray(y)
        self.classes_ = np.unique(y)
        k = len(self.classes_)
        if k < 2:
            raise ValueError("XGBClassifier needs at least 2 classes")
        objective = self.objective or (
            "binary:logistic" if k == 2 else "multi:softmax"
        )
        return objective, (1 if k == 2 else k), self._encode_labels(y)

    def _encode_labels(self, y) -> np.ndarray:
        y = np.asarray(y)
        idx = np.clip(np.searchsorted(self.classes_, y),
                      0, len(self.classes_) - 1)
        bad = self.classes_[idx] != y
        if np.any(bad):
            raise ValueError(
                "labels contain classes unseen in the training targets: "
                f"{sorted(set(np.unique(y[bad]).tolist()))}"
            )
        return idx.astype(np.float32)

    def fit(self, X, y, *, eval_set=None):
        return self._fit(X, y, eval_set=eval_set)

    def predict(self, X) -> np.ndarray:
        margins = self._predict_margins(X)
        if margins.shape[1] == 1:
            idx = (margins[:, 0] > 0.0).astype(int)
        else:
            idx = np.argmax(margins, axis=1)
        return self.classes_[idx]

    def predict_proba(self, X) -> np.ndarray:
        import jax

        margins = self._predict_margins(X)
        if margins.shape[1] == 1:
            p = np.asarray(jax.nn.sigmoid(margins[:, 0]))
            return np.column_stack([1.0 - p, p])
        return np.asarray(jax.nn.softmax(margins, axis=1))


class XGBRanker(_BoosterEstimator):
    """sklearn-style LambdaRank-pairwise ranker.

    Query structure comes in XGBoost's two equivalent forms: `qid` (one
    query id per row) or `group` (consecutive query sizes). `predict`
    returns raw ranking scores; no `score` method is defined (ranking has
    no single sklearn scorer — evaluate with `eval_metric=["ndcg@k"]`).
    """

    _default_objective = "rank:pairwise"

    @staticmethod
    def _qid(n_rows: int, qid, group) -> np.ndarray:
        if (qid is None) == (group is None):
            raise ValueError("pass exactly one of qid= or group=")
        if qid is not None:
            q = np.asarray(qid, np.int32)
        else:
            q = np.repeat(np.arange(len(group), dtype=np.int32),
                          np.asarray(group, np.int64))
        if q.shape[0] != n_rows:
            raise ValueError(
                f"query structure covers {q.shape[0]} rows, X has {n_rows}"
            )
        return q

    def fit(self, X, y, *, qid=None, group=None, eval_set=None,
            eval_qid=None):
        X = np.asarray(X, np.float32)
        gids = self._qid(X.shape[0], qid, group)
        eval_gids = None
        if eval_set:
            if eval_qid is None:
                raise ValueError("eval_set for ranking requires eval_qid")
            eval_gids = [np.asarray(q, np.int32) for q in eval_qid]
        return self._fit(X, y, eval_set=eval_set, group_ids=gids,
                         eval_group_ids=eval_gids)

    def predict(self, X) -> np.ndarray:
        return self._predict_values(X)


__all__ = [
    "HAVE_SKLEARN",
    "XGBClassifier",
    "XGBRanker",
    "XGBRegressor",
]
