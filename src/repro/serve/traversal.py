"""Fused ensemble traversal — all trees x a row block in one launch.

`core.predict` folds the ensemble with a `lax.scan` over stacked tree
arenas: one scan step per tree, each step a levelwise gather over all rows.
That shape is right *inside* the training round (the round step only ever
applies k trees), but for batch inference over a deep ensemble it serialises
n_trees tiny dispatches of O(rows) work each — on a 500-tree model the
traversal is latency-bound on loop overhead, not on gathers.

The serving path fuses the other axis instead: a levelwise loop advances a
BLOCK of trees over all rows at once. Per level the node state is a
(trees_block, n_rows) int32 plane, and each step costs exactly two gathers:

  * one on a per-tree **stacked routing table** — the arena's SoA fields
    (split feature, comparison threshold, default direction, left/right
    child) interleaved into a single (n_trees, arena, 5) f32 array, so the
    full routing record of a (tree, node) pair lands in one contiguous
    16-byte read instead of five strided gathers (leaves self-loop via
    child pointers and a +inf threshold, absorbing the is-leaf select);
  * one on the input block for the feature value.

Blocks of TREES_BLOCK trees keep the level planes cache-resident — the
whole-(n_trees, n_rows) formulation streams multi-MB temporaries through
memory every level and loses to the scan on CPU — while still collapsing
n_trees scan steps into n_trees / TREES_BLOCK. Work is otherwise identical
to the scan form: the leaf every (tree, row) pair lands in is the same and
the class fold reduces in the same order, so fused outputs are
BIT-IDENTICAL to `core.predict`'s (tested).

Two input modes, as everywhere else (DESIGN.md §2):

  * packed / bin-space — the model carries cut points and the rows arrive
    quantised (DeviceDMatrix, or the engine quantising a float batch):
    thresholds are integer bin ids, the reserved missing bin encodes NaN.
  * raw — float32 rows vs raw-space thresholds, NaN = missing. The only
    mode available to models imported from XGBoost JSON (no cuts attached).

A Pallas TPU kernel of the same computation (one-hot MXU formulation, no
gathers) lives in `kernels.ensemble_traversal`; the functions here are its
parity oracle and the default execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import predict as PR


TREES_BLOCK = 32  # level planes stay (32, n_rows) — cache-resident on CPU


def _stacked_table(feature, cmp_threshold, default_left, is_leaf):
    """Interleave the routing fields into one (n_trees, arena, 5) f32 table
    so each traversal level pays ONE contiguous gather per (tree, node).

    Columns: [split feature, comparison threshold, default_left, left child,
    right child]. Leaves self-loop (both children point at the leaf itself)
    behind a +inf threshold, so the levelwise step needs no is-leaf select;
    feature/child ids round-trip through f32 exactly (arena and feature
    counts are far below 2^24)."""
    arena = feature.shape[1]
    node_ids = jnp.arange(arena, dtype=jnp.int32)
    cl = jnp.where(is_leaf, node_ids, 2 * node_ids + 1)
    cr = jnp.where(is_leaf, node_ids, 2 * node_ids + 2)
    thr = jnp.where(is_leaf, jnp.inf, cmp_threshold.astype(jnp.float32))
    return jnp.stack(
        [
            feature.astype(jnp.float32), thr,
            default_left.astype(jnp.float32),
            cl.astype(jnp.float32), cr.astype(jnp.float32),
        ],
        axis=-1,
    )


def _blocked_leaves(table, leaf_value, lookup, n_rows: int, max_depth: int):
    """Scan TREES_BLOCK-sized tree blocks through the levelwise loop and
    return the (n_trees, n_rows) leaf-value plane.

    `lookup(f)` maps a (trees_block, n_rows) split-feature plane to
    `(value_f32, is_missing_bool)` planes — the only part that differs
    between raw and bin-space traversal.
    """
    n_trees, arena = leaf_value.shape
    tb = min(TREES_BLOCK, n_trees)
    pad = (-n_trees) % tb
    if pad:  # padding trees self-loop at node 0 and are sliced off below
        table = jnp.pad(table, ((0, pad), (0, 0), (0, 0)))
        leaf_value = jnp.pad(leaf_value, ((0, pad), (0, 0)))
    tables = table.reshape(-1, tb, arena, 5)
    leaf_values = leaf_value.reshape(-1, tb, arena)
    tree_ix = jnp.arange(tb, dtype=jnp.int32)[:, None]  # (tb, 1)

    def one_block(_, blk):
        t5, lv = blk

        def body(__, node):
            g = t5[tree_ix, node]  # (tb, N, 5): the level's one table gather
            f = g[..., 0].astype(jnp.int32)
            v, is_missing = lookup(f)
            go_left = jnp.where(is_missing, g[..., 2] > 0.5, v <= g[..., 1])
            return jnp.where(go_left, g[..., 3], g[..., 4]).astype(jnp.int32)

        node = jnp.zeros((tb, n_rows), jnp.int32)
        node = jax.lax.fori_loop(0, max_depth, body, node)
        return None, lv[tree_ix, node]

    _, leaves = jax.lax.scan(one_block, None, (tables, leaf_values))
    return leaves.reshape(-1, n_rows)[:n_trees]  # (T, N)


def traverse_ensemble_raw(
    feature, threshold, default_left, leaf_value, is_leaf,
    x: jax.Array, max_depth: int,
) -> jax.Array:
    """(n_trees, n_rows) leaf outputs over float32 rows (NaN = missing)."""
    n_rows = x.shape[0]
    row_ix = jnp.arange(n_rows, dtype=jnp.int32)[None, :]  # (1, N)

    def lookup(f):
        v = x[row_ix, f]  # (tb, N) gather on the row block
        return v, jnp.isnan(v)

    table = _stacked_table(feature, threshold, default_left, is_leaf)
    return _blocked_leaves(table, leaf_value, lookup, n_rows, max_depth)


def traverse_ensemble_packed(
    feature, split_bin, default_left, leaf_value, is_leaf,
    packed: jax.Array, bits: int, n_rows: int, missing_bin: int,
    max_depth: int,
) -> jax.Array:
    """(n_trees, n_rows) leaf outputs straight from the bit-packed matrix:
    per level, one uint32 word gather per (tree, row) plus a shift/mask —
    the dense bins plane never exists (DESIGN.md §2). Bin ids compare in
    f32 (exact: bins < 2^24), so the stacked table is shared with raw
    mode."""
    from repro.core import compress as C

    spw = C.symbols_per_word(bits)
    row = jnp.arange(n_rows, dtype=jnp.int32)
    word_ix = (row // spw)[None, :]  # (1, N)
    shift = ((row % spw).astype(jnp.uint32) * jnp.uint32(bits))[None, :]
    mask = jnp.uint32((1 << bits) - 1)

    def lookup(f):
        b = (packed[f, word_ix] >> shift) & mask
        return b.astype(jnp.float32), b == jnp.uint32(missing_bin)

    table = _stacked_table(feature, split_bin, default_left, is_leaf)
    return _blocked_leaves(table, leaf_value, lookup, n_rows, max_depth)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def predict_margins_fused(
    ens: PR.Ensemble, x: jax.Array, max_depth: int
) -> jax.Array:
    """Margins (n_rows, n_classes) from raw float rows, fused over trees.

    Bit-identical to `core.predict.predict_raw` (same leaves, same class
    fold) in n_trees / TREES_BLOCK scan steps instead of n_trees.
    """
    leaves = traverse_ensemble_raw(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, x, max_depth,
    )
    return PR._fold_classes(leaves, ens, x.shape[0])


@functools.partial(
    jax.jit, static_argnames=("bits", "n_rows", "missing_bin", "max_depth")
)
def predict_margins_fused_packed(
    ens: PR.Ensemble, packed: jax.Array, bits: int, n_rows: int,
    missing_bin: int, max_depth: int,
) -> jax.Array:
    """Margins from the bit-packed quantised matrix, fused over trees —
    bit-identical to `core.predict.predict_binned_packed`."""
    leaves = traverse_ensemble_packed(
        ens.feature, ens.split_bin, ens.default_left, ens.leaf_value,
        ens.is_leaf, packed, bits, n_rows, missing_bin, max_depth,
    )
    return PR._fold_classes(leaves, ens, n_rows)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "chunk_rows", "n_rows", "missing_bin",
                     "max_depth"),
)
def ensemble_leaves_chunk(
    ens: PR.Ensemble, chunk_words: jax.Array, bits: int, chunk_rows: int,
    n_rows: int, missing_bin: int, max_depth: int,
) -> jax.Array:
    """(n_trees, chunk_rows) leaf outputs of ONE packed chunk — the unit of
    the external-memory paged predict path (`Booster.predict` on an
    `ExternalDMatrix` streams host chunks through this, never materialising
    the full device stack). Every chunk shares one compiled program."""
    del n_rows  # chunks are traversed at their padded chunk_rows size
    return traverse_ensemble_packed(
        ens.feature, ens.split_bin, ens.default_left, ens.leaf_value,
        ens.is_leaf, chunk_words, bits, chunk_rows, missing_bin, max_depth,
    )


@functools.partial(
    jax.jit,
    static_argnames=("bits", "chunk_rows", "n_rows", "missing_bin",
                     "max_depth"),
)
def predict_margins_fused_chunked(
    ens: PR.Ensemble, packed: jax.Array, bits: int, chunk_rows: int,
    n_rows: int, missing_bin: int, max_depth: int,
) -> jax.Array:
    """Fused margins over a device-resident chunk stack (the representation
    an `ExternalDMatrix` that already paged in for training holds) — a scan
    over chunks of the fused per-chunk traversal, bit-identical to
    `core.predict.predict_binned_chunked`."""

    def one_chunk(carry, words):
        return carry, traverse_ensemble_packed(
            ens.feature, ens.split_bin, ens.default_left, ens.leaf_value,
            ens.is_leaf, words, bits, chunk_rows, missing_bin, max_depth,
        )

    _, leaves = jax.lax.scan(one_chunk, None, packed)  # (C, T, chunk_rows)
    leaves = jnp.moveaxis(leaves, 0, 1).reshape(
        leaves.shape[1], -1
    )[:, :n_rows]  # (T, N) in global row order
    return PR._fold_classes(leaves, ens, n_rows)
