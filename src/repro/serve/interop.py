"""XGBoost model-format interop (DESIGN.md §14).

`import_xgboost_json` loads a real `xgboost.Booster` JSON model (the
`save_model("*.json")` schema, arXiv 1603.02754's reference system) into
this repo's ensemble arena so the serving stack can front models trained
anywhere; `export_xgboost_json` writes our Booster back out to that schema
so models trained here load in stock XGBoost.

Mapping (the full table is in DESIGN.md §14):

  pointer trees -> implicit heap. XGBoost stores explicit
    left_children/right_children indices; our arena is an implicit binary
    heap (children of slot i at 2i+1 / 2i+2). Import walks each tree from
    the root placing nodes at their heap slot; the arena spans the deepest
    imported tree. Export walks the heap back into pointer arrays in
    preorder.
  `x < t` -> `x <= t`. XGBoost routes left on strictly-less; this repo on
    less-or-equal (cuts are inclusive upper bin edges). In float32 the two
    are exactly interconvertible: import stores nextafter(t, -inf), export
    stores nextafter(t, +inf); pred(succ(t)) == t makes the round trip
    bit-exact.
  NaN semantics agree: missing rows follow the split's default_left flag in
    both systems, so the flags transfer verbatim.
  base_score. XGBoost persists it in PROBABILITY space; margins start from
    ProbToMargin(base_score) (logit for logistic, log for poisson, identity
    otherwise). Import applies that map, export inverts it.
  round-robin multiclass. Both systems emit n_classes trees per boosting
    round; `tree_info` carries each tree's class id. Import reorders trees
    per iteration to the round-robin layout the arena assumes, export emits
    it directly.
  split_bin. Imported models carry no cut points, so bin-space thresholds
    do not exist: split_bin stays 0, `cuts=None`, and prediction runs the
    raw-threshold traversal only (DMatrix inputs are rejected by the cuts
    mismatch check, as with any foreign-cut matrix).

Unsupported and rejected explicitly: gblinear/dart boosters,
num_parallel_tree > 1 (random forests), categorical splits.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

_SUPPORTED_OBJECTIVES = {
    "reg:squarederror": "reg:squarederror",
    "reg:quantileerror": "reg:quantile",
    "reg:pseudohubererror": "reg:pseudohubererror",
    "count:poisson": "count:poisson",
    "binary:logistic": "binary:logistic",
    "multi:softmax": "multi:softmax",
    "multi:softprob": "multi:softmax",  # same margins; transform is argmax
    "rank:pairwise": "rank:pairwise",
}
_EXPORT_OBJECTIVE = {
    "reg:squarederror": "reg:squarederror",
    "reg:quantile": "reg:quantileerror",
    "reg:pseudohubererror": "reg:pseudohubererror",
    "count:poisson": "count:poisson",
    "binary:logistic": "binary:logistic",
    "multi:softmax": "multi:softmax",
    "rank:pairwise": "rank:pairwise",
}

_INT32_MAX = 2147483647  # xgboost's root parent sentinel


def _prob_to_margin(p: float, objective: str) -> float:
    """XGBoost LogisticRegression::ProbToMargin and friends."""
    if objective == "binary:logistic":
        p = min(max(p, 1e-16), 1.0 - 1e-16)
        return float(np.log(p / (1.0 - p)))
    if objective == "count:poisson":
        return float(np.log(max(p, 1e-16)))
    return float(p)


def _margin_to_prob(m: float, objective: str) -> float:
    if objective == "binary:logistic":
        return float(1.0 / (1.0 + np.exp(-m)))
    if objective == "count:poisson":
        return float(np.exp(m))
    return float(m)


def _tree_depth(lc, rc) -> int:
    depth = 0
    stack = [(0, 0)]
    while stack:
        nid, d = stack.pop()
        depth = max(depth, d)
        if lc[nid] != -1:
            stack.append((lc[nid], d + 1))
            stack.append((rc[nid], d + 1))
    return depth


def _tree_to_arena(tree: dict, arena: int) -> dict:
    """One pointer tree -> one implicit-heap arena row (numpy fields)."""
    lc, rc = tree["left_children"], tree["right_children"]
    sc = np.asarray(tree["split_conditions"], np.float32)
    si = tree["split_indices"]
    dl = tree["default_left"]
    lg = np.asarray(tree.get("loss_changes", [0.0] * len(lc)), np.float32)

    out = {
        "feature": np.zeros(arena, np.int32),
        "split_bin": np.zeros(arena, np.int32),
        "threshold": np.zeros(arena, np.float32),
        "default_left": np.zeros(arena, bool),
        "leaf_value": np.zeros(arena, np.float32),
        "is_leaf": np.ones(arena, bool),
        "gain": np.full(arena, -np.inf, np.float32),
    }
    stack = [(0, 0)]
    while stack:
        nid, slot = stack.pop()
        if lc[nid] == -1:
            out["leaf_value"][slot] = sc[nid]  # split_conditions holds the
            continue  # leaf value on leaves
        out["is_leaf"][slot] = False
        out["feature"][slot] = si[nid]
        # x < t (xgboost) == x <= pred(t) (ours), exactly, in float32.
        out["threshold"][slot] = np.nextafter(
            sc[nid], np.float32(-np.inf), dtype=np.float32
        )
        out["default_left"][slot] = bool(dl[nid])
        out["gain"][slot] = lg[nid]
        stack.append((lc[nid], 2 * slot + 1))
        stack.append((rc[nid], 2 * slot + 2))
    return out


def import_xgboost_json(model) -> "Booster":
    """Load an `xgboost.Booster` JSON model into a repro Booster.

    `model` may be a file path, a JSON string, or an already-parsed dict.
    The result predicts on raw float arrays (NaN = missing) through the
    fused serving traversal and matches xgboost's `predict()` to float32
    tolerance; it carries no cut points, so quantised-matrix inputs are not
    accepted.
    """
    from repro.core.booster import Booster, BoosterConfig
    from repro.core.predict import Ensemble

    if isinstance(model, dict):
        doc = model
    else:
        text = str(model)
        if text.lstrip().startswith("{"):
            doc = json.loads(text)
        else:
            with open(text) as fh:
                doc = json.load(fh)

    learner = doc["learner"]
    booster_name = learner["gradient_booster"].get("name", "gbtree")
    if booster_name != "gbtree":
        raise ValueError(
            f"unsupported booster type {booster_name!r}: only gbtree "
            "models import (gblinear has no trees; dart's per-tree weights "
            "are not representable in the arena)"
        )
    xgb_objective = learner["objective"]["name"]
    if xgb_objective not in _SUPPORTED_OBJECTIVES:
        raise ValueError(
            f"unsupported objective {xgb_objective!r}; supported: "
            f"{sorted(_SUPPORTED_OBJECTIVES)}"
        )
    objective = _SUPPORTED_OBJECTIVES[xgb_objective]

    lmp = learner["learner_model_param"]
    num_feature = int(lmp["num_feature"])
    n_classes = max(int(lmp.get("num_class", "0")), 1)
    base_score = _prob_to_margin(float(lmp["base_score"]), objective)

    gb_model = learner["gradient_booster"]["model"]
    gbp = gb_model.get("gbtree_model_param", {})
    if int(gbp.get("num_parallel_tree", "1")) != 1:
        raise ValueError(
            "num_parallel_tree > 1 (random forest rounds) is not supported"
        )
    trees = gb_model["trees"]
    if not trees:
        raise ValueError("model has no trees")
    for i, t in enumerate(trees):
        if any(int(s) != 0 for s in t.get("split_type", [])) or \
                t.get("categories"):
            raise ValueError(
                f"tree {i} uses categorical splits, which the arena does "
                "not represent; export the model with numeric splits only"
            )

    # Reorder to round-robin: iteration-major, class-minor (the arena's
    # layout contract). tree_info carries each tree's class id.
    tree_info = [int(c) for c in gb_model.get("tree_info", [0] * len(trees))]
    indptr = gb_model.get(
        "iteration_indptr",
        list(range(0, len(trees) + 1, max(n_classes, 1))),
    )
    order: list[int] = []
    for it in range(len(indptr) - 1):
        span = list(range(int(indptr[it]), int(indptr[it + 1])))
        if n_classes > 1:
            if sorted(tree_info[i] for i in span) != list(range(n_classes)):
                raise ValueError(
                    f"iteration {it} does not contain exactly one tree per "
                    "class; cannot map onto the round-robin arena layout"
                )
            span.sort(key=lambda i: tree_info[i])
        order.extend(span)
    if len(order) != len(trees):
        raise ValueError(
            f"iteration_indptr covers {len(order)} trees, model has "
            f"{len(trees)}"
        )

    depth = max(
        _tree_depth(t["left_children"], t["right_children"]) for t in trees
    )
    depth = max(depth, 1)
    arena = 2 ** (depth + 1) - 1
    rows = [_tree_to_arena(trees[i], arena) for i in order]
    fields = {
        k: jnp.asarray(np.stack([r[k] for r in rows]))
        for k in rows[0]
    }

    bst = Booster(BoosterConfig(
        n_rounds=len(trees) // n_classes,
        max_depth=depth,
        objective=objective,
        n_classes=n_classes,
    ))
    bst.ensemble = Ensemble(
        **fields, n_classes=n_classes, base_score=base_score
    )
    bst.base_score = base_score
    bst.n_rounds_trained = len(trees) // n_classes
    bst.cuts = None  # no bin space: raw-threshold traversal only
    bst.n_features_in_ = num_feature
    return bst


def _arena_to_tree(ens, t: int, num_feature: int) -> dict:
    """One arena row -> one xgboost pointer tree (preorder node ids)."""
    feature = np.asarray(ens.feature[t])
    threshold = np.asarray(ens.threshold[t], np.float32)
    default_left = np.asarray(ens.default_left[t])
    leaf_value = np.asarray(ens.leaf_value[t], np.float32)
    is_leaf = np.asarray(ens.is_leaf[t])
    gain = np.asarray(ens.gain[t], np.float32)

    ids: dict[int, int] = {}  # heap slot -> xgboost node id (preorder)
    slots: list[int] = []
    stack = [0]
    while stack:
        slot = stack.pop()
        ids[slot] = len(slots)
        slots.append(slot)
        if not is_leaf[slot]:
            stack.append(2 * slot + 2)  # preorder: left pops first
            stack.append(2 * slot + 1)

    n = len(slots)
    lc, rc, parents = [-1] * n, [-1] * n, [_INT32_MAX] * n
    sc, si, dl = [0.0] * n, [0] * n, [0] * n
    lg, sh, bw = [0.0] * n, [0.0] * n, [0.0] * n
    for slot in slots:
        nid = ids[slot]
        if is_leaf[slot]:
            sc[nid] = float(leaf_value[slot])
            bw[nid] = float(leaf_value[slot])
            continue
        lc[nid] = ids[2 * slot + 1]
        rc[nid] = ids[2 * slot + 2]
        parents[lc[nid]] = nid
        parents[rc[nid]] = nid
        # x <= t (ours) == x < succ(t) (xgboost), exactly, in float32.
        sc[nid] = float(np.nextafter(
            threshold[slot], np.float32(np.inf), dtype=np.float32
        ))
        si[nid] = int(feature[slot])
        dl[nid] = int(default_left[slot])
        g = float(gain[slot])
        lg[nid] = g if np.isfinite(g) else 0.0

    return {
        "base_weights": bw,
        "categories": [],
        "categories_nodes": [],
        "categories_segments": [],
        "categories_sizes": [],
        "default_left": dl,
        "id": t,
        "left_children": lc,
        "loss_changes": lg,
        "parents": parents,
        "right_children": rc,
        "split_conditions": sc,
        "split_indices": si,
        "split_type": [0] * n,
        "sum_hessian": sh,
        "tree_param": {
            "num_deleted": "0",
            "num_feature": str(num_feature),
            "num_nodes": str(n),
            "size_leaf_vector": "1",
        },
    }


def export_xgboost_json(booster, path: str | None = None) -> dict:
    """Write a fitted repro Booster as an `xgboost.Booster` JSON model.

    Returns the model dict; when `path` is given it is also serialised
    there, ready for `xgboost.Booster(model_file=path)`. Thresholds are
    nudged one float32 ulp up so xgboost's strict-less routing reproduces
    our traversal exactly; a later re-import round-trips bit-exactly.
    """
    ens = getattr(booster, "ensemble", None)
    if ens is None:
        raise RuntimeError("Booster is not fitted yet — nothing to export")
    objective = booster.cfg.objective
    if objective not in _EXPORT_OBJECTIVE:
        raise ValueError(
            f"objective {objective!r} has no xgboost equivalent; "
            f"exportable: {sorted(_EXPORT_OBJECTIVE)}"
        )
    nf = getattr(booster, "n_features_in_", None)
    if nf is None and getattr(booster, "cuts", None) is not None:
        nf = int(booster.cuts.shape[0])
    if nf is None:
        raise ValueError("cannot infer feature count for export")

    k = ens.n_classes
    n_trees = ens.n_trees
    trees = [_arena_to_tree(ens, t, nf) for t in range(n_trees)]
    doc = {
        "learner": {
            "attributes": {},
            "feature_names": [],
            "feature_types": [],
            "gradient_booster": {
                "model": {
                    "gbtree_model_param": {
                        "num_parallel_tree": "1",
                        "num_trees": str(n_trees),
                    },
                    "iteration_indptr": list(range(0, n_trees + 1, k)),
                    "tree_info": [t % k for t in range(n_trees)],
                    "trees": trees,
                },
                "name": "gbtree",
            },
            "learner_model_param": {
                "base_score": repr(
                    _margin_to_prob(float(ens.base_score), objective)
                ),
                "boost_from_average": "1",
                "num_class": str(k if k > 1 else 0),
                "num_feature": str(nf),
                "num_target": "1",
            },
            "objective": {"name": _EXPORT_OBJECTIVE[objective]},
        },
        "version": [2, 0, 0],
    }
    if objective == "binary:logistic":
        doc["learner"]["objective"]["reg_loss_param"] = {
            "scale_pos_weight": "1"
        }
    if objective == "multi:softmax":
        doc["learner"]["objective"]["softmax_multiclass_param"] = {
            "num_class": str(k)
        }
    if path is not None:
        with open(path, "w") as fh:
            json.dump(doc, fh)
    return doc
