"""PredictEngine — the serving front end (DESIGN.md §14).

Wraps a fitted (or imported) Booster behind a `predict(X)` call shaped for
request traffic rather than training:

  * Shape-bucketed compiled caches. XLA compiles one program per input
    shape; naive serving of mixed request sizes would recompile constantly.
    Incoming batches are padded up to a small static ladder of power-of-two
    row buckets, so after one warmup pass per bucket NO request size ever
    triggers a recompile (asserted by a trace counter the tests read).
    Padding rows are NaN — the legal missing marker, routed through default
    directions like any missing value — and are sliced off the output.
  * Donated input blocks. Off CPU the padded device block is donated to the
    compiled call (`donate_argnums`), letting XLA reuse its buffer for the
    margin output instead of allocating fresh HBM per request. CPU backends
    ignore donation, so it is gated to avoid the warning.
  * Persistent host staging. One preallocated float32 staging buffer per
    bucket: the request's rows are copied (and dtype-converted — the single
    float32 conversion on this path) into the buffer's head, the tail is
    NaN, and the device transfer always leaves from the same page-aligned
    allocation (the pinned-host pattern; on CPU it simply avoids per-call
    allocation).
  * Latency accounting. Every call records rows, wall seconds, and whether
    it compiled; `stats()` reduces to p50/p99 latency and rows/s with
    compile calls excluded (they are warmup, not steady state).

Validation mirrors DeviceDMatrix: inputs must be 2-D with the model's
feature count, ±inf is rejected with the same remedy message, NaN stays
legal missing.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predict as PR

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192)


class PredictEngine:
    """Batched-inference engine over a fitted Booster.

    Args:
      booster: a fitted `repro.core.Booster` (trained here or imported via
        `repro.serve.interop.import_xgboost_json`).
      buckets: ascending row-count ladder to pad batches onto. Requests
        larger than the top bucket are served in top-bucket slices.
      output_margin: serve raw margins instead of transformed predictions.
      iteration_range: XGBoost-style (a, b) round slice baked in at engine
        build (staged serving: one engine per stage, no per-call slicing).
      host_staging: keep one persistent staging buffer per bucket.

    `predict(X)` returns a numpy array of X's row count.
    """

    def __init__(
        self,
        booster,
        *,
        buckets: tuple[int, ...] = DEFAULT_BUCKETS,
        output_margin: bool = False,
        iteration_range: tuple[int, int] = (0, 0),
        host_staging: bool = True,
    ):
        if getattr(booster, "ensemble", None) is None:
            raise RuntimeError(
                "PredictEngine requires a fitted Booster — call fit() or "
                "import a model first"
            )
        buckets = tuple(sorted(int(b) for b in buckets))
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive ints, got {buckets!r}")

        ens = booster.ensemble
        if iteration_range != (0, 0):
            ens = PR.slice_rounds(ens, *iteration_range)
        self._ens = ens
        self._max_depth = booster.cfg.max_depth
        self._transform = None if output_margin else booster.obj.transform
        self._buckets = buckets
        self._host_staging = bool(host_staging)

        nf = getattr(booster, "n_features_in_", None)
        if nf is None and getattr(booster, "cuts", None) is not None:
            nf = int(booster.cuts.shape[0])
        if nf is None:
            raise ValueError(
                "cannot infer the model's feature count; booster has "
                "neither cuts nor n_features_in_"
            )
        self.n_features = int(nf)

        self._compiled: dict[int, object] = {}  # bucket -> jit'd fn
        self._staging: dict[int, np.ndarray] = {}
        self._trace_count = 0  # bumped at trace time; tests assert on it
        self.calls: list[dict] = []

    # --- compiled cache ----------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Number of XLA traces taken so far (one per bucket after warmup —
        a steady-state engine never increases this)."""
        return self._trace_count

    def _bucket_for(self, n_rows: int) -> int:
        for b in self._buckets:
            if n_rows <= b:
                return b
        return self._buckets[-1]

    def _compiled_for(self, bucket: int):
        fn = self._compiled.get(bucket)
        if fn is None:
            def traced(ens, block):
                # Trace-time side effect only: retraces are recompiles.
                self._trace_count += 1
                m = PR._fold_classes(
                    _traverse_raw(ens, block, self._max_depth), ens,
                    block.shape[0],
                )
                return m if self._transform is None else self._transform(m)

            donate = () if jax.default_backend() == "cpu" else (1,)
            fn = jax.jit(traced, donate_argnums=donate)
            self._compiled[bucket] = fn
        return fn

    def _stage(self, x: np.ndarray, bucket: int) -> np.ndarray:
        """Copy the batch into the bucket's persistent staging buffer (the
        single float32 conversion), NaN-fill the padding tail."""
        buf = self._staging.get(bucket)
        if buf is None:
            buf = np.empty((bucket, self.n_features), np.float32)
            if self._host_staging:
                self._staging[bucket] = buf
        n = x.shape[0]
        np.copyto(buf[:n], x, casting="unsafe")
        buf[n:] = np.nan
        return buf

    # --- serving -----------------------------------------------------------
    def warmup(self) -> "PredictEngine":
        """Compile every bucket up front so the first real request never
        pays a trace."""
        probe = np.zeros((1, self.n_features), np.float32)
        for b in self._buckets:
            fn = self._compiled_for(b)
            jax.block_until_ready(fn(self._ens, jnp.asarray(self._stage(probe, b))))
        return self

    def predict(self, x) -> np.ndarray:
        """Serve one request batch. Accepts any 2-D array-like; rows beyond
        the largest bucket are processed in largest-bucket slices."""
        t0 = time.perf_counter()
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(
                f"x must be 2-D (n_rows, n_features), got shape {x.shape}"
            )
        if x.shape[1] != self.n_features:
            raise ValueError(
                f"x has {x.shape[1]} features, model expects "
                f"{self.n_features}"
            )
        if x.shape[0] == 0:
            raise ValueError("x has 0 rows; nothing to predict")
        if np.isinf(x).any():
            raise ValueError(
                "x contains infinite feature values; replace ±inf with NaN "
                "(the legal missing marker) or a large finite value before "
                "prediction"
            )

        top = self._buckets[-1]
        parts = []
        compiled_before = self._trace_count
        for s in range(0, x.shape[0], top):
            part = x[s : s + top]
            bucket = self._bucket_for(part.shape[0])
            fn = self._compiled_for(bucket)
            block = jnp.asarray(self._stage(part, bucket))
            out = fn(self._ens, block)
            parts.append(np.asarray(out)[: part.shape[0]])
        result = parts[0] if len(parts) == 1 else np.concatenate(parts)

        self.calls.append({
            "rows": int(x.shape[0]),
            "seconds": time.perf_counter() - t0,
            "compiled": self._trace_count > compiled_before,
        })
        return result

    # --- accounting --------------------------------------------------------
    def stats(self, include_warmup: bool = False) -> dict:
        """p50/p99 latency and throughput over recorded calls. Calls that
        paid an XLA trace are excluded unless include_warmup=True."""
        calls = [
            c for c in self.calls if include_warmup or not c["compiled"]
        ]
        if not calls:
            return {"n_calls": 0}
        lat = np.array([c["seconds"] for c in calls])
        rows = sum(c["rows"] for c in calls)
        return {
            "n_calls": len(calls),
            "rows": rows,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "rows_per_s": float(rows / lat.sum()),
        }

    def reset_stats(self) -> None:
        self.calls.clear()


def _traverse_raw(ens: PR.Ensemble, x: jax.Array, max_depth: int):
    from repro.serve.traversal import traverse_ensemble_raw

    return traverse_ensemble_raw(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, x, max_depth,
    )
