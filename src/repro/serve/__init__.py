"""repro.serve — batched GBDT inference (DESIGN.md §14).

The training side of the paper got six PRs; this package is the serving
side: a dedicated batched-inference stack over the compact ensemble arena.

  * `traversal`  — fused ensemble traversal: ALL trees x a row block advance
    one level per step in a single program (levelwise gathers on the arena's
    SoA arrays), replacing the per-tree `lax.scan` of `core.predict` for
    batch inference. Bin-space fast path when the model carries cut points,
    raw-threshold path otherwise; a Pallas kernel lives in
    `kernels.ensemble_traversal` with the XLA form as its parity oracle.
  * `engine`     — `PredictEngine`: shape-bucketed compiled predict caches
    (mixed request sizes pad up to a small static set of power-of-two row
    buckets, so serving traffic never recompiles), donated output buffers,
    optional persistent host staging, and per-call latency accounting
    (p50/p99, rows/s).
  * `interop`    — XGBoost model-format interop: load a real
    `xgboost.Booster` JSON into our arena (matching its predictions) and
    export our Booster to that JSON, so the server can front models trained
    anywhere.
"""
from repro.serve.engine import PredictEngine
from repro.serve.interop import (
    export_xgboost_json,
    import_xgboost_json,
)

__all__ = [
    "PredictEngine",
    "export_xgboost_json",
    "import_xgboost_json",
]
