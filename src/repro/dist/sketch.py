"""Device-sharded quantile sketch construction (paper §quantiles).

The paper moves quantile sketch construction onto the accelerator because it
is a considerable preprocessing cost, and distributes it data-parallel: each
device summarises its row shard, then the summaries are merged. This module
reproduces that split on top of the mergeable `StreamingQuantileSketch`
(DESIGN.md §11):

  * **Device phase** — the O(n log n) part. Under `shard_map`, every shard
    fills NaN -> +inf and sorts each of its columns on device (one fused XLA
    program across all shards), also counting finite entries. No
    inter-device communication happens here.
  * **Host phase** — each shard's presorted columns become exact summaries
    via `StreamingQuantileSketch.push_sorted` (no host re-sort), and the
    per-shard sketches combine by a **log-depth pairwise tree merge**.
    Merging exact summaries is exact and associative, so with adequate
    capacity the merged cuts match single-shot `compute_cuts`; under
    pruning, tree merging performs O(log S) prune rounds instead of the
    sequential fold's O(S), tightening the rank-error bound.

`sharded_sketch_cuts` is the one-call front door used by
`DeviceDMatrix(cuts=...)` precomputation and `ExternalDMatrix(sketch_shards=)`.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantile import (
    DEFAULT_MAX_BINS,
    StreamingQuantileSketch,
)
from repro.jaxcompat import shard_map

from jax.sharding import PartitionSpec as P


def tree_merge(sketches: Sequence[StreamingQuantileSketch]):
    """Merge sketches pairwise in log-depth order.

    Round t merges sketch 2k with sketch 2k+1; after ceil(log2(S)) rounds
    one sketch remains. Exact summaries make the result merge-order
    invariant; pruned summaries see at most ceil(log2(S)) prune rounds on
    any leaf-to-root path (vs S-1 for a sequential fold).

    Mutates the sketches (merge folds right into left); the survivor is
    returned.
    """
    sketches = list(sketches)
    if not sketches:
        raise ValueError("tree_merge needs at least one sketch")
    while len(sketches) > 1:
        nxt = []
        for i in range(0, len(sketches) - 1, 2):
            nxt.append(sketches[i].merge(sketches[i + 1]))
        if len(sketches) % 2:
            nxt.append(sketches[-1])
        sketches = nxt
    return sketches[0]


def _device_sort_phase(x, mesh, data_axes):
    """Sort every column per shard on device; return host arrays.

    Returns (sorted_cols, n_valid): sorted_cols is (n_shards, shard_rows,
    n_features) with each column ascending, NaN pushed to the tail as +inf;
    n_valid is (n_shards, n_features) finite counts.
    """
    axes = tuple(data_axes)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    n = x.shape[0]
    if n % n_shards:
        raise ValueError(
            f"rows ({n}) must divide evenly across {n_shards} shards for "
            f"the device sketch phase"
        )

    def shard_fn(xs):
        finite = jnp.isfinite(xs)
        filled = jnp.where(finite, xs, jnp.inf)
        srt = jnp.sort(filled, axis=0)
        nv = jnp.sum(finite, axis=0, dtype=jnp.int32)[None, :]
        return srt, nv

    srt, nv = shard_map(
        shard_fn,
        mesh,
        in_specs=(P(axes, None),),
        out_specs=(P(axes, None), P(axes, None)),
    )(jnp.asarray(x, jnp.float32))
    srt_h = np.asarray(jax.device_get(srt)).reshape(n_shards, n // n_shards,
                                                    x.shape[1])
    nv_h = np.asarray(jax.device_get(nv)).reshape(n_shards, x.shape[1])
    return srt_h, nv_h


def sharded_sketch_cuts(
    x,
    *,
    max_bins: int = DEFAULT_MAX_BINS,
    capacity: int = 1024,
    mesh: jax.sharding.Mesh | None = None,
    data_axes: Sequence[str] = ("data",),
    n_shards: int | None = None,
) -> jax.Array:
    """Quantile cuts via per-shard sketches + log-depth tree merge.

    With `mesh`, the sort runs sharded on device (`shard_map`) and the
    number of shards is the mesh's data-axis extent. Without a mesh,
    `n_shards` (default 1) row-splits on host — the same merge tree, useful
    for tests and for bounding host working memory.

    Returns cuts shaped exactly like `compute_cuts(x, max_bins)`.
    """
    x = np.asarray(x, np.float32) if not isinstance(x, jax.Array) else x
    n, f = x.shape
    if mesh is not None:
        srt, nv = _device_sort_phase(x, mesh, data_axes)
        shards = srt.shape[0]
        sketches = []
        for s in range(shards):
            sk = StreamingQuantileSketch(f, max_bins, capacity)
            sk.push_sorted(srt[s], nv[s])
            sketches.append(sk)
        return tree_merge(sketches).get_cuts()
    shards = max(1, int(n_shards or 1))
    xh = np.asarray(x, np.float32)
    bounds = np.linspace(0, n, shards + 1, dtype=np.int64)
    sketches = []
    for s in range(shards):
        sk = StreamingQuantileSketch(f, max_bins, capacity)
        part = xh[bounds[s]: bounds[s + 1]]
        if part.shape[0]:
            sk.push(part)
        sketches.append(sk)
    return tree_merge(sketches).get_cuts()
