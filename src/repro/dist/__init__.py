"""Scale-out subsystem: pluggable collectives, compressed histogram
allreduce, device-sharded sketch construction (DESIGN.md §15).

Supersedes `repro.core.distributed` (kept as a re-export shim). Public
surface:

  * `Collective` + `PsumCollective` / `RingCollective` /
    `HierarchicalCollective`, selected by name via
    `Booster.fit(mesh=, collective=)` or directly via `get_collective`;
    `register_collective` adds strategies to the registry.
  * `CommStats` / `round_comm_stats` — the analytic per-round wire-byte
    and collective-call accounting surfaced on `Booster.comm_stats`.
  * `sharded_sketch_cuts` / `tree_merge` — data-parallel quantile sketch
    build (device-sorted shards, log-depth merge; paper §quantiles).
  * `RoundInputs` / `make_distributed_round` / `make_chunk_runner` — the
    shard_map training round behind `fit(mesh=)`.
"""
from repro.dist.collective import (
    Collective,
    CommStats,
    HierarchicalCollective,
    PsumCollective,
    RingCollective,
    collective_names,
    get_collective,
    register_collective,
    round_comm_stats,
)
from repro.dist.runner import (
    RoundInputs,
    make_chunk_runner,
    make_distributed_round,
    train_distributed,
)
from repro.dist.sketch import (
    sharded_sketch_cuts,
    tree_merge,
)

__all__ = [
    "Collective",
    "CommStats",
    "HierarchicalCollective",
    "PsumCollective",
    "RingCollective",
    "RoundInputs",
    "collective_names",
    "get_collective",
    "make_chunk_runner",
    "make_distributed_round",
    "register_collective",
    "round_comm_stats",
    "sharded_sketch_cuts",
    "train_distributed",
    "tree_merge",
]
