"""Pluggable collectives for distributed histogram aggregation (DESIGN.md §15).

The per-level histogram AllReduce is the distributed hot path of Algorithm 1
(the paper's NCCL AllReduceHistograms call; Zhang et al. 1706.08359 measure
inter-device histogram traffic as the scaling bottleneck). This module makes
that collective a strategy object so `Booster.fit(mesh=, collective=)` can
pick the reduction topology, and makes the payload compressible (f16 or
fixed-point int16 bin sums) with an on-device error check that falls back to
the exact f32 reduction when the compression error exceeds tolerance.

Three strategies live behind one registry:

  * ``psum`` — `jax.lax.psum`, XLA's fused all-reduce. The default; with
    compression off it compiles to the exact pre-subsystem program.
  * ``ring`` — an explicit segmented reduce-scatter + all-gather built from
    `jax.lax.ppermute` (NCCL's ring algorithm, spelled out). Each of the p
    devices sends 2*(p-1)/p of the payload, and — unlike psum — the wire
    dtype is under our control, so compressed hops genuinely halve bytes.
  * ``hier`` — two-level: intra-host psum over contiguous device groups
    (cheap links), then a ring over one lane of group leaders (expensive
    links), then an intra-host broadcast. Compression applies to the
    inter-host hops only, mirroring how real multi-host topologies are
    provisioned.

Compression modes (``compression=`` on any collective):

  * ``None``  — exact f32 payloads (bit-identical to the pre-subsystem psum
    path when the collective is ``psum``).
  * ``"f16"`` — bin sums cast to float16 for transport. Per-shard cast
    error is measured on device; accumulation error is not modelled (ring/
    hier accumulate in f32, plain psum accumulates in f16).
  * ``"q16"`` — fixed-point int16: a shared scale is derived from the
    psum of per-shard max magnitudes (so no partial sum can overflow
    int16), each shard quantises to integers, and the integer reduction is
    exact and order-independent — every collective produces bit-identical
    quantised results.

Error model: elementwise, |compressed_sum - exact_sum| <= sum over shards of
that shard's own max compression error, so ``psum(max |decode(encode(x)) -
x|)`` is an on-device upper bound on the true error, available *without*
computing the exact reduction. When the bound exceeds
``tolerance * psum(max|x|)`` the level falls back to the exact f32
reduction via `lax.cond` (the predicate is a psum result, hence replicated,
so every device takes the same branch). Fallback events are tallied at
trace time and surfaced per fit in `Booster.comm_stats`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp


_COMPRESSIONS = (None, "f16", "q16")


def _check_compression(compression):
    if compression not in _COMPRESSIONS:
        raise ValueError(
            f"compression must be one of {_COMPRESSIONS}, got {compression!r}"
        )


class Collective:
    """Reduction strategy for shard-partial arrays inside shard_map.

    Subclasses implement `_reduce_exact` (f32/any-dtype exact allreduce) and
    `_reduce_wire` (allreduce whose wire dtype is `wire`, accumulating in
    `acc`), plus the analytic `bytes_allreduce` wire model. The compressed
    encode/check/fallback logic is shared here in `allreduce_hist`.
    """

    name = "?"

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        data_axes: Sequence[str] = ("data",),
        *,
        compression: str | None = None,
        tolerance: float = 0.05,
    ):
        _check_compression(compression)
        if tolerance < 0:
            raise ValueError(f"tolerance must be >= 0, got {tolerance}")
        self.mesh = mesh
        self.axes = tuple(data_axes)
        self.sizes = tuple(mesh.shape[a] for a in self.axes)
        self.n_devices = math.prod(self.sizes)
        self.compression = compression
        self.tolerance = float(tolerance)
        self._tally: list | None = None

    # --- identity (compiled-fn cache key component) ------------------------
    @property
    def key(self):
        return (type(self).__name__, self.axes, self.compression,
                self.tolerance)

    # --- trace-time fallback tally -----------------------------------------
    def begin_round(self) -> None:
        """Reset the fallback tally; call at the top of a traced round."""
        self._tally = []

    def fallback_count(self) -> jax.Array:
        """Traced count of compressed allreduces that fell back to f32 this
        round (replicated scalar; 0 when compression is off)."""
        if not self._tally:
            return jnp.zeros((), jnp.int32)
        return sum(self._tally)

    # --- reduction entry points (called inside shard_map) ------------------
    def allreduce(self, x: jax.Array) -> jax.Array:
        """Exact allreduce (root sums, fallbacks, non-hot-path payloads)."""
        return self._reduce_exact(x)

    def allreduce_hist(self, hist: jax.Array) -> jax.Array:
        """The per-level histogram allreduce — compressed when configured,
        with the on-device error check and f32 fallback."""
        if self.compression is None:
            return self._reduce_exact(hist)
        axes = self.axes
        m_local = jnp.max(jnp.abs(hist))
        if self.compression == "f16":
            comp = hist.astype(jnp.float16)
            err_local = jnp.max(jnp.abs(comp.astype(jnp.float32) - hist))
            # One tiny collective: [max-magnitude, per-shard-error] together.
            m_sum, err_bound = jax.lax.psum(
                jnp.stack([m_local, err_local]), axes
            )

            def compressed():
                return self._reduce_wire(hist, jnp.float16, jnp.float32)
        else:  # q16 fixed point
            m_sum = jax.lax.psum(m_local, axes)
            # |sum_s x_s| <= sum_s max|x_s| = m_sum elementwise, so scaling
            # by m_sum/32766 keeps every partial sum inside int16.
            scale = jnp.maximum(m_sum, jnp.float32(1e-30)) / jnp.float32(32766.0)
            q = jnp.clip(
                jnp.round(hist / scale), -32767.0, 32767.0
            ).astype(jnp.int32)
            err_local = jnp.max(jnp.abs(q.astype(jnp.float32) * scale - hist))
            err_bound = jax.lax.psum(err_local, axes)

            def compressed():
                total = self._reduce_wire(q, jnp.int16, jnp.int32)
                return total.astype(jnp.float32) * scale

        ok = err_bound <= self.tolerance * m_sum + jnp.float32(1e-30)
        out = jax.lax.cond(ok, compressed, lambda: self._reduce_exact(hist))
        if self._tally is not None:
            self._tally.append(jnp.where(ok, 0, 1).astype(jnp.int32))
        return out

    # --- strategy internals ------------------------------------------------
    def _reduce_exact(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def _reduce_wire(self, x, wire, acc) -> jax.Array:
        raise NotImplementedError

    # --- analytic wire model (DESIGN.md §15) -------------------------------
    def wire_bytes_elem(self) -> int:
        """Bytes per element actually moved for a compressed hist allreduce
        (4 when the strategy cannot shrink its wire dtype)."""
        return 4

    def bytes_allreduce(self, n_elems: int, elem_bytes: int = 4) -> int:
        """Total wire bytes (summed over every device) for one allreduce of
        n_elems, under the bandwidth-optimal 2*(p-1)/p-per-device model."""
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        comp = f", compression={self.compression}" if self.compression else ""
        return f"{type(self).__name__}({self.n_devices} devices{comp})"


class PsumCollective(Collective):
    """`jax.lax.psum` — XLA's fused all-reduce (the pre-subsystem path).

    f16 compression psums the f16 array directly (f16 on the wire *and* in
    the accumulation). q16 must psum int32 (int16 partial sums are not
    expressible through psum), so its wire bytes stay 4 — pick ``ring`` or
    ``hier`` for genuinely narrower q16 transport.
    """

    name = "psum"

    def _reduce_exact(self, x):
        return jax.lax.psum(x, self.axes)

    def _reduce_wire(self, x, wire, acc):
        if wire == jnp.int16:  # psum cannot carry int16 partials
            return jax.lax.psum(x.astype(jnp.int32), self.axes)
        return jax.lax.psum(x.astype(wire), self.axes).astype(acc)

    def wire_bytes_elem(self) -> int:
        return 2 if self.compression == "f16" else 4

    def bytes_allreduce(self, n_elems, elem_bytes=4):
        p = self.n_devices
        return 2 * (p - 1) * n_elems * elem_bytes


def _ring_allreduce(x, axis_name, n, perm, ring_pos, wire, acc):
    """Segmented ring reduce-scatter + all-gather via ppermute.

    Payload is split into n segments; over n-1 hops each device accumulates
    one segment's full sum (partials travel in `wire` dtype, adds happen in
    `acc`), then n-1 more hops broadcast the finished segments. Total traffic
    is 2*(n-1)/n of the payload per participating device — NCCL's ring.
    """
    if n == 1:
        return x.astype(acc)
    shape = x.shape
    flat = x.reshape(-1).astype(acc)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    segs = flat.reshape(n, -1)

    def rs_step(t, segs):
        send = jnp.take(segs, (ring_pos - t) % n, axis=0).astype(wire)
        recv = jax.lax.ppermute(send, axis_name, perm)
        return segs.at[(ring_pos - t - 1) % n].add(recv.astype(acc))

    segs = jax.lax.fori_loop(0, n - 1, rs_step, segs)

    def ag_step(t, segs):
        send = jnp.take(segs, (ring_pos + 1 - t) % n, axis=0).astype(wire)
        recv = jax.lax.ppermute(send, axis_name, perm)
        return segs.at[(ring_pos - t) % n].set(recv.astype(acc))

    segs = jax.lax.fori_loop(0, n - 1, ag_step, segs)
    return segs.reshape(-1)[: x.size].reshape(shape)


class RingCollective(Collective):
    """Explicit segmented ring over a single data axis.

    Sends exactly 2*(p-1)/p of the payload per device per allreduce and
    carries compressed dtypes on the wire: f16 hops accumulate locally in
    f32; q16 hops are int16 with int32 local accumulation (exact — the
    shared scale bounds every partial inside int16).
    """

    name = "ring"

    def __init__(self, mesh, data_axes=("data",), **kw):
        super().__init__(mesh, data_axes, **kw)
        if len(self.axes) != 1:
            raise ValueError(
                f"ring collective runs over exactly one mesh axis, got "
                f"{self.axes}; use 'hier' for multi-axis meshes"
            )
        self._perm = [(i, (i + 1) % self.n_devices)
                      for i in range(self.n_devices)]

    def _ring(self, x, wire, acc):
        pos = jax.lax.axis_index(self.axes[0]).astype(jnp.int32)
        return _ring_allreduce(x, self.axes[0], self.n_devices, self._perm,
                               pos, wire, acc)

    def _reduce_exact(self, x):
        return self._ring(x, x.dtype, x.dtype)

    def _reduce_wire(self, x, wire, acc):
        return self._ring(x.astype(acc), wire, acc)

    def wire_bytes_elem(self) -> int:
        return 2 if self.compression in ("f16", "q16") else 4

    def bytes_allreduce(self, n_elems, elem_bytes=4):
        p = self.n_devices
        seg = -(-n_elems // p)  # padded segment length
        return 2 * (p - 1) * p * seg * elem_bytes


class HierarchicalCollective(Collective):
    """Two-level reduction: intra-host psum, inter-host ring, intra-host
    broadcast.

    On a two-axis mesh ``(inter, intra)`` the group structure is the mesh's;
    on a single axis of size p the devices are factored into contiguous
    groups of ``group_size`` (default: the largest divisor <= sqrt(p)).
    Only lane 0 of each group participates in the inter-host ring (the
    ppermute permutation names no other lanes, so they exchange nothing),
    and a final grouped psum broadcasts lane 0's totals group-wide.
    Compression applies to the inter-host hops only — the intra-host psum
    stays f32/int32 — matching how multi-host bandwidth is actually tiered.
    """

    name = "hier"

    def __init__(self, mesh, data_axes=("data",), *, group_size=None, **kw):
        super().__init__(mesh, data_axes, **kw)
        if len(self.axes) == 2:
            self.n_hosts, self.group_size = self.sizes
            if group_size is not None and group_size != self.group_size:
                raise ValueError(
                    f"group_size={group_size} conflicts with the inner mesh "
                    f"axis {self.axes[1]} of size {self.sizes[1]}"
                )
        elif len(self.axes) == 1:
            p = self.n_devices
            if group_size is None:
                group_size = max(
                    (d for d in range(1, int(math.isqrt(p)) + 1)
                     if p % d == 0),
                    default=1,
                )
            if p % group_size != 0:
                raise ValueError(
                    f"group_size={group_size} must divide the "
                    f"{p}-device data axis"
                )
            self.group_size, self.n_hosts = group_size, p // group_size
        else:
            raise ValueError(
                f"hier collective supports 1- or 2-axis meshes, got {self.axes}"
            )
        g, h = self.group_size, self.n_hosts
        self._intra_groups = [list(range(i * g, (i + 1) * g))
                              for i in range(h)]
        # Inter-host ring over lane 0 of each group only.
        self._inter_perm = [(i * g, ((i + 1) % h) * g) for i in range(h)]

    @property
    def key(self):
        return super().key + (self.group_size,)

    def _two_level(self, x, wire, acc):
        axis = self.axes[0]
        g, h = self.group_size, self.n_hosts
        if len(self.axes) == 2:
            y = jax.lax.psum(x.astype(acc), self.axes[1])
            pos = jax.lax.axis_index(self.axes[0]).astype(jnp.int32)
            perm = [(i, (i + 1) % h) for i in range(h)]
            return _ring_allreduce(y, self.axes[0], h, perm, pos, wire, acc)
        # Single axis, factored groups: intra reduce -> lane-0 ring ->
        # intra broadcast. Lanes != 0 run the same ppermute program but the
        # permutation never addresses them, so they send/receive nothing
        # meaningful and are masked out of the broadcast.
        idx = jax.lax.axis_index(axis).astype(jnp.int32)
        lane = idx % g
        host = idx // g
        y = jax.lax.psum(x.astype(acc), axis,
                         axis_index_groups=self._intra_groups)
        t = _ring_allreduce(y, axis, h, self._inter_perm, host, wire, acc)
        masked = jnp.where(lane == 0, t, jnp.zeros_like(t))
        return jax.lax.psum(masked, axis,
                            axis_index_groups=self._intra_groups)

    def _reduce_exact(self, x):
        return self._two_level(x, x.dtype, x.dtype).astype(x.dtype)

    def _reduce_wire(self, x, wire, acc):
        return self._two_level(x.astype(acc), wire, acc)

    def wire_bytes_elem(self) -> int:
        # Blended per-element cost: intra hops stay 4B, inter hops shrink.
        return 2 if self.compression in ("f16", "q16") else 4

    def bytes_allreduce(self, n_elems, elem_bytes=4):
        g, h = self.group_size, self.n_hosts
        seg = -(-n_elems // h)
        intra = 2 * h * 2 * (g - 1) * n_elems * 4  # reduce + broadcast, f32
        inter = 2 * (h - 1) * h * seg * elem_bytes  # lane-0 ring
        return intra + inter


_REGISTRY: dict[str, type[Collective]] = {
    "psum": PsumCollective,
    "ring": RingCollective,
    "hier": HierarchicalCollective,
    "hierarchical": HierarchicalCollective,
}


def register_collective(name: str, cls: type[Collective]) -> type[Collective]:
    """Register a Collective strategy under a `fit(collective=...)` name."""
    if not issubclass(cls, Collective):
        raise TypeError(f"{cls} must subclass Collective")
    _REGISTRY[name] = cls
    return cls


def collective_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_collective(
    spec,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    *,
    compression: str | None = None,
    tolerance: float = 0.05,
    **kw,
) -> Collective:
    """Resolve `fit(collective=...)`: a registry name, a Collective subclass,
    or an already-constructed Collective (returned as-is)."""
    if isinstance(spec, Collective):
        return spec
    if isinstance(spec, type) and issubclass(spec, Collective):
        return spec(mesh, data_axes, compression=compression,
                    tolerance=tolerance, **kw)
    if isinstance(spec, str):
        cls = _REGISTRY.get(spec)
        if cls is None:
            raise ValueError(
                f"unknown collective {spec!r}; registered: "
                f"{', '.join(collective_names())}"
            )
        return cls(mesh, data_axes, compression=compression,
                   tolerance=tolerance, **kw)
    raise TypeError(
        f"collective must be a name, Collective subclass or instance, "
        f"got {type(spec)}"
    )


# --- per-round communication accounting (DESIGN.md §15) ---------------------


@dataclasses.dataclass(frozen=True)
class CommStats:
    """Analytic per-round communication profile of a distributed fit.

    Bytes are wire totals summed over all devices under the strategy's
    documented model; `fallback_events` is measured (traced tally) and
    filled in after the fit.
    """

    collective: str
    compression: str | None
    devices: int
    bytes_per_round: int
    bytes_per_round_f32: int
    collective_calls_per_round: int
    hist_bytes_per_level: tuple[int, ...]
    fallback_events: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hist_bytes_per_level"] = list(self.hist_bytes_per_level)
        return d


def round_comm_stats(
    collective: Collective,
    *,
    max_depth: int,
    n_features: int,
    max_bins: int,
    n_trees_per_round: int = 1,
    sentinel: bool = False,
) -> CommStats:
    """Bytes and collective calls for ONE boosting round under Algorithm 1:
    per tree, one tiny root-sum allreduce plus one histogram allreduce per
    level (sharded growth always builds full levels — the histogram-
    subtraction shortcut is a single-shard optimisation), plus the
    compression side-channel (scale/error scalars) and the optional numeric
    sentinel's count psum."""
    comp = collective.compression
    wire = collective.wire_bytes_elem()
    per_level, per_level_f32 = [], []
    calls = 0
    for level in range(max_depth):
        n_elems = (2 ** level) * n_features * max_bins * 2
        per_level.append(collective.bytes_allreduce(n_elems, wire))
        per_level_f32.append(collective.bytes_allreduce(n_elems, 4))
        calls += 1
        if comp == "f16":
            calls += 1  # stacked [max, err] scalar psum
        elif comp == "q16":
            calls += 2  # max psum, then err psum (scale-dependent)
    overhead = 0
    if comp is not None:
        # Scale/error side-channel scalars travel via plain psum (not the
        # strategy): bandwidth-optimal model 2*(p-1)*N*B.
        scalars = 2 * max_depth
        overhead = 2 * (collective.n_devices - 1) * scalars * 4
    root = collective.bytes_allreduce(2, 4)
    k = n_trees_per_round
    bytes_round = k * (sum(per_level) + overhead + root)
    bytes_round_f32 = k * (sum(per_level_f32) + root)
    calls = k * (calls + 1)  # +1 root sum per tree
    if sentinel:
        bytes_round += collective.bytes_allreduce(1, 4)
        bytes_round_f32 += collective.bytes_allreduce(1, 4)
        calls += 1
    return CommStats(
        collective=collective.name,
        compression=comp,
        devices=collective.n_devices,
        bytes_per_round=int(bytes_round),
        bytes_per_round_f32=int(bytes_round_f32),
        collective_calls_per_round=int(calls),
        hist_bytes_per_level=tuple(int(b) for b in per_level),
    )
