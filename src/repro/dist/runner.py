"""Multi-device GBDT training (paper §2.3, Algorithm 1) via shard_map.

Rows are partitioned across the `data` (and `pod`) mesh axes — the paper's
"each GPU processes a subset of training instances". Each shard builds
partial histograms; a pluggable `Collective` strategy combines them (the
NCCL AllReduceHistograms call — psum, explicit ring, or hierarchical
two-level, optionally with compressed bin sums; see dist.collective). Split
evaluation and tree state are replicated, positions stay shard-local. The
per-round function is a single shard_map body, so XLA sees one SPMD program
with exactly one all-reduce per tree level.

This module supersedes `repro.core.distributed` (which re-exports it for
back compatibility). All round inputs travel as one named `RoundInputs`
pytree so every strategy shares a single shard_map signature.

Beyond-paper option (`feature_shards` > 1): histograms are additionally
sharded over features on the `model` axis, turning the full-histogram
all-reduce into a reduce-scatter-shaped psum of 1/p of the bytes, with each
shard evaluating only its features and an argmax-allgather of the (tiny)
per-node best-split records. See EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.core import compress as C
from repro.core import objectives as O
from repro.core import resilience as RES
from repro.core import sampling as SMP
from repro.core import tree as T
from repro.dist.collective import (
    Collective,
    get_collective,
    round_comm_stats,
)


class RoundInputs(NamedTuple):
    """Everything one distributed boosting round consumes, as ONE pytree.

    One named structure replaces the old positional 4-or-5 argument
    signature (the replicated stochastic key used to ride along as an
    ad-hoc 5th shard_map arg): `specs()` builds the matching shard_map
    in_specs, so every dist/ strategy shares a single signature and adding
    a replicated field is a one-line change. `rkey=None` is an empty
    pytree leaf — the same compiled signature serves deterministic fits.
    """

    data: Any  # row-sharded matrix (dense | packed words | chunk stack)
    margins: Any  # (n_local, k) row-sharded
    y: Any  # (n_local, ...) row-sharded labels
    cuts: Any  # replicated (f, n_cuts)
    rkey: Any = None  # replicated per-round PRNG key (stochastic fits)

    @staticmethod
    def specs(data_spec, row_spec, stochastic: bool) -> "RoundInputs":
        return RoundInputs(
            data=data_spec,
            margins=row_spec,
            y=row_spec,
            cuts=P(),
            rkey=P() if stochastic else None,
        )


# Compiled per-round shard_map programs and eval-margin updaters, keyed by
# static config (cuts/data are traced arguments) — mirrors
# booster._TRAIN_FN_CACHE so refits with mesh= skip recompilation too.
_ROUND_FN_CACHE: dict = {}
_APPLY_EVAL_CACHE: dict = {}


def make_distributed_round(
    cfg,
    obj: O.Objective,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    n_rows_per_shard: int | None = None,
    bits: int | None = None,
    chunk_rows: int | None = None,
    collective: Collective | None = None,
):
    """Returns a jit'd per-round function over a RoundInputs pytree.

    The returned fn takes one `RoundInputs` whose data/margins/y are
    row-sharded over data_axes and cuts/rkey replicated; tree output is
    replicated. Cached by static config (incl. the collective's identity
    key) so repeated fits reuse the compiled program.

    `collective` picks the histogram-reduction strategy (default: exact
    psum — the pre-subsystem program, bit for bit). `chunk_rows` set means
    external-memory data: each shard holds a stack of independently packed
    chunks (its row shard), and the per-level histogram is a chunk-scan
    on-shard followed by the usual allreduce — the chunk loop composes
    with Algorithm 1's AllReduce unchanged.
    """
    if collective is None:
        collective = get_collective("psum", mesh, data_axes)
    # Objective is a hashable NamedTuple; registry lookups return singletons,
    # so registered (incl. custom-registered) objectives key stably.
    key = (cfg, obj, mesh, tuple(data_axes), n_rows_per_shard, bits,
           chunk_rows, collective.key)
    cached = _ROUND_FN_CACHE.get(key)
    if cached is not None:
        return cached
    k = obj.n_outputs(cfg.n_classes)
    cfg_kw = O.config_kwargs(cfg)  # static under shard_map (cfg keys cache)
    chunked = chunk_rows is not None
    stoch = SMP.stochastic_params(cfg)
    sentinel = cfg.numeric_check != "off"
    compressed = collective.compression is not None
    # Static shard geometry for the shared-key sampling (DESIGN.md §12):
    # every shard draws the SAME global row selection / feature masks from
    # the replicated per-round key, then slices its own rows — identical to
    # the single-device sample, no extra collective, allreduce unchanged.
    axis_sizes = tuple(mesh.shape[a] for a in data_axes)
    n_shards = 1
    for s in axis_sizes:
        n_shards *= s

    def _shard_offset(n_local):
        lin = jnp.int32(0)
        for a, s in zip(data_axes, axis_sizes):
            lin = lin * s + jax.lax.axis_index(a)
        return lin * n_local

    def round_body(inputs: RoundInputs):
        from repro.core import booster as B  # lazy: avoid import cycle

        data, margins, y, cuts, rkey = inputs
        collective.begin_round()  # trace-time fallback tally reset
        if chunked:
            # External-memory: this shard's chunk stack is its matrix.
            rep = C.ChunkedPackedBins(
                packed=data, bits=bits, chunk_rows=chunk_rows,
                n_rows=n_rows_per_shard,
            )
        elif cfg.compress_matrix:
            # Packed-native: each shard's words ARE its training matrix —
            # no per-round unpack, no dense (n, f) bins (DESIGN.md §2).
            rep = C.PackedBins(packed=data, bits=bits, n_rows=n_rows_per_shard)
        else:
            rep = data
        n_features = (
            rep.n_features if cfg.compress_matrix or chunked
            else rep.shape[1]
        )
        gh_all = obj.grad(margins, y, **cfg_kw)
        gh_raw = gh_all
        if cfg.numeric_check == "clamp":
            gh_all = RES.clamp_gradients(gh_all)
        trees = []
        for c in range(k):
            gh_c = gh_all[:, c, :]
            ctx = None
            if stoch is not None:
                n_local = margins.shape[0]
                ctx, gh_c = SMP.make_tree_context(
                    stoch, jax.random.fold_in(rkey, c), gh_c, n_features,
                    compact=False,
                    n_total=n_local * n_shards,
                    row_offset=_shard_offset(n_local),
                    # GOSS needs the GLOBAL |g| vector: gh is all_gather'd
                    # over the data axes (gather order == the runner's row
                    # linearisation) so every shard draws the identical
                    # replicated selection, then slices at row_offset.
                    axis_name=tuple(data_axes),
                )
            tr = T.grow_tree(
                rep,
                gh_c,
                cuts,
                cfg.max_depth,
                cfg.max_bins,
                cfg.split_params,
                growth=cfg.growth,
                max_leaves=cfg.max_leaves or 2**cfg.max_depth,
                ctx=ctx,
                collective=collective,
            )
            # Materialise tree arrays before the margin update (same
            # barrier as booster._round_step_fn — see DESIGN.md §11).
            trees.append(jax.lax.optimization_barrier(tr))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        # One barriered add for all k columns, shared with the
        # single-device scan so both compile the update identically.
        new_margins = B._apply_stacked_trees(cfg, stacked, rep, margins)
        out = [stacked, new_margins]
        if sentinel:
            # Gradients/margins are shard-local; a shard seeing non-finite
            # values must poison the round globally (trees are replicated),
            # so the bad count is all-reduced before the policy applies.
            ok_local = RES.finite_flags(gh_raw, stacked.leaf_value,
                                        new_margins)
            bad = collective.allreduce(
                jnp.where(ok_local, 0, 1).astype(jnp.int32)
            )
            ok = bad == 0
            if cfg.numeric_check == "warn_skip":
                # Same neutralisation as booster._round_step_fn: zero
                # leaves, -inf gains, round-start margins carried forward.
                stacked = stacked._replace(
                    leaf_value=jnp.where(ok, stacked.leaf_value,
                                         jnp.zeros_like(stacked.leaf_value)),
                    gain=jnp.where(ok, stacked.gain,
                                   jnp.full_like(stacked.gain, -jnp.inf)),
                )
                new_margins = jnp.where(ok, new_margins, margins)
            out = [stacked, new_margins, ok]
        if compressed:
            # Replicated count of hist allreduces that fell back to exact
            # f32 this round (tolerance exceeded) — surfaced in comm_stats.
            out.append(collective.fallback_count())
        return tuple(out)

    axes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    row_spec = P(axes)
    if chunked:
        # chunk stack is (C, F, W): rows live in whole chunks on axis 0.
        data_spec = P(axes, None, None)
    elif cfg.compress_matrix:
        # packed matrix is (F, W): rows live in the words axis.
        data_spec = P(None, axes)
    else:
        data_spec = P(axes, None)

    in_specs = (RoundInputs.specs(data_spec, row_spec, stoch is not None),)
    out_specs = (P(), row_spec)
    if sentinel:
        out_specs = out_specs + (P(),)  # all-reduced ok flag, replicated
    if compressed:
        out_specs = out_specs + (P(),)  # fallback tally, replicated
    shard_fn = jaxcompat.shard_map(
        round_body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    fn = _ROUND_FN_CACHE[key] = jax.jit(shard_fn)
    return fn


def make_chunk_runner(
    cfg,
    obj: O.Objective,
    dmat,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str],
    eval_pbs: tuple = (),
    eval_ys: tuple = (),
    eval_extras: tuple = (),
    metrics: tuple = (),
    track_metric: bool = False,
    collective="psum",
    compression: str | None = None,
    comm_tolerance: float = 0.05,
):
    """The multi-device strategy behind Booster.fit(dtrain, mesh=...).

    Shards the DeviceDMatrix's rows over the data axes (re-packing the words
    per shard so each shard decodes independently), then exposes the same
    chunk interface as the single-device scan:

        run(length, start_round, margins, eval_margins) ->
            (margins, stacked_trees (length, k, arena...),
             train_metrics tuple-per-metric of (length,), eval_margins,
             eval_metrics tuple-per-set of tuple-per-metric of (length,),
             sentinel flags ((length,) bool, or () when numeric_check="off"))

    plus two attributes the Booster surfaces: `run.comm_stats` (analytic
    per-round CommStats for the chosen collective/compression) and
    `run.fallback_events` (measured count of compressed allreduces that
    fell back to exact f32, accumulated across calls).

    The per-round loop dispatches one shard_map'd program per round (one
    allreduce per tree level, Algorithm 1); eval-set margins are maintained
    incrementally on replicated eval data, and every requested metric is
    evaluated per round with values staying on device until the Booster
    reads them at chunk granularity — the same multi-metric stack as the
    single-device scan.
    """
    from repro.core.dmatrix import ExternalDMatrix

    coll = get_collective(collective, mesh, data_axes,
                          compression=compression, tolerance=comm_tolerance)
    n = dmat.n_rows
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    if n % n_shards != 0:
        raise ValueError(
            f"n_rows={n} must be divisible by the {n_shards} data shards "
            "(truncate or pad upstream)"
        )
    cuts = dmat.cuts
    if isinstance(dmat, ExternalDMatrix):
        # External-memory + multi-device: whole chunks are the sharding
        # unit (each chunk already decodes independently, so no per-shard
        # re-packing is needed). Shard boundaries must align with chunk
        # boundaries so each shard's rows are exactly its chunks' rows.
        if n % dmat.chunk_rows != 0:
            raise ValueError(
                f"external-memory training with mesh= requires n_rows={n} "
                f"to be a multiple of chunk_rows={dmat.chunk_rows} (the "
                "last chunk must be full so shards get whole chunks)"
            )
        if dmat.n_chunks % n_shards != 0:
            raise ValueError(
                f"n_chunks={dmat.n_chunks} must be divisible by the "
                f"{n_shards} data shards; pick chunk_rows so chunks "
                "distribute evenly"
            )
        bits, n_per = dmat.bits, n // n_shards
        data = dmat.packed_bins().packed
        chunk_rows = dmat.chunk_rows
    elif cfg.compress_matrix:
        # Re-pack per shard so each shard's words decode independently.
        # Cached on the DeviceDMatrix: the dense-bins transient (the matrix
        # DESIGN.md §2 bans from steady state) exists once per shard count,
        # not once per fit.
        bits = dmat.bits
        n_per = n // n_shards
        chunk_rows = None
        data = dmat._shard_pack_cache.get(n_shards)
        if data is None:
            bins = dmat.matrix.unpack()
            packed_shards = [
                C.pack(bins[i * n_per : (i + 1) * n_per], bits)
                for i in range(n_shards)
            ]
            data = jnp.concatenate(packed_shards, axis=1)  # (F, n_shards*W)
            dmat._shard_pack_cache[n_shards] = data
    else:
        data = dmat.matrix.unpack()
        bits, n_per, chunk_rows = None, None, None

    axes = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    row_sharding = jax.NamedSharding(mesh, P(axes))
    if chunk_rows is not None:
        data_spec = P(axes, None, None)  # whole chunks per shard
    elif cfg.compress_matrix:
        data_spec = P(None, axes)
    else:
        data_spec = P(axes, None)
    data_sharding = jax.NamedSharding(mesh, data_spec)
    y = jax.device_put(dmat.label, row_sharding)
    data = jax.device_put(data, data_sharding)
    round_fn = make_distributed_round(
        cfg, obj, mesh, data_axes, n_rows_per_shard=n_per, bits=bits,
        chunk_rows=chunk_rows, collective=coll,
    )

    from repro.core import booster as B  # lazy: avoid import cycle

    apply_eval = _APPLY_EVAL_CACHE.get(cfg)
    if apply_eval is None:
        apply_eval = _APPLY_EVAL_CACHE[cfg] = jax.jit(
            lambda stacked, pb, m, _cfg=cfg:
                B._apply_stacked_trees(_cfg, stacked, pb, m)
        )

    train_kw = O.config_kwargs(cfg)  # group_ids is single-device only
    stoch = SMP.stochastic_params(cfg)
    base_key = jax.random.PRNGKey(cfg.seed) if stoch is not None else None

    sentinel = cfg.numeric_check != "off"
    compressed = coll.compression is not None

    def run(length, start_round, margins, eval_margins):
        margins = jax.device_put(margins, row_sharding)
        trees, tr_rows, ev_rows, ok_rows, fb_rows = [], [], [], [], []
        for r in range(length):
            if stoch is None:
                rkey = None
            else:
                # Same fold path as the single-device scan body, from the
                # ABSOLUTE round index — single- and multi-device fits draw
                # identical samples/masks (DESIGN.md §12).
                rkey = jax.random.fold_in(
                    base_key, jnp.asarray(start_round + r, jnp.int32)
                )
            out = list(round_fn(RoundInputs(data, margins, y, cuts, rkey)))
            if compressed:
                fb_rows.append(out.pop())
            if sentinel:
                stacked, margins, ok = out
                ok_rows.append(ok)
            else:
                stacked, margins = out
            trees.append(stacked)
            eval_margins = tuple(
                apply_eval(stacked, pb, em)
                for pb, em in zip(eval_pbs, eval_margins)
            )
            if track_metric:
                tr_rows.append(tuple(
                    m.fn(margins, y, **train_kw).astype(jnp.float32)
                    for m in metrics
                ))
            ev_rows.append(tuple(
                tuple(m.fn(em, ey, **ex).astype(jnp.float32) for m in metrics)
                for em, ey, ex in zip(eval_margins, eval_ys, eval_extras)
            ))
        all_trees = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
        tr_metrics = tuple(
            jnp.stack([row[j] for row in tr_rows])
            for j in range(len(metrics))
        ) if track_metric else ()
        ev_metrics = tuple(
            tuple(jnp.stack([row[i][j] for row in ev_rows])
                  for j in range(len(metrics)))
            for i in range(len(eval_pbs))
        )
        flags = jnp.stack(ok_rows) if sentinel else ()
        if fb_rows:
            run.fallback_events += int(sum(int(f) for f in fb_rows))
        return margins, all_trees, tr_metrics, eval_margins, ev_metrics, flags

    run.fallback_events = 0
    run.comm_stats = round_comm_stats(
        coll,
        max_depth=cfg.max_depth,
        n_features=int(cuts.shape[0]),
        max_bins=cfg.max_bins,
        n_trees_per_round=obj.n_outputs(cfg.n_classes),
        sentinel=sentinel,
    )
    return run


def train_distributed(
    x,
    y,
    cfg,
    mesh: jax.sharding.Mesh,
    data_axes: Sequence[str] = ("data",),
    verbose_every: int = 0,
):
    """Deprecated shim: quantises x and runs Booster.fit(dtrain, mesh=mesh).

    Returns the same Booster object as single-device training (the old
    (ensemble, margins, history) tuple is reachable as attributes)."""
    from repro.core.booster import Booster
    from repro.core.dmatrix import DeviceDMatrix

    dtrain = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
    return Booster(cfg).fit(dtrain, verbose_every=verbose_every, mesh=mesh,
                            data_axes=data_axes)
