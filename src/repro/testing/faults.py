"""Fault-injection harness for the resilience layer (DESIGN.md §13).

Production code exposes *failure points* — named sites where the failures
that matter at paper scale (preemption, device OOM, corrupted host-paged
chunks, non-finite gradients, failed checkpoint writes) can be provoked on
demand. Each site is a single cheap call (`check`, `corrupt_array`, or
`trace_key`) that is a no-op unless a fault has been armed for it, so the
hooks cost nothing in normal operation and nothing is monkeypatched in
tests: the chaos suite arms a fault, runs the real code path, and asserts
the resilience machinery (detection, retry, policy, fallback) responds.

Known sites (the production call points):

  * ``chunk_load``       — ExternalDMatrix page-in (host -> device transfer);
                           raises a transient error, exercising retry/backoff.
  * ``chunk_corrupt``    — bit-flips one word of the host-paged chunk stack
                           on page-in; the per-chunk crc32 must catch it.
  * ``checkpoint_write`` — checkpoint/io.save_pytree; raises an OSError
                           before any bytes are written (atomicity check).
  * ``oom``              — Booster training dispatch; raises SimulatedOOM
                           (message mimics XLA's RESOURCE_EXHAUSTED), driving
                           the ``fit(on_oom="external")`` degradation path.
  * ``nan_grad``         — gradient corruption INSIDE the compiled scan at a
                           chosen round (payload: round=, value=); drives the
                           numeric-sentinel policies.

Usage::

    from repro.testing import faults

    with faults.inject("chunk_load", error=faults.TransientLoadError, times=2):
        dmat.packed_bins()   # first two attempts fail, retry succeeds

Arming is process-local and NOT thread-safe — the harness is for tests.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

SITES = ("chunk_load", "chunk_corrupt", "checkpoint_write", "oom", "nan_grad")


class TransientLoadError(IOError):
    """A retryable chunk-load failure (the kind backoff should absorb)."""


class SimulatedOOM(RuntimeError):
    """Stands in for jaxlib's XlaRuntimeError: RESOURCE_EXHAUSTED, which
    cannot be provoked deterministically on a test-sized host."""

    def __init__(self, msg: str = "RESOURCE_EXHAUSTED: simulated device OOM"):
        super().__init__(msg)


@dataclass
class FaultSpec:
    """One armed fault: raise/corrupt at `site`, `times` activations
    (None = every hit), skipping the first `after` hits."""

    site: str
    error: Callable[[], BaseException] | type | None = None
    times: int | None = 1
    after: int = 0
    payload: dict = field(default_factory=dict)
    hits: int = 0  # times the site was reached
    fired: int = 0  # times the fault actually activated

    def should_fire(self) -> bool:
        self.hits += 1
        if self.hits <= self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        self.fired += 1
        return True

    def make_error(self) -> BaseException:
        err = self.error or RuntimeError
        made = err() if isinstance(err, type) else err()
        if not isinstance(made, BaseException):
            raise TypeError(f"fault error factory returned {type(made)}")
        return made


_ACTIVE: dict[str, FaultSpec] = {}


def arm(site: str, *, error=None, times: int | None = 1, after: int = 0,
        **payload) -> FaultSpec:
    """Arm `site`. Unknown site names raise (catches typos in tests)."""
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r}; known: {SITES}")
    spec = FaultSpec(site=site, error=error, times=times, after=after,
                     payload=payload)
    _ACTIVE[site] = spec
    return spec


def disarm(site: str) -> None:
    _ACTIVE.pop(site, None)


def reset() -> None:
    _ACTIVE.clear()


def active(site: str) -> FaultSpec | None:
    return _ACTIVE.get(site)


@contextlib.contextmanager
def inject(site: str, *, error=None, times: int | None = 1, after: int = 0,
           **payload):
    """Context manager: arm on entry, disarm on exit. Yields the FaultSpec
    so tests can assert `spec.fired`."""
    spec = arm(site, error=error, times=times, after=after, **payload)
    try:
        yield spec
    finally:
        disarm(site)


# --- production-side hooks ---------------------------------------------------

def check(site: str) -> None:
    """Raise the armed fault's error at this failure point (no-op when the
    site is unarmed or its fire budget is exhausted)."""
    if not _ACTIVE:  # fast path: nothing armed anywhere
        return
    spec = _ACTIVE.get(site)
    if spec is not None and spec.should_fire():
        raise spec.make_error()


def corrupt_array(site: str, arr: np.ndarray) -> np.ndarray:
    """Bit-flip corruption hook: when `site` is armed, return a COPY of
    `arr` with one bit flipped (payload: chunk=, index=, bit= select the
    flat element within that chunk / leading slot). The input is never
    mutated — the corruption models damage in a transfer buffer, not in
    the caller's data."""
    if not _ACTIVE:
        return arr
    spec = _ACTIVE.get(site)
    if spec is None or not spec.should_fire():
        return arr
    out = np.array(arr, copy=True)
    chunk = int(spec.payload.get("chunk", 0))
    index = int(spec.payload.get("index", 0))
    bit = int(spec.payload.get("bit", 0))
    flat = out[chunk].reshape(-1)
    flat[index % flat.size] ^= np.asarray(
        1 << (bit % (flat.dtype.itemsize * 8)), flat.dtype
    )
    return out


def trace_key(site: str) -> tuple | None:
    """Hashable identity of the armed fault at `site`, for callers that bake
    the fault into a compiled/traced program and cache by configuration
    (booster._TRAIN_FN_CACHE): distinct faults get distinct cache entries,
    and the unarmed state keys as None so clean programs are never polluted
    by a previously armed fault."""
    spec = _ACTIVE.get(site)
    if spec is None:
        return None
    return (site, tuple(sorted(spec.payload.items())))
