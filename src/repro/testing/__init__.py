"""Testing utilities shipped with the package: the fault-injection harness
(`repro.testing.faults`) used by the chaos test suite and CI chaos-smoke job
to drive the resilience layer (DESIGN.md §13)."""
