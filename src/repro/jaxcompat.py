"""Version-tolerant wrappers for jax APIs that moved between releases.

The repo targets current jax, but several deployment images pin older
releases (e.g. 0.4.x lacks jax.shard_map / jax.sharding.AxisType /
jax.set_mesh). Multi-device code routes through these shims so the same
source runs on both; everything degrades to the oldest supported
spelling, never to a behaviour change.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map (new) or jax.experimental.shard_map.shard_map (old),
    with per-output replication checking disabled under either name."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def make_mesh(axis_shapes, axis_names):
    """jax.make_mesh, passing axis_types only where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)
