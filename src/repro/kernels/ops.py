"""jit'd public wrappers + backend dispatch for the Pallas kernels.

Two hist_builder entry points for grow_tree(hist_builder=...):

* `build_histograms_kernel_packed` — the compressed-native path
  (BoosterConfig(use_kernel_histograms=True, compress_matrix=True)): the
  privatised Pallas kernel consumes the training matrix's packed uint32
  words directly, no unpack/repack round trip anywhere (DESIGN.md §2/§16).
* `build_histograms_kernel` — dense-input compatibility path
  (compress_matrix=False): packs once so the kernel still exercises its
  unpack-in-VMEM path; only sees uncompressed workloads.

This module is also where quantile-cut construction picks its backend
(`compute_cuts_op`): the sort stage goes to the host's np.sort on CPU (the
XLA CPU sort is ~an order of magnitude slower at 1M rows) and to the XLA
device sort elsewhere; the selection stage goes to the Pallas kernel
(kernels/quantile_cuts.py) on accelerators when the sorted block fits
VMEM, and to the shared XLA selection otherwise. All paths emit
bit-identical cuts (tests/test_quantile.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import quantile as Q
from repro.kernels.histogram import histogram_packed, build_histograms_packed_kernel
from repro.kernels.quantile_cuts import quantile_cuts_from_sorted
from repro.kernels.split_scan import split_scan
from repro.kernels.decompress import decompress
from repro.kernels.ensemble_traversal import ensemble_margins_kernel

# Largest row count the cut-selection kernel keeps resident per feature
# block: (rows, F_BLK=8) f32 -> 4 MB at this bound, within VMEM budget.
CUTS_KERNEL_MAX_ROWS = 131072


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins", "bits"))
def histogram_packed_op(packed, gh, positions, n_nodes: int, max_bins: int, bits: int):
    return histogram_packed(packed, gh, positions, n_nodes, max_bins, bits)


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "max_bins", "bits", "n_private", "buffer_depth"),
)
def histogram_private_op(
    packed, gh, positions, n_nodes: int, max_bins: int, bits: int,
    n_private: int = 8, buffer_depth: int = 2,
):
    """The privatised double-buffered kernel (DESIGN.md §16), jit'd."""
    return build_histograms_packed_kernel(
        packed, gh, positions, n_nodes, max_bins, bits,
        n_private=n_private, buffer_depth=buffer_depth,
    )


def build_histograms_kernel_packed(
    data: C.PackedBins,
    gh: jax.Array,
    positions: jax.Array,
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Packed-native drop-in for core.histogram.build_histograms_packed:
    feeds the training matrix's packed words straight to the privatised
    Pallas kernel."""
    return histogram_private_op(
        data.packed, gh, positions, n_nodes, max_bins, data.bits
    )


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins"))
def build_histograms_kernel(
    bins: jax.Array,  # (n, f) int32 dense rows (compress_matrix=False path)
    gh: jax.Array,
    positions: jax.Array,
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Drop-in for core.histogram.build_histograms via the Pallas kernel.

    Packs the dense bins (cheap, fused by XLA) so the kernel exercises the
    same unpack-in-VMEM path it runs on TPU.
    """
    bits = C.bits_needed(max_bins - 1)
    packed = C.pack(bins, bits)
    return build_histograms_packed_kernel(
        packed, gh, positions, n_nodes, max_bins, bits
    )


@jax.jit
def _cuts_prep(x: jax.Array):
    """Missing-value fill + finite counts, shared by both sort backends."""
    x = x.astype(jnp.float32)
    finite = jnp.isfinite(x)
    return jnp.where(finite, x, jnp.inf), jnp.sum(finite, axis=0)


@functools.partial(jax.jit, static_argnames=("max_bins",))
def _compute_cuts_device(x: jax.Array, max_bins: int) -> jax.Array:
    """Fully on-device cut construction: XLA column sort, then the Pallas
    selection kernel when the sorted block fits VMEM, else the shared XLA
    selection."""
    filled, n_valid = _cuts_prep(x)
    srt = jnp.sort(filled, axis=0)
    if (
        jax.default_backend() != "cpu"
        and srt.shape[0] <= CUTS_KERNEL_MAX_ROWS
    ):
        return quantile_cuts_from_sorted(srt, n_valid, max_bins)
    return Q.select_cuts_from_sorted(srt, n_valid, max_bins)


def compute_cuts_op(x: jax.Array, max_bins: int) -> jax.Array:
    """Backend-dispatched compute_cuts (see module docstring). Bit-identical
    to core.quantile.compute_cuts_reference on every path.

    On CPU the sort stage runs on the HOST, at the Python level, between
    two jitted stages: numpy's cache-blocked introsort beats the XLA CPU
    sort by >10x at 1M rows and produces the identical array (same
    multiset per column; floats without NaN are totally ordered). It is
    deliberately NOT a pure_callback inside the jitted graph — a callback
    that materialises an intermediate of the executable that is invoking
    it (np.asarray on the operand) deadlocks the XLA CPU runtime, so the
    sort input is fetched only after `_cuts_prep` has fully completed.
    Under a jit trace (x is a Tracer) the eager host detour is impossible
    and the all-device path is used instead."""
    if isinstance(x, jax.core.Tracer) or jax.default_backend() != "cpu":
        return _compute_cuts_device(x, max_bins)
    filled, n_valid = _cuts_prep(x)
    srt = jnp.asarray(np.sort(np.asarray(filled), axis=0))
    return Q.select_cuts_from_sorted(srt, n_valid, max_bins)


def quantize_op(x: jax.Array, cuts: jax.Array) -> jax.Array:
    """Backend-dispatched quantize. Bit-identical to
    core.quantile.quantize_reference on every path.

    On CPU the per-column binary search runs on the host: numpy's
    searchsorted over the same ascending f32 cuts performs the identical
    sequence of exact float comparisons as the XLA lowering, but without
    XLA's gather/while overhead — ~15% faster at 1M rows and, more
    importantly for the DMatrix build, with zero compile time. NaN rows
    are overridden to the missing bin on both paths, so whatever either
    binary search returns for a NaN key never escapes. Under a jit trace
    (or off-CPU) the jitted reference runs instead."""
    if (
        isinstance(x, jax.core.Tracer)
        or isinstance(cuts, jax.core.Tracer)
        or jax.default_backend() != "cpu"
    ):
        return Q.quantize_reference(x, cuts)
    xn = np.asarray(x, np.float32)
    cn = np.asarray(cuts)
    n_cuts = cn.shape[1]
    out = np.empty(xn.shape, np.int32)
    for j in range(xn.shape[1]):
        col = xn[:, j]
        b = np.searchsorted(cn[j], col, side="left").astype(np.int32)
        out[:, j] = np.where(np.isnan(col), np.int32(n_cuts + 1), b)
    return jnp.asarray(out)


@functools.partial(jax.jit, static_argnames=("reg_lambda", "min_child_weight"))
def split_scan_op(hist, parent_sum, reg_lambda: float = 1.0, min_child_weight: float = 1.0):
    return split_scan(hist, parent_sum, reg_lambda, min_child_weight)


@functools.partial(jax.jit, static_argnames=("bits", "n_rows"))
def decompress_op(packed, bits: int, n_rows: int):
    return decompress(packed, bits, n_rows)


@functools.partial(jax.jit, static_argnames=("n_classes", "max_depth"))
def ensemble_margins_op(
    feature, threshold, default_left, leaf_value, is_leaf,
    x, n_classes: int, max_depth: int,
):
    """Raw-input serving margins (minus base_score) via the fused
    ensemble-traversal kernel (one launch for all trees x all rows)."""
    return ensemble_margins_kernel(
        feature, threshold, default_left, leaf_value, is_leaf,
        x, n_classes, max_depth,
    )
