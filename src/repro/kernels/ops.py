"""jit'd public wrappers for the Pallas kernels.

Two hist_builder entry points for grow_tree(hist_builder=...):

* `build_histograms_kernel_packed` — the compressed-native path
  (BoosterConfig(use_kernel_histograms=True, compress_matrix=True)): the
  Pallas kernel consumes the training matrix's packed uint32 words
  directly, no unpack/repack round trip anywhere (DESIGN.md §2).
* `build_histograms_kernel` — dense-input compatibility path
  (compress_matrix=False): packs once so the kernel still exercises its
  unpack-in-VMEM path; only sees uncompressed workloads.
"""
from __future__ import annotations

import functools

import jax

from repro.core import compress as C
from repro.kernels.histogram import histogram_packed
from repro.kernels.split_scan import split_scan
from repro.kernels.decompress import decompress
from repro.kernels.ensemble_traversal import ensemble_margins_kernel


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins", "bits"))
def histogram_packed_op(packed, gh, positions, n_nodes: int, max_bins: int, bits: int):
    return histogram_packed(packed, gh, positions, n_nodes, max_bins, bits)


def build_histograms_kernel_packed(
    data: C.PackedBins,
    gh: jax.Array,
    positions: jax.Array,
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Packed-native drop-in for core.histogram.build_histograms_packed:
    feeds the training matrix's packed words straight to the Pallas kernel."""
    return histogram_packed_op(data.packed, gh, positions, n_nodes, max_bins, data.bits)


@functools.partial(jax.jit, static_argnames=("n_nodes", "max_bins"))
def build_histograms_kernel(
    bins: jax.Array,  # (n, f) int32 dense rows (compress_matrix=False path)
    gh: jax.Array,
    positions: jax.Array,
    n_nodes: int,
    max_bins: int,
) -> jax.Array:
    """Drop-in for core.histogram.build_histograms via the Pallas kernel.

    Packs the dense bins (cheap, fused by XLA) so the kernel exercises the
    same unpack-in-VMEM path it runs on TPU.
    """
    bits = C.bits_needed(max_bins - 1)
    packed = C.pack(bins, bits)
    return histogram_packed(packed, gh, positions, n_nodes, max_bins, bits)


@functools.partial(jax.jit, static_argnames=("reg_lambda", "min_child_weight"))
def split_scan_op(hist, parent_sum, reg_lambda: float = 1.0, min_child_weight: float = 1.0):
    return split_scan(hist, parent_sum, reg_lambda, min_child_weight)


@functools.partial(jax.jit, static_argnames=("bits", "n_rows"))
def decompress_op(packed, bits: int, n_rows: int):
    return decompress(packed, bits, n_rows)


@functools.partial(jax.jit, static_argnames=("n_classes", "max_depth"))
def ensemble_margins_op(
    feature, threshold, default_left, leaf_value, is_leaf,
    x, n_classes: int, max_depth: int,
):
    """Raw-input serving margins (minus base_score) via the fused
    ensemble-traversal kernel (one launch for all trees x all rows)."""
    return ensemble_margins_kernel(
        feature, threshold, default_left, leaf_value, is_leaf,
        x, n_classes, max_depth,
    )
