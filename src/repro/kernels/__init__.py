"""Pallas TPU kernels for the paper's compute hot spots.

histogram   — gradient histogram build from the bit-packed matrix
              (one-hot MXU matmul replacing CUDA atomicAdd, DESIGN.md §4)
split_scan  — fused prefix-sum split-gain evaluation
decompress  — runtime bit-unpack of the compressed matrix

Each has a pure-jnp oracle in ref.py and a jit wrapper in ops.py; validated
with interpret=True on CPU (TPU is the target).
"""
