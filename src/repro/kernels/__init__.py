"""Pallas TPU kernels for the paper's compute hot spots.

histogram   — gradient histogram build from the bit-packed matrix
              (one-hot MXU matmul replacing CUDA atomicAdd, DESIGN.md §4)
split_scan  — fused prefix-sum split-gain evaluation
decompress  — runtime bit-unpack of the compressed matrix
ensemble_traversal — fused all-trees x row-block inference traversal for
              the serving path (one-hot MXU selects, DESIGN.md §14)

Each has a pure-jnp oracle in ref.py and a jit wrapper in ops.py; validated
with interpret=True on CPU (TPU is the target).
"""
