"""Pallas TPU kernel: quantile cut selection from sorted columns (paper §2.1).

The paper moves quantile sketch construction on-device because it is a
considerable preprocessing cost; profiling here agrees — cut construction
dominated DMatrix build time (BENCH `phases` section). The build splits
into two stages (DESIGN.md §16):

  sort      — per-feature ascending sort of the NaN->+inf-filled column.
              Stays outside the kernel: on CPU it dispatches to the host's
              cache-blocked `np.sort` (ops.sort_columns_op), on TPU to the
              XLA device sort.
  selection — weighted-rank selection + linear interpolation + dedup of
              the interior boundaries of `n_value_bins` equal-mass bins.
              THIS kernel: grid over feature blocks, one (n, F_BLK) sorted
              block resident in VMEM, rank gathers + the interpolation
              arithmetic of `core.quantile.select_cuts_from_sorted`
              executed per feature on the VPU.

The kernel reproduces the reference selection arithmetic operation for
operation (same f32 interpolation, same guards, same dedup); parity with
`select_cuts_from_sorted` is to ~1 ulp of arithmetic — compiled XLA may
contract `lo + frac*(hi-lo)` into an FMA where the kernel's evaluation
does not — which can additionally flip a floor() at an exact integer rank
boundary and select the neighbouring order statistic (still a valid
boundary for the same equal-mass bin). The final ascending re-sort of the
candidate vector is left to the caller, as in the reference. Rows must fit in VMEM per feature block (the
ops-layer dispatch bounds this; larger matrices use the XLA selection).
The CPU training path never takes this kernel (host sort + shared XLA
selection there is bit-identical to the reference by construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    srt_ref,  # (n, F_BLK) f32, each column ascending, +inf tail
    nv_ref,  # (1, F_BLK) i32, finite count per column
    out_ref,  # (F_BLK, n_cuts) f32, pre-sort candidate cuts
    *,
    max_bins: int,
):
    n, f_blk = srt_ref.shape
    nvb = max_bins - 1  # n_value_bins(max_bins)
    # iota (not arange) and roll (not concatenate): the kernel body may not
    # capture trace-time constant arrays, only generate values in-kernel.
    ranks = jax.lax.iota(jnp.float32, nvb - 1) + 1.0

    for fi in range(f_blk):  # static unroll: F_BLK small
        col = srt_ref[:, fi]  # (n,)
        nv = nv_ref[0, fi]
        # Identical arithmetic to core.quantile.select_cuts_from_sorted.
        qs = (ranks / nvb) * jnp.maximum(nv - 1, 1).astype(jnp.float32)
        lo = jnp.clip(jnp.floor(qs).astype(jnp.int32), 0, n - 1)
        hi = jnp.clip(lo + 1, 0, n - 1)
        frac = qs - lo.astype(jnp.float32)
        lov = jnp.take(col, lo)
        hiv = jnp.take(col, hi)
        hiv = jnp.where(jnp.isfinite(hiv), hiv, lov)
        cand = lov + frac * (hiv - lov)
        cand = jnp.where(jnp.isfinite(cand), cand, jnp.inf)
        # prev[0] = -inf, prev[i] = cand[i-1]: a one-step roll re-pinned at 0.
        prev = jnp.roll(cand, 1).at[0].set(-jnp.inf)
        cand = jnp.where(cand > prev, cand, jnp.inf)
        out_ref[fi, :] = cand


@functools.partial(jax.jit, static_argnames=("max_bins", "f_blk", "interpret"))
def quantile_cuts_from_sorted(
    srt: jax.Array,  # (n, F) f32 column-sorted, +inf at the tail
    n_valid: jax.Array,  # (F,) int finite count per column
    max_bins: int,
    *,
    f_blk: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Selection stage of compute_cuts on pre-sorted columns.

    Returns (F, n_value_bins - 1) f32 ascending cuts with +inf padding —
    the exact `compute_cuts` output format.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, f = srt.shape
    nvb = max_bins - 1
    n_cuts = nvb - 1
    n_fblk = -(-f // f_blk)
    f_pad = n_fblk * f_blk - f

    # Padding features are all-+inf / zero-valid columns; their cuts come
    # out +inf and are sliced off.
    srt_p = jnp.pad(srt, ((0, 0), (0, f_pad)), constant_values=jnp.inf)
    nv_p = jnp.pad(n_valid.astype(jnp.int32), (0, f_pad))[None, :]

    kern = functools.partial(_kernel, max_bins=max_bins)
    out = pl.pallas_call(
        kern,
        grid=(n_fblk,),
        in_specs=[
            pl.BlockSpec((n, f_blk), lambda fb: (0, fb)),
            pl.BlockSpec((1, f_blk), lambda fb: (0, fb)),
        ],
        out_specs=pl.BlockSpec((f_blk, n_cuts), lambda fb: (fb, 0)),
        out_shape=jax.ShapeDtypeStruct((n_fblk * f_blk, n_cuts), jnp.float32),
        interpret=interpret,
    )(srt_p, nv_p)
    # Final ascending re-sort (pushes +inf dedup markers to the tail), same
    # as the reference's trailing jnp.sort.
    return jnp.sort(out[:f], axis=-1)
