"""Pallas TPU kernel: gradient histogram build from the bit-packed matrix.

This is the compute hot spot of the paper (§2.3 BuildPartialHistograms) and
the centrepiece of the CUDA->TPU adaptation (DESIGN.md §3/§4): CUDA builds
histograms with atomicAdd scatter; TPU has no fast atomics, so the scatter
is recast as a dense **one-hot x gradient matmul on the MXU**:

    hist[node, f, bin, :] = sum_rows onehot(node*B + bin)[row] * gh[row, :]
                          = onehot.T @ gh        (contraction over rows)

The quantised matrix arrives *compressed* (paper §2.2): `bits`-wide bin ids
packed into uint32 words, column-major per feature. In the compressed-native
training path (DESIGN.md §2) these are the training matrix's own resident
words, handed over untouched via ops.build_histograms_kernel_packed — no
unpack/repack round trip anywhere between quantisation and this kernel. The
kernel unpacks with VPU shift/mask ops in VMEM — the paper's "runtime
bitwise unpacking", which costs a few vector ops and buys >=4x HBM traffic
reduction on the dominant input stream.

Blocking (defaults; VMEM budget in parentheses for bits=8):
  grid = (node_blocks, feature_blocks, row_blocks)   row axis innermost
  packed block  (F_BLK=8, W_BLK=64)  uint32               (2 KB)
  gh block      (ROWS_BLK=spw*W_BLK=256, 2) f32           (2 KB)
  one-hot       (ROWS_BLK, NODES_BLK*B=2048) f32          (2 MB scratch)
  out block     (NODES_BLK=8, F_BLK, B, 2) f32 accumulator (128 KB)
All matmul dims are multiples of 128 when B=256 (two MXU lane groups) and
ROWS_BLK=256 — MXU-aligned per DESIGN.md §4. Accumulation across row blocks
uses the sequential innermost grid axis (out block revisited, += pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    packed_ref,  # (F_BLK, W_BLK) uint32
    gh_ref,  # (ROWS_BLK, 2) f32
    pos_ref,  # (ROWS_BLK, 1) i32
    out_ref,  # (NODES_BLK, F_BLK, B, 2) f32
    *,
    bits: int,
    nodes_blk: int,
    max_bins: int,
):
    nb = pl.program_id(0)
    rb = pl.program_id(2)
    f_blk, w_blk = packed_ref.shape
    spw = 32 // bits
    rows = w_blk * spw
    width = nodes_blk * max_bins

    # --- runtime decompression (paper §2.2) ------------------------------
    words = packed_ref[...]
    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    bins = ((words[:, :, None] >> shifts) & mask).reshape(f_blk, rows)
    bins = bins.astype(jnp.int32)

    # --- node-block membership -------------------------------------------
    pos = pos_ref[...][:, 0]  # (ROWS_BLK,)
    local = pos - nb * nodes_blk
    valid = (local >= 0) & (local < nodes_blk)
    # invalid rows -> index `width` == off the one-hot range -> zero row.
    base = jnp.where(valid, local * max_bins, width)  # (ROWS_BLK,)
    gh = gh_ref[...]  # (ROWS_BLK, 2)

    # --- one-hot MXU matmul per feature ----------------------------------
    iota = jnp.arange(width, dtype=jnp.int32)[None, :]
    acc = []
    for f in range(f_blk):  # static unroll: F_BLK small
        idx = base + bins[f]  # (ROWS_BLK,)
        onehot = (idx[:, None] == iota).astype(jnp.float32)
        part = jnp.dot(
            onehot.T, gh, preferred_element_type=jnp.float32
        )  # (width, 2)
        acc.append(part.reshape(nodes_blk, max_bins, 2))
    block = jnp.stack(acc, axis=1)  # (NODES_BLK, F_BLK, B, 2)

    @pl.when(rb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += block


def histogram_packed(
    packed: jax.Array,  # (F, W) uint32, W*spw rows (padded)
    gh: jax.Array,  # (N, 2) f32
    positions: jax.Array,  # (N,) i32; value n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    *,
    nodes_blk: int = 8,
    f_blk: int = 8,
    w_blk: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns hist (n_nodes, F, max_bins, 2) f32. Pads rows/features/nodes
    to block multiples internally; dump rows (pos == n_nodes) contribute
    nowhere."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    f, w = packed.shape
    n = gh.shape[0]
    spw = 32 // bits
    rows_blk = w_blk * spw

    nodes_blk = min(nodes_blk, max(n_nodes, 1))
    n_nblk = -(-n_nodes // nodes_blk)
    n_fblk = -(-f // f_blk)
    w_pad = (-w) % w_blk
    f_pad = n_fblk * f_blk - f
    n_rows_padded = (w + w_pad) * spw

    packed_p = jnp.pad(packed, ((0, f_pad), (0, w_pad)))
    gh_p = jnp.pad(gh, ((0, n_rows_padded - n), (0, 0)))
    pos_p = jnp.pad(
        positions.astype(jnp.int32), (0, n_rows_padded - n), constant_values=-1
    )[:, None]
    n_rblk = n_rows_padded // rows_blk

    kern = functools.partial(
        _kernel, bits=bits, nodes_blk=nodes_blk, max_bins=max_bins
    )
    out = pl.pallas_call(
        kern,
        grid=(n_nblk, n_fblk, n_rblk),
        in_specs=[
            pl.BlockSpec((f_blk, w_blk), lambda nb, fb, rb: (fb, rb)),
            pl.BlockSpec((rows_blk, 2), lambda nb, fb, rb: (rb, 0)),
            pl.BlockSpec((rows_blk, 1), lambda nb, fb, rb: (rb, 0)),
        ],
        out_specs=pl.BlockSpec(
            (nodes_blk, f_blk, max_bins, 2), lambda nb, fb, rb: (nb, fb, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n_nblk * nodes_blk, n_fblk * f_blk, max_bins, 2), jnp.float32
        ),
        interpret=interpret,
    )(packed_p, gh_p, pos_p)
    return out[:n_nodes, :f]
