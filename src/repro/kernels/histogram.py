"""Pallas TPU kernel: gradient histogram build from the bit-packed matrix.

This is the compute hot spot of the paper (§2.3 BuildPartialHistograms) and
the centrepiece of the CUDA->TPU adaptation (DESIGN.md §3/§4): CUDA builds
histograms with atomicAdd scatter; TPU has no fast atomics, so the scatter
is recast as a dense **one-hot x gradient matmul on the MXU**:

    hist[node, f, bin, :] = sum_rows onehot(node*B + bin)[row] * gh[row, :]
                          = onehot.T @ gh        (contraction over rows)

The quantised matrix arrives *compressed* (paper §2.2): `bits`-wide bin ids
packed into uint32 words, column-major per feature. In the compressed-native
training path (DESIGN.md §2) these are the training matrix's own resident
words, handed over untouched via ops.build_histograms_kernel_packed — no
unpack/repack round trip anywhere between quantisation and this kernel. The
kernel unpacks with VPU shift/mask ops in VMEM — the paper's "runtime
bitwise unpacking", which costs a few vector ops and buys >=4x HBM traffic
reduction on the dominant input stream.

Blocking (defaults; VMEM budget in parentheses for bits=8):
  grid = (node_blocks, feature_blocks, row_blocks)   row axis innermost
  packed block  (F_BLK=8, W_BLK=64)  uint32               (2 KB)
  gh block      (ROWS_BLK=spw*W_BLK=256, 2) f32           (2 KB)
  one-hot       (ROWS_BLK, NODES_BLK*B=2048) f32          (2 MB scratch)
  out block     (NODES_BLK=8, F_BLK, B, 2) f32 accumulator (128 KB)
All matmul dims are multiples of 128 when B=256 (two MXU lane groups) and
ROWS_BLK=256 — MXU-aligned per DESIGN.md §4. Accumulation across row blocks
uses the sequential innermost grid axis (out block revisited, += pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    packed_ref,  # (F_BLK, W_BLK) uint32
    gh_ref,  # (ROWS_BLK, 2) f32
    pos_ref,  # (ROWS_BLK, 1) i32
    out_ref,  # (NODES_BLK, F_BLK, B, 2) f32
    *,
    bits: int,
    nodes_blk: int,
    max_bins: int,
):
    nb = pl.program_id(0)
    rb = pl.program_id(2)
    f_blk, w_blk = packed_ref.shape
    spw = 32 // bits
    rows = w_blk * spw
    width = nodes_blk * max_bins

    # --- runtime decompression (paper §2.2) ------------------------------
    words = packed_ref[...]
    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    bins = ((words[:, :, None] >> shifts) & mask).reshape(f_blk, rows)
    bins = bins.astype(jnp.int32)

    # --- node-block membership -------------------------------------------
    pos = pos_ref[...][:, 0]  # (ROWS_BLK,)
    local = pos - nb * nodes_blk
    valid = (local >= 0) & (local < nodes_blk)
    # invalid rows -> index `width` == off the one-hot range -> zero row.
    base = jnp.where(valid, local * max_bins, width)  # (ROWS_BLK,)
    gh = gh_ref[...]  # (ROWS_BLK, 2)

    # --- one-hot MXU matmul per feature ----------------------------------
    iota = jnp.arange(width, dtype=jnp.int32)[None, :]
    acc = []
    for f in range(f_blk):  # static unroll: F_BLK small
        idx = base + bins[f]  # (ROWS_BLK,)
        onehot = (idx[:, None] == iota).astype(jnp.float32)
        part = jnp.dot(
            onehot.T, gh, preferred_element_type=jnp.float32
        )  # (width, 2)
        acc.append(part.reshape(nodes_blk, max_bins, 2))
    block = jnp.stack(acc, axis=1)  # (NODES_BLK, F_BLK, B, 2)

    @pl.when(rb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += block


def histogram_packed(
    packed: jax.Array,  # (F, W) uint32, W*spw rows (padded)
    gh: jax.Array,  # (N, 2) f32
    positions: jax.Array,  # (N,) i32; value n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    *,
    nodes_blk: int = 8,
    f_blk: int = 8,
    w_blk: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns hist (n_nodes, F, max_bins, 2) f32. Pads rows/features/nodes
    to block multiples internally; dump rows (pos == n_nodes) contribute
    nowhere."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    f, w = packed.shape
    n = gh.shape[0]
    spw = 32 // bits
    rows_blk = w_blk * spw

    nodes_blk = min(nodes_blk, max(n_nodes, 1))
    n_nblk = -(-n_nodes // nodes_blk)
    n_fblk = -(-f // f_blk)
    w_pad = (-w) % w_blk
    f_pad = n_fblk * f_blk - f
    n_rows_padded = (w + w_pad) * spw

    packed_p = jnp.pad(packed, ((0, f_pad), (0, w_pad)))
    gh_p = jnp.pad(gh, ((0, n_rows_padded - n), (0, 0)))
    pos_p = jnp.pad(
        positions.astype(jnp.int32), (0, n_rows_padded - n), constant_values=-1
    )[:, None]
    n_rblk = n_rows_padded // rows_blk

    kern = functools.partial(
        _kernel, bits=bits, nodes_blk=nodes_blk, max_bins=max_bins
    )
    out = pl.pallas_call(
        kern,
        grid=(n_nblk, n_fblk, n_rblk),
        in_specs=[
            pl.BlockSpec((f_blk, w_blk), lambda nb, fb, rb: (fb, rb)),
            pl.BlockSpec((rows_blk, 2), lambda nb, fb, rb: (rb, 0)),
            pl.BlockSpec((rows_blk, 1), lambda nb, fb, rb: (rb, 0)),
        ],
        out_specs=pl.BlockSpec(
            (nodes_blk, f_blk, max_bins, 2), lambda nb, fb, rb: (nb, fb, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n_nblk * nodes_blk, n_fblk * f_blk, max_bins, 2), jnp.float32
        ),
        interpret=interpret,
    )(packed_p, gh_p, pos_p)
    return out[:n_nodes, :f]


# --- privatised kernel with explicit DMA pipelining (DESIGN.md §16) ----------


def _private_kernel(
    packed_hbm,  # (F_pad, W_pad) uint32, whole array in HBM/ANY
    gh_hbm,  # (N_pad, 2) f32, whole array
    pos_hbm,  # (N_pad, 1) i32, whole array
    out_ref,  # (1, F_BLK, width, 2) f32 — this program's partial histogram
    words_buf,  # VMEM (buffer_depth, F_BLK, W_BLK) uint32 scratch
    gh_buf,  # VMEM (buffer_depth, ROWS_BLK, 2) f32 scratch
    pos_buf,  # VMEM (buffer_depth, ROWS_BLK, 1) i32 scratch
    acc_ref,  # VMEM (F_BLK, width, 2) f32 scratch — the privatised histogram
    sem,  # DMA semaphores (3, buffer_depth)
    *,
    bits: int,
    max_bins: int,
    width: int,
    f_blk: int,
    w_blk: int,
    chunks_per_private: int,
    buffer_depth: int,
):
    pid = pl.program_id(0)  # which private row group
    fb = pl.program_id(1)  # which feature block
    spw = 32 // bits
    rows_blk = w_blk * spw

    def copies(chunk, slot):
        """The three DMAs that stage row-chunk `chunk` into buffer `slot`."""
        word0 = (pid * chunks_per_private + chunk) * w_blk
        row0 = (pid * chunks_per_private + chunk) * rows_blk
        return (
            pltpu.make_async_copy(
                packed_hbm.at[pl.ds(fb * f_blk, f_blk), pl.ds(word0, w_blk)],
                words_buf.at[slot],
                sem.at[0, slot],
            ),
            pltpu.make_async_copy(
                gh_hbm.at[pl.ds(row0, rows_blk), :], gh_buf.at[slot], sem.at[1, slot]
            ),
            pltpu.make_async_copy(
                pos_hbm.at[pl.ds(row0, rows_blk), :], pos_buf.at[slot], sem.at[2, slot]
            ),
        )

    def start(chunk, slot):
        for c in copies(chunk, slot):
            c.start()

    def wait(chunk, slot):
        for c in copies(chunk, slot):
            c.wait()

    acc_ref[...] = jnp.zeros_like(acc_ref)
    start(0, 0)

    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    iota = jnp.arange(width, dtype=jnp.int32)[None, :]

    def body(chunk, carry):
        slot = chunk % buffer_depth

        # Prefetch the next chunk into the next slot before blocking on this
        # one — with buffer_depth >= 2 the DMA overlaps this chunk's compute.
        if buffer_depth > 1:

            @pl.when(chunk + 1 < chunks_per_private)
            def _prefetch():
                start(chunk + 1, (chunk + 1) % buffer_depth)

        wait(chunk, slot)

        words = words_buf[slot]  # (F_BLK, W_BLK)
        bins = ((words[:, :, None] >> shifts) & mask).reshape(f_blk, rows_blk)
        bins = bins.astype(jnp.int32)
        gh = gh_buf[slot]  # (ROWS_BLK, 2)
        # pos <= n_nodes always (dump slot included in width), no masking.
        base = pos_buf[slot][:, 0] * max_bins  # (ROWS_BLK,)

        for f in range(f_blk):  # static unroll: F_BLK small
            onehot = ((base + bins[f])[:, None] == iota).astype(jnp.float32)
            acc_ref[f, :, :] += jnp.dot(
                onehot.T, gh, preferred_element_type=jnp.float32
            )

        # Single-buffer pipeline: the slot is free only now.
        if buffer_depth == 1:

            @pl.when(chunk + 1 < chunks_per_private)
            def _next():
                start(chunk + 1, 0)

        return carry

    jax.lax.fori_loop(0, chunks_per_private, body, jnp.int32(0))
    out_ref[0] = acc_ref[...]


def _tree_add(parts: jax.Array) -> jax.Array:
    """Merge per-group partial histograms with a binary tree of adds.

    Log-depth, pairwise — the epilogue the paper runs after per-block
    shared-memory histograms are flushed. The summation order is fixed by
    the (static) number of groups, so results are deterministic run-to-run.
    """
    while parts.shape[0] > 1:
        half = parts.shape[0] // 2
        even = parts[0 : 2 * half : 2] + parts[1 : 2 * half : 2]
        if parts.shape[0] % 2:
            even = jnp.concatenate([even, parts[-1:]], axis=0)
        parts = even
    return parts[0]


def build_histograms_packed_kernel(
    packed: jax.Array,  # (F, W) uint32, W*spw rows (padded)
    gh: jax.Array,  # (N, 2) f32
    positions: jax.Array,  # (N,) i32; value n_nodes = inactive
    n_nodes: int,
    max_bins: int,
    bits: int,
    *,
    f_blk: int = 8,
    w_blk: int = 64,
    n_private: int = 8,
    buffer_depth: int = 2,
    interpret: bool | None = None,
) -> jax.Array:
    """Privatised packed-histogram kernel: grid (row_groups, feature_blocks).

    The CUDA kernel's shared-memory privatisation (paper §2.3) mapped to
    TPU: each of `n_private` row groups accumulates its own full
    (F_BLK, (n_nodes+1)*max_bins, 2) histogram in a VMEM scratch
    accumulator — never contending with other groups — while packed words,
    (g, h) pairs and positions are staged HBM->VMEM with explicit
    `make_async_copy` DMAs, `buffer_depth` chunks in flight (1 = serial,
    2 = classic double buffering, 4 = deeper pipeline; BENCH sweeps all
    three). The per-group partials are merged by a log-depth tree-add
    epilogue (`_tree_add`), the analogue of the CUDA grid-wide flush.

    VMEM bound: the accumulator is f_blk * (n_nodes+1) * max_bins * 2 * 4
    bytes (~0.5 MB at depth 6 defaults) plus a (ROWS_BLK, width) one-hot
    transient, which caps practical n_nodes at ~32 (DESIGN.md §16); deeper
    levels use the XLA feature-major builder instead.

    Returns hist (n_nodes, F, max_bins, 2) f32.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    f, w = packed.shape
    n = gh.shape[0]
    spw = 32 // bits
    rows_blk = w_blk * spw
    width = (n_nodes + 1) * max_bins

    n_fblk = -(-f // f_blk)
    f_pad = n_fblk * f_blk - f
    chunks_per_private = max(1, -(-w // (n_private * w_blk)))
    w_padded = n_private * chunks_per_private * w_blk
    n_rows_padded = w_padded * spw

    packed_p = jnp.pad(packed, ((0, f_pad), (0, w_padded - w)))
    gh_p = jnp.pad(gh, ((0, n_rows_padded - n), (0, 0)))
    # Padding rows -> dump slot n_nodes (sliced off below), like inactive
    # rows; clamp real inactive markers the same way.
    pos_p = jnp.pad(
        jnp.minimum(positions, n_nodes).astype(jnp.int32),
        (0, n_rows_padded - n),
        constant_values=n_nodes,
    )[:, None]

    kern = functools.partial(
        _private_kernel,
        bits=bits,
        max_bins=max_bins,
        width=width,
        f_blk=f_blk,
        w_blk=w_blk,
        chunks_per_private=chunks_per_private,
        buffer_depth=buffer_depth,
    )
    partials = pl.pallas_call(
        kern,
        grid=(n_private, n_fblk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, f_blk, width, 2), lambda pid, fb: (pid, fb, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n_private, n_fblk * f_blk, width, 2), jnp.float32
        ),
        scratch_shapes=[
            pltpu.VMEM((buffer_depth, f_blk, w_blk), jnp.uint32),
            pltpu.VMEM((buffer_depth, rows_blk, 2), jnp.float32),
            pltpu.VMEM((buffer_depth, rows_blk, 1), jnp.int32),
            pltpu.VMEM((f_blk, width, 2), jnp.float32),
            pltpu.SemaphoreType.DMA((3, buffer_depth)),
        ],
        interpret=interpret,
    )(packed_p, gh_p, pos_p)
    merged = _tree_add(partials)  # (F_pad, width, 2)
    hist = merged.reshape(n_fblk * f_blk, n_nodes + 1, max_bins, 2)
    return hist.transpose(1, 0, 2, 3)[:n_nodes, :f]
