"""Pallas TPU kernel: fused ensemble traversal for batch inference.

The serving path (`repro.serve.traversal`) advances ALL trees x a row block
one level per step. Its XLA form routes each level through arbitrary
gathers (arena SoA lookup per (tree, node), input lookup per (row,
feature)); TPUs have no fast arbitrary gather, so — exactly as the
histogram kernel recasts atomicAdd scatter (DESIGN.md §4) — this kernel
recasts both gathers as dense **one-hot matmuls on the MXU**:

    node one-hot  (TB, RB, A) @ arena field (TB, A)  -> per-pair select
    feat one-hot  (TB, RB, F) @ row block   (RB, F)  -> per-pair value

Per level that is four batched mat-vecs (feature id, threshold,
default-direction, leaf flag) plus one value select; after `max_depth`
levels a final one-hot select reads the leaf values and a small
(TB, K) class-assignment matmul folds the tree block's contribution into
the (RB, K) margin accumulator.

Blocking:
  grid = (row_blocks, tree_blocks)        tree axis innermost, sequential
  arena fields   (TREES_BLK, A) f32       A padded to a lane multiple
  row block      (ROWS_BLK, F) f32        values NaN-sanitised by wrapper
  out block      (ROWS_BLK, K) f32        accumulated across tree blocks
                                          (@pl.when(tb==0) init, += after)

NaN handling: 0 * NaN = NaN would poison the one-hot contraction, so the
wrapper splits the input into a zero-filled value plane and a {0,1}
missing-mask plane; the kernel reads missingness through the same one-hot
matmul as the values. Arena thresholds on inactive slots are sanitised to
finite placeholders for the same reason (leaf masking makes their value
irrelevant to routing).

Raw-threshold mode only: serving traffic arrives as float rows, and
imported XGBoost models carry no cut points. The bin-space fused path
stays on the XLA form in serve/traversal.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _select(noh: jax.Array, field: jax.Array) -> jax.Array:
    """One-hot arena select: (TB, RB, A) x (TB, A) -> (TB, RB)."""
    return jax.lax.dot_general(
        noh, field,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _kernel(
    feature_ref,  # (TB, A) f32 (exact small ints)
    threshold_ref,  # (TB, A) f32, finite everywhere
    default_left_ref,  # (TB, A) f32 {0, 1}
    is_leaf_ref,  # (TB, A) f32 {0, 1}
    leaf_value_ref,  # (TB, A) f32
    class_oh_ref,  # (TB, K) f32; all-zero row = padding tree
    x_ref,  # (RB, F) f32, NaN replaced by 0
    miss_ref,  # (RB, F) f32 {0, 1} NaN mask
    out_ref,  # (RB, K) f32 margin accumulator
    *,
    max_depth: int,
):
    tb = pl.program_id(1)
    feature = feature_ref[...]
    threshold = threshold_ref[...]
    default_left = default_left_ref[...]
    is_leaf = is_leaf_ref[...]
    x = x_ref[...]
    miss = miss_ref[...]
    trees_blk, arena = feature.shape
    rows_blk, n_feat = x.shape

    iota_a = jnp.arange(arena, dtype=jnp.int32)[None, None, :]
    iota_f = jnp.arange(n_feat, dtype=jnp.float32)[None, None, :]

    def level(_, node):
        noh = (node[:, :, None] == iota_a).astype(jnp.float32)  # (TB, RB, A)
        f_id = _select(noh, feature)  # exact: small ints in f32
        foh = (f_id[:, :, None] == iota_f).astype(jnp.float32)  # (TB, RB, F)
        # Batch dims lead the dot_general output: (RB, TB) -> transpose.
        v = jax.lax.dot_general(
            foh, x,
            dimension_numbers=(((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32,
        ).T  # (TB, RB)
        is_missing = jax.lax.dot_general(
            foh, miss,
            dimension_numbers=(((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32,
        ).T > 0.5
        go_left = jnp.where(
            is_missing, _select(noh, default_left) > 0.5,
            v <= _select(noh, threshold),
        )
        child = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
        return jnp.where(_select(noh, is_leaf) > 0.5, node, child)

    node = jnp.zeros((trees_blk, rows_blk), jnp.int32)
    node = jax.lax.fori_loop(0, max_depth, level, node)
    noh = (node[:, :, None] == iota_a).astype(jnp.float32)
    leaf = _select(noh, leaf_value_ref[...])  # (TB, RB)

    # Fold this tree block into per-class margins: (RB, TB) @ (TB, K).
    part = jnp.dot(
        leaf.T, class_oh_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(tb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part


def ensemble_margins_kernel(
    feature: jax.Array,  # (T, A) int32
    threshold: jax.Array,  # (T, A) f32
    default_left: jax.Array,  # (T, A) bool
    leaf_value: jax.Array,  # (T, A) f32
    is_leaf: jax.Array,  # (T, A) bool
    x: jax.Array,  # (N, F) f32, NaN = missing
    n_classes: int,
    max_depth: int,
    *,
    trees_blk: int = 32,
    rows_blk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Margins (n_rows, n_classes) WITHOUT base_score (caller adds it, as
    core.predict's _fold_classes does). Round-robin multiclass layout."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_trees, arena = feature.shape
    n_rows, n_feat = x.shape

    trees_blk = min(trees_blk, max(n_trees, 1))
    n_tblk = -(-n_trees // trees_blk)
    n_rblk = -(-n_rows // rows_blk)
    t_pad = n_tblk * trees_blk - n_trees
    r_pad = n_rblk * rows_blk - n_rows
    a_pad = (-arena) % 128  # lane-align the one-hot contraction dim

    def pad_field(a, value, dtype):
        return jnp.pad(
            a.astype(dtype), ((0, t_pad), (0, a_pad)), constant_values=value
        )

    # Padding trees are all-leaf with zero class weight; padded arena slots
    # are unreachable leaves. Inactive-slot thresholds sanitised to 0 so the
    # one-hot contraction never multiplies 0 * inf.
    feature_p = pad_field(feature, 0, jnp.float32)
    threshold_p = pad_field(jnp.nan_to_num(threshold), 0.0, jnp.float32)
    default_p = pad_field(default_left, 0.0, jnp.float32)
    leaf_val_p = pad_field(jnp.nan_to_num(leaf_value), 0.0, jnp.float32)
    is_leaf_p = pad_field(is_leaf, 1.0, jnp.float32)

    # Round-robin class id per tree, zero row for padding trees.
    cls = jnp.arange(n_tblk * trees_blk, dtype=jnp.int32) % n_classes
    class_oh = (
        (cls[:, None] == jnp.arange(n_classes, dtype=jnp.int32)[None, :])
        & (jnp.arange(n_tblk * trees_blk)[:, None] < n_trees)
    ).astype(jnp.float32)

    x_p = jnp.pad(x.astype(jnp.float32), ((0, r_pad), (0, 0)))
    miss_p = jnp.isnan(x_p).astype(jnp.float32)
    x_p = jnp.nan_to_num(x_p)

    kern = functools.partial(_kernel, max_depth=max_depth)
    a_full = arena + a_pad
    out = pl.pallas_call(
        kern,
        grid=(n_rblk, n_tblk),
        in_specs=[
            pl.BlockSpec((trees_blk, a_full), lambda rb, tb: (tb, 0)),
            pl.BlockSpec((trees_blk, a_full), lambda rb, tb: (tb, 0)),
            pl.BlockSpec((trees_blk, a_full), lambda rb, tb: (tb, 0)),
            pl.BlockSpec((trees_blk, a_full), lambda rb, tb: (tb, 0)),
            pl.BlockSpec((trees_blk, a_full), lambda rb, tb: (tb, 0)),
            pl.BlockSpec((trees_blk, n_classes), lambda rb, tb: (tb, 0)),
            pl.BlockSpec((rows_blk, n_feat), lambda rb, tb: (rb, 0)),
            pl.BlockSpec((rows_blk, n_feat), lambda rb, tb: (rb, 0)),
        ],
        out_specs=pl.BlockSpec((rows_blk, n_classes), lambda rb, tb: (rb, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n_rblk * rows_blk, n_classes), jnp.float32
        ),
        interpret=interpret,
    )(
        feature_p, threshold_p, default_p, is_leaf_p, leaf_val_p,
        class_oh, x_p, miss_p,
    )
    return out[:n_rows]
