"""Pallas TPU kernel: runtime bit-unpack of the compressed matrix (paper §2.2).

"Values are packed and unpacked at runtime using bitwise operations ... the
small number of bitwise operations computed on the GPU incur no visible
performance penalty." The TPU story is identical: the VPU shifts/masks a
(F_BLK, W_BLK) word tile in VMEM into a (F_BLK, W_BLK*spw) bin tile. Used
standalone for prediction-side unpacking; the histogram kernel fuses the
same unpack inline (never materialising bins in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(packed_ref, out_ref, *, bits: int):
    words = packed_ref[...]  # (F_BLK, W_BLK)
    spw = 32 // bits
    shifts = (jnp.arange(spw, dtype=jnp.uint32) * bits)[None, None, :]
    mask = jnp.uint32((1 << bits) - 1)
    bins = ((words[:, :, None] >> shifts) & mask)
    out_ref[...] = bins.reshape(words.shape[0], -1).astype(jnp.int32)


def decompress(
    packed: jax.Array,  # (F, W) uint32
    bits: int,
    n_rows: int,
    *,
    f_blk: int = 8,
    w_blk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns bins (n_rows, F) int32 (transposed to row-major like unpack)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    f, w = packed.shape
    spw = 32 // bits
    n_fblk, n_wblk = -(-f // f_blk), -(-w // w_blk)
    packed_p = jnp.pad(packed, ((0, n_fblk * f_blk - f), (0, n_wblk * w_blk - w)))

    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(n_fblk, n_wblk),
        in_specs=[pl.BlockSpec((f_blk, w_blk), lambda fb, wb: (fb, wb))],
        out_specs=pl.BlockSpec((f_blk, w_blk * spw), lambda fb, wb: (fb, wb)),
        out_shape=jax.ShapeDtypeStruct(
            (n_fblk * f_blk, n_wblk * w_blk * spw), jnp.int32
        ),
        interpret=interpret,
    )(packed_p)
    return out[:f, :n_rows].T
