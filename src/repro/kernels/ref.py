"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import histogram as H
from repro.core.compress import unpack as _unpack


def histogram_ref(
    packed: jax.Array,  # (F, W) uint32
    gh: jax.Array,  # (N, 2) float32
    positions: jax.Array,  # (N,) int32, n_nodes = inactive/dump
    n_nodes: int,
    max_bins: int,
    bits: int,
) -> jax.Array:
    """Oracle for kernels.histogram — both the MXU-matmul kernel
    (histogram_packed) and the privatised DMA-pipelined kernel
    (build_histograms_packed_kernel) target this contract: unpack then
    scatter-add. Kernels differ from it only by f32 summation order."""
    n = gh.shape[0]
    bins = _unpack(packed, bits, n)
    return H.build_histograms(bins, gh, positions, n_nodes, max_bins)


def quantile_cuts_ref(
    srt: jax.Array,  # (n, F) f32 column-sorted, +inf tail
    n_valid: jax.Array,  # (F,) finite count per column
    max_bins: int,
) -> jax.Array:
    """Oracle for kernels.quantile_cuts: the shared XLA selection stage.
    The kernel reproduces this arithmetic operation for operation; parity
    is to ~1 ulp of arithmetic (compiled XLA may contract mul+add into FMA
    where the kernel's evaluation does not; at exact integer rank
    boundaries that can select the neighbouring order statistic), pinned
    by tests/test_kernels_cuts.py."""
    from repro.core.quantile import select_cuts_from_sorted

    return select_cuts_from_sorted(srt, n_valid, max_bins)


def decompress_ref(packed: jax.Array, bits: int, n_rows: int) -> jax.Array:
    """Oracle for kernels.decompress (= core.compress.unpack)."""
    return _unpack(packed, bits, n_rows)


def ensemble_margins_ref(
    feature: jax.Array,  # (T, A) int32
    threshold: jax.Array,  # (T, A) f32
    default_left: jax.Array,  # (T, A) bool
    leaf_value: jax.Array,  # (T, A) f32
    is_leaf: jax.Array,  # (T, A) bool
    x: jax.Array,  # (N, F) f32, NaN = missing
    n_classes: int,
    max_depth: int,
) -> jax.Array:
    """Oracle for kernels.ensemble_traversal: the XLA fused traversal
    (= serve.traversal, itself bit-identical to core.predict's scan) minus
    base_score, which the kernel also leaves to its caller."""
    from repro.serve.traversal import traverse_ensemble_raw

    leaves = traverse_ensemble_raw(
        feature, threshold, default_left, leaf_value, is_leaf, x, max_depth
    )  # (T, N)
    n_trees, n_rows = leaves.shape
    n_rounds = n_trees // n_classes
    per_class = leaves.reshape(n_rounds, n_classes, n_rows).sum(axis=0)
    return per_class.T


def split_scan_ref(
    hist: jax.Array,  # (n_nodes, F, B, 2)
    parent_sum: jax.Array,  # (n_nodes, 2)
    reg_lambda: float,
    min_child_weight: float,
) -> jax.Array:
    """Oracle for kernels.split_scan: per-(node, feature) best split.

    Returns (n_nodes, F, 4): [gain, best_bin, default_left, hl_at_best].
    Mirrors core.split.evaluate_splits' per-feature inner computation
    (gamma is applied by the caller; it is a constant shift).
    """
    g, h = hist[..., 0], hist[..., 1]
    g_tot = parent_sum[:, None, 0:1]
    h_tot = parent_sum[:, None, 1:2]
    g_miss, h_miss = g[..., -1:], h[..., -1:]

    gl = jnp.cumsum(g[..., :-1], axis=-1)[..., :-1]
    hl = jnp.cumsum(h[..., :-1], axis=-1)[..., :-1]

    def gain_of(gl_, hl_):
        gr_, hr_ = g_tot - gl_, h_tot - hl_
        gain = (
            gl_**2 / (hl_ + reg_lambda)
            + gr_**2 / (hr_ + reg_lambda)
            - g_tot**2 / (h_tot + reg_lambda)
        ) * 0.5
        ok = (hl_ >= min_child_weight) & (hr_ >= min_child_weight)
        return jnp.where(ok, gain, -jnp.inf)

    gain_r = gain_of(gl, hl)
    gain_l = gain_of(gl + g_miss, hl + h_miss)
    dl = gain_l > gain_r
    gain = jnp.maximum(gain_l, gain_r)  # (n, F, B-2)

    best = jnp.argmax(gain, axis=-1)  # (n, F)
    bg = jnp.take_along_axis(gain, best[..., None], axis=-1)[..., 0]
    bdl = jnp.take_along_axis(dl, best[..., None], axis=-1)[..., 0]
    hl_best = jnp.take_along_axis(hl, best[..., None], axis=-1)[..., 0]
    hl_best = hl_best + jnp.where(bdl, h_miss[..., 0], 0.0)
    return jnp.stack(
        [bg, best.astype(jnp.float32), bdl.astype(jnp.float32), hl_best], axis=-1
    )
