"""Pallas TPU kernel: fused split-gain prefix scan (paper §2.3 EvaluateSplit).

The paper computes split gain "with a parallel prefix sum operation" over
the gradient histogram (Harris et al. scan). On TPU the scan itself is a
few microseconds of VPU work; the perf value of a kernel is *fusion* — one
pass over the VMEM-resident histogram computes the prefix sums, both
missing-direction gain variants, validity masking and the per-feature
argmax, writing back 4 floats per (node, feature) instead of materialising
(n, F, B) gain tensors in HBM (that is what the XLA path does).

Output per (node, feature): [best_gain, best_bin, default_left, hl_at_best].
The cross-feature argmax is a tiny follow-up reduction done by the caller.

Blocking: grid = (n_nodes, feature_blocks); block = full bin axis, so the
scan never crosses a block boundary. VMEM: (F_BLK=8, B=256, 2) f32 = 16 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hist_ref, parent_ref, out_ref, *, reg_lambda, min_child_weight):
    h = hist_ref[...]  # (1, F_BLK, B, 2)
    g, hh = h[0, ..., 0], h[0, ..., 1]  # (F_BLK, B)
    p = parent_ref[...]  # (1, 2)
    g_tot, h_tot = p[0, 0], p[0, 1]
    g_miss, h_miss = g[:, -1:], hh[:, -1:]  # (F_BLK, 1)

    gl = jnp.cumsum(g[:, :-1], axis=-1)[:, :-1]  # (F_BLK, B-2)
    hl = jnp.cumsum(hh[:, :-1], axis=-1)[:, :-1]
    parent_gain = g_tot * g_tot / (h_tot + reg_lambda)

    def gain_of(gl_, hl_):
        gr_, hr_ = g_tot - gl_, h_tot - hl_
        gain = 0.5 * (
            gl_ * gl_ / (hl_ + reg_lambda)
            + gr_ * gr_ / (hr_ + reg_lambda)
            - parent_gain
        )
        ok = (hl_ >= min_child_weight) & (hr_ >= min_child_weight)
        return jnp.where(ok, gain, -jnp.inf)

    gain_r = gain_of(gl, hl)
    gain_l = gain_of(gl + g_miss, hl + h_miss)
    dl = gain_l > gain_r
    gain = jnp.maximum(gain_l, gain_r)  # (F_BLK, B-2)

    best = jnp.argmax(gain, axis=-1)  # (F_BLK,)
    take = lambda a: jnp.take_along_axis(a, best[:, None], axis=-1)[:, 0]
    bg, bdl = take(gain), take(dl)
    hl_best = take(hl) + jnp.where(bdl, h_miss[:, 0], 0.0)
    out_ref[...] = jnp.stack(
        [bg, best.astype(jnp.float32), bdl.astype(jnp.float32), hl_best], axis=-1
    )[None]


def split_scan(
    hist: jax.Array,  # (n_nodes, F, B, 2) f32
    parent_sum: jax.Array,  # (n_nodes, 2) f32
    reg_lambda: float = 1.0,
    min_child_weight: float = 1.0,
    *,
    f_blk: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (n_nodes, F, 4): [gain, bin, default_left, hl] per feature."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n_nodes, f, b, _ = hist.shape
    n_fblk = -(-f // f_blk)
    f_pad = n_fblk * f_blk - f
    hist_p = jnp.pad(hist, ((0, 0), (0, f_pad), (0, 0), (0, 0)))

    kern = functools.partial(
        _kernel, reg_lambda=reg_lambda, min_child_weight=min_child_weight
    )
    out = pl.pallas_call(
        kern,
        grid=(n_nodes, n_fblk),
        in_specs=[
            pl.BlockSpec((1, f_blk, b, 2), lambda n, fb: (n, fb, 0, 0)),
            pl.BlockSpec((1, 2), lambda n, fb: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, f_blk, 4), lambda n, fb: (n, fb, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, n_fblk * f_blk, 4), jnp.float32),
        interpret=interpret,
    )(hist_p, parent_sum)
    return out[:, :f]
