"""Checkpointing: msgpack-serialised pytrees (params, optimizer state,
GBDT ensembles). No external deps beyond msgpack + numpy."""
from repro.checkpoint.io import load_pytree, save_pytree, save_ensemble, load_ensemble

__all__ = ["save_pytree", "load_pytree", "save_ensemble", "load_ensemble"]
