"""Checkpointing: msgpack-serialised pytrees (params, optimizer state,
GBDT ensembles, self-describing Booster checkpoints). No external deps
beyond msgpack + numpy."""
from repro.checkpoint.io import (
    load_booster,
    load_ensemble,
    load_pytree,
    save_booster,
    save_ensemble,
    save_pytree,
)

__all__ = [
    "save_pytree",
    "load_pytree",
    "save_ensemble",
    "load_ensemble",
    "save_booster",
    "load_booster",
]
