"""msgpack pytree checkpointing.

Arrays are stored as (dtype, shape, raw bytes); the pytree structure is
stored as nested msgpack maps/lists. Works for model params, AdamW state
and GBDT ensembles. Writes are atomic and durable (tmp file + fsync +
rename) so an interrupted save never corrupts the previous checkpoint, and
every file is framed with a magic string + payload crc32 so truncated or
bit-flipped checkpoints are rejected with a `CheckpointError` instead of
being decoded into garbage (DESIGN.md §13).
"""
from __future__ import annotations

import os
import struct
import tempfile
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_ARR = "__arr__"
_TUP = "__tuple__"

MAGIC = b"RPROCKPT"  # 8 bytes, followed by crc32(payload) as >I, then payload


class CheckpointError(ValueError):
    """A checkpoint file is missing, corrupt, truncated, or the wrong
    format/version. Subclasses ValueError so pre-existing callers that
    caught ValueError keep working."""


def _encode(obj):
    if isinstance(obj, (jax.Array, np.ndarray, np.generic)):
        a = np.asarray(obj)
        return {_ARR: True, "d": a.dtype.str, "s": list(a.shape), "b": a.tobytes()}
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUP: [_encode(v) for v in obj]}
    if isinstance(obj, list):
        return [_encode(v) for v in obj]
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _decode(obj):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            a = np.frombuffer(obj["b"], dtype=np.dtype(obj["d"]))
            return jnp.asarray(a.reshape(obj["s"]))
        if _TUP in obj:
            return tuple(_decode(v) for v in obj[_TUP])
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def save_pytree(path: str, tree) -> None:
    from repro.testing import faults

    faults.check("checkpoint_write")
    host = jax.tree.map(lambda x: np.asarray(x) if hasattr(x, "shape") else x, tree)
    payload = msgpack.packb(_encode(host), use_bin_type=True)
    framed = MAGIC + struct.pack(">I", zlib.crc32(payload) & 0xFFFFFFFF) + payload
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(framed)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_pytree(path: str):
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as exc:
        raise CheckpointError(f"cannot read checkpoint {path}: {exc}") from exc
    if raw.startswith(MAGIC):
        header_len = len(MAGIC) + 4
        if len(raw) < header_len:
            raise CheckpointError(
                f"checkpoint {path} is truncated inside its header "
                f"({len(raw)} bytes)"
            )
        (expected,) = struct.unpack(">I", raw[len(MAGIC):header_len])
        payload = raw[header_len:]
        got = zlib.crc32(payload) & 0xFFFFFFFF
        if got != expected:
            raise CheckpointError(
                f"checkpoint {path} failed its payload checksum "
                f"(crc32 {got:#010x}, header says {expected:#010x}) — the "
                "file is corrupt or truncated"
            )
    else:
        # Pre-frame checkpoints (written before the magic+crc header) are
        # raw msgpack; keep reading them.
        payload = raw
    try:
        return _decode(msgpack.unpackb(payload, raw=False, strict_map_key=False))
    except Exception as exc:
        raise CheckpointError(
            f"checkpoint {path} is not decodable msgpack: {exc}"
        ) from exc


def _ensemble_fields_with_gain(fields: dict) -> dict:
    """Backfill `gain` for checkpoints written before gains were stored in
    the arena (importances on such models report zeros — -inf marks every
    slot as "not a known split")."""
    if "gain" not in fields:
        fields = dict(fields)
        fields["gain"] = jnp.full(
            np.asarray(fields["leaf_value"]).shape, -jnp.inf, jnp.float32
        )
    return fields


def save_ensemble(path: str, ens) -> None:
    from repro.core.predict import _ENSEMBLE_ARRAY_FIELDS, Ensemble

    assert isinstance(ens, Ensemble)
    save_pytree(
        path,
        {
            "fields": {k: getattr(ens, k) for k in _ENSEMBLE_ARRAY_FIELDS},
            "n_classes": ens.n_classes,
            "base_score": ens.base_score,
        },
    )


def load_ensemble(path: str):
    from repro.core.predict import Ensemble

    d = load_pytree(path)
    return Ensemble(**_ensemble_fields_with_gain(d["fields"]),
                    n_classes=d["n_classes"], base_score=d["base_score"])


# --- self-describing Booster checkpoints -----------------------------------

BOOSTER_FORMAT = "repro.booster"
BOOSTER_VERSION = 2  # v2 adds the optional in-run "resume" section
_READABLE_VERSIONS = (1, 2)


def save_booster(path: str, bst, *, ensemble=None, n_rounds_trained=None,
                 history=None, resume: dict | None = None) -> None:
    """Versioned checkpoint of a fitted Booster: config + cut points + base
    score + trees + training record. Loading needs NO caller-supplied
    max_depth / objective / n_classes — the model describes itself.

    The keyword overrides exist for in-run snapshots taken mid-`fit`: the
    Booster's own attributes still describe the PREVIOUS completed fit, so
    the checkpointer passes the partial ensemble / round count / history
    explicitly, plus a `resume` dict (margins, ES state, RNG anchor) that
    `Booster.resume` replays to a bit-identical continuation.

    Objectives are stored BY REGISTRY NAME: a model trained with a custom
    objective round-trips iff that objective was added with
    `objectives.register_objective` (in the saving process here, and in
    the loading process at load time). A bare callable passed via
    `fit(obj=...)` without registration is rejected with a ValueError —
    there is nothing durable to write for an anonymous Python function.
    """
    import dataclasses

    from repro.core import objectives as O
    from repro.core.predict import _ENSEMBLE_ARRAY_FIELDS

    obj = bst.obj
    if O.OBJECTIVES.get(obj.name) is not obj:
        raise ValueError(
            f"objective {obj.name!r} is not in the objective registry; a "
            "bare callable passed via fit(obj=...) cannot be checkpointed "
            "by name. Register it first with "
            "objectives.register_objective(name, grad, ...) and pass the "
            "registered objective (or its name) to fit."
        )
    ens = ensemble if ensemble is not None else bst.ensemble
    payload = {
        "format": BOOSTER_FORMAT,
        "version": BOOSTER_VERSION,
        "config": dataclasses.asdict(bst.cfg),
        "cuts": bst.cuts,
        "base_score": float(bst.base_score),
        "best_iteration": bst.best_iteration,
        "best_score": bst.best_score,
        "n_rounds_trained": int(
            n_rounds_trained if n_rounds_trained is not None
            else bst.n_rounds_trained
        ),
        "history": history if history is not None else bst.history,
        "ensemble": {
            "fields": {k: getattr(ens, k) for k in _ENSEMBLE_ARRAY_FIELDS},
            "n_classes": ens.n_classes,
        },
    }
    if resume is not None:
        payload["resume"] = resume
    save_pytree(path, payload)


def _load_booster_payload(path: str):
    import dataclasses

    from repro.core.booster import Booster, BoosterConfig
    from repro.core.predict import Ensemble

    d = load_pytree(path)
    if d.get("format") != BOOSTER_FORMAT:
        raise CheckpointError(
            f"{path} is not a {BOOSTER_FORMAT} checkpoint "
            f"(format={d.get('format')!r})"
        )
    if d.get("version") not in _READABLE_VERSIONS:
        raise CheckpointError(
            f"unsupported {BOOSTER_FORMAT} checkpoint version "
            f"{d.get('version')!r} in {path} (this build reads "
            f"{_READABLE_VERSIONS})"
        )
    known = {f.name for f in dataclasses.fields(BoosterConfig)}
    cfg = BoosterConfig(
        **{k: v for k, v in d["config"].items() if k in known}
    )
    from repro.core import objectives as O

    if cfg.objective not in O.OBJECTIVES:
        raise CheckpointError(
            f"checkpoint {path} was trained with objective "
            f"{cfg.objective!r}, which is not in this process's objective "
            "registry. Custom objectives must be re-registered before "
            "loading: objectives.register_objective"
            f"({cfg.objective!r}, grad, ...)"
        )
    bst = Booster(cfg)
    bst.cuts = d["cuts"]
    bst.base_score = d["base_score"]
    bst.best_iteration = d["best_iteration"]
    bst.best_score = d["best_score"]
    bst.n_rounds_trained = d["n_rounds_trained"]
    bst.history = d["history"]
    bst.ensemble = Ensemble(
        **_ensemble_fields_with_gain(d["ensemble"]["fields"]),
        n_classes=d["ensemble"]["n_classes"],
        base_score=d["base_score"],
    )
    return bst, d.get("resume")


def load_booster(path: str):
    bst, _ = _load_booster_payload(path)
    return bst


def load_booster_with_resume(path: str):
    """Load a checkpoint together with its in-run resume section (None for
    checkpoints of completed fits)."""
    return _load_booster_payload(path)
