"""msgpack pytree checkpointing.

Arrays are stored as (dtype, shape, raw bytes); the pytree structure is
stored as nested msgpack maps/lists. Works for model params, AdamW state
and GBDT ensembles. Writes are atomic (tmp file + rename) so an interrupted
save never corrupts the previous checkpoint.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_ARR = "__arr__"
_TUP = "__tuple__"


def _encode(obj):
    if isinstance(obj, (jax.Array, np.ndarray, np.generic)):
        a = np.asarray(obj)
        return {_ARR: True, "d": a.dtype.str, "s": list(a.shape), "b": a.tobytes()}
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return {_TUP: [_encode(v) for v in obj]}
    if isinstance(obj, list):
        return [_encode(v) for v in obj]
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _decode(obj):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            a = np.frombuffer(obj["b"], dtype=np.dtype(obj["d"]))
            return jnp.asarray(a.reshape(obj["s"]))
        if _TUP in obj:
            return tuple(_decode(v) for v in obj[_TUP])
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def save_pytree(path: str, tree) -> None:
    host = jax.tree.map(lambda x: np.asarray(x) if hasattr(x, "shape") else x, tree)
    payload = msgpack.packb(_encode(host), use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_pytree(path: str):
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False, strict_map_key=False))


def _ensemble_fields_with_gain(fields: dict) -> dict:
    """Backfill `gain` for checkpoints written before gains were stored in
    the arena (importances on such models report zeros — -inf marks every
    slot as "not a known split")."""
    if "gain" not in fields:
        fields = dict(fields)
        fields["gain"] = jnp.full(
            np.asarray(fields["leaf_value"]).shape, -jnp.inf, jnp.float32
        )
    return fields


def save_ensemble(path: str, ens) -> None:
    from repro.core.predict import _ENSEMBLE_ARRAY_FIELDS, Ensemble

    assert isinstance(ens, Ensemble)
    save_pytree(
        path,
        {
            "fields": {k: getattr(ens, k) for k in _ENSEMBLE_ARRAY_FIELDS},
            "n_classes": ens.n_classes,
            "base_score": ens.base_score,
        },
    )


def load_ensemble(path: str):
    from repro.core.predict import Ensemble

    d = load_pytree(path)
    return Ensemble(**_ensemble_fields_with_gain(d["fields"]),
                    n_classes=d["n_classes"], base_score=d["base_score"])


# --- self-describing Booster checkpoints -----------------------------------

BOOSTER_FORMAT = "repro.booster"
BOOSTER_VERSION = 1


def save_booster(path: str, bst) -> None:
    """Versioned checkpoint of a fitted Booster: config + cut points + base
    score + trees + training record. Loading needs NO caller-supplied
    max_depth / objective / n_classes — the model describes itself.

    Objectives are stored BY REGISTRY NAME: a model trained with a custom
    objective round-trips iff that objective was added with
    `objectives.register_objective` (in the saving process here, and in
    the loading process at load time). A bare callable passed via
    `fit(obj=...)` without registration is rejected with a ValueError —
    there is nothing durable to write for an anonymous Python function.
    """
    import dataclasses

    from repro.core import objectives as O
    from repro.core.predict import _ENSEMBLE_ARRAY_FIELDS

    obj = bst.obj
    if O.OBJECTIVES.get(obj.name) is not obj:
        raise ValueError(
            f"objective {obj.name!r} is not in the objective registry; a "
            "bare callable passed via fit(obj=...) cannot be checkpointed "
            "by name. Register it first with "
            "objectives.register_objective(name, grad, ...) and pass the "
            "registered objective (or its name) to fit."
        )
    payload = {
        "format": BOOSTER_FORMAT,
        "version": BOOSTER_VERSION,
        "config": dataclasses.asdict(bst.cfg),
        "cuts": bst.cuts,
        "base_score": float(bst.base_score),
        "best_iteration": bst.best_iteration,
        "best_score": bst.best_score,
        "n_rounds_trained": int(bst.n_rounds_trained),
        "history": bst.history,
        "ensemble": {
            "fields": {k: getattr(bst.ensemble, k)
                       for k in _ENSEMBLE_ARRAY_FIELDS},
            "n_classes": bst.ensemble.n_classes,
        },
    }
    save_pytree(path, payload)


def load_booster(path: str):
    import dataclasses

    from repro.core.booster import Booster, BoosterConfig
    from repro.core.predict import Ensemble

    d = load_pytree(path)
    if d.get("format") != BOOSTER_FORMAT:
        raise ValueError(
            f"{path} is not a {BOOSTER_FORMAT} checkpoint "
            f"(format={d.get('format')!r})"
        )
    if d.get("version") != BOOSTER_VERSION:
        raise ValueError(
            f"unsupported {BOOSTER_FORMAT} checkpoint version "
            f"{d.get('version')!r} (this build reads {BOOSTER_VERSION})"
        )
    known = {f.name for f in dataclasses.fields(BoosterConfig)}
    cfg = BoosterConfig(
        **{k: v for k, v in d["config"].items() if k in known}
    )
    from repro.core import objectives as O

    if cfg.objective not in O.OBJECTIVES:
        raise ValueError(
            f"checkpoint {path} was trained with objective "
            f"{cfg.objective!r}, which is not in this process's objective "
            "registry. Custom objectives must be re-registered before "
            "loading: objectives.register_objective"
            f"({cfg.objective!r}, grad, ...)"
        )
    bst = Booster(cfg)
    bst.cuts = d["cuts"]
    bst.base_score = d["base_score"]
    bst.best_iteration = d["best_iteration"]
    bst.best_score = d["best_score"]
    bst.n_rounds_trained = d["n_rounds_trained"]
    bst.history = d["history"]
    bst.ensemble = Ensemble(
        **_ensemble_fields_with_gain(d["ensemble"]["fields"]),
        n_classes=d["ensemble"]["n_classes"],
        base_score=d["base_score"],
    )
    return bst
