"""Encoder-decoder backbone (seamless-m4t style, arXiv:2308.11596).

The audio frontend (mel-spectrogram + conv feature extractor) is a STUB per
the brief: input_specs() provides precomputed frame embeddings (B, S_src, D)
that feed the encoder directly (with a learned input projection). The text
decoder is a causal transformer with cross-attention to the encoder output.

Encoder: bidirectional self-attention (no causal mask, no RoPE offset
games — standard rope over source positions). Decoder: causal self-attn
(KV cache for decode) + cross-attn (encoder output is static during decode,
so only self-attn is cached and the cross-attn K/V are precomputed once).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import BF16, _sdpa, dot, dot_f32, dot_tp_out, rmsnorm
from repro.models import transformer as TF


def _cross_attn(x, enc_kv, p, *, n_heads, n_kv_heads, head_dim):
    """Cross attention: queries from decoder x, keys/values precomputed from
    the encoder output (no mask, no rope)."""
    b, s, _ = x.shape
    q = dot(x, p["wq"]).reshape(b, s, n_heads, head_dim)
    out = _sdpa(q, enc_kv["k"], enc_kv["v"], jnp.ones((), bool))
    return dot_tp_out(out.reshape(b, s, n_heads * head_dim), p["wo"])


def cross_kv(enc_out, p, *, n_kv_heads, head_dim):
    b, t, _ = enc_out.shape
    k = dot(enc_out, p["wk"]).reshape(b, t, n_kv_heads, head_dim)
    v = dot(enc_out, p["wv"]).reshape(b, t, n_kv_heads, head_dim)
    return {"k": k, "v": v}


def init_dec_layer(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    base = TF.init_layer_params(k1, cfg)
    base["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
    base["cross"] = TF.init_attn_params(k2, cfg)
    return base


def init_params(cfg: ArchConfig, key):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "src_proj": TF._glorot(ks[2], (cfg.d_model, cfg.d_model)),
        "enc_layers": jax.vmap(lambda k: TF.init_layer_params(k, cfg))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "embed": TF._glorot(ks[3], (cfg.padded_vocab, cfg.d_model)),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": TF._glorot(ks[4], (cfg.d_model, cfg.padded_vocab)),
    }


def param_specs(cfg: ArchConfig, m: str = "model"):
    dec = TF.layer_param_specs(cfg, m, stacked=True)
    dec["ln_x"] = P(None, None)
    dec["cross"] = jax.tree.map(
        lambda s: P(None, *s), TF.attn_param_specs(cfg, m),
        is_leaf=lambda x: isinstance(x, P),
    )
    return {
        "src_proj": P(None, None),
        "enc_layers": TF.layer_param_specs(cfg, m, stacked=True),
        "enc_norm": P(None),
        "embed": P(m, None),
        "dec_layers": dec,
        "final_norm": P(None),
        "lm_head": P(None, m),
    }


def encode(params, src_embeds, cfg: ArchConfig, rules: TF.ShardingRules):
    x = dot(src_embeds.astype(BF16), params["src_proj"])
    positions = jnp.arange(x.shape[1])[None, :]
    x = TF._constrain(x, rules.act(), rules)

    def body(carry, lp):
        h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
        from repro.models.layers import attention_gqa

        attn_out, _ = attention_gqa(
            h, lp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            positions=positions, causal=False,
        )
        y = carry + attn_out
        h = rmsnorm(y, lp["ln2"], cfg.norm_eps)
        ffn = dot_tp_out(
            jax.nn.silu(dot(h, lp["ffn"]["w_gate"])) * dot(h, lp["ffn"]["w_up"]),
            lp["ffn"]["w_down"],
        )
        y = TF._constrain(y + ffn, rules.act(), rules)
        return y, None

    if cfg.remat:
        policy = (None if cfg.remat_policy == "full"
                  else getattr(jax.checkpoint_policies, cfg.remat_policy))
        body = jax.checkpoint(body, policy=policy)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(x, lp, enc_kv, cfg, positions, rules, cache=None, cache_index=None):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    from repro.models.layers import attention_gqa

    attn_out, new_cache = attention_gqa(
        h, lp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        positions=positions, cache=cache, cache_index=cache_index,
    )
    x = x + attn_out
    h = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
    x = x + _cross_attn(
        h, enc_kv, lp["cross"], n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
    )
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    ffn = dot_tp_out(
        jax.nn.silu(dot(h, lp["ffn"]["w_gate"])) * dot(h, lp["ffn"]["w_up"]),
        lp["ffn"]["w_down"],
    )
    x = TF._constrain(x + ffn, rules.act(), rules)
    return x, new_cache


def forward(params, batch, cfg: ArchConfig, rules: TF.ShardingRules):
    """Training/prefill forward. batch: src_embeds (B,Ss,D), tokens (B,St)."""
    enc_out = encode(params, batch["src_embeds"], cfg, rules)
    x = params["embed"][batch["tokens"]].astype(BF16)
    positions = jnp.arange(x.shape[1])[None, :]
    x = TF._constrain(x, rules.act(), rules)

    def body(carry, lp):
        ekv = cross_kv(enc_out, lp["cross"], n_kv_heads=cfg.n_kv_heads,
                       head_dim=cfg.resolved_head_dim)
        y, _ = _dec_layer(carry, lp, ekv, cfg, positions, rules)
        return y, None

    if cfg.remat:
        policy = (None if cfg.remat_policy == "full"
                  else getattr(jax.checkpoint_policies, cfg.remat_policy))
        body = jax.checkpoint(body, policy=policy)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return dot_f32(x, params["lm_head"]), {}


def init_cache(cfg: ArchConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    k, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, capacity, k, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, capacity, k, hd), dtype),
    }


def cache_specs(cfg: ArchConfig, rules: TF.ShardingRules):
    return {
        "k": P(None, rules.batch, rules.seq, None, None),
        "v": P(None, rules.batch, rules.seq, None, None),
    }


def decode_step(params, token, cache, cache_index, enc_out,
                cfg: ArchConfig, rules: TF.ShardingRules):
    """One decode step; enc_out (B, Ss, D) precomputed by encode()."""
    x = params["embed"][token].astype(BF16)
    positions = jnp.full((1, 1), cache_index, jnp.int32)

    def body(carry, inp):
        lp, lc = inp
        ekv = cross_kv(enc_out, lp["cross"], n_kv_heads=cfg.n_kv_heads,
                       head_dim=cfg.resolved_head_dim)
        y, nc = _dec_layer(carry, lp, ekv, cfg, positions, rules,
                           cache=lc, cache_index=cache_index)
        return y, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return dot_f32(x, params["lm_head"]), new_cache
