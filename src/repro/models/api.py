"""Unified model interface over the five backbone families.

build_model(cfg) returns a Model whose functions all take/return plain
pytrees so the launcher can jit them with explicit in/out shardings:

  init_params(key)                -> params
  param_specs()                   -> PartitionSpec pytree (mirrors params)
  loss_fn(params, batch, rules)   -> scalar (train step objective)
  forward_logits(params, batch, rules) -> logits (prefill / eval)
  init_cache(batch, capacity)     -> decode cache pytree
  cache_specs(rules)              -> PartitionSpec pytree for the cache
  decode_fn(params, batch, cache, index, rules) -> (logits, new_cache)

batch keys by family: tokens/targets (all), prefix_embeds (vlm),
src_embeds (audio/encdec).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import ssm_model as SM
from repro.models import transformer as TF
from repro.models.transformer import NO_SHARDING, ShardingRules  # noqa: F401 (re-export)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init_params: Callable
    param_specs: Callable
    loss_fn: Callable
    forward_logits: Callable
    init_cache: Callable
    cache_specs: Callable
    decode_fn: Callable
    supports_decode: bool = True


def _tf_model(cfg: ArchConfig) -> Model:
    def loss(params, batch, rules):
        return TF.loss_fn(params, batch, cfg, rules)

    def fwd(params, batch, rules):
        logits, _ = TF.forward(
            params, batch["tokens"], cfg, rules,
            prefix_embeds=batch.get("prefix_embeds"),
        )
        return logits

    def dec(params, batch, cache, index, rules):
        return TF.decode_step(params, batch["tokens"], cache, index, cfg, rules)

    return Model(
        cfg=cfg,
        init_params=lambda key: TF.init_params(cfg, key),
        param_specs=lambda m="model": TF.param_specs(cfg, m),
        loss_fn=loss,
        forward_logits=fwd,
        init_cache=lambda b, cap, dtype=jnp.bfloat16: TF.init_cache(cfg, b, cap, dtype),
        cache_specs=lambda rules: TF.cache_specs(cfg, rules),
        decode_fn=dec,
    )


def _ssm_model(cfg: ArchConfig) -> Model:
    def loss(params, batch, rules):
        logits, _ = SM.forward(params, batch["tokens"], cfg, rules)
        return TF.xent_loss(logits, batch["targets"])

    def fwd(params, batch, rules):
        return SM.forward(params, batch["tokens"], cfg, rules)[0]

    def dec(params, batch, cache, index, rules):
        return SM.decode_step(params, batch["tokens"], cache, index, cfg, rules)

    return Model(
        cfg=cfg,
        init_params=lambda key: SM.init_params(cfg, key),
        param_specs=lambda m="model": SM.param_specs(cfg, m),
        loss_fn=loss,
        forward_logits=fwd,
        init_cache=lambda b, cap=0, dtype=jnp.bfloat16: SM.init_cache(cfg, b, cap, dtype),
        cache_specs=lambda rules: SM.cache_specs(cfg, rules),
        decode_fn=dec,
    )


def _hybrid_model(cfg: ArchConfig) -> Model:
    def loss(params, batch, rules):
        logits, _ = HY.forward(params, batch["tokens"], cfg, rules)
        return TF.xent_loss(logits, batch["targets"])

    def fwd(params, batch, rules):
        return HY.forward(params, batch["tokens"], cfg, rules)[0]

    def dec(params, batch, cache, index, rules):
        return HY.decode_step(params, batch["tokens"], cache, index, cfg, rules)

    return Model(
        cfg=cfg,
        init_params=lambda key: HY.init_params(cfg, key),
        param_specs=lambda m="model": HY.param_specs(cfg, m),
        loss_fn=loss,
        forward_logits=fwd,
        init_cache=lambda b, cap, dtype=jnp.bfloat16: HY.init_cache(cfg, b, cap, dtype),
        cache_specs=lambda rules: HY.cache_specs(cfg, rules),
        decode_fn=dec,
    )


def _encdec_model(cfg: ArchConfig) -> Model:
    def loss(params, batch, rules):
        logits, _ = ED.forward(params, batch, cfg, rules)
        return TF.xent_loss(logits, batch["targets"])

    def fwd(params, batch, rules):
        return ED.forward(params, batch, cfg, rules)[0]

    def dec(params, batch, cache, index, rules):
        # Serving precomputes the encoder output once per request
        # (batch["enc_out"]); falls back to encoding src_embeds inline.
        enc_out = batch.get("enc_out")
        if enc_out is None:
            enc_out = ED.encode(params, batch["src_embeds"], cfg, rules)
        return ED.decode_step(
            params, batch["tokens"], cache, index, enc_out, cfg, rules
        )

    return Model(
        cfg=cfg,
        init_params=lambda key: ED.init_params(cfg, key),
        param_specs=lambda m="model": ED.param_specs(cfg, m),
        loss_fn=loss,
        forward_logits=fwd,
        init_cache=lambda b, cap, dtype=jnp.bfloat16: ED.init_cache(cfg, b, cap, dtype),
        cache_specs=lambda rules: ED.cache_specs(cfg, rules),
        decode_fn=dec,
    )


def build_model(cfg: ArchConfig) -> Model:
    if cfg.arch_type in ("dense", "moe", "vlm"):
        return _tf_model(cfg)
    if cfg.arch_type == "ssm":
        return _ssm_model(cfg)
    if cfg.arch_type == "hybrid":
        return _hybrid_model(cfg)
    if cfg.arch_type in ("encdec", "audio"):
        return _encdec_model(cfg)
    raise ValueError(f"unknown arch_type {cfg.arch_type}")
