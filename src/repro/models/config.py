"""Architecture config schema for the assigned-architecture substrate.

One frozen dataclass drives parameter init, forward functions, sharding
specs and the dry-run input specs. Exact assigned configs live in
repro/configs/<id>.py; reduced variants for smoke tests come from
ArchConfig.reduced().
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    attention: str = "gqa"  # gqa | mla | none (ssm)
    sliding_window: int = 0  # 0 = full attention; >0 = SWA (sub-quadratic)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # --- hybrid (zamba2): shared attention block every N mamba layers ---
    attn_every: int = 0
    # --- MLA (minicpm3) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 32
    nope_head_dim: int = 64
    v_head_dim: int = 0  # 0 -> nope_head_dim
    # --- encoder-decoder (seamless) ---
    n_enc_layers: int = 0
    # --- multimodal stubs: frontend provides this many embedding tokens ---
    n_prefix_tokens: int = 0
    # --- numerics ---
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    remat: bool = True
    # "full" = save only layer boundaries (recompute everything incl. dots);
    # "dots_saveable" = keep matmul outputs, recompute elementwise only
    # (§Perf iteration: trades HBM for ~25% fewer backward FLOPs and fewer
    # recomputed TP collectives). Default = the optimized setting; the
    # paper-faithful-style "full" baseline is archived in
    # experiments/dryrun_baseline/ (EXPERIMENTS.md §Perf).
    remat_policy: str = "dots_saveable"
    # KV-cache storage dtype for GQA decode: "bfloat16" (default) or "int8"
    # (per-token-per-head absmax quantisation — the paper's §2.2 compression
    # insight applied to the serving-side memory bottleneck; §Perf bonus).
    kv_cache_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    source: str = ""  # paper / model card citation

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab-sharded embed
        and lm_head divide evenly across the model axis (and stay 128-lane
        aligned). Standard practice (megatron's make_vocab_size_divisible);
        targets never index the padding."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.nope_head_dim

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, n_layers: int = 2, d_model: int = 256) -> "ArchConfig":
        """Reduced same-family variant for CPU smoke tests (brief: <=2
        layers, d_model<=512, <=4 experts)."""
        scale = d_model / self.d_model
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(heads, self.n_kv_heads))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=max(64, int(self.d_ff * scale) // 64 * 64) if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            kv_lora_rank=64 if self.kv_lora_rank else 0,
            q_lora_rank=96 if self.q_lora_rank else 0,
            rope_head_dim=16 if self.kv_lora_rank else self.rope_head_dim,
            nope_head_dim=32 if self.kv_lora_rank else self.nope_head_dim,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            remat=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
