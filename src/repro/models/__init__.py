"""Assigned-architecture substrate: dense/MoE/SSM/hybrid/enc-dec backbones
with scan-over-layers, GSPMD sharding specs, train + prefill + decode paths."""
from repro.models.api import Model, build_model, NO_SHARDING, ShardingRules
from repro.models.config import ArchConfig, ShapeConfig, SHAPES

__all__ = ["Model", "build_model", "NO_SHARDING", "ShardingRules",
           "ArchConfig", "ShapeConfig", "SHAPES"]
