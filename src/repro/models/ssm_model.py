"""Pure-SSM LM (mamba2-2.7b): a stack of Mamba2 blocks, attention-free.

Decode carries O(1) state per layer — this is the arch family for which
long_500k is natural (no KV cache at all; DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import BF16, dot_f32, rmsnorm
from repro.models import ssm as SSM
from repro.models import transformer as TF


def init_params(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: SSM.init_mamba2_params(k, cfg))(layer_keys)
    return {
        "embed": TF._glorot(ks[1], (cfg.padded_vocab, cfg.d_model)),
        "layers": layers,
        "layer_norms": jnp.ones((cfg.n_layers, cfg.d_model), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": TF._glorot(ks[2], (cfg.d_model, cfg.padded_vocab)),
    }


def param_specs(cfg: ArchConfig, m: str = "model"):
    mspec = SSM.mamba2_param_specs(m)
    return {
        "embed": P(m, None),
        "layers": jax.tree.map(lambda s: P(None, *s), mspec,
                               is_leaf=lambda x: isinstance(x, P)),
        "layer_norms": P(None, None),
        "final_norm": P(None),
        "lm_head": P(None, m),
    }


def forward(params, tokens, cfg: ArchConfig, rules: TF.ShardingRules):
    x = params["embed"][tokens].astype(BF16)
    x = TF._constrain(x, rules.act(), rules)

    def body(carry, inp):
        lp, nw = inp
        h = rmsnorm(carry, nw, cfg.norm_eps)
        out, _ = SSM.mamba2_block(h, lp, cfg)
        y = TF._constrain(carry + out, rules.act(), rules)
        return y, None

    if cfg.remat:
        policy = (None if cfg.remat_policy == "full"
                  else getattr(jax.checkpoint_policies, cfg.remat_policy))
        body = jax.checkpoint(body, policy=policy)
    x, _ = jax.lax.scan(body, x, (params["layers"], params["layer_norms"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return dot_f32(x, params["lm_head"]), {}


def init_cache(cfg: ArchConfig, batch: int, capacity: int = 0, dtype=jnp.bfloat16):
    l, k, n = cfg.n_layers, cfg.ssm_conv, cfg.ssm_state
    return {
        "conv": {
            "x": jnp.zeros((l, batch, k - 1, cfg.d_inner), jnp.float32),
            "b": jnp.zeros((l, batch, k - 1, n), jnp.float32),
            "c": jnp.zeros((l, batch, k - 1, n), jnp.float32),
        },
        "state": jnp.zeros(
            (l, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }


def cache_specs(cfg: ArchConfig, rules: TF.ShardingRules, m: str = "model"):
    return {
        "conv": {
            "x": P(None, rules.batch, None, m),
            "b": P(None, rules.batch, None, None),
            "c": P(None, rules.batch, None, None),
        },
        "state": P(None, rules.batch, m, None, None),
    }


def decode_step(params, token, cache, cache_index, cfg: ArchConfig,
                rules: TF.ShardingRules):
    x = params["embed"][token].astype(BF16)

    def body(carry, inp):
        lp, nw, lc = inp
        h = rmsnorm(carry, nw, cfg.norm_eps)
        out, nc = SSM.mamba2_block(h, lp, cfg, cache=lc)
        return carry + out, nc

    x, new_cache = jax.lax.scan(
        body, x, (params["layers"], params["layer_norms"], cache)
    )
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return dot_f32(x, params["lm_head"]), new_cache
