"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every `attn_every` layers (arXiv:2411.15242).

The shared block's weights are a single copy (zamba2's parameter-efficiency
trick); each *application* keeps its own KV cache. Layer params are stacked
(G, A, ...) — G groups of A mamba layers — so the forward is an outer scan
over groups (inner scan over mamba layers + one shared-attn call), keeping
the HLO at one mamba body + one attention body total.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import BF16, dot_f32, rmsnorm
from repro.models import ssm as SSM
from repro.models import transformer as TF


def _group_shape(cfg: ArchConfig) -> tuple[int, int, int]:
    a = cfg.attn_every
    g = cfg.n_layers // a
    rest = cfg.n_layers - g * a
    return g, a, rest


def init_params(cfg: ArchConfig, key):
    g, a, rest = _group_shape(cfg)
    ks = jax.random.split(key, 6)
    group_keys = jax.random.split(ks[0], g * a).reshape(g, a, 2)
    groups = jax.vmap(
        jax.vmap(lambda k: SSM.init_mamba2_params(k, cfg))
    )(group_keys)
    mamba_norms = {
        "groups": jnp.ones((g, a, cfg.d_model), jnp.float32),
        "rest": jnp.ones((rest, cfg.d_model), jnp.float32),
    }
    rest_keys = jax.random.split(ks[1], max(rest, 1))[:rest].reshape(rest, 2)
    rest_p = jax.vmap(lambda k: SSM.init_mamba2_params(k, cfg))(rest_keys) if rest else None
    params = {
        "embed": TF._glorot(ks[2], (cfg.padded_vocab, cfg.d_model)),
        "mamba_groups": groups,
        "mamba_norms": mamba_norms,
        "shared_attn": TF.init_layer_params(ks[3], cfg),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": TF._glorot(ks[4], (cfg.d_model, cfg.padded_vocab)),
    }
    if rest:
        params["mamba_rest"] = rest_p
    return params


def param_specs(cfg: ArchConfig, m: str = "model"):
    g, a, rest = _group_shape(cfg)
    mspec = SSM.mamba2_param_specs(m)
    grp = jax.tree.map(lambda s: P(None, None, *s), mspec,
                       is_leaf=lambda x: isinstance(x, P))
    specs = {
        "embed": P(m, None),
        "mamba_groups": grp,
        "mamba_norms": {"groups": P(None, None, None), "rest": P(None, None)},
        "shared_attn": TF.layer_param_specs(cfg, m, stacked=False),
        "final_norm": P(None),
        "lm_head": P(None, m),
    }
    if rest:
        specs["mamba_rest"] = jax.tree.map(
            lambda s: P(None, *s), mspec, is_leaf=lambda x: isinstance(x, P)
        )
    return specs


def _mamba_layer(x, lp, norm_w, cfg, rules, cache=None):
    h = rmsnorm(x, norm_w, cfg.norm_eps)
    out, new_cache = SSM.mamba2_block(h, lp, cfg, cache=cache)
    x = x + out
    return TF._constrain(x, rules.act(), rules), new_cache


def forward(params, tokens, cfg: ArchConfig, rules: TF.ShardingRules,
            prefix_embeds=None, window: int | None = None):
    g, a, rest = _group_shape(cfg)
    w = cfg.sliding_window if window is None else window
    x = params["embed"][tokens].astype(BF16)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    x = TF._constrain(x, rules.act(), rules)
    shared = params["shared_attn"]

    def mamba_body(carry, inp):
        lp, nw = inp
        y, _ = _mamba_layer(carry, lp, nw, cfg, rules)
        return y, None

    if cfg.remat:
        policy = (None if cfg.remat_policy == "full"
                  else getattr(jax.checkpoint_policies, cfg.remat_policy))
        mamba_body = jax.checkpoint(mamba_body, policy=policy)

    def group_body(carry, inp):
        gp, gn = inp  # one group's stacked mamba params + norms
        y, _ = jax.lax.scan(mamba_body, carry, (gp, gn))
        y, _ = TF._layer_fwd(y, shared, cfg, positions, rules, w)
        return y, None

    x, _ = jax.lax.scan(
        group_body, x, (params["mamba_groups"], params["mamba_norms"]["groups"])
    )
    if rest:
        x, _ = jax.lax.scan(
            mamba_body, x, (params["mamba_rest"], params["mamba_norms"]["rest"])
        )

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dot_f32(x, params["lm_head"])
    return logits, {}


def init_cache(cfg: ArchConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    g, a, rest = _group_shape(cfg)
    kk, n = cfg.ssm_conv, cfg.ssm_state
    mcache = lambda *lead: {
        "conv": {
            "x": jnp.zeros((*lead, batch, kk - 1, cfg.d_inner), jnp.float32),
            "b": jnp.zeros((*lead, batch, kk - 1, n), jnp.float32),
            "c": jnp.zeros((*lead, batch, kk - 1, n), jnp.float32),
        },
        "state": jnp.zeros(
            (*lead, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    }
    k, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache = {
        "mamba_groups": mcache(g, a),
        "attn": {
            "k": jnp.zeros((g, batch, capacity, k, hd), dtype),
            "v": jnp.zeros((g, batch, capacity, k, hd), dtype),
        },
    }
    if rest:
        cache["mamba_rest"] = mcache(rest)
    return cache


def cache_specs(cfg: ArchConfig, rules: TF.ShardingRules, m: str = "model"):
    g, a, rest = _group_shape(cfg)
    mspec = lambda n_lead: {
        "conv": {
            "x": P(*([None] * n_lead), rules.batch, None, m),
            "b": P(*([None] * n_lead), rules.batch, None, None),
            "c": P(*([None] * n_lead), rules.batch, None, None),
        },
        "state": P(*([None] * n_lead), rules.batch, m, None, None),
    }
    specs = {
        "mamba_groups": mspec(2),
        "attn": {
            "k": P(None, rules.batch, rules.seq, None, None),
            "v": P(None, rules.batch, rules.seq, None, None),
        },
    }
    if rest:
        specs["mamba_rest"] = mspec(1)
    return specs


def decode_step(params, token, cache, cache_index, cfg: ArchConfig,
                rules: TF.ShardingRules, window: int | None = None):
    g, a, rest = _group_shape(cfg)
    w = cfg.sliding_window if window is None else window
    x = params["embed"][token].astype(BF16)
    positions = jnp.full((1, 1), cache_index, jnp.int32)
    shared = params["shared_attn"]

    def mamba_body(carry, inp):
        lp, nw, lc = inp
        y, nc = _mamba_layer(carry, lp, nw, cfg, rules, cache=lc)
        return y, nc

    def group_body(carry, inp):
        gp, gn, gc, ac = inp
        y, new_mc = jax.lax.scan(mamba_body, carry, (gp, gn, gc))
        y, (new_ac, _) = TF._layer_fwd(
            y, shared, cfg, positions, rules, w, cache=ac, cache_index=cache_index
        )
        return y, (new_mc, new_ac)

    x, (new_groups, new_attn) = jax.lax.scan(
        group_body,
        x,
        (
            params["mamba_groups"],
            params["mamba_norms"]["groups"],
            cache["mamba_groups"],
            cache["attn"],
        ),
    )
    new_cache = {"mamba_groups": new_groups, "attn": new_attn}
    if rest:
        x, new_rest = jax.lax.scan(
            mamba_body, x,
            (params["mamba_rest"], params["mamba_norms"]["rest"], cache["mamba_rest"]),
        )
        new_cache["mamba_rest"] = new_rest

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dot_f32(x, params["lm_head"])
    return logits, new_cache
