"""Mixture-of-Experts FFN (llama4-style top-k routing, expert-parallel).

Experts are sharded over the `model` mesh axis (expert parallelism): the
stacked expert weights (E, D, F) carry PartitionSpec ("model", None, None).

Dispatch is scatter/gather based (sort-free): each routed token computes its
position in its expert's capacity-bounded queue via a prefix sum over the
one-hot routing matrix, then a scatter-add places it in the (E, C, D)
expert buffers and a gather brings expert outputs back. This avoids the
(N, E, C) one-hot dispatch tensor of the classic einsum formulation, which
at llama4-maverick scale (E=128) would be gigabytes per device. Under GSPMD
the buffer exchange lowers to the expert all-to-all tracked in §Perf.

Aux losses: switch-style load-balance loss + router z-loss (llama4 maverick
routes top-1, switch-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import BF16, dot


def moe_ffn(x, p, *, n_experts: int, top_k: int, capacity_factor: float,
            rules=None):
    """x (B, S, D) -> (out (B, S, D), aux dict).

    p: router (D, E), w_gate/w_up (E, D, F), w_down (E, F, D).

    `rules` (ShardingRules): when given, the expert buffers are constrained
    to P(model, batch, None) — experts over `model`, capacity over the data
    axes. Without the capacity constraint GSPMD replicates every expert's
    FULL global-capacity matmul on all 16 data shards (measured 11x useful
    FLOPs on llama4-maverick train_4k; EXPERIMENTS.md §Perf MoE iteration).
    """
    b, s, d = x.shape
    n_tok = b * s
    xt = x.reshape(n_tok, d)

    logits = dot(xt, p["router"])  # (N, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    capacity = max(int(capacity_factor * n_tok * top_k / n_experts), 4)

    # Queue position of each routing slot within its expert (prefix sum over
    # the (N*k, E) one-hot routing matrix — the scan the paper would call a
    # parallel prefix sum).
    flat_idx = gate_idx.reshape(n_tok * top_k)
    onehot = jax.nn.one_hot(flat_idx, n_experts, dtype=jnp.float32)
    pos = (jnp.cumsum(onehot, axis=0) - 1.0)[
        jnp.arange(n_tok * top_k), flat_idx
    ].astype(jnp.int32)  # (N*k,)
    keep = pos < capacity

    # Scatter tokens into expert buffers (dump row for overflow).
    slot = jnp.where(keep, flat_idx * capacity + pos, n_experts * capacity)
    xrep = jnp.repeat(xt, top_k, axis=0)  # (N*k, D)
    buf = jnp.zeros((n_experts * capacity + 1, d), jnp.float32)
    buf = buf.at[slot].add(xrep, mode="drop")
    ebuf = buf[:-1].reshape(n_experts, capacity, d)
    if rules is not None and rules.enabled:
        from jax.sharding import PartitionSpec as P

        ebuf = jax.lax.with_sharding_constraint(
            ebuf, P(rules.model, rules.batch, None))

    # Per-expert SwiGLU, batched over the (model-sharded) expert axis.
    g = jnp.einsum("ecd,edf->ecf", ebuf.astype(BF16), p["w_gate"].astype(BF16),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", ebuf.astype(BF16), p["w_up"].astype(BF16),
                   preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u
    eout = jnp.einsum("ecf,efd->ecd", h.astype(BF16), p["w_down"].astype(BF16),
                      preferred_element_type=jnp.float32)
    if rules is not None and rules.enabled:
        from jax.sharding import PartitionSpec as P

        eout = jax.lax.with_sharding_constraint(
            eout, P(rules.model, rules.batch, None))

    # Gather expert outputs back to tokens, apply gate weights, fold top-k.
    flat_out = eout.reshape(n_experts * capacity, d)
    tok_out = flat_out[jnp.clip(slot, 0, n_experts * capacity - 1)]
    tok_out = tok_out * (keep.astype(jnp.float32) * gate_vals.reshape(-1))[:, None]
    out = jnp.sum(tok_out.reshape(n_tok, top_k, d), axis=1)

    # Aux losses (switch transformer): load balance + router z-loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], n_experts, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    lb_loss = n_experts * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)))

    # back to the residual-stream dtype (bf16 in training)
    return out.reshape(b, s, d).astype(x.dtype), {"lb_loss": lb_loss, "z_loss": z_loss}
