"""Shared transformer layers: RMSNorm, RoPE, GQA / MLA attention, SwiGLU.

Mixed precision: params are stored f32, matmuls run in bf16 with f32
accumulation (preferred_element_type) — the roofline compute term assumes
bf16 MXU throughput.

Sharding is GSPMD-style: parameters get PartitionSpecs from
transformer.param_specs(); activations are constrained at layer boundaries
by the caller. Attention supports three modes used by the four input
shapes: full causal (train/prefill), KV-cache decode (decode_32k), and
sliding-window (long_500k's sub-quadratic carve-out for dense archs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BF16 = jnp.bfloat16


def dot(a, b):
    """bf16 matmul, bf16 out: keeps the residual stream AND its backward
    cotangents in bf16, halving the tensor-parallel all-reduce bytes in both
    directions (§Perf yi-6b iteration 3 — the f32-out variant left 5 GB/layer
    of f32 input-grad partial sums on the wire)."""
    return jnp.dot(a.astype(BF16), b.astype(BF16), preferred_element_type=BF16)


def dot_f32(a, b):
    """f32-accumulated matmul for the lm_head: logits stay f32 for the loss."""
    return jnp.dot(a.astype(BF16), b.astype(BF16), preferred_element_type=jnp.float32)


# Row-parallel output projections (wo / w_down / out_proj) — same bf16-out
# contract; name kept separate for intent.
dot_tp_out = dot


def rmsnorm(x, w, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    s = jnp.mean(x * x, axis=-1, keepdims=True)
    # stats in f32; output back in the stream dtype (bf16 in training)
    return (x * jax.lax.rsqrt(s + eps) * w).astype(dt)


def rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) int32 -> cos/sin (..., head_dim//2)."""
    freqs = 1.0 / theta ** (
        jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads.
    Rotation in f32, result back in the stream dtype."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    g = dot(x, w_gate)
    u = dot(x, w_up)
    return dot(jax.nn.silu(g) * u, w_down)


def _sdpa(q, k, v, mask):
    """q (B,S,H,D), k/v (B,T,K,D) with H = G*K query groups per kv head."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    q = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", q.astype(BF16), k.astype(BF16),
        preferred_element_type=jnp.float32,
    ) / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgst,btkd->bskgd", p.astype(BF16), v.astype(BF16),
        preferred_element_type=BF16,
    )
    return out.reshape(b, s, h, d)


def flash_attention_gqa(q, k, v, *, causal: bool, window: int = 0,
                        q_blk: int = 512, kv_blk: int = 512):
    """Online-softmax attention, nested-scan over (q blocks, kv blocks).

    Bounds activation memory to O(q_blk * kv_blk) per head instead of the
    O(S*T) materialised score matrix — mandatory for the 32k/500k shapes
    (32k^2 scores would be terabytes). Pure XLA (no Pallas) so the multi-pod
    dry-run lowers on any backend; causal masking is applied inside blocks,
    so HLO FLOPs count ~2x the useful causal work — documented in
    EXPERIMENTS.md §Roofline (a TPU Pallas flash kernel with triangle block
    skipping is the projected fix; see §Perf).
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    q_blk, kv_blk = min(q_blk, s), min(kv_blk, t)
    assert s % q_blk == 0 and t % kv_blk == 0, (s, t, q_blk, kv_blk)
    nq, nk = s // q_blk, t // kv_blk
    off = t - s  # query i sits at absolute position off + i

    qr = jnp.moveaxis(q.reshape(b, nq, q_blk, kh, g, d), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, kv_blk, kh, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kv_blk, kh, d), 1, 0)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def q_step(_, qin):
        qb, qi = qin  # (b, q_blk, kh, g, d), scalar block idx
        qpos = off + qi * q_blk + jnp.arange(q_blk)

        def kv_step(carry, kin):
            m, l, acc = carry
            kb, vb, ki = kin
            kpos = ki * kv_blk + jnp.arange(kv_blk)
            sc = jnp.einsum(
                "bqkgd,btkd->bkgqt", qb.astype(BF16), kb.astype(BF16),
                preferred_element_type=jnp.float32,
            ) * scale
            mask = jnp.ones((q_blk, kv_blk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            sc = jnp.where(mask[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(BF16), vb.astype(BF16),
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_blk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_blk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_blk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kr, vr, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (b, kh, g, q_blk, d)
        out = out.astype(BF16)
        return None, jnp.moveaxis(out, 3, 1)  # (b, q_blk, kh, g, d)

    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)
    return out


# Above this token count, full-seq attention switches to the flash path.
FLASH_THRESHOLD = 2048


def causal_mask(s: int, t: int, window: int = 0):
    """(1,1,1,s,t) bool; query i attends key j iff j <= i (+ window bound).
    For s == t the usual triangle; for cached decode t > s the query row is
    offset so the newest query sees everything."""
    qi = jnp.arange(s)[:, None] + (t - s)
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m[None, None, None]


def _quantize_kv(x):
    """(B, S, K, D) float -> (int8 values, (B, S, K) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def attention_gqa(
    x,
    p,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    positions,
    cache=None,  # dict(k, v) (B, T, K, D) or None
    cache_index=None,  # scalar write position when cache is given
    window: int = 0,
    causal: bool = True,
):
    """Returns (out, new_cache). Full-seq when cache is None; single-step
    (or short-step) decode against the cache otherwise."""
    b, s, _ = x.shape
    q = dot(x, p["wq"]).reshape(b, s, n_heads, head_dim)
    k = dot(x, p["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = dot(x, p["wv"]).reshape(b, s, n_kv_heads, head_dim)

    cos, sin = rope_angles(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is None:
        if s >= FLASH_THRESHOLD:
            out = flash_attention_gqa(q, k, v, causal=causal, window=window)
        else:
            mask = causal_mask(s, s, window) if causal else jnp.ones((), bool)
            out = _sdpa(q, k, v, mask)
    else:
        if cache["k"].dtype == jnp.int8:
            # int8 KV cache (paper §2.2's compression insight applied to
            # serving): per-(token, head) absmax quantisation halves the
            # dominant decode HBM stream vs bf16; dequant on read (fused on
            # TPU). §Perf bonus iteration in EXPERIMENTS.md.
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, cache_index, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, cache_index, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, cache_index, 0))
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            ck = ck.astype(BF16) * cks[..., None].astype(BF16)
            cv = cv.astype(BF16) * cvs[..., None].astype(BF16)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0)
            )
            new_cache = {"k": ck, "v": cv}
        t = ck.shape[1]
        kj = jnp.arange(t)[None, :]
        qi = cache_index + jnp.arange(s)[:, None]
        m = kj <= qi
        if window > 0:
            m &= kj > qi - window
        out = _sdpa(q, ck, cv, m[None, None, None])

    out = dot_tp_out(out.reshape(b, s, n_heads * head_dim), p["wo"])
    return out, new_cache


def attention_mla(
    x,
    p,
    *,
    n_heads: int,
    kv_lora_rank: int,
    q_lora_rank: int,
    rope_head_dim: int,
    nope_head_dim: int,
    v_head_dim: int,
    rope_theta: float,
    positions,
    cache=None,  # dict(ckv (B,T,R), krope (B,T,Dr)) or None
    cache_index=None,
    window: int = 0,
):
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style).

    KV state is compressed to a rank-R latent + a single shared RoPE key —
    the cache stores (R + Dr) floats per token instead of 2*K*D. For
    decode the latent is up-projected per step; this is the paper-exact
    "cache the latent" formulation (not the absorbed-weights serving trick).
    """
    b, s, _ = x.shape
    dq = nope_head_dim + rope_head_dim

    cq = dot(x, p["w_dq"])  # (b, s, q_lora)
    q = dot(cq, p["w_uq"]).reshape(b, s, n_heads, dq)
    q_nope, q_rope = q[..., :nope_head_dim], q[..., nope_head_dim:]

    ckv = dot(x, p["w_dkv"])  # (b, s, R)
    krope = dot(x, p["w_krope"]).reshape(b, s, 1, rope_head_dim)

    cos, sin = rope_angles(positions, rope_head_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    krope = apply_rope(krope, cos, sin)

    new_cache = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_index, 0)
        )
        krope_t = jax.lax.dynamic_update_slice(
            cache["krope"], krope[:, :, 0].astype(cache["krope"].dtype),
            (0, cache_index, 0),
        )
        new_cache = {"ckv": ckv, "krope": krope_t}
        krope_full = krope_t[:, :, None, :]
        t = ckv.shape[1]
        qi = cache_index + jnp.arange(s)[:, None]
    else:
        krope_full = krope
        t = s
        qi = jnp.arange(s)[:, None]

    k_nope = dot(ckv, p["w_uk"]).reshape(b, t, n_heads, nope_head_dim)
    value = dot(ckv, p["w_uv"]).reshape(b, t, n_heads, v_head_dim)

    if cache is None and s >= FLASH_THRESHOLD:
        # Long prefill: fold nope+rope into one head dim and use the flash
        # path (v is zero-padded to the q/k head dim, sliced after).
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        kf = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_full, (b, t, n_heads, rope_head_dim))],
            axis=-1,
        )
        vf = jnp.pad(value, ((0, 0), (0, 0), (0, 0), (0, dq - v_head_dim)))
        out = flash_attention_gqa(qf, kf, vf, causal=True, window=window)
        out = out[..., :v_head_dim]
        out = dot_tp_out(out.reshape(b, s, n_heads * v_head_dim), p["wo"])
        return out, new_cache

    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    mask = m[None, None, :, :]  # (1,1,s,t) -> broadcast over heads

    scale = 1.0 / jnp.sqrt(jnp.float32(dq))
    s_nope = jnp.einsum(
        "bshd,bthd->bhst", q_nope.astype(BF16), k_nope.astype(BF16),
        preferred_element_type=jnp.float32,
    )
    s_rope = jnp.einsum(
        "bshd,btxd->bhst", q_rope.astype(BF16),
        jnp.broadcast_to(krope_full, (b, t, 1, rope_head_dim)).astype(BF16),
        preferred_element_type=jnp.float32,
    )
    scores = (s_nope + s_rope) * scale
    scores = jnp.where(mask, scores, -1e30)
    pattn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhst,bthd->bshd", pattn.astype(BF16), value.astype(BF16),
        preferred_element_type=BF16,
    )
    out = dot_tp_out(out.reshape(b, s, n_heads * v_head_dim), p["wo"])
    return out, new_cache
