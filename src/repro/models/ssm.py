"""Mamba2 blocks via SSD — state-space duality (arXiv:2405.21060).

The chunked SSD algorithm: sequence is split into chunks of Q tokens;
within a chunk the recurrence is expanded into an attention-like quadratic
form (MXU-friendly — this is the "duality"), across chunks a short
lax.scan propagates the (H, P, N) state. Decode is the O(1) recurrence.

Shapes: x (B, L, H, P) heads x head_dim, B/C (B, L, N) (single group),
dt (B, L, H), A (H,) negative reals (stored as log magnitude).

Sharding: heads are sharded over the `model` axis; the inter-chunk scan
carries (B, H, P, N) states — no sequence-axis collectives are needed
because chunking is local to each data shard's rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import BF16, dot, dot_tp_out, rmsnorm


def _segsum_exp(dA_cs):
    """dA_cs (..., Q) inclusive cumsum -> exp lower-triangular decay (.., Q, Q).

    L[i, j] = exp(cs[i] - cs[j]) for i >= j else 0.
    """
    q = dA_cs.shape[-1]
    diff = dA_cs[..., :, None] - dA_cs[..., None, :]
    tri = jnp.tril(jnp.ones((q, q), bool))
    # Mask BEFORE exp: exp of a large positive (upper-triangle) diff is inf,
    # and where(tri, inf, 0) poisons the backward pass with 0 * inf = NaN.
    return jnp.exp(jnp.where(tri, diff, -jnp.inf))


def ssd_chunked(x, dt, a_log, bm, cm, chunk: int):
    """Full-sequence SSD. Returns y (B, L, H, P) and final state (B,H,P,N)."""
    bsz, l, h, p = x.shape
    n = bm.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative

    xr = x.reshape(bsz, nc, chunk, h, p)
    dtr = dt.reshape(bsz, nc, chunk, h)
    br = bm.reshape(bsz, nc, chunk, n)
    cr = cm.reshape(bsz, nc, chunk, n)

    dA = dtr * a  # (b, c, q, h)
    cs = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (quadratic / attention-like, MXU) -------------------
    decay = _segsum_exp(jnp.moveaxis(cs, -1, -2))  # (b, c, h, q, q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cr.astype(BF16), br.astype(BF16),
                        preferred_element_type=jnp.float32)
    w = scores[:, :, None] * decay * jnp.moveaxis(dtr, -1, -2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w.astype(BF16), xr.astype(BF16),
                         preferred_element_type=jnp.float32)

    # --- chunk states -----------------------------------------------------
    last = cs[:, :, -1:, :]  # (b, c, 1, h)
    sdecay = jnp.exp(last - cs)  # (b, c, q, h)
    wx = xr * (sdecay * dtr)[..., None]  # (b, c, q, h, p)
    states = jnp.einsum("bcqn,bcqhp->bchpn", br.astype(BF16), wx.astype(BF16),
                        preferred_element_type=jnp.float32)

    # --- inter-chunk recurrence (short scan over nc chunks) --------------
    chunk_decay = jnp.exp(last[:, :, 0])  # (b, c, h)

    def step(s, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        s_new = s * dec[..., None, None] + st
        return s_new, s

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev = jnp.moveaxis(prev, 0, 1)  # (b, c, h, p, n) state entering chunk c

    # --- inter-chunk contribution ----------------------------------------
    qdecay = jnp.exp(cs)  # (b, c, q, h)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cr.astype(BF16), prev.astype(BF16),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * qdecay[..., None]

    y = (y_intra + y_inter).reshape(bsz, lp, h, p)[:, :l]
    return y, final


def ssd_decode_step(x, dt, a_log, bm, cm, state):
    """One-token recurrence. x (B,1,H,P), dt (B,1,H), bm/cm (B,1,N),
    state (B,H,P,N) -> (y (B,1,H,P), new_state)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dA = jnp.exp(dt[:, 0] * a)  # (B,H)
    upd = jnp.einsum("bn,bhp->bhpn", bm[:, 0], x[:, 0] * dt[:, 0, :, None])
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, cm[:, 0])
    return y[:, None], new_state


def mamba2_block(x, p, cfg, *, cache=None):
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated norm -> out_proj.

    cache: None (full seq) or dict(conv (B, K-1, C_conv), state (B,H,P,N))
    for single-token decode. Returns (out, new_cache).
    """
    bsz, l, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner

    # Separate per-stream projections (NOT one fused zxbcdt matmul): the
    # fused form's slice boundaries cut across `model`-axis shards, which
    # made GSPMD insert ~100 GB/step of collective-permute resharding on the
    # production mesh (EXPERIMENTS.md §Perf, mamba2 iteration 1). Separate
    # weights shard each stream independently; XLA still fuses the matmuls.
    z = dot(x, p["w_z"])  # (B, L, di)        sharded over model
    xin = dot(x, p["w_x"])  # (B, L, di)      sharded over model
    bm = dot(x, p["w_b"])  # (B, L, N)        replicated (tiny)
    cm = dot(x, p["w_c"])  # (B, L, N)        replicated (tiny)
    dt = dot(x, p["w_dt"])  # (B, L, H)       sharded over model
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B, L, H)

    # Depthwise causal conv1d per stream (same sharding-alignment reasoning:
    # a fused conv over concat(x, B, C) would reshard at the concat).
    k = cfg.ssm_conv
    new_cache = None

    def causal_conv(inp, w, b, hist=None):
        if hist is None:
            padded = jnp.pad(inp, ((0, 0), (k - 1, 0), (0, 0)))
            out = sum(padded[:, i : i + l] * w[i][None, None, :] for i in range(k))
            return jax.nn.silu(out + b), None
        full = jnp.concatenate([hist, inp], axis=1)  # (B, k-1+l, C)
        out = sum(full[:, i : i + l] * w[i][None, None, :] for i in range(k))
        return jax.nn.silu(out + b), full[:, -(k - 1) :]

    hists = (cache or {}).get("conv", {})
    xs, hx = causal_conv(xin, p["conv_w_x"], p["conv_b_x"], hists.get("x"))
    bm, hb = causal_conv(bm, p["conv_w_b"], p["conv_b_b"], hists.get("b"))
    cm, hc = causal_conv(cm, p["conv_w_c"], p["conv_b_c"], hists.get("c"))
    if cache is not None:
        new_conv = {"x": hx, "b": hb, "c": hc}
    xs = xs.reshape(bsz, l, h, pdim)

    if cache is None:
        y, final = ssd_chunked(xs, dt, p["a_log"], bm, cm, cfg.ssm_chunk)
    else:
        y, final = ssd_decode_step(xs, dt, p["a_log"], bm, cm, cache["state"])
        new_cache = {"conv": new_conv, "state": final}

    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return dot_tp_out(y, p["out_proj"]), new_cache


def init_mamba2_params(key, cfg, dtype=jnp.float32):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    scale = lambda fan: 1.0 / jnp.sqrt(jnp.float32(fan))
    return {
        "w_z": jax.random.normal(ks[0], (d, di), dtype) * scale(d),
        "w_x": jax.random.normal(ks[1], (d, di), dtype) * scale(d),
        "w_b": jax.random.normal(ks[2], (d, n), dtype) * scale(d),
        "w_c": jax.random.normal(ks[3], (d, n), dtype) * scale(d),
        "w_dt": jax.random.normal(ks[4], (d, h), dtype) * scale(d),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype) * scale(di),
        "conv_w_x": jax.random.normal(ks[6], (cfg.ssm_conv, di), dtype) * 0.1,
        "conv_b_x": jnp.zeros((di,), dtype),
        "conv_w_b": jax.random.normal(ks[7], (cfg.ssm_conv, n), dtype) * 0.1,
        "conv_b_b": jnp.zeros((n,), dtype),
        "conv_w_c": jax.random.normal(ks[7], (cfg.ssm_conv, n), dtype) * 0.1,
        "conv_b_c": jnp.zeros((n,), dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "a_log": jnp.zeros((h,), dtype),  # A = -1
        "d_skip": jnp.ones((h,), dtype),
        "norm_w": jnp.ones((di,), dtype),
    }


def mamba2_param_specs(mesh_model_axis: str = "model"):
    """PartitionSpecs matching init_mamba2_params: the wide streams (z, x,
    dt, heads) shard over `model`; the tiny shared B/C streams replicate."""
    from jax.sharding import PartitionSpec as P

    m = mesh_model_axis
    return {
        "w_z": P(None, m),
        "w_x": P(None, m),
        "w_b": P(None, None),
        "w_c": P(None, None),
        "w_dt": P(None, m),
        "out_proj": P(m, None),
        "conv_w_x": P(None, m),
        "conv_b_x": P(m),
        "conv_w_b": P(None, None),
        "conv_b_b": P(None),
        "conv_w_c": P(None, None),
        "conv_b_c": P(None),
        "dt_bias": P(m),
        "a_log": P(m),
        "d_skip": P(m),
        "norm_w": P(m),
    }
