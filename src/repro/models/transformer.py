"""Decoder-only LM (dense / MoE / VLM-backbone) with scan-over-layers.

Layers are homogeneous and their params are stacked along a leading L axis
so the whole stack is one jax.lax.scan — critical for the multi-pod
dry-run: the HLO contains ONE layer body regardless of depth (81-layer
models compile in seconds, and SPMD partitioning cost stays flat).

Forward modes:
  forward(...)              full-sequence (train / prefill)
  decode_step(...)          one token against a KV cache
Caches are pytrees stacked (L, ...) and scanned alongside the params.

Sharding: param_specs() returns a PartitionSpec pytree mirroring
init_params() (megatron-style: heads/FFN/experts/vocab on `model`, batch on
`pod`+`data`); activations are constrained at layer boundaries by
with_sharding_constraint using the specs in ShardingRules.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import (
    attention_gqa,
    attention_mla,
    dot,
    dot_f32,
    dot_tp_out,
    rmsnorm,
)
from repro.models.moe import moe_ffn


@dataclass(frozen=True)
class ShardingRules:
    """Logical activation shardings. enabled=False (smoke tests, single
    device) turns every with_sharding_constraint into a no-op."""

    batch: tuple | str | None = ("pod", "data")
    model: str | None = "model"
    seq: str | None = None  # set to shard decode caches along sequence
    enabled: bool = True

    def act(self):  # (B, S, D)
        return P(self.batch, None, None)

    def cache_kv(self):  # (B, T, K, D)
        return P(self.batch, self.seq, None, None)


NO_SHARDING = ShardingRules(batch=None, model=None, enabled=False)


def _constrain(x, spec, rules: ShardingRules):
    if not rules.enabled:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# Parameter init + specs (shared structure builder)
# --------------------------------------------------------------------------


def _glorot(key, shape, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    return jax.random.normal(key, shape, dtype) / jnp.sqrt(jnp.float32(fan_in))


def init_attn_params(key, cfg: ArchConfig):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    if cfg.attention == "mla":
        dq = cfg.nope_head_dim + cfg.rope_head_dim
        return {
            "w_dq": _glorot(ks[0], (d, cfg.q_lora_rank)),
            "w_uq": _glorot(ks[1], (cfg.q_lora_rank, h * dq)),
            "w_dkv": _glorot(ks[2], (d, cfg.kv_lora_rank)),
            "w_krope": _glorot(ks[3], (d, cfg.rope_head_dim)),
            "w_uk": _glorot(ks[4], (cfg.kv_lora_rank, h * cfg.nope_head_dim)),
            "w_uv": _glorot(ks[5], (cfg.kv_lora_rank, h * cfg.resolved_v_head_dim)),
            "wo": _glorot(ks[6], (h * cfg.resolved_v_head_dim, d)),
        }
    return {
        "wq": _glorot(ks[0], (d, h * hd)),
        "wk": _glorot(ks[1], (d, k * hd)),
        "wv": _glorot(ks[2], (d, k * hd)),
        "wo": _glorot(ks[3], (h * hd, d)),
    }


def attn_param_specs(cfg: ArchConfig, m: str = "model"):
    if cfg.attention == "mla":
        return {
            "w_dq": P(None, None),
            "w_uq": P(None, m),
            "w_dkv": P(None, None),
            "w_krope": P(None, None),
            "w_uk": P(None, m),
            "w_uv": P(None, m),
            "wo": P(m, None),
        }
    return {"wq": P(None, m), "wk": P(None, m), "wv": P(None, m), "wo": P(m, None)}


def init_ffn_params(key, cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    if cfg.n_experts:
        e = cfg.n_experts
        return {
            "router": _glorot(ks[0], (d, e)),
            "w_gate": _glorot(ks[1], (e, d, f)),
            "w_up": _glorot(ks[2], (e, d, f)),
            "w_down": _glorot(ks[3], (e, f, d)),
        }
    return {
        "w_gate": _glorot(ks[0], (d, f)),
        "w_up": _glorot(ks[1], (d, f)),
        "w_down": _glorot(ks[2], (f, d)),
    }


def ffn_param_specs(cfg: ArchConfig, m: str = "model"):
    if cfg.n_experts:
        return {
            "router": P(None, None),
            "w_gate": P(m, None, None),
            "w_up": P(m, None, None),
            "w_down": P(m, None, None),
        }
    return {"w_gate": P(None, m), "w_up": P(None, m), "w_down": P(m, None)}


def init_layer_params(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": init_attn_params(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn": init_ffn_params(k2, cfg),
    }


def layer_param_specs(cfg: ArchConfig, m: str = "model", stacked: bool = True):
    add = (None,) if stacked else ()
    prep = lambda spec: P(*add, *spec)
    return {
        "ln1": prep(P(None)),
        "attn": jax.tree.map(prep, attn_param_specs(cfg, m),
                             is_leaf=lambda x: isinstance(x, P)),
        "ln2": prep(P(None)),
        "ffn": jax.tree.map(prep, ffn_param_specs(cfg, m),
                            is_leaf=lambda x: isinstance(x, P)),
    }


def init_params(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    params = {
        "embed": _glorot(ks[1], (cfg.padded_vocab, cfg.d_model)),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": _glorot(ks[2], (cfg.d_model, cfg.padded_vocab)),
    }
    if cfg.n_prefix_tokens:
        params["prefix_proj"] = _glorot(ks[3], (cfg.d_model, cfg.d_model))
    return params


def param_specs(cfg: ArchConfig, m: str = "model"):
    specs = {
        "embed": P(m, None),
        "layers": layer_param_specs(cfg, m, stacked=True),
        "final_norm": P(None),
        "lm_head": P(None, m),
    }
    if cfg.n_prefix_tokens:
        specs["prefix_proj"] = P(None, None)
    return specs


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _layer_fwd(x, lp, cfg: ArchConfig, positions, rules: ShardingRules,
               window: int, cache=None, cache_index=None):
    """One transformer layer. Returns (x, (new_cache, aux))."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        attn_out, new_cache = attention_mla(
            h, lp["attn"],
            n_heads=cfg.n_heads,
            kv_lora_rank=cfg.kv_lora_rank,
            q_lora_rank=cfg.q_lora_rank,
            rope_head_dim=cfg.rope_head_dim,
            nope_head_dim=cfg.nope_head_dim,
            v_head_dim=cfg.resolved_v_head_dim,
            rope_theta=cfg.rope_theta,
            positions=positions,
            cache=cache, cache_index=cache_index, window=window,
        )
    else:
        attn_out, new_cache = attention_gqa(
            h, lp["attn"],
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta,
            positions=positions,
            cache=cache, cache_index=cache_index, window=window,
        )
    x = x + attn_out
    x = _constrain(x, rules.act(), rules)

    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    aux = {}
    if cfg.n_experts:
        ffn_out, aux = moe_ffn(
            h, lp["ffn"], n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, rules=rules,
        )
    else:
        ffn_out = dot_tp_out(
            jax.nn.silu(dot(h, lp["ffn"]["w_gate"])) * dot(h, lp["ffn"]["w_up"]),
            lp["ffn"]["w_down"],
        )
    x = x + ffn_out
    x = _constrain(x, rules.act(), rules)
    return x, (new_cache, aux)


def forward(params, tokens, cfg: ArchConfig, rules: ShardingRules,
            prefix_embeds=None, window: int | None = None):
    """Full-sequence forward -> (logits, aux). tokens (B, S) int32;
    prefix_embeds (B, Pfx, D) for VLM/audio backbones."""
    w = cfg.sliding_window if window is None else window
    from repro.models.layers import BF16
    x = params["embed"][tokens].astype(BF16)  # (B, S, D) bf16 stream
    if prefix_embeds is not None:
        pfx = dot(prefix_embeds, params["prefix_proj"])
        x = jnp.concatenate([pfx, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    x = _constrain(x, rules.act(), rules)

    def body(carry, lp):
        y, (_, aux) = _layer_fwd(carry, lp, cfg, positions, rules, w)
        return y, aux

    if cfg.remat:
        policy = (None if cfg.remat_policy == "full"
                  else getattr(jax.checkpoint_policies, cfg.remat_policy))
        body = jax.checkpoint(body, policy=policy)
    x, auxes = jax.lax.scan(body, x, params["layers"])

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dot_f32(x, params["lm_head"])
    logits = _constrain(logits, P(rules.batch, None, rules.model), rules)
    aux = {k: jnp.mean(v) for k, v in auxes.items()} if auxes else {}
    return logits, aux


def init_cache(cfg: ArchConfig, batch: int, capacity: int, dtype=jnp.bfloat16):
    """Stacked (L, ...) KV cache. For SWA archs pass capacity=window.
    cfg.kv_cache_dtype == "int8" stores quantised values + per-(token, head)
    f32 scales (2.25 bytes/element effective vs 2 for bf16 values alone —
    net ~1.78x smaller than bf16, 3.6x smaller than f32)."""
    l = cfg.n_layers
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros((l, batch, capacity, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((l, batch, capacity, cfg.rope_head_dim), dtype),
        }
    k, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((l, batch, capacity, k, hd), jnp.int8),
            "v": jnp.zeros((l, batch, capacity, k, hd), jnp.int8),
            "k_scale": jnp.zeros((l, batch, capacity, k), jnp.float32),
            "v_scale": jnp.zeros((l, batch, capacity, k), jnp.float32),
        }
    return {
        "k": jnp.zeros((l, batch, capacity, k, hd), dtype),
        "v": jnp.zeros((l, batch, capacity, k, hd), dtype),
    }


def cache_specs(cfg: ArchConfig, rules: ShardingRules):
    if cfg.attention == "mla":
        return {
            "ckv": P(None, rules.batch, rules.seq, None),
            "krope": P(None, rules.batch, rules.seq, None),
        }
    specs = {
        "k": P(None, rules.batch, rules.seq, None, None),
        "v": P(None, rules.batch, rules.seq, None, None),
    }
    if cfg.kv_cache_dtype == "int8":
        specs["k_scale"] = P(None, rules.batch, rules.seq, None)
        specs["v_scale"] = P(None, rules.batch, rules.seq, None)
    return specs


def decode_step(params, token, cache, cache_index, cfg: ArchConfig,
                rules: ShardingRules, window: int | None = None):
    """One decode step. token (B, 1) int32; cache stacked (L, ...);
    cache_index: scalar write position. Returns (logits, new_cache)."""
    w = cfg.sliding_window if window is None else window
    from repro.models.layers import BF16
    x = params["embed"][token].astype(BF16)  # (B, 1, D)
    positions = jnp.full((1, 1), cache_index, jnp.int32)

    def body(carry, inp):
        lp, layer_cache = inp
        y, (new_cache, _) = _layer_fwd(
            carry, lp, cfg, positions, rules, w,
            cache=layer_cache, cache_index=cache_index,
        )
        return y, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = dot_f32(x, params["lm_head"])
    return logits, new_cache


# --------------------------------------------------------------------------
# Losses / steps
# --------------------------------------------------------------------------


def xent_loss(logits, targets, n_prefix: int = 0):
    """Mean next-token cross entropy; VLM/audio prefix positions excluded."""
    if n_prefix:
        logits = logits[:, n_prefix:]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - tgt)


def loss_fn(params, batch, cfg: ArchConfig, rules: ShardingRules):
    logits, aux = forward(
        params, batch["tokens"], cfg, rules,
        prefix_embeds=batch.get("prefix_embeds"),
    )
    loss = xent_loss(logits, batch["targets"], cfg.n_prefix_tokens)
    if aux:
        loss = loss + 0.01 * aux.get("lb_loss", 0.0) + 1e-3 * aux.get("z_loss", 0.0)
    return loss
