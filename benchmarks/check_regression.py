"""CI perf regression guard over BENCH_pipeline.json.

Compares selected per-round timings in a fresh benchmark run against a
checked-in smoke baseline and fails (exit 1) when any metric regresses by
more than --max-ratio. The generous default ratio absorbs runner-to-runner
hardware variance while still catching order-of-magnitude regressions
(e.g. the packed scan silently falling back to a dense per-round path, or
the external-memory chunk loop re-quantising per round).

Usage:
    python benchmarks/check_regression.py /tmp/BENCH_pipeline.json \
        benchmarks/smoke_baseline.json --max-ratio 2.5

The baseline file maps dotted JSON paths to reference seconds:
    {"metrics": {"round_loop.packed_scan_per_round_s": 0.123, ...}}

It may also carry hard INVARIANTS — within-run relations that must hold
regardless of runner speed (both sides are measured on the same machine
in the same process, so no variance allowance is needed):

    {"invariants": [
        {"name": "packed histogram <= dense",
         "left": "kernels.packed_total_s",
         "right": "kernels.dense_total_s", "max_ratio": 1.0},
        {"name": "per-depth packed/dense ratio bound",
         "path": "kernels.packed_vs_dense_max_ratio", "max": 1.1}
    ]}

`left`/`right` form: fail unless bench[left] <= max_ratio * bench[right].
`path`/`max` form: fail unless bench[path] <= max. These enforce the
ISSUE 9 acceptance relations (packed histogram no slower than dense;
dispatched cut construction >= 3x faster than the XLA reference) on
every CI run, not just against a stale baseline number.
"""
from __future__ import annotations

import argparse
import json
import sys


def lookup(tree: dict, dotted: str):
    node = tree
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", help="fresh BENCH_pipeline.json")
    ap.add_argument("baseline", help="checked-in smoke baseline json")
    ap.add_argument("--max-ratio", type=float, default=2.5)
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        bench = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures, checked = [], 0
    for path, ref in baseline["metrics"].items():
        value = lookup(bench, path)
        if value is None:
            failures.append(f"MISSING  {path}: not present in {args.bench}")
            continue
        checked += 1
        ratio = value / ref
        status = "OK" if ratio <= args.max_ratio else "REGRESSED"
        print(
            f"{status:9s} {path}: {value:.4f}s vs baseline {ref:.4f}s "
            f"({ratio:.2f}x, limit {args.max_ratio}x)"
        )
        if ratio > args.max_ratio:
            failures.append(
                f"REGRESSED {path}: {value:.4f}s is {ratio:.2f}x the "
                f"baseline {ref:.4f}s (limit {args.max_ratio}x)"
            )
    for inv in baseline.get("invariants", []):
        name = inv.get("name", json.dumps(inv, sort_keys=True))
        if "left" in inv:
            lv = lookup(bench, inv["left"])
            rv = lookup(bench, inv["right"])
            if lv is None or rv is None:
                failures.append(
                    f"MISSING  invariant '{name}': "
                    f"{inv['left']}={lv} {inv['right']}={rv}"
                )
                continue
            checked += 1
            limit = inv.get("max_ratio", 1.0)
            ok = lv <= limit * rv
            print(
                f"{'OK' if ok else 'VIOLATED':9s} invariant '{name}': "
                f"{inv['left']}={lv:.4f} vs {limit} * {inv['right']}="
                f"{limit * rv:.4f}"
            )
            if not ok:
                failures.append(
                    f"VIOLATED invariant '{name}': {lv:.4f} > "
                    f"{limit} * {rv:.4f}"
                )
        else:
            v = lookup(bench, inv["path"])
            if v is None:
                failures.append(
                    f"MISSING  invariant '{name}': {inv['path']} absent"
                )
                continue
            checked += 1
            ok = v <= inv["max"]
            print(
                f"{'OK' if ok else 'VIOLATED':9s} invariant '{name}': "
                f"{inv['path']}={v:.4f} (max {inv['max']})"
            )
            if not ok:
                failures.append(
                    f"VIOLATED invariant '{name}': {inv['path']}={v:.4f} "
                    f"exceeds {inv['max']}"
                )
    if not checked and not failures:
        failures.append("baseline lists no metrics")
    for line in failures:
        print(line, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
