"""Paper Figure 2: runtime scaling with device count (airline dataset).

The container has ONE physical core, so wall-clock cannot show real
speedup; what CAN be measured faithfully is the Algorithm-1 distribution
itself: per-device row count, per-device histogram work, and the AllReduce
bytes per boosting round, for p in {1, 2, 4, 8} virtual devices. Each p
runs in a subprocess (XLA_FLAGS must precede jax init).

AllReduce bytes/round (analytic, verified against the HLO in the dry-run):
  sum over levels l of 2^l * F * B * 2 * 4 bytes  (histogram f32 pairs)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from repro.core import Booster, BoosterConfig, DeviceDMatrix
from repro.data import make_dataset
from repro.jaxcompat import make_mesh

p = {p}
x, y, spec = make_dataset("airline", n_rows={rows})
cfg = BoosterConfig(n_rounds={rounds}, max_depth=6, max_bins=256,
                    objective=spec.objective)
mesh = make_mesh((p,), ("data",))
dtrain = DeviceDMatrix(x, label=y)
t0 = time.perf_counter()
bst = Booster(cfg).fit(dtrain, mesh=mesh)
jax.block_until_ready(bst.margins)
dt = time.perf_counter() - t0
print(json.dumps(dict(p=p, time_s=dt, rows_per_device=len(x)//p)))
"""


def allreduce_bytes_per_round(max_depth=6, n_features=13, max_bins=256):
    total = 0
    for level in range(max_depth):
        total += (2**level) * n_features * max_bins * 2 * 4
    return total


def run(rows=32_768, rounds=5, device_counts=(1, 2, 4, 8)):
    results = []
    for p in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        res = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_SCRIPT.format(
                p=p, rows=rows, rounds=rounds))],
            capture_output=True, text=True, timeout=900, env=env,
        )
        if res.returncode != 0:
            results.append({"p": p, "error": res.stderr[-300:]})
            continue
        rec = json.loads(res.stdout.strip().splitlines()[-1])
        rec["allreduce_bytes_per_round"] = allreduce_bytes_per_round()
        results.append(rec)
    return results


def main():
    rows = run()
    print("# Figure 2 (airline-shaped, virtual devices on 1 core):")
    print("devices,time_s,rows_per_device,allreduce_bytes_per_round")
    for r in rows:
        if "error" in r:
            print(f"{r['p']},ERROR,{r['error'][:80]}")
        else:
            print(f"{r['p']},{r['time_s']:.2f},{r['rows_per_device']},"
                  f"{r['allreduce_bytes_per_round']}")
    return rows


if __name__ == "__main__":
    main()
