"""Paper Figure 2: runtime scaling with device count (airline dataset).

The container has ONE physical core, so wall-clock cannot show real
speedup; what CAN be measured faithfully is the Algorithm-1 distribution
itself: a rows x devices grid recording rows/s and the per-round
communication profile (wire bytes, collective calls, compression fallbacks
— `Booster.comm_stats`, DESIGN.md §15) for each collective strategy
(psum / ring / hier) and compression mode (f32 / f16 / q16). Each cell
runs in a subprocess (XLA_FLAGS must precede jax init).

`--merge-into BENCH_pipeline.json` folds the results into the shared BENCH
file as a `scaling` section, including the headline comm-bytes reduction of
the compressed histogram allreduce vs exact f32.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from repro.core import Booster, BoosterConfig, DeviceDMatrix
from repro.data import make_dataset
from repro.jaxcompat import make_mesh

p = {p}
x, y, spec = make_dataset("airline", n_rows={rows})
cfg = BoosterConfig(n_rounds={rounds}, max_depth=6, max_bins=256,
                    objective=spec.objective)
mesh = make_mesh((p,), ("data",))
dtrain = DeviceDMatrix(x, label=y)
fit_kw = dict(mesh=mesh, collective={collective!r},
              compression={compression!r})
# untimed warm-up fit compiles the round program
Booster(BoosterConfig(n_rounds=1, max_depth=6, max_bins=256,
                      objective=spec.objective)).fit(dtrain, **fit_kw)
t0 = time.perf_counter()
bst = Booster(cfg).fit(dtrain, **fit_kw)
jax.block_until_ready(bst.margins)
dt = time.perf_counter() - t0
rec = dict(p=p, rows={rows}, time_s=dt, rows_per_device=len(x)//p,
           rows_per_s=len(x) * {rounds} / dt, collective={collective!r},
           compression={compression!r})
rec.update(bst.comm_stats)
print(json.dumps(rec))
"""


def allreduce_bytes_per_round(max_depth=6, n_features=13, max_bins=256):
    """Legacy single-number model: full-histogram f32 payload per round
    (sum over levels of 2^l * F * B * 2 * 4 bytes)."""
    total = 0
    for level in range(max_depth):
        total += (2**level) * n_features * max_bins * 2 * 4
    return total


def _cell(p, rows, rounds, collective, compression):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SCRIPT.format(
            p=p, rows=rows, rounds=rounds, collective=collective,
            compression=compression))],
        capture_output=True, text=True, timeout=1200, env=env,
    )
    if res.returncode != 0:
        return {"p": p, "rows": rows, "collective": collective,
                "compression": compression, "error": res.stderr[-300:]}
    rec = json.loads(res.stdout.strip().splitlines()[-1])
    rec["hist_bytes_per_round"] = sum(rec.pop("hist_bytes_per_level"))
    return rec


def run(rows_list=(32_768,), rounds=5, device_counts=(1, 2, 4, 8),
        collectives=("psum", "ring", "hier"),
        compressions=(None, "q16")):
    """The rows x devices x (collective, compression) grid.

    f32 runs cover every collective; compressed runs go through the ring
    (the strategy whose wire dtype actually narrows). p=1 runs only psum
    f32 (the single-device baseline row).
    """
    grid = []
    for rows in rows_list:
        for p in device_counts:
            cells = [("psum", None)]
            if p > 1:
                cells += [(c, None) for c in collectives if c != "psum"]
                cells += [("ring", comp) for comp in compressions
                          if comp is not None]
            for coll, comp in cells:
                grid.append(_cell(p, rows, rounds, coll, comp))
    return grid


def summarise(grid):
    """Headline: compressed ring vs exact f32 ring at the largest grid cell
    — histogram-payload and total wire-byte reduction factors."""
    ok = [g for g in grid if "error" not in g]
    ring_f32 = {(g["rows"], g["p"]): g for g in ok
                if g["collective"] == "ring" and g["compression"] is None}
    best = None
    for g in ok:
        if g["compression"] is None:
            continue
        ref = ring_f32.get((g["rows"], g["p"]))
        if ref is None:
            continue
        red_total = ref["bytes_per_round"] / g["bytes_per_round"]
        red_hist = ref["hist_bytes_per_round"] / g["hist_bytes_per_round"]
        cand = {
            "rows": g["rows"], "devices": g["p"],
            "collective": g["collective"], "compression": g["compression"],
            "bytes_per_round": g["bytes_per_round"],
            "bytes_per_round_f32": ref["bytes_per_round"],
            "reduction_hist": round(red_hist, 4),
            "reduction_total": round(red_total, 4),
            "fallback_events": g["fallback_events"],
        }
        if best is None or (cand["devices"], cand["reduction_hist"]) > (
                best["devices"], best["reduction_hist"]):
            best = cand
    return best


def merge_into(path, section):
    """Fold the scaling section into an existing BENCH json (created if
    missing), leaving every other section untouched."""
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["scaling"] = section
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", type=int, nargs="+", default=[32_768])
    ap.add_argument("--devices", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--compressions", nargs="+", default=["q16", "f16"])
    ap.add_argument("--out", default=None, help="write the grid json here")
    ap.add_argument("--merge-into", default=None,
                    help="BENCH json to receive the `scaling` section")
    args = ap.parse_args(argv)

    grid = run(rows_list=tuple(args.rows), rounds=args.rounds,
               device_counts=tuple(args.devices),
               compressions=tuple(args.compressions))
    print("# Figure 2 grid (airline-shaped, virtual devices on 1 core):")
    print("rows,devices,collective,compression,time_s,rows_per_s,"
          "bytes_per_round,hist_bytes_per_round,fallbacks")
    for g in grid:
        if "error" in g:
            print(f"{g['rows']},{g['p']},{g['collective']},"
                  f"{g['compression']},ERROR,{g['error'][:80]}")
        else:
            print(f"{g['rows']},{g['p']},{g['collective']},"
                  f"{g['compression']},{g['time_s']:.2f},"
                  f"{g['rows_per_s']:.0f},{g['bytes_per_round']},"
                  f"{g['hist_bytes_per_round']},{g['fallback_events']}")
    section = {
        "note": "virtual devices on one core: rows/s is NOT a speedup "
                "claim; comm bytes/round is the faithful signal "
                "(Booster.comm_stats, DESIGN.md §15)",
        "rounds": args.rounds,
        "grid": grid,
        "comm_reduction": summarise(grid),
    }
    if section["comm_reduction"]:
        cr = section["comm_reduction"]
        print(f"# comm reduction ({cr['collective']}+{cr['compression']}, "
              f"p={cr['devices']}): hist x{cr['reduction_hist']}, "
              f"total x{cr['reduction_total']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(section, f, indent=1)
            f.write("\n")
    if args.merge_into:
        merge_into(args.merge_into, section)
        print(f"# merged `scaling` into {args.merge_into}")
    return grid


if __name__ == "__main__":
    main()
