"""Roofline table from the dry-run JSONs (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
the per-(arch x shape x mesh) three-term table with the dominant bottleneck
and useful-FLOPs ratio. Single-pod rows are the canonical §Roofline table;
multi-pod rows prove the `pod` axis shards.
"""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if isinstance(r, dict) and "mesh" in r:  # skip gbdt_round.json etc.
            recs.append(r)
    return recs


def table(recs, mesh="pod16x16"):
    rows = []
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], r["status"],
                         r.get("reason", r.get("error", ""))[:60], "", "", "", ""))
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"], "ok", rf["dominant"],
            f"{rf['compute_s']:.3e}", f"{rf['memory_s']:.3e}",
            f"{rf['collective_s']:.3e}", f"{rf['useful_flops_ratio']:.2f}",
        ))
    return rows


def main():
    recs = load()
    if not recs:
        print("# no dry-run records found; run: python -m repro.launch.dryrun --all --both-meshes")
        return []
    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"# Roofline ({mesh}): arch,shape,status,dominant,compute_s,memory_s,collective_s,useful_ratio")
        for row in table(recs, mesh):
            print(",".join(str(c) for c in row))
    return recs


if __name__ == "__main__":
    main()
