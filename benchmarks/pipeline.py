"""Figure 1 pipeline benchmark + compressed-vs-dense round-loop comparison.

Three parts, all emitted into BENCH_pipeline.json so the perf trajectory is
tracked across PRs (EXPERIMENTS.md §Perf):

1. Phase split — where a boosting round spends its time (quantise,
   compress, gradients, histogram build, split eval, prediction), each
   phase jit'd and timed separately.

2. Round loop — per-round wall-clock of the scan-compiled packed-native
   training path (this repo's default) vs a seed-style dense path that
   re-creates the pre-compressed-native behaviour: per-round Python
   dispatch, full-matrix unpack at the top of every round, dense
   histogram/partition/prediction, and an end-of-training concatenate.

3. Objectives — per-round wall-clock of the compiled scan for EVERY
   built-in objective (with its default metric tracked in-scan), so a
   regression in any objective's grad/metric path shows up in the perf
   trajectory. rank:pairwise rows are capped (its gradient is O(n^2) in
   the group mask by design).

4. External memory — ExternalDMatrix build + training at a row count
   BEYOND the largest single-shot config (default 4x, ISSUE 4): the data
   is generated chunk by chunk and the flat float matrix never exists,
   so this measures the streaming-sketch -> chunked-pack -> scan-over-
   chunks pipeline end to end, plus a chunk-size sweep at the single-shot
   size.

5. Stochastic — warm per-round time at subsample in {1.0, 0.5, 0.25} and
   colsample_bytree=0.5 (ISSUE 5): subsampled rounds histogram a
   statically-shaped compacted row buffer, so per-round time should fall
   roughly with the subsample fraction.

6. Resilience — per-round overhead of in-run checkpointing (ISSUE 6):
   warm fit time with checkpoint_every=1 (an atomic snapshot after every
   round, the worst-case cadence) vs the plain fit, plus the snapshot
   size on disk. Acceptance: overhead < 5% per round at 1M x 50.

7. Serving — batch-inference timings (ISSUE 7): fused all-trees-one-
   launch traversal vs the per-tree scan loop on a >= 512-tree ensemble
   (raw and packed inputs), plus p50/p99 request latency and rows/s
   through the shape-bucketed PredictEngine under mixed batch sizes,
   with a zero-recompiles-after-warmup counter.

8. Kernels — packed vs dense histogram build across a tree-depth sweep
   (CI-enforced invariant: packed <= dense at every depth, best-of-N),
   a 1/2/4-deep scratch-buffer sweep of the privatised DMA-pipelined
   Pallas kernel (interpret mode on CPU), and dispatched cut
   construction vs the pure-XLA reference (ISSUE 9).

`--sections` runs a subset (e.g. only external_memory) and MERGES the
result into an existing --out file, so the artifact of record can be
refreshed incrementally.

Acceptance tracking: the packed path must be >= 1.5x faster per round at
1M x 50 synthetic rows on CPU (ISSUE 1); external_memory.rows must be
>= 4x config.rows (ISSUE 4).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Booster, DeviceDMatrix, ExternalDMatrix
from repro.core import booster as B
from repro.core import compress as C
from repro.core import histogram as H
from repro.core import metrics as M
from repro.core import objectives as O
from repro.core import predict as PR
from repro.core import quantile as Q
from repro.core import split as S
from repro.core import tree as T


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def synthetic(rows: int, features: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, features), dtype=np.float32)
    w = np.zeros(features, np.float32)
    k = max(3, features // 5)
    w[:k] = rng.standard_normal(k).astype(np.float32)
    y = ((x @ w + 0.3 * rng.standard_normal(rows)) > 0).astype(np.float32)
    return x, y


def phase_split(xj, yj, max_bins, max_depth, objective="binary:logistic"):
    rows = xj.shape[0]
    obj = O.OBJECTIVES[objective]

    t_quant_cuts = _time(lambda a: Q.compute_cuts(a, max_bins), xj)
    cuts = Q.compute_cuts(xj, max_bins)
    t_quantize = _time(lambda a: Q.quantize(a, cuts), xj)
    bins = Q.quantize(xj, cuts)
    bits = C.bits_needed(max_bins - 1)
    t_compress = _time(lambda b: C.pack(b, bits), bins)
    packed = C.pack(bins, bits)

    margins = jnp.zeros((rows, 1))
    t_grad = _time(lambda m: obj.grad(m, yj), margins)
    gh = obj.grad(margins, yj)[:, 0]

    pos = jnp.zeros(rows, jnp.int32)
    t_hist = _time(lambda b, g, p: H.build_histograms(b, g, p, 1, max_bins),
                   bins, gh, pos)
    t_hist_packed = _time(
        lambda pk, g, p: H.build_histograms_packed(
            pk, g, p, 1, max_bins, bits, rows),
        packed, gh, pos)
    hist = H.build_histograms(bins, gh, pos, 1, max_bins)
    parent = jnp.sum(gh, axis=0)[None]
    t_split = _time(lambda h, p: S.evaluate_splits(h, p), hist, parent)

    pb = C.PackedBins(packed=packed, bits=bits, n_rows=rows)
    tr = T.grow_tree(pb, gh, cuts, max_depth, max_bins)
    ens = PR.stack_trees([tr])
    t_pred = _time(
        lambda pk: PR.predict_binned_packed(
            ens, pk, bits, rows, max_bins - 1, max_depth),
        packed)
    t_tree = _time(lambda d, g: T.grow_tree(d, g, cuts, max_depth, max_bins),
                   pb, gh)

    return {
        "quantile_cuts_ms": t_quant_cuts * 1e3,
        "quantize_ms": t_quantize * 1e3,
        "compress_ms": t_compress * 1e3,
        "gradient_ms": t_grad * 1e3,
        "histogram_root_dense_ms": t_hist * 1e3,
        "histogram_root_packed_ms": t_hist_packed * 1e3,
        "split_eval_ms": t_split * 1e3,
        "predict_packed_ms": t_pred * 1e3,
        "full_tree_packed_ms": t_tree * 1e3,
    }


def _best(fn, *args, reps=3):
    """Best-of-N single-run timing (after one warmup run).

    Used for the kernels section's packed-vs-dense invariant: min-of-N is
    far less noise-sensitive than mean-of-N for a CI-enforced A<=B
    assertion on shared runners."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def kernels_split(xj, yj, max_bins, max_depth):
    """ISSUE 9 kernel section: the packed histogram builder vs the dense
    one across a tree-depth sweep (n_nodes in {1, 8, 32}), a buffer-depth
    sweep (1/2/4-deep scratch) of the privatised DMA-pipelined Pallas
    kernel in interpret mode, and the dispatched cut construction vs the
    pure-XLA reference. The depth sweep feeds the CI invariant: packed
    must be <= dense at EVERY benchmarked depth (best-of-N timings).
    """
    rows, features = xj.shape
    del yj
    cuts = Q.compute_cuts(xj, max_bins)
    bins = Q.quantize(xj, cuts)
    bits = C.bits_needed(max_bins - 1)
    packed = C.pack(bins, bits)
    rng = np.random.default_rng(0)
    gh = jnp.asarray(rng.standard_normal((rows, 2), dtype=np.float32))
    reps = 3 if rows > 200_000 else 5

    out = {}
    depth_sweep = {}
    max_ratio = 0.0
    dense_total = packed_total = 0.0
    for n_nodes in (1, 8, 32):
        pos = jnp.asarray(
            rng.integers(0, n_nodes, rows).astype(np.int32))
        t_dense = _best(
            lambda b, g, p, n=n_nodes: H.build_histograms(
                b, g, p, n, max_bins),
            bins, gh, pos, reps=reps)
        t_packed = _best(
            lambda pk, g, p, n=n_nodes: H.build_histograms_packed(
                pk, g, p, n, max_bins, bits, rows),
            packed, gh, pos, reps=reps)
        ratio = t_packed / t_dense
        depth_sweep[str(n_nodes)] = {
            "dense_s": t_dense, "packed_s": t_packed, "ratio": ratio,
        }
        max_ratio = max(max_ratio, ratio)
        dense_total += t_dense
        packed_total += t_packed
    out["depth_sweep"] = depth_sweep
    out["packed_vs_dense_max_ratio"] = max_ratio
    out["dense_total_s"] = dense_total
    out["packed_total_s"] = packed_total
    out["packed_vs_dense_total_ratio"] = packed_total / dense_total

    # Buffer-depth sweep of the privatised Pallas kernel. On CPU this runs
    # in interpret mode, so absolute numbers only characterise the DMA
    # schedule's overhead structure, not silicon throughput — a small
    # capped slice keeps it cheap.
    from repro.kernels import ops as KO

    cap_rows = min(rows, 4096)
    cap_f = min(features, 8)
    bins_s = bins[:cap_rows, :cap_f]
    packed_s = C.pack(bins_s, bits)
    gh_s = gh[:cap_rows]
    pos_s = jnp.asarray(rng.integers(0, 4, cap_rows).astype(np.int32))
    sweep = {}
    for depth in (1, 2, 4):
        t = _best(
            lambda pk, g, p, d=depth: KO.histogram_private_op(
                pk, g, p, 4, max_bins, bits, n_private=4, buffer_depth=d),
            packed_s, gh_s, pos_s, reps=3)
        sweep[str(depth)] = t
    out["buffer_depth_sweep_s"] = sweep
    out["buffer_sweep_rows"] = cap_rows
    out["buffer_sweep_mode"] = (
        "interpret" if jax.default_backend() == "cpu" else "compiled")

    # Cut construction: dispatched fast path (ops.compute_cuts_op) vs the
    # single-jit XLA reference it replaced.
    out["cuts_s"] = _time(
        lambda a: Q.compute_cuts(a, max_bins), xj, iters=1)
    out["cuts_reference_s"] = _time(
        lambda a: Q.compute_cuts_reference(a, max_bins), xj, iters=1)
    out["cuts_speedup"] = out["cuts_reference_s"] / out["cuts_s"]
    return out


def _make_seed_dense_round(cfg, obj, cuts, n_rows, bits):
    """The seed's round step, verbatim in spirit: full-matrix unpack up
    front, dense builders, per-tree Ensemble reconstruction for the margin
    update. jit'd per round and dispatched from Python."""
    mb = cfg.max_bins - 1

    @jax.jit
    def round_step(packed, margins, y):
        bins = C.unpack(packed, bits, n_rows)
        gh_all = obj.grad(margins, y)
        tr = T.grow_tree(
            bins, gh_all[:, 0, :], cuts, cfg.max_depth, cfg.max_bins,
            cfg.split_params,
            hist_subtraction=False,  # the seed had full builds every level
        )
        ens1 = PR.Ensemble(
            feature=tr.feature[None], split_bin=tr.split_bin[None],
            threshold=tr.threshold[None], default_left=tr.default_left[None],
            leaf_value=tr.leaf_value[None], is_leaf=tr.is_leaf[None],
            gain=tr.gain[None], n_classes=1, base_score=0.0,
        )
        delta = PR.predict_binned(ens1, bins, mb, cfg.max_depth)[:, 0]
        new_margins = margins.at[:, 0].add(cfg.learning_rate * delta)
        stacked = jax.tree.map(lambda a: a[None], tr)
        return stacked, new_margins

    return round_step


def round_loop(xj, yj, max_bins, max_depth, n_rounds):
    rows = xj.shape[0]
    cfg = B.BoosterConfig(
        n_rounds=n_rounds, max_depth=max_depth, max_bins=max_bins,
        objective="binary:logistic",
    )
    obj = O.OBJECTIVES[cfg.objective]
    cuts = Q.compute_cuts(xj, max_bins)
    bins = Q.quantize(xj, cuts)
    matrix = C.compress(bins, cuts, max_bins)
    pb = matrix.as_packed_bins()
    margins0 = jnp.zeros((rows, 1), jnp.float32)

    # --- seed-style dense path: python dispatch + unpack per round --------
    seed_round = _make_seed_dense_round(cfg, obj, cuts, rows, matrix.bits)
    _, warm = seed_round(matrix.packed, margins0, yj)  # compile
    jax.block_until_ready(warm)
    t0 = time.perf_counter()
    trees, margins = [], margins0
    for _ in range(n_rounds):
        stacked, margins = seed_round(matrix.packed, margins, yj)
        trees.append(stacked)
    all_trees = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)
    jax.block_until_ready((all_trees, margins))
    t_seed = time.perf_counter() - t0

    # --- scan-compiled packed-native path ---------------------------------
    train_fn = B._make_train_fn(cfg, obj, cuts, None, (), track_metric=False)
    out = train_fn(pb, margins0, yj, {})  # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = train_fn(pb, margins0, yj, {})
    jax.block_until_ready(out)
    t_packed = time.perf_counter() - t0

    dense_bins_bytes = rows * xj.shape[1] * 4
    return {
        "n_rounds": n_rounds,
        "seed_dense_per_round_s": t_seed / n_rounds,
        "packed_scan_per_round_s": t_packed / n_rounds,
        "speedup_packed_vs_seed_dense": t_seed / t_packed,
        "rows_per_sec_packed": rows * n_rounds / t_packed,
        "rows_per_sec_seed_dense": rows * n_rounds / t_seed,
        "resident_matrix_bytes_packed": matrix.nbytes_compressed(),
        "resident_matrix_bytes_dense_int32": dense_bins_bytes,
        "seed_transient_unpack_bytes_per_round": dense_bins_bytes,
        "packed_transient_unpack_bytes_per_round": 0,
        "compression_ratio_vs_fp32": matrix.compression_ratio(),
    }


RANK_ROWS_CAP = 4096  # rank:pairwise gradients are O(n^2) in the pair mask
OBJ_ROWS_CAP = 100_000  # keep the 7-objective sweep tractable at 1M-row runs


def objectives_split(xj, max_bins, max_depth, n_rounds):
    """Per-round time of the compiled scan per built-in objective, each
    with its default eval metric tracked in-scan — the grad + metric hot
    path of every objective lands in the perf trajectory."""
    rng = np.random.default_rng(1)
    out = {}
    packed = {}  # quantise ONCE per row cap, not once per objective
    for cap in {min(OBJ_ROWS_CAP, xj.shape[0]),
                min(RANK_ROWS_CAP, xj.shape[0])}:
        xr = xj[:cap]
        cuts = Q.compute_cuts(xr, max_bins)
        packed[cap] = (
            xr, cuts,
            C.compress(Q.quantize(xr, cuts), cuts, max_bins).as_packed_bins(),
        )
    for name in sorted(O.OBJECTIVES):
        obj = O.OBJECTIVES[name]
        cap = min(RANK_ROWS_CAP if name == "rank:pairwise" else OBJ_ROWS_CAP,
                  xj.shape[0])
        xr, cuts, pb = packed[cap]
        n = xr.shape[0]
        n_classes = 3 if name == "multi:softmax" else 1
        if name == "multi:softmax":
            y = rng.integers(0, n_classes, size=n)
        elif name == "binary:logistic":
            y = rng.random(n) < 0.5
        elif name == "count:poisson":
            y = rng.poisson(2.0, size=n)
        elif name == "rank:pairwise":
            y = rng.integers(0, 5, size=n)
        else:
            y = rng.standard_normal(n)
        yj = jnp.asarray(y.astype(np.float32))
        extra = {"quantile_alpha": 0.5}
        if name == "rank:pairwise":
            extra["group_ids"] = jnp.asarray(
                (np.arange(n) // 16).astype(np.int32))
        cfg = B.BoosterConfig(
            n_rounds=n_rounds, max_depth=max_depth, max_bins=max_bins,
            objective=name, n_classes=n_classes,
        )
        k = obj.n_outputs(n_classes)
        margins0 = jnp.zeros((n, k), jnp.float32)
        metric = M.get_metric(obj.default_metric)
        train_fn = B._make_train_fn(cfg, obj, cuts, None, (metric,),
                                    track_metric=True)
        warm = train_fn(pb, margins0, yj, extra)  # compile
        jax.block_until_ready(warm)
        t0 = time.perf_counter()
        res = train_fn(pb, margins0, yj, extra)
        jax.block_until_ready(res)
        out[name] = {
            "per_round_s": (time.perf_counter() - t0) / n_rounds,
            "rows": n,
            "trees_per_round": k,
            "metric": metric.name,
        }
    return out


def api_split(xj, yj, max_bins, max_depth, n_rounds):
    """Quantise-once vs fit, at the public-API level: DeviceDMatrix build
    time (cuts + quantise + compress, paid ONCE) reported separately from
    Booster.fit time, plus a second fit on the same matrix showing the
    amortisation (no re-quantisation). The build is additionally split
    into its three stages (cuts_s / quantize_s / compress_s) so the
    dominant term is attributable — cut construction used to be the
    whole-build blob's hidden 80% (ISSUE 9)."""
    t0 = time.perf_counter()
    dtrain = DeviceDMatrix(xj, label=yj, max_bins=max_bins)
    jax.block_until_ready(dtrain.matrix.packed)
    t_build = time.perf_counter() - t0

    # Stage split: the same three calls the constructor just ran, timed
    # individually (cold timings would double-count compilation; these are
    # warm, so they attribute the steady-state build cost).
    t_cuts = _time(lambda a: Q.compute_cuts(a, max_bins), xj, iters=1)
    cuts = Q.compute_cuts(xj, max_bins)
    t_quant = _time(lambda a: Q.quantize(a, cuts), xj, iters=1)
    bins = Q.quantize(xj, cuts)
    bits = C.bits_needed(max_bins - 1)
    t_comp = _time(lambda b: C.pack(b, bits), bins, iters=1)
    del cuts, bins

    def fit_once():
        bst = Booster(n_rounds=n_rounds, max_depth=max_depth,
                      max_bins=max_bins, objective="binary:logistic")
        t0 = time.perf_counter()
        bst.fit(dtrain)
        jax.block_until_ready(bst.margins)
        return time.perf_counter() - t0

    t_fit = fit_once()
    t_refit = fit_once()  # same DeviceDMatrix: quantisation fully amortised
    return {
        "dmatrix_build_s": t_build,
        "cuts_s": t_cuts,
        "quantize_s": t_quant,
        "compress_s": t_comp,
        "fit_s": t_fit,
        "refit_same_dmatrix_s": t_refit,
        "dmatrix_build_frac_of_first_fit": t_build / (t_build + t_fit),
        "dmatrix_nbytes": dtrain.nbytes,
    }


def _label_weights(features, seed=0):
    """The fixed seeded weight vector behind _external_batches labels —
    exposed so holdout sets can share it (same concept, fresh rows)."""
    wrng = np.random.default_rng(seed + 10_000)
    w = np.zeros(features, np.float32)
    k = max(3, features // 5)
    w[:k] = wrng.standard_normal(k).astype(np.float32)
    return w


def _external_batches(rows, features, chunk_rows, seed=0):
    """Synthetic data generated CHUNK BY CHUNK: the flat float matrix never
    exists anywhere (the point of the external-memory path). Labels come
    from a fixed seeded weight vector so every chunk is consistent."""
    w = _label_weights(features, seed)
    for i, start in enumerate(range(0, rows, chunk_rows)):
        m = min(chunk_rows, rows - start)
        rng = np.random.default_rng(seed + i)
        x = rng.standard_normal((m, features), dtype=np.float32)
        y = ((x @ w + 0.3 * rng.standard_normal(m)) > 0).astype(np.float32)
        yield x, y


OVERLAP_BENCH_ROWS_CAP = 24_000  # overlap/GOSS subsections (see below)
OVERLAP_BENCH_FEATURES_CAP = 10  # overlap subsection only (see below)


class _PagedStorageDMatrix(ExternalDMatrix):
    """Bench-only: ExternalDMatrix whose chunk loads model paged storage.

    The pipeline's synthetic chunk stack lives in host RAM, so a raw
    page-in is a memcpy — nothing for the async pager to hide on a CPU
    backend, where the pager thread and XLA compute share the same cores.
    Real out-of-core training pages chunks from NVMe/network/PCIe, paying
    a per-chunk latency that is independent of the compute cores. This
    subclass models that with a small GIL-releasing sleep per load (both
    sync and prefetching modes pay it identically), so the overlap
    subsection measures what the double-buffered pager actually buys:
    load latency hidden behind compute."""

    LATENCY_S = 0.002  # ~NVMe read + host staging for a small chunk

    def _load_chunk(self, i):
        time.sleep(self.LATENCY_S)
        return super()._load_chunk(i)


def external_memory_split(rows, features, max_bins, max_depth, n_rounds,
                          chunk_rows, single_shot_rows, sweep_rows=None):
    """ExternalDMatrix build + fit at `rows` (beyond single-shot capacity:
    >= 4x the largest single-shot config by default), plus a chunk-size
    sweep at the single-shot size showing the paging-granularity
    trade-off."""
    t0 = time.perf_counter()
    ext = ExternalDMatrix(
        _external_batches(rows, features, chunk_rows),
        chunk_rows=chunk_rows, max_bins=max_bins,
    )
    jax.block_until_ready(ext.packed_bins().packed)
    t_build = time.perf_counter() - t0

    def fit_once():
        bst = Booster(n_rounds=n_rounds, max_depth=max_depth,
                      max_bins=max_bins, objective="binary:logistic")
        t0 = time.perf_counter()
        bst.fit(ext)
        jax.block_until_ready(bst.margins)
        return time.perf_counter() - t0

    t_fit_cold = fit_once()  # includes chunk-scan program compilation
    t_fit = fit_once()  # steady state (compiled fn cached)

    out = {
        "rows": rows,
        "features": features,
        "chunk_rows": chunk_rows,
        "n_chunks": ext.n_chunks,
        "largest_single_shot_rows": single_shot_rows,
        "rows_vs_single_shot": rows / single_shot_rows,
        "dmatrix_build_s": t_build,
        "fit_cold_s": t_fit_cold,
        "fit_s": t_fit,
        "per_round_s": t_fit / n_rounds,
        "rows_per_sec": rows * n_rounds / t_fit,
        "host_packed_bytes": ext.nbytes_host,
        "device_stack_bytes": ext.nbytes_device,
        # what the in-memory path would have needed transiently on device
        "in_memory_transient_bytes_fp32_plus_bins": rows * features * 8,
        "chunk_dense_transient_bytes": chunk_rows * features * 8,
    }

    sweep_rows = sweep_rows or single_shot_rows
    sweep = {}
    for cr in (max(sweep_rows // 32, 1024), max(sweep_rows // 8, 4096),
               max(sweep_rows // 2, 16384)):
        e = ExternalDMatrix(
            _external_batches(sweep_rows, features, cr),
            chunk_rows=cr, max_bins=max_bins,
        )

        def sweep_fit():
            b = Booster(n_rounds=n_rounds, max_depth=max_depth,
                        max_bins=max_bins, objective="binary:logistic")
            t0 = time.perf_counter()
            b.fit(e)
            jax.block_until_ready(b.margins)
            return time.perf_counter() - t0

        sweep_fit()  # compile
        sweep[str(cr)] = {
            "n_chunks": e.n_chunks,
            "per_round_s": sweep_fit() / n_rounds,
        }
    out["chunk_size_sweep"] = {"rows": sweep_rows, "configs": sweep}

    # --- overlap: async double-buffered prefetch vs synchronous paging ---
    # Same fits, same work, different scheduling: paging="stream" runs the
    # eager per-chunk executor either with the background pager staging
    # chunk k+1 while chunk k computes (prefetch_chunks=2) or fully
    # synchronously (prefetch_chunks=0). The stack here lives in host RAM,
    # so raw page-in is nearly free; _PagedStorageDMatrix adds a small
    # GIL-releasing sleep per chunk load to model the storage latency
    # (NVMe read / PCIe transfer) that real out-of-core training pays —
    # the cost the pager thread exists to hide. Both modes pay the same
    # per-load latency; only the scheduling differs. best-of-3 min on both
    # sides so the check_regression invariant compares floors.
    # Capped (rows AND features): the invariant is RELATIVE (overlap <=
    # sync at the same simulated per-chunk latency), and growing
    # per-chunk compute only shrinks the latency fraction the pager can
    # hide — at the acceptance config (50 features) the 2 ms load is ~2%
    # of a round, below run-to-run noise, while the pager thread still
    # contends with XLA for the same cores. The subsection pins the
    # latency-bound regime the pager targets; full-scale shapes add
    # hours to the acceptance run without sharpening the signal.
    ov_rows = min(sweep_rows, OVERLAP_BENCH_ROWS_CAP)
    ov_feats = min(features, OVERLAP_BENCH_FEATURES_CAP)
    overlap = {"rows": ov_rows, "features": ov_feats,
               "simulated_load_latency_s": _PagedStorageDMatrix.LATENCY_S}
    for n_chunks in (8, 16):
        cr = max(ov_rows // n_chunks, 64)
        times = {}
        for mode, pf in (("overlap", 2), ("sync", 0)):
            e = _PagedStorageDMatrix(
                _external_batches(ov_rows, ov_feats, cr),
                chunk_rows=cr, max_bins=max_bins, paging="stream",
                prefetch_chunks=pf,
            )

            def stream_fit():
                b = Booster(n_rounds=n_rounds, max_depth=max_depth,
                            max_bins=max_bins, objective="binary:logistic")
                t0 = time.perf_counter()
                b.fit(e)
                jax.block_until_ready(b.margins)
                return time.perf_counter() - t0

            stream_fit()  # compile the per-chunk kernels
            times[mode] = min(stream_fit() for _ in range(3)) / n_rounds
        overlap[f"c{n_chunks}"] = {
            "chunk_rows": cr,
            "overlap_per_round_s": times["overlap"],
            "sync_per_round_s": times["sync"],
            "speedup": times["sync"] / times["overlap"],
        }
    out["overlap"] = overlap

    # --- GOSS through the streamed pager -------------------------------
    # rows_touched counts histogram-scatter rows (the work GOSS cuts);
    # chunks_paged shows chunk-skipping — chunks holding no selected rows
    # are never requested from the pager in the compacted builders.
    gs_rows = min(sweep_rows, OVERLAP_BENCH_ROWS_CAP)
    cr = max(gs_rows // 8, 64)
    hold = max(gs_rows // 4, 512)
    w = _label_weights(features)  # same concept as the training chunks
    hrng = np.random.default_rng(999_983)
    xv = hrng.standard_normal((hold, features)).astype(np.float32)
    yv = ((xv @ w + 0.3 * hrng.standard_normal(hold)) > 0).astype(np.float32)
    goss = {"rows": gs_rows, "top_rate": 0.1, "other_rate": 0.1}
    for name, kw in (
        ("full", {}),
        ("goss", {"sampling_method": "goss", "top_rate": 0.1,
                  "other_rate": 0.1}),
    ):
        e = ExternalDMatrix(
            _external_batches(gs_rows, features, cr),
            chunk_rows=cr, max_bins=max_bins, paging="stream",
        )

        def goss_fit():
            b = Booster(n_rounds=n_rounds, max_depth=max_depth,
                        max_bins=max_bins, objective="binary:logistic",
                        seed=0, **kw)
            t0 = time.perf_counter()
            b.fit(e)
            jax.block_until_ready(b.margins)
            return time.perf_counter() - t0, b

        goss_fit()  # compile
        dt, b = goss_fit()
        stats = e.stream_stats
        err = float(np.mean((np.asarray(b.predict(xv)) > 0.5) != yv))
        goss[name] = {
            "fit_s": dt,
            "per_round_s": dt / n_rounds,
            "rows_touched": stats.rows_touched,
            "chunks_paged": stats.chunks_paged,
            "holdout_error": err,
        }
    goss["rows_touched_ratio"] = (
        goss["goss"]["rows_touched"] / goss["full"]["rows_touched"]
    )
    goss["speedup"] = (
        goss["full"]["per_round_s"] / goss["goss"]["per_round_s"]
    )
    out["goss"] = goss
    return out


STOCH_ROWS_CAP = 250_000  # keep the 4-config stochastic sweep tractable


def stochastic_split(xj, yj, max_bins, max_depth, n_rounds):
    """Warm per-round fit time of the compiled stochastic scan: row
    subsampling rides the compacted-row histogram path, so per-round time
    should fall roughly with the subsample fraction; colsample_bytree only
    thins split evaluation (histograms are still built for every feature),
    so it stays near the deterministic baseline. The deterministic
    subsample=1.0 row doubles as the regression anchor for the section."""
    cap = min(STOCH_ROWS_CAP, xj.shape[0])
    xr, yr = xj[:cap], yj[:cap]
    dtrain = DeviceDMatrix(xr, label=yr, max_bins=max_bins)
    jax.block_until_ready(dtrain.matrix.packed)

    # Keys are dot-free so check_regression.py's dotted-path lookup works.
    configs = [
        ("subsample_100", {}),
        ("subsample_50", {"subsample": 0.5}),
        ("subsample_25", {"subsample": 0.25}),
        ("colsample_bytree_50", {"colsample_bytree": 0.5}),
    ]
    out = {"rows": cap}
    for name, kw in configs:
        def fit_once():
            bst = Booster(n_rounds=n_rounds, max_depth=max_depth,
                          max_bins=max_bins, objective="binary:logistic",
                          seed=0, **kw)
            t0 = time.perf_counter()
            bst.fit(dtrain)
            jax.block_until_ready(bst.margins)
            return time.perf_counter() - t0

        fit_once()  # compile
        out[name] = {"per_round_s": fit_once() / n_rounds, **kw}
    base = out["subsample_100"]["per_round_s"]
    for name, _ in configs[1:]:
        out[name]["speedup_vs_deterministic"] = base / out[name]["per_round_s"]
    return out


def resilience_split(xj, yj, max_bins, max_depth, n_rounds):
    """Checkpoint-write overhead per round: a fit snapshotting after EVERY
    round (checkpoint_every=1, the worst-case cadence — real deployments
    checkpoint every tens of rounds) vs the plain fit. Both variants run
    the chunked scan warm; the delta is the atomic write (msgpack encode +
    crc32 + fsync + rename) plus the per-chunk host sync."""
    import os
    import tempfile

    dtrain = DeviceDMatrix(xj, label=yj, max_bins=max_bins)
    jax.block_until_ready(dtrain.matrix.packed)

    def fit_once(ck=None, path=None):
        bst = Booster(n_rounds=n_rounds, max_depth=max_depth,
                      max_bins=max_bins, objective="binary:logistic")
        t0 = time.perf_counter()
        bst.fit(dtrain, checkpoint_every=ck, checkpoint_path=path)
        jax.block_until_ready(bst.margins)
        return time.perf_counter() - t0

    fit_once()  # compile the full-length scan
    t_plain = fit_once()
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "bench.ckpt")
        fit_once(ck=1, path=p)  # compile the length-1 chunk program
        t_ck = fit_once(ck=1, path=p)
        snapshot_bytes = os.path.getsize(p)
    per_plain = t_plain / n_rounds
    per_ck = t_ck / n_rounds
    return {
        "rows": int(xj.shape[0]),
        "checkpoint_every": 1,
        "plain_per_round_s": per_plain,
        "checkpointed_per_round_s": per_ck,
        "checkpoint_overhead_per_round_s": per_ck - per_plain,
        "checkpoint_overhead_frac": (per_ck - per_plain) / per_plain,
        "snapshot_bytes": int(snapshot_bytes),
    }


SERVE_ROWS_CAP = 50_000  # traversal throughput saturates well below 1M rows
SERVE_MIN_TREES = 512  # ISSUE 7 acceptance: fused wins on a >= 500-tree model


def serving_split(xj, yj, max_bins, max_depth, n_rounds):
    """Batch-inference timings (ISSUE 7): the fused all-trees-one-launch
    traversal vs the per-tree scan loop it replaced, on a >= 512-tree
    ensemble (a small trained model tiled out — traversal cost depends on
    tree count and depth, not on how the leaves were fitted), plus
    request-level p50/p99 latency through the shape-bucketed PredictEngine
    under mixed batch sizes. recompiles_after_warmup must stay 0: the
    bucket ladder, not the traffic, decides what gets compiled."""
    import dataclasses

    from repro.serve import PredictEngine
    from repro.serve import traversal as ST

    cap = min(SERVE_ROWS_CAP, xj.shape[0])
    xr, yr = xj[:cap], yj[:cap]
    dtrain = DeviceDMatrix(xr, label=yr, max_bins=max_bins)
    bst = Booster(n_rounds=16, max_depth=max_depth, max_bins=max_bins,
                  objective="binary:logistic").fit(dtrain)
    ens = bst.ensemble
    reps = -(-SERVE_MIN_TREES // ens.feature.shape[0])
    if reps > 1:
        tiled = {
            f: jnp.tile(getattr(ens, f),
                        (reps,) + (1,) * (getattr(ens, f).ndim - 1))
            for f in PR._ENSEMBLE_ARRAY_FIELDS
        }
        ens = dataclasses.replace(ens, **tiled)
    n_trees = int(ens.feature.shape[0])

    pb = dtrain.matrix.as_packed_bins()
    mb = max_bins - 1

    t_loop_raw = _time(
        lambda e, a: PR.predict_raw(e, a, max_depth), ens, xr)
    t_fused_raw = _time(
        lambda e, a: ST.predict_margins_fused(e, a, max_depth), ens, xr)
    t_loop_packed = _time(
        lambda e, p: PR.predict_binned_packed(e, p, pb.bits, cap, mb,
                                              max_depth), ens, pb.packed)
    t_fused_packed = _time(
        lambda e, p: ST.predict_margins_fused_packed(e, p, pb.bits, cap, mb,
                                                     max_depth),
        ens, pb.packed)

    # Request-level latency: mixed batch sizes through the bucketed engine,
    # serving the tiled 512-tree ensemble.
    bst.ensemble = ens
    engine = PredictEngine(bst, buckets=(16, 64, 256, 1024, 4096))
    engine.warmup()
    traces_after_warmup = engine.trace_count
    engine.reset_stats()
    x_np = np.asarray(xr)
    sizes = [1, 7, 16, 33, 100, 250, 777, 1024, 3000, 4096] * 3
    off = 0
    for n in sizes:
        engine.predict(x_np[off:off + n])
        off = (off + n) % max(cap - 4096, 1)
    stats = engine.stats()
    stats["recompiles_after_warmup"] = (
        engine.trace_count - traces_after_warmup
    )

    return {
        "rows": cap,
        "n_trees": n_trees,
        "max_depth": max_depth,
        "tree_loop_raw_s": t_loop_raw,
        "fused_raw_s": t_fused_raw,
        "fused_speedup_raw": t_loop_raw / t_fused_raw,
        "tree_loop_packed_s": t_loop_packed,
        "fused_packed_s": t_fused_packed,
        "fused_speedup_packed": t_loop_packed / t_fused_packed,
        "engine": stats,
    }


SECTIONS = ("phases", "api", "kernels", "round_loop", "objectives",
            "external_memory", "stochastic", "resilience", "serving")


def run(rows, features, max_bins, max_depth, n_rounds,
        sections=SECTIONS, external_rows=None, chunk_rows=131_072):
    result = {
        "config": {
            "rows": rows, "features": features, "max_bins": max_bins,
            "max_depth": max_depth, "backend": jax.default_backend(),
        },
    }
    in_memory = [s for s in sections if s != "external_memory"]
    if in_memory:
        x, y = synthetic(rows, features)
        xj, yj = jnp.asarray(x), jnp.asarray(y)
        if "phases" in sections:
            result["phases"] = phase_split(xj, yj, max_bins, max_depth)
        if "api" in sections:
            result["api"] = api_split(xj, yj, max_bins, max_depth, n_rounds)
        if "kernels" in sections:
            result["kernels"] = kernels_split(xj, yj, max_bins, max_depth)
        if "round_loop" in sections:
            result["round_loop"] = round_loop(xj, yj, max_bins, max_depth,
                                              n_rounds)
        if "objectives" in sections:
            result["objectives"] = objectives_split(xj, max_bins, max_depth,
                                                    n_rounds)
        if "stochastic" in sections:
            result["stochastic"] = stochastic_split(xj, yj, max_bins,
                                                    max_depth, n_rounds)
        if "resilience" in sections:
            result["resilience"] = resilience_split(xj, yj, max_bins,
                                                    max_depth, n_rounds)
        if "serving" in sections:
            result["serving"] = serving_split(xj, yj, max_bins, max_depth,
                                              n_rounds)
        del xj, yj, x, y
    if "external_memory" in sections:
        ext_rows = external_rows or 4 * rows
        result["external_memory"] = external_memory_split(
            ext_rows, features, max_bins, max_depth, n_rounds,
            min(chunk_rows, max(ext_rows // 3, 1)), rows,
        )
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000)
    ap.add_argument("--features", type=int, default=50)
    ap.add_argument("--max-bins", type=int, default=256)
    ap.add_argument("--max-depth", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--out", type=str, default="BENCH_pipeline.json")
    ap.add_argument("--sections", type=str, default="all",
                    help="comma list of sections to run "
                         f"({','.join(SECTIONS)}); others are kept from an "
                         "existing --out file")
    ap.add_argument("--external-rows", type=int, default=None,
                    help="external_memory row count (default 4 * --rows)")
    ap.add_argument("--chunk-rows", type=int, default=131_072,
                    help="external_memory chunk size (clamped so the run "
                         "always uses >= 3 chunks); 128k wins over the old "
                         "256k default in the chunk-size sweep")
    args = ap.parse_args(argv)

    sections = (
        SECTIONS if args.sections == "all"
        else tuple(s.strip() for s in args.sections.split(","))
    )
    unknown = set(sections) - set(SECTIONS)
    if unknown:
        ap.error(f"unknown sections: {sorted(unknown)}")

    r = run(args.rows, args.features, args.max_bins, args.max_depth,
            args.rounds, sections=sections, external_rows=args.external_rows,
            chunk_rows=args.chunk_rows)

    # Partial runs refresh only their sections in the artifact of record.
    # The top-level config describes the IN-MEMORY sections (external_memory
    # self-describes its rows/features), so an external-only refresh must
    # not clobber it with this run's --rows.
    if set(sections) != set(SECTIONS):
        try:
            with open(args.out) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
        cfg_new = r.pop("config")
        in_memory_refreshed = any(s != "external_memory" for s in sections)
        if "config" not in merged:
            merged["config"] = cfg_new
        elif in_memory_refreshed and merged["config"] != cfg_new:
            print("warning: in-memory sections refreshed at a different "
                  "config; updating config (sections kept from the old file "
                  "may be stale)")
            merged["config"] = cfg_new
        merged.update(r)
        r = merged

    print(f"# Pipeline ({args.rows}x{args.features}, depth {args.max_depth})")
    for k, v in r.get("phases", {}).items():
        print(f"{k},{v:.2f}")
    for k, v in r.get("api", {}).items():
        print(f"{k},{v}")
    for k, v in r.get("kernels", {}).items():
        print(f"kernels_{k},{v}")
    for k, v in r.get("round_loop", {}).items():
        print(f"{k},{v}")
    for k, v in r.get("objectives", {}).items():
        print(f"objective_{k}_per_round_s,{v['per_round_s']:.4f}")
    for k, v in r.get("stochastic", {}).items():
        if isinstance(v, dict):
            print(f"stochastic_{k}_per_round_s,{v['per_round_s']:.4f}")
    for k, v in r.get("external_memory", {}).items():
        print(f"external_{k},{v}")
    for k, v in r.get("serving", {}).items():
        print(f"serving_{k},{v}")
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
    print(f"wrote {args.out}")
    return r


if __name__ == "__main__":
    main()
