"""Figure 1 phase split: where does a boosting round spend its time?

Phases timed separately (all on-device, jit'd): quantise, compress,
gradient evaluation, histogram build, split evaluation, prediction update.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import histogram as H
from repro.core import objectives as O
from repro.core import predict as PR
from repro.core import quantile as Q
from repro.core import split as S
from repro.core import tree as T
from repro.data import make_dataset


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(rows=50_000, max_bins=256, max_depth=6):
    x, y, spec = make_dataset("higgs", n_rows=rows)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    obj = O.OBJECTIVES[spec.objective]

    t_quant_cuts = _time(lambda a: Q.compute_cuts(a, max_bins), xj)
    cuts = Q.compute_cuts(xj, max_bins)
    t_quantize = _time(lambda a: Q.quantize(a, cuts), xj)
    bins = Q.quantize(xj, cuts)
    bits = C.bits_needed(max_bins - 1)
    t_compress = _time(lambda b: C.pack(b, bits), bins)

    margins = jnp.zeros((rows, 1))
    t_grad = _time(lambda m: obj.grad(m, yj), margins)
    gh = obj.grad(margins, yj)[:, 0]

    pos = jnp.zeros(rows, jnp.int32)
    t_hist = _time(lambda b, g, p: H.build_histograms(b, g, p, 1, max_bins),
                   bins, gh, pos)
    hist = H.build_histograms(bins, gh, pos, 1, max_bins)
    parent = jnp.sum(gh, axis=0)[None]
    t_split = _time(lambda h, p: S.evaluate_splits(h, p), hist, parent)

    tr = T.grow_tree(bins, gh, cuts, max_depth, max_bins)
    ens = PR.stack_trees([tr])
    t_pred = _time(lambda b: PR.predict_binned(ens, b, max_bins - 1, max_depth),
                   bins)
    t_tree = _time(lambda b, g: T.grow_tree(b, g, cuts, max_depth, max_bins),
                   bins, gh)

    return {
        "quantile_cuts_s": t_quant_cuts,
        "quantize_s": t_quantize,
        "compress_s": t_compress,
        "gradient_s": t_grad,
        "histogram_root_s": t_hist,
        "split_eval_s": t_split,
        "predict_s": t_pred,
        "full_tree_s": t_tree,
    }


def main():
    r = run()
    print("# Pipeline phase split (higgs-shaped, 50k rows, depth 6)")
    for k, v in r.items():
        print(f"{k},{v*1e3:.2f}ms")
    return r


if __name__ == "__main__":
    main()
