"""Markdown diff of two BENCH_pipeline.json artifacts (perf trend step).

CI downloads the BENCH artifact of the last successful main-branch run,
diffs it against the artifact this run just produced, and appends the
rendered markdown to $GITHUB_STEP_SUMMARY — so every PR shows its perf
delta without anyone re-running benchmarks locally.

Numeric leaves are flattened to dotted paths (the same addressing scheme
check_regression.py uses) and joined on path. Deltas beyond +/-10% get a
direction marker so regressions stand out in the table; paths present on
only one side are listed separately (new/removed metrics, e.g. a section
added by the current PR).

Usage:
    python benchmarks/diff_bench.py OLD.json NEW.json [--threshold 0.10]

Exit code is always 0 — the trend step is informational; hard gating is
check_regression.py's job.
"""
from __future__ import annotations

import argparse
import json
import sys


def flatten(tree, prefix=""):
    """Dotted-path -> numeric leaf map (bools and strings are skipped)."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(tree, (int, float)) and not isinstance(tree, bool):
        out[prefix[:-1]] = float(tree)
    return out


def fmt(v: float) -> str:
    return f"{v:.4g}"


def render(old: dict, new: dict, threshold: float) -> str:
    fo, fn = flatten(old), flatten(new)
    shared = sorted(set(fo) & set(fn))
    added = sorted(set(fn) - set(fo))
    removed = sorted(set(fo) - set(fn))

    lines = ["## Benchmark trend", ""]
    if shared:
        lines += [
            "| metric | previous | current | delta |",
            "|---|---:|---:|---:|",
        ]
        for path in shared:
            o, n = fo[path], fn[path]
            if o == 0.0:
                delta = "n/a" if n == 0.0 else "+inf"
                mark = ""
            else:
                rel = n / o - 1.0
                delta = f"{rel:+.1%}"
                mark = (
                    " :small_red_triangle:" if rel > threshold
                    else " :white_check_mark:" if rel < -threshold
                    else ""
                )
            lines.append(
                f"| `{path}` | {fmt(o)} | {fmt(n)} | {delta}{mark} |")
    else:
        lines.append("_No shared numeric metrics between the two files._")
    if added:
        lines += ["", f"**New metrics ({len(added)}):** "
                  + ", ".join(f"`{p}`" for p in added[:40])
                  + (" …" if len(added) > 40 else "")]
    if removed:
        lines += ["", f"**Removed metrics ({len(removed)}):** "
                  + ", ".join(f"`{p}`" for p in removed[:40])
                  + (" …" if len(removed) > 40 else "")]
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", help="previous BENCH json (e.g. main artifact)")
    ap.add_argument("new", help="current BENCH json")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative delta beyond which a row is flagged")
    args = ap.parse_args(argv)

    try:
        with open(args.old) as f:
            old = json.load(f)
    except (OSError, ValueError) as e:
        print(f"## Benchmark trend\n\n_No previous benchmark artifact "
              f"available ({e.__class__.__name__}); nothing to diff._\n")
        return 0
    with open(args.new) as f:
        new = json.load(f)
    print(render(old, new, args.threshold))
    return 0


if __name__ == "__main__":
    sys.exit(main())
