"""Ablations over the paper's tunable design choices.

1. Growth strategy (§2.3: "reconfigurable to prioritise expanding nodes
   with a higher reduction in the objective function or nodes closer to
   the root"): depthwise vs lossguide at equal leaf budget.
2. Quantisation granularity (§2.1/2.2): max_bins 64/128/256 — accuracy vs
   compressed-matrix bits (the paper's accuracy-vs-memory trade).
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import BoosterConfig, predict_margins, train
from repro.core import metrics as M
from repro.core import objectives as O
from repro.data import make_dataset


def run(rows: int = 8000, rounds: int = 30):
    x, y, spec = make_dataset("higgs", n_rows=rows)
    n_tr = int(0.8 * rows)
    xt, yt, xv, yv = x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]
    metric = M.get_metric(O.get_objective(spec.objective).default_metric)
    out = []

    def fit(cfg, tag):
        t0 = time.perf_counter()
        st = train(xt, yt, cfg)
        dt = time.perf_counter() - t0
        mv = predict_margins(st.ensemble, jnp.asarray(xv), cfg.max_depth)
        acc = float(metric.fn(mv, jnp.asarray(yv)))
        out.append((tag, dt, acc, st.matrix.bits))

    # growth strategy at equal leaf budget (depth 5 = up to 32 leaves vs
    # lossguide depth 8 with 32-leaf budget)
    fit(BoosterConfig(n_rounds=rounds, max_depth=5, objective=spec.objective,
                      max_bins=256), "depthwise-d5")
    fit(BoosterConfig(n_rounds=rounds, max_depth=8, growth="lossguide",
                      max_leaves=32, objective=spec.objective, max_bins=256),
        "lossguide-32leaf")

    # quantisation granularity
    for b in (64, 128, 256):
        fit(BoosterConfig(n_rounds=rounds, max_depth=5,
                          objective=spec.objective, max_bins=b), f"bins-{b}")
    return out


def main():
    rows = run()
    print("# Ablations (higgs-shaped): config,time_s,valid_accuracy,matrix_bits")
    for tag, dt, acc, bits in rows:
        print(f"{tag},{dt:.2f},{acc:.4f},{bits}")
    return rows


if __name__ == "__main__":
    main()
