"""Reference GBDT baselines for Table 2 comparisons.

The paper compares against LightGBM/CatBoost CPU+GPU; offline we implement
the two algorithmically-relevant baselines ourselves:

  * cpu_hist  — pure-numpy histogram GBDT (same quantised algorithm as the
                paper's xgb-cpu-hist row: one core, no JAX/XLA),
  * exact     — exact greedy split enumeration over sorted feature values
                (the classic pre-histogram xgboost method; the paper's
                motivation for quantisation is beating exactly this).

Both share the booster loop; only FindBestSplit differs. Binary logistic +
squared error + softmax supported (enough for the six datasets).
"""
from __future__ import annotations

import numpy as np


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def _grad(objective, margins, y):
    if objective == "reg:squarederror":
        return margins[:, 0] - y, np.ones_like(y)
    if objective == "binary:logistic":
        p = _sigmoid(margins[:, 0])
        return p - y, p * (1 - p)
    raise ValueError(objective)


class _Node:
    __slots__ = ("feature", "thr", "left", "right", "value", "default_left")

    def __init__(self):
        self.feature = -1
        self.thr = 0.0
        self.left = self.right = None
        self.value = 0.0
        self.default_left = False


def _best_split_hist(x, g, h, idx, max_bins, cuts, bins, lam, mcw):
    best = (1e-12, -1, 0.0, False)
    g_tot, h_tot = g[idx].sum(), h[idx].sum()
    parent = g_tot**2 / (h_tot + lam)
    for f in range(x.shape[1]):
        b = bins[idx, f]
        gb = np.bincount(b, weights=g[idx], minlength=max_bins)
        hb = np.bincount(b, weights=h[idx], minlength=max_bins)
        gl = np.cumsum(gb[:-1])[:-1]
        hl = np.cumsum(hb[:-1])[:-1]
        gm, hm = gb[-1], hb[-1]
        for add_miss in (0, 1):
            gl2, hl2 = gl + add_miss * gm, hl + add_miss * hm
            gr2, hr2 = g_tot - gl2, h_tot - hl2
            ok = (hl2 >= mcw) & (hr2 >= mcw)
            gain = 0.5 * (gl2**2 / (hl2 + lam) + gr2**2 / (hr2 + lam) - parent)
            gain = np.where(ok, gain, -np.inf)
            j = int(np.argmax(gain))
            if gain[j] > best[0]:
                best = (float(gain[j]), f, float(cuts[f][j]) if j < len(cuts[f]) else np.inf,
                        bool(add_miss))
    return best


def _best_split_exact(x, g, h, idx, lam, mcw):
    best = (1e-12, -1, 0.0, False)
    g_tot, h_tot = g[idx].sum(), h[idx].sum()
    parent = g_tot**2 / (h_tot + lam)
    for f in range(x.shape[1]):
        v = x[idx, f]
        finite = ~np.isnan(v)
        order = np.argsort(v[finite])
        vs = v[finite][order]
        gs, hs = g[idx][finite][order], h[idx][finite][order]
        gm, hm = g[idx][~finite].sum(), h[idx][~finite].sum()
        glc, hlc = np.cumsum(gs)[:-1], np.cumsum(hs)[:-1]
        valid = vs[:-1] < vs[1:]  # split between distinct values
        for add_miss in (0, 1):
            gl = glc + add_miss * gm
            hl = hlc + add_miss * hm
            gr, hr = g_tot - gl, h_tot - hl
            ok = valid & (hl >= mcw) & (hr >= mcw)
            gain = 0.5 * (gl**2 / (hl + lam) + gr**2 / (hr + lam) - parent)
            gain = np.where(ok, gain, -np.inf)
            if len(gain) == 0:
                continue
            j = int(np.argmax(gain))
            if gain[j] > best[0]:
                best = (float(gain[j]), f, float((vs[j] + vs[j + 1]) / 2),
                        bool(add_miss))
    return best


def _grow(x, g, h, idx, depth, max_depth, lam, mcw, splitter):
    node = _Node()
    if depth >= max_depth or len(idx) < 2:
        node.value = -g[idx].sum() / (h[idx].sum() + lam)
        return node
    gain, f, thr, dl = splitter(idx)
    if f < 0 or gain <= 0:
        node.value = -g[idx].sum() / (h[idx].sum() + lam)
        return node
    v = x[idx, f]
    miss = np.isnan(v)
    left = (v <= thr) & ~miss
    if dl:
        left |= miss
    node.feature, node.thr, node.default_left = f, thr, dl
    node.left = _grow(x, g, h, idx[left], depth + 1, max_depth, lam, mcw, splitter)
    node.right = _grow(x, g, h, idx[~left], depth + 1, max_depth, lam, mcw, splitter)
    return node


def _predict_tree(node, x):
    out = np.empty(len(x))
    stack = [(node, np.arange(len(x)))]
    while stack:
        nd, idx = stack.pop()
        if nd.feature < 0:
            out[idx] = nd.value
            continue
        v = x[idx, nd.feature]
        miss = np.isnan(v)
        left = (v <= nd.thr) & ~miss
        if nd.default_left:
            left |= miss
        stack.append((nd.left, idx[left]))
        stack.append((nd.right, idx[~left]))
    return out


def train_numpy(x, y, *, method="hist", n_rounds=20, max_depth=6, lr=0.3,
                max_bins=256, objective="binary:logistic", lam=1.0, mcw=1.0):
    """Returns (predict_fn, margins) after training."""
    n = len(x)
    margins = np.zeros((n, 1), np.float64)
    if objective == "reg:squarederror":
        margins[:] = y.mean()

    if method == "hist":
        cuts, bins = [], np.empty(x.shape, np.int32)
        nvb = max_bins - 1
        for f in range(x.shape[1]):
            col = x[:, f]
            finite = col[~np.isnan(col)]
            qs = np.quantile(finite, np.linspace(0, 1, nvb + 1)[1:-1]) if len(finite) else np.array([])
            qs = np.unique(qs)
            cuts.append(qs)
            b = np.searchsorted(qs, col, side="left")
            bins[:, f] = np.where(np.isnan(col), max_bins - 1, b)

    trees = []
    for _ in range(n_rounds):
        g, h = _grad(objective, margins, y)
        if method == "hist":
            splitter = lambda idx: _best_split_hist(x, g, h, idx, max_bins, cuts, bins, lam, mcw)
        else:
            splitter = lambda idx: _best_split_exact(x, g, h, idx, lam, mcw)
        root = _grow(x, g, h, np.arange(n), 0, max_depth, lam, mcw, splitter)
        margins[:, 0] += lr * _predict_tree(root, x)
        trees.append(root)

    def predict(xq):
        m = np.zeros(len(xq))
        if objective == "reg:squarederror":
            m[:] = y.mean()
        for t in trees:
            m += lr * _predict_tree(t, xq)
        return m

    return predict, margins
