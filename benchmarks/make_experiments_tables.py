"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run JSONs (final = experiments/dryrun, baseline = experiments/dryrun_baseline)."""
from __future__ import annotations

import glob
import json
import os
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "phi-3-vision-4.2b", "zamba2-7b", "mamba2-2.7b", "minicpm3-4b", "glm4-9b",
    "yi-6b", "seamless-m4t-medium", "llama4-maverick-400b-a17b",
    "stablelm-12b", "llama4-scout-17b-a16e",
]


def load(d):
    recs = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        if "gbdt" in p:
            continue
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.1f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def roofline_table(recs, mesh):
    print(f"\n#### Mesh {mesh}\n")
    print("| arch | shape | dominant | compute_s | memory_s | collective_s | "
          "model TFLOPs/dev | useful ratio | peak HBM/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | SKIP | — | — | — | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | — | — | — | — | — | — |")
                continue
            rf = r["roofline"]
            mem = r["memory_analysis"]["peak_hbm_bytes_est"]
            print(
                f"| {arch} | {shape} | **{rf['dominant']}** "
                f"| {rf['compute_s']:.2e} | {rf['memory_s']:.2e} "
                f"| {rf['collective_s']:.2e} "
                f"| {rf['model_flops_per_device']/1e12:.2f} "
                f"| {rf['useful_flops_ratio']:.2f} | {fmt_bytes(mem)} |"
            )


def dryrun_summary(recs):
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    err = sum(1 for r in recs.values() if r["status"] not in ("ok", "skipped"))
    print(f"\nruns: {ok} ok, {skip} skipped (documented), {err} errors\n")
    print("| arch | shape | mesh | compile_s | params | active | arg bytes/dev | temp bytes/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None or r["status"] != "ok":
                    continue
                m = r["memory_analysis"]
                print(
                    f"| {arch} | {shape} | {mesh} | {r['compile_s']:.1f} "
                    f"| {r['params_total']/1e9:.2f}B | {r['params_active']/1e9:.2f}B "
                    f"| {fmt_bytes(m['argument_bytes'])} | {fmt_bytes(m['temp_bytes'])} |"
                )


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if which == "roofline":
        roofline_table(recs, "pod16x16")
        roofline_table(recs, "pod2x16x16")
    else:
        dryrun_summary(recs)
