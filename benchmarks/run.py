"""Benchmark entrypoint: one section per paper table/figure.

  python -m benchmarks.run               # all (reduced sizes for 1-core CPU)
  python -m benchmarks.run --only table2 compression
  python -m benchmarks.run --rows 20000  # bigger table2

Prints CSV-ish lines per section; EXPERIMENTS.md cites these outputs.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    choices=["table2", "compression", "fig2", "kernels",
                             "pipeline", "roofline", "ablations"])
    ap.add_argument("--rows", type=int, default=8000)
    args = ap.parse_args()
    sections = args.only or ["compression", "kernels", "pipeline", "table2",
                             "fig2", "ablations", "roofline"]

    t0 = time.perf_counter()
    for sec in sections:
        print(f"\n=== {sec} ===", flush=True)
        if sec == "table2":
            from benchmarks import table2
            table2.main(rows=args.rows)
        elif sec == "compression":
            from benchmarks import compression
            compression.main()
        elif sec == "fig2":
            from benchmarks import fig2_scaling
            fig2_scaling.main()
        elif sec == "kernels":
            from benchmarks import kernels
            kernels.main()
        elif sec == "pipeline":
            from benchmarks import pipeline
            # Reduced size (pipeline.main's own defaults are the 1M-row
            # acceptance run), and write to /tmp so the committed
            # BENCH_pipeline.json artifact of record is never clobbered.
            pipeline.main(["--rows", str(max(args.rows, 20_000)),
                           "--features", "20",
                           "--out", "/tmp/BENCH_pipeline.json"])
        elif sec == "ablations":
            from benchmarks import ablations
            ablations.main()
        elif sec == "roofline":
            from benchmarks import roofline
            roofline.main()
    print(f"\n# total benchmark time: {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
