"""Paper §2.2 + §3 memory claims.

1. Compression ratio per dataset: quantised+bit-packed vs fp32 (paper: >=4x).
2. The airline claim: "After compression and distributing training rows
   between 8 GPUs, we only require 600MB per GPU to store the entire
   matrix" — 115M rows x 13 features. We verify the arithmetic at FULL
   scale analytically and at reduced scale empirically (ratios are
   row-count independent).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compress as C
from repro.core import quantile as Q
from repro.data import DATASETS, make_dataset


def empirical_ratios(rows: int = 4000):
    out = []
    for name, spec in DATASETS.items():
        x, _, _ = make_dataset(name, n_rows=min(rows, spec.n_rows))
        cuts = Q.compute_cuts(jnp.asarray(x), 256)
        bins = Q.quantize(jnp.asarray(x), cuts)
        cm = C.compress(bins, cuts, 256)
        out.append((name, cm.bits, cm.compression_ratio()))
    return out


def airline_full_scale():
    """Analytic check of the 600 MB/GPU claim at the paper's exact shape."""
    rows, cols, gpus = 115_000_000, 13, 8
    fp32 = rows * cols * 4
    bits = 8  # 256 bins
    spw = 32 // bits
    words_per_gpu = cols * ((rows // gpus + spw - 1) // spw)
    packed_per_gpu = words_per_gpu * 4
    return {
        "fp32_total_GB": fp32 / 1e9,
        "packed_per_gpu_MB": packed_per_gpu / 1e6,
        "paper_claim_MB": 600,
        "ratio_vs_fp32": fp32 / (packed_per_gpu * gpus),
    }


def main():
    print("# Compression (paper >=4x claim)")
    print("dataset,bits,ratio_vs_fp32")
    for name, bits, ratio in empirical_ratios():
        print(f"{name},{bits},{ratio:.2f}")
    a = airline_full_scale()
    print("# Airline 115M x 13 across 8 devices (paper: 600 MB/GPU)")
    print(f"airline_packed_per_device_MB,{a['packed_per_gpu_MB']:.0f},claim={a['paper_claim_MB']}")
    print(f"airline_fp32_total_GB,{a['fp32_total_GB']:.1f},ratio={a['ratio_vs_fp32']:.1f}x")
    return a


if __name__ == "__main__":
    main()
