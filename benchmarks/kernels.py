"""Kernel microbenchmarks: Pallas (interpret mode on CPU — correctness
path; TPU is the perf target) vs the XLA reference path.

The interesting derived number on this container is the XLA-path histogram
throughput (rows*features/s) since interpret-mode Pallas timing is a Python
emulation. On TPU the kernel's roofline is reported in EXPERIMENTS.md §4.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as C
from repro.core import histogram as H
from repro.kernels import ops as KO


def _bench(fn, *args, iters=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(n=100_000, f=16, max_bins=256, n_nodes=8):
    rng = np.random.default_rng(0)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_nodes, size=n), jnp.int32)
    bits = C.bits_needed(max_bins - 1)
    packed = C.pack(bins, bits)

    t_xla = _bench(
        lambda b, g, p: H.build_histograms(b, g, p, n_nodes, max_bins),
        bins, gh, pos,
    )
    t_unpack = _bench(lambda q: C.unpack(q, bits, n), packed)

    # Pallas interpret-mode correctness spot check (timing not meaningful)
    small = 4096
    t0 = time.perf_counter()
    hk = KO.histogram_packed_op(packed[:, : small // (32 // bits)],
                                gh[:small], pos[:small], n_nodes, max_bins, bits)
    jax.block_until_ready(hk)
    t_pallas_interp = time.perf_counter() - t0

    return {
        "hist_xla_s": t_xla,
        "hist_xla_rows_per_s": n / t_xla,
        "unpack_s": t_unpack,
        "unpack_GBps": bins.size * 4 / t_unpack / 1e9,
        "pallas_interpret_4k_s": t_pallas_interp,
    }


def main():
    r = run()
    print("# Kernel microbench (CPU; Pallas interpret = correctness only)")
    for k, v in r.items():
        print(f"{k},{v:.4g}")
    return r


if __name__ == "__main__":
    main()
