"""Paper Table 2: time + accuracy across the six datasets.

Offline container: datasets are the paper-shaped synthetic generators at a
reduced row count (--rows, --full for the paper's sizes) and competitors
are our own numpy cpu-hist and exact-greedy baselines (DESIGN.md §8).
Columns mirror the paper: Time(s) and RMSE/Accuracy per dataset.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import BoosterConfig, predict_margins, train
from repro.core import metrics as M
from repro.core import objectives as O
from repro.data import DATASETS, make_dataset
from benchmarks.baselines import train_numpy

DEFAULT_ROWS = 8_000
ROUNDS = 40  # paper uses 500; scaled for 1-core CPU


def _metric(spec, margins, y):
    m = M.get_metric(O.get_objective(spec.objective).default_metric)
    return m.name, float(m.fn(jnp.asarray(margins), jnp.asarray(y)))


def run(rows: int = DEFAULT_ROWS, rounds: int = ROUNDS, datasets=None,
        include_exact: bool = True):
    results = []
    for name in datasets or list(DATASETS):
        spec = DATASETS[name]
        n = min(rows, spec.n_rows)
        f_cap = 128  # cap bosch's 968 cols for CPU run time
        x, y, _ = make_dataset(name, n_rows=n)
        x = x[:, :f_cap]
        n_tr = int(0.8 * n)
        xt, yt, xv, yv = x[:n_tr], y[:n_tr], x[n_tr:], y[n_tr:]

        # ---- ours (jax-hist, the paper's algorithm) ----------------------
        cfg = BoosterConfig(
            n_rounds=rounds, max_depth=6, max_bins=256,
            objective=spec.objective, n_classes=spec.n_classes,
        )
        t0 = time.perf_counter()
        st = train(xt, yt, cfg)
        jnp.asarray(st.margins).block_until_ready()
        t_ours = time.perf_counter() - t0
        mv = predict_margins(st.ensemble, jnp.asarray(xv), cfg.max_depth)
        mname, m_ours = _metric(spec, mv, yv)
        results.append((name, "jax-hist", t_ours, mname, m_ours))

        # ---- numpy cpu-hist ----------------------------------------------
        if spec.objective in ("binary:logistic", "reg:squarederror"):
            t0 = time.perf_counter()
            pred, _ = train_numpy(xt, yt.astype(np.float64), method="hist",
                                  n_rounds=rounds, max_depth=6,
                                  objective=spec.objective)
            t_hist = time.perf_counter() - t0
            mv = pred(xv)[:, None]
            _, m_hist = _metric(spec, mv, yv)
            results.append((name, "cpu-hist", t_hist, mname, m_hist))

            if include_exact:
                n_ex = min(n_tr, 3000)  # exact greedy is O(n log n * F * 2^d)
                t0 = time.perf_counter()
                pred, _ = train_numpy(xt[:n_ex], yt[:n_ex].astype(np.float64),
                                      method="exact", n_rounds=max(rounds // 4, 5),
                                      max_depth=6, objective=spec.objective)
                t_ex = (time.perf_counter() - t0)
                mv = pred(xv)[:, None]
                _, m_ex = _metric(spec, mv, yv)
                results.append((name, f"exact(n={n_ex})", t_ex, mname, m_ex))
    return results


def main(csv=True, **kw):
    rows = run(**kw)
    print("# Table 2 (reduced): dataset, algorithm, time_s, metric, value")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]:.2f},{r[3]},{r[4]:.4f}")
    return rows


if __name__ == "__main__":
    main()
