"""Compressed-native training path: parity + no-dense-materialisation.

The tentpole guarantee of the packed path (DESIGN.md §2): training with
compress_matrix=True consumes the bit-packed words directly in every phase
(histograms, repartition, binned prediction) and never materialises the
dense (n_rows, n_features) bins matrix after initial quantisation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BoosterConfig, train
from repro.core import booster as B
from repro.core import compress as C
from repro.core import objectives as O
from repro.core import partition as P
from repro.core import predict as PR
from repro.core import quantile as Q


@pytest.fixture(scope="module")
def small_data():
    rng = np.random.default_rng(7)
    n, f = 500, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f)
    y = ((x @ w + 0.3 * rng.normal(size=n)) > 0).astype(np.float32)
    x[rng.random(x.shape) < 0.05] = np.nan  # exercise the missing bin
    return x, y


@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_path_matches_dense(small_data, use_kernel):
    """compress_matrix=True/False (x kernel on/off) must grow identical
    trees and produce identical training margins."""
    x, y = small_data
    kw = dict(n_rounds=4, max_depth=3, objective="binary:logistic", max_bins=32,
              use_kernel_histograms=use_kernel)
    st_d = train(x, y, BoosterConfig(**kw, compress_matrix=False))
    st_p = train(x, y, BoosterConfig(**kw, compress_matrix=True))
    assert bool(jnp.all(st_d.ensemble.feature == st_p.ensemble.feature))
    assert bool(jnp.all(st_d.ensemble.split_bin == st_p.ensemble.split_bin))
    assert bool(jnp.all(st_d.ensemble.is_leaf == st_p.ensemble.is_leaf))
    np.testing.assert_allclose(np.asarray(st_d.ensemble.leaf_value),
                               np.asarray(st_p.ensemble.leaf_value), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_d.margins),
                               np.asarray(st_p.margins), atol=1e-4)


def test_packed_multiclass_parity(small_data):
    x, _ = small_data
    rng = np.random.default_rng(3)
    y = rng.integers(0, 3, size=x.shape[0]).astype(np.float32)
    kw = dict(n_rounds=3, max_depth=3, objective="multi:softmax", n_classes=3,
              max_bins=16)
    st_d = train(x, y, BoosterConfig(**kw, compress_matrix=False))
    st_p = train(x, y, BoosterConfig(**kw, compress_matrix=True))
    assert bool(jnp.all(st_d.ensemble.feature == st_p.ensemble.feature))
    assert bool(jnp.all(st_d.ensemble.split_bin == st_p.ensemble.split_bin))
    np.testing.assert_allclose(np.asarray(st_d.margins),
                               np.asarray(st_p.margins), atol=1e-4)


def test_predict_binned_packed_matches_dense(small_data):
    x, y = small_data
    cfg = BoosterConfig(n_rounds=3, max_depth=3, objective="binary:logistic",
                        max_bins=32)
    st = train(x, y, cfg)
    cuts = st.matrix.cuts
    bins = Q.quantize(jnp.asarray(x), cuts)
    mb = cfg.max_bins - 1
    dense = PR.predict_binned(st.ensemble, bins, mb, cfg.max_depth)
    packed = PR.predict_binned_packed(
        st.ensemble, st.matrix.packed, st.matrix.bits, st.matrix.n_rows,
        mb, cfg.max_depth,
    )
    np.testing.assert_allclose(np.asarray(dense), np.asarray(packed), atol=1e-5)


def test_update_positions_packed_matches_dense(rng):
    n, f, mb = 700, 5, 16
    bins = jnp.asarray(rng.integers(0, mb, size=(n, f)), jnp.int32)
    cm = C.compress(bins, jnp.zeros((f, 1)), mb)
    na = 15
    split_mask = jnp.asarray(rng.random(na) < 0.6)
    feat = jnp.asarray(rng.integers(0, f, size=na), jnp.int32)
    sbin = jnp.asarray(rng.integers(0, mb - 1, size=na), jnp.int32)
    dl = jnp.asarray(rng.random(na) < 0.5)
    pos = jnp.asarray(rng.integers(-1, 7, size=n), jnp.int32)
    want = P.update_positions(bins, pos, split_mask, feat, sbin, dl, mb - 1)
    got = P.update_positions_packed(
        cm.packed, pos, split_mask, feat, sbin, dl, mb - 1, cm.bits
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("packed", [False, True])
def test_histogram_subtraction_matches_full_builds(small_data, packed):
    """The smaller-child + subtraction growth (DESIGN.md §7.5) must produce
    the same tree as full per-level builds, packed and dense."""
    from repro.core import tree as T

    x, y = small_data
    max_bins, max_depth = 32, 4
    xj = jnp.asarray(x)
    cuts = Q.compute_cuts(xj, max_bins)
    bins = Q.quantize(xj, cuts)
    data = C.compress(bins, cuts, max_bins).as_packed_bins() if packed else bins
    obj = O.OBJECTIVES["binary:logistic"]
    gh = obj.grad(jnp.zeros((x.shape[0], 1)), jnp.asarray(y))[:, 0, :]
    tr_full = T.grow_tree(data, gh, cuts, max_depth, max_bins,
                          hist_subtraction=False)
    tr_sub = T.grow_tree(data, gh, cuts, max_depth, max_bins,
                         hist_subtraction=True)
    assert bool(jnp.all(tr_full.feature == tr_sub.feature))
    assert bool(jnp.all(tr_full.split_bin == tr_sub.split_bin))
    assert bool(jnp.all(tr_full.is_leaf == tr_sub.is_leaf))
    np.testing.assert_allclose(np.asarray(tr_full.leaf_value),
                               np.asarray(tr_sub.leaf_value), atol=1e-4)


def test_compress_accepts_precomputed_max_value(rng):
    bins = jnp.asarray(rng.integers(0, 200, size=(300, 4)), jnp.int32)
    cm = C.compress(bins, jnp.zeros((4, 1)), 256, max_value=255)
    assert cm.bits == 8  # derived from the caller's bound, no device sync
    np.testing.assert_array_equal(np.asarray(cm.as_packed_bins().packed),
                                  np.asarray(cm.packed))
    roundtrip = C.unpack(cm.packed, cm.bits, 300)
    np.testing.assert_array_equal(np.asarray(roundtrip), np.asarray(bins))


# --------------------------------------------------------------------------
# Acceptance: no dense (n, f) intermediate anywhere in the round step.
# --------------------------------------------------------------------------

def _iter_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            items = v if isinstance(v, (tuple, list)) else (v,)
            for item in items:
                if hasattr(item, "jaxpr"):  # ClosedJaxpr
                    yield from _iter_jaxprs(item.jaxpr)
                elif hasattr(item, "eqns"):  # raw Jaxpr
                    yield from _iter_jaxprs(item)


def _intermediate_sizes(jaxpr) -> set[tuple]:
    shapes = set()
    for j in _iter_jaxprs(jaxpr):
        for eqn in j.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    shapes.add(tuple(aval.shape))
    return shapes


def _round_step_shapes(n, f, compress_matrix, hist_block_rows):
    rng = np.random.default_rng(11)
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    cfg = BoosterConfig(n_rounds=2, max_depth=3, max_bins=16,
                        objective="binary:logistic",
                        compress_matrix=compress_matrix,
                        hist_block_rows=hist_block_rows)
    obj = O.OBJECTIVES[cfg.objective]
    cuts = Q.compute_cuts(jnp.asarray(x), cfg.max_bins)
    bins = Q.quantize(jnp.asarray(x), cuts)
    data = C.compress(bins, cuts, cfg.max_bins).as_packed_bins() \
        if compress_matrix else bins
    round_step = B._make_round_step(cfg, obj, cuts, None)
    margins = jnp.zeros((n, 1), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda d, m, yy: round_step(d, m, yy, {})
    )(data, margins, jnp.asarray(y))
    return _intermediate_sizes(jaxpr.jaxpr)


def test_round_step_never_materialises_dense_bins():
    """The packed round step's jaxpr must contain NO intermediate with
    n_rows * n_features elements — the dense bins matrix (in any layout or
    rank) never exists. Dense tiles are bounded by hist_block_rows."""
    n, f = 512, 7
    shapes = _round_step_shapes(n, f, compress_matrix=True, hist_block_rows=128)
    offenders = [s for s in shapes if int(np.prod(s)) == n * f]
    assert not offenders, f"dense-bins-sized intermediates found: {offenders}"


def test_dense_round_step_detector_sanity():
    """Same detector on the dense path DOES fire — proves the check above
    is capable of catching a full-matrix materialisation."""
    n, f = 512, 7
    shapes = _round_step_shapes(n, f, compress_matrix=False, hist_block_rows=128)
    assert any(int(np.prod(s)) == n * f for s in shapes)


def test_packed_builder_never_materialises_dense_bins(rng):
    """Builder-level version of the detector (ISSUE 9): the feature-major
    packed builder's own jaxpr contains no n_rows * n_features-element
    intermediate — one unpacked COLUMN at a time is its largest dense
    transient. Guards the builder directly, independent of how the round
    step composes it."""
    from repro.core import histogram as H

    n, f, max_bins, nodes = 512, 7, 16, 3
    bits = C.bits_needed(max_bins - 1)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, nodes + 1, size=n), jnp.int32)
    packed = C.pack(bins, bits)
    jaxpr = jax.make_jaxpr(
        lambda pk, g, p: H.build_histograms_packed(
            pk, g, p, nodes, max_bins, bits, n)
    )(packed, gh, pos)
    shapes = _intermediate_sizes(jaxpr.jaxpr)
    offenders = [s for s in shapes if int(np.prod(s)) >= n * f]
    assert not offenders, f"dense-bins-sized intermediates found: {offenders}"
