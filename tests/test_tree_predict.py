"""Tree growth (Algorithm 1) + prediction (§2.4)."""
import jax.numpy as jnp
import numpy as np

from repro.core import quantile as Q
from repro.core import tree as T
from repro.core import predict as PR
from repro.core.split import SplitParams


def _grow(x, gh, max_depth=3, max_bins=16, growth="depthwise", max_leaves=0):
    cuts = Q.compute_cuts(jnp.asarray(x), max_bins)
    bins = Q.quantize(jnp.asarray(x), cuts)
    tr = T.grow_tree(bins, jnp.asarray(gh), cuts, max_depth, max_bins,
                     SplitParams(), growth=growth, max_leaves=max_leaves)
    return tr, bins, cuts


def manual_traverse(tr, bins_row, missing_bin):
    node = 0
    while not bool(tr.is_leaf[node]):
        f, thr = int(tr.feature[node]), int(tr.split_bin[node])
        b = int(bins_row[f])
        if b == missing_bin:
            left = bool(tr.default_left[node])
        else:
            left = b <= thr
        node = 2 * node + 1 if left else 2 * node + 2
    return float(tr.leaf_value[node])


def test_single_perfect_split(rng):
    """y = sign(x0): depth-1 tree must find feature 0 and fit perfectly."""
    n = 400
    x = rng.normal(size=(n, 3)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    gh = np.stack([0.5 - y, np.full(n, 0.25)], axis=1).astype(np.float32)  # logistic at m=0
    tr, bins, _ = _grow(x, gh, max_depth=1)
    assert int(tr.feature[0]) == 0
    assert bool(tr.is_leaf[1]) and bool(tr.is_leaf[2])
    left, right = float(tr.leaf_value[1]), float(tr.leaf_value[2])
    assert (left < 0 < right) or (right < 0 < left)


def test_predict_matches_manual(rng):
    n, f = 300, 5
    x = rng.normal(size=(n, f)).astype(np.float32)
    x[rng.random(x.shape) < 0.1] = np.nan
    y = np.sin(x[:, 0]).astype(np.float32)
    y = np.nan_to_num(y)
    gh = np.stack([-y, np.ones(n)], axis=1).astype(np.float32)
    max_bins = 16
    tr, bins, cuts = _grow(x, gh, max_depth=3, max_bins=max_bins)
    ens = PR.stack_trees([tr])
    got = np.asarray(PR.predict_binned(ens, bins, max_bins - 1, 3))[:, 0]
    want = np.array([manual_traverse(tr, np.asarray(bins)[i], max_bins - 1)
                     for i in range(n)])
    np.testing.assert_allclose(got, want, atol=1e-6)
    # raw prediction agrees with binned on the training data
    raw = np.asarray(PR.predict_raw(ens, jnp.asarray(x), 3))[:, 0]
    np.testing.assert_allclose(raw, want, atol=1e-6)


def test_lossguide_leaf_budget(rng):
    n = 600
    x = rng.normal(size=(n, 6)).astype(np.float32)
    y = (x @ rng.normal(size=6)).astype(np.float32)
    gh = np.stack([-y, np.ones(n)], axis=1).astype(np.float32)
    for budget in (2, 4, 7):
        tr, _, _ = _grow(x, gh, max_depth=5, growth="lossguide", max_leaves=budget)
        n_leaves = int(jnp.sum(tr.is_leaf))
        assert n_leaves <= budget, (budget, n_leaves)


def test_gain_decreases_objective(rng):
    """Leaf-wise objective -G^2/(2(H+lam)) summed over leaves must improve
    with depth (boosting's guarantee at the tree level)."""
    n = 500
    x = rng.normal(size=(n, 4)).astype(np.float32)
    y = (np.sin(2 * x[:, 0]) + x[:, 1]).astype(np.float32)
    gh = np.stack([-y, np.ones(n)], axis=1).astype(np.float32)

    def tree_obj(max_depth):
        tr, bins, _ = _grow(x, gh, max_depth=max_depth)
        ens = PR.stack_trees([tr])
        pred = np.asarray(PR.predict_binned(ens, bins, 15, max_depth))[:, 0]
        # squared-error surrogate: 0.5*sum((pred - y)^2) with g = -y, h = 1
        return float(np.sum(0.5 * (pred - y) ** 2))

    objs = [tree_obj(d) for d in (0, 1, 2, 4)]
    assert all(objs[i + 1] <= objs[i] + 1e-3 for i in range(len(objs) - 1)), objs
