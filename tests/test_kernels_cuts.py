"""kernels.quantile_cuts vs the XLA selection oracle (interpret mode).

Parity is NOT bitwise: compiled XLA may contract the interpolation's
mul+add into an FMA where the kernel's evaluation does not (~1 ulp), and
at an exact integer rank boundary that ulp can flip a floor() and select
the NEIGHBOURING order statistic — still a valid boundary of the same
equal-mass bin. The tolerance below bounds exactly that failure mode:
one rank-unit of interpolation drift times the largest adjacent-value
gap in the sorted column, plus ulp-scale slack.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as KR
from repro.kernels.quantile_cuts import quantile_cuts_from_sorted


def _sorted_input(rng, n, f, nan_frac=0.1):
    x = rng.normal(size=(n, f)).astype(np.float32)
    x[rng.random((n, f)) < nan_frac] = np.nan
    srt = np.sort(np.where(np.isnan(x), np.inf, x), axis=0)
    n_valid = np.isfinite(srt).sum(axis=0).astype(np.int32)
    return srt, n_valid


@pytest.mark.parametrize(
    "n,f,max_bins",
    [(1000, 7, 16), (513, 3, 256), (4096, 17, 256), (64, 1, 256),
     (333, 11, 64)],
)
def test_cuts_kernel_parity(rng, n, f, max_bins):
    srt, n_valid = _sorted_input(rng, n, f)
    got = np.asarray(
        quantile_cuts_from_sorted(
            jnp.asarray(srt), jnp.asarray(n_valid), max_bins,
            interpret=True))
    want = np.asarray(
        KR.quantile_cuts_ref(jnp.asarray(srt), jnp.asarray(n_valid),
                             max_bins))
    assert got.shape == want.shape == (f, max_bins - 2)
    for j in range(f):
        nv = int(n_valid[j])
        gap = (float(np.diff(srt[:nv, j]).max()) if nv >= 2 else 0.0)
        tol = (np.spacing(np.float32(max(nv, 2))) * max(gap, 1.0)
               + 1e-5 + 1e-5 * np.abs(want[j]))
        gw, ww = got[j], want[j]
        # +inf dedup padding must agree exactly; finite cuts to the bound.
        np.testing.assert_array_equal(np.isfinite(gw), np.isfinite(ww))
        fin = np.isfinite(ww)
        assert np.all(np.abs(gw[fin] - ww[fin]) <= tol[fin]), (
            f"feature {j}: max err "
            f"{np.max(np.abs(gw[fin] - ww[fin]) - tol[fin])}"
        )


def test_cuts_kernel_structure(rng):
    """Rows come back ascending with +inf padding at the tail, padded
    feature blocks are sliced off, and degenerate columns behave."""
    n, max_bins = 200, 32
    x = rng.normal(size=(n, 5)).astype(np.float32)
    x[:, 1] = 3.25  # constant column -> single value, all cuts dedup away
    x[:, 3] = np.nan  # all-missing column -> zero valid, all +inf
    srt = np.sort(np.where(np.isnan(x), np.inf, x), axis=0)
    n_valid = np.isfinite(srt).sum(axis=0).astype(np.int32)

    # f=5 with f_blk=4 forces a ragged padded feature block.
    got = np.asarray(
        quantile_cuts_from_sorted(
            jnp.asarray(srt), jnp.asarray(n_valid), max_bins,
            f_blk=4, interpret=True))
    assert got.shape == (5, max_bins - 2)
    for row in got:
        r = row[np.isfinite(row)]
        assert np.all(np.diff(r) >= 0), "cuts must be ascending"
    # +inf padding is contiguous at the tail (the re-sort guarantees it).
    for row in got:
        fin = np.isfinite(row)
        assert not np.any(fin[np.argmin(fin):]) or np.all(fin)
    assert np.isfinite(got[1]).sum() == 1, "constant col dedups to one cut"
    assert got[1, 0] == 3.25
    assert not np.any(np.isfinite(got[3])), "all-missing col has no cuts"
    want = np.asarray(
        KR.quantile_cuts_ref(jnp.asarray(srt), jnp.asarray(n_valid),
                             max_bins))
    np.testing.assert_array_equal(np.isfinite(got), np.isfinite(want))
