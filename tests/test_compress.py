"""Paper §2.2: bit-packed compression — roundtrip + ratio properties."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import compress as C


@settings(max_examples=30, deadline=None)
@given(
    bits=st.integers(1, 16),
    n=st.integers(1, 300),
    f=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(bits, n, f, seed):
    rng = np.random.default_rng(seed)
    bins = rng.integers(0, 2**bits, size=(n, f)).astype(np.int32)
    packed = C.pack(jnp.asarray(bins), bits)
    out = C.unpack(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(out), bins)


def test_bits_needed():
    assert C.bits_needed(0) == 1
    assert C.bits_needed(1) == 1
    assert C.bits_needed(255) == 8
    assert C.bits_needed(256) == 9


def test_compression_ratio_paper_claim(rng):
    """The paper: >= 4x reduction vs fp32 for 256-bin (8-bit) quantisation."""
    bins = rng.integers(0, 256, size=(10_000, 32)).astype(np.int32)
    cm = C.compress(jnp.asarray(bins), jnp.zeros((32, 1)), 256)
    assert cm.bits == 8
    assert cm.compression_ratio() >= 4.0


def test_low_cardinality_packs_tighter(rng):
    """<= 16 distinct bins must pack at < 8 bits (paper: log2(max_value))."""
    bins = rng.integers(0, 16, size=(1000, 4)).astype(np.int32)
    cm = C.compress(jnp.asarray(bins), jnp.zeros((4, 1)), 256)
    assert cm.bits <= 4
    assert cm.compression_ratio() >= 8.0


def test_word_padding_edge(rng):
    """Row counts not divisible by symbols/word still roundtrip."""
    for n in (1, 3, 5, 7, 31):
        bins = rng.integers(0, 32, size=(n, 3)).astype(np.int32)
        packed = C.pack(jnp.asarray(bins), 5)
        np.testing.assert_array_equal(np.asarray(C.unpack(packed, 5, n)), bins)
