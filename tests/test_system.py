"""End-to-end behaviour of the paper's system (Figure 1 pipeline) plus the
framework glue: launcher drivers, flash attention, input specs."""
import numpy as np

from repro.core import BoosterConfig, train, predict_proba
from repro.data import make_dataset


def test_paper_pipeline_on_paper_shaped_data():
    """Reduced-size higgs-like data through the full pipeline: quantise ->
    compress -> boost -> predict. The paper's Table 2 metric (accuracy)
    must beat a decision stump by a clear margin."""
    x, y, spec = make_dataset("higgs", n_rows=3000)
    cfg = BoosterConfig(n_rounds=15, max_depth=5, objective=spec.objective,
                        max_bins=128)
    st = train(x, y, cfg)
    p = np.asarray(predict_proba(st.ensemble, x, cfg.max_depth, cfg.objective))
    acc = float(np.mean((p > 0.5) == y))

    stump_cfg = BoosterConfig(n_rounds=1, max_depth=1, objective=spec.objective,
                              max_bins=128)
    st0 = train(x, y, stump_cfg)
    p0 = np.asarray(predict_proba(st0.ensemble, x, 1, spec.objective))
    acc0 = float(np.mean((p0 > 0.5) == y))
    assert acc > acc0 + 0.08, (acc, acc0)
    # compression engaged (paper §2.2): 8-bit bins -> >= 4x vs fp32
    assert st.matrix.compression_ratio() >= 4.0


def test_sparse_dataset_trains():
    """bosch-like 81%-missing data must train (sparsity-aware splits)."""
    x, y, spec = make_dataset("bosch", n_rows=1500)
    x = x[:, :64]  # column subset for CPU speed
    cfg = BoosterConfig(n_rounds=8, max_depth=4, objective=spec.objective,
                        max_bins=32)
    st = train(x, y, cfg)
    p = np.asarray(predict_proba(st.ensemble, x, 4, spec.objective))
    assert np.isfinite(p).all()
    assert float(np.mean((p > 0.5) == y)) > 0.55


def test_lm_train_loop_improves():
    """Deliverable (b): the LM trainer drives loss down on a reduced arch."""
    from repro.configs import get_arch
    from repro.launch.train import train_loop

    cfg = get_arch("yi-6b").reduced()
    _, hist = train_loop(cfg, steps=12, batch=4, seq=64, lr=3e-3, log_every=4)
    assert hist[-1]["loss"] < hist[0]["loss"], hist


def test_input_specs_cover_all_pairs():
    """Every supported (arch x shape) pair produces well-formed specs."""
    from repro.configs import ARCHS, get_arch
    from repro.launch import specs as SP
    from repro.models.config import SHAPES

    n_ok = n_skip = 0
    for arch in ARCHS:
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            ok, reason = SP.supports_shape(cfg, shape)
            if not ok:
                n_skip += 1
                assert reason
                continue
            n_ok += 1
            specs = SP.input_specs(cfg, shape)
            assert "tokens" in specs
            b = shape.global_batch
            for v in specs.values():
                assert v.shape[0] == b
            if shape.kind == "decode":
                assert specs["tokens"].shape == (b, 1)
                cap = SP.cache_capacity(cfg, shape)
                assert 0 < cap <= shape.seq_len
    assert n_ok == 39 and n_skip == 1, (n_ok, n_skip)  # seamless long_500k


def test_gbdt_driver_cli(tmp_path):
    """train_gbdt driver end to end (single device)."""
    import subprocess, sys, os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train_gbdt", "--dataset", "higgs",
         "--rows", "2000", "--rounds", "5", "--max-bins", "32",
         "--checkpoint", str(tmp_path / "ens.msgpack")],
        capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stderr
    assert "valid_accuracy=" in res.stdout
    assert (tmp_path / "ens.msgpack").exists()
