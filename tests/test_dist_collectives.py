"""Pluggable collectives + compressed histogram allreduce (repro.dist,
DESIGN.md §15).

Multi-device equivalence and compression behaviour run in 8-virtual-device
subprocesses (mirroring tests/test_distributed.py); registry validation and
the analytic CommStats wire model run in-process.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro import dist
from repro.jaxcompat import make_mesh

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


def test_every_collective_matches_single_device():
    """fit(mesh=, collective=) in f32 mode: ring, hierarchical (1-axis
    factored and 2-axis mesh) all grow the same trees as the single-device
    fit — same features/split bins, leaf values to float tolerance."""
    out = _run("""
        import numpy as np, jax.numpy as jnp
        from repro.core import Booster, BoosterConfig, DeviceDMatrix
        from repro.jaxcompat import make_mesh
        rng = np.random.default_rng(5)
        n, f = 2048, 8
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x @ rng.normal(size=f) > 0).astype(np.float32)
        cfg = BoosterConfig(n_rounds=4, max_depth=3, max_bins=32,
                            objective="binary:logistic")
        d = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
        st = Booster(cfg).fit(d)
        mesh = make_mesh((8,), ("data",))
        mesh2 = make_mesh((4, 2), ("data", "pod"))
        runs = [
            (mesh, ("data",), "ring"),
            (mesh, ("data",), "hier"),
            (mesh2, ("data", "pod"), "hier"),
        ]
        for m, axes, name in runs:
            b = Booster(cfg).fit(d, mesh=m, data_axes=axes, collective=name)
            assert bool(jnp.all(st.ensemble.feature == b.ensemble.feature)), name
            assert bool(jnp.all(st.ensemble.split_bin
                                == b.ensemble.split_bin)), name
            diff = float(jnp.max(jnp.abs(st.ensemble.leaf_value
                                         - b.ensemble.leaf_value)))
            assert diff < 1e-4, (name, diff)
            cs = b.comm_stats
            assert cs["collective"] == name
            assert cs["compression"] is None
            assert cs["bytes_per_round"] > 0
            assert cs["fallback_events"] == 0
            # one hist allreduce per level + the root sum, per tree
            assert cs["collective_calls_per_round"] == cfg.max_depth + 1
        print("COLLECTIVES-F32-OK")
    """)
    assert "COLLECTIVES-F32-OK" in out


def test_compressed_allreduce_trains_within_tolerance():
    """f16/q16 compressed histogram allreduce: eval metric within tolerance
    of the exact fit, comm bytes/round at least halved on the ring, and the
    q16 integer reduction identical across ring and psum topologies."""
    out = _run("""
        import numpy as np, jax.numpy as jnp
        from repro.core import Booster, BoosterConfig, DeviceDMatrix
        from repro.jaxcompat import make_mesh
        rng = np.random.default_rng(7)
        n, f = 4096, 10
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x[:, 0] * 2 + x[:, 1] + 0.1 * rng.normal(size=n)).astype(
            np.float32)
        cfg = BoosterConfig(n_rounds=5, max_depth=4, max_bins=64)
        d = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
        mesh = make_mesh((8,), ("data",))
        exact = Booster(cfg).fit(d, mesh=mesh, collective="ring")
        p_exact = np.asarray(exact.predict(x))
        rmse_exact = float(np.sqrt(np.mean((p_exact - y) ** 2)))
        for comp in ("f16", "q16"):
            b = Booster(cfg).fit(d, mesh=mesh, collective="ring",
                                 compression=comp)
            p = np.asarray(b.predict(x))
            rmse = float(np.sqrt(np.mean((p - y) ** 2)))
            assert abs(rmse - rmse_exact) <= 0.05 * rmse_exact + 1e-4, (
                comp, rmse, rmse_exact)
            cs = b.comm_stats
            assert cs["compression"] == comp
            # the compressed histogram payload is exactly halved; the f32
            # side-channel scalars keep the TOTAL just under 2x
            hist = sum(cs["hist_bytes_per_level"])
            hist_f32 = 2 * hist  # 2-byte wire vs 4-byte wire, same model
            assert cs["bytes_per_round_f32"] - cs["bytes_per_round"] >= (
                hist_f32 - hist) * 0.999, cs
            assert cs["bytes_per_round_f32"] >= 1.95 * cs["bytes_per_round"], cs
            assert cs["fallback_events"] == 0, cs
        # q16 is an exact integer allreduce after shared scaling: the
        # reduction is order-independent, so ring and psum grow
        # bit-identical trees.
        rq = Booster(cfg).fit(d, mesh=mesh, collective="ring",
                              compression="q16")
        pq = Booster(cfg).fit(d, mesh=mesh, collective="psum",
                              compression="q16")
        assert bool(jnp.all(rq.ensemble.feature == pq.ensemble.feature))
        assert bool(jnp.all(rq.ensemble.split_bin == pq.ensemble.split_bin))
        assert bool(jnp.all(rq.ensemble.leaf_value == pq.ensemble.leaf_value))
        print("COMPRESSED-OK")
    """)
    assert "COMPRESSED-OK" in out


def test_fallback_on_adversarial_gradients():
    """Near-zero tolerance forces the on-device error check to reject the
    compressed payload every level: the fit falls back to exact f32
    (bit-identical trees to compression=None) and comm_stats counts every
    fallback. A loose tolerance on adversarial wide-range gradients still
    triggers at least one fallback for f16."""
    out = _run("""
        import numpy as np, jax.numpy as jnp
        from repro import dist
        from repro.core import Booster, BoosterConfig, DeviceDMatrix
        from repro.jaxcompat import make_mesh
        rng = np.random.default_rng(9)
        n, f = 2048, 6
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x[:, 0] + 0.1 * rng.normal(size=n)).astype(np.float32)
        cfg = BoosterConfig(n_rounds=2, max_depth=3, max_bins=32)
        d = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
        mesh = make_mesh((8,), ("data",))
        exact = Booster(cfg).fit(d, mesh=mesh, collective="ring")
        tight = dist.get_collective("ring", mesh, ("data",),
                                    compression="q16", tolerance=0.0)
        b = Booster(cfg).fit(d, mesh=mesh, collective=tight)
        # every hist allreduce fell back: rounds * levels
        assert b.comm_stats["fallback_events"] == cfg.n_rounds * cfg.max_depth, (
            b.comm_stats)
        assert bool(jnp.all(exact.ensemble.feature == b.ensemble.feature))
        assert bool(jnp.all(exact.ensemble.split_bin == b.ensemble.split_bin))
        assert bool(jnp.all(exact.ensemble.leaf_value
                            == b.ensemble.leaf_value))
        # Adversarial dynamic range: targets spanning ~6 orders of
        # magnitude give f16-unrepresentable bin sums -> fallbacks fire
        # even at a practical tolerance.
        y2 = (y * np.where(rng.random(n) < 0.01, 3e4, 1e-3)).astype(
            np.float32)
        d2 = DeviceDMatrix(x, label=y2, max_bins=cfg.max_bins)
        b2 = Booster(cfg).fit(d2, mesh=mesh, collective="ring",
                              compression="f16", comm_tolerance=1e-4)
        assert b2.comm_stats["fallback_events"] > 0, b2.comm_stats
        print("FALLBACK-OK")
    """)
    assert "FALLBACK-OK" in out


# --- in-process: registry + analytic wire model ----------------------------


def test_registry_resolution_and_errors():
    mesh = make_mesh((1,), ("data",))
    c = dist.get_collective("psum", mesh, ("data",))
    assert isinstance(c, dist.PsumCollective)
    assert dist.get_collective(c, mesh, ("data",)) is c  # instance passthrough
    c2 = dist.get_collective(dist.RingCollective, mesh, ("data",))
    assert isinstance(c2, dist.RingCollective)
    assert set(dist.collective_names()) >= {"psum", "ring", "hier"}

    with pytest.raises(ValueError, match="unknown collective"):
        dist.get_collective("allgather", mesh, ("data",))
    with pytest.raises(TypeError, match="collective must be"):
        dist.get_collective(42, mesh, ("data",))
    with pytest.raises(ValueError, match="compression"):
        dist.get_collective("psum", mesh, ("data",), compression="int4")
    with pytest.raises(ValueError, match="tolerance"):
        dist.get_collective("psum", mesh, ("data",), tolerance=-0.5)
    with pytest.raises(TypeError, match="subclass"):
        dist.register_collective("bad", int)

    class MyColl(dist.PsumCollective):
        name = "mine"

    dist.register_collective("mine", MyColl)
    assert isinstance(dist.get_collective("mine", mesh, ("data",)), MyColl)

    mesh2 = make_mesh((1, 1), ("data", "pod"))
    with pytest.raises(ValueError, match="one mesh axis"):
        dist.RingCollective(mesh2, ("data", "pod"))


def test_hier_group_geometry_validation():
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="divide"):
        dist.HierarchicalCollective(mesh, ("data",), group_size=3)
    mesh2 = make_mesh((1, 1), ("data", "pod"))
    with pytest.raises(ValueError, match="conflicts"):
        dist.HierarchicalCollective(mesh2, ("data", "pod"), group_size=7)
    c = dist.HierarchicalCollective(mesh2, ("data", "pod"))
    assert (c.n_hosts, c.group_size) == (1, 1)


def test_comm_stats_wire_model():
    """The analytic byte model: psum/ring move 2*(p-1)*N*B total; ring
    compression halves the hist payload; CommStats serialises cleanly."""

    class FakeMesh:  # duck-typed: only .shape is consulted
        shape = {"data": 8}

    mesh = FakeMesh()
    f32 = dist.get_collective("ring", mesh, ("data",))
    f16 = dist.get_collective("ring", mesh, ("data",), compression="f16")
    n_elems = 4 * 64 * 2  # one level: nodes * features-ish payload
    assert f32.bytes_allreduce(n_elems, 4) == 2 * 7 * 8 * (n_elems // 8) * 4
    assert f16.wire_bytes_elem() == 2
    s32 = dist.round_comm_stats(f32, max_depth=6, n_features=13, max_bins=256)
    s16 = dist.round_comm_stats(f16, max_depth=6, n_features=13, max_bins=256)
    assert s32.bytes_per_round == s32.bytes_per_round_f32
    assert s16.bytes_per_round_f32 == s32.bytes_per_round_f32
    # hist payload dominates, so halving the wire dtype ~halves the round
    assert s16.bytes_per_round < 0.51 * s32.bytes_per_round
    assert len(s16.hist_bytes_per_level) == 6
    assert s16.collective_calls_per_round > s32.collective_calls_per_round
    d = s16.as_dict()
    assert d["collective"] == "ring" and d["compression"] == "f16"
    assert isinstance(d["hist_bytes_per_level"], list)
    # q16 through plain psum cannot narrow the wire (int32 partials) — the
    # model reports no saving, steering users to ring/hier.
    q_psum = dist.get_collective("psum", mesh, ("data",), compression="q16")
    assert q_psum.wire_bytes_elem() == 4
    # hierarchical: intra stays f32, inter ring shrinks
    h16 = dist.get_collective("hier", mesh, ("data",), compression="f16")
    h32 = dist.get_collective("hier", mesh, ("data",))
    assert h16.bytes_allreduce(1024, 2) < h32.bytes_allreduce(1024, 4)
