"""Device-sharded sketch construction (repro.dist.sketch, DESIGN.md §15).

Host-side properties of the log-depth tree merge (associativity / shard-count
invariance in the exact regime, rank-error bounds under pruning, push_sorted
equivalence) run in-process; the shard_map device-sort phase runs in an
8-virtual-device subprocess, mirroring tests/test_distributed.py.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantile as Q
from repro.core.dmatrix import ExternalDMatrix
from repro.dist import sharded_sketch_cuts, tree_merge

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


def _shard_sketches(x, shards, max_bins=128, capacity=4096):
    out = []
    for part in np.array_split(x, shards):
        sk = Q.StreamingQuantileSketch(x.shape[1], max_bins, capacity)
        sk.push(part)
        out.append(sk)
    return out


def test_tree_merge_shard_count_invariance_exact(rng):
    """Exact summaries merge exactly, so 2/4/8-shard tree merges and the
    single sequential sketch all produce bitwise-identical cuts."""
    n, f = 1600, 5
    x = rng.normal(size=(n, f)).astype(np.float32)
    x[rng.random((n, f)) < 0.05] = np.nan
    x[:, 2] = rng.integers(0, 4, n)  # low cardinality

    ref = Q.StreamingQuantileSketch(f, 128, 4096).push(x).get_cuts()
    for shards in (2, 4, 8):
        merged = tree_merge(_shard_sketches(x, shards)).get_cuts()
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(merged))


def test_tree_merge_order_invariance_exact(rng):
    """Any permutation of the shard list tree-merges to the same cuts in
    the exact regime (associativity + commutativity of exact combine)."""
    n, f = 1200, 4
    x = rng.normal(size=(n, f)).astype(np.float32)
    base = tree_merge(_shard_sketches(x, 4)).get_cuts()
    for perm in ([3, 1, 0, 2], [2, 3, 0, 1], [1, 0, 3, 2]):
        sketches = _shard_sketches(x, 4)
        merged = tree_merge([sketches[i] for i in perm]).get_cuts()
        np.testing.assert_array_equal(np.asarray(base), np.asarray(merged))


def test_sharded_cuts_rank_error_bound(rng):
    """Cuts from a pruned sharded sketch stay within a GK-style rank-error
    bound of compute_cuts' exact quantiles: each finite cut's empirical
    rank deviates from its target by at most a small multiple of
    n/capacity per merge level."""
    n, capacity, shards = 40000, 256, 8
    col = (rng.standard_normal(n) ** 3).astype(np.float32)
    x = col[:, None]
    cuts = np.asarray(
        sharded_sketch_cuts(x, max_bins=64, capacity=capacity,
                            n_shards=shards)
    )[0]
    finite = cuts[np.isfinite(cuts)]
    assert finite.size == Q.n_value_bins(64) - 1  # all cuts used
    srt = np.sort(col)
    nvb = Q.n_value_bins(64)
    # Tree depth log2(8)=3 prune rounds + per-shard pushes; headroom x2.
    eps = 2.0 * (shards + 3) / capacity
    for b, v in enumerate(finite):
        target = (b + 1) / nvb * (n - 1)
        true_rank = np.searchsorted(srt, v)
        assert abs(true_rank - target) <= eps * n, (b, true_rank, target)


def test_push_sorted_equals_push(rng):
    """push_sorted on device-style presorted columns (NaN -> +inf tail)
    builds the same summaries as push on the raw rows."""
    n, f = 900, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    x[rng.random((n, f)) < 0.1] = np.nan
    x[:, 4] = np.nan  # all-missing feature

    a = Q.StreamingQuantileSketch(f, 64, 512).push(x)
    filled = np.where(np.isfinite(x), x, np.inf)
    b = Q.StreamingQuantileSketch(f, 64, 512).push_sorted(
        np.sort(filled, axis=0), np.isfinite(x).sum(axis=0)
    )
    np.testing.assert_array_equal(np.asarray(a.get_cuts()),
                                  np.asarray(b.get_cuts()))
    assert a.n_pushed == b.n_pushed

    with pytest.raises(ValueError, match="cols_sorted"):
        Q.StreamingQuantileSketch(f, 64, 512).push_sorted(
            np.zeros((4, f + 1), np.float32), np.zeros(f + 1)
        )
    with pytest.raises(ValueError, match="n_valid"):
        Q.StreamingQuantileSketch(f, 64, 512).push_sorted(
            np.zeros((4, f), np.float32), np.zeros(f - 1)
        )


def test_sharded_cuts_quantise_like_compute_cuts(rng):
    """With adequate capacity the host-sharded build reproduces
    compute_cuts exactly, so quantisation is bit-identical."""
    n, f = 2000, 5
    x = rng.normal(size=(n, f)).astype(np.float32)
    x[rng.random((n, f)) < 0.02] = np.nan
    exact = np.asarray(Q.compute_cuts(jnp.asarray(x), 64))
    sharded = np.asarray(
        sharded_sketch_cuts(x, max_bins=64, capacity=8192, n_shards=4)
    )
    np.testing.assert_allclose(exact, sharded, rtol=1e-6, atol=0)
    be = np.asarray(Q.quantize(jnp.asarray(x), jnp.asarray(exact)))
    bs = np.asarray(Q.quantize(jnp.asarray(x), jnp.asarray(sharded)))
    np.testing.assert_array_equal(be, bs)


def test_external_dmatrix_sketch_shards(rng):
    """ExternalDMatrix(sketch_shards=) routes cut generation through the
    tree merge; in the exact-capacity regime it matches the sequential
    sketch build bit for bit."""
    n, f = 3000, 4
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    seq = ExternalDMatrix.from_arrays(x, y, chunk_rows=500,
                                      sketch_capacity=8192)
    shd = ExternalDMatrix.from_arrays(x, y, chunk_rows=500,
                                      sketch_capacity=8192, sketch_shards=3)
    np.testing.assert_array_equal(np.asarray(seq.cuts), np.asarray(shd.cuts))
    with pytest.raises(ValueError, match="sketch_shards"):
        ExternalDMatrix.from_arrays(x, y, chunk_rows=500, sketch_shards=0)


def test_device_phase_sharded_sketch():
    """The shard_map device-sort phase: mesh-sharded sketch cuts match the
    host tree-merge and (at high capacity) compute_cuts, and a
    DeviceDMatrix(cuts=) fit on them trains normally."""
    out = _run("""
        import numpy as np, jax.numpy as jnp
        from repro.core import Booster, DeviceDMatrix
        from repro.core.quantile import compute_cuts
        from repro.dist import sharded_sketch_cuts
        from repro.jaxcompat import make_mesh
        rng = np.random.default_rng(11)
        n, f = 4096, 6
        x = rng.normal(size=(n, f)).astype(np.float32)
        x[rng.random((n, f)) < 0.03] = np.nan
        y = np.nan_to_num(x[:, 0] * 2 + x[:, 1]).astype(np.float32)
        mesh = make_mesh((8,), ("data",))
        dev = np.asarray(sharded_sketch_cuts(
            x, max_bins=64, capacity=8192, mesh=mesh))
        host = np.asarray(sharded_sketch_cuts(
            x, max_bins=64, capacity=8192, n_shards=8))
        np.testing.assert_array_equal(dev, host)
        exact = np.asarray(compute_cuts(jnp.asarray(x), 64))
        np.testing.assert_allclose(exact, dev, rtol=1e-6, atol=0)
        d = DeviceDMatrix(x, label=y, max_bins=64, cuts=dev)
        b = Booster(n_rounds=3, max_depth=3, max_bins=64).fit(d)
        p = np.asarray(b.predict(x))
        assert np.isfinite(p).all()
        print("DEVICE-SKETCH-OK")
    """)
    assert "DEVICE-SKETCH-OK" in out
