"""Launch-layer logic that needs no devices: shape support rules, cache
capacities, sliding-window gating, HLO text parsing, roofline math."""

from repro.configs import ARCHS, get_arch
from repro.launch import specs as SP
from repro.launch.hlo_analysis import _parse_shape, _nbytes, parse_hlo, aggregate
from repro.models.config import SHAPES


def test_window_engaged_only_for_long():
    cfg = get_arch("yi-6b")
    assert cfg.sliding_window == 8192
    assert SP.cfg_for_shape(cfg, SHAPES["train_4k"]).sliding_window == 0
    assert SP.cfg_for_shape(cfg, SHAPES["prefill_32k"]).sliding_window == 0
    assert SP.cfg_for_shape(cfg, SHAPES["decode_32k"]).sliding_window == 0
    assert SP.cfg_for_shape(cfg, SHAPES["long_500k"]).sliding_window == 8192


def test_cache_capacity_rules():
    yi = get_arch("yi-6b")
    assert SP.cache_capacity(yi, SHAPES["decode_32k"]) == 32768
    assert SP.cache_capacity(yi, SHAPES["long_500k"]) == 8192  # SWA window
    mam = get_arch("mamba2-2.7b")
    assert SP.cache_capacity(mam, SHAPES["decode_32k"]) == 32768  # unused by SSM


def test_seamless_long_skip_reason():
    ok, reason = SP.supports_shape(get_arch("seamless-m4t-medium"),
                                   SHAPES["long_500k"])
    assert not ok and "enc-dec" in reason


def test_padded_vocab_divisibility():
    for arch in ARCHS:
        cfg = get_arch(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256


def test_parse_shape_and_bytes():
    shapes = _parse_shape("f32[4,16]{1,0} bf16[8] pred[] s32[2,2]")
    assert _nbytes(shapes) == 4 * 16 * 4 + 8 * 2 + 1 + 4 * 4


def test_parse_hlo_while_multiplier():
    text = """
HloModule test

%body (p: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
  %p = (s32[], f32[4,16]) parameter(0)
  %a = f32[4,8]{1,0} constant(0)
  %b = f32[8,16]{1,0} constant(0)
  %dot = f32[4,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,16]{1,0} all-reduce(%dot), replica_groups={}
}

%cond (p: (s32[], f32[4,16])) -> pred[] {
  %p = (s32[], f32[4,16]) parameter(0)
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%c, %c), direction=LT
}

ENTRY %main (x: f32[4,16]) -> f32[4,16] {
  %x = f32[4,16]{1,0} parameter(0)
  %w = (s32[], f32[4,16]) while(%x), condition=%cond, body=%body
}
"""
    comps = parse_hlo(text)
    flops, dbytes, coll = aggregate(comps)
    assert flops == 7 * 2 * 4 * 16 * 8  # trip count 7 recovered from cond
    assert coll["all-reduce"] == 7 * 4 * 16 * 4


def test_roofline_term_arithmetic():
    from repro.launch.dryrun import active_params, model_flops
    cfg = get_arch("llama4-scout-17b-a16e")
    total = 100_000
    moe = cfg.n_layers * 3 * cfg.n_experts * cfg.d_model * cfg.d_ff
    act = active_params(cfg, total + moe)
    assert act == total + moe // cfg.n_experts
    mf = model_flops(cfg, SHAPES["train_4k"], 1_000)
    assert mf == 6.0 * 1_000 * 256 * 4096
    mfd = model_flops(cfg, SHAPES["decode_32k"], 1_000)
    assert mfd == 2.0 * 1_000 * 128


def test_batch_partition_specs_shapes():
    from repro.launch.mesh import batch_axes
    cfg = get_arch("phi-3-vision-4.2b")
    shape = SHAPES["train_4k"]
    # build rules without a mesh: emulate single-pod axes
    from repro.models.transformer import ShardingRules
    r = ShardingRules(batch=("data",), model="model", seq=None)
    specs = SP.batch_partition_specs(cfg, shape, r)
    assert set(specs) == {"prefix_embeds", "tokens", "targets"}
    si = SP.input_specs(cfg, shape)
    assert si["tokens"].shape == (256, 4096 - 576)
    assert si["prefix_embeds"].shape == (256, 576, cfg.d_model)
