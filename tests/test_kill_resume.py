"""Kill-and-resume: SIGKILL a checkpointing fit mid-run in a subprocess,
resume from its last atomic snapshot, and require the result be
BIT-IDENTICAL (trees, margins, predictions) to an uninterrupted fit —
the tentpole guarantee of the fault-tolerant runtime (DESIGN.md §13).

The child process kills itself with SIGKILL (no cleanup, no atexit, no
flushing — the closest a test gets to preemption); the parent asserts the
snapshot on disk resumes exactly.
"""
import os
import signal
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Booster, BoosterConfig, DeviceDMatrix, ExternalDMatrix

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Shared, deterministic problem: child and parent regenerate identical data.
DATA_SETUP = """
import numpy as np
rng = np.random.default_rng(123)
x = rng.normal(size=(512, 6)).astype(np.float32)
y = (x @ rng.normal(size=6) > 0).astype(np.float32)
xv = rng.normal(size=(160, 6)).astype(np.float32)
yv = (xv @ rng.normal(size=6) > 0).astype(np.float32)
"""

VARIANTS = {
    "plain": dict(cfg_kw="", es="None", evals=False, external=False),
    "subsample": dict(cfg_kw="subsample=0.7, colsample_bytree=0.8,",
                      es="None", evals=False, external=False),
    "es": dict(cfg_kw="", es="3", evals=True, external=False),
    "external": dict(cfg_kw="", es="None", evals=False, external=True),
}


def _make_data():
    ns = {}
    exec(DATA_SETUP, ns)
    return ns["x"], ns["y"], ns["xv"], ns["yv"]


def _matrices(variant, x, y, xv, yv):
    v = VARIANTS[variant]
    if v["external"]:
        d = ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32,
                                        cuts="exact")
    else:
        d = DeviceDMatrix(x, label=y, max_bins=32)
    evals = [(DeviceDMatrix(xv, label=yv, ref=d), "val")] if v["evals"] \
        else []
    return d, evals


def _config(variant):
    kw = {}
    if variant == "subsample":
        kw = dict(subsample=0.7, colsample_bytree=0.8)
    return BoosterConfig(n_rounds=10, max_depth=3,
                         objective="binary:logistic", max_bins=32, **kw)


def _run_killed_fit(variant, ckpt_path, kill_round, every=3):
    """Child fits with checkpointing and SIGKILLs itself at kill_round."""
    v = VARIANTS[variant]
    matrix = (
        "ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32, "
        "cuts='exact')"
        if v["external"] else "DeviceDMatrix(x, label=y, max_bins=32)"
    )
    ev = ("[(DeviceDMatrix(xv, label=yv, ref=d), 'val')]"
          if v["evals"] else "[]")
    script = DATA_SETUP + textwrap.dedent(f"""
        import os, signal
        from repro.core import Booster, BoosterConfig, DeviceDMatrix, \\
            ExternalDMatrix
        cfg = BoosterConfig(n_rounds=10, max_depth=3, {v['cfg_kw']}
                            objective='binary:logistic', max_bins=32)
        d = {matrix}
        def cb(r, rec):
            if r >= {kill_round}:
                os.kill(os.getpid(), signal.SIGKILL)
        Booster(cfg).fit(d, evals={ev}, early_stopping_rounds={v['es']},
                         checkpoint_every={every},
                         checkpoint_path={ckpt_path!r}, callback=cb)
        print('FIT-COMPLETED')  # unreachable: the callback kills first
        """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert res.returncode == -signal.SIGKILL, (
        f"child should die by SIGKILL, got {res.returncode}:\n"
        f"{res.stdout}\n{res.stderr}"
    )
    assert "FIT-COMPLETED" not in res.stdout
    return res


def _assert_identical(ref, got, x):
    assert got.n_rounds_trained == ref.n_rounds_trained
    assert got.best_iteration == ref.best_iteration
    for f in ("feature", "split_bin", "threshold", "default_left",
              "leaf_value", "is_leaf"):
        assert bool(jnp.all(getattr(ref.ensemble, f)
                            == getattr(got.ensemble, f))), f
    np.testing.assert_array_equal(np.asarray(ref.predict(x)),
                                  np.asarray(got.predict(x)))
    np.testing.assert_array_equal(np.asarray(ref.predict_margins(x)),
                                  np.asarray(got.predict_margins(x)))


@pytest.mark.slow
@pytest.mark.parametrize("variant", list(VARIANTS))
def test_sigkill_then_resume_bit_identical(tmp_path, variant):
    x, y, xv, yv = _make_data()
    p = str(tmp_path / f"{variant}.ckpt")
    _run_killed_fit(variant, p, kill_round=5)
    assert os.path.exists(p), "no snapshot survived the kill"

    d, evals = _matrices(variant, x, y, xv, yv)
    ref = Booster(_config(variant)).fit(
        d, evals=evals,
        early_stopping_rounds=3 if variant == "es" else None,
    )
    d2, evals2 = _matrices(variant, x, y, xv, yv)
    got = Booster.resume(p, d2, evals=evals2)
    _assert_identical(ref, got, x)


@pytest.mark.slow
@pytest.mark.parametrize("kill_round", [4, 8])
def test_sigkill_at_various_rounds(tmp_path, kill_round):
    """The snapshot cadence (every 3 of 10 rounds) leaves different amounts
    of lost work depending on when the kill lands; resume is exact either
    way."""
    x, y, xv, yv = _make_data()
    p = str(tmp_path / "k.ckpt")
    _run_killed_fit("plain", p, kill_round=kill_round)
    d, _ = _matrices("plain", x, y, xv, yv)
    ref = Booster(_config("plain")).fit(d)
    d2, _ = _matrices("plain", x, y, xv, yv)
    got = Booster.resume(p, d2)
    _assert_identical(ref, got, x)


@pytest.mark.slow
def test_resume_survives_second_kill(tmp_path):
    """Resume is itself checkpointed: kill the resumed fit too, resume
    again, still bit-identical."""
    x, y, xv, yv = _make_data()
    p = str(tmp_path / "twice.ckpt")
    _run_killed_fit("plain", p, kill_round=4)
    # second child resumes from the snapshot and dies at round 8
    script = DATA_SETUP + textwrap.dedent(f"""
        import os, signal
        from repro.core import Booster, DeviceDMatrix
        d = DeviceDMatrix(x, label=y, max_bins=32)
        def cb(r, rec):
            if r >= 8:
                os.kill(os.getpid(), signal.SIGKILL)
        Booster.resume({p!r}, d, callback=cb)
        """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == -signal.SIGKILL, res.stderr

    d, _ = _matrices("plain", x, y, xv, yv)
    ref = Booster(_config("plain")).fit(d)
    d2, _ = _matrices("plain", x, y, xv, yv)
    got = Booster.resume(p, d2)
    _assert_identical(ref, got, x)
