"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of the
same family (2 layers, d_model<=512, <=4 experts) runs one forward/train
step on CPU; output shapes asserted, no NaNs. Full configs are exercised
only by the dry-run (launch/dryrun.py, ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import NO_SHARDING, build_model


def _batch(cfg, b=2, s=32, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.arch_type == "vlm":
        batch["prefix_embeds"] = (
            jax.random.normal(key, (b, cfg.n_prefix_tokens, cfg.d_model)) * 0.02
        )
    if cfg.arch_type in ("audio", "encdec"):
        batch["src_embeds"] = jax.random.normal(key, (b, 16, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    b, s = batch["tokens"].shape

    logits = model.forward_logits(params, batch, NO_SHARDING)
    exp_s = s + (cfg.n_prefix_tokens if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, NO_SHARDING)
    )(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ["glm4-9b", "yi-6b", "llama4-scout-17b-a16e",
                                  "mamba2-2.7b"])
def test_one_opt_step_reduces_loss(arch):
    from repro.optimizer import AdamWConfig, adamw_init, adamw_update

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch(cfg, key=jax.random.PRNGKey(2))
    acfg = AdamWConfig(lr=5e-3, weight_decay=0.0)
    state = adamw_init(params)

    @jax.jit
    def step(p, st):
        loss, g = jax.value_and_grad(lambda q: model.loss_fn(q, batch, NO_SHARDING))(p)
        p, st = adamw_update(p, g, st, acfg)
        return p, st, loss

    losses = []
    for _ in range(8):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ["yi-6b", "minicpm3-4b", "mamba2-2.7b",
                                  "zamba2-7b", "seamless-m4t-medium"])
def test_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.arch_type in ("audio", "encdec"):
        batch["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(5), (2, 8, cfg.d_model)) * 0.02
    full = model.forward_logits(params, batch, NO_SHARDING)

    cache = model.init_cache(2, T, dtype=jnp.float32)
    dec = jax.jit(lambda p, bb, c, i: model.decode_fn(p, bb, c, i, NO_SHARDING))
    outs = []
    for t in range(T):
        db = {"tokens": toks[:, t : t + 1]}
        if "src_embeds" in batch:
            db["src_embeds"] = batch["src_embeds"]
        logits, cache = dec(params, db, cache, t)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(got - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 2e-2, rel


def test_exact_assigned_specs():
    """The full configs must match the assignment table exactly."""
    c = get_arch("glm4-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    c = get_arch("zamba2-7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size, c.ssm_state) == (
        81, 3584, 14336, 32000, 64)
    c = get_arch("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.vocab_size, c.ssm_state) == (
        64, 2560, 50280, 128)
    c = get_arch("llama4-maverick-400b-a17b")
    assert (c.n_experts, c.top_k, c.vocab_size, c.d_model) == (128, 1, 202048, 5120)
    c = get_arch("llama4-scout-17b-a16e")
    assert (c.n_experts, c.top_k) == (16, 1)
    c = get_arch("minicpm3-4b")
    assert (c.n_layers, c.attention, c.vocab_size) == (62, "mla", 73448)
    c = get_arch("seamless-m4t-medium")
    assert (c.n_layers, c.n_enc_layers, c.vocab_size) == (12, 12, 256206)
    c = get_arch("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.n_prefix_tokens) == (32, 3072, 576)
    c = get_arch("yi-6b")
    assert (c.n_kv_heads, c.d_ff, c.vocab_size) == (4, 11008, 64000)
    c = get_arch("stablelm-12b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (40, 5120, 100352)


def test_int8_kv_cache_decode_matches_forward():
    """§2.2 compression applied to serving: int8 KV cache decode must track
    the full forward within quantisation noise."""
    import dataclasses

    cfg = dataclasses.replace(get_arch("glm4-9b").reduced(), kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, T), 0, cfg.vocab_size)
    full = model.forward_logits(params, {"tokens": toks}, NO_SHARDING)
    cache = model.init_cache(2, T)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    dec = jax.jit(lambda p, b, c, i: model.decode_fn(p, b, c, i, NO_SHARDING))
    outs = []
    for t in range(T):
        logits, cache = dec(params, {"tokens": toks[:, t : t + 1]}, cache, t)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(got - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 0.05, rel
    # the int8 cache is ~1.8x smaller than bf16
    import numpy as np
    int8_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    bf16 = model.init_cache(2, T, dtype=jnp.bfloat16)
    cfg2 = dataclasses.replace(cfg, kv_cache_dtype="bfloat16")
    bf16 = build_model(cfg2).init_cache(2, T, dtype=jnp.bfloat16)
    bf16_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(bf16))
    assert int8_bytes < 0.7 * bf16_bytes
