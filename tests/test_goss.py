"""GOSS (gradient-based one-side sampling) — DESIGN.md §17.

sampling_method="goss" keeps the top_rate fraction of rows by |gradient|
and uniformly samples other_rate of the remainder per tree, reweighting
the sampled rest by (1 - top_rate) / other_rate. The selection is a pure
function of (seed, round, class, global |g|), so it replays identically
across resume/update(), device counts, and the in-memory / resident /
streamed executors.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Booster, BoosterConfig, DeviceDMatrix, ExternalDMatrix
from repro.core import sampling as SMP

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ENSEMBLE_FIELDS = (
    "feature",
    "split_bin",
    "threshold",
    "default_left",
    "leaf_value",
    "is_leaf",
)


def assert_boosters_identical(b1, b2):
    e1, e2 = b1.ensemble, b2.ensemble
    for f in ENSEMBLE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(e1, f)),
            np.asarray(getattr(e2, f)),
            err_msg=f"ensemble field {f} differs",
        )


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(17)
    n, f = 3000, 8
    x = rng.standard_normal((n, f)).astype(np.float32)
    w = rng.standard_normal(f).astype(np.float32)
    y = (x @ w + 0.3 * rng.standard_normal(n) > 0).astype(np.float32)
    x[rng.random((n, f)) < 0.05] = np.nan
    return x, y, w


def _goss_kw(**over):
    kw = dict(
        n_rounds=6,
        max_depth=3,
        objective="binary:logistic",
        sampling_method="goss",
        top_rate=0.2,
        other_rate=0.1,
        seed=5,
    )
    kw.update(over)
    return kw


# --- config validation ------------------------------------------------------


def test_goss_config_validation():
    ok = dict(n_rounds=2, max_depth=2, objective="binary:logistic")
    with pytest.raises(ValueError, match="sampling_method"):
        BoosterConfig(**ok, sampling_method="lossguide")
    with pytest.raises(ValueError, match="top_rate"):
        BoosterConfig(**ok, sampling_method="goss", top_rate=0.0)
    with pytest.raises(ValueError, match="other_rate"):
        BoosterConfig(**ok, sampling_method="goss", other_rate=1.0)
    with pytest.raises(ValueError, match="must be <= 1.0"):
        BoosterConfig(**ok, sampling_method="goss", top_rate=0.7,
                      other_rate=0.6)
    with pytest.raises(ValueError, match="subsample"):
        BoosterConfig(**ok, sampling_method="goss", subsample=0.5)
    # the rates are inert under uniform sampling: no validation applies
    BoosterConfig(**ok, top_rate=0.0, other_rate=1.0)


def test_goss_selection_properties():
    """Unit contract of the selection kernel: exact sizes, top rows always
    kept, rest disjoint from top, pure function of (key, |g|, sizes)."""
    key = jax.random.key(3)
    g = jax.random.normal(jax.random.key(9), (500,))
    m_top, m_other = SMP.goss_sizes(
        500, SMP.StochasticParams(sampling_method="goss", top_rate=0.1,
                                  other_rate=0.2)
    )
    assert (m_top, m_other) == (50, 100)
    sel, rest = SMP.goss_selection(key, jnp.abs(g), m_top, m_other)
    sel, rest = np.asarray(sel), np.asarray(rest)
    assert sel.sum() == m_top + m_other
    assert rest.sum() == m_other
    top_ids = np.argsort(-np.abs(np.asarray(g)))[:m_top]
    assert sel[top_ids].all()
    assert not rest[top_ids].any()
    sel2, rest2 = SMP.goss_selection(key, jnp.abs(g), m_top, m_other)
    np.testing.assert_array_equal(sel, np.asarray(sel2))
    np.testing.assert_array_equal(rest, np.asarray(rest2))


# --- end-to-end determinism and executor parity -----------------------------


def test_goss_fit_deterministic_and_seed_sensitive(data):
    x, y, _ = data
    d = DeviceDMatrix(x, label=y)
    b1 = Booster(**_goss_kw()).fit(d)
    b2 = Booster(**_goss_kw()).fit(d)
    assert_boosters_identical(b1, b2)
    b3 = Booster(**_goss_kw(seed=6)).fit(d)
    with pytest.raises(AssertionError):
        assert_boosters_identical(b1, b3)
    # and GOSS actually changes the model vs full-data training
    b4 = Booster(**_goss_kw(sampling_method="uniform")).fit(d)
    with pytest.raises(AssertionError):
        assert_boosters_identical(b1, b4)


def test_goss_external_and_streamed_match_in_memory(data):
    """The same GOSS fit bit for bit across all three executors on shared
    cuts: in-memory, external resident (compiled chunked scan), external
    streamed (async pager)."""
    x, y, _ = data
    ext = ExternalDMatrix.from_arrays(
        x, y, chunk_rows=700, cuts="exact", paging="resident"
    )
    b_mem = Booster(**_goss_kw()).fit(DeviceDMatrix(x, label=y, cuts=ext.cuts))
    b_res = Booster(**_goss_kw()).fit(ext)
    b_str = Booster(**_goss_kw()).fit(
        ExternalDMatrix.from_arrays(
            x, y, chunk_rows=700, cuts="exact", paging="stream"
        )
    )
    assert_boosters_identical(b_mem, b_res)
    assert_boosters_identical(b_res, b_str)


def test_goss_update_continuation_matches_longer_fit(data):
    """The per-round key folds the ABSOLUTE round index, so update() replays
    the same selections a single longer fit would draw."""
    x, y, _ = data
    d = DeviceDMatrix(x, label=y)
    long = Booster(**_goss_kw(n_rounds=8)).fit(d)
    short = Booster(**_goss_kw(n_rounds=5)).fit(d)
    short.update(d, 3)
    assert_boosters_identical(long, short)


def test_goss_streamed_skips_rows_and_holds_accuracy(data):
    """The perf claim at test scale: GOSS touches a small fraction of the
    rows per round (top 10% + 10% of the rest) while staying competitive
    with full-data training on a holdout."""
    x, y, w = data
    rng = np.random.default_rng(23)
    xv = rng.standard_normal((1500, x.shape[1])).astype(np.float32)
    yv = (xv @ w + 0.3 * rng.standard_normal(1500) > 0).astype(np.float32)
    touched, errs = {}, {}
    for name, over in (
        ("full", dict(sampling_method="uniform")),
        ("goss", dict(top_rate=0.1, other_rate=0.1)),
    ):
        ext = ExternalDMatrix.from_arrays(
            x, y, chunk_rows=500, cuts="exact", paging="stream"
        )
        b = Booster(**_goss_kw(n_rounds=20, max_depth=4, **over)).fit(ext)
        touched[name] = ext.stream_stats.rows_touched
        errs[name] = float(
            np.mean((np.asarray(b.predict(xv)) > 0.5) != yv)
        )
    # >= 3x reduction in histogram rows touched (ISSUE acceptance bar)
    assert touched["goss"] <= touched["full"] / 3, touched
    assert errs["full"] < 0.35, errs
    assert errs["goss"] < errs["full"] + 0.05, errs


def test_goss_sharded_equals_single_device():
    """8-device GOSS parity, to the repo's distributed-stochastic
    convention: identical tree structure, leaf values within 1e-4 (compact
    single-device build vs masked sharded build associate f32 sums
    differently)."""
    script = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import Booster, BoosterConfig, DeviceDMatrix
        from repro.jaxcompat import make_mesh
        rng = np.random.default_rng(4)
        n, f = 1024, 6
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x @ rng.normal(size=f) > 0).astype(np.float32)
        cfg = BoosterConfig(n_rounds=4, max_depth=3,
                            objective="binary:logistic", max_bins=32,
                            sampling_method="goss", top_rate=0.2,
                            other_rate=0.1, seed=11)
        dtrain = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
        st = Booster(cfg).fit(dtrain)
        mesh = make_mesh((8,), ("data",))
        bst = Booster(cfg).fit(dtrain, mesh=mesh)
        for fld in ("feature", "split_bin", "default_left", "is_leaf"):
            a = getattr(st.ensemble, fld)
            b = getattr(bst.ensemble, fld)
            assert bool(jnp.all(a == b)), fld
        d = float(jnp.max(jnp.abs(st.ensemble.leaf_value
                                  - bst.ensemble.leaf_value)))
        assert d < 1e-4, d
        print("GOSS-SHARDED-OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "GOSS-SHARDED-OK" in res.stdout
