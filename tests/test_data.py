"""Data pipeline: paper-dataset generators + LM token stream."""
import numpy as np

from repro.data import DATASETS, TokenStream, make_dataset


def test_specs_match_paper_table1():
    assert DATASETS["year_prediction"].n_rows == 515_345
    assert DATASETS["year_prediction"].n_features == 90
    assert DATASETS["synthetic"].n_rows == 10_000_000
    assert DATASETS["higgs"].n_features == 28
    assert DATASETS["covtype"].n_classes == 7
    assert DATASETS["bosch"].n_features == 968
    assert DATASETS["airline"].n_rows == 115_000_000
    assert DATASETS["airline"].n_features == 13


def test_generator_shapes_and_tasks():
    for name in DATASETS:
        x, y, spec = make_dataset(name, n_rows=500)
        assert x.shape == (500, spec.n_features)
        assert y.shape == (500,)
        if spec.task == "multiclass":
            assert set(np.unique(y)).issubset(set(range(spec.n_classes)))
        elif spec.task == "binary":
            assert set(np.unique(y)).issubset({0.0, 1.0})


def test_bosch_missingness():
    x, _, spec = make_dataset("bosch", n_rows=2000)
    frac = float(np.mean(np.isnan(x)))
    assert abs(frac - spec.missing_frac) < 0.02


def test_generator_deterministic():
    x1, y1, _ = make_dataset("higgs", n_rows=100, seed=7)
    x2, y2, _ = make_dataset("higgs", n_rows=100, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_token_stream():
    ts = TokenStream(vocab_size=1000, batch=4, seq_len=32, seed=3)
    toks, tgts = ts.next_batch()
    assert toks.shape == (4, 32) and tgts.shape == (4, 32)
    assert toks.max() < 1000 and toks.min() >= 0
    np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])
    # deterministic across constructions
    t2, _ = TokenStream(vocab_size=1000, batch=4, seq_len=32, seed=3).next_batch()
    np.testing.assert_array_equal(toks, t2)


def test_token_stream_learnable_structure():
    """The planted bigram makes successor entropy < unigram entropy."""
    ts = TokenStream(vocab_size=512, batch=64, seq_len=64, seed=0)
    toks, tgts = ts.next_batch()
    follows = (ts.succ[toks.ravel()] == tgts.ravel()).mean()
    assert follows > 0.4  # ~50% planted
