"""Fault-tolerant training runtime (DESIGN.md §13).

Chaos suite: every test arms a fault via repro.testing.faults against the
REAL production code path (no monkeypatching) and asserts the resilience
machinery — checkpoint framing, input validation, numeric sentinels,
chunk integrity + retry, OOM degradation, checkpoint/resume bit-identity —
responds as specified.
"""
import os
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Booster,
    BoosterConfig,
    CheckpointError,
    ChunkIntegrityError,
    DeviceDMatrix,
    ExternalDMatrix,
    NumericError,
)
from repro.checkpoint import io as CIO
from repro.testing import faults


@pytest.fixture(autouse=True)
def _no_leftover_faults():
    faults.reset()
    yield
    faults.reset()


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(512, 6)).astype(np.float32)
    y = (x @ rng.normal(size=6) > 0).astype(np.float32)
    return x, y


def _cfg(**kw):
    base = dict(n_rounds=6, max_depth=3, objective="binary:logistic",
                max_bins=32)
    base.update(kw)
    return BoosterConfig(**base)


# --------------------------------------------------------------------------
# Checkpoint framing: magic + crc32, corruption and truncation detection
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_frame(tmp_path):
    p = str(tmp_path / "t.ckpt")
    tree = {"a": jnp.arange(5.0), "n": 3, "t": (jnp.ones(2), "x")}
    CIO.save_pytree(p, tree)
    with open(p, "rb") as f:
        assert f.read(8) == CIO.MAGIC
    out = CIO.load_pytree(p)
    assert out["n"] == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(5.0))


def test_checkpoint_bit_flip_detected(tmp_path, data):
    """Flipping any single byte of a real booster checkpoint is caught by
    the payload crc32 and reported with the file name."""
    x, y = data
    p = str(tmp_path / "b.ckpt")
    b = Booster(_cfg(n_rounds=3)).fit(DeviceDMatrix(x, label=y, max_bins=32))
    b.save(p)
    raw = bytearray(open(p, "rb").read())
    size = len(raw)
    # a spread of positions inside the payload (past the 12-byte header)
    for pos in (12, size // 3, size // 2, size - 1):
        bad = bytearray(raw)
        bad[pos] ^= 0x40
        with open(p, "wb") as f:
            f.write(bad)
        with pytest.raises(CheckpointError, match="checksum"):
            CIO.load_booster(p)
    # header crc corruption is also caught
    bad = bytearray(raw)
    bad[9] ^= 0x01
    with open(p, "wb") as f:
        f.write(bad)
    with pytest.raises(CheckpointError):
        CIO.load_booster(p)


def test_checkpoint_truncation_detected(tmp_path, data):
    x, y = data
    p = str(tmp_path / "t.ckpt")
    b = Booster(_cfg(n_rounds=2)).fit(DeviceDMatrix(x, label=y, max_bins=32))
    b.save(p)
    raw = open(p, "rb").read()
    for cut in (5, 11, len(raw) // 2):
        with open(p, "wb") as f:
            f.write(raw[:cut])
        with pytest.raises(CheckpointError):
            CIO.load_pytree(p)


def test_checkpoint_missing_and_garbage(tmp_path):
    with pytest.raises(CheckpointError, match="cannot read"):
        CIO.load_pytree(str(tmp_path / "nope.ckpt"))
    p = str(tmp_path / "garbage.ckpt")
    with open(p, "wb") as f:
        f.write(b"not a checkpoint at all, definitely not msgpack" * 3)
    with pytest.raises(CheckpointError):
        CIO.load_pytree(p)
    # CheckpointError subclasses ValueError: pre-existing callers keep working
    assert issubclass(CheckpointError, ValueError)


def test_checkpoint_legacy_unframed_readable(tmp_path):
    """Files written before the magic+crc frame (raw msgpack) still load."""
    import msgpack

    p = str(tmp_path / "legacy.ckpt")
    payload = msgpack.packb({"n": 7, "s": "old"}, use_bin_type=True)
    with open(p, "wb") as f:
        f.write(payload)
    assert CIO.load_pytree(p) == {"n": 7, "s": "old"}


def test_checkpoint_write_fault_is_atomic(tmp_path):
    """An injected write failure leaves no file (and no tmp litter)."""
    p = str(tmp_path / "w.ckpt")
    with faults.inject("checkpoint_write", error=OSError):
        with pytest.raises(OSError):
            CIO.save_pytree(p, {"a": 1})
    assert not os.path.exists(p)
    assert os.listdir(str(tmp_path)) == []


# --------------------------------------------------------------------------
# Input validation
# --------------------------------------------------------------------------

def test_device_dmatrix_rejects_bad_inputs(data):
    x, y = data
    with pytest.raises(ValueError, match="0 rows"):
        DeviceDMatrix(np.empty((0, 4), np.float32))
    with pytest.raises(ValueError, match="0 features"):
        DeviceDMatrix(np.empty((4, 0), np.float32))
    xb = x.copy()
    xb[3, 2] = np.inf
    with pytest.raises(ValueError, match="inf"):
        DeviceDMatrix(xb, label=y)
    yb = y.copy()
    yb[5] = np.nan
    with pytest.raises(ValueError, match="label"):
        DeviceDMatrix(x, label=yb)
    # NaN features stay legal: they are the missing-value marker
    xn = x.copy()
    xn[1, 1] = np.nan
    DeviceDMatrix(xn, label=y)


def test_external_dmatrix_rejects_bad_inputs(data):
    x, y = data
    xb = x.copy()
    xb[200, 3] = -np.inf
    with pytest.raises(ValueError, match="inf"):
        ExternalDMatrix.from_arrays(xb, y, chunk_rows=128, max_bins=32)
    yb = y.copy()
    yb[300] = np.inf
    with pytest.raises(ValueError, match="label"):
        ExternalDMatrix.from_arrays(x, yb, chunk_rows=128, max_bins=32)


# --------------------------------------------------------------------------
# Numeric sentinels (nan_grad fault drives the in-scan finite checks)
# --------------------------------------------------------------------------

def test_numeric_check_raise(data):
    x, y = data
    with faults.inject("nan_grad", round=3):
        with pytest.raises(NumericError, match=r"round\(s\) \[3"):
            Booster(_cfg(numeric_check="raise")).fit(
                DeviceDMatrix(x, label=y, max_bins=32)
            )


def test_numeric_check_warn_skip(data):
    """The poisoned round's tree is zeroed, margins stay clean, and only
    that round is skipped — later rounds train on unpolluted state."""
    x, y = data
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("nan_grad", round=2):
            b = Booster(_cfg(numeric_check="warn_skip")).fit(
                DeviceDMatrix(x, label=y, max_bins=32)
            )
    assert b.skipped_rounds == [2]
    assert any("zeroed" in str(m.message) for m in w)
    assert b.n_rounds_trained == 6
    pred = np.asarray(b.predict(x))
    assert np.isfinite(pred).all()
    # the skipped tree contributes nothing: leaf values all zero at round 2
    leaf = np.asarray(b.ensemble.leaf_value).reshape(6, -1)
    assert (leaf[2] == 0).all()
    assert (leaf[3] != 0).any()
    assert [e["event"] for e in b.resilience_events] == ["rounds_skipped"]


def test_numeric_check_clamp(data):
    x, y = data
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("nan_grad", round=2):
            b = Booster(_cfg(numeric_check="clamp")).fit(
                DeviceDMatrix(x, label=y, max_bins=32)
            )
    assert any("clip" in str(m.message) for m in w)
    assert np.isfinite(np.asarray(b.predict(x))).all()
    assert [e["event"] for e in b.resilience_events] == ["gradients_clamped"]


def test_numeric_check_off_is_default_and_validated(data):
    x, y = data
    assert BoosterConfig().numeric_check == "off"
    with pytest.raises(ValueError, match="numeric_check"):
        BoosterConfig(numeric_check="nope")
    # off + armed fault: NaNs flow through unchecked (policy off means the
    # sentinel adds nothing to the traced program)
    with faults.inject("nan_grad", round=0):
        b = Booster(_cfg()).fit(DeviceDMatrix(x, label=y, max_bins=32))
    assert not np.isfinite(np.asarray(b.predict_margins(x))).all()


def test_sentinel_clean_fit_unchanged(data):
    """With no fault armed, every policy trains the identical model — the
    sentinel observes, it must not perturb."""
    x, y = data
    ref = Booster(_cfg()).fit(DeviceDMatrix(x, label=y, max_bins=32))
    for policy in ("raise", "warn_skip", "clamp"):
        b = Booster(_cfg(numeric_check=policy)).fit(
            DeviceDMatrix(x, label=y, max_bins=32)
        )
        assert bool(jnp.all(ref.ensemble.leaf_value == b.ensemble.leaf_value))
        assert b.skipped_rounds == []


# --------------------------------------------------------------------------
# External-memory chunk integrity + retry + OOM degradation
# --------------------------------------------------------------------------

def test_chunk_corruption_detected(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32)
    with faults.inject("chunk_corrupt", times=None, chunk=1, index=7, bit=3):
        with pytest.raises(ChunkIntegrityError, match=r"chunk\(s\) \[1\]"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                ext.packed_bins()


def test_chunk_corruption_transient_retried(data):
    """One corrupted transfer followed by a clean one: retry absorbs it."""
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("chunk_corrupt", times=1, chunk=0, index=2) as spec:
            pb = ext.packed_bins()
    assert spec.fired == 1
    assert pb.n_rows == x.shape[0]
    assert any("retry" in str(m.message) for m in w)


def test_chunk_load_transient_retried(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        with faults.inject("chunk_load", error=faults.TransientLoadError,
                           times=2) as spec:
            pb = ext.packed_bins()
    assert spec.fired == 2  # default load_retries=2 absorbs both
    assert pb.n_rows == x.shape[0]


def test_chunk_load_persistent_raises(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32,
                                      load_retries=1, load_backoff=0.0)
    with faults.inject("chunk_load", error=faults.TransientLoadError,
                       times=None):
        with pytest.raises(faults.TransientLoadError):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                ext.packed_bins()


def test_verify_chunks_off_skips_crc(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=128, max_bins=32,
                                      verify_chunks=False)
    with faults.inject("chunk_corrupt", times=None, chunk=0, index=0):
        ext.packed_bins()  # corruption sails through unverified


def test_rechunk_and_from_dmatrix_bit_identical(data):
    """The OOM degradation paths (DeviceDMatrix -> external, external ->
    smaller chunks) train bit-identical models on the same data."""
    x, y = data
    cfg = _cfg(n_rounds=4)
    dm = DeviceDMatrix(x, label=y, max_bins=32)
    ref = Booster(cfg).fit(dm)
    ext = ExternalDMatrix.from_dmatrix(dm, chunk_rows=200)
    b1 = Booster(cfg).fit(ext)
    assert bool(jnp.all(ref.ensemble.leaf_value == b1.ensemble.leaf_value))
    b2 = Booster(cfg).fit(ext.rechunk(100))
    assert bool(jnp.all(ref.ensemble.leaf_value == b2.ensemble.leaf_value))


def test_on_oom_external_completes(data):
    x, y = data
    cfg = _cfg(n_rounds=5)
    ref = Booster(cfg).fit(DeviceDMatrix(x, label=y, max_bins=32))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("oom", error=faults.SimulatedOOM, times=1):
            b = Booster(cfg).fit(DeviceDMatrix(x, label=y, max_bins=32),
                                 on_oom="external")
    assert b.n_rounds_trained == 5
    assert any("external-memory" in str(m.message) for m in w)
    assert [e["event"] for e in b.resilience_events] == ["oom_fallback"]
    # bit-identical to the in-memory fit (same bins, same cuts)
    assert bool(jnp.all(ref.ensemble.leaf_value == b.ensemble.leaf_value))


def test_on_oom_external_halves_until_fits(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=256, max_bins=32)
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        with faults.inject("oom", error=faults.SimulatedOOM, times=2):
            b = Booster(_cfg(n_rounds=3)).fit(ext, on_oom="external")
    assert b.n_rounds_trained == 3
    rows = [e["chunk_rows"] for e in b.resilience_events
            if e["event"] == "oom_fallback"]
    assert rows == [128, 64]


def test_on_oom_raise_default(data):
    x, y = data
    with faults.inject("oom", error=faults.SimulatedOOM, times=1):
        with pytest.raises(faults.SimulatedOOM):
            Booster(_cfg(n_rounds=3)).fit(
                DeviceDMatrix(x, label=y, max_bins=32)
            )


# --------------------------------------------------------------------------
# In-run checkpointing + resume (in-process; kill-based tests live in
# test_kill_resume.py)
# --------------------------------------------------------------------------

class _Stop(Exception):
    pass


def _interrupted_fit(cfg, mk, path, stop_round, evals=False, es=None,
                     every=3):
    """Fit with checkpointing, aborting from the round callback — the
    in-process stand-in for a kill."""
    b = Booster(cfg)

    def cb(r, rec):
        if r >= stop_round:
            raise _Stop

    kw = dict(checkpoint_every=every, checkpoint_path=path, callback=cb)
    d = mk()
    ev = [(mk.eval(d), "val")] if evals else []
    try:
        b.fit(d, evals=ev, early_stopping_rounds=es, **kw)
    except _Stop:
        pass


def _mk_factory(x, y, xv=None, yv=None, external=False):
    def mk():
        if external:
            return ExternalDMatrix.from_arrays(x, y, chunk_rows=128,
                                               max_bins=32, cuts="exact")
        return DeviceDMatrix(x, label=y, max_bins=32)

    def mk_eval(d):
        return DeviceDMatrix(xv, label=yv, ref=d)

    mk.eval = mk_eval
    return mk


@pytest.fixture(scope="module")
def eval_data():
    rng = np.random.default_rng(11)
    xv = rng.normal(size=(200, 6)).astype(np.float32)
    yv = (xv @ rng.normal(size=6) > 0).astype(np.float32)
    return xv, yv


@pytest.mark.parametrize("variant", ["plain", "subsample", "es", "external"])
def test_resume_bit_identical(tmp_path, data, eval_data, variant):
    x, y = data
    xv, yv = eval_data
    kw = {}
    es = None
    evals = False
    external = False
    if variant == "subsample":
        kw = dict(subsample=0.7, colsample_bytree=0.8)
    elif variant == "es":
        es, evals = 3, True
    elif variant == "external":
        external = True
    cfg = _cfg(n_rounds=10, **kw)
    mk = _mk_factory(x, y, xv, yv, external=external)

    d = mk()
    ev = [(mk.eval(d), "val")] if evals else []
    ref = Booster(cfg).fit(d, evals=ev, early_stopping_rounds=es)

    p = str(tmp_path / "run.ckpt")
    _interrupted_fit(cfg, mk, p, stop_round=5, evals=evals, es=es)
    assert os.path.exists(p)
    d2 = mk()
    ev2 = [(mk.eval(d2), "val")] if evals else []
    r = Booster.resume(p, d2, evals=ev2)

    assert r.n_rounds_trained == ref.n_rounds_trained
    assert r.best_iteration == ref.best_iteration
    for f in ("feature", "split_bin", "threshold", "leaf_value", "is_leaf"):
        assert bool(jnp.all(getattr(ref.ensemble, f)
                            == getattr(r.ensemble, f))), f
    np.testing.assert_array_equal(np.asarray(ref.predict(x)),
                                  np.asarray(r.predict(x)))


def test_resume_completed_checkpoint_rejected(tmp_path, data):
    x, y = data
    p = str(tmp_path / "done.ckpt")
    b = Booster(_cfg(n_rounds=3)).fit(DeviceDMatrix(x, label=y, max_bins=32))
    b.save(p)
    with pytest.raises(CheckpointError, match="COMPLETED"):
        Booster.resume(p, DeviceDMatrix(x, label=y, max_bins=32))


def test_resume_wrong_cuts_rejected(tmp_path, data):
    x, y = data
    p = str(tmp_path / "run.ckpt")
    mk = _mk_factory(x, y)
    _interrupted_fit(_cfg(n_rounds=8), mk, p, stop_round=4)
    with pytest.raises(ValueError, match="cuts"):
        Booster.resume(p, DeviceDMatrix(x, label=y, max_bins=16))


def test_final_checkpoint_is_complete(tmp_path, data):
    """After an uninterrupted checkpointed fit, the file holds a COMPLETED
    model (no resume section) loadable with Booster.load."""
    x, y = data
    p = str(tmp_path / "run.ckpt")
    b = Booster(_cfg(n_rounds=5)).fit(DeviceDMatrix(x, label=y, max_bins=32),
                                      checkpoint_every=2, checkpoint_path=p)
    bst, rs = CIO.load_booster_with_resume(p)
    assert rs is None
    assert bst.n_rounds_trained == 5
    np.testing.assert_array_equal(np.asarray(b.predict(x)),
                                  np.asarray(bst.predict(x)))


def test_checkpoint_write_failure_does_not_kill_training(tmp_path, data):
    x, y = data
    p = str(tmp_path / "run.ckpt")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("checkpoint_write", error=OSError, times=None):
            b = Booster(_cfg(n_rounds=5)).fit(
                DeviceDMatrix(x, label=y, max_bins=32),
                checkpoint_every=2, checkpoint_path=p,
            )
    assert b.n_rounds_trained == 5
    assert any("training continues" in str(m.message) for m in w)
    assert any(e["event"] == "checkpoint_write_failed"
               for e in b.resilience_events)
    assert not os.path.exists(p)


def test_checkpoint_every_validation(data):
    x, y = data
    d = DeviceDMatrix(x, label=y, max_bins=32)
    with pytest.raises(ValueError, match="checkpoint_path"):
        Booster(_cfg()).fit(d, checkpoint_every=2)
    with pytest.raises(ValueError, match="positive"):
        Booster(_cfg()).fit(d, checkpoint_every=0, checkpoint_path="x.ckpt")
    with pytest.raises(ValueError, match="on_oom"):
        Booster(_cfg()).fit(d, on_oom="panic")


# --------------------------------------------------------------------------
# Fault harness self-tests
# --------------------------------------------------------------------------

def test_fault_harness_contract():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.arm("no_such_site")
    spec = faults.arm("oom", times=2, after=1)
    assert not spec.should_fire()  # skipped by after=1
    assert spec.should_fire()
    assert spec.should_fire()
    assert not spec.should_fire()  # budget exhausted
    faults.reset()
    assert faults.active("oom") is None
    # corrupt_array never mutates its input
    a = np.arange(8, dtype=np.uint32).reshape(2, 4)
    with faults.inject("chunk_corrupt", chunk=1, index=2, bit=5):
        out = faults.corrupt_array("chunk_corrupt", a)
    assert (a == np.arange(8, dtype=np.uint32).reshape(2, 4)).all()
    assert (out != a).sum() == 1
    # trace_key distinguishes payloads and clears on disarm
    with faults.inject("nan_grad", round=3):
        k1 = faults.trace_key("nan_grad")
    with faults.inject("nan_grad", round=4):
        k2 = faults.trace_key("nan_grad")
    assert k1 != k2 and k1 is not None
    assert faults.trace_key("nan_grad") is None


# --------------------------------------------------------------------------
# Streamed paging (DESIGN.md §17): faults inside the async prefetch ring
# --------------------------------------------------------------------------

def _stream_ext(x, y, **kw):
    base = dict(chunk_rows=128, max_bins=32, cuts="exact", paging="stream")
    base.update(kw)
    return ExternalDMatrix.from_arrays(x, y, **base)


def test_streamed_prefetch_transient_fault_retried(data):
    """A transient load failure inside the background pager thread is
    retried by _load_chunk's own retry policy without corrupting the ring:
    the fit completes and is bit-identical to an unfaulted streamed fit."""
    x, y = data
    clean = Booster(_cfg()).fit(_stream_ext(x, y))
    ext = _stream_ext(x, y)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("chunk_load", error=faults.TransientLoadError,
                           times=2) as spec:
            faulted = Booster(_cfg()).fit(ext)
    assert spec.fired == 2  # default load_retries=2 absorbed both
    assert any("retry" in str(m.message) for m in w)
    assert (clean.ensemble.leaf_value == faulted.ensemble.leaf_value).all()
    assert (clean.ensemble.feature == faulted.ensemble.feature).all()


def test_streamed_prefetch_persistent_fault_raises(data):
    """When retries are exhausted the worker forwards the error through the
    queue, stops producing, and the consumer re-raises it."""
    x, y = data
    ext = _stream_ext(x, y, load_retries=1, load_backoff=0.0)
    with faults.inject("chunk_load", error=faults.TransientLoadError,
                       times=None):
        with pytest.raises(faults.TransientLoadError):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                Booster(_cfg()).fit(ext)


def test_streamed_corruption_detected_and_retried(data):
    """crc verification runs on first page-in of each chunk: a one-shot
    corrupted transfer is detected and absorbed by the retry."""
    x, y = data
    clean = Booster(_cfg()).fit(_stream_ext(x, y))
    ext = _stream_ext(x, y)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faults.inject("chunk_corrupt", times=1, chunk=0,
                           index=2) as spec:
            faulted = Booster(_cfg()).fit(ext)
    assert spec.fired == 1
    assert any("retry" in str(m.message) for m in w)
    assert (clean.ensemble.leaf_value == faulted.ensemble.leaf_value).all()


def test_verify_once_vs_always_on_repaged_chunks(data):
    """The verify_chunks policy split: "once" trusts chunks it has already
    verified (later corrupted transfers sail through unchecked), "always"
    re-checks the crc on EVERY page-in and catches them."""
    x, y = data
    for policy, caught in (("once", False), ("always", True)):
        ext = _stream_ext(x, y, verify_chunks=policy)
        Booster(_cfg(n_rounds=2)).fit(ext)  # every chunk verified once
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with faults.inject("chunk_corrupt", times=1, chunk=0,
                               index=2) as spec:
                Booster(_cfg(n_rounds=2)).fit(ext)
        assert spec.fired == 1
        retried = any("retry" in str(m.message) for m in w)
        assert retried == caught, (policy, [str(m.message) for m in w])
