"""The serving subsystem (DESIGN.md §14): fused ensemble traversal,
PredictEngine shape-bucketed caches, and the streaming ExternalDMatrix
predict path.

The fused traversal's contract is BIT-IDENTITY with core.predict's
per-tree scan (same leaves, same class-fold order) — asserted exactly, not
to tolerance. The engine's contract is zero recompiles across mixed batch
sizes after warmup — asserted with the trace-counter idiom (the counter
bumps at trace time only). The Pallas kernel is validated in interpret
mode against the XLA oracle (matmul accumulation differs, so to
tolerance).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Booster, DeviceDMatrix, ExternalDMatrix
from repro.core import predict as PR
from repro.kernels import ref as KREF
from repro.kernels.ops import ensemble_margins_op
from repro.serve import PredictEngine
from repro.serve import traversal as TV


@pytest.fixture(scope="module")
def binary():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(600, 7)).astype(np.float32)
    x[rng.random(x.shape) < 0.12] = np.nan
    y = (np.nan_to_num(x[:, 0]) + np.nan_to_num(x[:, 2])
         + 0.3 * rng.normal(size=600) > 0).astype(np.float32)
    d = DeviceDMatrix(x, label=y, max_bins=64)
    bst = Booster(n_rounds=7, max_depth=4, max_bins=64,
                  objective="binary:logistic", seed=0).fit(d)
    return bst, d, x, y


@pytest.fixture(scope="module")
def multiclass():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(500, 5)).astype(np.float32)
    x[rng.random(x.shape) < 0.1] = np.nan
    y = (np.nan_to_num(x[:, 0]) > 0).astype(np.float32) \
        + (np.nan_to_num(x[:, 1]) > 0.5)
    d = DeviceDMatrix(x, label=y.astype(np.float32), max_bins=32)
    bst = Booster(n_rounds=5, max_depth=3, max_bins=32,
                  objective="multi:softmax", n_classes=3, seed=1).fit(d)
    return bst, d, x


# --- fused traversal: bit-identity with the per-tree scan -------------------

def test_fused_raw_bit_identical(binary):
    bst, _, x, _ = binary
    ens, md = bst.ensemble, bst.ensemble.max_depth
    ref = PR.predict_raw(ens, jnp.asarray(x), md)
    fused = TV.predict_margins_fused(ens, jnp.asarray(x), md)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))


def test_fused_raw_bit_identical_multiclass(multiclass):
    bst, _, x = multiclass
    ens, md = bst.ensemble, bst.ensemble.max_depth
    ref = PR.predict_raw(ens, jnp.asarray(x), md)
    fused = TV.predict_margins_fused(ens, jnp.asarray(x), md)
    assert ref.shape == (x.shape[0], 3)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))


def test_fused_packed_bit_identical(binary):
    bst, d, _, _ = binary
    ens, md = bst.ensemble, bst.ensemble.max_depth
    pb = d.matrix.as_packed_bins()
    mb = d.max_bins - 1
    ref = PR.predict_binned_packed(ens, pb.packed, pb.bits, d.n_rows, mb, md)
    fused = TV.predict_margins_fused_packed(
        ens, pb.packed, pb.bits, d.n_rows, mb, md
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))


def test_fused_chunked_bit_identical(binary):
    bst, d, x, y = binary
    ens, md = bst.ensemble, bst.ensemble.max_depth
    ed = ExternalDMatrix.from_arrays(
        x, label=y, chunk_rows=128, max_bins=64, cuts=np.asarray(d.cuts)
    )
    cpb = ed.packed_bins()
    mb = d.max_bins - 1
    ref = PR.predict_binned_chunked(
        ens, cpb.packed, cpb.bits, cpb.chunk_rows, cpb.n_rows, mb, md
    )
    fused = TV.predict_margins_fused_chunked(
        ens, cpb.packed, cpb.bits, cpb.chunk_rows, cpb.n_rows, mb, md
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))


def test_booster_predict_routes_through_fused(binary):
    """Booster.predict on arrays / DeviceDMatrix stays exactly what the
    per-tree scan produced before the fused path replaced it."""
    bst, d, x, _ = binary
    ens, md = bst.ensemble, bst.ensemble.max_depth
    np.testing.assert_array_equal(
        np.asarray(bst.predict_margins(x)),
        np.asarray(PR.predict_raw(ens, jnp.asarray(x), md)),
    )
    pb = d.matrix.as_packed_bins()
    np.testing.assert_array_equal(
        np.asarray(bst.predict_margins(d)),
        np.asarray(PR.predict_binned_packed(
            ens, pb.packed, pb.bits, d.n_rows, d.max_bins - 1, md
        )),
    )


# --- Pallas kernel (interpret mode) -----------------------------------------

def test_kernel_matches_oracle(binary):
    bst, _, x, _ = binary
    ens, md = bst.ensemble, bst.ensemble.max_depth
    got = ensemble_margins_op(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, jnp.asarray(x), ens.n_classes, md,
    )
    want = KREF.ensemble_margins_ref(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, jnp.asarray(x), ens.n_classes, md,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


def test_kernel_matches_oracle_multiclass(multiclass):
    bst, _, x = multiclass
    ens, md = bst.ensemble, bst.ensemble.max_depth
    got = ensemble_margins_op(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, jnp.asarray(x), ens.n_classes, md,
    )
    want = KREF.ensemble_margins_ref(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, jnp.asarray(x), ens.n_classes, md,
    )
    assert got.shape == (x.shape[0], 3)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


def test_kernel_small_block_sizes(binary):
    """Blocking must not change results: odd row counts and tiny blocks
    exercise padding rows (NaN) and padding trees (zero class weight)."""
    from repro.kernels.ensemble_traversal import ensemble_margins_kernel

    bst, _, x, _ = binary
    ens, md = bst.ensemble, bst.ensemble.max_depth
    got = ensemble_margins_kernel(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, jnp.asarray(x[:193]), ens.n_classes, md,
        trees_blk=4, rows_blk=64,
    )
    want = KREF.ensemble_margins_ref(
        ens.feature, ens.threshold, ens.default_left, ens.leaf_value,
        ens.is_leaf, jnp.asarray(x[:193]), ens.n_classes, md,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


# --- iteration_range / output_margin ----------------------------------------

def test_iteration_range_default_is_full_model(binary):
    bst, _, x, _ = binary
    np.testing.assert_array_equal(
        np.asarray(bst.predict_margins(x, iteration_range=(0, 0))),
        np.asarray(bst.predict_margins(x)),
    )


def test_iteration_range_staged_sum(binary):
    """Margins over [0,a) and [a,n) sum to the full model (one base_score)."""
    bst, _, x, _ = binary
    full = np.asarray(bst.predict_margins(x))
    head = np.asarray(bst.predict_margins(x, iteration_range=(0, 3)))
    tail = np.asarray(bst.predict_margins(x, iteration_range=(3, 0)))
    np.testing.assert_allclose(
        head + tail - bst.base_score, full, rtol=1e-5, atol=1e-6
    )


def test_iteration_range_multiclass_slices_rounds_not_trees(multiclass):
    bst, _, x = multiclass
    m = bst.predict_margins(x, iteration_range=(0, 2))
    assert m.shape == (x.shape[0], 3)
    sliced = PR.slice_rounds(bst.ensemble, 0, 2)
    assert sliced.n_trees == 2 * 3


def test_iteration_range_invalid_raises(binary):
    bst, _, x, _ = binary
    with pytest.raises(ValueError, match="iteration_range"):
        bst.predict_margins(x, iteration_range=(5, 3))
    with pytest.raises(ValueError, match="iteration_range"):
        bst.predict_margins(x, iteration_range=(0, 99))


def test_output_margin_matches_margins(binary):
    bst, _, x, _ = binary
    np.testing.assert_array_equal(
        np.asarray(bst.predict(x, output_margin=True)),
        np.asarray(bst.predict_margins(x)),
    )
    p = np.asarray(bst.predict(x))
    assert p.min() >= 0.0 and p.max() <= 1.0  # sigmoid applied


# --- ExternalDMatrix streaming predict --------------------------------------

def test_external_predict_streams_without_full_page_in(binary):
    """The satellite bugfix: predict on a paged-out ExternalDMatrix must
    stream chunk-by-chunk — never materialising the full device stack —
    and stay bit-identical to the DeviceDMatrix answer."""
    bst, d, x, y = binary
    ed = ExternalDMatrix.from_arrays(
        x, label=y, chunk_rows=150, max_bins=64, cuts=np.asarray(d.cuts)
    )
    assert ed.nbytes_device == 0
    got = bst.predict_margins(ed)
    assert ed.nbytes_device == 0, "predict paged in the full chunk stack"
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(bst.predict_margins(d))
    )


def test_external_predict_uses_resident_stack_when_paged_in(binary):
    bst, d, x, y = binary
    ed = ExternalDMatrix.from_arrays(
        x, label=y, chunk_rows=150, max_bins=64, cuts=np.asarray(d.cuts)
    )
    ed.packed_bins()  # training-style page-in
    assert ed.nbytes_device > 0
    np.testing.assert_array_equal(
        np.asarray(bst.predict_margins(ed)),
        np.asarray(bst.predict_margins(d)),
    )


# --- PredictEngine ----------------------------------------------------------

def test_engine_no_recompile_across_mixed_batches(binary):
    bst, _, x, _ = binary
    eng = PredictEngine(bst, buckets=(32, 128, 512)).warmup()
    before = eng.trace_count
    assert before == 3  # one trace per bucket
    for n in (1, 7, 32, 33, 100, 128, 129, 300, 512, 600):
        out = eng.predict(x[:n] if n <= len(x)
                          else np.vstack([x, x[: n - len(x)]]))
        assert out.shape[0] == n
    assert eng.trace_count == before, "mixed batch sizes recompiled"


def test_engine_matches_booster_predict(binary):
    bst, _, x, _ = binary
    eng = PredictEngine(bst)
    for n in (1, 5, 300, 600):
        np.testing.assert_array_equal(
            eng.predict(x[:n]), np.asarray(bst.predict(x[:n]))
        )


def test_engine_output_margin_and_iteration_range(binary):
    bst, _, x, _ = binary
    eng = PredictEngine(bst, output_margin=True, iteration_range=(0, 3))
    np.testing.assert_array_equal(
        eng.predict(x),
        np.asarray(bst.predict_margins(x, iteration_range=(0, 3))),
    )


def test_engine_oversized_batch_slices(binary):
    bst, _, x, _ = binary
    eng = PredictEngine(bst, buckets=(64, 256))
    big = np.vstack([x, x])  # 1200 rows > top bucket 256
    np.testing.assert_array_equal(
        eng.predict(big), np.asarray(bst.predict(big))
    )


def test_engine_multiclass_class_ids(multiclass):
    bst, _, x = multiclass
    eng = PredictEngine(bst)
    np.testing.assert_array_equal(eng.predict(x), np.asarray(bst.predict(x)))


def test_engine_validation(binary):
    bst, _, x, _ = binary
    eng = PredictEngine(bst)
    with pytest.raises(ValueError, match="2-D"):
        eng.predict(x[0])
    with pytest.raises(ValueError, match="features"):
        eng.predict(x[:, :3])
    with pytest.raises(ValueError, match="0 rows"):
        eng.predict(x[:0])
    bad = x[:4].copy()
    bad[0, 0] = np.inf
    with pytest.raises(ValueError, match="infinite feature values"):
        eng.predict(bad)
    # NaN stays the legal missing marker.
    ok = x[:4].copy()
    ok[0, 0] = np.nan
    assert eng.predict(ok).shape[0] == 4


def test_engine_nan_padding_is_inert(binary):
    """Bucket padding rows are NaN; they must not perturb real rows (each
    row's traversal is independent, asserted by exact equality between a
    padded 5-row call and the direct unpadded predict)."""
    bst, _, x, _ = binary
    eng = PredictEngine(bst, buckets=(512,))
    np.testing.assert_array_equal(
        eng.predict(x[:5]), np.asarray(bst.predict(x[:5]))
    )


def test_engine_stats_accounting(binary):
    bst, _, x, _ = binary
    eng = PredictEngine(bst, buckets=(64,))
    eng.predict(x[:10])  # pays the trace
    for _ in range(5):
        eng.predict(x[:10])
    s = eng.stats()
    assert s["n_calls"] == 5  # compile call excluded
    assert s["rows"] == 50
    assert s["p50_ms"] > 0 and s["p99_ms"] >= s["p50_ms"]
    assert s["rows_per_s"] > 0
    assert eng.stats(include_warmup=True)["n_calls"] == 6
    eng.reset_stats()
    assert eng.stats() == {"n_calls": 0}


def test_engine_requires_fitted_booster():
    with pytest.raises(RuntimeError, match="fitted"):
        PredictEngine(Booster())


def test_sklearn_serve_parity_and_no_recompile():
    from repro.sklearn import XGBClassifier

    rng = np.random.default_rng(9)
    x = rng.normal(size=(400, 5)).astype(np.float32)
    y = (x[:, 0] > 0).astype(int)
    plain = XGBClassifier(n_estimators=5, max_depth=3).fit(x, y)
    served = XGBClassifier(n_estimators=5, max_depth=3, serve=True).fit(x, y)
    np.testing.assert_array_equal(served.predict(x), plain.predict(x))
    np.testing.assert_array_equal(
        served.predict_proba(x), plain.predict_proba(x)
    )
    sizes = (3, 50, 200, 399)
    for n in sizes:  # first pass warms each bucket
        served.predict(x[:n])
    eng = served._serve_engine(output_margin=True)
    before = eng.trace_count
    for n in sizes:  # steady state: no recompiles
        served.predict(x[:n])
    assert eng.trace_count == before
