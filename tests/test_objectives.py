"""§2.5 gradient evaluation: g/h must equal d/dm and d2/dm2 of the loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import objectives as O


def _check_against_autodiff(obj, loss_scalar, margins, y, **kw):
    gh = np.asarray(obj.grad(jnp.asarray(margins), jnp.asarray(y), **kw))
    g_auto = jax.grad(lambda m: loss_scalar(m, jnp.asarray(y)))(jnp.asarray(margins))
    np.testing.assert_allclose(gh[..., 0], np.asarray(g_auto), atol=1e-4)


def test_logistic_gradients(rng):
    n = 50
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)

    def loss(margins, yy):
        return jnp.sum(jax.nn.softplus(margins[:, 0]) - yy * margins[:, 0])

    _check_against_autodiff(O.logistic, loss, m, y)
    gh = np.asarray(O.logistic.grad(jnp.asarray(m), jnp.asarray(y)))
    p = 1 / (1 + np.exp(-m[:, 0]))
    np.testing.assert_allclose(gh[:, 0, 1], p * (1 - p), atol=1e-5)  # eq (2)


def test_squared_gradients(rng):
    n = 40
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)

    def loss(margins, yy):
        return 0.5 * jnp.sum((margins[:, 0] - yy) ** 2)

    _check_against_autodiff(O.squared_error, loss, m, y)


def test_softmax_gradients(rng):
    n, k = 30, 5
    m = rng.normal(size=(n, k)).astype(np.float32)
    y = rng.integers(0, k, size=n).astype(np.float32)

    def loss(margins, yy):
        lse = jax.nn.logsumexp(margins, axis=1)
        tgt = jnp.take_along_axis(margins, yy.astype(jnp.int32)[:, None], axis=1)[:, 0]
        return jnp.sum(lse - tgt)

    _check_against_autodiff(O.softmax, loss, m, y)


def test_pairwise_gradients(rng):
    n = 24
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    gid = np.repeat(np.arange(4), 6).astype(np.int32)

    def loss(margins, yy):
        s = margins[:, 0]
        same = jnp.asarray(gid)[:, None] == jnp.asarray(gid)[None, :]
        better = (yy[:, None] > yy[None, :]) & same
        pair = jax.nn.softplus(-(s[:, None] - s[None, :]))
        return jnp.sum(jnp.where(better, pair, 0.0))

    _check_against_autodiff(O.pairwise_rank, loss, m, y,
                            group_ids=jnp.asarray(gid))


def test_hessians_positive(rng):
    n = 64
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    for obj in (O.logistic, O.squared_error):
        gh = np.asarray(obj.grad(jnp.asarray(m), jnp.asarray(y)))
        assert np.all(gh[..., 1] > 0)
