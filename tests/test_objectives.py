"""§2.5 gradient evaluation: g/h must equal d/dm and d2/dm2 of the loss.

Plus the beyond-paper objectives (quantile / pseudo-Huber / Poisson) and
the early-stopping direction regression (satellite of ISSUE 3): direction
lives on the METRIC, so minimizing and maximizing metrics must both stop
at their own optimum.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Booster, DeviceDMatrix
from repro.core import metrics as M
from repro.core import objectives as O


def _check_against_autodiff(obj, loss_scalar, margins, y, **kw):
    gh = np.asarray(obj.grad(jnp.asarray(margins), jnp.asarray(y), **kw))
    g_auto = jax.grad(lambda m: loss_scalar(m, jnp.asarray(y)))(jnp.asarray(margins))
    np.testing.assert_allclose(gh[..., 0], np.asarray(g_auto), atol=1e-4)


def test_logistic_gradients(rng):
    n = 50
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)

    def loss(margins, yy):
        return jnp.sum(jax.nn.softplus(margins[:, 0]) - yy * margins[:, 0])

    _check_against_autodiff(O.logistic, loss, m, y)
    gh = np.asarray(O.logistic.grad(jnp.asarray(m), jnp.asarray(y)))
    p = 1 / (1 + np.exp(-m[:, 0]))
    np.testing.assert_allclose(gh[:, 0, 1], p * (1 - p), atol=1e-5)  # eq (2)


def test_squared_gradients(rng):
    n = 40
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)

    def loss(margins, yy):
        return 0.5 * jnp.sum((margins[:, 0] - yy) ** 2)

    _check_against_autodiff(O.squared_error, loss, m, y)


def test_softmax_gradients(rng):
    n, k = 30, 5
    m = rng.normal(size=(n, k)).astype(np.float32)
    y = rng.integers(0, k, size=n).astype(np.float32)

    def loss(margins, yy):
        lse = jax.nn.logsumexp(margins, axis=1)
        tgt = jnp.take_along_axis(margins, yy.astype(jnp.int32)[:, None], axis=1)[:, 0]
        return jnp.sum(lse - tgt)

    _check_against_autodiff(O.softmax, loss, m, y)


def test_pairwise_gradients(rng):
    n = 24
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    gid = np.repeat(np.arange(4), 6).astype(np.int32)

    def loss(margins, yy):
        s = margins[:, 0]
        same = jnp.asarray(gid)[:, None] == jnp.asarray(gid)[None, :]
        better = (yy[:, None] > yy[None, :]) & same
        pair = jax.nn.softplus(-(s[:, None] - s[None, :]))
        return jnp.sum(jnp.where(better, pair, 0.0))

    _check_against_autodiff(O.pairwise_rank, loss, m, y,
                            group_ids=jnp.asarray(gid))


def test_hessians_positive(rng):
    n = 64
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    for obj in (O.logistic, O.squared_error, O.pseudohuber, O.poisson,
                O.quantile):
        gh = np.asarray(obj.grad(jnp.asarray(m), jnp.asarray(y)))
        assert np.all(gh[..., 1] > 0), obj.name


def test_quantile_gradients(rng):
    """Pinball-loss subgradient away from the kink (|m - y| > eps)."""
    n, alpha = 60, 0.8
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    y = np.where(np.abs(m[:, 0] - y) < 0.05, y + 0.2, y)  # step off the kink

    def loss(margins, yy):
        err = yy - margins[:, 0]
        return jnp.sum(jnp.maximum(alpha * err, (alpha - 1.0) * err))

    gh = np.asarray(O.quantile.grad(jnp.asarray(m), jnp.asarray(y),
                                    quantile_alpha=alpha))
    g_auto = jax.grad(lambda mm: loss(mm, jnp.asarray(y)))(jnp.asarray(m))
    np.testing.assert_allclose(gh[:, 0, 0], np.asarray(g_auto)[:, 0],
                               atol=1e-5)
    np.testing.assert_array_equal(gh[:, 0, 1], np.ones(n, np.float32))


def test_pseudohuber_gradients(rng):
    n = 50
    m = rng.normal(size=(n, 1)).astype(np.float32) * 2
    y = rng.normal(size=n).astype(np.float32)

    def loss(margins, yy):
        r = margins[:, 0] - yy
        return jnp.sum(jnp.sqrt(1.0 + r * r) - 1.0)

    _check_against_autodiff(O.pseudohuber, loss, m, y)
    # hessian = exact second derivative (1 + r^2)^(-3/2)
    gh = np.asarray(O.pseudohuber.grad(jnp.asarray(m), jnp.asarray(y)))
    r = m[:, 0] - y
    np.testing.assert_allclose(gh[:, 0, 1], (1 + r * r) ** -1.5, atol=1e-5)


def test_poisson_gradients(rng):
    n = 50
    m = (rng.normal(size=(n, 1)) * 0.5).astype(np.float32)
    y = rng.poisson(2.0, size=n).astype(np.float32)

    def loss(margins, yy):
        return jnp.sum(jnp.exp(margins[:, 0]) - yy * margins[:, 0])

    _check_against_autodiff(O.poisson, loss, m, y)
    # hessian is exp(m) inflated by exp(0.7) — XGBoost's max_delta_step
    # guard for sparse counts, deliberately NOT the bare second derivative.
    gh = np.asarray(O.poisson.grad(jnp.asarray(m), jnp.asarray(y)))
    np.testing.assert_allclose(gh[:, 0, 1], np.exp(m[:, 0] + 0.7), rtol=1e-5)


# --- end-to-end: beyond-paper objectives beat constant baselines -----------

@pytest.fixture(scope="module")
def feature_matrix():
    rng = np.random.default_rng(31)
    n, f = 1200, 5
    x = rng.normal(size=(n, f)).astype(np.float32)
    return rng, x


def test_quantile_fit_beats_constant_baseline(feature_matrix):
    """Acceptance: reg:quantile at alpha=0.9 must beat the best CONSTANT
    prediction (the empirical 0.9-quantile) on pinball loss, and cover
    roughly alpha of the data."""
    rng, x = feature_matrix
    n = x.shape[0]
    w = rng.normal(size=x.shape[1])
    y = (x @ w + (0.5 + np.abs(x[:, 0])) * rng.normal(size=n)).astype(
        np.float32)
    alpha = 0.9
    dtrain = DeviceDMatrix(x, label=y, max_bins=64)
    bst = Booster(n_rounds=40, max_depth=4, objective="reg:quantile",
                  quantile_alpha=alpha, max_bins=64).fit(dtrain)
    pred = np.asarray(bst.predict(x))

    def pinball(p):
        err = y - p
        return float(np.mean(np.maximum(alpha * err, (alpha - 1) * err)))

    model_loss = pinball(pred)
    const_loss = pinball(np.quantile(y, alpha))  # best constant predictor
    assert model_loss < 0.7 * const_loss, (model_loss, const_loss)
    coverage = float(np.mean(y <= pred))
    assert 0.82 < coverage < 0.98, coverage


def test_poisson_fit_beats_constant_baseline(feature_matrix):
    """Acceptance: count:poisson must beat the best constant rate (the
    label mean) on held-in negative log-likelihood."""
    rng, x = feature_matrix
    n = x.shape[0]
    lam = np.exp(0.6 * x[:, 0] - 0.4 * x[:, 1])
    y = rng.poisson(lam).astype(np.float32)
    dtrain = DeviceDMatrix(x, label=y, max_bins=64)
    bst = Booster(n_rounds=30, max_depth=4, objective="count:poisson",
                  max_bins=64).fit(dtrain)
    nll = M.METRICS["poisson-nloglik"]
    model_nll = float(nll.fn(bst.predict_margins(dtrain), jnp.asarray(y)))
    const_margin = jnp.full((n, 1), np.log(y.mean()), jnp.float32)
    const_nll = float(nll.fn(const_margin, jnp.asarray(y)))
    assert model_nll < const_nll - 0.1, (model_nll, const_nll)
    # predictions are rates (exp link): non-negative by construction
    assert float(np.min(np.asarray(bst.predict(x)))) >= 0.0


# --- early stopping direction lives on the metric (satellite) --------------

@pytest.fixture(scope="module")
def es_setup():
    """Training data with real signal, eval labels pure noise: minimizing
    metrics bottom out early, maximizing metrics peak early — in both
    cases best_iteration must sit at that metric's own optimum."""
    rng = np.random.default_rng(41)
    n, f = 900, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = ((x @ rng.normal(size=f)) > 0).astype(np.float32)
    dtrain = DeviceDMatrix(x[:700], label=y[:700], max_bins=32)
    noise = (rng.random(200) < 0.5).astype(np.float32)
    dval = DeviceDMatrix(x[700:], label=noise, ref=dtrain)
    return dtrain, dval


@pytest.mark.parametrize("metric,pick", [
    ("logloss", np.argmin),
    ("error", np.argmin),
    ("auc", np.argmax),
    ("accuracy", np.argmax),
])
def test_early_stopping_direction_follows_metric(es_setup, metric, pick):
    dtrain, dval = es_setup
    bst = Booster(n_rounds=40, max_depth=3, learning_rate=0.6,
                  objective="binary:logistic", max_bins=32)
    bst.fit(dtrain, evals=[(dval, "valid")], eval_metric=metric,
            early_stopping_rounds=4)
    series = [h[f"valid_{metric}"] for h in bst.history]
    assert bst.best_iteration == int(pick(series)), (metric, series)
    assert bst.best_score == pytest.approx(series[bst.best_iteration])
    assert bst.n_rounds_trained == bst.best_iteration + 1  # truncated


def test_objective_carries_no_direction():
    """Satellite: the maximize footgun is gone from Objective — direction
    is resolved through the metric registry only."""
    assert not hasattr(O.squared_error, "maximize")
    assert not hasattr(O.logistic, "metric")
    assert not hasattr(O.logistic, "metric_name")
    for obj in O.OBJECTIVES.values():
        M.get_metric(obj.default_metric)  # every default resolves
