"""Stochastic & constrained training (ISSUE 5, DESIGN.md §12).

Covers the TreeContext threading end to end: deterministic defaults,
seeded subsampling (compact-buffer path), column sampling, monotone
constraints (split rejection + bound propagation), external-memory parity,
cross-process seeded determinism, checkpoint round-trip of the new config
knobs, and feature importances against a numpy oracle.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Booster, BoosterConfig, DeviceDMatrix, ExternalDMatrix
from repro.core import sampling as SMP

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _data(n=3000, f=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f).astype(np.float32)
    y = (x @ w + 0.3 * rng.normal(size=n) > 0).astype(np.float32)
    return x, y


def _ens_equal(a, b):
    return (
        bool(jnp.all(a.feature == b.feature))
        and bool(jnp.all(a.split_bin == b.split_bin))
        and bool(jnp.all(a.leaf_value == b.leaf_value))
        and bool(jnp.all(a.default_left == b.default_left))
    )


# --- defaults stay deterministic --------------------------------------------

def test_defaults_ignore_seed():
    """With every stochastic knob at its default the seed must not matter:
    the config selects the exact pre-stochastic program."""
    x, y = _data()
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    kw = dict(n_rounds=4, max_depth=4, max_bins=32,
              objective="binary:logistic")
    b1 = Booster(**kw, seed=0).fit(dtrain)
    b2 = Booster(**kw, seed=12345).fit(dtrain)
    assert _ens_equal(b1.ensemble, b2.ensemble)


def test_explicit_default_knobs_identical():
    x, y = _data()
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    kw = dict(n_rounds=3, max_depth=3, max_bins=32,
              objective="binary:logistic")
    b1 = Booster(**kw).fit(dtrain)
    b2 = Booster(**kw, subsample=1.0, colsample_bytree=1.0,
                 colsample_bylevel=1.0, colsample_bynode=1.0,
                 monotone_constraints=(0,) * x.shape[1]).fit(dtrain)
    assert _ens_equal(b1.ensemble, b2.ensemble)


# --- config validation ------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="subsample"):
        BoosterConfig(subsample=0.0)
    with pytest.raises(ValueError, match="colsample_bytree"):
        BoosterConfig(colsample_bytree=1.5)
    with pytest.raises(ValueError, match="monotone"):
        BoosterConfig(monotone_constraints=(2, 0))
    # lists coerce to a hashable tuple
    cfg = BoosterConfig(monotone_constraints=[1, 0, -1])
    assert cfg.monotone_constraints == (1, 0, -1)
    hash(cfg)


def test_monotone_length_checked_at_fit():
    x, y = _data(n=500, f=4)
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    bst = Booster(n_rounds=2, max_bins=32, monotone_constraints=(1, 0))
    with pytest.raises(ValueError, match="4 features"):
        bst.fit(dtrain)


# --- subsampling ------------------------------------------------------------

def test_subsample_seeded_and_learns():
    x, y = _data()
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    kw = dict(n_rounds=6, max_depth=4, max_bins=32,
              objective="binary:logistic", subsample=0.5)
    b1 = Booster(**kw, seed=7).fit(dtrain)
    b2 = Booster(**kw, seed=7).fit(dtrain)
    b3 = Booster(**kw, seed=8).fit(dtrain)
    assert _ens_equal(b1.ensemble, b2.ensemble)
    assert not _ens_equal(b1.ensemble, b3.ensemble)
    acc = float(np.mean(
        (np.asarray(b1.predict(x)).reshape(-1) > 0.5) == y
    ))
    assert acc > 0.85, acc


def test_subsample_update_continuation_matches_longer_fit():
    """The key stream folds from the ABSOLUTE round index, so fit(4) +
    update(4) replays exactly the rounds of one fit(8)."""
    x, y = _data()
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    kw = dict(max_depth=4, max_bins=32, objective="binary:logistic",
              subsample=0.6, seed=11)
    long = Booster(n_rounds=8, **kw).fit(dtrain)
    cont = Booster(n_rounds=4, **kw).fit(dtrain)
    cont.update(dtrain, 4)
    assert _ens_equal(long.ensemble, cont.ensemble)


def test_subsample_external_memory_bit_identical():
    """Sampled growth over the chunk stack (compacted chunked-row builders)
    matches the in-memory compacted path bit for bit on the same cuts."""
    x, y = _data(n=2500)
    kw = dict(n_rounds=4, max_depth=4, max_bins=32,
              objective="binary:logistic", subsample=0.5,
              colsample_bytree=0.75, seed=5)
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=700, max_bins=32,
                                      cuts="exact")
    bi = Booster(**kw).fit(dtrain)
    be = Booster(**kw).fit(ext)
    assert _ens_equal(bi.ensemble, be.ensemble)
    assert bool(jnp.all(bi.margins == be.margins))


def test_row_selection_mask_exact_count_and_determinism():
    import jax

    key = jax.random.PRNGKey(3)
    for n, m in ((100, 37), (1024, 512), (7, 1)):
        sel = SMP.row_selection_mask(key, n, m)
        assert int(jnp.sum(sel)) == m
        sel2 = SMP.row_selection_mask(key, n, m)
        assert bool(jnp.all(sel == sel2))
    rid = SMP.compact_row_ids(SMP.row_selection_mask(key, 1024, 512), 512)
    rid = np.asarray(rid)
    assert np.all(np.diff(rid) > 0)  # ascending, unique
    assert rid.min() >= 0 and rid.max() < 1024


def test_masked_equals_compact_subsampling():
    """The distributed shards zero unselected rows' gradients instead of
    compacting; both executions must grow the same tree."""
    import jax

    from repro.core import objectives as O
    from repro.core import tree as T

    x, y = _data(n=1500, f=5)
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    cfg = BoosterConfig(n_rounds=1, max_depth=4, max_bins=32,
                        objective="binary:logistic", subsample=0.5, seed=21)
    obj = O.get_objective(cfg.objective)
    stoch = SMP.stochastic_params(cfg)
    pb = dtrain.packed_bins()
    margins = jnp.zeros((x.shape[0], 1), jnp.float32)
    gh = obj.grad(margins, dtrain.label)[:, 0, :]
    tkey = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(21), 0), 0)
    ctx_c, gh_c = SMP.make_tree_context(stoch, tkey, gh, 5, compact=True)
    ctx_m, gh_m = SMP.make_tree_context(stoch, tkey, gh, 5, compact=False)
    tr_c = T.grow_tree(pb, gh_c, dtrain.cuts, cfg.max_depth, cfg.max_bins,
                       cfg.split_params, ctx=ctx_c)
    tr_m = T.grow_tree(pb, gh_m, dtrain.cuts, cfg.max_depth, cfg.max_bins,
                       cfg.split_params, ctx=ctx_m)
    assert bool(jnp.all(tr_c.feature == tr_m.feature))
    assert bool(jnp.all(tr_c.split_bin == tr_m.split_bin))
    assert float(jnp.max(jnp.abs(tr_c.leaf_value - tr_m.leaf_value))) < 1e-5


def test_seeded_determinism_across_processes():
    """Same seed => bit-identical boosters in two fresh subprocesses."""
    script = textwrap.dedent("""
        import hashlib
        import numpy as np
        from repro.core import Booster, DeviceDMatrix
        rng = np.random.default_rng(1)
        x = rng.normal(size=(1200, 6)).astype(np.float32)
        y = (x @ rng.normal(size=6) > 0).astype(np.float32)
        dtrain = DeviceDMatrix(x, label=y, max_bins=32)
        bst = Booster(n_rounds=4, max_depth=3, max_bins=32,
                      objective="binary:logistic", subsample=0.5,
                      colsample_bytree=0.8, seed=42).fit(dtrain)
        h = hashlib.sha256()
        for a in (bst.ensemble.feature, bst.ensemble.split_bin,
                  bst.ensemble.leaf_value):
            h.update(np.asarray(a).tobytes())
        print("HASH", h.hexdigest())
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    hashes = []
    for _ in range(2):
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=600,
                             env=env)
        assert res.returncode == 0, res.stdout + "\n" + res.stderr
        hashes.append(res.stdout.strip().split()[-1])
    assert hashes[0] == hashes[1]


# --- column sampling --------------------------------------------------------

def test_colsample_bytree_restricts_features():
    """With one feature per tree, every tree's splits use a single
    feature — observable straight off the arena."""
    x, y = _data(n=2000, f=8)
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    bst = Booster(n_rounds=6, max_depth=3, max_bins=32,
                  objective="binary:logistic",
                  colsample_bytree=1 / 8, seed=3).fit(dtrain)
    ens = bst.ensemble
    gain = np.asarray(ens.gain)
    feat = np.asarray(ens.feature)
    used_per_tree = [
        set(feat[t][np.isfinite(gain[t])].tolist())
        for t in range(ens.n_trees)
    ]
    assert all(len(u) <= 1 for u in used_per_tree), used_per_tree
    # across trees, more than one feature should appear (different draws)
    assert len(set().union(*used_per_tree)) > 1


def test_feature_sample_mask_counts():
    import jax

    key = jax.random.PRNGKey(0)
    m = SMP.feature_sample_mask(key, 3, 10)
    assert m.shape == (10,) and int(jnp.sum(m)) == 3
    base = jnp.arange(10) < 5
    m2 = SMP.feature_sample_mask(key, 2, 10, base_mask=base)
    assert int(jnp.sum(m2)) == 2 and bool(jnp.all(~m2[5:]))
    m3 = SMP.feature_sample_mask(key, 4, 10, base_mask=base, n_nodes=6)
    assert m3.shape == (6, 10)
    assert bool(jnp.all(jnp.sum(m3, axis=1) == 4))
    assert bool(jnp.all(~m3[:, 5:]))


# --- monotone constraints ---------------------------------------------------

def _monotone_fit(direction, n_rounds=25):
    rng = np.random.default_rng(4)
    n = 4000
    x = rng.uniform(-2, 2, size=(n, 3)).astype(np.float32)
    signal = 1.5 * x[:, 0] + np.sin(2 * x[:, 1])
    y = (direction * signal + 0.3 * rng.normal(size=n)).astype(np.float32)
    dtrain = DeviceDMatrix(x, label=y, max_bins=64)
    bst = Booster(n_rounds=n_rounds, max_depth=4, max_bins=64,
                  monotone_constraints=(direction, 0, 0)).fit(dtrain)
    return bst


@pytest.mark.parametrize("direction", [1, -1])
def test_monotone_constraint_holds_on_sweep_grid(direction):
    bst = _monotone_fit(direction)
    grid = np.linspace(-2.2, 2.2, 64, dtype=np.float32)
    for others in (-1.5, 0.0, 0.7):
        xt = np.full((64, 3), others, np.float32)
        xt[:, 0] = grid
        pred = np.asarray(bst.predict(xt)).reshape(-1)
        diffs = np.diff(pred) * direction
        assert np.all(diffs >= -1e-6), (others, pred)


def test_monotone_still_learns():
    bst = _monotone_fit(1)
    rng = np.random.default_rng(9)
    xt = rng.uniform(-2, 2, size=(800, 3)).astype(np.float32)
    yt = 1.5 * xt[:, 0] + np.sin(2 * xt[:, 1])
    pred = np.asarray(bst.predict(xt)).reshape(-1)
    resid = float(np.mean((pred - yt) ** 2))
    base = float(np.mean((yt - yt.mean()) ** 2))
    assert resid < 0.5 * base, (resid, base)


def test_monotone_with_subsample():
    rng = np.random.default_rng(5)
    n = 3000
    x = rng.uniform(-2, 2, size=(n, 4)).astype(np.float32)
    y = (x[:, 0] + 0.2 * rng.normal(size=n)).astype(np.float32)
    dtrain = DeviceDMatrix(x, label=y, max_bins=64)
    bst = Booster(n_rounds=15, max_depth=4, max_bins=64, subsample=0.5,
                  monotone_constraints=(1, 0, 0, 0), seed=6).fit(dtrain)
    grid = np.linspace(-2, 2, 50, dtype=np.float32)
    xt = np.zeros((50, 4), np.float32)
    xt[:, 0] = grid
    pred = np.asarray(bst.predict(xt)).reshape(-1)
    assert np.all(np.diff(pred) >= -1e-6)


# --- feature importances ----------------------------------------------------

def _importance_oracle(ens, n_features):
    """Numpy reference: walk every arena slot; finite gain == split node."""
    gain = np.asarray(ens.gain, np.float64)
    feat = np.asarray(ens.feature)
    weight = np.zeros(n_features)
    total = np.zeros(n_features)
    for t in range(gain.shape[0]):
        for a in range(gain.shape[1]):
            if np.isfinite(gain[t, a]):
                weight[feat[t, a]] += 1.0
                total[feat[t, a]] += gain[t, a]
    mean = np.divide(total, weight, out=np.zeros_like(total),
                     where=weight > 0)
    return weight, total, mean


def test_feature_importances_match_oracle():
    x, y = _data(n=2500, f=6)
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    bst = Booster(n_rounds=5, max_depth=4, max_bins=32,
                  objective="binary:logistic").fit(dtrain)
    weight, total, mean = _importance_oracle(bst.ensemble, 6)
    np.testing.assert_allclose(bst.feature_importances("weight"), weight)
    np.testing.assert_allclose(bst.feature_importances("total_gain"), total,
                               rtol=1e-12)
    np.testing.assert_allclose(bst.feature_importances("gain"), mean,
                               rtol=1e-12)
    assert weight.sum() > 0
    with pytest.raises(ValueError, match="importance_type"):
        bst.feature_importances("cover")


def test_feature_importances_survive_checkpoint(tmp_path):
    x, y = _data(n=1500, f=5)
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    bst = Booster(n_rounds=3, max_depth=3, max_bins=32,
                  objective="binary:logistic").fit(dtrain)
    path = str(tmp_path / "bst.ckpt")
    bst.save(path)
    loaded = Booster.load(path)
    np.testing.assert_allclose(loaded.feature_importances("gain"),
                               bst.feature_importances("gain"))


def test_sklearn_feature_importances_normalised():
    from repro.sklearn import XGBClassifier

    x, y = _data(n=1500, f=6)
    clf = XGBClassifier(n_estimators=5, max_depth=3, max_bins=32)
    clf.fit(x, y)
    fi = clf.feature_importances_
    assert fi.shape == (6,)
    assert abs(float(fi.sum()) - 1.0) < 1e-9
    oracle = clf.get_booster().feature_importances("gain")
    np.testing.assert_allclose(fi, oracle / oracle.sum())


def test_sklearn_stochastic_params_roundtrip():
    from repro.sklearn import XGBRegressor

    reg = XGBRegressor(n_estimators=4, max_depth=3, max_bins=32,
                       subsample=0.5, colsample_bytree=0.5,
                       monotone_constraints=[1, 0, 0, 0], random_state=3)
    params = reg.get_params()
    assert params["subsample"] == 0.5
    assert params["random_state"] == 3
    x, y = _data(n=1200, f=4)
    reg.fit(x, y)
    cfg = reg.get_booster().cfg
    assert cfg.subsample == 0.5 and cfg.colsample_bytree == 0.5
    assert cfg.monotone_constraints == (1, 0, 0, 0) and cfg.seed == 3
