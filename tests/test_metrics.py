"""The metric registry: values vs plain-numpy oracles, directions, the
parameterised ndcg@k family, and plugin resolution (DESIGN.md §10)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as M


def _val(name, margins, y, **extra):
    m = M.get_metric(name)
    return float(m.fn(jnp.asarray(margins), jnp.asarray(y), **extra))


@pytest.fixture()
def binary(rng):
    n = 200
    margins = rng.normal(size=(n, 1)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    return margins, y


def test_regression_metrics_match_numpy(rng):
    n = 150
    m = rng.normal(size=(n, 1)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    assert _val("rmse", m, y) == pytest.approx(
        np.sqrt(np.mean((m[:, 0] - y) ** 2)), rel=1e-5)
    assert _val("mae", m, y) == pytest.approx(
        np.mean(np.abs(m[:, 0] - y)), rel=1e-5)
    r = m[:, 0] - y
    assert _val("mphe", m, y) == pytest.approx(
        np.mean(np.sqrt(1 + r * r) - 1), rel=1e-5)
    a = 0.8
    pin = np.mean(np.maximum(a * (y - m[:, 0]), (a - 1) * (y - m[:, 0])))
    assert _val("quantile", m, y, quantile_alpha=a) == pytest.approx(
        pin, rel=1e-5)


def test_binary_metrics_match_numpy(binary):
    m, y = binary
    p = 1 / (1 + np.exp(-m[:, 0]))
    ll = -np.mean(y * np.log(p) + (1 - y) * np.log(1 - p))
    assert _val("logloss", m, y) == pytest.approx(ll, rel=1e-4)
    acc = np.mean((m[:, 0] > 0) == (y > 0.5))
    assert _val("accuracy", m, y) == pytest.approx(acc, rel=1e-6)
    assert _val("error", m, y) == pytest.approx(1 - acc, abs=1e-6)


def test_auc_matches_pair_counting_with_ties(rng):
    """AUC oracle: fraction of (pos, neg) pairs ranked correctly, ties
    counting half — the rank-sum implementation must agree exactly."""
    n = 120
    # Quantised scores force plenty of ties (tree margins tie the same way).
    s = np.round(rng.normal(size=n) * 2) / 2
    y = (rng.random(n) < 0.4).astype(np.float32)
    pos, neg = s[y > 0.5], s[y <= 0.5]
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    want = (wins + 0.5 * ties) / (len(pos) * len(neg))
    got = _val("auc", s[:, None].astype(np.float32), y)
    assert got == pytest.approx(want, rel=1e-5)
    assert M.METRICS["auc"].maximize is True


def test_multiclass_metrics_match_numpy(rng):
    n, k = 90, 4
    m = rng.normal(size=(n, k)).astype(np.float32)
    y = rng.integers(0, k, size=n).astype(np.float32)
    pred = np.argmax(m, axis=1)
    assert _val("merror", m, y) == pytest.approx(
        np.mean(pred != y.astype(int)), abs=1e-6)
    assert _val("accuracy", m, y) == pytest.approx(
        np.mean(pred == y.astype(int)), abs=1e-6)
    z = m - m.max(axis=1, keepdims=True)
    logp = z - np.log(np.exp(z).sum(axis=1, keepdims=True))
    want = -np.mean(logp[np.arange(n), y.astype(int)])
    assert _val("mlogloss", m, y) == pytest.approx(want, rel=1e-4)


def _ndcg_numpy(s, y, gids, k):
    """Literal per-group reference: sort by score, DCG@k over 2^rel-1
    gains, normalised by the ideal ordering."""
    vals = []
    for g in np.unique(gids):
        sel = gids == g
        sg, yg = s[sel], y[sel]
        order = np.lexsort((np.arange(len(sg)), -sg))  # stable by -score
        gains = 2.0 ** yg - 1.0
        disc = 1.0 / np.log2(np.arange(len(sg)) + 2.0)
        dcg = np.sum((gains[order] * disc)[:k])
        ideal = np.lexsort((np.arange(len(yg)), -yg))
        idcg = np.sum((gains[ideal] * disc)[:k])
        vals.append(dcg / idcg if idcg > 0 else 1.0)
    return float(np.mean(vals))


@pytest.mark.parametrize("k", [1, 3, 8])
def test_ndcg_matches_reference(rng, k):
    n_groups, per = 12, 7
    n = n_groups * per
    s = rng.normal(size=n).astype(np.float32)
    y = rng.integers(0, 4, size=n).astype(np.float32)
    gids = np.repeat(np.arange(n_groups), per).astype(np.int32)
    got = _val(f"ndcg@{k}", s[:, None], y, group_ids=jnp.asarray(gids))
    want = _ndcg_numpy(s, y, gids, k)
    assert got == pytest.approx(want, rel=1e-5)


def test_ndcg_zero_idcg_group_scores_one(rng):
    """A group with all-zero relevance has no ideal ordering; XGBoost's
    convention (NDCG = 1) must hold instead of a 0/0 blowup."""
    s = rng.normal(size=8).astype(np.float32)
    y = np.zeros(8, np.float32)
    y[4:] = np.array([3, 1, 0, 2], np.float32)  # second group informative
    gids = np.repeat(np.arange(2), 4).astype(np.int32)
    got = _val("ndcg@4", s[:, None], y, group_ids=jnp.asarray(gids))
    want = _ndcg_numpy(s, y, gids, 4)  # reference also scores group0 as 1
    assert got == pytest.approx(want, rel=1e-5)


def test_ndcg_without_groups_is_single_query(rng):
    n = 20
    s = rng.normal(size=n).astype(np.float32)
    y = rng.integers(0, 3, size=n).astype(np.float32)
    got = _val("ndcg@5", s[:, None], y)
    want = _ndcg_numpy(s, y, np.zeros(n, np.int32), 5)
    assert got == pytest.approx(want, rel=1e-5)


def test_get_metric_parametric_caching():
    a = M.get_metric("ndcg@7")
    b = M.get_metric("ndcg@7")
    assert a is b and a.name == "ndcg@7" and a.maximize
    with pytest.raises(ValueError, match="ndcg"):
        M.get_metric("ndcg@0")
    with pytest.raises(ValueError, match="unknown eval metric"):
        M.get_metric("not_a_metric")


def test_metric_directions():
    """Satellite: direction lives on the METRIC. A new objective cannot
    silently early-stop the wrong way anymore."""
    for name in ("rmse", "mae", "logloss", "error", "merror", "mlogloss",
                 "quantile", "mphe", "poisson-nloglik"):
        assert M.METRICS[name].maximize is False, name
    for name in ("accuracy", "auc", "pairwise_acc"):
        assert M.METRICS[name].maximize is True, name
    assert M.get_metric("ndcg@3").maximize is True


def test_callable_and_tuple_specs_resolve_and_cache():
    def half_mae(margins, y):
        return 0.5 * jnp.mean(jnp.abs(margins[:, 0] - y))

    a = M.get_metric(half_mae)
    b = M.get_metric(half_mae)
    assert a is b and a.name == "half_mae" and a.maximize is False
    c = M.get_metric(("hm", half_mae, True))
    assert c.name == "hm" and c.maximize is True
    m = jnp.asarray([[1.0], [3.0]])
    y = jnp.asarray([0.0, 0.0])
    assert float(a.fn(m, y, group_ids=None)) == pytest.approx(1.0)


def test_register_metric_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        M.register_metric("rmse", lambda m, y: 0.0)


def test_resolve_metrics_spec_forms():
    """A bare (name, fn[, maximize]) tuple is ONE metric spec, not a
    sequence of two; sequences mix all spec forms."""
    def fn(margins, y):
        return jnp.mean(margins[:, 0] - y)

    assert M.resolve_metrics(None) == ()
    (single,) = M.resolve_metrics("rmse")
    assert single is M.METRICS["rmse"]
    (bare,) = M.resolve_metrics(("pd", fn))
    assert bare.name == "pd" and not bare.maximize
    (bare_max,) = M.resolve_metrics(("pd", fn, True))
    assert bare_max.maximize
    pair = M.resolve_metrics(["rmse", ("pd", fn), fn])
    assert [m.name for m in pair] == ["rmse", "pd", "fn"]


def test_user_constructed_metric_gets_extra_adaptation():
    """A hand-built Metric whose fn takes only (margins, y) must survive
    the scan's **extra keywords, and resolve to a stable object so the
    compiled-fn cache keys consistently."""
    raw = M.Metric("mad", lambda m, y: jnp.mean(jnp.abs(m[:, 0] - y)))
    a = M.get_metric(raw)
    b = M.get_metric(raw)
    assert a is b
    val = a.fn(jnp.asarray([[1.0], [3.0]]), jnp.asarray([0.0, 0.0]),
               quantile_alpha=0.5, group_ids=None)
    assert float(val) == pytest.approx(2.0)
