"""The sklearn estimator facade (repro.sklearn).

Params round-trip through get_params/set_params (with or without sklearn
installed); estimators fit/predict/score over the compiled booster; and —
when scikit-learn is available — GridSearchCV / cross_val_score drive the
estimators out of the box (the ISSUE 3 acceptance smoke)."""
import numpy as np
import pytest

from repro.sklearn import (
    HAVE_SKLEARN,
    XGBClassifier,
    XGBRanker,
    XGBRegressor,
)

needs_sklearn = pytest.mark.skipif(not HAVE_SKLEARN,
                                   reason="scikit-learn not installed")


@pytest.fixture(scope="module")
def reg_data():
    rng = np.random.default_rng(23)
    n, f = 700, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (x @ rng.normal(size=f) + 0.3 * x[:, 0] * x[:, 1]).astype(np.float32)
    return x, y


@pytest.fixture(scope="module")
def cls_data(reg_data):
    x, y = reg_data
    return x, np.where(y > 0, "spam", "ham")  # string labels round-trip


def test_get_set_params_roundtrip():
    est = XGBRegressor(n_estimators=7, max_depth=3, quantile_alpha=0.8)
    p = est.get_params()
    assert p["n_estimators"] == 7 and p["quantile_alpha"] == 0.8
    est.set_params(max_depth=5, learning_rate=0.1)
    assert est.get_params()["max_depth"] == 5
    with pytest.raises(ValueError, match="invalid parameter|Invalid parameter"):
        est.set_params(not_a_param=1)
    # a fresh estimator built from get_params is equivalent (clone contract)
    est2 = XGBRegressor(**est.get_params())
    assert est2.get_params() == est.get_params()


def test_regressor_fit_predict_score(reg_data):
    x, y = reg_data
    reg = XGBRegressor(n_estimators=20, max_depth=4, max_bins=64)
    assert reg.fit(x, y) is reg
    assert reg.n_features_in_ == x.shape[1]
    pred = reg.predict(x)
    assert pred.shape == (len(y),)
    assert reg.score(x, y) > 0.8  # R^2 on train

    with pytest.raises(RuntimeError, match="not fitted"):
        XGBRegressor().predict(x)


def test_regressor_quantile_objective(reg_data):
    x, y = reg_data
    reg = XGBRegressor(n_estimators=20, max_depth=3, max_bins=32,
                       objective="reg:quantile", quantile_alpha=0.9)
    reg.fit(x, y)
    cover = float(np.mean(y <= reg.predict(x)))
    assert 0.8 < cover <= 1.0, cover  # predicts the upper quantile


def test_classifier_binary_labels_proba_and_es(cls_data):
    x, yc = cls_data
    clf = XGBClassifier(n_estimators=30, max_depth=3, max_bins=32,
                        eval_metric=["logloss", "auc"],
                        early_stopping_rounds=5)
    clf.fit(x[:500], yc[:500], eval_set=[(x[500:], yc[500:])])
    assert list(clf.classes_) == ["ham", "spam"]
    assert set(np.unique(clf.predict(x))) <= {"ham", "spam"}
    proba = clf.predict_proba(x[:40])
    assert proba.shape == (40, 2)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, atol=1e-5)
    assert clf.score(x, yc) > 0.85
    # multi-metric per-round history flowed through; ES bookkeeping exposed
    assert {"validation_0_logloss", "validation_0_auc"} <= set(
        clf.evals_result_[-1])
    assert clf.best_iteration_ is not None


def test_classifier_rejects_unseen_eval_labels(cls_data):
    x, yc = cls_data
    clf = XGBClassifier(n_estimators=3, max_depth=2, max_bins=32)
    bad = yc[500:].copy()
    bad[0] = "zzz"  # class absent from the training targets
    with pytest.raises(ValueError, match="unseen"):
        clf.fit(x[:500], yc[:500], eval_set=[(x[500:], bad)])


def test_classifier_multiclass(rng):
    n, f, k = 600, 5, 3
    centers = rng.normal(size=(k, f)) * 2.5
    yi = rng.integers(0, k, size=n)
    x = (centers[yi] + rng.normal(size=(n, f))).astype(np.float32)
    labels = np.array([10, 20, 30])[yi]  # non-contiguous label values
    clf = XGBClassifier(n_estimators=8, max_depth=3, max_bins=32)
    clf.fit(x, labels)
    assert list(clf.classes_) == [10, 20, 30]
    proba = clf.predict_proba(x)
    assert proba.shape == (n, k)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, atol=1e-5)
    assert clf.score(x, labels) > 0.9


def test_ranker_qid_group_equivalent(rng):
    n_groups, per = 25, 8
    n = n_groups * per
    x = rng.normal(size=(n, 5)).astype(np.float32)
    rel = np.clip(np.round(x @ rng.normal(size=5) + 2), 0, 4).astype(
        np.float32)
    qid = np.repeat(np.arange(n_groups), per)
    kw = dict(n_estimators=6, max_depth=3, max_bins=32)
    a = XGBRanker(**kw).fit(x, rel, qid=qid)
    b = XGBRanker(**kw).fit(x, rel, group=[per] * n_groups)
    np.testing.assert_array_equal(a.predict(x), b.predict(x))
    with pytest.raises(ValueError, match="exactly one"):
        XGBRanker(**kw).fit(x, rel)
    with pytest.raises(ValueError, match="exactly one"):
        XGBRanker(**kw).fit(x, rel, qid=qid, group=[per] * n_groups)


@needs_sklearn
def test_gridsearchcv_smoke(cls_data):
    """Acceptance: XGBClassifier survives a GridSearchCV run."""
    from sklearn.model_selection import GridSearchCV

    x, yc = cls_data
    gs = GridSearchCV(
        XGBClassifier(n_estimators=8, max_bins=32),
        {"max_depth": [2, 3], "learning_rate": [0.3, 0.6]},
        cv=2,
    )
    gs.fit(x, yc)
    assert gs.best_score_ > 0.8
    assert set(gs.best_params_) == {"max_depth", "learning_rate"}
    assert gs.best_estimator_.score(x, yc) > 0.8


@needs_sklearn
def test_cross_val_score_regressor(reg_data):
    from sklearn.model_selection import cross_val_score

    x, y = reg_data
    scores = cross_val_score(
        XGBRegressor(n_estimators=10, max_depth=3, max_bins=32), x, y, cv=3)
    assert scores.shape == (3,) and scores.mean() > 0.5


@needs_sklearn
def test_sklearn_clone_contract():
    from sklearn.base import clone

    est = XGBClassifier(n_estimators=5, max_depth=2, eval_metric=["auc"])
    c = clone(est)
    assert c.get_params() == est.get_params()


def test_chunk_rows_external_memory_fit(cls_data):
    """chunk_rows= routes training through ExternalDMatrix and matches the
    in-memory estimator bit for bit (exact-cuts chunking is artificial, but
    sketch cuts differ only in binning, so compare predictions loosely)."""
    x, yc = cls_data
    mem = XGBClassifier(n_estimators=8, max_depth=3, max_bins=32).fit(x, yc)
    ext = XGBClassifier(n_estimators=8, max_depth=3, max_bins=32,
                        chunk_rows=100).fit(x, yc)
    assert ext.booster_.matrix is None  # no flat matrix was ever built
    agree = np.mean(ext.predict(x) == mem.predict(x))
    assert agree > 0.95
    assert ext.score(x, yc) > 0.85
