"""Optimizer + checkpoint substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree, save_ensemble, load_ensemble
from repro.optimizer import (
    AdamWConfig, adamw_init, adamw_update, clip_by_global_norm, sgd_init,
    sgd_update,
)
from repro.optimizer.util import cosine_schedule, global_norm


def test_adamw_quadratic_converges():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray(2.0)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0)
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_first_step_matches_reference():
    """After one step from zero moments: delta = lr * g/(|g|) elementwise
    (bias-corrected), independent of g's magnitude."""
    params = {"w": jnp.asarray([1.0, 1.0])}
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, grad_clip=0)
    state = adamw_init(params)
    g = {"w": jnp.asarray([0.5, -2.0])}
    new, _ = adamw_update(params, g, state, cfg)
    delta = np.asarray(params["w"] - new["w"])
    np.testing.assert_allclose(delta, [0.01, -0.01], rtol=1e-3)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-3
    assert float(norm) > 30


def test_sgd_momentum_step():
    params = {"w": jnp.asarray(1.0)}
    state = sgd_init(params)
    g = {"w": jnp.asarray(1.0)}
    p1, state = sgd_update(params, g, state, lr=0.1)
    assert abs(float(p1["w"]) - 0.9) < 1e-6
    p2, state = sgd_update(p1, g, state, lr=0.1)  # momentum kicks in
    assert float(p2["w"]) < 0.8 - 1e-6


def test_cosine_schedule():
    assert float(cosine_schedule(jnp.asarray(0), 1.0, 10, 100)) == 0.0
    assert abs(float(cosine_schedule(jnp.asarray(10), 1.0, 10, 100)) - 1.0) < 1e-5
    assert float(cosine_schedule(jnp.asarray(100), 1.0, 10, 100)) < 0.11


def test_pytree_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.asarray([1, 2], jnp.int32), "c": "hello", "d": 3.5},
        "tup": (jnp.ones(2), jnp.zeros(1, jnp.bool_)),
        "none": None,
    }
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_pytree(path, tree)
    out = load_pytree(path)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]), [1, 2])
    assert out["nested"]["c"] == "hello" and out["nested"]["d"] == 3.5
    assert isinstance(out["tup"], tuple) and out["none"] is None


def test_ensemble_roundtrip(tmp_path, rng):
    from repro.core import BoosterConfig, train, predict_margins

    x = rng.normal(size=(300, 4)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    cfg = BoosterConfig(n_rounds=3, max_depth=2, objective="binary:logistic",
                        max_bins=16)
    st = train(x, y, cfg)
    path = os.path.join(tmp_path, "ens.msgpack")
    save_ensemble(path, st.ensemble)
    ens = load_ensemble(path)
    a = predict_margins(st.ensemble, jnp.asarray(x), 2)
    b = predict_margins(ens, jnp.asarray(x), 2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
