"""Paper §2.1: feature quantile generation."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import quantile as Q


def test_cuts_monotonic(rng):
    x = rng.normal(size=(500, 6)).astype(np.float32)
    cuts = np.asarray(Q.compute_cuts(jnp.asarray(x), 64))
    finite = np.where(np.isfinite(cuts), cuts, np.inf)
    assert np.all(np.diff(finite, axis=1) >= 0), "cuts must be ascending"


def test_quantize_range_and_missing(rng):
    x = rng.normal(size=(300, 4)).astype(np.float32)
    x[rng.random(x.shape) < 0.2] = np.nan
    max_bins = 32
    cuts = Q.compute_cuts(jnp.asarray(x), max_bins)
    bins = np.asarray(Q.quantize(jnp.asarray(x), cuts))
    miss = Q.missing_bin_id(max_bins)
    assert bins.min() >= 0 and bins.max() <= miss
    np.testing.assert_array_equal(bins == miss, np.isnan(x))


def test_quantize_equal_mass(rng):
    """Each used value bin should hold roughly n/n_bins rows for a
    continuous feature."""
    n, max_bins = 8192, 16
    x = rng.normal(size=(n, 1)).astype(np.float32)
    cuts = Q.compute_cuts(jnp.asarray(x), max_bins)
    bins = np.asarray(Q.quantize(jnp.asarray(x), cuts))[:, 0]
    counts = np.bincount(bins, minlength=max_bins)
    used = counts[counts > 0]
    assert len(used) == Q.n_value_bins(max_bins)
    assert used.max() / used.min() < 1.5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), max_bins=st.sampled_from([4, 16, 64]))
def test_quantize_order_preserving(seed, max_bins):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(100, 1)).astype(np.float32)
    cuts = Q.compute_cuts(jnp.asarray(x), max_bins)
    bins = np.asarray(Q.quantize(jnp.asarray(x), cuts))[:, 0]
    order = np.argsort(x[:, 0])
    assert np.all(np.diff(bins[order]) >= 0), "quantisation must preserve order"


def test_constant_feature(rng):
    x = np.full((100, 1), 3.14, np.float32)
    cuts = Q.compute_cuts(jnp.asarray(x), 16)
    bins = np.asarray(Q.quantize(jnp.asarray(x), cuts))
    assert len(np.unique(bins)) == 1, "constant feature -> single bin"


def _edge_case_matrix(rng):
    """NaN holes, a constant column, an all-missing column — the shapes
    that distinguish the dispatched fast path from the reference if the
    fill/sort/selection stages drift."""
    x = rng.normal(size=(400, 6)).astype(np.float32)
    x[rng.random(x.shape) < 0.15] = np.nan
    x[:, 2] = -1.5
    x[:, 4] = np.nan
    return x


def test_compute_cuts_matches_reference_bitwise(rng):
    """The backend-dispatched compute_cuts (host sort on CPU, device sort
    elsewhere) must be BIT-identical to the single-jit XLA reference: the
    sort produces the same array either way (same multiset per column,
    floats without NaN are totally ordered) and the selection stage is the
    same compiled function."""
    x = _edge_case_matrix(rng)
    for max_bins in (16, 256):
        got = np.asarray(Q.compute_cuts(jnp.asarray(x), max_bins))
        want = np.asarray(Q.compute_cuts_reference(jnp.asarray(x), max_bins))
        np.testing.assert_array_equal(got, want)


def test_quantize_matches_reference_bitwise(rng):
    """The dispatched quantize (host searchsorted on CPU) must be
    BIT-identical to the jitted reference: both perform the same exact
    float comparisons over the same ascending cuts, and NaN rows are
    overridden to the missing bin on both paths."""
    import jax

    x = _edge_case_matrix(rng)
    for max_bins in (16, 256):
        cuts = Q.compute_cuts(jnp.asarray(x), max_bins)
        got = np.asarray(Q.quantize(jnp.asarray(x), cuts))
        want = np.asarray(Q.quantize_reference(jnp.asarray(x), cuts))
        np.testing.assert_array_equal(got, want)
    # Under jit the host detour is impossible; the traced path must match.
    cuts = Q.compute_cuts(jnp.asarray(x), 64)
    gj = np.asarray(jax.jit(Q.quantize)(jnp.asarray(x), cuts))
    np.testing.assert_array_equal(
        gj, np.asarray(Q.quantize_reference(jnp.asarray(x), cuts)))


def test_compute_cuts_under_jit(rng):
    """compute_cuts must stay traceable: under jit the eager host-sort
    detour is impossible, so the all-device path runs — and still matches
    the reference bitwise."""
    import jax

    x = _edge_case_matrix(rng)
    got = np.asarray(jax.jit(lambda a: Q.compute_cuts(a, 64))(jnp.asarray(x)))
    want = np.asarray(Q.compute_cuts_reference(jnp.asarray(x), 64))
    np.testing.assert_array_equal(got, want)
