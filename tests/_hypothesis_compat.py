"""Shared shim: property tests degrade to skips on minimal installs.

Import `given, settings, st` from here instead of hypothesis directly —
when hypothesis is absent, @given-decorated tests become pytest skips
while the plain tests in the same module keep running.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # pragma: no cover - exercised on minimal installs
    import pytest as _pytest

    def given(*_a, **_k):
        return lambda fn: _pytest.mark.skip(reason="hypothesis not installed")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _MissingStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _MissingStrategies()
