"""End-to-end gradient boosting (Figure 1 pipeline) behaviour tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BoosterConfig, train, predict_proba, predict_margins
from repro.core import get_metric


@pytest.fixture(scope="module")
def binary_data():
    rng = np.random.default_rng(0)
    n, f = 1500, 8
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f)
    y = ((x @ w + 0.4 * np.sin(3 * x[:, 0]) + 0.3 * rng.normal(size=n)) > 0)
    return x, y.astype(np.float32)


def test_binary_classification(binary_data):
    x, y = binary_data
    cfg = BoosterConfig(n_rounds=20, max_depth=4, objective="binary:logistic",
                        max_bins=64)
    st = train(x, y, cfg)
    p = np.asarray(predict_proba(st.ensemble, x, cfg.max_depth, cfg.objective))
    acc = np.mean((p > 0.5) == y)
    assert acc > 0.9, acc


def test_train_metric_improves(binary_data):
    x, y = binary_data
    cfg = BoosterConfig(n_rounds=15, max_depth=3, objective="binary:logistic",
                        max_bins=32)
    st = train(x, y, cfg, verbose_every=7)
    accs = [h["train_accuracy"] for h in st.history if "train_accuracy" in h]
    assert accs[-1] > accs[0], accs


def test_regression_rmse(rng):
    n, f = 1200, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (x @ rng.normal(size=f) + 0.5 * x[:, 0] * x[:, 1]).astype(np.float32)
    cfg = BoosterConfig(n_rounds=30, max_depth=4, objective="reg:squarederror",
                        max_bins=64)
    st = train(x, y, cfg)
    pred = np.asarray(predict_margins(st.ensemble, jnp.asarray(x), 4))[:, 0]
    rmse = float(np.sqrt(np.mean((pred - y) ** 2)))
    base = float(np.std(y))
    assert rmse < 0.4 * base, (rmse, base)


def test_multiclass(rng):
    n, f, k = 900, 6, 4
    centers = rng.normal(size=(k, f)) * 2.5
    y = rng.integers(0, k, size=n)
    x = (centers[y] + rng.normal(size=(n, f))).astype(np.float32)
    cfg = BoosterConfig(n_rounds=10, max_depth=3, objective="multi:softmax",
                        n_classes=k, max_bins=32)
    st = train(x, y.astype(np.float32), cfg)
    assert st.ensemble.n_trees == 10 * k  # k trees per round
    pred = np.asarray(predict_proba(st.ensemble, x, 3, "multi:softmax"))
    assert np.mean(pred == y) > 0.9


def test_missing_values_learned_direction(rng):
    """Signal carried BY missingness: x0 is NaN for class 1. The
    sparsity-aware default direction must pick it up."""
    n = 1000
    x = rng.normal(size=(n, 3)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    x[y == 1, 0] = np.nan
    cfg = BoosterConfig(n_rounds=5, max_depth=2, objective="binary:logistic",
                        max_bins=16)
    st = train(x, y, cfg)
    p = np.asarray(predict_proba(st.ensemble, x, 2, cfg.objective))
    assert np.mean((p > 0.5) == y) > 0.95


def test_kernel_path_identical(binary_data):
    """Pallas histogram kernel path must reproduce the XLA path's trees."""
    x, y = binary_data
    x, y = x[:600], y[:600]
    kw = dict(n_rounds=4, max_depth=3, objective="binary:logistic", max_bins=32)
    st_a = train(x, y, BoosterConfig(**kw))
    st_b = train(x, y, BoosterConfig(**kw, use_kernel_histograms=True))
    assert bool(jnp.all(st_a.ensemble.feature == st_b.ensemble.feature))
    assert bool(jnp.all(st_a.ensemble.split_bin == st_b.ensemble.split_bin))
    np.testing.assert_allclose(np.asarray(st_a.ensemble.leaf_value),
                               np.asarray(st_b.ensemble.leaf_value), atol=1e-4)


def test_rank_pairwise(rng):
    n_groups, per = 40, 8
    n = n_groups * per
    x = rng.normal(size=(n, 5)).astype(np.float32)
    rel = (x @ rng.normal(size=5)).astype(np.float32)
    gids = np.repeat(np.arange(n_groups), per).astype(np.int32)
    cfg = BoosterConfig(n_rounds=10, max_depth=3, objective="rank:pairwise",
                        max_bins=32)
    st = train(x, rel, cfg, group_ids=gids)
    m = predict_margins(st.ensemble, jnp.asarray(x), 3)
    pairwise_acc = get_metric("pairwise_acc")
    acc = float(pairwise_acc.fn(m, jnp.asarray(rel)))
    assert acc > 0.75, acc
    ndcg = get_metric("ndcg@5")
    nd = float(ndcg.fn(m, jnp.asarray(rel), group_ids=jnp.asarray(gids)))
    assert nd > 0.8, nd


def test_eval_set(binary_data):
    x, y = binary_data
    cfg = BoosterConfig(n_rounds=8, max_depth=3, objective="binary:logistic",
                        max_bins=32)
    st = train(x[:1000], y[:1000], cfg, eval_set=(x[1000:], y[1000:]))
    rec = [h for h in st.history if "valid_accuracy" in h]
    assert rec and rec[-1]["valid_accuracy"] > 0.8


def test_lossguide_end_to_end(binary_data):
    x, y = binary_data
    cfg = BoosterConfig(n_rounds=10, max_depth=6, growth="lossguide",
                        max_leaves=8, objective="binary:logistic", max_bins=32)
    st = train(x, y, cfg)
    leaves = np.asarray(jnp.sum(st.ensemble.is_leaf, axis=1))
    assert np.all(leaves <= 8)
    p = np.asarray(predict_proba(st.ensemble, x, 6, cfg.objective))
    assert np.mean((p > 0.5) == y) > 0.85
