"""Paper §2.3: histogram build + split evaluation correctness."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import histogram as H
from repro.core import split as S


def brute_hist(bins, gh, pos, n_nodes, max_bins):
    out = np.zeros((n_nodes, bins.shape[1], max_bins, 2), np.float64)
    for i in range(bins.shape[0]):
        if pos[i] < n_nodes:
            for f in range(bins.shape[1]):
                out[pos[i], f, bins[i, f]] += gh[i]
    return out


def test_histogram_vs_bruteforce(rng):
    n, f, b, nodes = 500, 5, 16, 3
    bins = rng.integers(0, b, size=(n, f)).astype(np.int32)
    gh = rng.normal(size=(n, 2)).astype(np.float32)
    pos = rng.integers(0, nodes + 1, size=n).astype(np.int32)
    got = np.asarray(H.build_histograms(jnp.asarray(bins), jnp.asarray(gh),
                                        jnp.asarray(pos), nodes, b))
    want = brute_hist(bins, gh, pos, nodes, b)
    np.testing.assert_allclose(got, want, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_histogram_mass_conservation(seed):
    """Sum over (feature-0 bins) of each node == that node's (G, H) sum —
    every feature's bins partition the same rows (invariant the split
    evaluator relies on)."""
    rng = np.random.default_rng(seed)
    n, f, b, nodes = 200, 3, 8, 2
    bins = rng.integers(0, b, size=(n, f)).astype(np.int32)
    gh = rng.normal(size=(n, 2)).astype(np.float32)
    pos = rng.integers(0, nodes, size=n).astype(np.int32)
    hist = np.asarray(H.build_histograms(jnp.asarray(bins), jnp.asarray(gh),
                                         jnp.asarray(pos), nodes, b))
    for nd in range(nodes):
        want = gh[pos == nd].sum(axis=0)
        for feat in range(f):
            np.testing.assert_allclose(hist[nd, feat].sum(axis=0), want, atol=1e-3)


def brute_best_split(bins, gh, max_bins, lam, mcw):
    """Enumerate every (feature, threshold, missing-direction)."""
    n, f = bins.shape
    g_tot, h_tot = gh.sum(axis=0)
    parent = g_tot**2 / (h_tot + lam)
    best = (-np.inf, 0, 0, False)
    for feat in range(f):
        for thr in range(max_bins - 2):
            for dl in (False, True):
                val = bins[:, feat]
                missing = val == max_bins - 1
                left = (val <= thr) & ~missing
                if dl:
                    left = left | missing
                gl, hl = gh[left].sum(axis=0) if left.any() else (0.0, 0.0)
                gr, hr = g_tot - gl, h_tot - hl
                if hl < mcw or hr < mcw:
                    continue
                gain = 0.5 * (gl**2 / (hl + lam) + gr**2 / (hr + lam) - parent)
                if gain > best[0] + 1e-9:
                    best = (gain, feat, thr, dl)
    return best


def test_split_vs_bruteforce(rng):
    n, f, b = 120, 3, 8
    for trial in range(5):
        bins = rng.integers(0, b, size=(n, f)).astype(np.int32)
        gh = np.stack([rng.normal(size=n), np.abs(rng.normal(size=n)) + 0.1],
                      axis=1).astype(np.float32)
        pos = np.zeros(n, np.int32)
        hist = H.build_histograms(jnp.asarray(bins), jnp.asarray(gh),
                                  jnp.asarray(pos), 1, b)
        parent = jnp.asarray(gh.sum(axis=0))[None]
        sp = S.evaluate_splits(hist, parent, S.SplitParams(1.0, 0.0, 0.5))
        want = brute_best_split(bins, gh, b, 1.0, 0.5)
        assert abs(float(sp.gain[0]) - want[0]) < 1e-3, (trial, float(sp.gain[0]), want)
        assert int(sp.feature[0]) == want[1]
        assert int(sp.split_bin[0]) == want[2]


def test_split_child_sums_consistent(rng):
    n, f, b = 200, 4, 16
    bins = rng.integers(0, b, size=(n, f)).astype(np.int32)
    gh = np.stack([rng.normal(size=n), np.ones(n)], axis=1).astype(np.float32)
    pos = np.zeros(n, np.int32)
    hist = H.build_histograms(jnp.asarray(bins), jnp.asarray(gh),
                              jnp.asarray(pos), 1, b)
    parent = jnp.asarray(gh.sum(axis=0))[None]
    sp = S.evaluate_splits(hist, parent, S.SplitParams())
    np.testing.assert_allclose(
        np.asarray(sp.left_sum + sp.right_sum), np.asarray(parent), atol=1e-3
    )
    # recompute left sum by routing rows (bin b-1 is the missing bin and
    # follows the learned default direction)
    feat, thr, dl = int(sp.feature[0]), int(sp.split_bin[0]), bool(sp.default_left[0])
    val = bins[:, feat]
    missing = val == b - 1
    left = (val <= thr) & ~missing
    if dl:
        left |= missing
    np.testing.assert_allclose(
        gh[left].sum(axis=0), np.asarray(sp.left_sum[0]), atol=1e-3
    )


def test_no_valid_split_gives_neg_inf():
    """A pure node (all same bin) has no valid split."""
    bins = np.zeros((50, 2), np.int32)
    gh = np.stack([np.ones(50), np.ones(50)], axis=1).astype(np.float32)
    hist = H.build_histograms(jnp.asarray(bins), jnp.asarray(gh),
                              jnp.zeros(50, jnp.int32), 1, 8)
    sp = S.evaluate_splits(hist, jnp.asarray([[50.0, 50.0]]), S.SplitParams())
    assert not np.isfinite(float(sp.gain[0])) or float(sp.gain[0]) <= 1e-5


# --------------------------------------------------------------------------
# Packed-builder bit-identity (ISSUE 9): the feature-major packed scatter
# and the chunk-stacked scatter must reproduce the dense row-major build
# EXACTLY — per (node, feature, bin) slot the f32 adds occur in global row
# order in all three layouts, so not even summation order differs. The
# subtraction trick and the external-memory scan rely on this.
# --------------------------------------------------------------------------

def test_packed_feature_major_bitwise_vs_dense(rng):
    from repro.core import compress as C

    for n, f, max_bins, nodes in [
        (1000, 7, 16, 5), (513, 3, 256, 8), (257, 4, 64, 1), (2048, 9, 32, 13),
    ]:
        bits = C.bits_needed(max_bins - 1)
        bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
        gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, nodes + 1, size=n), jnp.int32)
        dense = H.build_histograms(bins, gh, pos, nodes, max_bins)
        packed = C.pack(bins, bits)
        got = H.build_histograms_packed(packed, gh, pos, nodes, max_bins,
                                        bits, n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))


def test_chunked_bitwise_vs_dense(rng):
    from repro.core import compress as C

    n, f, max_bins, nodes, chunk_rows = 1000, 7, 16, 5, 100
    bits = C.bits_needed(max_bins - 1)
    bins_np = rng.integers(0, max_bins, size=(n, f)).astype(np.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, nodes + 1, size=n), jnp.int32)
    chunks = [
        np.asarray(C.pack(jnp.asarray(bins_np[lo:lo + chunk_rows]), bits))
        for lo in range(0, n, chunk_rows)
    ]
    got = H.build_histograms_chunked(
        jnp.asarray(np.stack(chunks)), gh, pos, nodes, max_bins, bits,
        chunk_rows, n)
    dense = H.build_histograms(jnp.asarray(bins_np), gh, pos, nodes, max_bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
