"""The objective/metric plugin path (DESIGN.md §10).

Covers the ISSUE 3 acceptance surface: a hand-written objective callable
passed via fit(obj=...) produces a bit-identical ensemble to the built-in,
a custom objective plus several metrics all run inside ONE compiled fit
(verified by Python-side trace counters — the functions execute once at
trace time, not once per round), multi-metric fits emit {set}_{metric}
history keys for every requested metric, and checkpointing resolves
objectives by registry name with clear errors for anonymous callables.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Booster,
    DeviceDMatrix,
    register_objective,
)
from repro.core import booster as B
from repro.core import objectives as O


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(17)
    n, f = 900, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f)
    y = ((x @ w + 0.3 * rng.normal(size=n)) > 0).astype(np.float32)
    xt, yt, xv, yv = x[:700], y[:700], x[700:], y[700:]
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    dval = DeviceDMatrix(xv, label=yv, ref=dtrain)
    return dtrain, dval


def _hand_logistic(margins, y):
    p = jax.nn.sigmoid(margins[:, 0])
    return p - y, p * (1.0 - p)


def _ensembles_identical(a, b):
    assert bool(jnp.all(a.feature == b.feature))
    assert bool(jnp.all(a.split_bin == b.split_bin))
    assert bool(jnp.all(a.is_leaf == b.is_leaf))
    np.testing.assert_array_equal(np.asarray(a.leaf_value),
                                  np.asarray(b.leaf_value))


def test_custom_objective_matches_builtin_bit_identical(data):
    """Acceptance: fit(obj=callable) with the logistic gradients must equal
    the built-in binary:logistic ensemble bit for bit."""
    dtrain, _ = data
    kw = dict(n_rounds=6, max_depth=3, max_bins=32)
    b_custom = Booster(**kw).fit(dtrain, obj=_hand_logistic)
    b_builtin = Booster(**kw, objective="binary:logistic").fit(dtrain)
    _ensembles_identical(b_custom.ensemble, b_builtin.ensemble)
    np.testing.assert_array_equal(np.asarray(b_custom.margins),
                                  np.asarray(b_builtin.margins))


def test_custom_obj_and_metrics_trace_into_one_compiled_fit(data):
    """Acceptance: a custom objective and two simultaneous eval metrics run
    INSIDE one compiled fit. The Python bodies execute only at trace time —
    a per-round host dispatch would execute them n_rounds times."""
    dtrain, dval = data
    calls = {"grad": 0, "metric": 0}

    def my_obj(margins, y):
        calls["grad"] += 1
        return _hand_logistic(margins, y)

    def my_metric(margins, y):
        calls["metric"] += 1
        return jnp.mean(jnp.abs(jax.nn.sigmoid(margins[:, 0]) - y))

    n_rounds = 10
    bst = Booster(n_rounds=n_rounds, max_depth=3, max_bins=32)
    bst.fit(dtrain, evals=[(dval, "valid")], obj=my_obj,
            eval_metric=["logloss"], custom_metric=("pdist", my_metric))
    # One trace of the scan body: grad runs once, the custom metric once for
    # the train stack + once for the eval stack. Never once per round.
    assert 1 <= calls["grad"] <= 2, calls
    assert 1 <= calls["metric"] <= 4, calls
    assert calls["grad"] < n_rounds and calls["metric"] < n_rounds

    # Both requested metrics, per round, for train and eval set.
    assert [h["round"] for h in bst.history] == list(range(n_rounds))
    for key in ("train_logloss", "train_pdist", "valid_logloss",
                "valid_pdist"):
        assert all(key in h for h in bst.history), key

    # Refit with the SAME callables hits the compiled-fn cache: the wrapped
    # objective/metric resolve to identical registry objects, so no retrace.
    before = dict(calls)
    Booster(n_rounds=n_rounds, max_depth=3, max_bins=32).fit(
        dtrain, evals=[(dval, "valid")], obj=my_obj,
        eval_metric=["logloss"], custom_metric=("pdist", my_metric))
    assert calls == before, (before, calls)


def test_bare_tuple_and_metric_instance_specs_in_fit(data):
    """eval_metric accepts a bare (name, fn) tuple (one metric, not two)
    and a hand-built Metric whose fn ignores the scan's extra keywords."""
    from repro.core import Metric

    dtrain, dval = data

    def spread(margins, y):
        return jnp.max(margins[:, 0]) - jnp.min(margins[:, 0])

    bst = Booster(n_rounds=3, max_depth=2, objective="binary:logistic",
                  max_bins=32)
    bst.fit(dtrain, evals=[(dval, "valid")], eval_metric=("spread", spread))
    assert all("valid_spread" in h and "valid_rmse" not in h
               for h in bst.history)

    bst2 = Booster(n_rounds=3, max_depth=2, objective="binary:logistic",
                   max_bins=32)
    bst2.fit(dtrain, evals=[(dval, "valid")],
             eval_metric=Metric("spread2", spread, maximize=True))
    assert all("valid_spread2" in h for h in bst2.history)
    post = bst2.eval(dval, "valid", metrics=("spread2", spread))
    assert post["valid_spread2"] == pytest.approx(
        bst2.history[-1]["valid_spread2"], rel=1e-5)


def test_multi_metric_history_keys_for_every_metric(data):
    dtrain, dval = data
    bst = Booster(n_rounds=4, max_depth=3, objective="binary:logistic",
                  max_bins=32)
    bst.fit(dtrain, evals=[(dval, "valid")],
            eval_metric=["logloss", "error", "auc"])
    for h in bst.history:
        for mname in ("logloss", "error", "auc"):
            assert f"train_{mname}" in h and f"valid_{mname}" in h
    # auc direction sanity: the model separates classes, so auc >> 0.5
    assert bst.history[-1]["valid_auc"] > 0.8


def test_in_scan_multi_metrics_match_posthoc_eval(data):
    """Metrics computed inside the compiled scan agree with a post-hoc
    Booster.eval of the same metric list (bin-space traversal is exact)."""
    dtrain, dval = data
    bst = Booster(n_rounds=5, max_depth=3, objective="binary:logistic",
                  max_bins=32)
    bst.fit(dtrain, evals=[(dval, "valid")], eval_metric=["logloss", "auc"])
    post = bst.eval(dval, "valid", metrics=["logloss", "auc"])
    assert bst.history[-1]["valid_logloss"] == pytest.approx(
        post["valid_logloss"], rel=1e-5)
    assert bst.history[-1]["valid_auc"] == pytest.approx(
        post["valid_auc"], rel=1e-5)


def test_registered_custom_objective_checkpoint_roundtrip(data, tmp_path):
    """Satellite: a model trained with a REGISTERED custom objective saves
    by name and loads bit-identically (objective resolved from the
    registry at load time)."""
    dtrain, _ = data
    name = "test:logistic_plugin"
    try:
        obj = register_objective(
            name, _hand_logistic,
            transform=lambda m: jax.nn.sigmoid(m[:, 0]),
            default_metric="accuracy",
        )
        bst = Booster(n_rounds=4, max_depth=3, max_bins=32).fit(dtrain,
                                                                obj=obj)
        path = str(tmp_path / "plugin.msgpack")
        bst.save(path)
        loaded = Booster.load(path)
        assert loaded.cfg.objective == name
        _ensembles_identical(bst.ensemble, loaded.ensemble)
        x = np.asarray(dtrain.matrix.cuts[:, :1].T)  # any (1, f) probe
        np.testing.assert_array_equal(np.asarray(bst.predict(x)),
                                      np.asarray(loaded.predict(x)))
    finally:
        O.OBJECTIVES.pop(name, None)


def test_unregistered_callable_save_raises_naming_the_fix(data, tmp_path):
    dtrain, _ = data
    bst = Booster(n_rounds=2, max_depth=2, max_bins=32).fit(
        dtrain, obj=_hand_logistic)
    with pytest.raises(ValueError, match="register_objective"):
        bst.save(str(tmp_path / "nope.msgpack"))


def test_load_unknown_objective_raises_naming_the_fix(data, tmp_path):
    dtrain, _ = data
    name = "test:ephemeral"
    obj = register_objective(name, _hand_logistic)
    try:
        bst = Booster(n_rounds=2, max_depth=2, max_bins=32).fit(dtrain,
                                                                obj=obj)
        path = str(tmp_path / "eph.msgpack")
        bst.save(path)
    finally:
        O.OBJECTIVES.pop(name, None)
    with pytest.raises(ValueError, match="register_objective"):
        Booster.load(path)


def test_unknown_objective_name_lists_builtins():
    with pytest.raises(ValueError, match="binary:logistic"):
        Booster(objective="not:an_objective").obj


def test_custom_objective_compile_cache_keyed_stably(data):
    """The compiled-train-fn cache must key the SAME callable to the same
    entry across fits (no per-fit recompile) and different callables to
    different entries."""
    dtrain, _ = data

    def obj_a(margins, y):
        return _hand_logistic(margins, y)

    def obj_b(margins, y):
        return margins[:, 0] - y, jnp.ones_like(y)

    kw = dict(n_rounds=3, max_depth=2, max_bins=32)
    B._TRAIN_FN_CACHE.clear()
    Booster(**kw).fit(dtrain, obj=obj_a)
    n1 = len(B._TRAIN_FN_CACHE)
    Booster(**kw).fit(dtrain, obj=obj_a)  # same callable -> cache hit
    assert len(B._TRAIN_FN_CACHE) == n1
    Booster(**kw).fit(dtrain, obj=obj_b)  # different loss -> new entry
    assert len(B._TRAIN_FN_CACHE) == n1 + 1
