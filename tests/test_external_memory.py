"""External-memory training path (DESIGN.md §11).

The headline guarantee: training over an artificially chunked
ExternalDMatrix is BIT-IDENTICAL to single-shot training on the same data
— same trees, same margins, same predictions — because the chunked round
performs the same f32 operations in the same order (per-bin scatter order,
one barriered margin add). Plus: from_batches assembly identity, batch
validation errors, eval sets / early stopping / continuation over chunks,
and sketch-cut training quality.
"""
import numpy as np
import pytest

from repro.core import Booster, DeviceDMatrix, ExternalDMatrix
from repro.core import compress as C

ENSEMBLE_FIELDS = (
    "feature",
    "split_bin",
    "threshold",
    "default_left",
    "leaf_value",
    "is_leaf",
)


def assert_boosters_identical(b1, b2):
    for fld in ENSEMBLE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(b1.ensemble, fld)),
            np.asarray(getattr(b2.ensemble, fld)),
            err_msg=f"ensemble field {fld} differs",
        )


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    n, f = 3000, 8
    x = rng.standard_normal((n, f)).astype(np.float32)
    x[rng.random((n, f)) < 0.05] = np.nan
    w = rng.standard_normal(f).astype(np.float32)
    y = ((np.nan_to_num(x) @ w + 0.3 * rng.standard_normal(n)) > 0).astype(
        np.float32
    )
    return x, y


def test_multi_chunk_fit_bit_identical_to_single_shot(data):
    """The acceptance criterion: fit over >= 4 chunks (last one short)
    equals the in-memory fit bit for bit."""
    x, y = data
    dtrain = DeviceDMatrix(x, label=y)
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=700, cuts="exact")
    assert ext.n_chunks == 5  # 4 full chunks + a short one
    b1 = Booster(n_rounds=10, max_depth=4, objective="binary:logistic").fit(dtrain)
    b2 = Booster(n_rounds=10, max_depth=4, objective="binary:logistic").fit(ext)
    assert_boosters_identical(b1, b2)
    np.testing.assert_array_equal(np.asarray(b1.margins), np.asarray(b2.margins))
    np.testing.assert_array_equal(
        np.asarray(b1.predict(x)), np.asarray(b2.predict(x))
    )
    # bin-space prediction over the chunked matrix agrees with flat
    np.testing.assert_array_equal(
        np.asarray(b2.predict(ext)), np.asarray(b1.predict(dtrain))
    )


def test_multiclass_chunked_bit_identical(data):
    x, _ = data
    rng = np.random.default_rng(11)
    y = rng.integers(0, 3, x.shape[0]).astype(np.float32)
    d = DeviceDMatrix(x, label=y)
    e = ExternalDMatrix.from_arrays(x, y, chunk_rows=640, cuts="exact")
    kw = dict(n_rounds=6, max_depth=3, objective="multi:softmax", n_classes=3)
    assert_boosters_identical(Booster(**kw).fit(d), Booster(**kw).fit(e))


def test_update_continuation_matches_longer_fit(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=800, cuts="exact")
    long = Booster(n_rounds=8, max_depth=3, objective="binary:logistic").fit(ext)
    short = Booster(n_rounds=5, max_depth=3, objective="binary:logistic").fit(ext)
    short.update(ext, 3)
    assert_boosters_identical(long, short)


def test_external_eval_sets_and_early_stopping(data):
    x, y = data
    rng = np.random.default_rng(5)
    xv = rng.standard_normal((600, x.shape[1])).astype(np.float32)
    yv = (rng.random(600) < 0.5).astype(np.float32)
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=800)
    dvalid = ExternalDMatrix.from_arrays(xv, yv, chunk_rows=250, ref=ext)
    bst = Booster(n_rounds=40, max_depth=3, objective="binary:logistic").fit(
        ext, evals=[(dvalid, "valid")], early_stopping_rounds=4
    )
    assert bst.best_iteration is not None
    assert bst.n_rounds_trained == bst.best_iteration + 1
    assert any(k.startswith("valid_") for k in bst.history[0])
    # mixed eval types work too: a DeviceDMatrix sharing the external cuts
    dv2 = DeviceDMatrix(xv, label=yv, ref=ext)
    res = bst.eval(dv2, name="v2", metrics="logloss")
    assert np.isfinite(res["v2_logloss"])


def test_from_batches_identity(data):
    """DeviceDMatrix.from_batches == DeviceDMatrix on the concatenation,
    bit for bit (packed words, cuts, labels and the resulting fit)."""
    x, y = data
    chunks = [
        (x[:1000], y[:1000]),
        (x[1000:1500], y[1000:1500]),
        (x[1500:], y[1500:]),
    ]
    d1 = DeviceDMatrix(x, label=y)
    d2 = DeviceDMatrix.from_batches(iter(chunks))
    np.testing.assert_array_equal(
        np.asarray(d1.matrix.packed), np.asarray(d2.matrix.packed)
    )
    np.testing.assert_array_equal(np.asarray(d1.cuts), np.asarray(d2.cuts))
    np.testing.assert_array_equal(np.asarray(d1.label), np.asarray(d2.label))
    b1 = Booster(n_rounds=5, max_depth=3, objective="binary:logistic").fit(d1)
    b2 = Booster(n_rounds=5, max_depth=3, objective="binary:logistic").fit(d2)
    assert_boosters_identical(b1, b2)


def test_batch_validation_errors(data):
    """The satellite fix: inconsistent batches fail fast with a clear error
    naming the offending batch, not an opaque XLA shape error."""
    x, y = data
    with pytest.raises(ValueError, match="batch 1 has 4 features"):
        DeviceDMatrix.from_batches([x[:10, :8], x[10:20, :4]])
    with pytest.raises(ValueError, match="batch 1 has dtype"):
        DeviceDMatrix.from_batches([x[:10], x[10:20].astype(np.float64)])
    with pytest.raises(ValueError, match="batch 0 must be 2-D"):
        DeviceDMatrix.from_batches([x[0]])
    with pytest.raises(ValueError, match="non-numeric"):
        DeviceDMatrix.from_batches([np.array([["a", "b"], ["c", "d"]])])
    with pytest.raises(ValueError, match="label has 3 rows"):
        DeviceDMatrix.from_batches([(x[:10], y[:3])])
    with pytest.raises(ValueError, match="label"):
        DeviceDMatrix.from_batches([(x[:10], y[:10]), x[10:20]])
    with pytest.raises(ValueError, match="no batches"):
        DeviceDMatrix.from_batches([])
    with pytest.raises(ValueError, match="batch 1 is empty"):
        ExternalDMatrix([x[:10], x[:0]], chunk_rows=8)
    with pytest.raises(ValueError, match="chunk_rows"):
        ExternalDMatrix.from_arrays(x, y, chunk_rows=0)
    with pytest.raises(ValueError, match="cuts must be"):
        ExternalDMatrix.from_arrays(x, y, chunk_rows=512, cuts="bogus")


def test_rechunking_arbitrary_batch_sizes(data):
    """Incoming batch sizes need not match chunk_rows: rows are re-sliced
    into uniform chunks and the fit stays bit-identical."""
    x, y = data
    sizes = [123, 1001, 7, 869, 1000]
    chunks, start = [], 0
    for s in sizes:
        chunks.append((x[start : start + s], y[start : start + s]))
        start += s
    e1 = ExternalDMatrix(iter(chunks), chunk_rows=512, cuts="exact")
    e2 = ExternalDMatrix.from_arrays(x, y, chunk_rows=512, cuts="exact")
    assert e1.n_chunks == e2.n_chunks == 6
    np.testing.assert_array_equal(e1._host_packed, e2._host_packed)
    np.testing.assert_array_equal(np.asarray(e1.label), np.asarray(e2.label))


def test_sketch_cuts_training_quality(data):
    """Default (sketch) cuts train to near-parity with exact cuts."""
    x, y = data
    rng = np.random.default_rng(13)
    mask = rng.random(x.shape[0]) < 0.8
    kw = dict(n_rounds=10, max_depth=4, objective="binary:logistic")
    ext = ExternalDMatrix.from_arrays(x[mask], y[mask], chunk_rows=500)
    dmem = DeviceDMatrix(x[mask], label=y[mask])
    acc = []
    for bst in (Booster(**kw).fit(ext), Booster(**kw).fit(dmem)):
        p = np.asarray(bst.predict(x[~mask])) > 0.5
        acc.append(float(np.mean(p == y[~mask])))
    assert acc[0] > 0.75
    assert abs(acc[0] - acc[1]) < 0.05


def test_paging_and_surfaces(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=1000)
    assert ext.n_rows == x.shape[0]
    assert ext.n_features == x.shape[1]
    assert ext.nbytes_device == 0  # nothing paged in yet
    cpb = ext.packed_bins()
    assert isinstance(cpb, C.ChunkedPackedBins)
    assert ext.nbytes_device == ext.nbytes_host
    assert cpb.padded_rows >= ext.n_rows
    ext.unload()
    assert ext.nbytes_device == 0
    # save/load roundtrip after an external fit
    bst = Booster(n_rounds=4, max_depth=3, objective="binary:logistic").fit(ext)
    import tempfile

    with tempfile.NamedTemporaryFile(suffix=".msgpack") as tmp:
        bst.save(tmp.name)
        loaded = Booster.load(tmp.name)
    np.testing.assert_array_equal(
        np.asarray(loaded.predict(x)), np.asarray(bst.predict(x))
    )


def test_kernel_histograms_rejected_for_external(data):
    x, y = data
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=1000)
    bst = Booster(
        n_rounds=2,
        max_depth=3,
        objective="binary:logistic",
        use_kernel_histograms=True,
    )
    with pytest.raises(NotImplementedError, match="kernel"):
        bst.fit(ext)


def test_distributed_external_matches_single_device():
    """The chunk loop composes with shard_map: chunks shard across the mesh
    and the resulting Booster matches single-device external training."""
    import os
    import subprocess
    import sys
    import textwrap

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src")
    script = textwrap.dedent(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import Booster, BoosterConfig, ExternalDMatrix
        from repro.jaxcompat import make_mesh
        rng = np.random.default_rng(2)
        n, f = 2048, 6
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x @ rng.normal(size=f) > 0).astype(np.float32)
        cfg = BoosterConfig(n_rounds=4, max_depth=3,
                            objective="binary:logistic", max_bins=32)
        # 16 chunks of 128 rows -> 2 chunks per shard on an 8-way mesh
        ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=128,
                                          max_bins=32, cuts="exact")
        single = Booster(cfg).fit(ext)
        mesh = make_mesh((8,), ("data",))
        sharded = Booster(cfg).fit(ext, mesh=mesh)
        assert bool(jnp.all(single.ensemble.feature == sharded.ensemble.feature))
        assert bool(jnp.all(single.ensemble.split_bin == sharded.ensemble.split_bin))
        d = float(jnp.max(jnp.abs(single.ensemble.leaf_value
                                  - sharded.ensemble.leaf_value)))
        assert d < 1e-4, d
        # misaligned chunking is rejected with a clear error
        bad = ExternalDMatrix.from_arrays(x[:2000], y[:2000], chunk_rows=300,
                                          max_bins=32)
        try:
            Booster(cfg).fit(bad, mesh=mesh)
        except ValueError as e:
            assert "chunk_rows" in str(e)
        else:
            raise AssertionError("misaligned chunks should be rejected")
        print("EXTERNAL-SHARDED-OK")
        """
    )
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=900,
        env=env,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "EXTERNAL-SHARDED-OK" in res.stdout


def test_chunked_packed_bins_roundtrip(data):
    """Unpacking each chunk of the stack reproduces the flat bins."""
    x, y = data
    d = DeviceDMatrix(x, label=y)
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=700, cuts="exact")
    cpb = ext.packed_bins()
    rows = [
        np.asarray(C.unpack(cpb.packed[c], cpb.bits, cpb.chunk_rows))
        for c in range(cpb.n_chunks)
    ]
    bins_chunked = np.concatenate(rows)[: ext.n_rows]
    np.testing.assert_array_equal(bins_chunked, np.asarray(d.matrix.unpack()))


# --- streamed out-of-core executor (DESIGN.md §17) -------------------------


def _ext(x, y, paging, prefetch=2, chunk_rows=700):
    return ExternalDMatrix.from_arrays(
        x,
        y,
        chunk_rows=chunk_rows,
        cuts="exact",
        paging=paging,
        prefetch_chunks=prefetch,
    )


def test_streamed_fit_bit_identical_resident_and_overlap_off(data):
    """The tentpole guarantee: the streamed executor (async prefetch ring)
    equals the resident compiled-scan fit bit for bit, with the overlap on
    (prefetch_chunks=2) or off (prefetch_chunks=0), and equals the
    in-memory fit on the same cuts."""
    x, y = data
    kw = dict(n_rounds=8, max_depth=4, objective="binary:logistic")
    ext = _ext(x, y, "resident")
    b_res = Booster(**kw).fit(ext)
    b_str = Booster(**kw).fit(_ext(x, y, "stream"))
    b_syn = Booster(**kw).fit(_ext(x, y, "stream", prefetch=0))
    b_mem = Booster(**kw).fit(DeviceDMatrix(x, label=y, cuts=ext.cuts))
    assert_boosters_identical(b_res, b_str)
    assert_boosters_identical(b_str, b_syn)
    assert_boosters_identical(b_str, b_mem)
    np.testing.assert_array_equal(np.asarray(b_res.margins), np.asarray(b_str.margins))
    np.testing.assert_array_equal(
        np.asarray(b_res.predict(x)), np.asarray(b_str.predict(x))
    )


def test_streamed_fit_never_pages_full_stack(data):
    """The point of streaming: device residency stays bounded by the pager
    ring — the full chunk stack is never device-resident."""
    x, y = data
    ext = _ext(x, y, "stream")
    bst = Booster(n_rounds=4, max_depth=3, objective="binary:logistic").fit(ext)
    assert ext.nbytes_device == 0  # no cached device stack after the fit
    assert ext.stream_stats is not None
    assert ext.stream_stats.chunks_paged > 0
    assert ext.stream_stats.rows_touched > 0
    assert bst.n_rounds_trained == 4


def test_streamed_multiclass_and_sampled_bit_identical(data):
    x, _ = data
    rng = np.random.default_rng(11)
    y3 = rng.integers(0, 3, x.shape[0]).astype(np.float32)
    kw = dict(n_rounds=5, max_depth=3, objective="multi:softmax", n_classes=3)
    assert_boosters_identical(
        Booster(**kw).fit(_ext(x, y3, "resident")),
        Booster(**kw).fit(_ext(x, y3, "stream")),
    )
    _, y = data
    kw = dict(
        n_rounds=5,
        max_depth=3,
        objective="binary:logistic",
        subsample=0.6,
        colsample_bytree=0.8,
        seed=3,
    )
    assert_boosters_identical(
        Booster(**kw).fit(_ext(x, y, "resident")),
        Booster(**kw).fit(_ext(x, y, "stream")),
    )


def test_streamed_update_continuation_matches_longer_fit(data):
    """update() over a streamed matrix replays one long fit's key stream
    and margins exactly (resume-safe eager executor)."""
    x, y = data
    kw = dict(n_rounds=8, max_depth=3, objective="binary:logistic")
    long = Booster(**kw).fit(_ext(x, y, "stream"))
    ext = _ext(x, y, "stream")
    short = Booster(n_rounds=5, max_depth=3, objective="binary:logistic").fit(ext)
    short.update(ext, 3)
    assert_boosters_identical(long, short)


def test_streamed_eval_sets_and_early_stopping_match_resident(data):
    x, y = data
    rng = np.random.default_rng(5)
    xv = rng.standard_normal((600, x.shape[1])).astype(np.float32)
    yv = (xv[:, 0] > 0).astype(np.float32)
    boosters = []
    for paging in ("resident", "stream"):
        ext = _ext(x, y, paging, chunk_rows=800)
        dv = DeviceDMatrix(xv, label=yv, ref=ext)
        bst = Booster(n_rounds=30, max_depth=3, objective="binary:logistic")
        boosters.append(bst.fit(ext, evals=[(dv, "valid")], early_stopping_rounds=4))
    b_res, b_str = boosters
    assert b_res.best_iteration == b_str.best_iteration
    assert b_res.history == b_str.history
    assert_boosters_identical(b_res, b_str)


def test_paging_knob_validation_and_auto(data):
    x, y = data
    with pytest.raises(ValueError, match="paging"):
        ExternalDMatrix.from_arrays(x, y, chunk_rows=700, paging="bogus")
    with pytest.raises(ValueError, match="prefetch_chunks"):
        ExternalDMatrix.from_arrays(x, y, chunk_rows=700, prefetch_chunks=-1)
    ext = ExternalDMatrix.from_arrays(x, y, chunk_rows=700)
    assert ext.paging == "auto"
    # CPU backends report no usable memory limit -> proven resident path
    assert ext.resolved_paging() in ("resident", "stream")
    assert _ext(x, y, "stream").resolved_paging() == "stream"
    assert _ext(x, y, "resident").resolved_paging() == "resident"
