"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress as C
from repro.kernels import ops as KO
from repro.kernels import ref as KR


@pytest.mark.parametrize(
    "n,f,max_bins,n_nodes",
    [
        (257, 4, 16, 1),
        (1000, 17, 64, 4),
        (513, 3, 256, 8),
        (64, 1, 8, 2),
        (2048, 9, 32, 13),
    ],
)
def test_histogram_kernel_sweep(rng, n, f, max_bins, n_nodes):
    bits = C.bits_needed(max_bins - 1)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_nodes + 1, size=n), jnp.int32)
    packed = C.pack(bins, bits)
    got = KO.histogram_packed_op(packed, gh, pos, n_nodes, max_bins, bits)
    want = KR.histogram_ref(packed, gh, pos, n_nodes, max_bins, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("gh_dtype", [jnp.float32])
@pytest.mark.parametrize("block", [(4, 4, 16), (8, 8, 64)])
def test_histogram_kernel_blocks(rng, gh_dtype, block):
    from repro.kernels.histogram import histogram_packed

    nodes_blk, f_blk, w_blk = block
    n, f, max_bins, n_nodes = 700, 6, 32, 5
    bits = C.bits_needed(max_bins - 1)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), gh_dtype)
    pos = jnp.asarray(rng.integers(0, n_nodes + 1, size=n), jnp.int32)
    packed = C.pack(bins, bits)
    got = histogram_packed(packed, gh, pos, n_nodes, max_bins, bits,
                           nodes_blk=nodes_blk, f_blk=f_blk, w_blk=w_blk)
    want = KR.histogram_ref(packed, gh, pos, n_nodes, max_bins, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("buffer_depth", [1, 2, 4])
@pytest.mark.parametrize("n_private", [1, 3, 8])
def test_histogram_private_kernel_sweep(rng, buffer_depth, n_private):
    """The privatised DMA-pipelined kernel across its scheduling space:
    every (scratch depth, privatisation factor) combination must agree
    with the oracle — the tree-add epilogue only reorders f32 sums."""
    from repro.kernels.histogram import build_histograms_packed_kernel

    n, f, max_bins, n_nodes = 700, 6, 32, 5
    bits = C.bits_needed(max_bins - 1)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_nodes + 1, size=n), jnp.int32)
    packed = C.pack(bins, bits)
    got = build_histograms_packed_kernel(
        packed, gh, pos, n_nodes, max_bins, bits,
        f_blk=4, w_blk=8, n_private=n_private, buffer_depth=buffer_depth,
    )
    want = KR.histogram_ref(packed, gh, pos, n_nodes, max_bins, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize(
    "n,f,max_bins,n_nodes",
    [(257, 4, 16, 1), (1000, 17, 64, 4), (513, 3, 256, 8)],
)
def test_histogram_private_op_shapes(rng, n, f, max_bins, n_nodes):
    """Default-scheduled ops-layer entry point over odd shapes (ragged
    feature/word padding) vs the oracle."""
    bits = C.bits_needed(max_bins - 1)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_nodes + 1, size=n), jnp.int32)
    packed = C.pack(bins, bits)
    got = KO.histogram_private_op(packed, gh, pos, n_nodes, max_bins, bits)
    want = KR.histogram_ref(packed, gh, pos, n_nodes, max_bins, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_private_kernel_parity_compacted_rows(rng):
    """Subtraction-trick consumers: the compacted-row builder over a row
    subset must agree with the kernel fed the full matrix with unselected
    rows parked in the dump slot — same per-node histograms either way."""
    from repro.core import histogram as H

    n, f, max_bins, n_nodes = 900, 5, 16, 3
    bits = C.bits_needed(max_bins - 1)
    bins = jnp.asarray(rng.integers(0, max_bins, size=(n, f)), jnp.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_nodes, size=n), jnp.int32)
    packed = C.pack(bins, bits)

    sel = np.flatnonzero(rng.random(n) < 0.4).astype(np.int32)
    row_ids = jnp.asarray(sel)
    compacted = H.build_histograms_packed_rows(
        packed, gh[row_ids], pos[row_ids], row_ids,
        n_nodes, max_bins, bits, block_rows=256,
    )

    mask = np.zeros(n, bool)
    mask[sel] = True
    pos_dumped = jnp.asarray(np.where(mask, np.asarray(pos), n_nodes),
                             jnp.int32)
    kern = KO.histogram_private_op(
        packed, gh, pos_dumped, n_nodes, max_bins, bits)
    np.testing.assert_allclose(
        np.asarray(kern), np.asarray(compacted), atol=2e-5)


def test_private_kernel_parity_chunked_build(rng):
    """External-memory consumers: the chunked builder over a chunk stack
    must agree with the kernel over the equivalent flat packed matrix."""
    from repro.core import histogram as H

    n, f, max_bins, n_nodes, chunk_rows = 1000, 4, 64, 4, 256
    bits = C.bits_needed(max_bins - 1)
    bins_np = rng.integers(0, max_bins, size=(n, f)).astype(np.int32)
    gh = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, n_nodes + 1, size=n), jnp.int32)

    chunks = []
    for lo in range(0, n, chunk_rows):
        blk = bins_np[lo:lo + chunk_rows]
        if blk.shape[0] < chunk_rows:  # zero-pad the ragged tail chunk
            blk = np.pad(blk, ((0, chunk_rows - blk.shape[0]), (0, 0)))
        chunks.append(np.asarray(C.pack(jnp.asarray(blk), bits)))
    stack = jnp.asarray(np.stack(chunks))
    chunked = H.build_histograms_chunked(
        stack, gh, pos, n_nodes, max_bins, bits, chunk_rows, n)

    packed = C.pack(jnp.asarray(bins_np), bits)
    kern = KO.histogram_private_op(
        packed, gh, pos, n_nodes, max_bins, bits)
    np.testing.assert_allclose(
        np.asarray(kern), np.asarray(chunked), atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 3, 8), (4, 17, 64), (8, 5, 256)])
def test_split_scan_kernel_sweep(rng, shape):
    n_nodes, f, b = shape
    hist = jnp.asarray(np.abs(rng.normal(size=(n_nodes, f, b, 2))), jnp.float32)
    parent = jnp.sum(hist[:, 0], axis=1)
    got = KO.split_scan_op(hist, parent, 1.0, 0.5)
    want = KR.split_scan_ref(hist, parent, 1.0, 0.5)
    fin = np.isfinite(np.asarray(want[..., 0]))
    assert np.array_equal(np.isfinite(np.asarray(got[..., 0])), fin)
    np.testing.assert_allclose(
        np.asarray(got)[fin], np.asarray(want)[fin], rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("bits", [1, 2, 4, 5, 8, 10, 16])
def test_decompress_kernel_bits(rng, bits):
    n, f = 333, 7
    bins = jnp.asarray(rng.integers(0, 2**bits, size=(n, f)), jnp.int32)
    packed = C.pack(bins, bits)
    got = KO.decompress_op(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(bins))
    want = KR.decompress_ref(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
