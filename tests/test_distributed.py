"""Algorithm 1's multi-device path: shard_map + psum AllReduce equivalence.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps seeing 1 device (per the brief's carve-out).
"""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


def test_row_sharded_equals_single_device():
    """The distributed path is a strategy behind Booster.fit(mesh=...):
    same DeviceDMatrix in, same Booster object out, identical trees."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import Booster, BoosterConfig, DeviceDMatrix
        from repro.core.distributed import train_distributed
        rng = np.random.default_rng(2)
        n, f = 1024, 6
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x @ rng.normal(size=f) > 0).astype(np.float32)
        cfg = BoosterConfig(n_rounds=4, max_depth=3,
                            objective="binary:logistic", max_bins=32)
        dtrain = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
        st = Booster(cfg).fit(dtrain)
        from repro.jaxcompat import make_mesh
        mesh = make_mesh((8,), ("data",))
        bst = Booster(cfg).fit(dtrain, mesh=mesh)
        assert type(bst) is type(st)  # identical object shape out
        ens = bst.ensemble
        assert bool(jnp.all(st.ensemble.feature == ens.feature))
        assert bool(jnp.all(st.ensemble.split_bin == ens.split_bin))
        d = float(jnp.max(jnp.abs(st.ensemble.leaf_value - ens.leaf_value)))
        assert d < 1e-4, d
        # deprecated one-shot shim returns the same Booster type
        legacy = train_distributed(x, y, cfg, mesh)
        assert bool(jnp.all(legacy.ensemble.feature == ens.feature))
        print("ROW-SHARDED-OK")
    """)
    assert "ROW-SHARDED-OK" in out


def test_subsampled_row_sharded_equals_single_device():
    """Stochastic training under mesh=: shards derive the SAME row sample
    and feature masks from the shared (seed, round, class) key, so single-
    and multi-device subsampled fits grow identical trees (DESIGN.md §12)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import Booster, BoosterConfig, DeviceDMatrix
        rng = np.random.default_rng(6)
        n, f = 2048, 6
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x @ rng.normal(size=f) > 0).astype(np.float32)
        cfg = BoosterConfig(n_rounds=4, max_depth=3, max_bins=32,
                            objective="binary:logistic", subsample=0.5,
                            colsample_bytree=0.8, colsample_bylevel=0.9,
                            seed=13)
        dtrain = DeviceDMatrix(x, label=y, max_bins=cfg.max_bins)
        st = Booster(cfg).fit(dtrain)
        from repro.jaxcompat import make_mesh
        mesh = make_mesh((8,), ("data",))
        bst = Booster(cfg).fit(dtrain, mesh=mesh)
        assert bool(jnp.all(st.ensemble.feature == bst.ensemble.feature))
        assert bool(jnp.all(st.ensemble.split_bin == bst.ensemble.split_bin))
        d = float(jnp.max(jnp.abs(st.ensemble.leaf_value
                                  - bst.ensemble.leaf_value)))
        assert d < 1e-4, d
        # monotone constraints compute identically on every shard too
        cfg2 = BoosterConfig(n_rounds=3, max_depth=3, max_bins=32,
                             monotone_constraints=(1, 0, 0, 0, 0, -1))
        st2 = Booster(cfg2).fit(dtrain)
        bst2 = Booster(cfg2).fit(dtrain, mesh=mesh)
        assert bool(jnp.all(st2.ensemble.feature == bst2.ensemble.feature))
        assert bool(jnp.all(st2.ensemble.is_leaf == bst2.ensemble.is_leaf))
        print("SUBSAMPLED-SHARDED-OK")
    """)
    assert "SUBSAMPLED-SHARDED-OK" in out


def test_feature_sharded_equals_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import tree as T
        from repro.core import quantile as Q
        import jax.nn
        rng = np.random.default_rng(3)
        n, f = 512, 8
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = (x @ rng.normal(size=f) > 0).astype(np.float32)
        cuts = Q.compute_cuts(jnp.asarray(x), 32)
        bins = Q.quantize(jnp.asarray(x), cuts)
        p = jax.nn.sigmoid(jnp.zeros(n)); gh = jnp.stack([p - y, p*(1-p)], -1)
        ref = T.grow_tree(bins, gh, cuts, 4, 32)
        from repro.jaxcompat import make_mesh, shard_map
        mesh = make_mesh((4, 2), ("data", "model"))
        fn = jax.jit(shard_map(
            lambda b, g, c: T.grow_tree(b, g, c, 4, 32, axis_name="data",
                                        feature_axis="model"),
            mesh=mesh,
            in_specs=(P("data", "model"), P("data", None), P("model", None)),
            out_specs=P()))
        tr = fn(bins, gh, cuts)
        assert bool(jnp.all(ref.feature == tr.feature))
        assert bool(jnp.all(ref.split_bin == tr.split_bin))
        assert bool(jnp.all(ref.is_leaf == tr.is_leaf))
        print("FEATURE-SHARDED-OK")
    """)
    assert "FEATURE-SHARDED-OK" in out


def test_hlo_analyzer_matches_analytic():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.jaxcompat import make_mesh
        from repro.launch.hlo_analysis import analyze
        mesh = make_mesh((2, 4), ("data", "model"))
        D, L, B = 64, 4, 8
        def fwd(x, ws):
            def body(c, w): return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(y)
        xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        compiled = jax.jit(fwd, in_shardings=(
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P(None, None, "model")),
        )).lower(xs, ws).compile()
        res = analyze(compiled.as_text())
        # per-device: L * (B/2) * D * (D/4) * 2
        assert res["dot_flops_per_device"] == L * (B // 2) * D * (D // 4) * 2, res
        assert res["collective_bytes_total"] > 0
        print("HLO-ANALYZER-OK")
    """)
    assert "HLO-ANALYZER-OK" in out
