"""Streaming quantile sketch (external-memory cut generation, DESIGN.md §11).

Property tests: merge-order invariance in the exact (unpruned) regime,
rank-error bounds vs exact quantiles under pruning, equivalence with
compute_cuts when the summary is exact, and degenerate/constant features.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quantile as Q


def _chunks(x, sizes):
    out, start = [], 0
    for s in sizes:
        out.append(x[start : start + s])
        start += s
    assert start == x.shape[0]
    return out


def test_exact_regime_matches_compute_cuts(rng):
    """With capacity above the distinct-value count the sketch is exact and
    reproduces compute_cuts' interpolation: cuts agree to float32 round-off
    and quantisation agrees everywhere."""
    n, f = 1500, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    x[:, 3] = 2.5  # constant feature
    x[rng.random((n, f)) < 0.03] = np.nan
    x[:, 4] = np.nan  # all-missing feature
    x[:, 5] = rng.integers(0, 3, n)  # low cardinality

    exact = np.asarray(Q.compute_cuts(jnp.asarray(x), 256))
    sk = Q.StreamingQuantileSketch(f, 256, capacity=4096)
    for chunk in _chunks(x, [400, 400, 400, 300]):
        sk.push(chunk)
    sketch = np.asarray(sk.get_cuts())

    assert np.allclose(exact, sketch, rtol=1e-6, atol=0, equal_nan=True)
    bins_exact = np.asarray(Q.quantize(jnp.asarray(x), jnp.asarray(exact)))
    bins_sketch = np.asarray(Q.quantize(jnp.asarray(x), jnp.asarray(sketch)))
    np.testing.assert_array_equal(bins_exact, bins_sketch)


def test_merge_order_invariance_exact_regime(rng):
    """Merging exact summaries is exact, so any merge order produces
    bitwise-identical cuts."""
    n, f = 1200, 5
    x = rng.normal(size=(n, f)).astype(np.float32)
    parts = _chunks(x, [500, 400, 300])
    sketches = []
    for part in parts:
        sk = Q.StreamingQuantileSketch(f, 128, capacity=4096)
        sk.push(part)
        sketches.append(sk)

    def merged(order):
        acc = Q.StreamingQuantileSketch(f, 128, capacity=4096)
        for i in order:
            acc.merge(sketches[i])
        return np.asarray(acc.get_cuts())

    a = merged([0, 1, 2])
    b = merged([2, 0, 1])
    c = merged([1, 2, 0])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)
    # push-streaming the same chunks in order agrees too
    streamed = Q.StreamingQuantileSketch(f, 128, capacity=4096)
    for part in parts:
        streamed.push(part)
    np.testing.assert_array_equal(a, np.asarray(streamed.get_cuts()))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rank_error_bound_under_pruning(seed):
    """Every summary entry's rank uncertainty stays within a small multiple
    of total/capacity after many merge+prune cycles, and querying any rank
    lands within that bound of the true order statistic."""
    rng = np.random.default_rng(seed)
    n, capacity, n_chunks = 40000, 256, 10
    col = (rng.standard_normal(n) ** 3).astype(np.float32)
    sk = Q.StreamingQuantileSketch(1, 256, capacity=capacity)
    for chunk in np.array_split(col, n_chunks):
        sk.push(chunk[:, None])
    srt = np.sort(col)
    eps = 2.0 * n_chunks / capacity  # empirical GK-style bound w/ headroom
    for frac in np.linspace(0.02, 0.98, 25):
        r = frac * (n - 1)
        v = Q._value_at_rank(sk._summaries[0], np.asarray([r]))[0]
        true_rank = np.searchsorted(srt, v)
        assert abs(true_rank - r) <= eps * n, (frac, true_rank, r)


def test_bin_mass_balance_under_pruning(rng):
    """Cuts from a heavily pruned sketch still produce roughly equal-mass
    bins: no bin hoards more than a few times the ideal mass."""
    n = 50000
    col = (rng.standard_normal(n) ** 3).astype(np.float32)
    sk = Q.StreamingQuantileSketch(1, 256, capacity=256)
    for chunk in np.array_split(col, 10):
        sk.push(chunk[:, None])
    cuts = np.asarray(sk.get_cuts())[0]
    finite = cuts[np.isfinite(cuts)]
    assert len(finite) > 100  # the used prefix is substantial
    mass = np.bincount(
        np.searchsorted(finite, col, side="left"), minlength=len(finite) + 1
    )
    assert mass.max() / n <= 1 / Q.n_value_bins(256) + 10 / 256


def test_constant_and_degenerate_features(rng):
    """Constant / all-missing / single-row features match compute_cuts."""
    n = 800
    x = np.zeros((n, 3), np.float32)
    x[:, 0] = 7.25
    x[:, 1] = np.nan
    x[:, 2] = np.where(rng.random(n) < 0.5, -1.0, 3.0)
    exact = np.asarray(Q.compute_cuts(jnp.asarray(x), 64))
    sk = Q.StreamingQuantileSketch(3, 64, capacity=512)
    for chunk in np.array_split(x, 4):
        sk.push(chunk)
    np.testing.assert_array_equal(exact, np.asarray(sk.get_cuts()))
    # constant feature: exactly one finite cut at the value
    cuts0 = np.asarray(sk.get_cuts())[0]
    assert cuts0[0] == np.float32(7.25) and np.all(np.isinf(cuts0[1:]))
    # all-missing: no finite cuts
    assert np.all(np.isinf(np.asarray(sk.get_cuts())[1]))


def test_weighted_sketch_tracks_weighted_quantiles(rng):
    """Weights shift the cut mass: doubling the weight of the upper half
    moves the median cut into it."""
    n = 8000
    col = np.sort(rng.standard_normal(n).astype(np.float32))
    w = np.ones(n)
    w[n // 2 :] = 3.0  # upper half worth 3x
    sk = Q.StreamingQuantileSketch(1, 4, capacity=2048)  # 3 value bins
    for chunk_x, chunk_w in zip(np.array_split(col, 5), np.array_split(w, 5)):
        sk.push(chunk_x[:, None], weights=chunk_w)
    cuts = np.asarray(sk.get_cuts())[0]
    # total mass = 2n; the 1/3 cut sits near weighted rank 2n/3 -> the
    # unweighted median region, well above the unweighted 1/3 quantile.
    assert cuts[0] > col[int(0.45 * n)]


def test_push_and_merge_validation():
    sk = Q.StreamingQuantileSketch(3, 64, capacity=64)
    with pytest.raises(ValueError, match="rows, 3"):
        sk.push(np.zeros((5, 4), np.float32))
    with pytest.raises(ValueError, match="weights"):
        sk.push(np.zeros((5, 3), np.float32), weights=np.ones(4))
    other = Q.StreamingQuantileSketch(2, 64, capacity=64)
    with pytest.raises(ValueError, match="disagree"):
        sk.merge(other)
    with pytest.raises(TypeError):
        sk.merge(np.zeros(3))
    with pytest.raises(ValueError, match="capacity"):
        Q.StreamingQuantileSketch(3, 64, capacity=2)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    max_bins=st.sampled_from([16, 64, 256]),
)
def test_cuts_shape_and_monotone(seed, max_bins):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(300, 2)).astype(np.float32)
    sk = Q.StreamingQuantileSketch(2, max_bins, capacity=64)
    for chunk in np.array_split(x, 3):
        sk.push(chunk)
    cuts = np.asarray(sk.get_cuts())
    nvb = Q.n_value_bins(max_bins)
    assert cuts.shape == (2, nvb - 1)
    assert cuts.dtype == np.float32
    finite = np.where(np.isfinite(cuts), cuts, np.inf)
    assert np.all(np.diff(finite, axis=1) >= 0)
