"""XGBoost model-format interop (DESIGN.md §14).

The real `xgboost` package is an OPTIONAL dev dependency (pyproject's
`interop` extra); the tests that train genuine xgboost models skip when it
is absent. Everything else runs against two xgboost-independent witnesses:

  * schema fixtures — hand-built JSON documents in the exact
    `xgboost.Booster.save_model` schema, and
  * `_oracle_margins` — an independent numpy interpreter of that schema
    (pointer-following, strict `x < t` routing, default_left on NaN,
    probability-space base_score), written against xgboost's documented
    semantics rather than against our import code.

Import correctness = our predict matches the oracle on the same document;
export correctness = the oracle run on OUR exported document matches our
predictions (i.e. a strict-less evaluator reproduces us — which is what
stock xgboost will do when it loads the file).
"""
import json

import numpy as np
import pytest

from repro.core import Booster, DeviceDMatrix
from repro.serve import export_xgboost_json, import_xgboost_json


# --- independent schema interpreter -----------------------------------------

def _prob_to_margin(p, objective):
    if objective == "binary:logistic":
        return float(np.log(p / (1.0 - p)))
    if objective == "count:poisson":
        return float(np.log(p))
    return float(p)


def _oracle_margins(doc, x):
    """Margins per xgboost's documented semantics: strict x < t goes left,
    NaN follows default_left, leaf values accumulate per tree_info class,
    base_score enters margin space via the objective's link."""
    learner = doc["learner"]
    objective = learner["objective"]["name"]
    lmp = learner["learner_model_param"]
    k = max(int(lmp.get("num_class", "0")), 1)
    base = _prob_to_margin(float(lmp["base_score"]), objective)
    model = learner["gradient_booster"]["model"]
    trees = model["trees"]
    tree_info = model.get("tree_info", [0] * len(trees))

    out = np.full((x.shape[0], k), np.float32(base), np.float32)
    for t, tree in enumerate(trees):
        cls = int(tree_info[t]) if k > 1 else 0
        lc, rc = tree["left_children"], tree["right_children"]
        sc = np.asarray(tree["split_conditions"], np.float32)
        si, dl = tree["split_indices"], tree["default_left"]
        for r in range(x.shape[0]):
            nid = 0
            while lc[nid] != -1:
                v = x[r, si[nid]]
                if np.isnan(v):
                    nid = lc[nid] if dl[nid] else rc[nid]
                elif np.float32(v) < sc[nid]:
                    nid = lc[nid]
                else:
                    nid = rc[nid]
            out[r, cls] += sc[nid]
    return out


# --- schema fixture builders ------------------------------------------------

def _leaf(value):
    return {"leaf": float(value)}


def _split(feature, threshold, left, right, default_left=True, gain=1.0):
    return {"f": int(feature), "t": float(threshold), "l": left, "r": right,
            "dl": bool(default_left), "g": float(gain)}


def _tree_doc(spec, num_feature):
    """Nested spec -> an xgboost-schema tree dict (preorder node ids)."""
    nodes = []

    def place(s, parent):
        nid = len(nodes)
        nodes.append(None)
        if "leaf" in s:
            nodes[nid] = dict(leaf=s["leaf"], parent=parent)
        else:
            nodes[nid] = dict(split=s, parent=parent)
            nodes[nid]["left"] = place(s["l"], nid)
            nodes[nid]["right"] = place(s["r"], nid)
        return nid

    place(spec, 2147483647)
    n = len(nodes)
    tree = {
        "base_weights": [0.0] * n,
        "categories": [], "categories_nodes": [],
        "categories_segments": [], "categories_sizes": [],
        "default_left": [0] * n,
        "id": 0,
        "left_children": [-1] * n,
        "loss_changes": [0.0] * n,
        "parents": [nd["parent"] for nd in nodes],
        "right_children": [-1] * n,
        "split_conditions": [0.0] * n,
        "split_indices": [0] * n,
        "split_type": [0] * n,
        "sum_hessian": [1.0] * n,
        "tree_param": {
            "num_deleted": "0", "num_feature": str(num_feature),
            "num_nodes": str(n), "size_leaf_vector": "1",
        },
    }
    for nid, nd in enumerate(nodes):
        if "leaf" in nd:
            tree["split_conditions"][nid] = nd["leaf"]
            tree["base_weights"][nid] = nd["leaf"]
        else:
            s = nd["split"]
            tree["left_children"][nid] = nd["left"]
            tree["right_children"][nid] = nd["right"]
            tree["split_conditions"][nid] = s["t"]
            tree["split_indices"][nid] = s["f"]
            tree["default_left"][nid] = int(s["dl"])
            tree["loss_changes"][nid] = s["g"]
    return tree


def _model_doc(tree_specs, *, objective, num_feature, base_score,
               num_class=0, tree_info=None):
    trees = [_tree_doc(s, num_feature) for s in tree_specs]
    for i, t in enumerate(trees):
        t["id"] = i
    k = max(num_class, 1)
    return {
        "learner": {
            "attributes": {},
            "feature_names": [], "feature_types": [],
            "gradient_booster": {
                "model": {
                    "gbtree_model_param": {
                        "num_parallel_tree": "1",
                        "num_trees": str(len(trees)),
                    },
                    "iteration_indptr": list(
                        range(0, len(trees) + 1, k)
                    ),
                    "tree_info": tree_info if tree_info is not None
                    else [i % k for i in range(len(trees))],
                    "trees": trees,
                },
                "name": "gbtree",
            },
            "learner_model_param": {
                "base_score": repr(base_score),
                "boost_from_average": "1",
                "num_class": str(num_class),
                "num_feature": str(num_feature),
                "num_target": "1",
            },
            "objective": {"name": objective},
        },
        "version": [2, 0, 0],
    }


@pytest.fixture
def rng_x():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(300, 4)).astype(np.float32)
    x[rng.random(x.shape) < 0.15] = np.nan
    return x


# --- import: fixtures vs the oracle -----------------------------------------

def test_import_regression_matches_oracle(rng_x):
    doc = _model_doc(
        [
            _split(0, 0.1, _split(1, -0.5, _leaf(1.0), _leaf(2.0)),
                   _leaf(-1.0), default_left=False),
            _split(2, 0.7, _leaf(0.25), _split(3, 0.0, _leaf(-0.5),
                   _leaf(0.5), default_left=True)),
        ],
        objective="reg:squarederror", num_feature=4, base_score=1.5,
    )
    bst = import_xgboost_json(doc)
    got = np.asarray(bst.predict_margins(rng_x))
    np.testing.assert_allclose(
        got, _oracle_margins(doc, rng_x), rtol=1e-5, atol=1e-6
    )


def test_import_strict_less_boundary():
    """x == threshold must go RIGHT (xgboost is strict less); x one float32
    ulp below must go left — the nextafter nudge, at the exact boundary."""
    t = np.float32(0.75)
    doc = _model_doc(
        [_split(0, float(t), _leaf(-7.0), _leaf(7.0))],
        objective="reg:squarederror", num_feature=1, base_score=0.0,
    )
    bst = import_xgboost_json(doc)
    x = np.array(
        [[t], [np.nextafter(t, np.float32(-np.inf), dtype=np.float32)]],
        np.float32,
    )
    got = np.asarray(bst.predict_margins(x))[:, 0]
    np.testing.assert_array_equal(got, [7.0, -7.0])
    np.testing.assert_array_equal(_oracle_margins(doc, x)[:, 0], got)


def test_import_nan_default_direction():
    doc = _model_doc(
        [
            _split(0, 0.0, _leaf(-1.0), _leaf(1.0), default_left=True),
            _split(0, 0.0, _leaf(-10.0), _leaf(10.0), default_left=False),
        ],
        objective="reg:squarederror", num_feature=1, base_score=0.0,
    )
    bst = import_xgboost_json(doc)
    x = np.array([[np.nan]], np.float32)
    # tree 1: NaN -> left (-1); tree 2: NaN -> right (+10).
    np.testing.assert_allclose(np.asarray(bst.predict_margins(x)), [[9.0]])


def test_import_binary_logistic_base_score(rng_x):
    doc = _model_doc(
        [_split(0, 0.0, _leaf(-0.4), _leaf(0.6))],
        objective="binary:logistic", num_feature=4, base_score=0.2,
    )
    bst = import_xgboost_json(doc)
    want = _oracle_margins(doc, rng_x)
    np.testing.assert_allclose(
        np.asarray(bst.predict_margins(rng_x)), want, rtol=1e-5, atol=1e-6
    )
    # predict applies the sigmoid, like xgboost's predict on this objective
    np.testing.assert_allclose(
        np.asarray(bst.predict(rng_x)),
        1.0 / (1.0 + np.exp(-want[:, 0])), rtol=1e-5, atol=1e-6,
    )


def test_import_multiclass_reorders_tree_info(rng_x):
    """Trees arrive class-shuffled within each iteration; import must map
    them onto the arena's round-robin layout by tree_info."""
    specs = [
        _split(0, 0.0, _leaf(0.1), _leaf(0.2)),   # iter 0, class 1
        _split(1, 0.0, _leaf(0.3), _leaf(0.4)),   # iter 0, class 0
        _split(2, 0.0, _leaf(0.5), _leaf(0.6)),   # iter 0, class 2
        _split(3, 0.0, _leaf(0.7), _leaf(0.8)),   # iter 1, class 2
        _split(0, 0.5, _leaf(0.9), _leaf(1.0)),   # iter 1, class 0
        _split(1, 0.5, _leaf(1.1), _leaf(1.2)),   # iter 1, class 1
    ]
    doc = _model_doc(
        specs, objective="multi:softmax", num_feature=4, base_score=0.5,
        num_class=3, tree_info=[1, 0, 2, 2, 0, 1],
    )
    bst = import_xgboost_json(doc)
    np.testing.assert_allclose(
        np.asarray(bst.predict_margins(rng_x)),
        _oracle_margins(doc, rng_x), rtol=1e-5, atol=1e-6,
    )


def test_import_from_string_and_file(tmp_path, rng_x):
    doc = _model_doc(
        [_leaf(2.0)], objective="reg:squarederror", num_feature=4,
        base_score=0.0,
    )
    from_dict = import_xgboost_json(doc)
    from_str = import_xgboost_json(json.dumps(doc))
    path = tmp_path / "model.json"
    path.write_text(json.dumps(doc))
    from_file = import_xgboost_json(str(path))
    for bst in (from_dict, from_str, from_file):
        np.testing.assert_allclose(
            np.asarray(bst.predict_margins(rng_x[:5])), 2.0
        )


def test_import_rejections():
    base = _model_doc(
        [_leaf(1.0)], objective="reg:squarederror", num_feature=2,
        base_score=0.0,
    )
    dart = json.loads(json.dumps(base))
    dart["learner"]["gradient_booster"]["name"] = "dart"
    with pytest.raises(ValueError, match="gbtree"):
        import_xgboost_json(dart)

    forest = json.loads(json.dumps(base))
    forest["learner"]["gradient_booster"]["model"]["gbtree_model_param"][
        "num_parallel_tree"] = "4"
    with pytest.raises(ValueError, match="num_parallel_tree"):
        import_xgboost_json(forest)

    cat = _model_doc(
        [_split(0, 0.0, _leaf(1.0), _leaf(2.0))],
        objective="reg:squarederror", num_feature=2, base_score=0.0,
    )
    cat["learner"]["gradient_booster"]["model"]["trees"][0][
        "split_type"][0] = 1
    with pytest.raises(ValueError, match="categorical"):
        import_xgboost_json(cat)

    alien = json.loads(json.dumps(base))
    alien["learner"]["objective"]["name"] = "survival:cox"
    with pytest.raises(ValueError, match="unsupported objective"):
        import_xgboost_json(alien)


# --- export: oracle on our documents ----------------------------------------

def _train(objective, n_classes=1, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(500, 5)).astype(np.float32)
    x[rng.random(x.shape) < 0.1] = np.nan
    if n_classes > 1:
        y = ((np.nan_to_num(x[:, 0]) > 0)
             + (np.nan_to_num(x[:, 1]) > 0.5)).astype(np.float32)
    elif objective == "binary:logistic":
        y = (np.nan_to_num(x[:, 0]) > 0).astype(np.float32)
    else:
        y = (np.nan_to_num(x[:, 0])
             + 0.2 * rng.normal(size=500)).astype(np.float32)
    d = DeviceDMatrix(x, label=y, max_bins=32)
    bst = Booster(n_rounds=4, max_depth=3, max_bins=32,
                  objective=objective, n_classes=n_classes, seed=seed).fit(d)
    return bst, x


@pytest.mark.parametrize("objective,k", [
    ("reg:squarederror", 1),
    ("binary:logistic", 1),
    ("multi:softmax", 3),
])
def test_export_semantics_under_strict_less(objective, k):
    """The oracle (strict-less evaluator, as stock xgboost) run on OUR
    exported JSON must reproduce our margins — the ulp-up nudge at work."""
    bst, x = _train(objective, k)
    doc = export_xgboost_json(bst)
    np.testing.assert_allclose(
        _oracle_margins(doc, x), np.asarray(bst.predict_margins(x)),
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize("objective,k", [
    ("reg:squarederror", 1),
    ("binary:logistic", 1),
    ("multi:softmax", 3),
])
def test_export_import_round_trip_bit_exact(objective, k):
    bst, x = _train(objective, k)
    back = import_xgboost_json(export_xgboost_json(bst))
    np.testing.assert_array_equal(
        np.asarray(back.predict_margins(x)),
        np.asarray(bst.predict_margins(x)),
    )
    # thresholds survive a second hop unchanged (pred/succ are inverses)
    d1 = export_xgboost_json(bst)
    d2 = export_xgboost_json(back)
    for t1, t2 in zip(
        d1["learner"]["gradient_booster"]["model"]["trees"],
        d2["learner"]["gradient_booster"]["model"]["trees"],
    ):
        assert t1["split_conditions"] == t2["split_conditions"]
        assert t1["left_children"] == t2["left_children"]


def test_export_writes_file(tmp_path):
    bst, x = _train("reg:squarederror")
    path = tmp_path / "model.json"
    doc = export_xgboost_json(bst, str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(doc))


def test_export_unfitted_raises():
    with pytest.raises(RuntimeError, match="not fitted"):
        export_xgboost_json(Booster())


# --- against real xgboost (optional dep, skip-if-absent) --------------------

try:
    import xgboost as xgb
except ImportError:  # pragma: no cover - exercised when the extra is absent
    xgb = None

requires_xgboost = pytest.mark.skipif(
    xgb is None, reason="xgboost not installed (pip install .[interop])"
)


def _xgb_train(objective, x, y, k=0):
    params = {"objective": objective, "max_depth": 3, "eta": 0.3,
              "base_score": 0.5, "tree_method": "hist"}
    if k:
        params["num_class"] = k
    dtrain = xgb.DMatrix(x, label=y)
    return xgb.train(params, dtrain, num_boost_round=5)


@requires_xgboost
@pytest.mark.parametrize("objective,k", [
    ("reg:squarederror", 0),
    ("binary:logistic", 0),
    ("multi:softprob", 3),
])
def test_real_xgboost_import_parity(tmp_path, objective, k):
    rng = np.random.default_rng(5)
    x = rng.normal(size=(400, 6)).astype(np.float32)
    x[rng.random(x.shape) < 0.1] = np.nan
    if k:
        y = ((np.nan_to_num(x[:, 0]) > 0)
             + (np.nan_to_num(x[:, 1]) > 0.5)).astype(np.float32)
    elif objective == "binary:logistic":
        y = (np.nan_to_num(x[:, 0]) > 0).astype(np.float32)
    else:
        y = np.nan_to_num(x[:, 0]).astype(np.float32)
    model = _xgb_train(objective, x, y, k)
    path = tmp_path / "xgb.json"
    model.save_model(str(path))

    bst = import_xgboost_json(str(path))
    ours = np.asarray(bst.predict_margins(x))
    theirs = model.predict(xgb.DMatrix(x), output_margin=True)
    np.testing.assert_allclose(
        ours, theirs.reshape(ours.shape), rtol=1e-5, atol=1e-5
    )


@requires_xgboost
def test_real_xgboost_loads_our_export(tmp_path):
    bst, x = _train("binary:logistic")
    path = tmp_path / "ours.json"
    export_xgboost_json(bst, str(path))
    model = xgb.Booster(model_file=str(path))
    theirs = model.predict(xgb.DMatrix(x), output_margin=True)
    np.testing.assert_allclose(
        theirs, np.asarray(bst.predict_margins(x))[:, 0],
        rtol=1e-5, atol=1e-5,
    )
