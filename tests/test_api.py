"""The two-noun public API: DeviceDMatrix + self-describing Booster.

Covers the ISSUE 2 acceptance surface: save/load round-trips predicting
bit-identically with no per-call max_depth/objective/n_classes, update()
continuation matching a single longer fit, early stopping halting at the
recorded best_iteration with per-round in-scan eval metrics, and
DeviceDMatrix reuse across fits.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Booster, BoosterConfig, DeviceDMatrix, train


@pytest.fixture(scope="module")
def reg_data():
    rng = np.random.default_rng(5)
    n, f = 1200, 6
    x = rng.normal(size=(n, f)).astype(np.float32)
    y = (x @ rng.normal(size=f) + 0.4 * x[:, 0] * x[:, 1]).astype(np.float32)
    x[rng.random(x.shape) < 0.03] = np.nan
    return x[:900], y[:900], x[900:], y[900:]


@pytest.fixture(scope="module")
def multi_data():
    rng = np.random.default_rng(6)
    n, f, k = 800, 5, 3
    centers = rng.normal(size=(k, f)) * 2.5
    y = rng.integers(0, k, size=n)
    x = (centers[y] + rng.normal(size=(n, f))).astype(np.float32)
    return x, y.astype(np.float32), k


def _ensembles_equal(a, b, atol=0.0):
    assert bool(jnp.all(a.feature == b.feature))
    assert bool(jnp.all(a.split_bin == b.split_bin))
    assert bool(jnp.all(a.is_leaf == b.is_leaf))
    if atol == 0.0:
        np.testing.assert_array_equal(np.asarray(a.leaf_value),
                                      np.asarray(b.leaf_value))
        np.testing.assert_array_equal(np.asarray(a.threshold),
                                      np.asarray(b.threshold))
    else:
        np.testing.assert_allclose(np.asarray(a.leaf_value),
                                   np.asarray(b.leaf_value), atol=atol)


def test_dmatrix_surface(reg_data):
    xt, yt, xv, yv = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    assert dtrain.n_rows == xt.shape[0]
    assert dtrain.n_features == xt.shape[1]
    assert dtrain.max_bins == 32
    assert 1 <= dtrain.bits <= 8
    assert dtrain.nbytes > 0
    dval = DeviceDMatrix(xv, label=yv, ref=dtrain)
    assert dval.same_cuts(dtrain) and dval.max_bins == dtrain.max_bins


def test_save_load_regression_bit_identical(reg_data, tmp_path):
    """Booster.load(path).predict(xv) reproduces pre-save predictions with
    no max_depth/objective/n_classes argument anywhere in the call."""
    xt, yt, xv, yv = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    bst = Booster(n_rounds=8, max_depth=4, objective="reg:squarederror",
                  max_bins=32).fit(dtrain)
    before = np.asarray(bst.predict(xv))
    path = str(tmp_path / "reg.msgpack")
    bst.save(path)
    loaded = Booster.load(path)
    np.testing.assert_array_equal(before, np.asarray(loaded.predict(xv)))
    _ensembles_equal(bst.ensemble, loaded.ensemble)
    assert loaded.cfg == bst.cfg and loaded.base_score == bst.base_score


def test_save_load_multiclass_bit_identical(multi_data, tmp_path):
    x, y, k = multi_data
    dtrain = DeviceDMatrix(x, label=y, max_bins=32)
    bst = Booster(n_rounds=5, max_depth=3, objective="multi:softmax",
                  n_classes=k, max_bins=32).fit(dtrain)
    before = np.asarray(bst.predict(x))  # class ids, self-described
    path = str(tmp_path / "multi.msgpack")
    bst.save(path)
    after = np.asarray(Booster.load(path).predict(x))
    np.testing.assert_array_equal(before, after)
    assert np.mean(before == y) > 0.9


def test_checkpoint_rejects_foreign_payload(tmp_path):
    from repro.checkpoint import load_booster, save_pytree

    path = str(tmp_path / "not_a_booster.msgpack")
    save_pytree(path, {"weights": np.zeros(3)})
    with pytest.raises(ValueError, match="not a repro.booster"):
        load_booster(path)


def test_update_matches_single_longer_fit(reg_data):
    """Warm-start continuation re-enters the scan with the existing margins:
    fit(6) + update(6) must equal fit(12) bit-for-bit on squared error."""
    xt, yt, _, _ = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    kw = dict(max_depth=3, objective="reg:squarederror", max_bins=32)
    b_long = Booster(n_rounds=12, **kw).fit(dtrain)
    b_cont = Booster(n_rounds=6, **kw).fit(dtrain).update(dtrain, 6)
    assert b_cont.n_rounds_trained == 12
    _ensembles_equal(b_long.ensemble, b_cont.ensemble)
    np.testing.assert_array_equal(np.asarray(b_long.margins),
                                  np.asarray(b_cont.margins))


def test_dmatrix_reuse_identical_fits(reg_data):
    """Quantise once, fit twice: the same DeviceDMatrix through two fresh
    Boosters must produce identical ensembles."""
    xt, yt, _, _ = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    kw = dict(n_rounds=5, max_depth=3, objective="reg:squarederror",
              max_bins=32)
    b1 = Booster(**kw).fit(dtrain)
    b2 = Booster(**kw).fit(dtrain)
    _ensembles_equal(b1.ensemble, b2.ensemble)


def test_early_stopping_halts_and_records_best(reg_data):
    """Noise validation labels: valid rmse bottoms out early; fit must stop
    before n_rounds, truncate to best_iteration+1 and record per-round
    in-scan eval history."""
    xt, yt, xv, _ = reg_data
    rng = np.random.default_rng(9)
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    dval = DeviceDMatrix(xv, label=rng.normal(size=xv.shape[0]).astype(np.float32),
                         ref=dtrain)
    bst = Booster(n_rounds=60, max_depth=3, learning_rate=0.5,
                  objective="reg:squarederror", max_bins=32)
    bst.fit(dtrain, evals=[(dval, "valid")], early_stopping_rounds=5)
    assert bst.n_rounds_trained < 60  # halted early
    assert bst.best_iteration == bst.n_rounds_trained - 1  # truncated to best
    assert bst.ensemble.n_trees == bst.best_iteration + 1
    # history is honest per-round in-scan eval, best matches the record
    vals = [h["valid_rmse"] for h in bst.history]
    assert len(vals) == len({h["round"] for h in bst.history})
    assert int(np.argmin(vals)) == bst.best_iteration
    assert bst.best_score == pytest.approx(min(vals))


def test_in_scan_eval_matches_posthoc_eval(reg_data):
    """Per-round eval metrics computed inside the compiled scan must agree
    with a post-hoc Booster.eval on the same matrix (bin-space traversal is
    exact vs raw thresholds)."""
    xt, yt, xv, yv = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    dval = DeviceDMatrix(xv, label=yv, ref=dtrain)
    bst = Booster(n_rounds=6, max_depth=3, objective="reg:squarederror",
                  max_bins=32).fit(dtrain, evals=[(dval, "valid")])
    assert [h["round"] for h in bst.history] == list(range(6))
    final = bst.eval(dval, "valid")["valid_rmse"]
    assert bst.history[-1]["valid_rmse"] == pytest.approx(final, rel=1e-5)
    # raw-threshold prediction agrees with the binned in-scan path
    m = np.asarray(bst.predict(xv))
    rmse = float(np.sqrt(np.mean((m - yv) ** 2)))
    assert rmse == pytest.approx(final, rel=1e-5)


def test_predict_accepts_numpy_jax_dmatrix(reg_data):
    xt, yt, xv, _ = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    bst = Booster(n_rounds=4, max_depth=3, objective="reg:squarederror",
                  max_bins=32).fit(dtrain)
    dv = DeviceDMatrix(xv, ref=dtrain)  # unlabelled is fine for predict
    p_np = np.asarray(bst.predict(xv))
    p_jx = np.asarray(bst.predict(jnp.asarray(xv)))
    p_dm = np.asarray(bst.predict(dv))
    np.testing.assert_array_equal(p_np, p_jx)
    np.testing.assert_array_equal(p_np, p_dm)


def test_early_stopping_without_evals_rejected(reg_data):
    xt, yt, _, _ = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    bst = Booster(n_rounds=4, max_depth=2, objective="reg:squarederror",
                  max_bins=32)
    with pytest.raises(ValueError, match="eval set"):
        bst.fit(dtrain, early_stopping_rounds=3)


def test_refit_reuses_compiled_train_fn(reg_data):
    """Quantise-once must not be eaten by recompilation: a second fit on the
    same config + shapes reuses the cached compiled scan."""
    from repro.core import booster as B

    xt, yt, _, _ = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    kw = dict(n_rounds=4, max_depth=3, objective="reg:squarederror",
              max_bins=32)
    import time

    B._TRAIN_FN_CACHE.clear()  # hermetic: earlier tests may have warmed it
    t0 = time.perf_counter()
    Booster(**kw).fit(dtrain)
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    Booster(**kw).fit(dtrain)
    t_second = time.perf_counter() - t0
    # compile dominates the first fit at this size; a cached refit must be
    # several times faster (loose bound: CI machines are noisy)
    assert t_second < 0.6 * t_first, (t_first, t_second)


def test_mismatched_max_bins_rejected(reg_data):
    """A matrix quantised at a different bin count than the booster expects
    must be rejected (bin-space thresholds would silently disagree)."""
    xt, yt, _, _ = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=64)
    bst = Booster(n_rounds=2, max_depth=2, objective="reg:squarederror",
                  max_bins=32)
    with pytest.raises(ValueError, match="max_bins"):
        bst.fit(dtrain)


def test_mismatched_cuts_rejected(reg_data):
    xt, yt, xv, yv = reg_data
    dtrain = DeviceDMatrix(xt, label=yt, max_bins=32)
    foreign = DeviceDMatrix(xv, label=yv, max_bins=32)  # own cuts
    bst = Booster(n_rounds=3, max_depth=2, objective="reg:squarederror",
                  max_bins=32)
    with pytest.raises(ValueError, match="different cuts"):
        bst.fit(dtrain, evals=[(foreign, "valid")])
    bst.fit(dtrain)
    with pytest.raises(ValueError, match="different cuts"):
        bst.predict(foreign)


def test_legacy_eval_set_history_is_per_round(reg_data):
    """Satellite: the legacy train(eval_set=...) path must record honest
    per-round entries (not a single end-of-training record tagged with the
    final round id)."""
    xt, yt, xv, yv = reg_data
    cfg = BoosterConfig(n_rounds=5, max_depth=3,
                        objective="reg:squarederror", max_bins=32)
    st = train(xt, yt, cfg, eval_set=(xv, yv))
    recs = [h for h in st.history if "valid_rmse" in h]
    assert [h["round"] for h in recs] == list(range(5))
    assert all(np.isfinite(h["valid_rmse"]) for h in recs)
